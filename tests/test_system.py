"""End-to-end system tests: the paper's graph-analytics application, the
LM train/serve drivers, and the dry-run analysis machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_graph_run_end_to_end(capsys):
    """The paper's kind of end-to-end driver: generate graph, run all six
    primitives, validate each against its oracle."""
    from repro.launch.graph_run import main
    main(["--graph", "rmat", "--scale", "9", "--edge-factor", "8",
          "--primitives", "bfs,sssp,pagerank,cc,bc,tc,wtf",
          "--validate"])
    out = capsys.readouterr().out
    assert out.count("PASS") == 6     # wtf has no PASS/FAIL oracle line
    assert "FAIL" not in out


def test_train_driver_with_failure_injection(tmp_path):
    from repro.launch.train import main
    report = main(["--arch", "minicpm-2b", "--smoke", "--steps", "12",
                   "--batch", "4", "--seq", "64",
                   "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
                   "--simulate-failure", "7"])
    assert report["completed"]
    assert report["restarts"] == 1
    losses = [h["loss"] for h in report["history"]]
    assert losses[-1] < losses[0]


def test_train_driver_quantized_optimizer(tmp_path):
    from repro.launch.train import main
    report = main(["--arch", "yi-6b", "--smoke", "--steps", "6",
                   "--batch", "4", "--seq", "64",
                   "--quantized-optimizer"])
    assert report["completed"]
    assert all(np.isfinite(h["loss"]) for h in report["history"])


def test_serve_driver(capsys):
    from repro.launch.serve import main
    main(["--arch", "minicpm-2b", "--smoke", "--requests", "4",
          "--batch", "2", "--prompt-len", "16", "--gen-len", "8"])
    out = capsys.readouterr().out
    assert "4 requests" in out


def test_wsd_schedule_used_for_minicpm():
    """The MiniCPM arch trains with its published WSD schedule."""
    from repro.launch.train import main
    report = main(["--arch", "minicpm-2b", "--smoke", "--steps", "10",
                   "--batch", "2", "--seq", "32"])
    lrs = [h["lr"] for h in report["history"]]
    # warmup then flat(ish) stable phase — strictly nondecreasing early
    assert lrs[1] >= lrs[0]


def test_parse_collectives():
    from repro.launch.dryrun import parse_collectives
    hlo = """
  %all-gather.67 = f32[4096,128]{1,0} all-gather(%x), replica_groups=[16,16]<=[16,16]T(1,0), dimensions={0}
  %all-reduce.1 = f32[8,128]{1,0} all-reduce(%dot), channel_id=1, replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %rs = bf16[128]{0} reduce-scatter(%y), replica_groups=[4,2]<=[2,4]T(1,0), dimensions={0}
  %cp = f32[64]{0} collective-permute(%z), source_target_pairs={{0,1}}
    """
    got = parse_collectives(hlo)
    per = got["per_op"]
    assert per["all-gather"]["count"] == 1
    assert per["all-gather"]["bytes"] == 4096 * 128 * 4
    assert per["all-reduce"]["link_bytes"] == 2 * 8 * 128 * 4
    assert per["reduce-scatter"]["link_bytes"] == 128 * 2 * 2
    assert per["collective-permute"]["bytes"] == 64 * 4


def test_probe_extrapolation_arithmetic():
    """fixed + units×marginal reconstruction used by the dry-run."""
    c1, c2, k1, k2, units = 110.0, 210.0, 1, 2, 32
    marginal = (c2 - c1) / (k2 - k1)
    fixed = c1 - k1 * marginal
    assert fixed == 10.0
    assert fixed + units * marginal == 10.0 + 3200.0


def test_input_specs_cover_all_cells():
    """Every (arch × applicable shape) produces well-formed input specs."""
    from repro.configs import ARCH_IDS, get_config, shapes_for
    from repro.models import build_model
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build_model(cfg)
        for name, shp in shapes_for(cfg).items():
            sds = model.input_specs(shp, shp["kind"])
            assert sds, (arch, name)
            for k, v in sds.items():
                assert all(int(d) > 0 for d in v.shape), (arch, name, k)
