"""Semiring sparse-linear-algebra layer (repro.linalg).

Coverage demanded by the PR-3 checklist:
  * semiring SpMV / SpMM / SpGEMM parity matrix — xla vs pallas, masked
    vs unmasked (vs complemented), structural vs weighted;
  * dense numpy oracles per semiring;
  * tc vs networkx triangle counts (and the tc_ref oracle);
  * label_propagation convergence on a planted-partition graph;
  * reach vs the bfs depth ≤ k oracle;
  * Graph.from_csr builds ELL metadata once; the pagerank / lp / reach
    impls trace with abstract values only (no host sync — one-trace
    tests);
  * the csr_spmv shim's removal (the one-release deprecation expired).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import linalg
from repro.core import backend as B
from repro.core import graph as G
from repro.core import ref as R
from repro.core.primitives import (label_propagation, pagerank, reach,
                                   reach_batch, triangle_count)
from repro.core.primitives.tc import triangle_count_full
from repro.linalg import (max_min, min_plus, or_and, plus_and, plus_times,
                          semiring)

GRAPHS = ["rmat", "grid"]
SEMIRINGS = [plus_times, min_plus, or_and, max_min, plus_and]


@pytest.fixture(params=GRAPHS)
def any_graph(request, rmat_graph, grid_graph):
    return {"rmat": rmat_graph, "grid": grid_graph}[request.param]


def _dense(graph, structural):
    ro = np.asarray(graph.row_offsets)
    ci = np.asarray(graph.col_indices)
    n = len(ro) - 1
    src = np.repeat(np.arange(n), np.diff(ro))
    a = np.zeros((n, n), np.float32)
    if structural or graph.edge_values is None:
        a[src, ci] = 1.0
    else:
        a[src, ci] = np.asarray(graph.edge_values)
    return a


def _dense_product(a, x, sr):
    """Dense semiring oracle: y[i] = ⊕_j a[i,j] ⊗ x[j] over stored nnz."""
    nnz = a != 0
    mul = {"times": lambda p, q: p * q, "plus": lambda p, q: p + q,
           "and": np.minimum, "min": np.minimum,
           "max": np.maximum}[sr.mul]
    red = {"plus": np.sum, "min": np.min, "max": np.max,
           "or": np.max}[sr.add]
    y = np.full(a.shape[0], sr.zero, np.float32)
    for i in range(a.shape[0]):
        js = np.nonzero(nnz[i])[0]
        if len(js):
            y[i] = red(mul(a[i, js], x[js]))
    return y


# ---------------------------------------------------------------------------
# semiring objects
# ---------------------------------------------------------------------------


def test_semirings_are_jit_closable():
    for sr in SEMIRINGS:
        hash(sr)                                  # hashable (static arg)
        assert semiring.get(sr.name) is sr
    with pytest.raises(ValueError):
        semiring.get("tropical_typo")
    with pytest.raises(ValueError):
        semiring.Semiring("bad", "xor", "times", 0.0, 1.0)


# ---------------------------------------------------------------------------
# SpMV parity matrix: backends × semirings × (un)masked
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sr", SEMIRINGS, ids=lambda s: s.name)
@pytest.mark.parametrize("masked", ["unmasked", "masked", "complemented"])
def test_spmv_parity(any_graph, sr, masked):
    g = any_graph
    n = g.num_vertices
    rng = np.random.default_rng(3)
    x = rng.random(n).astype(np.float32)
    mask = rng.random(n) < 0.5 if masked != "unmasked" else None
    kw = dict(semiring=sr, mask=mask, complement=masked == "complemented")
    yx = np.asarray(linalg.spmv(g, x, backend="xla", **kw))
    yp = np.asarray(linalg.spmv(g, x, backend="pallas", **kw))
    np.testing.assert_allclose(yx, yp, rtol=1e-5, atol=1e-5)
    # dense oracle (weighted values)
    a = _dense(g, structural=False)
    want = _dense_product(a, x, sr)
    if mask is not None:
        eff = ~mask if masked == "complemented" else mask
        want = np.where(eff, want, sr.zero).astype(np.float32)
    np.testing.assert_allclose(yx, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_spmv_structural_and_transpose(rmat_graph, backend):
    g = rmat_graph
    n = g.num_vertices
    rng = np.random.default_rng(5)
    x = rng.random(n).astype(np.float32)
    a = _dense(g, structural=True)
    ys = np.asarray(linalg.spmv(g, x, structural=True, backend=backend))
    np.testing.assert_allclose(ys, a @ x, rtol=1e-4, atol=1e-4)
    yt = np.asarray(linalg.spmv(g, x, structural=True, transpose=True,
                                backend=backend))
    np.testing.assert_allclose(yt, a.T @ x, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_spmsv_matches_dense_spmv(rmat_graph, backend):
    """SpMSpV with an all-active sparse vector ≡ the CSC-transpose SpMV;
    with a partial frontier ≡ the dense product of the zero-padded x."""
    g = rmat_graph
    n = g.num_vertices
    rng = np.random.default_rng(6)
    x = rng.random(n).astype(np.float32)
    full = np.asarray(linalg.spmsv(g, np.arange(n), x, backend=backend))
    want = np.asarray(linalg.spmv(g, x, transpose=True, backend=backend))
    np.testing.assert_allclose(full, want, rtol=1e-4, atol=1e-4)
    ids = np.unique(rng.integers(0, n, 40))
    sparse_x = np.zeros(n, np.float32)
    sparse_x[ids] = x[ids]
    got = np.asarray(linalg.spmsv(g, ids, x[ids], backend=backend))
    want = np.asarray(linalg.spmv(g, sparse_x, transpose=True,
                                  backend=backend))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_spmsv_duplicate_ids_expand_fully(rmat_graph):
    """Duplicate frontier lanes each contribute (the serving driver pads
    ragged batches by repeating sources): the default capacity must
    cover the duplicated expansion, not just m."""
    g = rmat_graph
    n = g.num_vertices
    deg = np.diff(np.asarray(g.row_offsets))
    hub = int(np.argmax(deg))
    got = np.asarray(linalg.spmsv(g, [hub, hub], [1.0, 2.0],
                                  structural=True, backend="xla"))
    x_eff = np.zeros(n, np.float32)
    x_eff[hub] = 3.0                    # plus_times: lanes sum per id
    want = np.asarray(linalg.spmv(g, x_eff, structural=True,
                                  transpose=True, backend="xla"))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_from_csr_sorts_rows_for_the_probe():
    """The SpGEMM/intersection probe binary-searches rows; from_csr must
    deliver sorted neighbor lists even from unsorted input."""
    g = G.Graph.from_csr(np.asarray([0, 2, 4, 6]),
                         np.asarray([2, 1, 2, 0, 1, 0]))   # triangle
    assert np.array_equal(np.asarray(g.col_indices), [1, 2, 0, 2, 0, 1])
    c = linalg.mxm(g, g, ([0], [1]), semiring=plus_and,
                   b_transpose=True, structural=True, backend="xla")
    assert int(c[0]) == 1                  # common neighbor: vertex 2


def test_spmsv_under_jit_requires_static_cap(rmat_graph):
    g = rmat_graph
    with pytest.raises(ValueError, match="cap_out"):
        jax.jit(lambda i: linalg.spmsv(g, i))(jnp.asarray([0, 0]))
    got = jax.jit(lambda i: linalg.spmsv(g, i, structural=True,
                                         cap_out=4 * g.num_edges))(
        jnp.asarray([0, 0]))
    want = linalg.spmsv(g, [0, 0], structural=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_raw_triple_rejects_transpose(rmat_graph):
    g = rmat_graph
    triple = (g.row_offsets, g.col_indices, None)
    x = np.ones(g.num_vertices, np.float32)
    with pytest.raises(ValueError, match="transpose"):
        linalg.spmv(triple, x, transpose=True, backend="xla")
    with pytest.raises(ValueError, match="transpose"):
        # mxm's default b side needs column access → same guard
        linalg.mxm(g, triple, (np.zeros(4, np.int32),
                               np.zeros(4, np.int32)),
                   semiring=plus_and, backend="xla")


# ---------------------------------------------------------------------------
# SpMM parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sr", [plus_times, or_and], ids=lambda s: s.name)
@pytest.mark.parametrize("masked", [False, True])
def test_spmm_parity(any_graph, sr, masked):
    g = any_graph
    n = g.num_vertices
    rng = np.random.default_rng(7)
    x = (rng.random((n, 5)) < 0.4).astype(np.float32)
    mask = rng.random(n) < 0.6 if masked else None
    yx = np.asarray(linalg.spmm(g, x, semiring=sr, mask=mask,
                                structural=True, backend="xla"))
    yp = np.asarray(linalg.spmm(g, x, semiring=sr, mask=mask,
                                structural=True, backend="pallas"))
    np.testing.assert_allclose(yx, yp, rtol=1e-5, atol=1e-5)
    a = _dense(g, structural=True)
    want = a @ x if sr is plus_times else ((a @ x) > 0).astype(np.float32)
    if mask is not None:
        want = np.where(mask[:, None], want, sr.zero)
    np.testing.assert_allclose(yx, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# masked SpGEMM (mxm) parity + oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sr", [plus_and, plus_times, or_and],
                         ids=lambda s: s.name)
def test_mxm_parity_and_oracle(any_graph, sr):
    g = any_graph
    n = g.num_vertices
    rng = np.random.default_rng(11)
    msrc = rng.integers(0, n, 64).astype(np.int32)
    mdst = rng.integers(0, n, 64).astype(np.int32)
    cx = np.asarray(linalg.mxm(g, g, (msrc, mdst), semiring=sr,
                               b_transpose=True, structural=True,
                               backend="xla"))
    cp = np.asarray(linalg.mxm(g, g, (msrc, mdst), semiring=sr,
                               b_transpose=True, structural=True,
                               backend="pallas"))
    np.testing.assert_allclose(cx, cp, rtol=1e-5, atol=1e-5)
    a = _dense(g, structural=True) != 0
    mul = np.minimum if sr.mul in ("and", "min") else \
        (lambda p, q: p * q) if sr.mul == "times" else np.add
    red = np.max if sr.add in ("max", "or") else np.sum
    want = np.zeros(len(msrc), np.float32)
    for e, (u, v) in enumerate(zip(msrc, mdst)):
        ws = np.nonzero(a[u] & a[v])[0]
        if len(ws):
            want[e] = red(mul(np.float32(1.0), np.ones(len(ws),
                                                       np.float32)))
    np.testing.assert_allclose(cx, want)


def test_mxm_csc_path_matches_transpose_path(rmat_graph):
    """A ⊗ B via b's CSC mirror ≡ A ⊗ (bᵀ)ᵀ via the shared-structure
    path when B is symmetric-free... exercised by comparing against the
    dense oracle on the general (non-shared) path."""
    g = rmat_graph
    n = g.num_vertices
    rng = np.random.default_rng(13)
    msrc = rng.integers(0, n, 32).astype(np.int32)
    mdst = rng.integers(0, n, 32).astype(np.int32)
    got = np.asarray(linalg.mxm(g, g, (msrc, mdst), semiring=plus_and,
                                structural=True, backend="xla"))
    a = _dense(g, structural=True) != 0
    want = np.array([(a[u] & a[:, v]).sum() for u, v in zip(msrc, mdst)],
                    np.float32)
    np.testing.assert_allclose(got, want)


# ---------------------------------------------------------------------------
# primitives through the algebra layer
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_tc_matches_networkx(any_graph, backend):
    nx = pytest.importorskip("networkx")
    g = any_graph
    src, dst = G.edge_list(g)
    gx = nx.Graph()
    gx.add_nodes_from(range(g.num_vertices))
    gx.add_edges_from(zip(src.tolist(), dst.tolist()))
    want = sum(nx.triangles(gx).values()) // 3
    r = triangle_count(g, backend=backend)
    assert int(r.total) == want == R.tc_ref(g)
    # per-edge counts sum to the total and the full variant agrees
    assert int(np.asarray(r.per_edge).sum()) == want


def test_tc_full_variant(grid_graph):
    want = R.tc_ref(grid_graph)
    assert int(triangle_count_full(grid_graph, backend="xla")) == want


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_pagerank_matches_networkx(rmat_graph, backend):
    nx = pytest.importorskip("networkx")
    g = rmat_graph
    src, dst = G.edge_list(g)
    gx = nx.DiGraph()
    gx.add_nodes_from(range(g.num_vertices))
    gx.add_edges_from(zip(src.tolist(), dst.tolist()))
    want = np.array([v for _, v in sorted(
        nx.pagerank(gx, alpha=0.85, tol=1e-12, max_iter=200).items())])
    r = pagerank(g, max_iter=100, tol=1e-10, backend=backend)
    np.testing.assert_allclose(np.asarray(r.rank), want, atol=1e-5)


def _planted_partition(blocks=4, size=50, p_in=0.3, p_out=0.005, seed=0):
    rng = np.random.default_rng(seed)
    n = blocks * size
    member = np.repeat(np.arange(blocks), size)
    iu, ju = np.triu_indices(n, k=1)
    same = member[iu] == member[ju]
    p = np.where(same, p_in, p_out)
    keep = rng.random(len(iu)) < p
    return (G.from_edge_list(iu[keep], ju[keep], n=n, undirected=True),
            member)


def test_label_propagation_planted_partition():
    g, member = _planted_partition()
    r = label_propagation(g, max_iter=30, backend="xla")
    assert int(r.iterations) < 30              # converged, not capped
    labels = np.asarray(r.labels)
    # each planted block should be dominated by a single label, and
    # dominant labels should differ across blocks (communities resolved)
    dominants = []
    for b in range(member.max() + 1):
        blk = labels[member == b]
        top, cnt = np.unique(blk, return_counts=True)
        purity = cnt.max() / len(blk)
        assert purity >= 0.9, (b, purity)
        dominants.append(top[np.argmax(cnt)])
    assert len(set(dominants)) == len(dominants)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_label_propagation_matches_ref(grid_graph, backend):
    r = label_propagation(grid_graph, max_iter=5, backend=backend)
    want = R.label_propagation_ref(grid_graph, max_iter=5)
    assert np.array_equal(np.asarray(r.labels), want)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_reach_vs_bfs_depth_oracle(rmat_graph, backend):
    g = rmat_graph
    srcs = [3, 99, 250, 3]                     # ragged + duplicate lanes
    for k in (1, 3):
        r = reach_batch(g, srcs, k, backend=backend)
        for i, s in enumerate(srcs):
            want = R.reach_ref(g, s, k)
            assert np.array_equal(np.asarray(r.reached[i]), want), (s, k)
            assert int(r.counts[i]) == int(want.sum())
    single = reach(g, 3, 2, backend=backend)
    assert np.array_equal(np.asarray(single.reached), R.reach_ref(g, 3, 2))


# ---------------------------------------------------------------------------
# metadata / jit-cleanliness / registry / deprecation
# ---------------------------------------------------------------------------


def test_graph_from_csr_builds_metadata_once(rmat_graph):
    ro = np.asarray(rmat_graph.row_offsets)
    ci = np.asarray(rmat_graph.col_indices)
    ev = np.asarray(rmat_graph.edge_values)
    g2 = G.Graph.from_csr(ro, ci, ev)
    assert g2.ell_width == rmat_graph.ell_width
    assert g2.csc_ell_width == rmat_graph.csc_ell_width
    assert np.array_equal(np.asarray(g2.csc_offsets),
                          np.asarray(rmat_graph.csc_offsets))
    assert np.array_equal(np.asarray(g2.csc_indices),
                          np.asarray(rmat_graph.csc_indices))
    # no-CSC build leaves the mirror (and its width) absent
    g3 = G.Graph.from_csr(ro, ci, build_csc=False)
    assert not g3.has_csc and g3.csc_ell_width is None
    assert isinstance(g3.ell_width, int)


def test_algebra_impls_trace_without_host_sync(rmat_graph):
    """One-trace tests: every algebra-layer primitive must trace with
    abstract values only (a hidden device_get / recomputed ELL width
    would raise ConcretizationTypeError under eval_shape)."""
    from repro.core.primitives.label_propagation import _lp_impl
    from repro.core.primitives.pagerank import _pagerank_impl
    from repro.core.primitives.reach import _reach_impl
    g = rmat_graph
    inv_deg = jnp.zeros((g.num_vertices,), jnp.float32)
    for bk in ("xla", "pallas"):
        jax.eval_shape(
            lambda gg, iv: _pagerank_impl(gg, iv, jnp.float32(0.85),
                                          jnp.float32(0.0), 2, bk,
                                          g.csc_ell_width), g, inv_deg)
        jax.eval_shape(
            lambda gg: _lp_impl(gg, jnp.arange(g.num_vertices,
                                               dtype=jnp.int32), 2, bk,
                                g.ell_width, g.num_vertices, 32), g)
        jax.eval_shape(
            lambda gg: _reach_impl(gg, jnp.asarray([0, 1], jnp.int32), 2,
                                   bk, g.csc_ell_width), g)


def test_pagerank_pallas_requires_build_time_metadata(rmat_graph):
    """The satellite fix: the ELL width is never recomputed in the
    wrapper — a metadata-less Graph is rejected on the pallas path."""
    bare = G.Graph(row_offsets=rmat_graph.row_offsets,
                   col_indices=rmat_graph.col_indices,
                   csc_offsets=rmat_graph.csc_offsets,
                   csc_indices=rmat_graph.csc_indices)
    with pytest.raises(ValueError, match="from_csr"):
        pagerank(bare, backend="pallas")
    # the xla path never needed the width and still runs
    r = pagerank(bare, max_iter=2, backend="xla")
    assert np.isfinite(np.asarray(r.rank)).all()


def test_linalg_ops_registered_on_both_backends():
    for op in ("spmv", "spmm", "mxm"):
        assert B.registered(op, B.XLA), op
        assert B.registered(op, B.PALLAS), op
        assert B.dispatch(op, B.PALLAS) is not B.dispatch(op, B.XLA)


def test_csr_spmv_shim_removed():
    # the one-release csr_spmv deprecation shim has expired: the symbol
    # must be gone (its replacement is repro.linalg.spmv)
    from repro.kernels import ops as K
    assert not hasattr(K, "csr_spmv")
