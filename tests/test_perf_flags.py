"""Opt-in performance flags: int8 KV cache, int8 MoE weights,
sequence-sharded activation checkpoints — correctness contracts."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models.moe import moe_ffn, moe_init

rng = np.random.default_rng(0)


def test_kv_quant_decode_consistency():
    cfg = get_smoke_config("yi-6b").replace(kv_quant=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, s = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 1)), jnp.int32)
    pf = jax.jit(functools.partial(model.prefill, cache_len=s + 4))
    _, cache = pf(params, {"tokens": toks[:, :s]})
    assert cache["k"].dtype == jnp.int8
    lg2, _ = jax.jit(model.decode_step)(params, cache,
                                        {"tokens": toks[:, s:s + 1]})
    lgd, _ = jax.jit(model.prefill)(params, {"tokens": toks})
    # both paths quantize identically => tight match
    assert float(jnp.max(jnp.abs(lg2 - lgd))) < 2e-3


def test_kv_quant_close_to_bf16_model():
    cfg = get_smoke_config("yi-6b")
    model = build_model(cfg)
    model_q = build_model(cfg.replace(kv_quant=True))
    params = model.init(jax.random.PRNGKey(1))
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    lg, _ = jax.jit(model.prefill)(params, {"tokens": toks})
    lgq, _ = jax.jit(model_q.prefill)(params, {"tokens": toks})
    rel = float(jnp.linalg.norm(lg - lgq) / jnp.linalg.norm(lg))
    assert rel < 0.05, rel


def test_weight_quant_moe_close():
    cfg = get_smoke_config("qwen3-moe-235b-a22b").replace(
        capacity_factor=8.0)
    cfg_q = cfg.replace(weight_quant=True)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    p_q = moe_init(jax.random.PRNGKey(0), cfg_q, jnp.float32)
    assert p_q["w1"].dtype == jnp.int8
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    y, _ = moe_ffn(p, x, cfg)
    yq, _ = moe_ffn(p_q, x, cfg_q)
    rel = float(jnp.linalg.norm(y - yq) / jnp.linalg.norm(y))
    assert rel < 0.05, rel


def test_weight_quant_param_specs_cover_scales():
    cfg = get_smoke_config("qwen3-moe-235b-a22b").replace(
        weight_quant=True)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = model.param_specs({"data": 2, "model": 4})
    # spec tree must match the quantized param tree structure
    jax.tree.map(lambda a, b: None, params, specs)


def test_seq_shard_acts_semantics_unchanged():
    cfg = get_smoke_config("yi-6b")
    model = build_model(cfg)
    model_s = build_model(cfg.replace(seq_shard_acts=True, remat="full"))
    params = model.init(jax.random.PRNGKey(2))
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)),
                                   jnp.int32)}
    l1, _ = jax.jit(model.loss)(params, batch)
    l2, _ = jax.jit(model_s.loss)(params, batch)
    assert np.isclose(float(l1), float(l2), rtol=1e-5)
    g1 = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    g2 = jax.grad(lambda p: model_s.loss(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
