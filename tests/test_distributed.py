"""Distributed behavior under 8 fake devices — run in subprocesses so the
main test session keeps 1 device (the dry-run contract)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_distributed_parity_vs_single_device():
    """2- and 4-way partitions must reproduce the single-device
    primitives: BFS labels bit-identical; PageRank ranks equal to within
    one float32 ulp-scale bound (the psum combines per-device partial
    sums whose addition order differs from the single-device sweep — the
    only permitted deviation). The graph carries an isolated tail so the
    last partition's local frontier is empty in every iteration."""
    out = run_sub("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import graph as G
        from repro.core.partition import partition_1d
        from repro.core.distributed import distributed_bfs, \\
            distributed_pagerank
        from repro.core.primitives import bfs, pagerank

        base = G.rmat(8, 8, seed=3)
        src_e, dst_e = G.edge_list(base)
        n2 = base.num_vertices * 2
        g = G.from_edge_list(src_e, dst_e, n=n2)  # [n, 2n) isolated
        deg = np.diff(np.asarray(g.row_offsets))
        src = int(np.argmax(deg))
        r1 = bfs(g, src)
        p1 = pagerank(g, max_iter=12)
        for p in (2, 4):
            pg = partition_1d(g, p)
            mesh = Mesh(np.array(jax.devices()[:p]), ("graph",))
            rd = distributed_bfs(pg, src, mesh)
            assert np.array_equal(np.asarray(rd.labels),
                                  np.asarray(r1.labels)), p
            # the empty-frontier lane really is empty: the tail
            # partition owns only isolated vertices
            vpp = pg.verts_per_part
            assert np.asarray(r1.labels)[(p - 1) * vpp:].max() < 0
            pd = distributed_pagerank(pg, mesh, iters=12)
            assert np.allclose(np.asarray(pd), np.asarray(p1.rank),
                               rtol=0, atol=1e-7), p
        print("PARITY_OK")
    """, devices=4)
    assert "PARITY_OK" in out


def test_distributed_bfs_and_pagerank():
    out = run_sub("""
        import numpy as np, jax
        from repro.core import graph as G, ref as R
        from repro.core.partition import partition_1d
        from repro.core.distributed import distributed_bfs, \\
            distributed_pagerank
        g = G.rmat(9, 8, seed=3)
        pg = partition_1d(g, 8)
        from repro.jax_compat import make_mesh
        mesh = make_mesh((8,), ("graph",))
        deg = np.diff(np.asarray(g.row_offsets))
        src = int(np.argmax(deg))
        r = distributed_bfs(pg, src, mesh)
        assert np.array_equal(np.asarray(r.labels), R.bfs_ref(g, src))
        pr = distributed_pagerank(pg, mesh, iters=12)
        assert np.allclose(np.asarray(pr), R.pagerank_ref(g, iters=12),
                           atol=1e-6)
        print("DIST_OK")
    """)
    assert "DIST_OK" in out


def test_pipeline_parallel_mlp():
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.parallel.pipeline import pipeline_apply
        from repro.jax_compat import make_mesh
        mesh = make_mesh((4,), ("stage",))
        rng = np.random.default_rng(0)
        ws = jnp.asarray(rng.standard_normal((4, 16, 16)) * 0.3,
                         jnp.float32)
        x = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
        y = pipeline_apply(lambda w, h: jnp.tanh(h @ w), ws, x, mesh,
                           n_microbatches=8)
        ref = x
        for i in range(4):
            ref = jnp.tanh(ref @ ws[i])
        assert float(jnp.max(jnp.abs(y - ref))) < 1e-6
        print("PIPE_OK")
    """, devices=4)
    assert "PIPE_OK" in out


def test_sharded_train_step_dp_tp():
    """2-way DP × 4-way TP training step on a smoke model: loss finite,
    params sharded per spec, runs end to end."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.data import make_batch_for
        from repro.jax_compat import set_mesh
        from repro.launch.mesh import make_test_mesh, mesh_axis_sizes
        from repro.parallel.sharding import tree_shardings
        from repro.train import adamw, make_schedule

        cfg = get_smoke_config("yi-6b")
        model = build_model(cfg)
        mesh = make_test_mesh(2, 4)
        with set_mesh(mesh):
            params = model.init(jax.random.PRNGKey(0))
            specs = model.param_specs(mesh_axis_sizes(mesh))
            sh = tree_shardings(mesh, specs)
            params = jax.tree.map(
                lambda p, s: jax.device_put(p, s), params, sh)
            opt_init, opt_update = adamw(
                make_schedule("constant", 1e-3, 10))
            opt = opt_init(params)
            batch = make_batch_for(cfg, {"global_batch": 4,
                                         "seq_len": 32}, "train")

            @jax.jit
            def step(p, o, b):
                (l, m), g = jax.value_and_grad(model.loss,
                                               has_aux=True)(p, b)
                p, o, _ = opt_update(g, o, p)
                return p, o, l

            params, opt, loss = step(params, opt, batch)
            assert np.isfinite(float(loss))
            # TP sharding visible on attention weights
            wq = params["layers"]["attn"]["wq"]
            assert "model" in str(wq.sharding.spec)
        print("DPTP_OK", float(loss))
    """)
    assert "DPTP_OK" in out


def test_moe_ep_sharded():
    """MoE under data×model mesh: EP dispatch compiles + finite output."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.data import make_batch_for
        from repro.jax_compat import set_mesh
        from repro.launch.mesh import make_test_mesh, mesh_axis_sizes

        cfg = get_smoke_config("qwen3-moe-235b-a22b")
        model = build_model(cfg)
        mesh = make_test_mesh(2, 4)   # E=8 experts over model=4
        with set_mesh(mesh):
            params = model.init(jax.random.PRNGKey(0))
            batch = make_batch_for(cfg, {"global_batch": 4,
                                         "seq_len": 32}, "train")
            loss, m = jax.jit(model.loss)(params, batch)
            assert np.isfinite(float(loss))
        print("EP_OK")
    """)
    assert "EP_OK" in out


def test_elastic_reshard_across_meshes():
    """Save on (2,4) mesh, restore on (4,2) — elastic scale change."""
    out = run_sub("""
        import tempfile, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.ckpt import save_checkpoint, restore_checkpoint
        from repro.jax_compat import set_mesh
        from repro.launch.mesh import make_test_mesh
        from repro.parallel.sharding import tree_shardings

        t = {"w": jnp.arange(64.0).reshape(8, 8)}
        spec = {"w": P("data", "model")}
        m1 = make_test_mesh(2, 4)
        with set_mesh(m1):
            sh = tree_shardings(m1, spec)
            t1 = jax.tree.map(jax.device_put, t, sh)
            with tempfile.TemporaryDirectory() as d:
                save_checkpoint(d, 1, t1)
                m2 = make_test_mesh(4, 2)
                got, _ = restore_checkpoint(
                    d, 1, jax.tree.map(jnp.zeros_like, t), mesh=m2,
                    spec_tree=spec)
                assert np.array_equal(np.asarray(got["w"]),
                                      np.asarray(t["w"]))
                assert got["w"].sharding.mesh.shape["data"] == 4
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_production_mesh_smoke_lower():
    """make_production_mesh(512 fake devices) + lower/compile a smoke
    model train step with full sharding machinery — the dry-run path."""
    out = run_sub("""
        import os
        assert os.environ["XLA_FLAGS"].endswith("512")
        import jax, jax.numpy as jnp
        from repro.jax_compat import cost_analysis
        from repro.launch.mesh import make_production_mesh
        from repro.launch.dryrun import lower_program
        from repro.configs import get_smoke_config
        for mp in (False, True):
            mesh = make_production_mesh(multi_pod=mp)
            cfg = get_smoke_config("yi-6b").replace(scan_layers=True)
            compiled = lower_program(
                cfg, {"global_batch": 64, "seq_len": 128,
                      "kind": "train"}, "train", mesh, False)
            assert cost_analysis(compiled)["flops"] > 0
        print("PRODMESH_OK")
    """, devices=512, timeout=1200)
    assert "PRODMESH_OK" in out
