"""Subgraph matching vs brute-force oracle."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import graph as G
from repro.core.primitives.subgraph import subgraph_match, \
    subgraph_match_ref

TRIANGLE = (3, [(0, 1), (0, 2), (1, 2)])
PATH3 = (3, [(0, 1), (1, 2)])
STAR3 = (4, [(0, 1), (0, 2), (0, 3)])
SQUARE = (4, [(0, 1), (1, 2), (2, 3), (0, 3)])


@pytest.mark.parametrize("query", [TRIANGLE, PATH3, STAR3, SQUARE])
def test_match_vs_oracle(query):
    g = G.rmat(7, 4, seed=11)
    n_q, q_edges = query
    r = subgraph_match(g, n_q, q_edges, cap=500000)
    ref = subgraph_match_ref(g, n_q, q_edges)
    assert not r.truncated
    assert int(r.count) == ref, (query, int(r.count), ref)


def test_truncation_flag():
    g = G.rmat(7, 4, seed=11)
    r = subgraph_match(g, *STAR3, cap=1000)
    assert r.truncated and int(r.count) == 1000


def test_triangle_query_equals_tc_times_automorphisms():
    from repro.core import ref as R
    g = G.rmat(7, 4, seed=3)
    r = subgraph_match(g, TRIANGLE[0], TRIANGLE[1], cap=200000)
    # ordered embeddings = 6 per undirected triangle (|Aut(K3)| = 6)
    assert int(r.count) == 6 * R.tc_ref(g)


def test_labels_filter():
    # path a-b-c with labels [0,1,0]: only even->odd->even paths
    src = [0, 1, 2, 3]
    dst = [1, 2, 3, 4]
    g = G.from_edge_list(src, dst, n=5, undirected=True)
    import jax.numpy as jnp
    labels = jnp.asarray([0, 1, 0, 1, 0])
    r = subgraph_match(g, 3, [(0, 1), (1, 2)], cap=64, labels=labels,
                       q_labels=[0, 1, 0])
    # paths: 0-1-2, 2-1-0, 2-3-4, 4-3-2
    assert int(r.count) == 4
    emb = np.asarray(r.embeddings)[:int(r.count)]
    assert {tuple(e) for e in emb} == {(0, 1, 2), (2, 1, 0), (2, 3, 4),
                                       (4, 3, 2)}


@given(st.integers(0, 5))
@settings(max_examples=6, deadline=None)
def test_match_property_random(seed):
    g = G.rmat(6, 3, seed=seed)
    r = subgraph_match(g, 3, [(0, 1), (1, 2)], cap=200000)
    assert int(r.count) == subgraph_match_ref(g, 3, [(0, 1), (1, 2)])
    # every returned embedding is a real match
    emb = np.asarray(r.embeddings)[:min(int(r.count), 50)]
    ro = np.asarray(g.row_offsets)
    ci = np.asarray(g.col_indices)
    adj = [set(ci[ro[u]:ro[u + 1]]) for u in range(g.num_vertices)]
    for e in emb:
        assert e[1] in adj[e[0]] and e[2] in adj[e[1]]
        assert len(set(e.tolist())) == 3
