"""Observability layer (PR 8): telemetry oracles + bit-parity, span
tracing, serving metrics, the log knob, and the bench-regression gate."""
import json
import logging
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro import obs
from repro.core import graph as G
from repro.core.primitives import (bc_batch, bfs, bfs_batch,
                                   connected_components, pagerank, sssp,
                                   sssp_batch, triangle_count)
from repro.obs import telemetry as T
from repro.obs.metrics import Histogram, Metrics, latency_summary, quantile

BACKENDS = ("xla", "pallas")


@pytest.fixture(scope="module")
def small_graph():
    return G.rmat(7, 8, seed=3, weighted=True)


def _level_sizes(labels: np.ndarray, steps: int) -> np.ndarray:
    """BFS oracle: telemetry step t records the size of depth-(t+1)
    level (the frontier *after* the step); the final step records 0."""
    lab = labels[labels >= 0]
    counts = np.bincount(lab, minlength=steps + 1)
    expect = np.zeros(steps, np.int64)
    upto = min(steps, len(counts) - 1)
    expect[:upto] = counts[1:upto + 1]
    return expect


# ---------------------------------------------------------------- telemetry

@pytest.mark.parametrize("backend", BACKENDS)
def test_bfs_telemetry_matches_level_oracle(rmat_graph, high_degree_src,
                                            backend):
    r, buf = bfs_batch(rmat_graph, [high_degree_src], backend=backend,
                       telemetry=True)
    trace = T.trim(buf, np.asarray(r.iterations)).lane(0)
    assert trace.steps == int(r.iterations[0])
    expect = _level_sizes(np.asarray(r.labels[0]), trace.steps)
    assert np.array_equal(trace["frontier"], expect)
    # direction column is the per-step push/pull mode: 0 or 1 only
    assert set(np.unique(trace["direction"])) <= {0, 1}
    assert np.all(trace["tier"] > 0)


def test_run_until_any_lane_iters_match_buffer(rmat_graph,
                                               high_degree_src):
    # a ragged batch: the hub plus a low-degree vertex have different
    # eccentricities, so lane iteration counts differ
    deg = np.diff(np.asarray(rmat_graph.row_offsets))
    lo = int(np.argmin(np.where(deg > 0, deg, deg.max() + 1)))
    srcs = [high_degree_src, lo]
    r, buf = bfs_batch(rmat_graph, srcs, telemetry=True)
    lane_iters = np.asarray(r.iterations)
    trace = T.trim(buf, lane_iters)
    # the buffer records every wall-clock step: the slowest lane's count
    assert trace.steps == int(lane_iters.max())
    assert int(buf.cursor) == trace.steps
    for b in range(len(srcs)):
        lane = trace.lane(b)
        assert lane.steps == int(lane_iters[b])
        expect = _level_sizes(np.asarray(r.labels[b]), lane.steps)
        assert np.array_equal(lane["frontier"], expect)
        assert lane["frontier"][-1] == 0        # termination step


def _run(prim, g, src, backend, telemetry):
    if prim == "bfs":
        r = bfs(g, src, backend=backend, telemetry=telemetry)
    elif prim == "sssp":
        r = sssp(g, src, backend=backend, telemetry=telemetry)
    elif prim == "pagerank":
        r = pagerank(g, max_iter=10, backend=backend,
                     telemetry=telemetry)
    elif prim == "cc":
        r = connected_components(g, backend=backend, telemetry=telemetry)
    elif prim == "bc":
        r = bc_batch(g, [src], backend=backend, telemetry=telemetry)
    else:
        r = triangle_count(g, backend=backend, telemetry=telemetry)
    return r[0] if telemetry else r


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("prim", ("bfs", "sssp", "pagerank", "cc", "bc",
                                  "tc"))
def test_telemetry_changes_no_result_bit(small_graph, backend, prim):
    deg = np.diff(np.asarray(small_graph.row_offsets))
    src = int(np.argmax(deg))
    plain = _run(prim, small_graph, src, backend, False)
    with_t = _run(prim, small_graph, src, backend, True)
    la, lb = jax.tree_util.tree_leaves(plain), \
        jax.tree_util.tree_leaves(with_t)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y)), prim


def test_sssp_telemetry_columns(rmat_graph, high_degree_src):
    r, buf = sssp_batch(rmat_graph, [high_degree_src], telemetry=True)
    trace = T.trim(buf, np.asarray(r.iterations)).lane(0)
    assert set(trace.names) == {"frontier", "tier", "bucket",
                                "relaxations"}
    assert np.all(trace["relaxations"] >= 0)
    assert np.all(np.diff(trace["bucket"]) >= 0)    # buckets only grow


def test_distributed_trace_comm_model(rmat_graph, high_degree_src):
    from repro.core.distributed import exchange_bytes_per_step
    from repro.core.partition import partition_1d
    pg = partition_1d(rmat_graph, 2)
    r = bfs(rmat_graph, high_degree_src)
    steps = int(r.iterations)
    trace = T.distributed_trace(pg, "bfs", steps,
                                labels=np.asarray(r.labels))
    assert trace.steps == steps
    per = exchange_bytes_per_step(pg, "bfs")
    assert np.all(trace["exchange_bytes"] == per) and per > 0
    # the frontier column recovered from labels is the same level oracle
    assert np.array_equal(trace["frontier"],
                          _level_sizes(np.asarray(r.labels), steps))


def test_buffer_overflow_drops_but_counts():
    buf = T.TelemetryBuffer.make(2, {"x": ((), np.int32)})
    for i in range(5):
        buf = buf.record(x=i)
    assert int(buf.cursor) == 5
    trace = T.trim(buf)
    assert trace.steps == 2                         # capped at capacity
    assert np.array_equal(trace["x"], [0, 1])       # drops kept rows


def test_format_table_renders_direction():
    buf = T.TelemetryBuffer.make(2, {"frontier": ((1,), np.int32),
                                     "direction": ((1,), np.int32)})
    buf = buf.record(frontier=np.array([7]), direction=np.array([0]))
    buf = buf.record(frontier=np.array([3]), direction=np.array([1]))
    table = T.trim(buf).format_table()
    assert "push" in table and "pull" in table and "frontier" in table


# ------------------------------------------------------------------ metrics

def test_quantiles_linear_interpolation_small_samples():
    xs = [10.0, 20.0]
    assert quantile(xs, 0.5) == pytest.approx(15.0)
    s = latency_summary(xs)
    assert s["samples"] == 2
    assert s["lat_ms_p50"] == pytest.approx(15.0)
    assert s["lat_ms_p99"] == pytest.approx(
        float(np.quantile(xs, 0.99)), abs=0.01)
    one = latency_summary([5.0])
    assert one["lat_ms_p50"] == one["lat_ms_p99"] == 5.0


def test_histogram_streaming_quantiles():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(1.0, 0.7, size=5000)
    h = Histogram()
    h.observe_many(xs)
    for q in (0.5, 0.95, 0.99):
        exact = float(np.quantile(xs, q))
        est = h.quantile(q)
        # log-bucketed with growth sqrt(2): relative error < one bucket
        assert abs(est - exact) / exact < 0.5, (q, est, exact)
    assert h.quantile(0.0) == pytest.approx(float(xs.min()))
    assert h.quantile(1.0) == pytest.approx(float(xs.max()))


def test_histogram_merge_and_layout_guard():
    a, b = Histogram(), Histogram()
    a.observe_many([1.0, 2.0, 4.0])
    b.observe_many([8.0, 16.0])
    a.merge(b)
    assert a.total == 5
    assert a.quantile(1.0) == pytest.approx(16.0)
    with pytest.raises(ValueError):
        a.merge(Histogram(buckets=4))


def test_metrics_render_parseable_prometheus():
    m = Metrics()
    for v in (1.0, 2.0, 3.0, 50.0):
        m.observe("latency_ms", v, help="per-query latency", kind="bfs")
    m.counter("queries_total", 4, help="queries", kind="bfs")
    m.gauge_max("queue_depth_peak", 7, help="peak depth")
    text = m.render()
    import re
    sample = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*"
                        r"(\{[^}]*\})? -?[0-9eE.+-]+(\.[0-9]+)?$|"
                        r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? \+?Inf$")
    names = set()
    for line in text.strip().splitlines():
        if line.startswith("#"):
            continue
        assert sample.match(line), f"bad exposition line: {line!r}"
        names.add(line.split("{")[0].split(" ")[0])
    assert "graph_serve_latency_ms_bucket" in names
    assert "graph_serve_latency_ms_count" in names
    assert "graph_serve_latency_ms_quantile" in names
    assert "graph_serve_queries_total" in names
    assert "graph_serve_queue_depth_peak" in names
    # histogram buckets must be cumulative and end at the sample count
    counts = [float(ln.rsplit(" ", 1)[1])
              for ln in text.splitlines()
              if ln.startswith("graph_serve_latency_ms_bucket")]
    assert counts == sorted(counts) and counts[-1] == 4.0


# ------------------------------------------------------------------ tracing

def test_span_registry_and_chrome_export(tmp_path):
    obs.reset()
    with obs.span("outer", category="setup"):
        with obs.span("inner", category="dispatch",
                      args={"k": 1}):
            pass
    events = obs.registry().events
    assert [e.name for e in events] == ["inner", "outer"]
    out = tmp_path / "trace.json"
    n = obs.export_chrome_trace(str(out))
    assert n == 2
    doc = json.loads(out.read_text())
    assert isinstance(doc["traceEvents"], list)
    for ev in doc["traceEvents"]:
        assert ev["ph"] == "X"
        assert ev["dur"] >= 0 and "ts" in ev and ev["name"]
    inner = [e for e in doc["traceEvents"] if e["name"] == "inner"][0]
    assert inner["args"] == {"k": 1}
    obs.reset()
    assert not obs.registry().events


# ---------------------------------------------------------------------- log

def test_logger_hierarchy_and_env_knob(monkeypatch):
    from repro.obs import log as L
    lg = L.get_logger("tuner")
    assert lg.name == "repro.tuner"
    # no-arg configure is idempotent once installed; forcing a fresh
    # configure re-reads the env knob (keeps the lazy-stdout handler)
    monkeypatch.setenv(L.ENV_VAR, "debug")
    monkeypatch.setattr(L, "_configured", False)
    assert L.configure().level == logging.DEBUG
    monkeypatch.setenv(L.ENV_VAR, "warning")
    monkeypatch.setattr(L, "_configured", False)
    assert L.configure().level == logging.WARNING
    monkeypatch.delenv(L.ENV_VAR)
    L.configure(level=logging.INFO)     # restore the default for the rest


def test_deprecated_still_warns():
    from repro.obs.log import deprecated
    with pytest.warns(DeprecationWarning, match="gone soon"):
        deprecated("gone soon")


def test_use_kernel_deprecation_unchanged(rmat_graph, high_degree_src):
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        bfs(rmat_graph, high_degree_src, use_kernel=False)


# ------------------------------------------------------------ compare gate

COMPARE = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                       "compare.py")


def _compare(tmp_path, fresh_rows, base_rows, threshold="0.25"):
    fp, bp = tmp_path / "fresh.json", tmp_path / "base.json"
    fp.write_text(json.dumps(fresh_rows))
    bp.write_text(json.dumps(base_rows))
    return subprocess.run(
        [sys.executable, COMPARE, str(fp), "--baseline", str(bp),
         "--threshold", threshold],
        capture_output=True, text=True)


def _row(ms, **kw):
    row = {"bench": "frontier_scaling", "primitive": "bfs",
           "backend": "xla", "tiered": True, "n": 512, "m": 4096,
           "ms": ms, "platform": "cpu"}
    row.update(kw)
    return row


def test_compare_passes_within_threshold(tmp_path):
    r = _compare(tmp_path, [_row(11.0)], [_row(10.0)])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


def test_compare_fails_on_injected_slowdown(tmp_path):
    r = _compare(tmp_path, [_row(20.0)], [_row(10.0)])
    assert r.returncode == 1, r.stdout + r.stderr
    assert "REGRESSION" in r.stdout


def test_compare_ignores_unshared_and_cross_platform(tmp_path):
    # different n => different cell; different platform => not compared
    r = _compare(tmp_path,
                 [_row(99.0, n=1024), _row(99.0, platform="gpu")],
                 [_row(10.0)])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "no shared" in r.stdout


def test_compare_skips_rows_without_ms(tmp_path):
    occ = {"bench": "frontier_occupancy", "backend": "xla",
           "frontier": 32, "ms_tiered": 0.1, "ms_pinned": 1.0}
    r = _compare(tmp_path, [_row(10.0), occ], [_row(10.0), occ])
    assert r.returncode == 0
    assert "1 shared cells" in r.stdout or "OK" in r.stdout
