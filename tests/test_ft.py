"""Robustness-layer contracts (PR 10).

  * fault injection: spec parsing, seeded determinism (same seed → the
    same fault schedule), site scoping, context install/restore;
  * budgets: validation, iteration capping, deadlines — and the
    primitive-level partial-result contract (``converged=False`` exactly
    when a budget cut the loop short, bit-identical results otherwise);
  * retry: the backoff schedule is exact and deterministic, escalation
    hands the attempt index to the callable, exhaustion re-raises;
  * degradation ladder: rung order (exact-preserving first), clamping;
  * admission: per-kind and global sheds;
  * chaos-through-serve: every injected fault class leaves the stream
    alive with exactly one terminal status per query, and the metrics
    counters reconcile with the per-query statuses;
  * chaos parity: with a zero-probability plan installed (and after it
    is torn down) the healthy path is bit-identical to never-installed.
"""
import numpy as np
import pytest

from repro import ft
from repro.core import graph as G
from repro.core.primitives import (bfs_batch, pagerank,
                                   reach_batch, sssp_batch)
from repro.ft import inject
from repro.ft.retry import backoff_ms
from repro.launch import graph_serve
from repro.obs.metrics import Metrics

from test_graph_serve import FakeClock, _stub_runner


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    inject._reset_for_tests()
    yield
    inject._reset_for_tests()


# ---- fault injection ------------------------------------------------------

def test_fault_spec_errors():
    for bad in ("provider_miss", "frobnicate@0.5", "nan@lots",
                "nan@1.5", "nan@-0.1"):
        with pytest.raises(inject.FaultSpecError):
            inject.FaultPlan(bad)


def test_fault_plan_is_seed_deterministic():
    spec = "provider_miss@0.5;nan:bfs@0.25"
    a = inject.FaultPlan(spec, seed=7)
    b = inject.FaultPlan(spec, seed=7)
    seq = [(k, s) for k in ("provider_miss", "nan") for s in ("bfs", "sssp")]
    draws_a = [a.should(k, s) for _ in range(40) for k, s in seq]
    draws_b = [b.should(k, s) for _ in range(40) for k, s in seq]
    assert draws_a == draws_b
    assert any(draws_a) and not all(draws_a)
    # a different seed yields a different schedule
    c = inject.FaultPlan(spec, seed=8)
    draws_c = [c.should(k, s) for _ in range(40) for k, s in seq]
    assert draws_c != draws_a


def test_fault_site_scoping():
    plan = inject.FaultPlan("nan:bfs@1.0", seed=0)
    assert plan.should("nan", "bfs")
    assert not plan.should("nan", "sssp")     # clause is site-scoped
    assert not plan.should("straggler", "bfs")  # kind not in the plan


def test_faults_context_installs_and_restores():
    assert inject.active() is None
    with inject.faults("nan@1.0", seed=3) as plan:
        assert inject.active() is plan
        assert plan.seed == 3
    assert inject.active() is None


# ---- budgets --------------------------------------------------------------

def test_budget_validation():
    with pytest.raises(ValueError):
        ft.Budget(max_iters=0)
    with pytest.raises(ValueError):
        ft.Budget(wall_ms=0)
    assert ft.UNLIMITED.cap_iters(17) == 17
    assert ft.UNLIMITED.deadline_from(5.0) is None
    b = ft.Budget(max_iters=3, wall_ms=250.0)
    assert b.cap_iters(17) == 3
    assert b.cap_iters(2) == 2
    assert b.deadline_from(1.0) == pytest.approx(1.25)


@pytest.fixture(scope="module")
def small_graph():
    return G.rmat(6, 8, seed=3, weighted=True)


def test_budget_partial_results_flag_converged(small_graph):
    g = small_graph
    srcs = [0, 1, 2, 3]
    full = bfs_batch(g, srcs, backend="xla")
    assert bool(np.asarray(full.converged).all())
    cut = bfs_batch(g, srcs, backend="xla",
                        budget=ft.Budget(max_iters=1))
    # one hop cannot finish an rmat component: partial + flagged
    assert not bool(np.asarray(cut.converged).all())
    # the partial depths agree with the full run wherever they are set
    d_cut, d_full = np.asarray(cut.labels), np.asarray(full.labels)
    seen = d_cut >= 0
    assert np.array_equal(d_cut[seen], d_full[seen])

    pr_cut = pagerank(g, max_iter=20, backend="xla",
                               budget=ft.Budget(max_iters=2))
    assert not bool(np.asarray(pr_cut.converged))
    assert int(pr_cut.iterations) == 2
    pr_full = pagerank(g, max_iter=20, backend="xla")
    assert bool(np.asarray(pr_full.converged))

    r_cut = reach_batch(g, srcs, k=4, backend="xla",
                              budget=ft.Budget(max_iters=2))
    assert not bool(np.asarray(r_cut.converged))
    assert int(r_cut.hops) == 2
    # the clamped run answers the smaller neighborhood exactly
    r2 = reach_batch(g, srcs, k=2, backend="xla")
    assert np.array_equal(np.asarray(r_cut.reached), np.asarray(r2.reached))

    s_cut = sssp_batch(g, srcs, backend="xla",
                            budget=ft.Budget(max_iters=1))
    assert not bool(np.asarray(s_cut.converged).all())


def test_unbudgeted_results_unchanged(small_graph):
    g = small_graph
    a = bfs_batch(g, [0, 5], backend="xla")
    b = bfs_batch(g, [0, 5], backend="xla", budget=ft.UNLIMITED)
    assert np.array_equal(np.asarray(a.labels), np.asarray(b.labels))
    assert bool(np.asarray(b.converged).all())


# ---- retry ----------------------------------------------------------------

def test_backoff_schedule_is_exact():
    p = ft.RetryPolicy(retries=3, base_ms=10.0, factor=2.0, jitter=0.0)
    assert [backoff_ms(p, a) for a in range(3)] == [10.0, 20.0, 40.0]
    pj = ft.RetryPolicy(retries=3, base_ms=10.0, factor=2.0, jitter=0.5)
    for a in range(3):
        d = backoff_ms(pj, a, seed=11)
        nominal = 10.0 * 2.0 ** a
        assert nominal <= d <= nominal * 1.5
        assert d == backoff_ms(pj, a, seed=11)   # deterministic


def test_with_retry_escalates_and_records_sleeps():
    p = ft.RetryPolicy(retries=2, base_ms=10.0, factor=2.0, jitter=0.0)
    sleeps, seen = [], []

    def flaky(attempt):
        seen.append(attempt)
        if attempt < 2:
            raise RuntimeError("boom")
        return "ok"

    out, attempts = ft.with_retry(flaky, p, sleep=sleeps.append)
    assert out == "ok" and attempts == 3
    assert seen == [0, 1, 2]               # attempt index escalates
    assert sleeps == [0.010, 0.020]        # exact backoff, seconds


def test_with_retry_exhaustion_and_nonretryable():
    p = ft.RetryPolicy(retries=1, base_ms=0.0, jitter=0.0)
    with pytest.raises(RuntimeError):
        ft.with_retry(lambda a: (_ for _ in ()).throw(RuntimeError("x")),
                      p, sleep=lambda s: None)
    calls = []

    def bad(attempt):
        calls.append(attempt)
        raise KeyboardInterrupt

    with pytest.raises(KeyboardInterrupt):
        ft.with_retry(bad, p, retryable=(RuntimeError,),
                      sleep=lambda s: None)
    assert calls == [0]                    # no retry on non-retryable


# ---- degradation ladder ---------------------------------------------------

def test_ladder_orders_exact_preserving_first():
    rungs = ft.ladder("bfs", "pallas", "single")
    assert [(r.backend, r.placement) for r in rungs] == [
        ("pallas", "single"), ("xla", "single")]
    assert rungs[0].reason == "" and "pallas" in rungs[1].reason

    rungs = ft.ladder("sssp", "pallas", "2d")
    assert [(r.backend, r.placement) for r in rungs] == [
        ("pallas", "2d"), ("xla", "2d"), ("xla", "sharded"),
        ("xla", "single")]

    rungs = ft.ladder("reach", "xla", "single", hops=4)
    assert rungs[-1].hops == 2 and rungs[-1].approximate

    rungs = ft.ladder("bc", "xla", "single")
    assert rungs[-1].sampled and rungs[-1].approximate

    # the ladder clamps at the bottom rung
    assert ft.rung_for_attempt(rungs, 99) is rungs[-1]


# ---- admission ------------------------------------------------------------

def test_admission_policy():
    with pytest.raises(ValueError):
        ft.AdmissionPolicy(max_per_kind=0)
    pol = ft.AdmissionPolicy(max_per_kind=2, max_pending=3)
    assert pol.admit("bfs", {"bfs": [1, 2]}) is not None
    assert pol.admit("bfs", {"bfs": [1]}) is None
    assert pol.admit("sssp", {"bfs": [1, 2], "sssp": [3]}) is not None
    assert ft.UNBOUNDED.admit("bfs", {"bfs": list(range(999))}) is None


# ---- chaos through serve_mixed --------------------------------------------

def _ctotal(metrics, name):
    fam = metrics._families.get(f"graph_serve_{name}")
    return 0 if fam is None else int(sum(fam.series.values()))


def _statuses(stats):
    return [q["status"] for q in stats["queries"]]


def _assert_reconciled(stats, metrics):
    """The acceptance invariant: counters == per-query statuses."""
    counts = stats["status_counts"]
    assert sum(counts.values()) == stats["requests"]
    assert all(q is not None for q in stats["queries"])
    for st in graph_serve.STATUSES:
        assert _ctotal(metrics, graph_serve._STATUS_COUNTER[st]) == \
            counts[st], st
    assert _ctotal(metrics, "queries_retried_total") == stats["retried"]


def _serve(queries, clock, monkeypatch, *, spec=None, seed=0,
           backend="xla", **kw):
    monkeypatch.setattr(graph_serve, "time", clock)
    metrics = Metrics()
    kw.setdefault("runner", _stub_runner(clock))
    kw.setdefault("retry", ft.RetryPolicy(retries=2, base_ms=10.0,
                                          jitter=0.0))
    if spec is None:
        stats = graph_serve.serve_mixed(None, queries, batch=2,
                                        backend=backend, metrics=metrics,
                                        **kw)
    else:
        with inject.faults(spec, seed=seed):
            stats = graph_serve.serve_mixed(None, queries, batch=2,
                                            backend=backend, metrics=metrics,
                                            **kw)
    _assert_reconciled(stats, metrics)
    return stats


def test_chaos_provider_miss_exhausts_ladder(monkeypatch):
    clock = FakeClock()
    stats = _serve([("bfs", 0)] * 4, clock, monkeypatch,
                   spec="provider_miss@1.0")
    assert _statuses(stats) == ["error"] * 4
    assert all("ProviderMissError" in q["reason"]
               for q in stats["queries"])
    assert stats["retried"] == 4


def test_chaos_nan_guardrail_retry_recovers(monkeypatch):
    # a seed where the bfs nan stream hits on draw 0 and misses on
    # draw 1: attempt 1 is poisoned, the retry comes back clean
    seed = next(s for s in range(64)
                if inject._draw(s, "nan", "bfs", 0) < 0.6
                and inject._draw(s, "nan", "bfs", 1) >= 0.6)
    clock = FakeClock()
    stats = _serve([("bfs", 0)] * 2, clock, monkeypatch,
                   spec="nan:bfs@0.6", seed=seed)
    assert _statuses(stats) == ["ok", "ok"]
    assert all(q["attempts"] == 2 for q in stats["queries"])
    assert stats["retried"] == 2


def test_chaos_nan_guardrail_terminal_error(monkeypatch):
    clock = FakeClock()
    stats = _serve([("sssp", 0)] * 2, clock, monkeypatch, spec="nan@1.0")
    assert _statuses(stats) == ["error"] * 2
    assert all("PoisonedResultError" in q["reason"]
               for q in stats["queries"])


def test_deadline_expires_in_queue(monkeypatch):
    # sssp#1 (t=0) waits while two bfs batches burn 2 fake seconds; its
    # 1.5 s deadline expires before its batch dispatches. sssp#2 joins
    # at t=2 and completes inside its own window.
    clock = FakeClock()
    queries = [("sssp", 0)] + [("bfs", 0)] * 4 + [("sssp", 0)]
    stats = _serve(queries, clock, monkeypatch,
                   budget=ft.Budget(wall_ms=1500.0))
    by_kind = [q for q in stats["queries"] if q["kind"] == "sssp"]
    assert [q["status"] for q in by_kind] == ["deadline_exceeded", "ok"]
    assert "expired in queue" in by_kind[0]["reason"]
    assert [q["status"] for q in stats["queries"]
            if q["kind"] == "bfs"] == ["ok"] * 4


def test_deadline_late_completion_is_stamped(monkeypatch):
    # every batch costs 1 fake second but the budget is 500 ms: queries
    # still get their (partial-trust) answers, stamped past-deadline
    clock = FakeClock()
    stats = _serve([("bfs", 0)] * 2, clock, monkeypatch,
                   budget=ft.Budget(wall_ms=500.0))
    assert _statuses(stats) == ["deadline_exceeded"] * 2
    assert all("after deadline" in q["reason"] for q in stats["queries"])


def test_admission_sheds_over_cap(monkeypatch):
    # cap below the batch size: the queue holds one query that never
    # fills a batch, so later arrivals shed until the ragged-tail flush
    clock = FakeClock()
    stats = _serve([("bfs", i) for i in range(4)], clock, monkeypatch,
                   admission=ft.AdmissionPolicy(max_per_kind=1))
    assert _statuses(stats) == ["ok", "shed", "shed", "shed"]
    assert all("full" in q["reason"] for q in stats["queries"][1:])


def test_malformed_queries_become_structured_errors(small_graph):
    metrics = Metrics()
    n = small_graph.num_vertices
    queries = [("bfs", 0), ("pagerank_typo", 0), ("bfs", "zero"),
               ("sssp", n + 17), ("sssp", 1)]
    stats = graph_serve.serve_mixed(
        small_graph, queries, batch=1, backend="xla", metrics=metrics,
        retry=ft.RetryPolicy(retries=0, base_ms=0.0, jitter=0.0))
    _assert_reconciled(stats, metrics)
    sts = _statuses(stats)
    assert sts[0] == "ok" and sts[4] == "ok"
    assert sts[1] == sts[2] == sts[3] == "error"
    assert "unknown kind" in stats["queries"][1]["reason"]
    assert "not an integer" in stats["queries"][2]["reason"]
    assert "out of range" in stats["queries"][3]["reason"]


def test_degraded_batch_is_stamped_and_declared(monkeypatch):
    # provider_miss on attempt 0 only: the retry lands on the xla rung
    # and the answers are stamped degraded (not ok, not error)
    seed = next(s for s in range(64)
                if inject._draw(s, "provider_miss", "bfs", 0) < 0.6
                and inject._draw(s, "provider_miss", "bfs", 1) >= 0.6)
    clock = FakeClock()
    stats = _serve([("bfs", 0)] * 2, clock, monkeypatch,
                   spec="provider_miss:bfs@0.6", seed=seed,
                   backend="pallas")
    assert _statuses(stats) == ["degraded"] * 2
    assert all(q["degraded_to"] == "backend pallas→xla"
               for q in stats["queries"])


# ---- chaos parity ---------------------------------------------------------

def test_zero_probability_plan_is_bit_invisible(small_graph):
    g = small_graph
    srcs = [0, 1, 2, 3]
    base = {
        "bfs": np.asarray(bfs_batch(g, srcs, backend="xla").labels),
        "sssp": np.asarray(sssp_batch(g, srcs, backend="xla").dist),
        "pr": np.asarray(pagerank(g, backend="xla").rank),
        "reach": np.asarray(
            reach_batch(g, srcs, k=3, backend="xla").reached),
    }
    spec = "provider_miss@0.0;nan@0.0;straggler@0.0;shard_loss@0.0"
    with inject.faults(spec, seed=1):
        inside = {
            "bfs": np.asarray(bfs_batch(g, srcs, backend="xla").labels),
            "sssp": np.asarray(
                sssp_batch(g, srcs, backend="xla").dist),
            "pr": np.asarray(pagerank(g, backend="xla").rank),
            "reach": np.asarray(
                reach_batch(g, srcs, k=3, backend="xla").reached),
        }
    after = np.asarray(bfs_batch(g, srcs, backend="xla").labels)
    for k in base:
        assert np.array_equal(base[k], inside[k]), k
    assert np.array_equal(base["bfs"], after)


def test_serve_statuses_identical_disabled_vs_never(monkeypatch,
                                                    small_graph):
    queries = [(k, i) for i in range(4)
               for k in ("bfs", "sssp", "pagerank", "reach")]

    def run(spec):
        m = Metrics()
        kw = dict(batch=4, backend="xla", metrics=m,
                  retry=ft.RetryPolicy(retries=0, base_ms=0.0, jitter=0.0))
        if spec is None:
            st = graph_serve.serve_mixed(small_graph, queries, **kw)
        else:
            with inject.faults(spec, seed=5):
                st = graph_serve.serve_mixed(small_graph, queries, **kw)
        _assert_reconciled(st, m)
        return st

    never = run(None)
    disabled = run("provider_miss@0.0;nan@0.0;straggler@0.0;shard_loss@0.0")
    assert _statuses(never) == _statuses(disabled) == ["ok"] * len(queries)
    assert never["status_counts"] == disabled["status_counts"]
