"""Bandwidth-proportional storage layer (PR 6).

Contracts under test:
  * plan ladder: narrowest safe index dtype from n, explicit overrides
    validated (never silently narrowed), bad knobs rejected;
  * delta encoding: exact round trip through decode_cols / gather_cols,
    including the uint16 escape side-list on rows spanning > 0xFFFE ids;
  * x64 drift regression: Graph build under jax_enable_x64 pins every
    structural array to the plan dtype, and int64 plans refuse to build
    without the switch;
  * end-to-end parity: bfs / sssp / pagerank are BIT-identical across
    {int16, int32, delta} storage on both backends (exact semirings
    decode exactly);
  * mixed precision: bf16 PageRank within the documented tolerance,
    bf16 rejected for non-plus-accumulating semirings;
  * resident_bytes accounting matches the arrays it describes.
"""
import jax
import numpy as np
import pytest

from repro.core import graph as G
from repro.core import storage as S
from repro.core.primitives import bfs, pagerank, sssp
from repro.linalg import semiring as SR

BACKENDS = ["xla", "pallas"]
STORAGE_KW = {
    "int16": {},                          # auto ladder picks int16 at n=2^9
    "int32": {"index_dtype": "int32"},
    "delta": {"encoding": "delta"},
}


@pytest.fixture(scope="module")
def storage_graphs():
    """The same scale-9 weighted rmat under every storage plan (one
    topology, three layouts — the parity matrix's fixtures)."""
    return {tag: G.rmat(9, 8, seed=7, weighted=True, **kw)
            for tag, kw in STORAGE_KW.items()}


# ---------------------------------------------------------------------------
# plan ladder
# ---------------------------------------------------------------------------


def test_plan_ladder_picks_narrowest():
    assert S.plan_for(100).index_dtype == "int16"
    assert S.plan_for(2**15).index_dtype == "int16"      # max id 32767
    assert S.plan_for(2**15 + 1).index_dtype == "int32"
    assert S.plan_for(2**31).index_dtype == "int32"
    assert S.plan_for(2**31 + 1).index_dtype == "int64"
    assert S.plan_for(0).index_dtype == "int16"


def test_plan_override_widens_never_narrows():
    assert S.plan_for(100, index_dtype="int64").index_dtype == "int64"
    with pytest.raises(ValueError, match="cannot hold"):
        S.plan_for(10**6, index_dtype="int16")
    with pytest.raises(ValueError):
        S.plan_for(100, index_dtype="int8")
    with pytest.raises(ValueError):
        S.plan_for(100, encoding="rle")
    with pytest.raises(ValueError):
        S.plan_for(100, value_dtype="fp16")


def test_plan_is_static_aux(storage_graphs):
    """The plan rides pytree aux data: hashable, equal across leaves-only
    transforms, and part of the jit cache key."""
    g = storage_graphs["delta"]
    assert g.plan == S.StoragePlan(index_dtype="int16", encoding="delta")
    leaves, treedef = jax.tree_util.tree_flatten(g)
    assert jax.tree_util.tree_unflatten(treedef, leaves).plan == g.plan
    hash(g.plan)


# ---------------------------------------------------------------------------
# delta encoding round trip
# ---------------------------------------------------------------------------


def test_delta_roundtrip(storage_graphs):
    gd, g32 = storage_graphs["delta"], storage_graphs["int32"]
    st = gd.col_store
    assert isinstance(st, S.EncodedCols)
    assert st.delta.dtype == np.uint16
    dense = np.asarray(g32.col_indices)
    assert np.array_equal(np.asarray(S.decode_cols(st)), dense)
    assert np.array_equal(np.asarray(S.decode_cols(gd.csc_store)),
                          np.asarray(g32.csc_indices))
    # gather at random positions, with and without the src hint
    eid = np.random.default_rng(0).integers(0, gd.num_edges, 64)
    row = np.asarray(gd.row_seg)[eid]
    assert np.array_equal(np.asarray(S.gather_cols(st, eid)), dense[eid])
    assert np.array_equal(np.asarray(S.gather_cols(st, eid, row)),
                          dense[eid])


def test_delta_escape_side_list():
    """One row spanning > 0xFFFE vertex ids forces the escape path: the
    sentinel slot reads its true value from the sorted side list while
    inline slots are untouched."""
    n = 70_000
    src = np.array([0, 0, 0, 1], np.int64)
    dst = np.array([1, 2, n - 1, 2], np.int64)       # 0→(n-1): delta 69998
    g = G.from_edge_list(src, dst, n=n, encoding="delta")
    st = g.col_store
    assert st.num_escapes >= 1
    dense = np.asarray(
        G.from_edge_list(src, dst, n=n).col_indices).astype(np.int64)
    assert np.array_equal(np.asarray(S.decode_cols(st)), dense)
    eid = np.arange(g.num_edges)
    assert np.array_equal(np.asarray(S.gather_cols(st, eid)), dense)
    # traversal through the escape store still reaches the far vertex
    labels = np.asarray(bfs(g, 0, backend="xla").labels)
    assert labels[n - 1] == 1


def test_delta_requires_sorted_rows():
    ro = np.array([0, 2], np.int64)
    cols = np.array([5, 1], np.int64)                # descending row
    with pytest.raises(ValueError, match="sorted"):
        S.encode_delta(ro, cols, np.zeros(2, np.int64))


def test_gather_cols_edgeless_store():
    e = np.zeros(0, np.int64)
    for enc in ("dense", "delta"):
        g = G.from_edge_list(e, e, n=4, encoding=enc)
        out = S.gather_cols(g.col_store, np.zeros(3, np.int32))
        assert out.shape == (3,) and np.all(np.asarray(out) == 0)


# ---------------------------------------------------------------------------
# x64 dtype-drift regression (satellite: graph build under enable_x64)
# ---------------------------------------------------------------------------


def test_x64_build_keeps_plan_dtypes():
    with jax.experimental.enable_x64():
        g = G.rmat(6, 4, seed=1, weighted=True)
        assert g.plan.index_dtype == "int16"
        assert g.col_indices.dtype == np.int16
        assert g.row_offsets.dtype == np.int32
        assert g.row_seg.dtype == np.int32
        r = bfs(g, 0, backend="xla")
        assert np.asarray(r.labels).dtype == np.int32
    # and the graph built under x64 keeps working outside the context
    r2 = bfs(g, 0, backend="xla")
    assert np.array_equal(np.asarray(r.labels), np.asarray(r2.labels))


def test_int64_plan_requires_x64():
    e = np.zeros(0, np.int64)
    with pytest.raises(RuntimeError, match="jax_enable_x64"):
        G.from_edge_list(e, e, n=4, index_dtype="int64")
    with jax.experimental.enable_x64():
        g = G.from_edge_list(e, e, n=4, index_dtype="int64")
        assert g.col_indices.dtype == np.int64


# ---------------------------------------------------------------------------
# end-to-end parity: every storage plan, both backends, bit-identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("storage", ["int16", "delta"])
def test_traversal_parity_across_storage(storage_graphs, storage, backend):
    g32 = storage_graphs["int32"]
    g = storage_graphs[storage]
    src = int(np.argmax(np.diff(np.asarray(g32.row_offsets))))
    for name, run in [
        ("bfs", lambda gg: bfs(gg, src, backend=backend).labels),
        ("sssp", lambda gg: sssp(gg, src, backend=backend).dist),
        ("pagerank", lambda gg: pagerank(gg, max_iter=10,
                                         backend=backend).rank),
    ]:
        assert np.array_equal(np.asarray(run(g32)), np.asarray(run(g))), (
            name, storage, backend)


# ---------------------------------------------------------------------------
# mixed precision
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_bf16_pagerank_within_tolerance(storage_graphs, backend):
    g = storage_graphs["delta"]
    full = np.asarray(pagerank(g, max_iter=10, backend=backend).rank)
    half = np.asarray(pagerank(g, max_iter=10, backend=backend,
                               precision="bf16").rank)
    assert half.dtype == np.float32          # fp32 accumulate throughout
    assert float(np.abs(full - half).max()) < 1e-2


def test_bf16_only_for_plus_accumulation():
    sr = SR.with_precision(SR.plus_times, "bf16")
    assert sr.precision == "bf16"
    assert SR.with_precision(sr, "fp32").precision == "fp32"
    with pytest.raises(ValueError, match="plus"):
        SR.with_precision(SR.min_plus, "bf16")
    with pytest.raises(ValueError):
        SR.with_precision(SR.plus_times, "fp8")


def test_bf16_rounds_the_product():
    sr = SR.with_precision(SR.plus_times, "bf16")
    x = np.float32(1.0 + 2.0**-12)           # below bf16 resolution
    assert float(sr.round_prod(x)) == 1.0
    assert float(SR.plus_times.round_prod(x)) == float(x)
    assert float(sr.mul_op(np.float32(3.0), x)) == 3.0


# ---------------------------------------------------------------------------
# resident-byte accounting
# ---------------------------------------------------------------------------


def test_resident_bytes_accounting(storage_graphs):
    rb16 = S.resident_bytes(storage_graphs["int16"])
    rb32 = S.resident_bytes(storage_graphs["int32"])
    rbd = S.resident_bytes(storage_graphs["delta"])
    m = storage_graphs["int32"].num_edges
    # dense column bytes are exactly width × m per direction
    assert rb16["arrays"]["col_storage"] == 2 * m
    assert rb32["arrays"]["col_storage"] == 4 * m
    # delta stream: uint16 per edge + int32 anchor per vertex (+ empty
    # escape lists) per direction — under int32, above bare uint16
    n = storage_graphs["delta"].num_vertices
    assert rbd["arrays"]["col_storage"] == 2 * m + 4 * n
    assert rbd["column_bytes"] < rb32["column_bytes"]
    assert rb16["total_bytes"] == sum(rb16["arrays"].values())
    assert rb16["plan"] == {"index_dtype": "int16", "encoding": "dense",
                            "value_dtype": "fp32"}
    assert rbd["bytes_per_edge"] == round(rbd["column_bytes"] / m, 3)
