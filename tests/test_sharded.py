"""Sharded-placement behavior: registry semantics (in-process) and
bit-parity of sharded vs single-device primitives (subprocess with fake
host-platform devices, like tests/test_distributed.py)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


# ---------------------------------------------------------------------------
# placement as a registry dimension (no devices needed)
# ---------------------------------------------------------------------------


def test_placement_resolution_precedence(monkeypatch):
    from repro.core import backend as B
    assert B.resolve_placement() == B.SINGLE
    monkeypatch.setenv(B.PLACEMENT_ENV_VAR, B.SHARDED)
    assert B.resolve_placement() == B.SHARDED
    with B.use_placement(B.SINGLE):
        assert B.resolve_placement() == B.SINGLE          # context > env
        assert B.resolve_placement(B.SHARDED) == B.SHARDED  # call > ctx
    monkeypatch.delenv(B.PLACEMENT_ENV_VAR)
    with pytest.raises(ValueError):
        B.resolve_placement("mesh")


def test_placement_context_carries_mesh():
    from repro.core import backend as B
    assert B.placement_mesh() is None
    sentinel = object()
    with B.use_placement(B.SHARDED, mesh=sentinel, axis="g"):
        assert B.placement_mesh() == (sentinel, "g")
        with B.use_placement(B.SINGLE):      # inner ctx without a mesh
            assert B.placement_mesh() == (sentinel, "g")
    assert B.placement_mesh() is None


def test_sharded_providers_registered():
    from repro.core import backend as B
    for op in ("advance", "spmv", "spmm", "mxm"):
        assert B.registered(op, B.XLA, B.SHARDED), op
    # single-placement registrations are untouched by the new dimension
    for op in ("spmv", "spmm", "mxm"):
        assert B.registered(op, B.XLA), op
        assert B.registered(op, B.PALLAS), op


def test_sharded_dispatch_never_falls_back_to_single():
    from repro.core import backend as B
    # "compact" has single-placement impls only: sharded dispatch must
    # raise, not silently run the single-device path
    with pytest.raises(KeyError):
        B.dispatch("compact", B.XLA, B.SHARDED)
    # pallas backend falls back across BACKENDS to the xla sharded
    # provider (kernels under shard_map are future work)
    assert B.dispatch("spmv", B.PALLAS, B.SHARDED) \
        is B.dispatch("spmv", B.XLA, B.SHARDED)


def test_plain_graph_under_sharded_placement_is_an_error():
    from repro.core import backend as B
    from repro.core import graph as G
    with pytest.raises(ValueError, match="ShardedGraph"):
        B.resolve_graph_placement(G.demo_graph(), B.SHARDED)


# ---------------------------------------------------------------------------
# bit-parity: sharded vs single device
# ---------------------------------------------------------------------------


def test_sharded_parity_all_primitives():
    """bfs/sssp/cc/pagerank/label_propagation/reach at 2/4/8-way
    partitions bit-match the single-device primitives. The graph has a
    non-divisible vertex count (padded tail part) and an isolated tail
    (parts whose local frontier stays empty every iteration)."""
    out = run_sub("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import graph as G
        from repro.core.partition import partition_1d
        from repro.core.distributed import (
            distributed_bfs, distributed_sssp, distributed_cc,
            distributed_pagerank, distributed_label_propagation,
            distributed_reach)
        from repro.core.primitives import (
            bfs, sssp, connected_components, pagerank,
            label_propagation, reach_batch)

        base = G.rmat(7, 8, seed=3, weighted=True)
        se, de = G.edge_list(base)
        vals = np.asarray(base.edge_values)
        # non-divisible n: 2*128 + 7; vertices [128, 263) are isolated,
        # so tail parts own only empty frontiers
        n2 = base.num_vertices * 2 + 7
        g = G.from_edge_list(se, de, n=n2, values=vals)
        deg = np.diff(np.asarray(g.row_offsets))
        src = int(np.argmax(deg))
        r1 = bfs(g, src); s1 = sssp(g, src)
        c1 = connected_components(g)
        p1 = pagerank(g, max_iter=12)
        l1 = label_propagation(g, max_iter=8)
        srcs = [0, 5, 17]
        rr1 = reach_batch(g, srcs, 3)
        for p in (2, 4, 8):
            pg = partition_1d(g, p)
            assert p * pg.verts_per_part > g.num_vertices  # padded tail
            mesh = Mesh(np.array(jax.devices()[:p]), ("graph",))
            rd = distributed_bfs(pg, src, mesh)
            assert np.array_equal(np.asarray(rd.labels),
                                  np.asarray(r1.labels)), ("bfs", p)
            # the empty-frontier parts really are empty: the isolated
            # tail is unreachable
            assert np.asarray(r1.labels)[base.num_vertices:].max() < 0
            sd = distributed_sssp(pg, src, mesh)
            assert np.array_equal(np.asarray(sd.dist),
                                  np.asarray(s1.dist)), ("sssp", p)
            cd = distributed_cc(pg, mesh)
            assert np.array_equal(np.asarray(cd.labels),
                                  np.asarray(c1.labels)), ("cc", p)
            assert int(cd.num_components) == int(c1.num_components)
            pd = distributed_pagerank(pg, mesh, iters=12)
            assert np.array_equal(np.asarray(pd),
                                  np.asarray(p1.rank)), ("pagerank", p)
            ld = distributed_label_propagation(pg, mesh, max_iter=8)
            assert np.array_equal(np.asarray(ld.labels),
                                  np.asarray(l1.labels)), ("lp", p)
            xd = distributed_reach(pg, srcs, 3, mesh=mesh)
            assert np.array_equal(np.asarray(xd.reached),
                                  np.asarray(rr1.reached)), ("reach", p)
        print("SHARDED_PARITY_OK")
    """)
    assert "SHARDED_PARITY_OK" in out


def test_sharded_storage_plan_parity():
    """PR 6: a source graph built under any storage plan (narrow ids,
    delta columns) shards into the canonical dense-int32 per-shard
    layout, and distributed bfs/sssp/pagerank bit-match the
    single-device run of the int64-under-x64 widest baseline at 2- and
    4-way partitions."""
    out = run_sub("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import graph as G
        from repro.core.distributed import (
            distributed_bfs, distributed_pagerank, distributed_sssp)
        from repro.core.partition import partition_1d
        from repro.core.primitives import bfs, pagerank, sssp

        with jax.experimental.enable_x64():
            g64 = G.rmat(7, 8, seed=5, weighted=True, index_dtype="int64")
            src = int(np.argmax(np.diff(np.asarray(g64.row_offsets))))
            labels = np.asarray(bfs(g64, src).labels)
            dist = np.asarray(sssp(g64, src).dist)
            rank = np.asarray(pagerank(g64, max_iter=12).rank)
        for kw in ({"index_dtype": "int32"}, {"encoding": "delta"}):
            g = G.rmat(7, 8, seed=5, weighted=True, **kw)
            for p in (2, 4):
                pg = partition_1d(g, p)
                mesh = Mesh(np.array(jax.devices()[:p]), ("graph",))
                rd = distributed_bfs(pg, src, mesh)
                assert np.array_equal(np.asarray(rd.labels), labels), \\
                    ("bfs", kw, p)
                sd = distributed_sssp(pg, src, mesh)
                assert np.array_equal(np.asarray(sd.dist), dist), \\
                    ("sssp", kw, p)
                pd = distributed_pagerank(pg, mesh, iters=12)
                assert np.array_equal(np.asarray(pd), rank), \\
                    ("pagerank", kw, p)
        print("SHARDED_STORAGE_OK")
    """, devices=4)
    assert "SHARDED_STORAGE_OK" in out


def test_sharded_linalg_ops_parity():
    """The public linalg wrappers route a ShardedGraph through the
    sharded providers: masked spmv/spmm across all five semirings and a
    masked SpGEMM (sharded expansion side, replicated probe side) all
    bit-match the single-device results."""
    out = run_sub("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import graph as G
        from repro.core.partition import partition_1d
        from repro import linalg

        g = G.rmat(7, 8, seed=2, weighted=True)
        n = g.num_vertices
        pg = partition_1d(g, 4)
        mesh = Mesh(np.array(jax.devices()[:4]), ("graph",))
        sg = pg.shard(mesh)
        rng = np.random.default_rng(0)
        x = rng.random(n).astype(np.float32)
        X = rng.random((n, 5)).astype(np.float32)
        mask = rng.random(n) > 0.4
        for srn in ("plus_times", "min_plus", "or_and", "max_min",
                    "plus_and"):
            y1 = linalg.spmv(g, x, semiring=srn, mask=mask)
            y2 = linalg.spmv(sg, x, semiring=srn, mask=mask)
            assert np.array_equal(np.asarray(y1), np.asarray(y2)), srn
            z1 = linalg.spmm(g, X, semiring=srn, mask=mask,
                             complement=True)
            z2 = linalg.spmm(sg, X, semiring=srn, mask=mask,
                             complement=True)
            assert np.array_equal(np.asarray(z1), np.asarray(z2)), srn
        t1 = linalg.spmv(g, x, transpose=True)
        t2 = linalg.spmv(sg, x, transpose=True)
        assert np.array_equal(np.asarray(t1), np.asarray(t2))
        se, de = G.edge_list(g)
        c1 = linalg.mxm(g, g, (se, de), semiring=linalg.plus_and,
                        b_transpose=True, structural=True)
        c2 = linalg.mxm(sg, g, (se, de), semiring=linalg.plus_and,
                        b_transpose=True, structural=True)
        assert np.array_equal(np.asarray(c1), np.asarray(c2))
        print("SHARDED_LINALG_OK")
    """, devices=4)
    assert "SHARDED_LINALG_OK" in out


# ---------------------------------------------------------------------------
# 2-D vertex-cut placement (placement="2d")
# ---------------------------------------------------------------------------


def test_2d_placement_registry():
    from repro.core import backend as B
    from repro.core import graph as G
    assert B.TWOD in B.PLACEMENTS
    assert B.resolve_placement("2d") == B.TWOD
    for op in ("advance", "advance_filter", "spmv", "spmm", "mxm"):
        assert B.registered(op, B.XLA, B.TWOD), op
    # 2d dispatch never falls back to the single placement …
    with pytest.raises(KeyError):
        B.dispatch("compact", B.XLA, B.TWOD)
    # … but the pallas backend falls back to the xla 2d provider
    assert B.dispatch("spmv", B.PALLAS, B.TWOD) \
        is B.dispatch("spmv", B.XLA, B.TWOD)
    with pytest.raises(ValueError, match="Sharded2DGraph"):
        B.resolve_graph_placement(G.demo_graph(), B.TWOD)


def test_2d_balance_reports_edge_and_vertex_imbalance():
    """Satellite: balance() surfaces edge-balance (the stat hub skew
    shows up in) next to vertex-balance on BOTH partition containers,
    and the 2-D container adds the vertex-cut mirror stats."""
    from repro.core import graph as G
    from repro.core.partition import partition_1d, partition_2d
    g = G.rmat(7, 8, seed=3)
    b1 = partition_1d(g, 4).balance()
    assert b1["edge_imbalance"] >= 1.0
    assert b1["vertex_imbalance"] >= 1.0
    assert len(b1["edges_per_part"]) == 4
    pg = partition_2d(g, 2, 2)
    b2 = pg.balance()
    assert b2["mesh"] == [2, 2]
    assert b2["edge_imbalance"] >= 1.0
    assert b2["vertex_imbalance"] >= 1.0
    assert np.sum(b2["edges_per_block"]) == g.num_edges
    # every vertex has at least its owner copy; mirrors only add
    assert b2["mirror_factor"] >= 1.0
    # comm model: the 2-D bfs exchange is chunk-proportional and beats
    # the 1-D n-proportional exchange at equal device count
    from repro.core.distributed import exchange_bytes_per_step
    assert exchange_bytes_per_step(pg, "bfs") \
        < exchange_bytes_per_step(partition_1d(g, 4), "bfs")


def test_2d_parity_all_primitives():
    """bfs/sssp/cc/pagerank/label_propagation/reach on 2×2 and 2×4
    meshes bit-match the single-device primitives. n is non-divisible
    on BOTH axes (263 = 2·132−1 rows, 4·66−1 cols) and the isolated
    tail gives whole blocks whose frontier stays empty every
    iteration."""
    out = run_sub("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import graph as G
        from repro.core.partition import partition_2d
        from repro.core.distributed import (
            distributed_bfs, distributed_sssp, distributed_cc,
            distributed_pagerank, distributed_label_propagation,
            distributed_reach)
        from repro.core.primitives import (
            bfs, sssp, connected_components, pagerank,
            label_propagation, reach_batch)

        base = G.rmat(7, 8, seed=3, weighted=True)
        se, de = G.edge_list(base)
        vals = np.asarray(base.edge_values)
        n2 = base.num_vertices * 2 + 7
        g = G.from_edge_list(se, de, n=n2, values=vals)
        deg = np.diff(np.asarray(g.row_offsets))
        src = int(np.argmax(deg))
        r1 = bfs(g, src); s1 = sssp(g, src)
        c1 = connected_components(g)
        p1 = pagerank(g, max_iter=12)
        l1 = label_propagation(g, max_iter=8)
        srcs = [0, 5, 17]
        rr1 = reach_batch(g, srcs, 3)
        for (R, C) in ((2, 2), (2, 4)):
            pg = partition_2d(g, R, C)
            # both axes genuinely padded (non-divisible n)
            assert R * pg.vpr > g.num_vertices
            assert C * pg.vpc > g.num_vertices
            mesh = Mesh(np.array(jax.devices()[:R * C]).reshape(R, C),
                        ("row", "col"))
            rd = distributed_bfs(pg, src, mesh)
            assert np.array_equal(np.asarray(rd.labels),
                                  np.asarray(r1.labels)), ("bfs", R, C)
            # the empty-frontier blocks really are empty: the isolated
            # tail is unreachable
            assert np.asarray(r1.labels)[base.num_vertices:].max() < 0
            sd = distributed_sssp(pg, src, mesh)
            assert np.array_equal(np.asarray(sd.dist),
                                  np.asarray(s1.dist)), ("sssp", R, C)
            cd = distributed_cc(pg, mesh)
            assert np.array_equal(np.asarray(cd.labels),
                                  np.asarray(c1.labels)), ("cc", R, C)
            assert int(cd.num_components) == int(c1.num_components)
            pd = distributed_pagerank(pg, mesh, iters=12)
            assert np.array_equal(np.asarray(pd),
                                  np.asarray(p1.rank)), ("pr", R, C)
            ld = distributed_label_propagation(pg, mesh, max_iter=8)
            assert np.array_equal(np.asarray(ld.labels),
                                  np.asarray(l1.labels)), ("lp", R, C)
            xd = distributed_reach(pg, srcs, 3, mesh=mesh)
            assert np.array_equal(np.asarray(xd.reached),
                                  np.asarray(rr1.reached)), ("rc", R, C)
        print("2D_PARITY_OK")
    """)
    assert "2D_PARITY_OK" in out


def test_2d_degenerate_meshes_match_1d_and_single():
    """1×C and R×1 meshes are honest members of the placement axis:
    they bit-match BOTH the existing 1-D sharded path and the
    single-device primitives (same graph, same sources)."""
    out = run_sub("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import graph as G
        from repro.core.partition import partition_1d, partition_2d
        from repro.core.distributed import (
            distributed_bfs, distributed_sssp, distributed_pagerank)
        from repro.core.primitives import bfs, sssp, pagerank

        base = G.rmat(7, 8, seed=3, weighted=True)
        se, de = G.edge_list(base)
        n2 = base.num_vertices * 2 + 7
        g = G.from_edge_list(se, de, n=n2,
                             values=np.asarray(base.edge_values))
        src = int(np.argmax(np.diff(np.asarray(g.row_offsets))))
        labels = np.asarray(bfs(g, src).labels)
        dist = np.asarray(sssp(g, src).dist)
        rank = np.asarray(pagerank(g, max_iter=12).rank)
        pg1 = partition_1d(g, 4)
        mesh1 = Mesh(np.array(jax.devices()[:4]), ("graph",))
        l1 = np.asarray(distributed_bfs(pg1, src, mesh1).labels)
        d1 = np.asarray(distributed_sssp(pg1, src, mesh1).dist)
        r1 = np.asarray(distributed_pagerank(pg1, mesh1, iters=12))
        assert np.array_equal(l1, labels) and np.array_equal(d1, dist)
        assert np.array_equal(r1, rank)
        for (R, C) in ((1, 4), (4, 1)):
            pg = partition_2d(g, R, C)
            mesh = Mesh(np.array(jax.devices()[:4]).reshape(R, C),
                        ("row", "col"))
            l2 = np.asarray(distributed_bfs(pg, src, mesh).labels)
            d2 = np.asarray(distributed_sssp(pg, src, mesh).dist)
            r2 = np.asarray(distributed_pagerank(pg, mesh, iters=12))
            assert np.array_equal(l2, l1) and np.array_equal(l2, labels)
            assert np.array_equal(d2, d1) and np.array_equal(d2, dist)
            assert np.array_equal(r2, r1) and np.array_equal(r2, rank)
        print("2D_DEGENERATE_OK")
    """, devices=4)
    assert "2D_DEGENERATE_OK" in out


def test_2d_linalg_ops_parity():
    """The public linalg wrappers route a Sharded2DGraph through the 2d
    providers: masked spmv/spmm across all five semirings (the pre-fold
    product exchange is exact for every ⊕) and a plus_and masked SpGEMM
    all bit-match the single-device results."""
    out = run_sub("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import graph as G
        from repro.core.partition import partition_2d
        from repro import linalg

        g = G.rmat(7, 8, seed=2, weighted=True)
        n = g.num_vertices
        pg = partition_2d(g, 2, 4)
        mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 4),
                    ("row", "col"))
        sg = pg.shard(mesh)
        rng = np.random.default_rng(0)
        x = rng.random(n).astype(np.float32)
        X = rng.random((n, 5)).astype(np.float32)
        mask = rng.random(n) > 0.4
        for srn in ("plus_times", "min_plus", "or_and", "max_min",
                    "plus_and"):
            y1 = linalg.spmv(g, x, semiring=srn, mask=mask)
            y2 = linalg.spmv(sg, x, semiring=srn, mask=mask)
            assert np.array_equal(np.asarray(y1), np.asarray(y2)), srn
            z1 = linalg.spmm(g, X, semiring=srn, mask=mask,
                             complement=True)
            z2 = linalg.spmm(sg, X, semiring=srn, mask=mask,
                             complement=True)
            assert np.array_equal(np.asarray(z1), np.asarray(z2)), srn
        t1 = linalg.spmv(g, x, transpose=True)
        t2 = linalg.spmv(sg, x, transpose=True)
        assert np.array_equal(np.asarray(t1), np.asarray(t2))
        se, de = G.edge_list(g)
        c1 = linalg.mxm(g, g, (se, de), semiring=linalg.plus_and,
                        b_transpose=True, structural=True)
        c2 = linalg.mxm(sg, g, (se, de), semiring=linalg.plus_and,
                        b_transpose=True, structural=True)
        assert np.array_equal(np.asarray(c1), np.asarray(c2))
        print("2D_LINALG_OK")
    """)
    assert "2D_LINALG_OK" in out


def test_graph_serve_2d_mesh_smoke():
    """graph_serve --mesh RxC serves the mixed stream from the 2-D
    vertex cut with oracle validation, reports the mesh shape and the
    vertex-cut balance stats, and rejects bad mesh specs with clear
    errors."""
    out = run_sub("""
        import json, numpy as np
        from repro.launch.graph_serve import main
        main(["--graph", "rmat", "--scale", "7", "--kinds",
              "bfs,sssp,pagerank,reach", "--requests", "8", "--batch",
              "4", "--mesh", "2x4", "--validate", "--json",
              "/tmp/_serve_mesh_test.json"])
        row = json.load(open("/tmp/_serve_mesh_test.json"))[-1]
        assert row["parts"] == 8
        assert row["mesh"] == [2, 4]
        assert row["validation_failures"] == 0
        bal = row["balance"]
        assert bal["mesh"] == [2, 4]
        assert bal["edge_imbalance"] >= 1.0
        assert bal["vertex_imbalance"] >= 1.0
        assert bal["mirror_factor"] >= 1.0
        for argv, frag in (
                (["--mesh", "4x4"], "devices"),        # R*C > visible
                (["--mesh", "2x"], "RxC"),             # malformed
                (["--mesh", "2x2", "--parts", "4"],
                 "mutually exclusive")):
            try:
                main(["--graph", "rmat", "--scale", "7", "--requests",
                      "4", "--batch", "4"] + argv)
            except SystemExit as e:
                assert frag in str(e), (argv, e)
            else:
                raise AssertionError(f"no error for {argv}")
        print("SERVE_2D_OK")
    """)
    assert "SERVE_2D_OK" in out


def test_graph_serve_sharded_smoke():
    """graph_serve --parts serves a mixed stream from the mesh with
    oracle validation and reports partition balance."""
    out = run_sub("""
        import json, numpy as np
        from repro.launch.graph_serve import main
        main(["--graph", "rmat", "--scale", "7", "--kinds",
              "bfs,sssp,pagerank,reach", "--requests", "8", "--batch",
              "4", "--parts", "4", "--validate", "--json",
              "/tmp/_serve_parts_test.json"])
        row = json.load(open("/tmp/_serve_parts_test.json"))[-1]
        assert row["parts"] == 4
        assert row["validation_failures"] == 0
        bal = row["balance"]
        assert len(bal["edges_per_part"]) == 4
        assert sum(bal["vertices_per_part"]) == 128
        print("SERVE_PARTS_OK")
    """, devices=4)
    assert "SERVE_PARTS_OK" in out
