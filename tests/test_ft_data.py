"""Fault tolerance + data pipeline: injected failure resume, watchdog,
pipeline determinism/resumability."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import SyntheticLMDataset
from repro.ft import RestartableTrainer, StepWatchdog, check_devices
from repro.ft.elastic import FailAt


def test_data_deterministic_and_resumable():
    ds1 = SyntheticLMDataset(vocab=1000, seq_len=64, global_batch=4,
                             seed=5)
    batches = [ds1.next_batch() for _ in range(5)]
    # restore mid-stream: identical continuation
    ds2 = SyntheticLMDataset(vocab=1000, seq_len=64, global_batch=4,
                             seed=5)
    ds2.restore({"step": 3, "seed": 5})
    b3 = ds2.next_batch()
    assert np.array_equal(np.asarray(b3["tokens"]),
                          np.asarray(batches[3]["tokens"]))
    # distinct steps differ
    assert not np.array_equal(np.asarray(batches[0]["tokens"]),
                              np.asarray(batches[1]["tokens"]))
    # labels = next-token shift
    assert np.array_equal(np.asarray(batches[0]["labels"])[:, :10],
                          np.asarray(batches[0]["tokens"])[:, 1:11])


def test_data_learnable_structure():
    ds = SyntheticLMDataset(vocab=1000, seq_len=128, global_batch=2,
                            seed=0)
    b = ds.next_batch()
    toks = np.asarray(b["tokens"])
    # block structure: position 32+i repeats position i+1 (roll by -1)
    assert np.array_equal(toks[:, 32:40], toks[:, 1:9])


def test_restartable_trainer_resumes(tmp_path):
    calls = {"n": 0}

    def init_state():
        return {"w": jnp.zeros((3,))}

    def step_fn(state, step):
        calls["n"] += 1
        return {"w": state["w"] + 1.0}, {"loss": float(10 - step)}

    ds = SyntheticLMDataset(vocab=10, seq_len=8, global_batch=1)
    tr = RestartableTrainer(str(tmp_path), ckpt_every=4, max_restarts=2)
    report = tr.run(init_state=init_state, step_fn=step_fn,
                    data_state=ds.state, restore_data=ds.restore,
                    total_steps=10, fail_at=6)
    assert report["completed"]
    assert report["restarts"] == 1
    # steps 0..5 ran, failed at 6 (before executing), resumed from ckpt 4:
    # re-ran 4..9 → total executed = 6 + 6 = 12
    assert calls["n"] == 12
    # state reflects exactly 10 effective steps from the resumed lineage


def test_restartable_trainer_gives_up(tmp_path):
    def init_state():
        return {"w": jnp.zeros(())}

    def step_fn(state, step):
        raise FailAt("always")

    ds = SyntheticLMDataset(vocab=10, seq_len=8, global_batch=1)
    tr = RestartableTrainer(str(tmp_path) + "/x", ckpt_every=100,
                            max_restarts=1)
    report = tr.run(init_state=init_state, step_fn=step_fn,
                    data_state=ds.state, restore_data=ds.restore,
                    total_steps=3, fail_at=None)
    assert not report["completed"]
    assert report["restarts"] == 2  # initial failure + 1 allowed restart


def test_watchdog_flags_stragglers():
    events = []
    wd = StepWatchdog(window=16, threshold=1.5,
                      on_straggler=lambda s, dt, med: events.append(s))
    for step in range(12):
        wd.start(step)
        time.sleep(0.012 if step == 10 else 0.002)
        wd.stop()
    assert any(s == 10 for s, _, _ in wd.stragglers)
    assert events == [s for s, _, _ in wd.stragglers]


def test_device_health():
    rep = check_devices()
    assert all(rep.values())
