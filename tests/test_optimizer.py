"""Optimizer + schedules: convergence, clipping, int8 moment quantization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.train.optimizer import (QBLOCK, QTensor, adamw,
                                   dequantize_blockwise, global_norm,
                                   make_schedule, moment_specs,
                                   quantizable, quantize_blockwise)


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 512)), jnp.float32)
    codes, scale = quantize_blockwise(x)
    assert codes.shape == x.shape and codes.dtype == jnp.int8
    assert scale.shape == (8, 2)
    back = dequantize_blockwise(codes, scale, x.shape, jnp.float32)
    err = np.abs(np.asarray(back - x))
    bound = np.abs(np.asarray(x)).reshape(8, 2, QBLOCK).max(-1) / 127.0
    assert np.all(err.reshape(8, 2, QBLOCK)
                  <= bound[..., None] * 0.5 + 1e-7)


@given(st.integers(1, 4), st.integers(1, 3))
@settings(max_examples=10)
def test_quantize_shapes(rows, blocks):
    x = jnp.ones((rows, blocks * QBLOCK))
    codes, scale = quantize_blockwise(x)
    assert codes.shape == x.shape
    assert scale.shape == (rows, blocks)


def test_quantizable_predicate():
    assert quantizable((4, 512))
    assert not quantizable((512,))       # 1-D
    assert not quantizable((4, 100))     # last dim not divisible


def test_adamw_converges_quadratic():
    for q in (False, True):
        init, upd = adamw(make_schedule("constant", 0.05, 100,
                                        warmup_steps=1),
                          quantize_moments=q, weight_decay=0.0)
        params = {"w": jnp.full((2, 512), 3.0)}
        st_ = init(params)
        for _ in range(80):
            g = jax.grad(lambda p: jnp.sum((p["w"] - 1.0) ** 2))(params)
            params, st_, m = upd(g, st_, params)
        assert float(jnp.max(jnp.abs(params["w"] - 1.0))) < 0.1, q


def test_quantized_state_structure():
    init, _ = adamw(make_schedule("constant", 0.1, 10),
                    quantize_moments=True)
    params = {"big": jnp.zeros((4, 512)), "small": jnp.zeros((7,))}
    st_ = init(params)
    assert isinstance(st_.m["big"], QTensor)
    assert not isinstance(st_.m["small"], QTensor)   # fallback fp32


def test_grad_clipping():
    init, upd = adamw(make_schedule("constant", 0.1, 10), clip_norm=1.0)
    params = {"w": jnp.zeros((3,))}
    st_ = init(params)
    g = {"w": jnp.full((3,), 100.0)}
    _, _, m = upd(g, st_, params)
    assert float(m["grad_norm"]) > 1.0   # reported pre-clip


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert np.isclose(float(global_norm(t)), 5.0)


def test_schedules_shapes():
    total = 1000
    for kind in ("constant", "cosine", "wsd"):
        s = make_schedule(kind, 1e-3, total, warmup_steps=100)
        assert float(s(jnp.asarray(0))) < 1e-3 * 0.02     # warmup start
        assert np.isclose(float(s(jnp.asarray(100))), 1e-3, rtol=1e-2)
    wsd = make_schedule("wsd", 1e-3, total, warmup_steps=100,
                        stable_frac=0.9)
    # stable until 90%: flat
    assert np.isclose(float(wsd(jnp.asarray(500))), 1e-3)
    assert np.isclose(float(wsd(jnp.asarray(880))), 1e-3)
    # decay tail
    assert float(wsd(jnp.asarray(total))) < 1.2e-4
    cos = make_schedule("cosine", 1e-3, total, warmup_steps=100)
    assert float(cos(jnp.asarray(total))) < 1.2e-4


def test_moment_specs_structure():
    from jax.sharding import PartitionSpec as P
    pspecs = {"big": P("data", "model"), "small": P(None)}
    sds = {"big": jax.ShapeDtypeStruct((4, 512), jnp.float32),
           "small": jax.ShapeDtypeStruct((7,), jnp.float32)}
    ms = moment_specs(pspecs, sds, quantize_moments=True)
    assert isinstance(ms["big"], QTensor)
    assert ms["big"].codes == P("data", "model")
    assert ms["small"] == P(None)
