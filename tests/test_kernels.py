"""Per-kernel validation vs ref.py oracles: shape/dtype sweeps +
hypothesis property tests (interpret mode = correctness contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops as K
from repro.kernels import ref as R

rng = np.random.default_rng(42)


# ---- lb_expand -----------------------------------------------------------

@pytest.mark.parametrize("cap_in,cap_out", [(1, 8), (17, 100), (64, 2048),
                                            (500, 513)])
def test_lb_expand_shapes(cap_in, cap_out):
    sizes = jnp.asarray(rng.integers(0, 9, cap_in), jnp.int32)
    exp = K.lb_expand(sizes, cap_out)
    offsets = jnp.concatenate([jnp.zeros(1, jnp.int32),
                               jnp.cumsum(sizes)])
    ip, rk, vd = R.lb_expand_ref(offsets, cap_out)
    v = np.asarray(exp.valid)
    assert np.array_equal(v, np.asarray(vd) > 0)
    assert np.array_equal(np.asarray(exp.in_pos)[v], np.asarray(ip)[v])
    assert np.array_equal(np.asarray(exp.rank)[v], np.asarray(rk)[v])


@given(st.lists(st.integers(0, 12), min_size=1, max_size=40))
def test_lb_expand_property(sizes_l):
    sizes = jnp.asarray(sizes_l, jnp.int32)
    exp = K.lb_expand(sizes, 96)
    v = np.asarray(exp.valid)
    ip = np.asarray(exp.in_pos)[v]
    rk = np.asarray(exp.rank)[v]
    assert v.sum() == min(sum(sizes_l), 96)
    # each valid slot's rank < its segment size; segments appear in order
    for p, r in zip(ip, rk):
        assert 0 <= r < sizes_l[p]


# ---- segment_search ------------------------------------------------------

@pytest.mark.parametrize("hs,ns", [(10, 5), (333, 700), (4096, 512)])
def test_segment_search_shapes(hs, ns):
    hay = jnp.sort(jnp.asarray(rng.integers(0, 500, hs), jnp.int32))
    lo = jnp.asarray(rng.integers(0, hs, ns), jnp.int32)
    hi = jnp.minimum(lo + rng.integers(0, 50, ns).astype(np.int32), hs)
    needles = jnp.asarray(rng.integers(0, 500, ns), jnp.int32)
    got = K.segment_search(hay, lo, hi, needles)
    want = R.segment_search_ref(hay, lo, hi, needles)
    assert np.array_equal(np.asarray(got), np.asarray(want) > 0)


# ---- spmv ----------------------------------------------------------------

@pytest.mark.parametrize("n,w", [(8, 3), (300, 7), (1000, 16)])
def test_semiring_ell_plus_times(n, w):
    """The fused masked-semiring row kernel at plus_times with an
    all-ones mask must equal the classic ELL SpMV oracle (the absorbed
    kernels/spmv.py contract)."""
    from repro.kernels.semiring_spmv import semiring_ell_kernel
    from repro.linalg.semiring import plus_times
    nbrs = rng.integers(-1, n, (n, w)).astype(np.int32)
    vals = rng.random((n, w)).astype(np.float32)
    x = jnp.asarray(rng.random(n), jnp.float32)
    mask = jnp.ones((n,), jnp.int32)
    got = semiring_ell_kernel(jnp.asarray(nbrs), jnp.asarray(vals),
                              x[:, None], mask, plus_times)[:, 0]
    want = R.spmv_ell_ref(jnp.asarray(nbrs), jnp.asarray(vals), x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ["min_plus", "or_and", "max_min"])
def test_semiring_ell_vs_oracle(name):
    from repro.kernels.semiring_spmv import semiring_ell_kernel
    from repro.linalg import semiring as S
    sr = S.get(name)
    n, w, k = 130, 5, 3
    nbrs = rng.integers(-1, n, (n, w)).astype(np.int32)
    vals = rng.random((n, w)).astype(np.float32)
    x = jnp.asarray(rng.random((n, k)), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, n), jnp.int32)
    got = semiring_ell_kernel(jnp.asarray(nbrs), jnp.asarray(vals), x,
                              mask, sr)
    want = R.semiring_ell_ref(jnp.asarray(nbrs), jnp.asarray(vals), x,
                              mask, sr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_semiring_spmv_hybrid_overflow():
    # one ultra-high-degree row exercises the COO overflow path of the
    # registered pallas "spmv" impl (ELL width forced below max degree)
    from repro.core import graph as G
    from repro.linalg import spmv
    n = 200
    src = [0] * 150 + list(range(1, 50))
    dst = list(range(1, 151)) + [0] * 49
    g = G.from_edge_list(src, dst, n=n, undirected=False)
    x = jnp.asarray(rng.random(n), jnp.float32)
    got = spmv(g, x, structural=True, ell_width=4, backend="pallas")
    ro = np.asarray(g.row_offsets)
    ci = np.asarray(g.col_indices)
    want = np.zeros(n, np.float32)
    for u in range(n):
        want[u] = np.asarray(x)[ci[ro[u]:ro[u + 1]]].sum()
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5,
                               atol=1e-5)


# ---- filter_compact ------------------------------------------------------

@pytest.mark.parametrize("cap", [4, 255, 256, 1000])
def test_filter_compact(cap):
    ids = jnp.asarray(rng.integers(0, 99, cap), jnp.int32)
    keep = jnp.asarray(rng.random(cap) < 0.35)
    p, c = K.filter_compact(ids, keep)
    pr, cr = R.filter_compact_ref(ids, keep)
    assert int(c) == int(cr)
    assert np.array_equal(np.asarray(p), np.asarray(pr))


@given(st.lists(st.booleans(), min_size=1, max_size=300))
def test_filter_compact_property(keeps):
    ids = jnp.arange(len(keeps), dtype=jnp.int32)
    keep = jnp.asarray(keeps)
    p, c = K.filter_compact(ids, keep)
    expect = [i for i, k in enumerate(keeps) if k]
    assert int(c) == len(expect)
    assert np.asarray(p)[:len(expect)].tolist() == expect


# ---- flash attention -----------------------------------------------------

@pytest.mark.parametrize("sq,sk,d,causal,dtype", [
    (64, 64, 32, True, jnp.float32),
    (128, 128, 64, True, jnp.float32),
    (100, 37, 16, True, jnp.float32),
    (16, 256, 64, False, jnp.float32),
    (64, 64, 32, True, jnp.bfloat16),
])
def test_flash_attention(sq, sk, d, causal, dtype):
    q = jnp.asarray(rng.standard_normal((sq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((sk, d)), dtype)
    v = jnp.asarray(rng.standard_normal((sk, d)), dtype)
    got = K.flash_attention(q, k, v, causal=causal, bq=32, bk=32)
    want = R.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_matches_model_sdpa():
    from repro.models.layers import _sdpa
    q = jnp.asarray(rng.standard_normal((2, 48, 3, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 48, 3, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 48, 3, 16)), jnp.float32)
    want = _sdpa(q, k, v, causal=True)
    got = jax.vmap(jax.vmap(
        lambda qq, kk, vv: K.flash_attention(qq, kk, vv, bq=16, bk=16),
        in_axes=1, out_axes=1))(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


# ---- moe gather ----------------------------------------------------------

@pytest.mark.parametrize("t,d,s", [(10, 8, 30), (128, 64, 128),
                                   (50, 16, 7)])
def test_moe_gather(t, d, s):
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    st_ = jnp.asarray(rng.integers(-1, t, s), jnp.int32)
    got = K.moe_gather(x, st_)
    want = R.moe_gather_ref(x, st_)
    assert np.allclose(np.asarray(got), np.asarray(want))
