"""The analysis layer's own contract: every lint rule and every
sanitizer must fire on a seeded bug (true positives) and stay silent on
the shipped tree / healthy kernels (no false positives).

  * reprolint: one seeded violation per rule (RL001-RL005) through
    ``lint_source``, the suppression syntax, and the shipped-tree-green
    invariant the CI job enforces;
  * registry contracts: the real provider matrix passes CT001-CT006;
    seeded registry corruptions surface the right finding; provider
    misses raise the structured ``ProviderMissError``;
  * sanitizers: the retrace guard passes a cached hot loop and fails a
    shape-churning one; the Pallas memory checker faults a seeded
    out-of-bounds tile map and a seeded write-write race, and passes
    the real kernels bit-identically on dense and delta storage.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro.analysis import budgets, sanitize
from repro.analysis.contracts import PRIMITIVES, check_registry, matrix
from repro.analysis.lint import RULES, lint_paths, lint_source
from repro.core import backend as B
from repro.core import graph as G
from repro.core.primitives import (bc, bfs, connected_components, pagerank,
                                   sssp, triangle_count)
from repro.kernels import runtime
from repro.kernels.advance_fused import advance_fused_kernel
from repro.kernels.semiring_spmv import semiring_ell_kernel
from repro.linalg import semiring as SR

rng = np.random.default_rng(11)


# ---- reprolint: seeded true positives ------------------------------------

JITTED = "import jax\n@jax.jit\ndef f(x):\n"


def rules_of(findings):
    return {f.rule for f in findings}


def test_rl001_host_sync_in_jit():
    src = JITTED + "    return x.sum().item()\n"
    assert "RL001" in rules_of(lint_source(src))


def test_rl001_int_cast_in_jit():
    src = JITTED + "    n = int(x.sum())\n    return n\n"
    assert "RL001" in rules_of(lint_source(src))


def test_rl002_python_branch_on_tracer():
    src = JITTED + "    if x.sum() > 0:\n        return x\n    return -x\n"
    assert "RL002" in rules_of(lint_source(src))


def test_rl002_python_loop_over_tracer():
    # iterating an array EXPRESSION (a bare-Name iter may be a static
    # argument, which Python control flow is legal over)
    src = JITTED + ("    import jax.numpy as jnp\n    t = 0\n"
                    "    for v in jnp.cumsum(x):\n        t = t + v\n"
                    "    return t\n")
    assert "RL002" in rules_of(lint_source(src))


def test_rl003_unpinned_int_sum():
    src = ("import jax.numpy as jnp\n"
           "def f(m):\n"
           "    k = m.astype(jnp.int32)\n"
           "    return jnp.sum(k)\n")
    assert "RL003" in rules_of(lint_source(src))


def test_rl003_pinned_is_clean():
    src = ("import jax.numpy as jnp\n"
           "def f(m):\n"
           "    k = m.astype(jnp.int32)\n"
           "    return jnp.sum(k, dtype=jnp.int32)\n")
    assert "RL003" not in rules_of(lint_source(src))


def test_rl004_unfenced_timing():
    src = ("import time\n"
           "def f(step):\n"
           "    t0 = time.monotonic()\n"
           "    y = step()\n"
           "    return time.monotonic() - t0\n")
    assert "RL004" in rules_of(lint_source(src))


def test_rl004_fenced_is_clean():
    src = ("import time, jax\n"
           "def f(step):\n"
           "    t0 = time.monotonic()\n"
           "    y = jax.block_until_ready(step())\n"
           "    return time.monotonic() - t0\n")
    assert "RL004" not in rules_of(lint_source(src))


def test_rl005_bare_print_in_lib():
    src = "def f():\n    print('hi')\n"
    assert "RL005" in rules_of(lint_source(src, lib=True))
    # the rule is library-scoped: scripts/benchmark CLIs are exempt
    assert "RL005" not in rules_of(lint_source(src, lib=False))


def test_rl006_bare_except_swallows():
    src = ("def f(step):\n"
           "    try:\n"
           "        step()\n"
           "    except:\n"
           "        pass\n")
    assert "RL006" in rules_of(lint_source(src))


def test_rl006_broad_except_trivial_body():
    for body in ("pass", "..."):
        src = ("def f(step):\n"
               "    try:\n"
               "        step()\n"
               f"    except Exception:\n        {body}\n")
        assert "RL006" in rules_of(lint_source(src)), body
    src = ("def f(steps):\n"
           "    for s in steps:\n"
           "        try:\n"
           "            s()\n"
           "        except BaseException:\n"
           "            continue\n")
    assert "RL006" in rules_of(lint_source(src))


def test_rl006_handled_or_narrow_is_clean():
    # a broad handler with a real body is a decision, not a swallow
    src = ("def f(step, log):\n"
           "    try:\n"
           "        return step()\n"
           "    except Exception as e:\n"
           "        log.error(e)\n"
           "        return None\n")
    assert "RL006" not in rules_of(lint_source(src))
    # narrowing to a concrete type is deliberate even when empty
    src = ("def f(step):\n"
           "    try:\n"
           "        step()\n"
           "    except ValueError:\n"
           "        pass\n")
    assert "RL006" not in rules_of(lint_source(src))
    # bare except that re-raises is a cleanup handler, not a swallow
    src = ("def f(step, undo):\n"
           "    try:\n"
           "        step()\n"
           "    except:\n"
           "        undo()\n"
           "        raise\n")
    assert "RL006" not in rules_of(lint_source(src))


def test_rl006_declared_boundary_suppresses():
    src = ("def f(step):\n"
           "    try:\n"
           "        step()\n"
           "    except Exception:  "
           "# reprolint: disable=RL006 -- probe boundary\n"
           "        pass\n")
    assert lint_source(src) == []


def test_every_rule_has_a_seeded_test():
    # the tests above cover exactly the declared rule set
    assert set(RULES) == {"RL001", "RL002", "RL003", "RL004", "RL005",
                          "RL006"}


# ---- reprolint: suppression syntax ---------------------------------------

def test_suppress_same_line():
    src = "def f():\n    print('x')  # reprolint: disable=RL005 -- CLI\n"
    assert lint_source(src, lib=True) == []


def test_suppress_line_above():
    src = ("def f():\n"
           "    # reprolint: disable=RL005 -- CLI output\n"
           "    print('x')\n")
    assert lint_source(src, lib=True) == []


def test_suppress_bare_disables_all():
    src = "def f():\n    print('x')  # reprolint: disable\n"
    assert lint_source(src, lib=True) == []


def test_suppress_wrong_rule_does_not_silence():
    src = "def f():\n    print('x')  # reprolint: disable=RL001\n"
    assert "RL005" in rules_of(lint_source(src, lib=True))


def test_skip_file():
    src = "# reprolint: skip-file\ndef f():\n    print('x')\n"
    assert lint_source(src, lib=True) == []


def test_shipped_tree_is_lint_clean():
    # the CI gate: the library and benchmarks carry zero findings
    assert lint_paths(["src/repro", "benchmarks"]) == []


# ---- registry contracts --------------------------------------------------

def test_real_registry_passes_contracts():
    assert check_registry() == []


def test_matrix_renders_every_op():
    out = matrix()
    for op in ("advance", "advance_filter", "spmv", "mxm"):
        assert op in out
    assert "(declared)" in out        # advance_filter's sharded hole


def test_seeded_ct001_undeclared_hole(monkeypatch):
    monkeypatch.setitem(B._REGISTRY, ("fakeop", B.XLA, B.SHARDED),
                        lambda: None)
    monkeypatch.setitem(B._ENCODINGS, ("fakeop", B.XLA, B.SHARDED),
                        ("dense",))
    found = [f for f in check_registry() if f.rule == "CT001"]
    assert any("fakeop" in f.key for f in found)


def test_seeded_ct002_missing_dense(monkeypatch):
    key = ("advance", B.XLA, B.SINGLE)
    assert key in B._ENCODINGS
    monkeypatch.setitem(B._ENCODINGS, key, ("delta",))
    found = [f for f in check_registry() if f.rule == "CT002"]
    assert any("advance/xla/single" == f.key for f in found)


def test_seeded_ct004_aliased_single_callable(monkeypatch):
    single = B._REGISTRY[("advance", B.XLA, B.SINGLE)]
    monkeypatch.setitem(B._REGISTRY, ("advance", B.XLA, B.TWOD), single)
    found = [f for f in check_registry() if f.rule == "CT004"]
    assert any(f.key == "advance/xla/2d" for f in found)


def test_register_rejects_unknown_encoding():
    with pytest.raises(ValueError, match="unknown storage encoding"):
        B.register("x", B.XLA, encodings=("zstd",))


def test_provider_miss_is_structured():
    with pytest.raises(B.ProviderMissError) as ei:
        B.dispatch("compact", B.XLA, B.SHARDED)
    err = ei.value
    assert isinstance(err, KeyError)          # the pinned public contract
    assert (err.op, err.backend, err.placement) == \
        ("compact", B.XLA, B.SHARDED)
    assert err.nearest == ("compact", B.XLA, B.SINGLE)
    msg = str(err)
    assert "compact" in msg and "sharded" in msg and "nearest" in msg


def test_provider_miss_suggests_closest_op_name():
    with pytest.raises(B.ProviderMissError) as ei:
        B.dispatch("advanse", B.XLA, B.SINGLE)
    assert ei.value.nearest is not None
    assert ei.value.nearest[0] == "advance"


def test_declare_fallback_requires_reason():
    with pytest.raises(ValueError):
        B.declare_fallback("advance", B.SHARDED, reason="")
    assert B.declared_fallback("advance_filter", B.SHARDED)
    assert B.declared_fallback("advance", B.SHARDED) is None


# ---- retrace detector ----------------------------------------------------

def test_trace_probe_counts_cache_misses():
    @jax.jit
    def f(x):
        sanitize.trace_probe("probe_unit_test")
        return x + 1

    f(jnp.zeros((3,)))
    c1 = sanitize.trace_count("probe_unit_test")
    assert c1 >= 1
    f(jnp.ones((3,)))                     # same shape: cache hit
    assert sanitize.trace_count("probe_unit_test") == c1
    f(jnp.zeros((4,)))                    # new shape: one more trace
    assert sanitize.trace_count("probe_unit_test") == c1 + 1


def test_retrace_guard_fires_on_shape_churn():
    @jax.jit
    def f(x):
        sanitize.trace_probe("seeded_retrace")
        return x * 2

    with pytest.raises(sanitize.RetraceError, match="seeded_retrace"):
        with sanitize.retrace_guard("seeded_retrace", budget=1):
            for k in range(3):            # 3 shapes -> 3 traces > budget 1
                f(jnp.zeros((5 + k,)))


def test_retrace_guard_clean_and_reports():
    @jax.jit
    def f(x):
        sanitize.trace_probe("clean_retrace")
        return x * 2

    with sanitize.retrace_guard("clean_retrace", budget=1) as rep:
        for _ in range(5):
            f(jnp.zeros((9,)))
    assert rep["traces"] <= 1


def test_budget_pins():
    # the declared contract; bc's 2 covers the ragged tail chunk of the
    # chunked Brandes sweep
    assert budgets.COMPILE_BUDGETS == {
        "bfs": 1, "sssp": 1, "pagerank": 1, "cc": 1, "bc": 2, "tc": 1}
    with pytest.raises(KeyError, match="no compile budget"):
        budgets.budget_for("nope")


def test_primitive_probes_wired_and_within_budget():
    """Each primitive's jitted impl carries a probe, and one fixed
    workload config stays inside its declared budget across repeat
    calls — the serving-path no-recompile property."""
    g = G.rmat(6, 4, seed=31, weighted=True)
    calls = {
        "bfs": lambda: bfs(g, 0),
        "sssp": lambda: sssp(g, 0),
        "pagerank": lambda: pagerank(g, max_iter=4),
        "cc": lambda: connected_components(g),
        "bc": lambda: bc(g, 0),
        "tc": lambda: triangle_count(g),
    }
    assert set(calls) == set(PRIMITIVES)
    for name, call in calls.items():
        call()                                      # warm the cache
        assert sanitize.trace_count(name) >= 1, name
        with sanitize.retrace_guard(name):          # declared budget
            call()
            call()


# ---- pallas memory sanitizer ---------------------------------------------

def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def test_seeded_out_of_bounds_tile():
    with sanitize.sanitizing():
        call = runtime.pallas_call(
            _copy_kernel, name="seeded_oob", grid=(4,),
            # off-by-one tile map: cell 3 -> block 4 of 4 valid blocks
            in_specs=[pl.BlockSpec((8,), lambda i: (i + 1,))],
            out_specs=pl.BlockSpec((8,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((32,), jnp.float32),
            interpret=True)
        with pytest.raises(sanitize.MemoryFault, match="out-of-bounds"):
            call(jnp.zeros((32,), jnp.float32))


def test_seeded_write_write_race():
    with sanitize.sanitizing():
        call = runtime.pallas_call(
            _copy_kernel, name="seeded_race", grid=(4,),
            in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
            # every cell writes output block 0 — a race unless declared
            out_specs=pl.BlockSpec((8,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((32,), jnp.float32),
            interpret=True)
        with pytest.raises(sanitize.MemoryFault, match="write-write race"):
            call(jnp.zeros((32,), jnp.float32))


def test_accumulate_declares_the_race_away():
    with sanitize.sanitizing():
        call = runtime.pallas_call(
            _copy_kernel, name="declared_accum", grid=(4,),
            in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
            out_specs=pl.BlockSpec((8,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
            interpret=True, accumulate=(0,))
        out = call(jnp.arange(32, dtype=jnp.float32))
        assert out.shape == (8,)


def test_rank_mismatch_faults():
    with sanitize.sanitizing():
        with pytest.raises(sanitize.MemoryFault, match="rank"):
            runtime.pallas_call(
                _copy_kernel, name="seeded_rank", grid=(2,),
                in_specs=[pl.BlockSpec((4, 4), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((4, 4), lambda i: (i, 0)),
                out_shape=jax.ShapeDtypeStruct((8, 8), jnp.float32),
                interpret=True)(jnp.zeros((16,), jnp.float32))


def test_sanitizer_off_means_no_check():
    call = runtime.pallas_call(
        _copy_kernel, name="oob_unsanitized", grid=(1,),
        in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
        out_specs=pl.BlockSpec((8,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
        interpret=True)
    out = call(jnp.arange(8, dtype=jnp.float32))
    assert np.array_equal(np.asarray(out), np.arange(8, dtype=np.float32))


# ---- clean-run matrix: real kernels under the sanitizer ------------------

@pytest.mark.parametrize("encoding", ["dense", "delta"])
def test_advance_kernels_clean_under_sanitizer(encoding):
    """The fused advance kernels' declared accumulate pattern passes the
    checker, bit-identically to an unsanitized run, on both storage
    encodings (fresh shapes force a trace inside the context)."""
    kw = {} if encoding == "dense" else {"encoding": "delta"}
    g = G.rmat(7, 5, seed=97, **kw)
    with sanitize.sanitizing():
        r1 = bfs(g, 0, backend="pallas")
    r2 = bfs(g, 0, backend="pallas")
    assert np.array_equal(np.asarray(r1.labels), np.asarray(r2.labels))


def test_semiring_ell_clean_under_sanitizer():
    n, w, k = 37, 5, 3
    nbrs = jnp.asarray(rng.integers(-1, n, (n, w)), jnp.int32)
    vals = jnp.asarray(rng.standard_normal((n, w)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((n, k)), jnp.float32)
    mask = jnp.ones((n,), jnp.int32)
    with sanitize.sanitizing():
        y1 = semiring_ell_kernel(nbrs, vals, x, mask, SR.plus_times,
                                 interpret=True)
    y2 = semiring_ell_kernel(nbrs, vals, x, mask, SR.plus_times,
                             interpret=True)
    assert np.array_equal(np.asarray(y1), np.asarray(y2))


def test_advance_fused_clean_under_sanitizer():
    n = 41
    src = np.arange(n - 1)
    dst = np.arange(1, n)
    g = G.from_edge_list(src, dst, n=n, undirected=True)
    sizes = jnp.asarray(np.diff(np.asarray(g.row_offsets)), jnp.int32)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(sizes, dtype=jnp.int32)])
    base = jnp.arange(n, dtype=jnp.int32)
    with sanitize.sanitizing():
        out1 = advance_fused_kernel(offsets, base, g.row_offsets,
                                    g.col_indices, 96, interpret=True)
    out2 = advance_fused_kernel(offsets, base, g.row_offsets,
                                g.col_indices, 96, interpret=True)
    for a, b in zip(out1, out2):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_env_var_enables_sanitizer(monkeypatch):
    monkeypatch.setenv(sanitize.ENV_VAR, "1")
    assert sanitize.enabled()
    monkeypatch.setenv(sanitize.ENV_VAR, "0")
    assert not sanitize.enabled()
    with sanitize.sanitizing():              # context wins over env
        assert sanitize.enabled()
