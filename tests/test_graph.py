"""Graph container + generator invariants (unit + property)."""
import numpy as np
import pytest
from _hyp import given, st

from repro.core import graph as G


def _check_csr(g):
    ro = np.asarray(g.row_offsets)
    ci = np.asarray(g.col_indices)
    n = g.num_vertices
    assert ro[0] == 0 and ro[-1] == len(ci)
    assert np.all(np.diff(ro) >= 0)
    if len(ci):
        assert ci.min() >= 0 and ci.max() < n


def test_demo_graph_matches_paper():
    g = G.demo_graph()
    assert g.num_vertices == 7
    assert g.num_edges == 15
    _check_csr(g)


@pytest.mark.parametrize("scale,ef", [(6, 4), (8, 8), (10, 16)])
def test_rmat_wellformed(scale, ef):
    g = G.rmat(scale, ef, seed=1, weighted=True)
    _check_csr(g)
    assert g.num_vertices == 1 << scale
    # undirected symmetrization: every edge has its reverse
    src, dst = G.edge_list(g)
    fwd = set(zip(src.tolist(), dst.tolist()))
    assert all((d, s) in fwd for s, d in list(fwd)[:500])
    # weights in [1, 64) like the paper's datasets
    w = np.asarray(g.edge_values)
    assert w.min() >= 1 and w.max() < 64


def test_sorted_neighbor_lists():
    g = G.rmat(8, 8, seed=2)
    ro = np.asarray(g.row_offsets)
    ci = np.asarray(g.col_indices)
    for u in range(0, g.num_vertices, 7):
        nb = ci[ro[u]:ro[u + 1]]
        assert np.all(np.diff(nb) > 0), "neighbors must be sorted+unique"


def test_csc_is_transpose():
    g = G.rmat(7, 6, seed=3)
    src, dst = G.edge_list(g)
    fwd = sorted(zip(src.tolist(), dst.tolist()))
    co = np.asarray(g.csc_offsets)
    ci2 = np.asarray(g.csc_indices)
    rev_dst = np.repeat(np.arange(g.num_vertices), np.diff(co))
    rev = sorted(zip(ci2.tolist(), rev_dst.tolist()))
    assert fwd == rev


def test_grid2d_structure():
    g = G.grid2d(5)
    _check_csr(g)
    assert g.num_vertices == 25
    deg = np.diff(np.asarray(g.row_offsets))
    assert deg.max() == 4 and deg.min() == 2


def test_rgg_degrees_bounded():
    g = G.random_geometric(512, 0.08, seed=1)
    _check_csr(g)
    src, dst = G.edge_list(g)
    assert np.all(src != dst)


def test_bipartite_direction():
    g = G.bipartite_random(50, 30, 4, seed=0)
    src, dst = G.edge_list(g)
    assert src.max() < 50 and dst.min() >= 50


@given(st.lists(st.tuples(st.integers(0, 19), st.integers(0, 19)),
                min_size=0, max_size=60))
def test_from_edge_list_properties(edges):
    src = [e[0] for e in edges]
    dst = [e[1] for e in edges]
    g = G.from_edge_list(src, dst, n=20, undirected=False)
    _check_csr(g)
    # dedup + self-loop removal
    s2, d2 = G.edge_list(g)
    pairs = list(zip(s2.tolist(), d2.tolist()))
    assert len(pairs) == len(set(pairs))
    assert all(s != d for s, d in pairs)
    expect = {(s, d) for s, d in edges if s != d}
    assert set(pairs) == expect


def test_neighbors_padded():
    g = G.demo_graph()
    nbrs, mask = g.neighbors_padded(4)
    deg = np.diff(np.asarray(g.row_offsets))
    assert np.array_equal(np.asarray(mask).sum(1),
                          np.minimum(deg, 4))
    assert np.all(np.asarray(nbrs)[~np.asarray(mask)] == -1)


# ---- structural validation (PR 10, Graph.from_csr(validate=True)) --------

def test_validate_accepts_wellformed():
    indptr = np.array([0, 2, 3, 3, 4], np.int64)
    cols = np.array([1, 3, 2, 0], np.int64)
    vals = np.array([1.0, 2.0, 0.5, 3.0], np.float32)
    assert G.validate_csr(indptr, cols, vals) == (4, 4)
    g = G.Graph.from_csr(indptr, cols, vals, validate=True)
    assert g.num_vertices == 4 and g.num_edges == 4


@pytest.mark.parametrize("indptr, cols, vals, needle", [
    # non-monotone indptr: row 1 named with both offsets
    ([0, 3, 2, 4], [0, 1, 2, 3], None, "row 1"),
    # indptr does not start at zero
    ([1, 2, 4], [0, 1, 1], None, "must be 0"),
    # last offset disagrees with the edge count
    ([0, 2, 3], [0, 1], None, "col_indices"),
    # out-of-range column id: the edge index is named
    ([0, 2], [0, 7], None, "edge 1"),
    # negative column id
    ([0, 1], [-1], None, "edge 0"),
    # edge_values length mismatch
    ([0, 1, 2], [0, 1], [1.0], "edge_values"),
    # non-finite weight
    ([0, 1, 2], [0, 1], [1.0, float("nan")], "finite"),
])
def test_validate_rejects_malformed(indptr, cols, vals, needle):
    vals = None if vals is None else np.asarray(vals, np.float32)
    with pytest.raises(G.GraphValidationError, match=needle):
        G.validate_csr(np.asarray(indptr, np.int64),
                       np.asarray(cols, np.int64), vals)
    with pytest.raises(G.GraphValidationError):
        G.Graph.from_csr(np.asarray(indptr, np.int64),
                         np.asarray(cols, np.int64), vals, validate=True)


def test_validate_default_off_is_unchanged():
    # an indptr that does not start at 0 builds (garbage-in) without
    # validate= — the flag must not change default construction
    indptr = np.array([0, 2, 3, 3, 4], np.int64)
    cols = np.array([1, 3, 2, 0], np.int64)
    a = G.Graph.from_csr(indptr, cols)
    b = G.Graph.from_csr(indptr, cols, validate=True)
    assert np.array_equal(np.asarray(a.row_offsets),
                          np.asarray(b.row_offsets))
    assert np.array_equal(a.cols_np(), b.cols_np())


def test_validate_graph_roundtrip():
    g = G.rmat(6, 8, seed=3, weighted=True)
    n, m = G.validate_graph(g)
    assert (n, m) == (g.num_vertices, g.num_edges)
