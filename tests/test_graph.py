"""Graph container + generator invariants (unit + property)."""
import numpy as np
import pytest
from _hyp import given, st

from repro.core import graph as G


def _check_csr(g):
    ro = np.asarray(g.row_offsets)
    ci = np.asarray(g.col_indices)
    n = g.num_vertices
    assert ro[0] == 0 and ro[-1] == len(ci)
    assert np.all(np.diff(ro) >= 0)
    if len(ci):
        assert ci.min() >= 0 and ci.max() < n


def test_demo_graph_matches_paper():
    g = G.demo_graph()
    assert g.num_vertices == 7
    assert g.num_edges == 15
    _check_csr(g)


@pytest.mark.parametrize("scale,ef", [(6, 4), (8, 8), (10, 16)])
def test_rmat_wellformed(scale, ef):
    g = G.rmat(scale, ef, seed=1, weighted=True)
    _check_csr(g)
    assert g.num_vertices == 1 << scale
    # undirected symmetrization: every edge has its reverse
    src, dst = G.edge_list(g)
    fwd = set(zip(src.tolist(), dst.tolist()))
    assert all((d, s) in fwd for s, d in list(fwd)[:500])
    # weights in [1, 64) like the paper's datasets
    w = np.asarray(g.edge_values)
    assert w.min() >= 1 and w.max() < 64


def test_sorted_neighbor_lists():
    g = G.rmat(8, 8, seed=2)
    ro = np.asarray(g.row_offsets)
    ci = np.asarray(g.col_indices)
    for u in range(0, g.num_vertices, 7):
        nb = ci[ro[u]:ro[u + 1]]
        assert np.all(np.diff(nb) > 0), "neighbors must be sorted+unique"


def test_csc_is_transpose():
    g = G.rmat(7, 6, seed=3)
    src, dst = G.edge_list(g)
    fwd = sorted(zip(src.tolist(), dst.tolist()))
    co = np.asarray(g.csc_offsets)
    ci2 = np.asarray(g.csc_indices)
    rev_dst = np.repeat(np.arange(g.num_vertices), np.diff(co))
    rev = sorted(zip(ci2.tolist(), rev_dst.tolist()))
    assert fwd == rev


def test_grid2d_structure():
    g = G.grid2d(5)
    _check_csr(g)
    assert g.num_vertices == 25
    deg = np.diff(np.asarray(g.row_offsets))
    assert deg.max() == 4 and deg.min() == 2


def test_rgg_degrees_bounded():
    g = G.random_geometric(512, 0.08, seed=1)
    _check_csr(g)
    src, dst = G.edge_list(g)
    assert np.all(src != dst)


def test_bipartite_direction():
    g = G.bipartite_random(50, 30, 4, seed=0)
    src, dst = G.edge_list(g)
    assert src.max() < 50 and dst.min() >= 50


@given(st.lists(st.tuples(st.integers(0, 19), st.integers(0, 19)),
                min_size=0, max_size=60))
def test_from_edge_list_properties(edges):
    src = [e[0] for e in edges]
    dst = [e[1] for e in edges]
    g = G.from_edge_list(src, dst, n=20, undirected=False)
    _check_csr(g)
    # dedup + self-loop removal
    s2, d2 = G.edge_list(g)
    pairs = list(zip(s2.tolist(), d2.tolist()))
    assert len(pairs) == len(set(pairs))
    assert all(s != d for s, d in pairs)
    expect = {(s, d) for s, d in edges if s != d}
    assert set(pairs) == expect


def test_neighbors_padded():
    g = G.demo_graph()
    nbrs, mask = g.neighbors_padded(4)
    deg = np.diff(np.asarray(g.row_offsets))
    assert np.array_equal(np.asarray(mask).sum(1),
                          np.minimum(deg, 4))
    assert np.all(np.asarray(nbrs)[~np.asarray(mask)] == -1)
