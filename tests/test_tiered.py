"""Frontier-proportional performance layer: capacity tiers, the fused
advance_filter megakernel, and the kernel autotuner.

Contracts under test:
  * tier machinery: ladder construction, rung selection, pinning;
  * fused advance_filter == the unfused advance→filter composition,
    bit for bit, on both backends (single-lane and batched, empty
    frontiers, duplicate-heavy expansions, cap_front overflow);
  * bfs/sssp results are bit-identical between the tiered dispatch and
    the pinned top tier, on both backends, with frontier sizes
    straddling the tier ladder's rungs (the rmat fixture's BFS crosses
    512 within two hops);
  * tuner: clamped default heuristic, cache round trip, env switches.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend as B
from repro.core import frontier as F
from repro.core import graph as G
from repro.core import operators as ops
from repro.core import ref as R
from repro.core.enactor import tiered_step
from repro.core.primitives import bfs_batch, sssp_batch
from repro.kernels import runtime, tuner

BACKENDS = ["xla", "pallas"]


# ---------------------------------------------------------------------------
# tier metadata
# ---------------------------------------------------------------------------


def test_tier_caps_ladder():
    assert F.tier_caps(100) == (100,)
    assert F.tier_caps(512) == (512,)
    assert F.tier_caps(513) == (512, 513)
    assert F.tier_caps(5000) == (512, 1024, 2048, 4096, 5000)
    # top rung is exactly the cap, never a rounded-up power of two
    assert F.tier_caps(97194)[-1] == 97194


def test_tier_index_picks_smallest_sufficient_rung():
    caps = (512, 1024, 2048, 4096)
    for need, want in [(0, 0), (1, 0), (512, 0), (513, 1), (1024, 1),
                       (2049, 3), (4096, 3), (999999, 3)]:
        assert int(F.tier_index(jnp.int32(need), caps)) == want, need


def test_tier_plan_floor_and_pinning():
    caps = B.tier_plan("advance_filter", 4096)
    assert caps[0] >= F.MIN_TIER and caps[-1] == 4096
    impl, pinned = B.dispatch_tiered("advance", cap=4096, pin=True)
    assert pinned == (4096,)
    assert callable(impl)


def test_tiered_step_runs_selected_branch():
    caps = (4, 8, 16)
    out = tiered_step(jnp.int32(5), caps, lambda c: (lambda s: s + c),
                      jnp.int32(0))
    assert int(out) == 8
    # single-rung ladder: no switch, just the one branch
    out = tiered_step(jnp.int32(5), (32,), lambda c: (lambda s: s + c),
                      jnp.int32(0))
    assert int(out) == 32


def test_frontier_workload_counts_live_degrees(rmat_graph):
    fr = F.from_ids([0, 1, 2], 8)
    deg = np.diff(np.asarray(rmat_graph.row_offsets))
    want = int(deg[0] + deg[1] + deg[2])
    assert int(ops.frontier_workload(rmat_graph, fr)) == want
    # dead lanes contribute nothing
    assert int(ops.frontier_workload(rmat_graph, F.empty(8))) == 0


# ---------------------------------------------------------------------------
# fused advance_filter vs the unfused composition
# ---------------------------------------------------------------------------


def _compose_reference(g, fr, visited, cap_out, cap_front):
    """The definitional oracle: unfused advance, visited predicate,
    first-occurrence culling, compaction — in plain numpy."""
    res, _ = ops.advance(g, fr, cap_out, backend="xla")
    dst = np.asarray(res.dst)
    src = np.asarray(res.src)
    valid = np.asarray(res.valid)
    vis = np.asarray(visited).astype(bool)
    seen = set()
    ids, srcs = [], []
    total = 0
    for i in range(cap_out):
        if not valid[i] or vis[dst[i]] or dst[i] in seen:
            continue
        seen.add(dst[i])
        total += 1
        if len(ids) < cap_front:
            ids.append(dst[i])
            srcs.append(src[i])
    pad = cap_front - len(ids)
    return (np.array(ids + [-1] * pad, np.int32),
            np.array(srcs + [-1] * pad, np.int32), len(ids), total)


@pytest.mark.parametrize("backend", BACKENDS)
def test_advance_filter_matches_composition(rmat_graph, backend):
    g = rmat_graph
    n = g.num_vertices
    rng = np.random.default_rng(3)
    fr = F.from_ids(rng.integers(0, n, 12), 32)
    visited = jnp.asarray(rng.random(n) < 0.3)
    out, srcs, total = ops.advance_filter(g, fr, visited, 2048, 64,
                                          backend=backend)
    w_ids, w_srcs, w_len, w_total = _compose_reference(
        g, fr, visited, 2048, 64)
    assert np.array_equal(np.asarray(out.ids), w_ids)
    assert np.array_equal(np.asarray(srcs), w_srcs)
    assert int(out.length) == w_len
    assert int(total) == w_total


def test_advance_filter_backend_parity_matrix(rmat_graph, grid_graph):
    """xla and pallas providers agree bit for bit across graphs,
    visited densities and cap_front overflow."""
    rng = np.random.default_rng(11)
    for g in (rmat_graph, grid_graph):
        n = g.num_vertices
        for density, cap_front in [(0.0, 256), (0.5, 256), (0.9, 8)]:
            fr = F.from_ids(rng.integers(0, n, 24), 32)
            visited = jnp.asarray(rng.random(n) < density)
            ox, sx, tx = ops.advance_filter(g, fr, visited, 4096,
                                            cap_front, backend="xla")
            op_, sp, tp = ops.advance_filter(g, fr, visited, 4096,
                                             cap_front, backend="pallas")
            key = (density, cap_front)
            assert np.array_equal(np.asarray(ox.ids),
                                  np.asarray(op_.ids)), key
            assert np.array_equal(np.asarray(sx), np.asarray(sp)), key
            assert int(ox.length) == int(op_.length), key
            assert int(tx) == int(tp), key


@pytest.mark.parametrize("backend", BACKENDS)
def test_advance_filter_empty_frontier(rmat_graph, backend):
    out, srcs, total = ops.advance_filter(
        rmat_graph, F.empty(16),
        jnp.zeros(rmat_graph.num_vertices, bool), 512, 32,
        backend=backend)
    assert int(out.length) == 0 and int(total) == 0
    assert np.all(np.asarray(out.ids) == -1)
    assert np.all(np.asarray(srcs) == -1)


@pytest.mark.parametrize("backend", BACKENDS)
def test_advance_filter_batch_matches_single(rmat_graph, backend):
    g = rmat_graph
    n = g.num_vertices
    rng = np.random.default_rng(7)
    lanes = [rng.integers(0, n, 6) for _ in range(3)]
    bf = F.BatchedSparseFrontier(
        ids=jnp.stack([F.from_ids(l, 16).ids for l in lanes]),
        lengths=jnp.asarray([len(l) for l in lanes], jnp.int32))
    visited = jnp.asarray(rng.random((3, n)) < 0.4)
    bout, bsrcs, btot = ops.advance_filter_batch(g, bf, visited, 1024,
                                                 128, backend=backend)
    for i, l in enumerate(lanes):
        out, srcs, tot = ops.advance_filter(
            g, F.from_ids(l, 16), visited[i], 1024, 128, backend=backend)
        assert np.array_equal(np.asarray(bout.ids[i]),
                              np.asarray(out.ids)), i
        assert np.array_equal(np.asarray(bsrcs[i]), np.asarray(srcs)), i
        assert int(btot[i]) == int(tot), i


# ---------------------------------------------------------------------------
# tiered primitives bit-match the pinned top tier across tier boundaries
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_bfs_tiered_bitmatch_across_boundaries(rmat_graph,
                                               high_degree_src, backend):
    """The hub source's first expansion exceeds 512 while later
    iterations collapse under it, so one traversal crosses rungs in
    both directions; corner sources stay sub-tier throughout."""
    g = rmat_graph
    assert B.tier_plan("advance_filter", g.num_edges)[0] < g.num_edges
    srcs = [high_degree_src, 0, g.num_vertices - 1]
    rt = bfs_batch(g, srcs, backend=backend, tiered=True)
    ru = bfs_batch(g, srcs, backend=backend, tiered=False)
    for f in rt._fields:
        assert np.array_equal(np.asarray(getattr(rt, f)),
                              np.asarray(getattr(ru, f))), (f, backend)
    for i, s in enumerate(srcs):
        assert np.array_equal(np.asarray(rt.labels[i]),
                              R.bfs_ref(g, s)), i


@pytest.mark.parametrize("backend", BACKENDS)
def test_sssp_tiered_bitmatch(rmat_graph, high_degree_src, backend):
    g = rmat_graph
    srcs = [high_degree_src, 0]
    rt = sssp_batch(g, srcs, backend=backend, tiered=True)
    ru = sssp_batch(g, srcs, backend=backend, tiered=False)
    for f in rt._fields:
        assert np.array_equal(np.asarray(getattr(rt, f)),
                              np.asarray(getattr(ru, f))), (f, backend)
    assert np.allclose(np.asarray(rt.dist[0]),
                       R.sssp_ref(g, high_degree_src), rtol=1e-5)


# ---------------------------------------------------------------------------
# degenerate graphs through the tiered dispatch (PR 6 satellite):
# shapes where the tier ladder collapses (0/1 rungs), rows expand to
# nothing, or one row exceeds every non-top rung by itself
# ---------------------------------------------------------------------------


def _tiered_equals_pinned(g, srcs, backend):
    rt = bfs_batch(g, srcs, backend=backend, tiered=True)
    ru = bfs_batch(g, srcs, backend=backend, tiered=False)
    for f in rt._fields:
        assert np.array_equal(np.asarray(getattr(rt, f)),
                              np.asarray(getattr(ru, f))), (f, backend)
    for i, s in enumerate(srcs):
        assert np.array_equal(np.asarray(rt.labels[i]), R.bfs_ref(g, s)), i
    return rt


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("encoding", ["dense", "delta"])
def test_tiered_edgeless_graph(backend, encoding):
    """Zero edges: the expansion cap is 0, so the fused tiered path is
    skipped entirely — every source terminates at depth 0."""
    e = np.zeros(0, np.int64)
    g = G.from_edge_list(e, e, n=8, encoding=encoding)
    assert g.num_edges == 0
    rt = _tiered_equals_pinned(g, [0, 7], backend)
    want = np.full(8, -1, np.int32)
    want[0] = 0
    assert np.array_equal(np.asarray(rt.labels[0]), want)


@pytest.mark.parametrize("backend", BACKENDS)
def test_tiered_single_vertex(backend):
    e = np.zeros(0, np.int64)
    g = G.from_edge_list(e, e, n=1)
    rt = _tiered_equals_pinned(g, [0], backend)
    assert np.asarray(rt.labels[0]).tolist() == [0]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("encoding", ["dense", "delta"])
def test_tiered_all_self_loops(backend, encoding):
    """Every row is exactly one self-loop: frontiers expand into already-
    visited vertices only, so the traversal must settle after one step
    (a filter that never compacts anything new)."""
    ids = np.arange(16, dtype=np.int64)
    g = G.from_edge_list(ids, ids, n=16, remove_self_loops=False,
                         encoding=encoding)
    assert g.num_edges == 16
    rt = _tiered_equals_pinned(g, [3], backend)
    want = np.full(16, -1, np.int32)
    want[3] = 0
    assert np.array_equal(np.asarray(rt.labels[0]), want)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("encoding", ["dense", "delta"])
def test_tiered_star_degree_exceeds_lower_rungs(backend, encoding):
    """A hub whose single-row expansion (1500 edges) exceeds every
    power-of-two rung below the top: the first step must select the top
    (exact-cap) rung while the return wave (1500 leaves × degree 1) fits
    a bottom rung — both directions of the ladder in one traversal."""
    hub = np.zeros(1500, np.int64)
    leaves = np.arange(1, 1501, dtype=np.int64)
    w = np.random.default_rng(0).integers(1, 64, 1500).astype(np.float32)
    g = G.from_edge_list(hub, leaves, n=1501, undirected=True, values=w,
                         encoding=encoding)
    caps = B.tier_plan("advance_filter", g.num_edges)
    assert caps[0] < 1500 <= caps[-1]
    rt = _tiered_equals_pinned(g, [0, 1500], backend)
    assert int(np.asarray(rt.labels[0]).max()) == 1
    sr = sssp_batch(g, [0], backend=backend, tiered=True)
    su = sssp_batch(g, [0], backend=backend, tiered=False)
    assert np.array_equal(np.asarray(sr.dist), np.asarray(su.dist))


def test_bfs_tiered_overflow_lane_stays_frozen(rmat_graph):
    """A lane that converges early (empty frontier ⇒ workload 0) keeps
    selecting the bottom rung while the straggler drives the switch —
    frozen lanes must stay bit-stable regardless of the rung chosen."""
    g = rmat_graph
    deg = np.diff(np.asarray(g.row_offsets))
    leaf = int(np.argmin(deg))
    rt = bfs_batch(g, [leaf, int(np.argmax(deg))], tiered=True)
    ru = bfs_batch(g, [leaf, int(np.argmax(deg))], tiered=False)
    assert np.array_equal(np.asarray(rt.labels), np.asarray(ru.labels))
    assert np.array_equal(np.asarray(rt.iterations),
                          np.asarray(ru.iterations))


# ---------------------------------------------------------------------------
# tuner + runtime
# ---------------------------------------------------------------------------


def test_default_tile_clamps_to_padded_output():
    """The satellite fix: a small capacity must never inflate the tile
    past pow2_ceil(cap) (the old heuristic pinned 512 minimum)."""
    assert tuner.default_tile(40) == 64
    assert tuner.default_tile(1) == 1
    assert tuner.default_tile(512) == 512
    # the grid bound still grows tiles for big caps…
    assert tuner.default_tile(512 * 1024) > 512
    # …but never past the padded output size, even under a tiny grid
    # budget that would have doubled forever pre-fix
    assert tuner.default_tile(700, min_tile=512, max_grid=1) == 1024
    assert tuner.default_tile(40, max_grid=1) == 64


def test_tile_for_prefers_cache_entry(tmp_path, monkeypatch):
    path = tmp_path / "cache.json"
    key = f"advance|{tuner.tier_of(4096)}|{runtime.platform()}|dense"
    path.write_text(json.dumps(
        {"version": 2, "entries": {key: {"tile": 2048}}}))
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(path))
    monkeypatch.delenv("REPRO_TUNE", raising=False)
    assert tuner.tile_for("advance", 4096) == 2048
    # REPRO_TUNE=0 ignores the cache (pure heuristic)
    monkeypatch.setenv("REPRO_TUNE", "0")
    assert tuner.tile_for("advance", 4096) == tuner.default_tile(4096)
    # stale schema versions are ignored wholesale — v1 entries lacked
    # the encoding axis, so the v2 bump invalidates them
    monkeypatch.delenv("REPRO_TUNE", raising=False)
    path.write_text(json.dumps(
        {"version": 1, "entries": {key.rsplit("|", 1)[0]: {"tile": 2048}}}))
    assert tuner.tile_for("advance", 4096) == tuner.default_tile(4096)


def test_tile_for_encoding_axis(tmp_path, monkeypatch):
    """The v2 cache keys on storage encoding: a delta launch prefers its
    own measurement, falls back to the dense entry at the same tier, and
    a dense launch never reads the delta entry."""
    path = tmp_path / "cache.json"
    tier = tuner.tier_of(4096)
    plat = runtime.platform()
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(path))
    monkeypatch.delenv("REPRO_TUNE", raising=False)
    # dense-only cache: delta launches borrow the dense measurement
    path.write_text(json.dumps({"version": 2, "entries": {
        f"advance|{tier}|{plat}|dense": {"tile": 2048}}}))
    assert tuner.tile_for("advance", 4096, encoding="delta") == 2048
    # both present: each encoding reads its own entry
    path.write_text(json.dumps({"version": 2, "entries": {
        f"advance|{tier}|{plat}|dense": {"tile": 2048},
        f"advance|{tier}|{plat}|delta": {"tile": 1024}}}))
    assert tuner.tile_for("advance", 4096, encoding="delta") == 1024
    assert tuner.tile_for("advance", 4096, encoding="dense") == 2048
    # delta-only cache: a dense launch does NOT borrow backwards
    path.write_text(json.dumps({"version": 2, "entries": {
        f"advance|{tier}|{plat}|delta": {"tile": 1024}}}))
    assert tuner.tile_for("advance", 4096) == tuner.default_tile(4096)


def test_autotune_persists_measured_tile(tmp_path, monkeypatch):
    path = tmp_path / "cache.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(path))
    monkeypatch.setenv("REPRO_TUNE", "1")
    calls = []

    def probe(cap, tile):
        calls.append(tile)
        return 0.001 if tile == 256 else 0.01

    tile = tuner.autotune("fake_op", 1024, probe, repeats=1, force=True)
    assert tile == 256
    data = json.loads(path.read_text())
    assert data["version"] == 2
    entry = data["entries"][
        f"fake_op|{tuner.tier_of(1024)}|{runtime.platform()}|dense"]
    assert entry["tile"] == 256
    # a second call hits the cache, not the probe
    calls.clear()
    assert tuner.tile_for("fake_op", 1024) == 256
    assert calls == []


def test_probes_registered_for_hot_ops():
    import repro.kernels.ops  # noqa: F401  registers on import
    for op in ("advance", "advance_filter", "compact", "lb_expand",
               "spmv"):
        assert op in tuner.PROBES, op


def test_interpret_mode_resolution(monkeypatch):
    assert runtime.interpret_mode(True) is True
    assert runtime.interpret_mode(False) is False
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "0")
    assert runtime.interpret_mode(None) is False
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    assert runtime.interpret_mode(None) is True
    monkeypatch.delenv("REPRO_FORCE_INTERPRET")
    import jax
    assert runtime.interpret_mode(None) == (jax.default_backend()
                                            != "tpu")
    # the tuner's platform key distinguishes interpret mode
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    assert runtime.platform().endswith("+interpret")


def test_registry_has_advance_filter_both_backends():
    for op in ("advance_filter", "advance_filter_batch"):
        assert B.registered(op, B.XLA), op
        assert B.registered(op, B.PALLAS), op
