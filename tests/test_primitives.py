"""Graph primitives vs. numpy oracles (unit + property)."""
import collections

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import graph as G
from repro.core import ref as R
from repro.core.primitives import (bc, bfs, connected_components, pagerank,
                                   sssp, triangle_count, who_to_follow)
from repro.core.primitives.sssp import sssp_bellman_ford
from repro.core.primitives.tc import triangle_count_full


@pytest.mark.parametrize("direction,idem,strategy", [
    (False, False, "LB"), (False, True, "LB"), (True, True, "LB"),
    (True, False, "LB"), (False, False, "TWC"), (False, False, "THREAD"),
])
def test_bfs_all_modes(rmat_graph, high_degree_src, direction, idem,
                       strategy):
    r = bfs(rmat_graph, high_degree_src, direction=direction,
            idempotence=idem, strategy=strategy)
    ref = R.bfs_ref(rmat_graph, high_degree_src)
    assert np.array_equal(np.asarray(r.labels), ref)


def test_bfs_direction_actually_pulls(rmat_graph, high_degree_src):
    r = bfs(rmat_graph, high_degree_src, direction=True, do_a=0.001,
            do_b=0.2)
    assert int(r.pull_iters) > 0, "scale-free graph should trigger pull"


def test_bfs_preds_form_tree(rmat_graph, high_degree_src):
    r = bfs(rmat_graph, high_degree_src, direction=False,
            record_preds=True)
    lab = np.asarray(r.labels)
    pre = np.asarray(r.preds)
    for v in range(rmat_graph.num_vertices):
        if lab[v] > 0:
            assert lab[pre[v]] == lab[v] - 1


def test_bfs_mesh_graph(grid_graph):
    r = bfs(grid_graph, 0, direction=True)
    assert np.array_equal(np.asarray(r.labels), R.bfs_ref(grid_graph, 0))


def test_sssp_delta_and_bf(rmat_graph, high_degree_src):
    ref = R.sssp_ref(rmat_graph, high_degree_src)
    for fn, kw in [(sssp, {}), (sssp, {"delta": 16.0}),
                   (sssp_bellman_ford, {})]:
        r = fn(rmat_graph, high_degree_src, **kw)
        assert np.allclose(np.asarray(r.dist), ref, rtol=1e-5), kw


def test_sssp_preds_valid(rmat_graph, high_degree_src):
    r = sssp(rmat_graph, high_degree_src)
    dist = np.asarray(r.dist)
    preds = np.asarray(r.preds)
    ro = np.asarray(rmat_graph.row_offsets)
    ci = np.asarray(rmat_graph.col_indices)
    w = np.asarray(rmat_graph.edge_values)
    for v in range(rmat_graph.num_vertices):
        if np.isfinite(dist[v]) and v != high_degree_src:
            p = preds[v]
            assert p >= 0
            edges = {ci[e]: w[e] for e in range(ro[p], ro[p + 1])}
            assert v in edges
            assert np.isclose(dist[p] + edges[v], dist[v], rtol=1e-5)


def test_sssp_delta_stepping_fewer_relaxations(grid_graph):
    # delta-stepping should do no more relaxation work than Bellman-Ford
    # on a large-diameter graph (the paper's motivation for the PQ)
    r_d = sssp(grid_graph, 0, delta=32.0)
    r_bf = sssp_bellman_ford(grid_graph, 0)
    assert np.allclose(np.asarray(r_d.dist), np.asarray(r_bf.dist))
    assert int(r_d.relaxations) <= int(r_bf.relaxations)


def test_pagerank(rmat_graph):
    r = pagerank(rmat_graph, max_iter=15)
    ref = R.pagerank_ref(rmat_graph, iters=15)
    assert np.allclose(np.asarray(r.rank), ref, atol=1e-6)
    assert abs(float(jnp.sum(r.rank)) - 1.0) < 1e-3


def test_pagerank_convergence_filter(rmat_graph):
    r = pagerank(rmat_graph, tol=1e-7, max_iter=200)
    assert int(r.iterations) < 200


def _same_partition(a, b):
    pa = collections.defaultdict(set)
    pb = collections.defaultdict(set)
    for i, (x, y) in enumerate(zip(a, b)):
        pa[x].add(i)
        pb[y].add(i)
    return sorted(map(frozenset, pa.values())) == \
        sorted(map(frozenset, pb.values()))


def test_cc(rmat_graph):
    r = connected_components(rmat_graph)
    ref = R.cc_ref(rmat_graph)
    assert _same_partition(np.asarray(r.labels).tolist(), ref.tolist())
    assert int(r.num_components) == len(set(ref.tolist()))


def test_bc(rmat_graph, high_degree_src):
    r = bc(rmat_graph, high_degree_src)
    ref = R.bc_ref(rmat_graph, high_degree_src)
    assert np.allclose(np.asarray(r.bc), ref, rtol=1e-3, atol=1e-3)


def test_tc_filtered_and_full(rmat_graph):
    ref = R.tc_ref(rmat_graph)
    assert int(triangle_count(rmat_graph).total) == ref
    assert int(triangle_count_full(rmat_graph)) == ref


def test_tc_kernel(rmat_graph):
    assert int(triangle_count(rmat_graph, use_kernel=True).total) == \
        R.tc_ref(rmat_graph)


def test_wtf_pipeline(rmat_graph, high_degree_src):
    r = who_to_follow(rmat_graph, high_degree_src, k=32, ppr_iters=15,
                      salsa_iters=4)
    assert np.allclose(np.asarray(r.ppr),
                       R.ppr_ref(rmat_graph, high_degree_src, iters=15),
                       atol=1e-5)
    cot = np.asarray(r.cot)
    vals = np.asarray(r.ppr)[cot]
    hubs = np.zeros(rmat_graph.num_vertices, bool)
    hubs[cot[vals > 0]] = True
    h_ref, a_ref = R.salsa_ref(rmat_graph, hubs, iters=4)
    assert np.allclose(np.asarray(r.hub_scores), h_ref, atol=1e-5)
    assert np.allclose(np.asarray(r.auth_scores), a_ref, atol=1e-5)
    # the query user must not recommend itself
    assert high_degree_src not in cot.tolist()


# ---------------------------------------------------------------------------
# property-based: random graphs, random sources
# ---------------------------------------------------------------------------

@st.composite
def random_graph(draw):
    n = draw(st.integers(4, 24))
    m = draw(st.integers(0, 60))
    edges = draw(st.lists(st.tuples(st.integers(0, n - 1),
                                    st.integers(0, n - 1)),
                          min_size=m, max_size=m))
    src = [e[0] for e in edges]
    dst = [e[1] for e in edges]
    w = [float(draw(st.integers(1, 9))) for _ in edges]
    g = G.from_edge_list(src, dst, n=n, values=w, undirected=True)
    return g


@given(random_graph(), st.integers(0, 3))
@settings(max_examples=12)
def test_bfs_property(g, src_seed):
    src = src_seed % g.num_vertices
    r = bfs(g, src, direction=False, idempotence=False)
    assert np.array_equal(np.asarray(r.labels), R.bfs_ref(g, src))


@given(random_graph(), st.integers(0, 3))
@settings(max_examples=12)
def test_sssp_property(g, src_seed):
    if not g.weighted or g.num_edges == 0:
        return
    src = src_seed % g.num_vertices
    r = sssp(g, src)
    assert np.allclose(np.asarray(r.dist), R.sssp_ref(g, src), rtol=1e-5)


@given(random_graph())
@settings(max_examples=12)
def test_cc_property(g):
    r = connected_components(g)
    assert _same_partition(np.asarray(r.labels).tolist(),
                           R.cc_ref(g).tolist())


@given(random_graph())
@settings(max_examples=12)
def test_tc_property(g):
    assert int(triangle_count(g).total) == R.tc_ref(g)
