"""Direct unit tests for the direction-optimization heuristics
(core/direction.py, paper §5.1.4 eqs. 1–6) — previously exercised only
indirectly through the fig21 benchmark sweep."""
import jax.numpy as jnp
import numpy as np

from repro.core.direction import (PULL, PUSH, DirectionParams,
                                  decide_direction, estimate_workloads)


def test_estimate_workloads_printed_formulas():
    """m_f = n_f·m/n and m_u = n_u·n/(n−n_u), the paper's eqs. 3/4."""
    n, m = 100, 1600
    m_f, m_u = estimate_workloads(jnp.int32(10), jnp.int32(40), n, m)
    assert np.isclose(float(m_f), 10 * m / n)
    assert np.isclose(float(m_u), 40 * n / (n - 40))


def test_estimate_workloads_n_u_equals_n_guard():
    """The n_u == n pole of eq. 4 (nothing visited yet): the max(·, 1)
    denominator guard must keep the estimate finite."""
    n, m = 64, 512
    m_f, m_u = estimate_workloads(jnp.int32(1), jnp.int32(n), n, m)
    assert np.isfinite(float(m_u))
    assert np.isclose(float(m_u), n * n / 1.0)
    # and past the pole (n_u > n can transiently happen with batched
    # bookkeeping): still finite, still the clamped denominator
    m_f, m_u = estimate_workloads(jnp.int32(1), jnp.int32(n + 3), n, m)
    assert np.isfinite(float(m_u))


def test_decide_direction_disabled_always_push():
    params = DirectionParams(enabled=False)
    for mode in (PUSH, PULL):
        got = decide_direction(mode, jnp.int32(50), jnp.int32(1),
                               64, 4096, params)
        assert int(got) == int(PUSH), mode


def test_decide_direction_hysteresis_round_trip():
    """push→pull on a growing frontier, pull→push once it collapses,
    and the in-between band keeps the current mode (do_b < do_a band
    hysteresis)."""
    n, m = 1000, 16000
    params = DirectionParams(do_a=0.5, do_b=0.01)
    # big frontier while most is unvisited: m_f > m_u·do_a → PULL
    got = decide_direction(PUSH, jnp.int32(600), jnp.int32(390), n, m,
                           params)
    assert int(got) == int(PULL)
    # collapsed frontier: m_f < m_u·do_b → back to PUSH
    got = decide_direction(PULL, jnp.int32(1), jnp.int32(900), n, m,
                           params)
    assert int(got) == int(PUSH)
    # the hysteresis band: neither threshold crossed keeps the mode
    n_f, n_u = jnp.int32(10), jnp.int32(500)
    m_f, m_u = estimate_workloads(n_f, n_u, n, m)
    assert float(m_u) * params.do_b < float(m_f) < float(m_u) * params.do_a
    assert int(decide_direction(PUSH, n_f, n_u, n, m, params)) == int(PUSH)
    assert int(decide_direction(PULL, n_f, n_u, n, m, params)) == int(PULL)


def test_decide_direction_default_params_scale_free_profile():
    """With the paper's defaults a hub frontier on a scale-free graph
    flips to pull within the first hops (the Fig. 21 sweet spot)."""
    n, m = 4096, 97000
    params = DirectionParams()
    assert int(decide_direction(PUSH, jnp.int32(800), jnp.int32(3000),
                                n, m, params)) == int(PULL)
    # a near-dead frontier with plenty still unvisited flips back
    assert int(decide_direction(PULL, jnp.int32(2), jnp.int32(400),
                                n, m, params)) == int(PUSH)
