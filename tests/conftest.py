"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 device;
distributed tests spawn subprocesses that set their own device count."""
import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, settings
except ImportError:          # optional dev dependency (requirements-dev.txt)
    settings = None
else:
    settings.register_profile(
        "repro", max_examples=15, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.load_profile("repro")


@pytest.fixture(scope="session")
def rmat_graph():
    from repro.core import graph as G
    return G.rmat(9, 8, seed=7, weighted=True)


@pytest.fixture(scope="session")
def grid_graph():
    from repro.core import graph as G
    return G.grid2d(20, weighted=True, seed=3)


@pytest.fixture(scope="session")
def high_degree_src(rmat_graph):
    deg = np.diff(np.asarray(rmat_graph.row_offsets))
    return int(np.argmax(deg))
