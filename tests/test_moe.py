"""MoE frontier-dispatch: exactness, capacity culling, aux metrics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.moe import _capacity, moe_ffn, moe_init

rng = np.random.default_rng(0)


def _setup(cf=8.0, b=2, s=8):
    cfg = get_smoke_config("qwen3-moe-235b-a22b").replace(
        capacity_factor=cf)
    params = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    return cfg, params, x


def test_moe_exact_vs_dense_reference():
    cfg, params, x = _setup()
    y, aux = moe_ffn(params, x, cfg)
    assert float(aux["moe_drop_frac"]) == 0.0
    t = x.shape[0] * x.shape[1]
    x2 = x.reshape(t, cfg.d_model)
    probs = jax.nn.softmax(x2 @ params["router"], -1)
    gate, expert = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    yref = np.zeros((t, cfg.d_model), np.float32)
    for i in range(t):
        for j in range(cfg.top_k):
            e = int(expert[i, j])
            v = x2[i]
            h = jax.nn.silu(v @ params["w1"][e]) * (v @ params["w3"][e])
            yref[i] += float(gate[i, j]) * np.asarray(h @ params["w2"][e])
    np.testing.assert_allclose(np.asarray(y.reshape(t, -1)), yref,
                               atol=1e-5)


def test_moe_capacity_drops():
    cfg, params, x = _setup(cf=0.1, b=4, s=32)  # tiny capacity => drops
    y, aux = moe_ffn(params, x, cfg)
    assert float(aux["moe_drop_frac"]) > 0.0
    assert np.all(np.isfinite(np.asarray(y)))


def test_moe_kernel_gather_path():
    cfg, params, x = _setup()
    y1, _ = moe_ffn(params, x, cfg, use_kernel=False)
    # kernel path only valid for the single-shard layout
    import repro.models.moe as M
    y2, _ = moe_ffn(params, x, cfg, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2))


def test_capacity_rounding():
    cfg = get_smoke_config("kimi-k2-1t-a32b")
    c = _capacity(1024, cfg)
    assert c % 8 == 0 and c >= 1024 * cfg.top_k / cfg.n_experts


def test_moe_aux_loss_balanced_vs_skewed():
    cfg, params, x = _setup()
    _, aux = moe_ffn(params, x, cfg)
    base = float(aux["moe_aux_loss"])
    # aux loss is >= 1 (perfectly balanced == 1 for switch-style loss)
    assert base >= 0.99


def test_moe_shared_expert():
    cfg, params, x = _setup()
    cfg2 = cfg.replace(n_shared_experts=1)
    params2 = moe_init(jax.random.PRNGKey(0), cfg2, jnp.float32)
    y, _ = moe_ffn(params2, x, cfg2)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))
