"""SSD core: chunked scan == step recurrence; conv cache; h0 chaining."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.models.mamba2 import (_causal_conv, ssd_chunked, ssd_decode)

rng = np.random.default_rng(3)


def _inputs(b=2, s=24, h=3, p=8, n=5):
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((b, s, h)),
                                     jnp.float32))
    A = -jnp.exp(jnp.asarray(rng.standard_normal((h,)), jnp.float32))
    B = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    return x, dt, A, B, C


def _recurrent(x, dt, A, B, C, h0=None):
    b, s, h, p = x.shape
    n = B.shape[-1]
    hs = jnp.zeros((b, h, n, p)) if h0 is None else h0
    ys = []
    for t in range(s):
        y, hs = ssd_decode(x[:, t:t + 1], dt[:, t:t + 1], A,
                           B[:, t:t + 1], C[:, t:t + 1], hs)
        ys.append(y)
    return jnp.concatenate(ys, axis=1), hs


@given(st.integers(1, 4), st.sampled_from([1, 7, 16, 24, 33]))
@settings(max_examples=10)
def test_chunked_equals_recurrent(chunk_pow, s):
    chunk = 2 ** chunk_pow
    x, dt, A, B, C = _inputs(s=s)
    y1, h1 = ssd_chunked(x, dt, A, B, C, chunk)
    y2, h2 = _recurrent(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)


def test_chunked_h0_chaining():
    """Processing [first half | second half] with state handoff must equal
    one pass — the prefill/decode state contract."""
    x, dt, A, B, C = _inputs(s=32)
    y_full, h_full = ssd_chunked(x, dt, A, B, C, 8)
    y1, h1 = ssd_chunked(x[:, :16], dt[:, :16], A, B[:, :16], C[:, :16], 8)
    y2, h2 = ssd_chunked(x[:, 16:], dt[:, 16:], A, B[:, 16:], C[:, 16:],
                         8, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               atol=1e-4)


def test_causal_conv_streaming():
    """Streaming 1-token conv with state == full-sequence conv."""
    b, s, c, k = 2, 10, 6, 4
    xbc = jnp.asarray(rng.standard_normal((b, s, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, c)) * 0.3, jnp.float32)
    bias = jnp.zeros((c,), jnp.float32)
    full, _ = _causal_conv(xbc, w, bias)
    state = jnp.zeros((b, k - 1, c), jnp.float32)
    outs = []
    for t in range(s):
        o, state = _causal_conv(xbc[:, t:t + 1], w, bias, state)
        outs.append(o)
    stream = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stream), np.asarray(full),
                               atol=1e-5)


def test_decay_stability_long_sequence():
    """No NaN/overflow on long sequences (the long_500k path at small
    scale): decays are exp of negative numbers only."""
    x, dt, A, B, C = _inputs(s=512)
    y, h = ssd_chunked(x, dt, A, B, C, 64)
    assert np.all(np.isfinite(np.asarray(y)))
    assert np.all(np.isfinite(np.asarray(h)))
