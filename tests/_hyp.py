"""Optional-hypothesis shim for the test suite.

``from _hyp import given, settings, st`` behaves exactly like importing
from hypothesis when it is installed (requirements-dev.txt). When it is
missing, only the property-based tests skip — the plain unit tests in the
same module still collect and run, instead of the whole module being
skipped at import time.
"""
import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    HealthCheck = None

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """Absorbs any strategy-construction expression at decoration
        time (st.lists(st.integers(0, 5)), @st.composite, ...)."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

strategies = st
