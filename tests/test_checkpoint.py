"""Checkpointing: roundtrip, atomicity, retention, structure guards."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32),
                  "d": jnp.zeros((2, 2), jnp.bfloat16)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, extra={"data": {"step": 7}})
    like = jax.tree.map(jnp.zeros_like, t)
    got, extra = restore_checkpoint(str(tmp_path), 7, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))
        assert a.dtype == b.dtype
    assert extra["data"]["step"] == 7


def test_latest_and_retention(tmp_path):
    t = _tree()
    for s in (5, 10, 15, 20):
        save_checkpoint(str(tmp_path), s, t, keep=2)
    assert latest_step(str(tmp_path)) == 20
    steps = sorted(int(d[5:]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [15, 20]


def test_atomicity_partial_write_ignored(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    # simulate a crashed mid-write checkpoint: tmp dir without manifest
    os.makedirs(tmp_path / "step_00000009.tmp")
    # and a corrupt final dir missing the manifest
    os.makedirs(tmp_path / "step_00000008")
    assert latest_step(str(tmp_path)) == 3


def test_structure_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    bad = {"a": jnp.zeros((3, 4))}   # fewer leaves
    with pytest.raises(AssertionError):
        restore_checkpoint(str(tmp_path), 1, bad)


def test_shape_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    bad = jax.tree.map(jnp.zeros_like, _tree())
    bad["a"] = jnp.zeros((4, 4))
    with pytest.raises(AssertionError):
        restore_checkpoint(str(tmp_path), 1, bad)


def test_restore_with_mesh_resharding(tmp_path):
    """Elastic path: restore under a (1,1) mesh with spec tree."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_test_mesh
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 2, t)
    mesh = make_test_mesh(1, 1)
    got, _ = restore_checkpoint(str(tmp_path), 2,
                                jax.tree.map(jnp.zeros_like, t),
                                mesh=mesh,
                                spec_tree={"w": P("data", "model")})
    assert np.array_equal(np.asarray(got["w"]), np.asarray(t["w"]))
