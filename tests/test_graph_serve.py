"""Serving-driver unit behavior — in particular the serve_mixed latency
accounting: per-query latency must be measured from the query's OWN
enqueue time, not from stream start. The old code charged every query
all the batches that ran before it joined its slot queue, so p50/p95 of
a mixed stream grew monotonically with stream position."""
import numpy as np
import pytest

from repro.launch import graph_serve


class FakeClock:
    """Deterministic monotonic clock; only the (stubbed) batch execution
    advances it, so latencies are exact integers of 'batch runtimes'."""

    def __init__(self):
        self.t = 0.0

    def monotonic(self):
        return self.t

    def sleep(self, seconds):
        # retry backoff advances fake time instead of blocking the suite
        self.t += seconds


def _stub_runner(clock, batch_seconds=1.0):
    def run(kind, srcs, backend, hops):
        clock.t += batch_seconds           # one batch costs 1 fake second
        return np.zeros((len(srcs), 4), np.float32), \
            np.zeros(len(srcs), np.int64)
    return run


def test_serve_mixed_latency_measured_from_enqueue(monkeypatch):
    clock = FakeClock()
    monkeypatch.setattr(graph_serve, "time", clock)
    # two full bfs batches run BEFORE the sssp queries even arrive; a
    # third kind's single query arrives last and flushes in the ragged
    # tail. Stream: 4×bfs, 2×sssp, 1×reach with batch=2 →
    #   bfs flushes at t=1 and t=2, sssp at t=3, reach (tail) at t=4.
    queries = ([("bfs", 0)] * 4) + ([("sssp", 0)] * 2) + [("reach", 0)]
    stats = graph_serve.serve_mixed(
        None, queries, batch=2, backend="xla",
        runner=_stub_runner(clock))
    per = stats["per_kind"]
    # bfs batch 1 enqueued at t=0, done t=1; batch 2 enqueued t=1, done
    # t=2 → every bfs query waited exactly one batch
    assert per["bfs"]["lat_ms_mean"] == pytest.approx(1000.0)
    assert per["bfs"]["lat_ms_p95"] == pytest.approx(1000.0)
    # the sssp queries enqueued AFTER two bfs batches already ran (t=2)
    # and completed at t=3 — one batch of latency, NOT three. The old
    # stream-start accounting reported 3000 ms here.
    assert per["sssp"]["lat_ms_mean"] == pytest.approx(1000.0)
    # late ragged-tail query: enqueued t=3, flushed t=4
    assert per["reach"]["lat_ms_mean"] == pytest.approx(1000.0)
    # aggregate percentiles no longer grow with stream position
    assert stats["lat_ms_p95"] == pytest.approx(1000.0)
    assert stats["batches"] == 4


def test_serve_mixed_latency_includes_queue_wait(monkeypatch):
    """A query that sits in a half-full slot queue while OTHER kinds'
    batches run still pays its true queue wait (enqueue → completion),
    so the fix cannot under-report either."""
    clock = FakeClock()
    monkeypatch.setattr(graph_serve, "time", clock)
    # sssp#1 arrives first, then two full bfs batches flush (t=1, t=2),
    # then sssp#2 completes the sssp batch which flushes at t=3:
    # sssp#1 waited 3 fake seconds, sssp#2 only 1.
    queries = [("sssp", 0)] + ([("bfs", 0)] * 4) + [("sssp", 0)]
    stats = graph_serve.serve_mixed(
        None, queries, batch=2, backend="xla",
        runner=_stub_runner(clock))
    per = stats["per_kind"]
    assert per["sssp"]["lat_ms_mean"] == pytest.approx(2000.0)  # (3+1)/2
    assert per["bfs"]["lat_ms_mean"] == pytest.approx(1000.0)


def test_serve_mixed_empty_stream_rejected():
    with pytest.raises(ValueError):
        graph_serve.serve_mixed(None, [], batch=2, backend="xla",
                                runner=lambda *a: None)
