"""Per-arch smoke tests (the REQUIRED reduced-config checks): one
forward/train step on CPU asserting output shapes + no NaNs, plus decode
consistency and a loss-decrease run for one arch per family."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, \
    shapes_for
from repro.data import make_batch_for
from repro.models import build_model

B, S = 2, 32


def _train_batch(cfg, seed=0):
    return make_batch_for(cfg, {"global_batch": B, "seq_len": S},
                          "train", seed=seed)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _train_batch(cfg)

    @jax.jit
    def step(p, b):
        (l, m), g = jax.value_and_grad(model.loss, has_aux=True)(p, b)
        return l, g

    loss, grads = step(params, batch)
    assert np.isfinite(float(loss)), arch
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32))), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_shapes(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pf = make_batch_for(cfg, {"global_batch": B, "seq_len": S}, "prefill",
                        seed=1)
    lg, cache = jax.jit(model.prefill)(params, pf)
    assert lg.shape[0] == B and lg.shape[1] == 1
    dec = make_batch_for(cfg, {"global_batch": B, "seq_len": S}, "decode",
                         seed=2)
    if cfg.family == "vlm":
        dec["positions"] = jnp.full((3, B, 1), S, jnp.int32)
    lg2, cache2 = jax.jit(model.decode_step)(params, cache, dec)
    assert lg2.shape[:2] == (B, 1)
    assert np.all(np.isfinite(np.asarray(lg2, np.float32))), arch
    assert int(cache2["len"]) == int(cache["len"]) + 1


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if a != "qwen2-vl-2b"])
def test_decode_matches_direct(arch):
    cfg = get_smoke_config(arch)
    if cfg.is_moe:
        cfg = cfg.replace(capacity_factor=8.0)  # drop-free => exact
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
    extra = {}
    if cfg.family == "encdec":
        extra["frames"] = jnp.asarray(
            rng.standard_normal((B, 16, cfg.d_model)), jnp.float32)
    pf = jax.jit(functools.partial(model.prefill, cache_len=S + 4))
    _, cache = pf(params, {**extra, "tokens": toks[:, :S]})
    lg2, _ = jax.jit(model.decode_step)(params, cache,
                                        {"tokens": toks[:, S:S + 1]})
    lgd, _ = jax.jit(model.prefill)(params, {**extra, "tokens": toks})
    assert float(jnp.max(jnp.abs(lg2 - lgd))) < 2e-3, arch


def test_vlm_mrope_positions_affect_output():
    cfg = get_smoke_config("qwen2-vl-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _train_batch(cfg)
    l1, _ = jax.jit(model.loss)(params, batch)
    batch2 = dict(batch)
    batch2["positions"] = batch["positions"] * 3
    l2, _ = jax.jit(model.loss)(params, batch2)
    assert not np.isclose(float(l1), float(l2))


@pytest.mark.parametrize("arch", ["minicpm-2b", "kimi-k2-1t-a32b",
                                  "mamba2-780m", "zamba2-2.7b",
                                  "whisper-large-v3"])
def test_loss_decreases(arch):
    """Each family must actually learn on the structured synthetic data."""
    from repro.train import adamw, make_schedule
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_init, opt_update = adamw(make_schedule("constant", 5e-3, 100,
                                               warmup_steps=2))
    opt = opt_init(params)

    @jax.jit
    def step(p, o, b):
        (l, m), g = jax.value_and_grad(model.loss, has_aux=True)(p, b)
        p, o, _ = opt_update(g, o, p)
        return p, o, l

    losses = []
    for i in range(12):
        batch = _train_batch(cfg, seed=0)   # same batch: must overfit
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.2, (arch, losses)


def test_full_configs_buildable():
    """Full-size configs must build model objects + spec trees without
    touching device memory (eval_shape only)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build_model(cfg)
        sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(sds))
        specs = model.param_specs({"pod": 2, "data": 16, "model": 16})
        jax.tree.flatten(specs)
        assert n > 1e8, arch  # full configs are big
        shp = shapes_for(cfg)
        assert ("long_500k" in shp) == (cfg.family in ("ssm", "hybrid"))


def test_param_counts_match_billing():
    """Analytic active-param counts ≈ actual param counts for dense."""
    cfg = get_config("yi-6b")
    model = build_model(cfg)
    sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(sds))
    active = model.active_param_count()
    assert abs(total - active) / total < 0.01
