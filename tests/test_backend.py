"""Backend dispatch + xla/pallas parity matrix.

The pallas backend runs in interpret mode off-TPU (the correctness
contract). Every combination of advance strategy × input kind, every
filter uniquify mode, and segmented intersection must produce *identical*
results on both backends, on both graph fixtures, including empty
frontiers and cap overflow.
"""
import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend as B
from repro.core import frontier as F
from repro.core import graph as G
from repro.core import operators as ops
from repro.core.primitives import bfs, pagerank, sssp, triangle_count

GRAPHS = ["rmat", "grid"]


@pytest.fixture(params=GRAPHS)
def any_graph(request, rmat_graph, grid_graph):
    return {"rmat": rmat_graph, "grid": grid_graph}[request.param]


def _assert_advance_equal(rx, rp):
    for name in ("src", "dst", "edge_id", "in_pos", "valid"):
        a = np.asarray(getattr(rx, name))
        b = np.asarray(getattr(rp, name))
        assert np.array_equal(a, b), name
    assert int(rx.total) == int(rp.total)


# ---------------------------------------------------------------------------
# selection mechanics
# ---------------------------------------------------------------------------


def test_resolve_precedence(monkeypatch):
    monkeypatch.delenv(B.ENV_VAR, raising=False)
    assert B.resolve() == B.XLA                      # default
    monkeypatch.setenv(B.ENV_VAR, "pallas")
    assert B.resolve() == B.PALLAS                   # env var
    with B.use_backend("xla"):
        assert B.resolve() == B.XLA                  # context beats env
        with B.use_backend("pallas"):
            assert B.resolve() == B.PALLAS           # innermost wins
        assert B.resolve(backend="pallas") == B.PALLAS   # per-call beats all
    assert B.resolve() == B.PALLAS


def test_resolve_auto_off_tpu(monkeypatch):
    monkeypatch.delenv(B.ENV_VAR, raising=False)
    want = B.PALLAS if jax.default_backend() == "tpu" else B.XLA
    assert B.resolve("auto") == want
    monkeypatch.setenv(B.ENV_VAR, "auto")
    assert B.resolve() == want


def test_resolve_rejects_unknown():
    with pytest.raises(ValueError):
        B.resolve("cuda")
    with pytest.raises(ValueError):
        with B.use_backend("nope"):
            pass


def test_use_kernel_alias_deprecated():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert B.resolve(use_kernel=True) == B.PALLAS
        assert B.resolve(use_kernel=False) == B.XLA
    assert any(issubclass(x.category, DeprecationWarning) for x in w)


def test_registry_has_both_backends():
    for op in ("advance", "compact", "segment_search",
               "spmv", "spmm", "mxm"):
        assert B.registered(op, B.XLA), op
        assert B.registered(op, B.PALLAS), op
    # ops without a pallas impl fall back to xla instead of raising
    assert B.dispatch("compact", B.PALLAS) is not B.dispatch("compact",
                                                            B.XLA)


def test_env_var_reaches_operators(monkeypatch, rmat_graph):
    monkeypatch.setenv(B.ENV_VAR, "pallas")
    fr = F.from_ids([0, 1], 8)
    res, _ = ops.advance(rmat_graph, fr, 256)
    monkeypatch.setenv(B.ENV_VAR, "xla")
    ref, _ = ops.advance(rmat_graph, fr, 256)
    _assert_advance_equal(ref, res)


# ---------------------------------------------------------------------------
# advance parity: all strategies × input kinds
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ["LB", "TWC", "THREAD"])
@pytest.mark.parametrize("input_kind", ["vertex", "edge"])
def test_advance_parity(any_graph, strategy, input_kind):
    if strategy == "THREAD" and input_kind == "edge":
        pytest.skip("THREAD supports vertex frontiers only")
    g = any_graph
    n, m = g.num_vertices, g.num_edges
    if input_kind == "vertex":
        ids = [0, 1, 5, n // 2, n - 1]
    else:
        ids = [0, 1, m // 3, m - 1]
    fr = F.from_ids(ids, 64)
    rx, _ = ops.advance(g, fr, 4096, input_kind=input_kind,
                        strategy=strategy, backend="xla")
    rp, _ = ops.advance(g, fr, 4096, input_kind=input_kind,
                        strategy=strategy, backend="pallas")
    _assert_advance_equal(rx, rp)
    assert int(rx.total) > 0


@pytest.mark.parametrize("strategy", ["LB", "TWC", "THREAD"])
def test_advance_parity_empty_frontier(any_graph, strategy):
    fr = F.empty(32)
    rx, _ = ops.advance(any_graph, fr, 512, strategy=strategy,
                        backend="xla")
    rp, _ = ops.advance(any_graph, fr, 512, strategy=strategy,
                        backend="pallas")
    _assert_advance_equal(rx, rp)
    assert int(rp.total) == 0
    assert not np.asarray(rp.valid).any()


@pytest.mark.parametrize("strategy", ["LB", "TWC"])
def test_advance_parity_cap_overflow(any_graph, strategy):
    """cap_out smaller than the true expansion: both backends keep the
    same leading slots and report the same (larger) total."""
    g = any_graph
    n = g.num_vertices
    fr = F.from_ids(list(range(0, n, 2))[:48], 64)
    cap = 8          # guaranteed overflow
    rx, _ = ops.advance(g, fr, cap, strategy=strategy, backend="xla")
    rp, _ = ops.advance(g, fr, cap, strategy=strategy, backend="pallas")
    _assert_advance_equal(rx, rp)
    assert int(rx.total) > cap


def test_advance_parity_with_functor(any_graph):
    def functor(s, d, e, rank, valid, data):
        return valid & (d % 2 == 0), data + 1

    rx, dx = ops.advance(any_graph, F.from_ids([0, 3, 7], 16), 1024,
                         functor=functor, data=jnp.int32(0), backend="xla")
    rp, dp = ops.advance(any_graph, F.from_ids([0, 3, 7], 16), 1024,
                         functor=functor, data=jnp.int32(0),
                         backend="pallas")
    _assert_advance_equal(rx, rp)
    assert int(dx) == int(dp) == 1


# ---------------------------------------------------------------------------
# filter parity: all uniquify modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("uniquify", ["none", "exact", "hash"])
def test_filter_parity(any_graph, uniquify):
    n = any_graph.num_vertices
    rng = np.random.default_rng(11)
    ids = rng.integers(0, n, size=200).tolist()
    fr = F.from_ids(ids, 256)
    ox, _ = ops.filter_frontier(fr, n=n, uniquify=uniquify, backend="xla")
    op_, _ = ops.filter_frontier(fr, n=n, uniquify=uniquify,
                                 backend="pallas")
    assert np.array_equal(np.asarray(ox.ids), np.asarray(op_.ids))
    assert int(ox.length) == int(op_.length)


@pytest.mark.parametrize("uniquify", ["none", "exact", "hash"])
def test_filter_parity_empty(uniquify):
    fr = F.empty(64)
    ox, _ = ops.filter_frontier(fr, n=16, uniquify=uniquify, backend="xla")
    op_, _ = ops.filter_frontier(fr, n=16, uniquify=uniquify,
                                 backend="pallas")
    assert int(ox.length) == int(op_.length) == 0
    assert np.array_equal(np.asarray(ox.ids), np.asarray(op_.ids))


def test_filter_parity_cap_overflow():
    fr = F.from_ids(list(range(100)), 128)
    ox, _ = ops.filter_frontier(fr, cap=16, backend="xla")
    op_, _ = ops.filter_frontier(fr, cap=16, backend="pallas")
    assert np.array_equal(np.asarray(ox.ids), np.asarray(op_.ids))
    assert int(ox.length) == int(op_.length) == 16


def test_filter_parity_functor_predicate(any_graph):
    def functor(ids, valid, data):
        return ids % 3 == 0, data

    fr = F.from_ids(list(range(60)), 64)
    ox, _ = ops.filter_frontier(fr, functor=functor, backend="xla")
    op_, _ = ops.filter_frontier(fr, functor=functor, backend="pallas")
    assert np.array_equal(np.asarray(ox.ids), np.asarray(op_.ids))


# ---------------------------------------------------------------------------
# segmented intersection parity
# ---------------------------------------------------------------------------


def test_segmented_intersect_parity(any_graph):
    g = any_graph
    n = g.num_vertices
    rng = np.random.default_rng(5)
    a = rng.integers(0, n, size=32)
    b = rng.integers(0, n, size=32)
    fa, fb = F.from_ids(a, 64), F.from_ids(b, 64)
    rx = ops.segmented_intersect(g, fa, fb, 2048, backend="xla")
    rp = ops.segmented_intersect(g, fa, fb, 2048, backend="pallas")
    assert int(rx.total) == int(rp.total)
    assert int(rx.length) == int(rp.length)
    assert np.array_equal(np.asarray(rx.items), np.asarray(rp.items))
    assert np.array_equal(np.asarray(rx.pair_of), np.asarray(rp.pair_of))
    assert np.array_equal(np.asarray(rx.counts), np.asarray(rp.counts))


def test_segmented_intersect_parity_empty(any_graph):
    fa, fb = F.empty(16), F.empty(16)
    rx = ops.segmented_intersect(any_graph, fa, fb, 128, backend="xla")
    rp = ops.segmented_intersect(any_graph, fa, fb, 128, backend="pallas")
    assert int(rx.total) == int(rp.total) == 0
    assert np.array_equal(np.asarray(rx.items), np.asarray(rp.items))


def test_segmented_intersect_parity_cap_overflow(rmat_graph):
    g = rmat_graph
    deg = np.diff(np.asarray(g.row_offsets))
    hubs = np.argsort(deg)[-16:]          # high-degree pairs → big output
    fa = F.from_ids(hubs[:8], 8)
    fb = F.from_ids(hubs[8:], 8)
    rx = ops.segmented_intersect(g, fa, fb, 4, backend="xla")
    rp = ops.segmented_intersect(g, fa, fb, 4, backend="pallas")
    assert int(rx.total) == int(rp.total)
    assert np.array_equal(np.asarray(rx.items), np.asarray(rp.items))


# ---------------------------------------------------------------------------
# primitive-level parity (the whole enactor loop under REPRO_BACKEND)
# ---------------------------------------------------------------------------


def test_bfs_parity_env(monkeypatch, rmat_graph, high_degree_src):
    monkeypatch.setenv(B.ENV_VAR, "pallas")
    rp = bfs(rmat_graph, high_degree_src)
    monkeypatch.setenv(B.ENV_VAR, "xla")
    rx = bfs(rmat_graph, high_degree_src)
    assert np.array_equal(np.asarray(rx.labels), np.asarray(rp.labels))


def test_sssp_parity(rmat_graph, high_degree_src):
    rx = sssp(rmat_graph, high_degree_src, backend="xla")
    rp = sssp(rmat_graph, high_degree_src, backend="pallas")
    np.testing.assert_allclose(np.asarray(rx.dist), np.asarray(rp.dist))


def test_pagerank_parity_and_jit_clean(rmat_graph):
    rx = pagerank(rmat_graph, backend="xla")
    rp = pagerank(rmat_graph, backend="pallas")
    np.testing.assert_allclose(np.asarray(rx.rank), np.asarray(rp.rank),
                               atol=1e-6)
    # jit-clean: the pallas impl must trace with abstract values only (a
    # hidden device_get would raise a ConcretizationTypeError here)
    from repro.core.primitives.pagerank import _pagerank_impl
    inv_deg = jnp.zeros((rmat_graph.num_vertices,), jnp.float32)
    jax.eval_shape(
        lambda g, iv: _pagerank_impl(g, iv, jnp.float32(0.85),
                                     jnp.float32(0.0),
                                     max_iter=2, backend="pallas",
                                     ell_width=rmat_graph.csc_ell_width),
        rmat_graph, inv_deg)


def test_tc_parity(grid_graph):
    rx = triangle_count(grid_graph, backend="xla")
    rp = triangle_count(grid_graph, backend="pallas")
    assert int(rx.total) == int(rp.total)


def test_graph_ell_width_metadata(rmat_graph):
    assert isinstance(rmat_graph.ell_width, int)
    assert isinstance(rmat_graph.csc_ell_width, int)
    assert 1 <= rmat_graph.ell_width <= 1024
    # metadata survives pytree round trips (jit boundaries)
    leaves, treedef = jax.tree_util.tree_flatten(rmat_graph)
    g2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert g2.ell_width == rmat_graph.ell_width
    assert g2.csc_ell_width == rmat_graph.csc_ell_width


def test_use_kernel_alias_still_routes(rmat_graph):
    fr = F.from_ids([1, 2, 3], 16)
    with pytest.deprecated_call():
        rp, _ = ops.advance(rmat_graph, fr, 1024, use_kernel=True)
    rx, _ = ops.advance(rmat_graph, fr, 1024, backend="xla")
    _assert_advance_equal(rx, rp)


def test_use_kernel_warns_everywhere():
    """The alias warns on every public wrapper, even when backend= is
    also given (backend wins); internal surfaces no longer accept it."""
    with pytest.deprecated_call():
        assert B.resolve(backend="xla", use_kernel=True) == B.XLA
    with pytest.deprecated_call():
        bfs(G.demo_graph(), 0, use_kernel=False)
    g = G.demo_graph()
    gw = G.from_edge_list(*G.edge_list(g), n=g.num_vertices,
                          values=np.ones(g.num_edges, np.float32))
    with pytest.deprecated_call():
        sssp(gw, 0, use_kernel=False)
    with pytest.deprecated_call():
        triangle_count(g, use_kernel=False)
    # dropped from internal call sites: dispatch takes backend only
    import inspect
    assert "use_kernel" not in inspect.signature(B.dispatch).parameters
