"""Batched multi-source engine: frontier / enactor / operators /
primitives parity with the single-source paths, on both backends.

The contract under test: lane i of a batched run is *bit-identical* to
the corresponding single-source run (which itself is a squeezed
batch-of-1 call), ragged convergence freezes finished lanes, duplicate
sources are independent, and the whole batch shares one jitted trace.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import frontier as F
from repro.core import graph as G
from repro.core import operators as ops
from repro.core import ref as R
from repro.core.enactor import run_until_any
from repro.core.primitives import bc, bc_batch, bfs, bfs_batch, sssp, \
    sssp_batch
from repro.core.primitives.bfs import _bfs_impl

BACKENDS = ["xla", "pallas"]


@pytest.fixture(scope="module")
def small_graph():
    # small enough that the pallas interpret-mode legs stay fast
    return G.rmat(7, 8, seed=7, weighted=True)


@pytest.fixture(scope="module")
def tiny_grid():
    return G.grid2d(8, weighted=True, seed=3)


# ---------------------------------------------------------------------------
# enactor
# ---------------------------------------------------------------------------


def test_run_until_any_ragged_freeze():
    """Lanes converge at different steps; finished lanes freeze exactly."""
    targets = jnp.asarray([0, 3, 7, 2], jnp.int32)

    final, lane_iters, iters = run_until_any(
        lambda c: c < targets,
        lambda c: c + 1,
        jnp.zeros((4,), jnp.int32),
        max_iter=100)
    assert np.array_equal(np.asarray(final), [0, 3, 7, 2])
    assert np.array_equal(np.asarray(lane_iters), [0, 3, 7, 2])
    assert int(iters) == 7


def test_run_until_any_max_iter_guard():
    final, lane_iters, iters = run_until_any(
        lambda c: jnp.ones((2,), bool), lambda c: c + 1,
        jnp.zeros((2,), jnp.int32), max_iter=5)
    assert int(iters) == 5
    assert np.array_equal(np.asarray(final), [5, 5])


# ---------------------------------------------------------------------------
# batched frontier
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_frontier_roundtrip(backend):
    n = 40
    bf = F.from_ids_batch([3, 0, 39], 8)
    assert bf.batch == 3 and bf.capacity == 8
    dense = bf.to_dense(n)
    assert np.array_equal(np.asarray(dense.lengths), [1, 1, 1])
    back = dense.to_sparse(8, backend=backend)
    assert np.array_equal(np.asarray(back.ids[:, 0]), [3, 0, 39])
    assert np.array_equal(np.asarray(back.lengths), [1, 1, 1])
    # lane view matches the single-lane class
    lane = bf.lane(0)
    assert isinstance(lane, F.SparseFrontier)
    assert int(lane.length) == 1 and int(lane.ids[0]) == 3


@pytest.mark.parametrize("backend", BACKENDS)
def test_compact_values_batch_overflow_totals(backend):
    """The clamp is reported, not silent: totals carry the true count."""
    vals = jnp.tile(jnp.arange(10, dtype=jnp.int32)[None, :], (2, 1))
    mask = jnp.stack([jnp.arange(10) < 7, jnp.arange(10) < 2])
    buf, lengths, totals = F.compact_values_batch(vals, mask, 4,
                                                  backend=backend)
    assert buf.shape == (2, 4)
    assert np.array_equal(np.asarray(lengths), [4, 2])
    assert np.array_equal(np.asarray(totals), [7, 2])


@pytest.mark.parametrize("backend", BACKENDS)
def test_filter_frontier_batch_overflow_counter(backend):
    fr = F.BatchedSparseFrontier(
        ids=jnp.tile(jnp.arange(6, dtype=jnp.int32)[None, :], (2, 1)),
        lengths=jnp.asarray([6, 1], jnp.int32))
    out, _, overflow = ops.filter_frontier_batch(fr, cap=2,
                                                 backend=backend)
    assert np.array_equal(np.asarray(overflow), [4, 0])
    assert np.array_equal(np.asarray(out.lengths), [2, 1])


# ---------------------------------------------------------------------------
# primitive parity matrix: every lane == the single-source run
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_bfs_batch_parity_matrix(small_graph, backend):
    g = small_graph
    deg = np.diff(np.asarray(g.row_offsets))
    # mixed depths, a hub, a leaf, and a duplicate pair
    srcs = [int(np.argmax(deg)), 0, g.num_vertices - 1, 0]
    rb = bfs_batch(g, srcs, backend=backend)
    for i, s in enumerate(srcs):
        r1 = bfs(g, s, backend=backend)
        assert np.array_equal(np.asarray(rb.labels[i]),
                              np.asarray(r1.labels)), i
        assert np.array_equal(np.asarray(rb.preds[i]),
                              np.asarray(r1.preds)), i
        assert np.array_equal(np.asarray(rb.labels[i]),
                              R.bfs_ref(g, s)), i
    # duplicate sources are independent identical lanes
    assert np.array_equal(np.asarray(rb.labels[1]),
                          np.asarray(rb.labels[3]))
    assert int(rb.iterations[1]) == int(rb.iterations[3])


@pytest.mark.parametrize("backend", BACKENDS)
def test_sssp_batch_parity_matrix(small_graph, backend):
    g = small_graph
    deg = np.diff(np.asarray(g.row_offsets))
    srcs = [int(np.argmax(deg)), 0, g.num_vertices - 1, 0]
    rb = sssp_batch(g, srcs, backend=backend)
    for i, s in enumerate(srcs):
        r1 = sssp(g, s, backend=backend)
        assert np.array_equal(np.asarray(rb.dist[i]),
                              np.asarray(r1.dist)), i
        assert np.allclose(np.asarray(rb.dist[i]), R.sssp_ref(g, s),
                           rtol=1e-5), i
    assert np.array_equal(np.asarray(rb.dist[1]), np.asarray(rb.dist[3]))


def test_bfs_batch_ragged_convergence(tiny_grid):
    """Sources at the corner and the center finish at different depths;
    the shallow lane freezes while the deep one continues."""
    g = tiny_grid
    side = 8
    corner, center = 0, side * (side // 2) + side // 2
    rb = bfs_batch(g, [center, corner], direction=False)
    assert int(rb.iterations[0]) < int(rb.iterations[1])
    for i, s in enumerate([center, corner]):
        assert np.array_equal(np.asarray(rb.labels[i]), R.bfs_ref(g, s))


def test_sssp_batch_ragged_convergence(tiny_grid):
    g = tiny_grid
    rb = sssp_batch(g, [0, 27])
    for i, s in enumerate([0, 27]):
        assert np.allclose(np.asarray(rb.dist[i]), R.sssp_ref(g, s),
                           rtol=1e-5)


def test_batch_of_one_squeeze_roundtrip(small_graph):
    """bfs() is literally a squeezed batch-of-1 bfs_batch() call."""
    g = small_graph
    r1 = bfs(g, 5)
    rb = bfs_batch(g, [5])
    assert r1.labels.ndim == 1 and rb.labels.ndim == 2
    for name in r1._fields:
        assert np.array_equal(np.asarray(getattr(r1, name)),
                              np.asarray(getattr(rb, name)[0])), name
    s1 = sssp(g, 5)
    sb = sssp_batch(g, [5])
    for name in s1._fields:
        assert np.array_equal(np.asarray(getattr(s1, name)),
                              np.asarray(getattr(sb, name)[0])), name


def test_bfs_batch_single_trace(small_graph):
    """32 sources run as ONE jitted program, and a second batch of the
    same shape reuses it (no per-source or per-batch retrace)."""
    g = small_graph
    rng = np.random.default_rng(0)
    before = _bfs_impl._cache_size()
    rb = bfs_batch(g, rng.integers(0, g.num_vertices, 32))
    after_first = _bfs_impl._cache_size()
    assert after_first == before + 1
    bfs_batch(g, rng.integers(0, g.num_vertices, 32))
    assert _bfs_impl._cache_size() == after_first
    assert rb.labels.shape == (32, g.num_vertices)


def test_bfs_batch_overflow_counter_clean(small_graph):
    """Exact-uniquify runs can never overflow the min(n, m) vertex
    frontier; the counter must stay zero."""
    rb = bfs_batch(small_graph, [0, 1, 2], idempotence=False)
    assert np.array_equal(np.asarray(rb.overflow), [0, 0, 0])


# ---------------------------------------------------------------------------
# betweenness centrality: exact + sampled multi-source
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bc_graph():
    return G.rmat(6, 6, seed=1)


def test_bc_single_source_unchanged(bc_graph):
    deg = np.diff(np.asarray(bc_graph.row_offsets))
    s = int(np.argmax(deg))
    r = bc(bc_graph, s)
    assert np.allclose(np.asarray(r.bc), R.bc_ref(bc_graph, s),
                       rtol=1e-3, atol=1e-3)


def test_bc_batch_lanes_match_single(bc_graph):
    srcs = [0, 5, 9]
    rb = bc_batch(bc_graph, srcs)
    for i, s in enumerate(srcs):
        assert np.allclose(np.asarray(rb.bc[i]), R.bc_ref(bc_graph, s),
                           rtol=1e-3, atol=1e-3), i


def test_bc_exact_matches_oracle_sum(bc_graph):
    """bc(graph) with no src == sum of per-source Brandes passes
    (the exact-BC acceptance contract), across a chunk size that does
    not divide n (exercises the padded final chunk)."""
    n = bc_graph.num_vertices
    ref = sum(R.bc_ref(bc_graph, s).astype(np.float64) for s in range(n))
    r = bc(bc_graph, chunk=24)
    assert r.chunks == -(-n // 24)
    assert int(r.num_sources) == n
    assert np.allclose(np.asarray(r.bc), ref, rtol=1e-3, atol=1e-3)


def test_bc_exact_matches_networkx(bc_graph):
    nx = pytest.importorskip("networkx")
    src_e, dst_e = G.edge_list(bc_graph)
    dg = nx.DiGraph()
    dg.add_nodes_from(range(bc_graph.num_vertices))
    dg.add_edges_from(zip(src_e.tolist(), dst_e.tolist()))
    ref = nx.betweenness_centrality(dg, normalized=False)
    ref = np.array([ref[v] for v in range(bc_graph.num_vertices)])
    r = bc(bc_graph, chunk=32)
    assert np.allclose(np.asarray(r.bc), ref, rtol=1e-3, atol=1e-3)


def test_bc_sampled_all_roots_equals_exact(bc_graph):
    n = bc_graph.num_vertices
    exact = bc(bc_graph, chunk=32)
    sampled = bc(bc_graph, samples=n, seed=0, chunk=32)
    assert np.allclose(np.asarray(sampled.bc), np.asarray(exact.bc),
                       rtol=1e-4, atol=1e-4)


def test_bc_sampled_subset_is_scaled_estimate(bc_graph):
    n = bc_graph.num_vertices
    r = bc(bc_graph, samples=16, seed=3, chunk=8)
    assert int(r.num_sources) == 16
    exact = bc(bc_graph, chunk=32)
    # unbiased estimator: same total mass scale (loose sanity bound)
    tot_e = float(np.asarray(exact.bc).sum())
    tot_s = float(np.asarray(r.bc).sum())
    assert 0.3 * tot_e < tot_s < 3.0 * tot_e
