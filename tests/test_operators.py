"""Operator semantics: advance / filter / segmented intersect /
neighborhood reduce — unit + property tests vs. brute force."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, st

from repro.core import frontier as F
from repro.core import graph as G
from repro.core import operators as ops


def brute_advance(g, ids):
    ro = np.asarray(g.row_offsets)
    ci = np.asarray(g.col_indices)
    out = []
    for i in ids:
        out.extend(ci[ro[i]:ro[i + 1]].tolist())
    return out


@pytest.mark.parametrize("strategy", ["LB", "TWC", "THREAD"])
def test_advance_matches_bruteforce(strategy):
    g = G.rmat(7, 6, seed=5)
    ids = [0, 3, 9, 77, 101]
    fr = F.from_ids(ids, 128)
    res, _ = ops.advance(g, fr, 4096, strategy=strategy)
    got = np.asarray(res.dst)[np.asarray(res.valid)]
    # THREAD/TWC may produce a different (but stable) order; compare
    # multisets of produced destinations
    assert sorted(got.tolist()) == sorted(brute_advance(g, ids))


def test_advance_kernel_path():
    g = G.rmat(7, 6, seed=5)
    fr = F.from_ids([1, 2, 3], 16)
    res, _ = ops.advance(g, fr, 1024, use_kernel=True)
    got = np.asarray(res.dst)[np.asarray(res.valid)]
    assert sorted(got.tolist()) == sorted(brute_advance(g, [1, 2, 3]))


def test_advance_edge_input_kind():
    g = G.demo_graph()
    # edge 0 points 0->1; expanding it visits N(1) = {2, 4}
    fr = F.from_ids([0], 8)
    res, _ = ops.advance(g, fr, 64, input_kind="edge")
    got = sorted(np.asarray(res.dst)[np.asarray(res.valid)].tolist())
    assert got == [2, 4]


def test_advance_functor_filtering():
    g = G.demo_graph()
    fr = F.from_ids([0], 8)

    def functor(src, dst, eid, rank, valid, data):
        return valid & (dst >= 2), data

    res, _ = ops.advance(g, fr, 64, functor=functor)
    got = sorted(np.asarray(res.dst)[np.asarray(res.valid)].tolist())
    assert got == [2, 3]


def test_advance_pull_equals_push():
    g = G.rmat(7, 6, seed=6)
    n = g.num_vertices
    cur = np.zeros(n, bool)
    cur[[3, 5, 8]] = True
    visited = cur.copy()
    pull = ops.advance_pull(g, F.DenseFrontier(jnp.asarray(~visited)),
                            F.DenseFrontier(jnp.asarray(cur)))
    push = set(brute_advance(g, [3, 5, 8])) - {3, 5, 8}
    got = set(np.nonzero(np.asarray(pull.flags))[0].tolist())
    assert got == push


def test_filter_exact_unique():
    fr = F.from_ids([5, 3, 5, 5, 2, 3, 9], 16)
    out, _ = ops.filter_frontier(fr, n=10, uniquify="exact")
    ids = np.asarray(out.ids)[:int(out.length)]
    assert sorted(ids.tolist()) == [2, 3, 5, 9]


@given(st.lists(st.integers(0, 30), min_size=0, max_size=50))
def test_filter_hash_never_drops_uniques(ids):
    fr = F.from_ids(ids, 64)
    out, _ = ops.filter_frontier(fr, n=32, uniquify="hash", hash_size=8)
    kept = np.asarray(out.ids)[:int(out.length)].tolist()
    # heuristic culling may leave duplicates but must keep >= 1 copy of
    # every distinct id and never invent ids
    assert set(kept) == set(ids)


def test_filter_functor_predicate():
    fr = F.from_ids(list(range(10)), 16)

    def functor(ids, valid, data):
        return (ids % 2 == 0), data

    out, _ = ops.filter_frontier(fr, functor=functor)
    assert np.asarray(out.ids)[:int(out.length)].tolist() == [0, 2, 4, 6, 8]


def test_partition_frontier_near_far():
    fr = F.from_ids([1, 2, 3, 4, 5], 8)
    near, far = ops.partition_frontier(fr, jnp.asarray(
        [True, False, True, False, True, False, False, False]))
    assert np.asarray(near.ids)[:int(near.length)].tolist() == [1, 3, 5]
    assert np.asarray(far.ids)[:int(far.length)].tolist() == [2, 4]


def test_neighborhood_reduce_degrees():
    g = G.demo_graph()
    fr = F.from_ids([0, 2, 6], 4)
    out = ops.neighborhood_reduce(
        g, fr, 64, edge_map=lambda s, d, e, v, data: jnp.ones_like(
            s, jnp.float32), reduce_op="add")
    deg = np.diff(np.asarray(g.row_offsets))
    assert np.asarray(out)[:3].tolist() == [deg[0], deg[2], deg[6]]


def test_segmented_intersect_counts():
    g = G.demo_graph()
    # N(0)={1,2,3}, N(2)={3,5} -> intersection {3}
    fa = F.from_ids([0], 4)
    fb = F.from_ids([2], 4)
    res = ops.segmented_intersect(g, fa, fb, 32)
    assert int(res.total) == 1
    assert np.asarray(res.items)[0] == 3


@given(st.integers(0, 6), st.integers(0, 6))
def test_segmented_intersect_vs_numpy(u, v):
    g = G.demo_graph()
    ro = np.asarray(g.row_offsets)
    ci = np.asarray(g.col_indices)
    expect = set(ci[ro[u]:ro[u + 1]]) & set(ci[ro[v]:ro[v + 1]])
    res = ops.segmented_intersect(g, F.from_ids([u], 2),
                                  F.from_ids([v], 2), 32)
    assert int(res.total) == len(expect)


def test_compact_values_property():
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.integers(0, 100, 40), jnp.int32)
    mask = jnp.asarray(rng.random(40) < 0.4)
    buf, length = F.compact_values(vals, mask, 40)
    expect = np.asarray(vals)[np.asarray(mask)]
    assert np.array_equal(np.asarray(buf)[:int(length)], expect)


def test_scatter_helpers():
    tgt = jnp.full((5,), 10.0)
    out = ops.scatter_min(jnp.asarray([3.0, 7.0, 1.0]),
                          jnp.asarray([1, 1, 4]),
                          jnp.asarray([True, True, True]), tgt)
    assert np.asarray(out).tolist() == [10., 3., 10., 10., 1.]
    out = ops.scatter_add(jnp.asarray([2.0, 5.0]), jnp.asarray([0, 0]),
                          jnp.asarray([True, False]),
                          jnp.zeros((2,)))
    assert np.asarray(out).tolist() == [2.0, 0.0]
