"""Paper Table 7 analogue: scalability of the primitives on
synthetically-grown Kronecker graphs of similar structure (runtime +
MTEPS vs size; the paper observes near-linear BFS scaling and atomic-
contention sublinearity for BC/SSSP/PR)."""
from __future__ import annotations

import numpy as np

from repro.core import graph as G
from repro.core.primitives import bc, bfs, connected_components, pagerank, \
    sssp

from .common import best_source, emit, timed


def run():
    rows = []
    for scale in (10, 11, 12, 13):
        g = G.rmat(scale, 8, seed=scale, weighted=True)
        src = best_source(g)
        m = g.num_edges
        for pname, fn, edges in [
            ("bfs", lambda: bfs(g, src), None),
            ("sssp", lambda: sssp(g, src), None),
            ("bc", lambda: bc(g, src), 2 * m),
            ("pagerank", lambda: pagerank(g, max_iter=10), 10 * m),
            ("cc", lambda: connected_components(g), None),
        ]:
            r, t = timed(fn)
            ev = edges
            if pname == "bfs":
                ev = int(r.edges_visited)
            mteps = round(ev / t / 1e6, 1) if ev else ""
            rows.append([f"kron_s{scale}", g.num_vertices, m, pname,
                         round(t * 1e3, 2), mteps])
    return emit(rows, ["dataset", "n", "m", "primitive", "ms", "mteps"],
                table="table7_scaling")
