"""Paper Tables 9/10 + Fig. 24 analogue: Who-To-Follow pipeline runtimes
(PPR / CoT+SALSA split) and scalability over growing follow graphs."""
from __future__ import annotations

import numpy as np

from repro.core import graph as G
from repro.core.primitives import who_to_follow
from repro.core.primitives.wtf import _wtf_impl

from .common import emit, timed


def run():
    rows = []
    for scale, avg_deg in [(10, 8), (12, 8), (13, 16), (14, 16)]:
        n_users = 1 << scale
        g = G.bipartite_random(n_users, n_users // 2, avg_deg, seed=scale)
        deg = np.diff(np.asarray(g.row_offsets))
        user = int(np.argmax(deg))
        r, t = timed(lambda: who_to_follow(g, user, k=min(
            1000, g.num_vertices - 1), ppr_iters=20, salsa_iters=8))
        rows.append([f"follow_s{scale}", g.num_vertices, g.num_edges,
                     round(t * 1e3, 2),
                     int(np.sum(np.asarray(r.auth_scores) > 0))])
    return emit(rows, ["dataset", "n", "m", "total_ms",
                       "nonzero_recommendations"],
                table="table10_wtf")
