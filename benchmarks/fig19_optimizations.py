"""Paper Fig. 19 analogue: BFS under the 4 combinations of idempotence ×
direction-optimized traversal. Paper claims reproduced (relative):
DO speeds up scale-free graphs and not meshes; idempotence on very
uniform-degree graphs can hurt (extra filter pass ≥ atomic savings)."""
from __future__ import annotations

import numpy as np

from repro.core.primitives import bfs

from .common import DATASETS, best_source, dataset, emit, timed


def run():
    rows = []
    for name in DATASETS:
        g = dataset(name)
        src = best_source(g)
        for direction in (False, True):
            for idem in (False, True):
                r, t = timed(lambda: bfs(g, src, direction=direction,
                                         idempotence=idem))
                rows.append([name, int(direction), int(idem),
                             round(t * 1e3, 2),
                             round(int(r.edges_visited) / t / 1e6, 1),
                             int(r.pull_iters)])
    return emit(rows, ["dataset", "direction_opt", "idempotence", "ms",
                       "mteps", "pull_iters"],
                table="fig19_optimizations")
