"""Paper Fig. 25 analogue: TC variants — filtered (forward algorithm,
induced-DAG intersections) vs full (both directions, ÷6) vs the numpy
baseline. Paper claim reproduced: filtering removes ~5/6 of intersection
work and wins on scale-free graphs."""
from __future__ import annotations

import numpy as np

from repro.core import ref as R
from repro.core.primitives import triangle_count
from repro.core.primitives.tc import triangle_count_full

from .common import DATASETS, dataset, emit, timed


def run():
    rows = []
    for name in DATASETS:
        g = dataset(name)
        ref, t_cpu = timed(lambda: R.tc_ref(g))
        r, t_f = timed(lambda: triangle_count(g))
        rf, t_u = timed(lambda: triangle_count_full(g))
        rows.append([name, ref, int(r.total), int(rf),
                     round(t_cpu * 1e3, 1), round(t_f * 1e3, 2),
                     round(t_u * 1e3, 2),
                     round(t_u / max(t_f, 1e-9), 2)])
    return emit(rows, ["dataset", "triangles", "tc_filtered", "tc_full",
                       "cpu_baseline_ms", "filtered_ms", "full_ms",
                       "full/filtered"],
                table="fig25_tc")
