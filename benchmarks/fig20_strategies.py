"""Paper Fig. 20 analogue: workload-mapping strategy ablation
(LB vs TWC vs THREAD static mapping) on BFS and SSSP.

Paper claim reproduced (relative): LB wins on scale-free/power-law
degree graphs; the static mapping is competitive only on uniform-degree
meshes (where its zero balancing overhead pays)."""
from __future__ import annotations

from repro.core.primitives import bfs, sssp

from .common import DATASETS, best_source, dataset, emit, timed


def run():
    rows = []
    for name in DATASETS:
        g = dataset(name)
        src = best_source(g)
        for strategy in ("LB", "TWC", "THREAD"):
            r, t = timed(lambda: bfs(g, src, direction=False,
                                     idempotence=False,
                                     strategy=strategy))
            rows.append([name, "bfs", strategy, round(t * 1e3, 2)])
            r, t = timed(lambda: sssp(g, src, strategy=strategy))
            rows.append([name, "sssp", strategy, round(t * 1e3, 2)])
    return emit(rows, ["dataset", "primitive", "strategy", "ms"],
                table="fig20_strategies")
