"""Bench-regression gate: diff a fresh run against a committed trajectory.

    python benchmarks/compare.py FRESH.json [MORE.json ...] \
        --baseline BENCH_pr8.json --threshold 0.25

Rows are matched into cells by their identity keys — everything that
names the workload (bench, primitive, tiered, backend, n, m, parts,
placement, ...) and nothing that measures it (ms, mteps, speedups) or
stamps it (provenance). A cell present in both files whose fresh ``ms``
exceeds baseline by more than ``--threshold`` (fractional, default 25%)
fails the gate (exit 1). Cells only on one side are reported and
ignored — CI quick runs at a different scale simply share no cells with
a full-scale trajectory instead of producing nonsense ratios.

Cross-platform guard: rows stamped with provenance (``platform``,
``device_kind``) only compare against rows from the same platform —
a CPU-interpret CI runner can never "regress" a GPU trajectory.

Exit codes: 0 = no regression (including the no-shared-cells case,
which warns), 1 = regression past threshold, 2 = usage/load error.
"""
from __future__ import annotations

import argparse
import json
import sys

# measurement / stamp keys never used for cell identity
MEASURE_KEYS = frozenset({
    "ms", "mteps", "ms_tiered", "ms_pinned", "qps", "total_s",
    "baseline_pr4_ms", "speedup_vs_pr4", "occupancy", "workload",
    "tier", "comm_bytes_per_step", "edge_imbalance",
    "jax_version", "git_sha", "force_interpret_env", "samples",
})
PLATFORM_KEYS = ("platform", "device_kind", "interpret")


def load_rows(path: str) -> list:
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, dict):           # pr6-style payload or {"results"}
        data = data.get("rows") or data.get("results") or []
    return [r for r in data if isinstance(r, dict)]


def cell_key(row: dict):
    ident = {k: v for k, v in sorted(row.items())
             if k not in MEASURE_KEYS and k not in PLATFORM_KEYS
             and not isinstance(v, (dict, list))}
    return tuple(ident.items())


def platform_of(row: dict):
    return tuple(row.get(k) for k in PLATFORM_KEYS)


def index(rows: list) -> dict:
    out = {}
    for r in rows:
        if "ms" not in r:                # occupancy/storage rows: no gate
            continue
        # keep the best (min) ms per cell — reruns in one file collapse
        # to the strongest number, matching the benches' min-of-reps
        key = (cell_key(r), platform_of(r))
        if key not in out or r["ms"] < out[key]["ms"]:
            out[key] = r
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail CI when a fresh bench run regresses the "
                    "committed trajectory")
    ap.add_argument("fresh", nargs="+", help="fresh bench JSON file(s)")
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_pr*.json to compare against")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated fractional slowdown (default "
                         "0.25 = 25%%)")
    args = ap.parse_args(argv)

    try:
        base = index(load_rows(args.baseline))
        fresh_rows = []
        for p in args.fresh:
            fresh_rows.extend(load_rows(p))
        fresh = index(fresh_rows)
    except (OSError, json.JSONDecodeError) as e:
        print(f"[compare] cannot load input: {e}", file=sys.stderr)
        return 2

    shared = sorted(set(base) & set(fresh),
                    key=lambda k: str(k))
    only_base = len(set(base) - set(fresh))
    only_fresh = len(set(fresh) - set(base))
    if only_base or only_fresh:
        print(f"[compare] unshared cells ignored: {only_base} "
              f"baseline-only, {only_fresh} fresh-only")
    if not shared:
        print("[compare] WARNING: no shared (workload, backend, "
              "platform) cells between fresh run and baseline — "
              "nothing gated")
        return 0

    regressions = 0
    for key in shared:
        b, f = base[key]["ms"], fresh[key]["ms"]
        ratio = f / b if b > 0 else float("inf")
        ident = dict(key[0])
        name = (f"{ident.get('bench', '?')}/{ident.get('primitive', '?')}"
                f" backend={ident.get('backend', '?')}"
                f" tiered={ident.get('tiered', '-')}"
                f" n={ident.get('n', '?')}")
        flag = ""
        if ratio > 1.0 + args.threshold:
            flag = f"  REGRESSION (> +{args.threshold:.0%})"
            regressions += 1
        print(f"[compare] {name}: {b:.2f} ms -> {f:.2f} ms "
              f"({ratio - 1.0:+.1%}){flag}")
    if regressions:
        print(f"[compare] FAIL: {regressions}/{len(shared)} cells "
              f"regressed past +{args.threshold:.0%}", file=sys.stderr)
        return 1
    print(f"[compare] OK: {len(shared)} shared cells within "
          f"+{args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
