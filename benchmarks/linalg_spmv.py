"""Semiring-algebra benchmark — the PR 3 headline measurement.

Times the masked/unmasked semiring SpMV, the linalg-routed PageRank and
the masked-SpGEMM triangle count on both backends and writes
BENCH_pr3.json next to the PR 1/PR 2 numbers. Comparisons to read from
the rows:

  * pagerank rows vs the pagerank rows of BENCH_pr1.json — the PR 1
    numbers went through the standalone ``csr_spmv`` path, these go
    through the ``"spmv"`` registry op (acceptance: pallas no slower);
  * spmv vs spmv_masked — the mask is free on the xla path (a where)
    and on the pallas path (same tiles, identity writes);
  * tc rows vs the tc rows of BENCH_pr1.json (same masked-intersection
    workload, now expressed as ``C⟨G'⟩ = G' ⊗ G'ᵀ``).

The xla rows use the PR 1 rmat scale-14 graph; the pallas TC row uses a
smaller graph because interpret mode executes the kernel grid on the
host (the PR 2 precedent, documented in the row) — pallas pagerank/spmv
stay at scale 14 so the PR 1 comparison is direct.

  PYTHONPATH=src python -m benchmarks.linalg_spmv --json BENCH_pr3.json
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import linalg
from repro.core import graph as G
from repro.core.primitives import pagerank, triangle_count

REPEATS = 3


def _time_ms(fn, repeats: int = REPEATS) -> float:
    jax.block_until_ready(fn())          # pay the trace outside the clock
    best = float("inf")
    for _ in range(repeats):
        t0 = time.monotonic()
        jax.block_until_ready(fn())
        best = min(best, (time.monotonic() - t0) * 1e3)
    return round(best, 2)


def bench_backend(backend: str, scale: int, tc_scale: int,
                  edge_factor: int = 16, seed: int = 0):
    g = G.rmat(scale, edge_factor, seed=seed, weighted=True)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random(g.num_vertices), jnp.float32)
    mask = jnp.asarray(rng.random(g.num_vertices) < 0.5)
    rows = []

    spmv_j = jax.jit(lambda v: linalg.spmv(g, v, structural=True,
                                           backend=backend))
    rows.append({"op": "spmv", "backend": backend, "scale": scale,
                 "ms": _time_ms(lambda: spmv_j(x))})
    spmv_m = jax.jit(lambda v: linalg.spmv(g, v, mask=mask,
                                           structural=True,
                                           backend=backend))
    rows.append({"op": "spmv_masked", "backend": backend, "scale": scale,
                 "ms": _time_ms(lambda: spmv_m(x))})
    rows.append({"op": "pagerank", "backend": backend, "scale": scale,
                 "ms": _time_ms(
                     lambda: pagerank(g, max_iter=20,
                                      backend=backend).rank),
                 "note": "compare the pagerank rows of BENCH_pr1.json "
                         "(PR 1 csr_spmv path)"})
    for row in rows:
        print(f"[linalg_spmv] {row['op']:12s} backend={backend} "
              f"scale={row['scale']}: {row['ms']} ms")

    gt = g if tc_scale == scale else G.rmat(tc_scale, edge_factor,
                                            seed=seed, weighted=True)
    tc_row = {"op": "tc", "backend": backend, "scale": tc_scale,
              "ms": _time_ms(
                  lambda: triangle_count(gt, backend=backend).total,
                  repeats=1)}
    if tc_scale != scale:
        tc_row["note"] = ("smaller graph: interpret mode runs the "
                          "kernel grid on the host (PR 2 precedent)")
    rows.append(tc_row)
    print(f"[linalg_spmv] {'tc':12s} backend={backend} "
          f"scale={tc_scale}: {tc_row['ms']} ms")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_pr3.json")
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--pallas-tc-scale", type=int, default=10)
    args = ap.parse_args(argv)
    out = {
        "pr": 3,
        "note": "semiring algebra layer: masked SpMV + linalg-routed "
                "pagerank/tc; compare pagerank and tc rows against "
                "BENCH_pr1.json (csr_spmv / segmented-intersect paths)",
        "repeats": REPEATS,
        "jax_backend": jax.default_backend(),
        "interpret_pallas": jax.default_backend() != "tpu",
        "platform": platform.platform(),
        "results": (bench_backend("xla", args.scale, args.scale)
                    + bench_backend("pallas", args.scale,
                                    args.pallas_tc_scale)),
    }
    with open(args.json, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[linalg_spmv] wrote {args.json}")


def run():
    main([])


if __name__ == "__main__":
    main()
