"""Paper Fig. 21 analogue: direction-optimizing parameter sweep — BFS
TEPS as a function of (do_a, do_b) on a scale-free and a mesh graph.
Reproduces the paper's observation that no single (do_a, do_b) is optimal
for all datasets and that a rectangular high-performance region exists."""
from __future__ import annotations

import numpy as np

from repro.core.primitives import bfs

from .common import best_source, dataset, emit, timed

DO_VALUES = [0.0001, 0.001, 0.01, 0.1, 1.0, 10.0]


def run():
    rows = []
    for name in ("rmat_s12_e16", "grid_90"):
        g = dataset(name)
        src = best_source(g)
        for do_a in DO_VALUES:
            for do_b in DO_VALUES:
                r, t = timed(lambda: bfs(g, src, direction=True,
                                         do_a=do_a, do_b=do_b),
                             repeats=1)
                ok = int(np.all(np.asarray(r.labels)[
                    np.asarray(r.labels) >= 0] >= 0))
                rows.append([name, do_a, do_b, round(t * 1e3, 2),
                             round(int(r.edges_visited) / t / 1e6, 1),
                             int(r.pull_iters), ok])
    return emit(rows, ["dataset", "do_a", "do_b", "ms", "mteps",
                       "pull_iters", "ok"],
                table="fig21_doab")
