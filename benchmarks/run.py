"""Benchmark harness — one module per paper table/figure (§7):

  table6_primitives   runtime + MTEPS per primitive × dataset (Table 6)
  table7_scaling      size scaling on Kronecker graphs (Table 7)
  table8_utilization  load-balance quality / lane utilization (Table 8)
  fig19_optimizations idempotence × direction-optimization (Fig. 19)
  fig20_strategies    LB / TWC / THREAD workload mappings (Fig. 20)
  fig21_doab          do_a/do_b direction-parameter sweep (Fig. 21)
  fig25_tc            TC filtered vs full vs CPU baseline (Fig. 25)
  table10_wtf         Who-To-Follow pipeline + scaling (Tables 9-11)
  roofline            LM dry-run roofline tables (deliverable g)
  frontier_scaling    tiered/fused traversal vs pinned worst-case +
                      frontier-occupancy sweep (PR 5; → BENCH_pr5.json)
  bandwidth           storage-plan grid {int64,int32,delta}×{fp32,bf16}:
                      ms + bytes-per-edge + parity (PR 6; →
                      BENCH_pr6.json)

Run all:  PYTHONPATH=src python -m benchmarks.run
One:      PYTHONPATH=src python -m benchmarks.run --only fig25_tc
Backend:  PYTHONPATH=src python -m benchmarks.run --backend pallas \
              --json bench_pallas.json
"""
from __future__ import annotations

import argparse
import importlib
import os
import time
import traceback

MODULES = [
    "table6_primitives",
    "table7_scaling",
    "table8_utilization",
    "fig19_optimizations",
    "fig20_strategies",
    "fig21_doab",
    "fig25_tc",
    "table10_wtf",
    "roofline",
    "frontier_scaling",
    "bandwidth",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--backend", default=None,
                    choices=("xla", "pallas", "auto"),
                    help="operator backend for every module (emitted as a "
                         "column in the CSV/JSON output)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write all emitted rows (backend column included) "
                         "as JSON")
    args = ap.parse_args()
    if args.backend:
        os.environ["REPRO_BACKEND"] = args.backend
    mods = [args.only] if args.only else MODULES
    failures = []
    for name in mods:
        print(f"\n===== {name} =====", flush=True)
        # reprolint: disable=RL004 -- progress wall-clock around a whole module run
        t0 = time.monotonic()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.run()
            print(f"# {name} done in {time.monotonic()-t0:.1f}s",
                  flush=True)
        except Exception:
            traceback.print_exc()
            failures.append(name)
    # resident-bytes accounting for every dataset the run touched (plus
    # the zoo defaults when run standalone) — the storage side of every
    # ms number above
    print("\n===== storage =====", flush=True)
    try:
        from benchmarks.common import _CACHE, dataset, emit_storage
        if not _CACHE:
            dataset("rmat_s12_e16")
        emit_storage(dict(_CACHE))
    except Exception:
        traceback.print_exc()
        failures.append("storage")
    if args.json:
        from benchmarks.common import write_json
        write_json(args.json)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
