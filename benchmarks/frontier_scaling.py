"""Frontier-proportional performance benchmark → BENCH_pr5.json.

Two measurements:

  1. Primitive wall clock at scale 12 (same graph/methodology as
     benchmarks/distributed_scale.py, whose parts=1 rows are the
     BENCH_pr4 single-device baselines): bfs / sssp / pagerank through
     the tiered+fused engine, plus the pinned top tier (tiered=False)
     as the A/B control. The acceptance bar is ≥2× on bfs and pagerank
     versus the BENCH_pr4 numbers.

  2. Frontier-occupancy sweep: one fused advance_filter dispatch at
     frontier sizes sweeping 2⁰ … n, tiered vs pinned. Sub-capacity
     frontiers must cost sub-linearly in the tiered engine (the
     Gunrock property: work ∝ frontier, not graph) while the pinned
     path stays ~flat at worst-case cost.

Usage:
    python benchmarks/frontier_scaling.py --scale 12 --json BENCH_pr5.json
    python benchmarks/frontier_scaling.py --quick       # CI smoke
    REPRO_TUNE=1 python benchmarks/frontier_scaling.py --tune   # retune
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402
import numpy as np                                           # noqa: E402

from repro.core import backend as B                          # noqa: E402
from repro.core import frontier as F                         # noqa: E402
from repro.core import graph as G                            # noqa: E402
from repro.core import operators as ops                      # noqa: E402
from repro.core.primitives import bfs_batch, pagerank, \
    sssp_batch                                               # noqa: E402

ROWS = []


def timeit(fn, reps=5):
    fn()                                    # warmup (pays the trace)
    best = float("inf")
    for _ in range(reps):
        t0 = time.monotonic()
        jax.block_until_ready(fn())
        best = min(best, time.monotonic() - t0)
    return best * 1e3


def emit(row):
    ROWS.append(row)
    keys = " ".join(f"{k}={v}" for k, v in row.items()
                    if k not in ("bench",))
    print(f"[bench] {keys}")


def bench_primitives(g, src, backend, reps, baselines):
    edges = int(g.num_edges)

    def run(name, fn, tiered):
        ms = timeit(fn, reps)
        mteps = edges / ms / 1e3
        row = {"bench": "frontier_scaling", "primitive": name,
               "tiered": tiered, "backend": backend,
               "ms": round(ms, 2), "mteps": round(mteps, 2),
               "n": g.num_vertices, "m": edges}
        base = baselines.get(name)
        if base and tiered:
            row["baseline_pr4_ms"] = base
            row["speedup_vs_pr4"] = round(base / ms, 2)
        emit(row)

    for tiered in (True, False):
        run("bfs", lambda t=tiered: bfs_batch(
            g, [src], backend=backend, tiered=t).labels, tiered)
        run("sssp", lambda t=tiered: sssp_batch(
            g, [src], backend=backend, tiered=t).dist, tiered)
    # pagerank's sweep is dense (pinned top tier by design): one flavour
    run("pagerank", lambda: pagerank(
        g, max_iter=20, backend=backend).rank, True)


def bench_occupancy(g, backend, reps):
    """One fused push step at controlled frontier occupancy: cost must
    track the live frontier (tiered) vs stay worst-case flat (pinned)."""
    n, m = g.num_vertices, g.num_edges
    cap_v = min(n, m)
    caps = B.tier_plan("advance_filter", m)
    rng = np.random.default_rng(0)
    visited = jnp.zeros((n,), bool)

    def step(ids, cap_t):
        fr = F.from_ids(ids, cap_v)
        return ops.advance_filter(g, fr, visited, cap_t, cap_v,
                                  backend=backend)[0].ids

    size = 4
    while size <= n:
        ids = rng.choice(n, size=size, replace=False)
        fr = F.from_ids(ids, cap_v)
        need = int(ops.frontier_workload(g, fr))
        tier = caps[int(F.tier_index(jnp.int32(need), caps))]
        jit_t = jax.jit(lambda i: step(i, tier))
        jit_p = jax.jit(lambda i: step(i, caps[-1]))
        idsj = jnp.asarray(ids, jnp.int32)
        ms_t = timeit(lambda: jit_t(idsj), reps)
        ms_p = timeit(lambda: jit_p(idsj), reps)
        emit({"bench": "frontier_occupancy", "backend": backend,
              "frontier": size, "workload": need, "tier": int(tier),
              "ms_tiered": round(ms_t, 3), "ms_pinned": round(ms_p, 3),
              "occupancy": round(need / max(m, 1), 4)})
        size *= 8


def run():
    """benchmarks.run entry point (ambient REPRO_BACKEND honored); rows
    also land in benchmarks.common.RESULTS for the aggregate --json."""
    main(["--scale", "10", "--reps", "3",
          "--json", os.environ.get("FRONTIER_SCALING_JSON", "")])
    from benchmarks.common import RESULTS
    RESULTS.extend({"table": r.pop("bench"), **r} for r in list(ROWS))
    ROWS.clear()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edge-factor", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default=None,
                    choices=("xla", "pallas", "auto"))
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--json", default="BENCH_pr5.json")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: scale 9, 1 rep, skip the sweep tail")
    ap.add_argument("--tune", action="store_true",
                    help="autotune kernel tiles first (REPRO_TUNE=1)")
    ap.add_argument("--baseline", default="BENCH_pr4.json",
                    help="PR-4 JSON with the parts=1 rows to compare to")
    args = ap.parse_args(argv)
    if args.quick:
        args.scale, args.reps = 9, 1
    backend = B.resolve(args.backend)

    if args.tune:
        os.environ.setdefault("REPRO_TUNE", "1")
        from repro.kernels import tuner
        import repro.kernels.ops  # noqa: F401  (registers probes)
        caps = [512, 2048, 8192, 32768, 131072]
        picked = tuner.autotune_all(caps)
        print(f"[tune] {len(picked)} entries -> {tuner.cache_path()}")

    g = G.rmat(args.scale, args.edge_factor, seed=args.seed,
               weighted=True)
    deg = np.diff(np.asarray(g.row_offsets))
    src = int(np.argmax(deg))
    print(f"[bench] rmat scale={args.scale}: n={g.num_vertices} "
          f"m={g.num_edges} backend={backend}")

    baselines = {}
    base_path = os.path.join(os.path.dirname(__file__), "..",
                             args.baseline)
    if args.scale == 12 and os.path.exists(base_path):
        with open(base_path) as f:
            for row in json.load(f):
                if row.get("parts") == 1:
                    baselines[row["primitive"]] = row["ms"]

    with B.use_backend(backend):
        bench_primitives(g, src, backend, args.reps, baselines)
        bench_occupancy(g, backend, args.reps)

    if args.json:
        # stamp platform provenance into every persisted row so a
        # committed trajectory records what produced it (and compare.py
        # can refuse cross-platform comparisons)
        try:
            from benchmarks.common import provenance
        except ImportError:          # run as a bare script
            from common import provenance
        with open(args.json, "w") as f:
            json.dump([{**r, **provenance()} for r in ROWS], f, indent=1)
        print(f"[bench] wrote {args.json}")
    # machine-checkable summary (the CI perf-smoke contract)
    worst = min((r.get("mteps", 1) for r in ROWS
                 if r["bench"] == "frontier_scaling"), default=0)
    assert worst > 0, "zero-throughput row in frontier_scaling results"
    print(f"[bench] OK: min mteps {worst}")


if __name__ == "__main__":
    main()
