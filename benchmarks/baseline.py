"""PR-1 perf baseline: bfs/sssp/pagerank/tc on both operator backends.

Emits one JSON file so the perf trajectory of later PRs starts from a
recorded point instead of an asserted one. Off-TPU the pallas backend
runs in interpret mode — those numbers measure the *dispatch path*, not
kernel speed (expect pallas ≫ xla wall time on CPU; the comparison
becomes meaningful on a real TPU backend).

  PYTHONPATH=src python -m benchmarks.baseline --scale 14 \
      --out BENCH_pr1.json
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax

from repro.core import backend as B
from repro.core import graph as G
from repro.core.primitives import bfs, pagerank, sssp, triangle_count

from .common import best_source, timed

PRIMS = ("bfs", "sssp", "pagerank", "tc")


def _run_one(name: str, g, src: int, backend: str, repeats: int):
    if name == "bfs":
        r, t = timed(lambda: bfs(g, src, backend=backend), repeats=repeats)
        edges = int(r.edges_visited)
    elif name == "sssp":
        r, t = timed(lambda: sssp(g, src, backend=backend),
                     repeats=repeats)
        edges = g.num_edges
    elif name == "pagerank":
        r, t = timed(lambda: pagerank(g, max_iter=20, backend=backend),
                     repeats=repeats)
        edges = 20 * g.num_edges
    elif name == "tc":
        r, t = timed(lambda: triangle_count(g, backend=backend),
                     repeats=repeats)
        edges = g.num_edges
    else:
        raise ValueError(name)
    return {"primitive": name, "backend": backend,
            "ms": round(t * 1e3, 2),
            "mteps": round(edges / t / 1e6, 2)}


def run(scale: int = 14, edge_factor: int = 16, repeats: int = 1,
        out: str = "BENCH_pr1.json",
        backends=(B.XLA, B.PALLAS), prims=PRIMS):
    g = G.rmat(scale, edge_factor, seed=0, weighted=True)
    src = best_source(g)
    rows = []
    for backend in backends:
        for name in prims:
            # reprolint: disable=RL004 -- progress wall-clock; _run_one fences its own measurement
            t0 = time.monotonic()
            row = _run_one(name, g, src, backend, repeats)
            rows.append(row)
            print(f"[baseline] {name:9s} backend={backend:6s} "
                  f"{row['ms']:10.2f} ms  {row['mteps']:9.2f} MTEPS "
                  f"(wall {time.monotonic()-t0:.1f}s)", flush=True)
    doc = {
        "pr": 1,
        "graph": {"kind": "rmat", "scale": scale,
                  "edge_factor": edge_factor, "n": g.num_vertices,
                  "m": g.num_edges, "src": src},
        "repeats": repeats,
        "jax_backend": jax.default_backend(),
        "interpret_pallas": jax.default_backend() != "tpu",
        "platform": platform.platform(),
        "results": rows,
    }
    with open(out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# wrote {out}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--edge-factor", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=1)
    ap.add_argument("--out", default="BENCH_pr1.json")
    ap.add_argument("--backends", default="xla,pallas")
    ap.add_argument("--primitives", default=",".join(PRIMS))
    args = ap.parse_args()
    run(scale=args.scale, edge_factor=args.edge_factor,
        repeats=args.repeats, out=args.out,
        backends=tuple(args.backends.split(",")),
        prims=tuple(args.primitives.split(",")))


if __name__ == "__main__":
    main()
