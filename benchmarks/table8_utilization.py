"""Paper Table 8 analogue: load-balance quality. GPU 'warp execution
efficiency' becomes *lane utilization*: real edges ÷ the work slots a
strategy occupies.

  LB/TWC — output-balanced expansion: slots = frontier work rounded up to
           the VPU tile (512); utilization ≈ 100% by construction.
  THREAD — the static dense sweep touches every CSR slot: slots = m, so
           utilization = frontier_edges / m, collapsing on small
           frontiers — exactly the paper's load-imbalance story for
           static mappings (its GPU counterpart is warp efficiency).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import frontier as F
from repro.core import operators as ops

from .common import DATASETS, best_source, dataset, emit

TILE = 512


def run():
    rows = []
    for name in DATASETS:
        g = dataset(name)
        src = best_source(g)
        ro = np.asarray(g.row_offsets)
        ci = g.cols_np()
        ids = np.unique(ci[ro[src]:ro[src + 1]])[:256]
        fr = F.from_ids(ids, g.num_edges)
        work = int(np.sum(np.diff(ro)[ids]))
        for strategy in ("LB", "TWC", "THREAD"):
            res, _ = ops.advance(g, fr, g.num_edges, strategy=strategy)
            valid = int(jnp.sum(res.valid))
            if strategy == "THREAD":
                slots = g.num_edges          # dense sweep touches all m
            else:
                slots = max(-(-valid // TILE) * TILE, TILE)
            rows.append([name, strategy, work, slots,
                         round(100.0 * valid / slots, 2)])
    return emit(rows, ["dataset", "strategy", "frontier_edges",
                       "slots", "utilization_pct"],
                table="table8_utilization")
