"""Shared benchmark utilities: timed runs, CSV/JSON emit, graph zoo.

Measurement methodology mirrors the paper (§7): runtime excludes graph
build/transfer; each primitive runs once to compile then `repeats` times
for the average; MTEPS = edges visited / runtime.

Every emitted row carries the operator backend that was active when the
row was produced (resolved from the ambient context / REPRO_BACKEND), so
fused-vs-unfused deltas are measured, not asserted. ``emit`` also
accumulates rows into ``RESULTS`` for JSON output (benchmarks.run
--json).
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.core import backend as B
from repro.core import graph as G

REPEATS = 3

# accumulated row dicts (one per emitted CSV row, backend column included)
RESULTS: list[dict] = []

# CPU-scaled dataset zoo (paper Table 4 families: scale-free rmat ×3
# sizes, web-ish low-ef rmat, mesh-like grid + rgg)
DATASETS = {
    "rmat_s12_e16": lambda: G.rmat(12, 16, seed=1, weighted=True),
    "rmat_s13_e8": lambda: G.rmat(13, 8, seed=2, weighted=True),
    "rmat_s14_e4": lambda: G.rmat(14, 4, seed=3, weighted=True),
    "web_s13_e4": lambda: G.rmat(13, 4, a=0.65, b=0.15, c=0.15, seed=4,
                                 weighted=True),
    "grid_90": lambda: G.grid2d(90, weighted=True, seed=5),
    "rgg_s13": lambda: G.random_geometric(1 << 13, 0.018, seed=6,
                                          weighted=True),
}

_CACHE = {}


def dataset(name: str):
    if name not in _CACHE:
        _CACHE[name] = DATASETS[name]()
    return _CACHE[name]


def best_source(g) -> int:
    deg = np.diff(np.asarray(g.row_offsets))
    return int(np.argmax(deg))


def timed(fn, *args, repeats: int = REPEATS, **kw):
    """Compile once, then average wall time. Returns (result, seconds)."""
    out = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(out))
    times = []
    for _ in range(repeats):
        t0 = time.monotonic()
        out = fn(*args, **kw)
        jax.block_until_ready(jax.tree.leaves(out))
        times.append(time.monotonic() - t0)
    return out, float(np.median(times))


_PROVENANCE = None


def provenance() -> dict:
    """Platform/provenance stamp merged into every JSON bench row, so a
    number can never outlive the context that produced it (the ROADMAP's
    "CPU interpret-mode caveat" made queryable): jax version, backend
    platform, device kind, interpret-mode flags, and the git SHA of the
    tree that ran. Memoized — one device query per process."""
    global _PROVENANCE
    if _PROVENANCE is not None:
        return _PROVENANCE
    from repro.kernels import runtime
    dev = jax.devices()[0]
    try:
        import subprocess
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except Exception:
        sha = None
    _PROVENANCE = {
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "device_kind": getattr(dev, "device_kind", str(dev)),
        "interpret": runtime.interpret_mode(None),
        "force_interpret_env":
            os.environ.get(runtime.ENV_VAR, "") or None,
        "git_sha": sha,
    }
    return _PROVENANCE


def emit(rows, header, table: str | None = None):
    backend = B.resolve()
    print(",".join(list(header) + ["backend"]))
    for r in rows:
        print(",".join(str(x) for x in list(r) + [backend]))
        # JSON rows carry the full provenance stamp; the CSV stays the
        # historical column set (smoke-test greps parse it)
        RESULTS.append({"table": table, "backend": backend,
                        **dict(zip(header, r)), **provenance()})
    return rows


def emit_storage(graphs: dict) -> None:
    """Emit one resident-bytes row per named graph (per-array breakdown
    plus the headline column bytes-per-edge) into the shared CSV/JSON
    stream — every harness run reports what the bandwidth-bound kernels
    will actually stream."""
    from repro.core.storage import resident_bytes
    header = None
    rows = []
    for name, g in graphs.items():
        rb = resident_bytes(g)
        row = {"dataset": name,
               "index_dtype": rb["plan"]["index_dtype"],
               "encoding": rb["plan"]["encoding"],
               "bytes_per_edge": rb["bytes_per_edge"],
               "column_bytes": rb["column_bytes"],
               "total_bytes": rb["total_bytes"],
               "total_bytes_per_edge": rb["total_bytes_per_edge"],
               **rb["arrays"]}
        if header is None:
            header = tuple(row)
        rows.append([row[h] for h in header])
    if rows:
        emit(rows, header, table="storage")


def write_json(path: str) -> None:
    """Dump every row emitted so far (with its backend column) to JSON."""
    with open(path, "w") as f:
        json.dump({"results": RESULTS}, f, indent=1, default=str)
    print(f"# wrote {len(RESULTS)} rows to {path}")
