"""Sharded-placement scaling benchmark → BENCH_pr4.json.

Runs bfs / sssp / cc / pagerank single-device (the PR 2/3 engine — the
baseline) and through the sharded placement at 1/2/4-way partitions on
fake host-platform devices. On CPU the mesh is simulated, so the point
is the partitioning/exchange OVERHEAD trajectory (and trace-cache reuse
across queries), not speedup — the speedup story needs real devices.
Numbers land next to the PR1–PR3 baselines in the repo root.

    python benchmarks/distributed_scale.py --scale 12 --json BENCH_pr4.json
"""
import argparse
import json
import os
import time

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import sys                                                   # noqa: E402
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                   # noqa: E402
import numpy as np                                           # noqa: E402
from jax.sharding import Mesh                                # noqa: E402

from repro.core import graph as G                            # noqa: E402
from repro.core.distributed import (distributed_bfs,         # noqa: E402
                                    distributed_cc,
                                    distributed_pagerank,
                                    distributed_sssp)
from repro.core.partition import partition_1d                # noqa: E402
from repro.core.primitives import (bfs, connected_components,  # noqa: E402
                                   pagerank, sssp)


def timeit(fn, reps=3):
    fn()                                    # warmup (pays the trace)
    best = float("inf")
    for _ in range(reps):
        t0 = time.monotonic()
        jax.block_until_ready(fn())
        best = min(best, time.monotonic() - t0)
    return best * 1e3


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edge-factor", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="BENCH_pr4.json")
    args = ap.parse_args()

    g = G.rmat(args.scale, args.edge_factor, seed=args.seed, weighted=True)
    deg = np.diff(np.asarray(g.row_offsets))
    src = int(np.argmax(deg))
    print(f"[bench] rmat scale={args.scale}: n={g.num_vertices} "
          f"m={g.num_edges} devices={len(jax.devices())}")

    rows = []

    def emit(primitive, parts, ms, extra=None):
        row = {"bench": "distributed_scale", "primitive": primitive,
               "parts": parts, "ms": round(ms, 2),
               "n": g.num_vertices, "m": g.num_edges,
               "scale": args.scale}
        row.update(extra or {})
        rows.append(row)
        tag = "single" if parts == 1 else f"{parts}-way"
        print(f"[bench] {primitive:9s} {tag:7s} {ms:9.2f} ms")

    # single-device baselines (the PR 2/3 engine)
    emit("bfs", 1, timeit(lambda: bfs(g, src).labels))
    emit("sssp", 1, timeit(lambda: sssp(g, src).dist))
    emit("cc", 1, timeit(lambda: connected_components(g).labels))
    emit("pagerank", 1, timeit(lambda: pagerank(g, max_iter=20).rank))

    for p in (2, 4):
        if len(jax.devices()) < p:
            print(f"[bench] skipping {p}-way (only "
                  f"{len(jax.devices())} devices)")
            continue
        pg = partition_1d(g, p)
        mesh = Mesh(np.array(jax.devices()[:p]), ("graph",))
        bal = pg.balance()
        extra = {"edge_imbalance": bal["edge_imbalance"]}
        emit("bfs", p,
             timeit(lambda: distributed_bfs(pg, src, mesh).labels), extra)
        emit("sssp", p,
             timeit(lambda: distributed_sssp(pg, src, mesh).dist), extra)
        emit("cc", p,
             timeit(lambda: distributed_cc(pg, mesh).labels), extra)
        emit("pagerank", p,
             timeit(lambda: distributed_pagerank(pg, mesh, iters=20)),
             extra)

    with open(args.json, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"[bench] wrote {args.json}")


if __name__ == "__main__":
    main()
