"""Placement scaling benchmark (1-D sharded vs 2-D vertex cut)
→ BENCH_pr7.json.

Runs bfs / sssp / cc / pagerank single-device (the PR 2/3 engine — the
baseline), through the 1-D sharded placement at 4/8-way partitions, and
through the 2-D vertex-cut placement on 2×2 / 2×4 meshes, on fake
host-platform devices. On CPU the mesh is simulated, so wall time shows
the partitioning/exchange OVERHEAD trajectory, not speedup — the
speedup story needs real devices. What IS real on any platform is the
``comm_bytes_per_step`` column: the analytic bytes each device
exchanges per BSP step (ring-collective cost model, see
``repro.core.distributed.exchange_bytes_per_step``). The 2-D win the
ISSUE measures lives there — traversal exchanges drop from
n-proportional (1-D replicated-vector all-reduce) to chunk-proportional
(row psum of (vpc,) uint8 tiles + column gather).

    python benchmarks/distributed_scale.py --scales 12,13,14 \
        --json BENCH_pr7.json
"""
import argparse
import json
import os
import time

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import sys                                                   # noqa: E402
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                   # noqa: E402
import numpy as np                                           # noqa: E402
from jax.sharding import Mesh                                # noqa: E402

from repro.core import graph as G                            # noqa: E402
from repro.core.distributed import (distributed_bfs,         # noqa: E402
                                    distributed_cc,
                                    distributed_pagerank,
                                    distributed_sssp,
                                    exchange_bytes_per_step)
from repro.core.partition import (partition_1d,              # noqa: E402
                                  partition_2d)
from repro.core.primitives import (bfs, connected_components,  # noqa: E402
                                   pagerank, sssp)

PRIMS = ("bfs", "sssp", "cc", "pagerank")


def timeit(fn, reps=3):
    fn()                                    # warmup (pays the trace)
    best = float("inf")
    for _ in range(reps):
        t0 = time.monotonic()
        jax.block_until_ready(fn())
        best = min(best, time.monotonic() - t0)
    return best * 1e3


def run_prim(primitive, g, pg, mesh, src, iters):
    if pg is None:
        return {
            "bfs": lambda: bfs(g, src).labels,
            "sssp": lambda: sssp(g, src).dist,
            "cc": lambda: connected_components(g).labels,
            "pagerank": lambda: pagerank(g, max_iter=iters).rank,
        }[primitive]
    return {
        "bfs": lambda: distributed_bfs(pg, src, mesh).labels,
        "sssp": lambda: distributed_sssp(pg, src, mesh).dist,
        "cc": lambda: distributed_cc(pg, mesh).labels,
        "pagerank": lambda: distributed_pagerank(pg, mesh, iters=iters),
    }[primitive]


def bench_scale(scale, edge_factor, seed, iters, parts_1d, meshes_2d,
                rows):
    g = G.rmat(scale, edge_factor, seed=seed, weighted=True)
    deg = np.diff(np.asarray(g.row_offsets))
    src = int(np.argmax(deg))
    print(f"[bench] rmat scale={scale}: n={g.num_vertices} "
          f"m={g.num_edges} devices={len(jax.devices())}")

    def emit(primitive, placement, parts, mesh_shape, ms, pg=None):
        comm = (0 if pg is None
                else exchange_bytes_per_step(pg, primitive))
        row = {"bench": "distributed_scale", "primitive": primitive,
               "placement": placement, "parts": parts,
               "mesh": list(mesh_shape) if mesh_shape else None,
               "ms": round(ms, 2), "comm_bytes_per_step": comm,
               "n": g.num_vertices, "m": g.num_edges, "scale": scale}
        if pg is not None:
            bal = pg.balance()
            row["edge_imbalance"] = bal["edge_imbalance"]
        rows.append(row)
        tag = ("single" if parts == 1
               else f"{mesh_shape[0]}x{mesh_shape[1]}" if mesh_shape
               else f"{parts}-way")
        print(f"[bench] {primitive:9s} {tag:7s} {ms:9.2f} ms  "
              f"{comm / 1024:8.1f} KiB/step")

    for prim in PRIMS:
        emit(prim, "single", 1, None,
             timeit(run_prim(prim, g, None, None, src, iters)))
    for p in parts_1d:
        if len(jax.devices()) < p:
            print(f"[bench] skipping {p}-way (only "
                  f"{len(jax.devices())} devices)")
            continue
        pg = partition_1d(g, p)
        mesh = Mesh(np.array(jax.devices()[:p]), ("graph",))
        for prim in PRIMS:
            emit(prim, "sharded", p, None,
                 timeit(run_prim(prim, g, pg, mesh, src, iters)), pg)
    for (r, c) in meshes_2d:
        if len(jax.devices()) < r * c:
            print(f"[bench] skipping {r}x{c} (only "
                  f"{len(jax.devices())} devices)")
            continue
        pg = partition_2d(g, r, c)
        mesh = Mesh(np.array(jax.devices()[:r * c]).reshape(r, c),
                    ("row", "col"))
        for prim in PRIMS:
            emit(prim, "2d", r * c, (r, c),
                 timeit(run_prim(prim, g, pg, mesh, src, iters)), pg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scales", default="12,13,14",
                    help="comma-separated rmat scales")
    ap.add_argument("--edge-factor", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--iters", type=int, default=20,
                    help="pagerank iterations")
    ap.add_argument("--quick", action="store_true",
                    help="CI mode: one small scale, 4/8-way only, fewer "
                         "pagerank iterations")
    ap.add_argument("--json", default="BENCH_pr7.json")
    args = ap.parse_args()

    scales = [int(s) for s in args.scales.split(",")]
    parts_1d, meshes_2d, iters = (4, 8), ((2, 2), (2, 4)), args.iters
    if args.quick:
        scales, parts_1d, meshes_2d, iters = [10], (4, 8), \
            ((2, 2), (2, 4)), 8
    rows = []
    for scale in scales:
        bench_scale(scale, args.edge_factor, args.seed, iters,
                    parts_1d, meshes_2d, rows)

    try:
        from benchmarks.common import provenance
    except ImportError:              # run as a bare script
        from common import provenance
    with open(args.json, "w") as f:
        json.dump([{**r, **provenance()} for r in rows], f, indent=1)
    print(f"[bench] wrote {args.json}")


if __name__ == "__main__":
    main()
