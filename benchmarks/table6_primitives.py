"""Paper Table 6 analogue: runtime (ms) + MTEPS for every primitive on
every dataset, with oracle validation (the 'hardwired' comparison role is
played by the numpy references — correctness + relative scaling claims)."""
from __future__ import annotations

import numpy as np

from repro.core import ref as R
from repro.core.primitives import (bc, bfs, connected_components, pagerank,
                                   sssp, triangle_count)

from .common import DATASETS, best_source, dataset, emit, timed


def run():
    rows = []
    for name in DATASETS:
        g = dataset(name)
        src = best_source(g)
        m = g.num_edges

        r, t = timed(lambda: bfs(g, src))
        rows.append([name, "bfs", round(t * 1e3, 2),
                     round(int(r.edges_visited) / t / 1e6, 1),
                     int(np.array_equal(np.asarray(r.labels),
                                        R.bfs_ref(g, src)))])
        r, t = timed(lambda: sssp(g, src))
        rows.append([name, "sssp", round(t * 1e3, 2), "",
                     int(np.allclose(np.asarray(r.dist),
                                     R.sssp_ref(g, src), rtol=1e-5))])
        r, t = timed(lambda: pagerank(g, max_iter=20))
        rows.append([name, "pagerank", round(t * 1e3, 2),
                     round(20 * m / t / 1e6, 1),
                     int(np.allclose(np.asarray(r.rank),
                                     R.pagerank_ref(g, iters=20),
                                     atol=1e-6))])
        r, t = timed(lambda: connected_components(g))
        ref = R.cc_ref(g)
        rows.append([name, "cc", round(t * 1e3, 2), "",
                     int(int(r.num_components) == len(set(ref.tolist())))])
        r, t = timed(lambda: bc(g, src))
        rows.append([name, "bc", round(t * 1e3, 2),
                     round(2 * m / t / 1e6, 1),
                     int(np.allclose(np.asarray(r.bc), R.bc_ref(g, src),
                                     rtol=1e-3, atol=1e-3))])
        r, t = timed(lambda: triangle_count(g))
        rows.append([name, "tc", round(t * 1e3, 2), "",
                     int(int(r.total) == R.tc_ref(g))])
    return emit(rows, ["dataset", "primitive", "ms", "mteps", "valid"],
                table="table6_primitives")
