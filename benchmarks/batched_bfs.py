"""Batched-BFS serving throughput — the PR 2 headline measurement.

Serves a fixed query stream through ``launch.graph_serve.serve`` at
batch sizes B ∈ {1, 8, 32} on both backends and writes BENCH_pr2.json
next to the PR 1 single-source baseline (BENCH_pr1.json). The xla rows
use the same rmat scale-14 graph as PR 1; the pallas rows use a smaller
graph because interpret mode executes the kernel grid on the host
(documented in the row — it is a correctness backend off-TPU, not a
fast path).

  PYTHONPATH=src python -m benchmarks.batched_bfs --json BENCH_pr2.json
"""
from __future__ import annotations

import argparse
import json
import platform

import jax
import numpy as np

from repro.core import graph as G
from repro.core.primitives import bfs_batch
from repro.launch.graph_serve import serve

BATCHES = (1, 8, 32)
REQUESTS = 32


def bench_backend(backend: str, scale: int, edge_factor: int = 16,
                  seed: int = 0):
    g = G.rmat(scale, edge_factor, seed=seed, weighted=True)
    rng = np.random.default_rng(seed)
    rows = []
    for b in BATCHES:
        # pay the trace outside the timed run
        w = bfs_batch(g, rng.integers(0, g.num_vertices, b),
                      backend=backend)
        jax.block_until_ready(w.labels)
        sources = rng.integers(0, g.num_vertices, REQUESTS)
        stats = serve(g, "bfs", sources, b, backend)
        stats["scale"] = scale
        rows.append(stats)
        print(f"[batched_bfs] backend={backend} scale={scale} B={b}: "
              f"{stats['qps']} q/s (p50 {stats['lat_ms_p50']} ms)")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_pr2.json")
    ap.add_argument("--xla-scale", type=int, default=14)
    ap.add_argument("--pallas-scale", type=int, default=10)
    args = ap.parse_args(argv)
    out = {
        "pr": 2,
        "note": "batched multi-source BFS serving throughput; compare "
                "the B=1 rows against the single-source bfs rows in "
                "BENCH_pr1.json",
        "requests": REQUESTS,
        "jax_backend": jax.default_backend(),
        "interpret_pallas": jax.default_backend() != "tpu",
        "platform": platform.platform(),
        "results": (bench_backend("xla", args.xla_scale)
                    + bench_backend("pallas", args.pallas_scale)),
    }
    with open(args.json, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[batched_bfs] wrote {args.json}")


def run():
    main([])


if __name__ == "__main__":
    main()
