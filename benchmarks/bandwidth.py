"""Bandwidth-proportional storage benchmark (PR 6; → BENCH_pr6.json).

Traversal on this engine is memory-bound: every advance / SpMV sweep
streams the CSR (or CSC) column array, so *bytes per edge* bounds
throughput. This module measures exactly that tradeoff across the
storage-plan grid introduced by ``repro.core.storage``:

  storage axis   int64 (the widest baseline, run under jax_enable_x64),
                 int32 (the classic layout), delta (narrow auto dtype +
                 per-row anchored uint16 deltas)
  value axis     fp32 everywhere; bf16 additionally for PageRank (the
                 one inexact-semiring workload in the sweep)

Workloads are the paper's three traversal archetypes — BFS, SSSP,
PageRank — on weighted R-MAT at scales 12–14. For each (workload,
scale) the int64 run is the parity oracle: int32 and delta results must
be BIT-identical (exact semirings decode exactly); bf16 PageRank must
agree within the documented ~1e-2 absolute tolerance (DESIGN.md §8).

Timing is compile-once-then-median (benchmarks.common.timed); on this
CPU container the numbers are relative, not TPU-absolute — the metric
that transfers is the ratio between storage formats at identical
topology, plus the exact resident-byte accounting from
``storage.resident_bytes``.

Run:     PYTHONPATH=src python -m benchmarks.bandwidth
Quick:   PYTHONPATH=src python -m benchmarks.bandwidth --quick
         (scale 12 only, 1 repeat — the CI bench-schema smoke)
Output:  BENCH_pr6.json (override with --json PATH)
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from benchmarks.common import emit, timed
from repro.core import backend as B
from repro.core import graph as G
from repro.core import storage as S
from repro.core.primitives import bfs, pagerank, sssp

SCALES = (12, 13, 14)
EDGE_FACTOR = 8
BF16_TOL = 1e-2

# storage tag -> Graph build kwargs (the plan knobs of from_edge_list)
STORAGES = {
    "int64": {"index_dtype": "int64"},
    "int32": {"index_dtype": "int32"},
    "delta": {"encoding": "delta"},
}

WORKLOADS = {
    "bfs": lambda g, src: bfs(g, src).labels,
    "sssp": lambda g, src: sssp(g, src).dist,
    "pagerank": lambda g, src: pagerank(g, max_iter=20).rank,
}


def _build(scale: int, storage: str):
    kw = STORAGES[storage]
    return G.rmat(scale, EDGE_FACTOR, seed=scale, weighted=True, **kw)


def _source(g) -> int:
    return int(np.argmax(np.diff(np.asarray(g.row_offsets))))


def run(scales=SCALES, repeats: int = 3, json_path: str = "BENCH_pr6.json",
        quick: bool = False):
    if quick:
        scales, repeats = scales[:1], 1
    backend = B.resolve()
    rows = []
    speedups = {}
    drops = {}
    for scale in scales:
        # the int64 baseline needs real 64-bit arrays, which JAX only
        # provides under the x64 switch; the whole baseline branch
        # (build + run) lives inside the context so nothing narrows.
        with jax.experimental.enable_x64():
            g64 = _build(scale, "int64")
            src = _source(g64)
            base_ms, base_out, base_bpe = {}, {}, None
            rb = S.resident_bytes(g64)
            base_bpe = rb["bytes_per_edge"]
            for wl, fn in WORKLOADS.items():
                out, sec = timed(fn, g64, src, repeats=repeats)
                base_ms[wl] = sec * 1e3
                base_out[wl] = np.asarray(out)
                rows.append(dict(
                    workload=wl, scale=scale, storage="int64",
                    value_dtype="fp32", ms=round(base_ms[wl], 3),
                    bytes_per_edge=base_bpe,
                    total_bytes=rb["total_bytes"], parity="baseline",
                    speedup_vs_int64=1.0))
        for storage in ("int32", "delta"):
            g = _build(scale, storage)
            rb = S.resident_bytes(g)
            bpe = rb["bytes_per_edge"]
            drops[f"{storage}_s{scale}"] = round(1.0 - bpe / base_bpe, 3)
            for wl, fn in WORKLOADS.items():
                out, sec = timed(fn, g, src, repeats=repeats)
                ms = sec * 1e3
                ok = np.array_equal(base_out[wl], np.asarray(out))
                sp = base_ms[wl] / ms if ms > 0 else float("inf")
                speedups[f"{wl}_s{scale}_{storage}"] = round(sp, 3)
                rows.append(dict(
                    workload=wl, scale=scale, storage=storage,
                    value_dtype="fp32", ms=round(ms, 3),
                    bytes_per_edge=bpe, total_bytes=rb["total_bytes"],
                    parity="bit" if ok else "FAIL",
                    speedup_vs_int64=round(sp, 3)))
                assert ok, (
                    f"{wl} scale={scale} {storage}: results must be "
                    f"bit-identical to the int64 baseline")
            # the inexact-semiring axis: bf16 PageRank on this storage
            out, sec = timed(lambda g_: pagerank(
                g_, max_iter=20, precision="bf16").rank, g,
                repeats=repeats)
            diff = float(np.abs(base_out["pagerank"]
                                - np.asarray(out)).max())
            rows.append(dict(
                workload="pagerank", scale=scale, storage=storage,
                value_dtype="bf16", ms=round(sec * 1e3, 3),
                bytes_per_edge=bpe, total_bytes=rb["total_bytes"],
                parity=f"maxabs={diff:.2e}",
                speedup_vs_int64=round(base_ms["pagerank"] / (sec * 1e3),
                                       3)))
            assert diff < BF16_TOL, (
                f"bf16 pagerank drifted {diff} > {BF16_TOL}")
    header = ("workload", "scale", "storage", "value_dtype", "ms",
              "bytes_per_edge", "total_bytes", "parity",
              "speedup_vs_int64")
    emit([[r[h] for h in header] for r in rows], header,
         table="bandwidth")
    best = max(speedups.values()) if speedups else 0.0
    from benchmarks.common import provenance
    payload = {
        "schema": "bandwidth-v1",
        "backend": backend,
        "quick": quick,
        "scales": list(scales),
        "rows": rows,
        "speedups": speedups,
        "best_traversal_speedup": best,
        "bytes_per_edge_drop": drops,
        "provenance": provenance(),
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"# wrote {json_path}: best speedup vs int64 = {best:.2f}x, "
          f"bytes/edge drops = {drops}")
    return payload


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description="storage bandwidth benchmark")
    ap.add_argument("--quick", action="store_true",
                    help="scale 12 only, 1 repeat (CI smoke)")
    ap.add_argument("--json", default="BENCH_pr6.json")
    args = ap.parse_args(argv)
    run(json_path=args.json, quick=args.quick)


if __name__ == "__main__":
    main()
