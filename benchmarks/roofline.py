"""Roofline analysis (deliverable g): renders results/dryrun.json into
the §Dry-run and §Roofline tables of EXPERIMENTS.md.

Terms (per device, v5e):
  compute    = flops / 197e12          [s]
  memory     = bytes / 819e9           [s]
  collective = link_bytes / 50e9       [s]
Dominant term = bottleneck. Roofline fraction for the compute term =
MODEL_FLOPS/(chips · 197e12) ÷ max(term)s — how close the *useful* math
comes to the machine's peak given the measured program.
"""
from __future__ import annotations

import argparse
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def load(path: str):
    with open(path) as f:
        return json.load(f)


def terms(row):
    est = row.get("est") or {}
    flops = est.get("flops", 0.0)
    bytes_ = est.get("bytes", 0.0)
    coll = est.get("coll_link_bytes", 0.0)
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    t_x = coll / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    model_t = row["model_flops_global"] / row["chips"] / PEAK_FLOPS
    frac = model_t / dom[1] if dom[1] > 0 else 0.0
    useful = (row["model_flops_global"] / row["chips"] / flops
              if flops else 0.0)
    return t_c, t_m, t_x, dom[0], frac, useful


def advice(row, dom):
    kind = row["kind"]
    if dom == "collective":
        return ("overlap/shrink FSDP gathers (bf16 gathers, wider TP) "
                if kind == "train" else "shrink EP all-to-all / "
                "replicate small weights")
    if dom == "memory":
        return ("fuse attention (flash kernel) / raise arithmetic "
                "intensity per HBM byte" if kind != "train"
                else "larger microbatch per device / fused optimizer")
    return "already MXU-bound: tune tile shapes, cut remat recompute"


def render(path: str, multi: bool = False):
    data = load(path)
    rows = [r for r in data["rows"]]
    out = []
    out.append("| arch | shape | mesh | peak GiB/dev | compute s | "
               "memory s | collective s | bottleneck | MODEL/HLO flops | "
               "roofline frac |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if (r["mesh"] != "16x16") and not multi:
            continue
        t_c, t_m, t_x, dom, frac, useful = terms(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['memory']['peak_per_device']/2**30:.2f} | "
            f"{t_c:.3e} | {t_m:.3e} | {t_x:.3e} | {dom} | "
            f"{useful:.2f} | {frac:.3f} |")
    if data.get("failures"):
        out.append("")
        out.append(f"FAILURES: {data['failures']}")
    return "\n".join(out)


def run():
    path = os.environ.get("REPRO_DRYRUN_JSON", "results/dryrun.json")
    if not os.path.exists(path):
        alt = "results/dryrun_single.json"
        if os.path.exists(alt):
            path = alt
        else:
            print("roofline: no dryrun json found — run "
                  "`python -m repro.launch.dryrun --all --out "
                  "results/dryrun.json` first")
            return []
    text = render(path, multi=True)
    print(text)
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.md", "w") as f:
        f.write(text + "\n")
    return text.splitlines()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="results/dryrun.json")
    ap.add_argument("--multi", action="store_true", default=True)
    args = ap.parse_args()
    os.environ["REPRO_DRYRUN_JSON"] = args.json
    run()


if __name__ == "__main__":
    main()
