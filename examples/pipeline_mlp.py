"""Pipeline parallelism: a 4-stage GPipe schedule over 4 (simulated)
devices with microbatch interleaving and ppermute stage handoff.

    python examples/pipeline_mlp.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                    # noqa: E402
import jax.numpy as jnp                       # noqa: E402
import numpy as np                            # noqa: E402

from repro.parallel.pipeline import pipeline_apply  # noqa: E402

mesh = jax.make_mesh((4,), ("stage",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
stage_weights = jnp.asarray(rng.standard_normal((4, 64, 64)) * 0.2,
                            jnp.float32)
x = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)

y = pipeline_apply(lambda w, h: jnp.tanh(h @ w), stage_weights, x, mesh,
                   n_microbatches=8)

ref = x
for i in range(4):
    ref = jnp.tanh(ref @ stage_weights[i])
err = float(jnp.max(jnp.abs(y - ref)))
bubble = (4 - 1) / (8 + 4 - 1)
print(f"4-stage pipeline over 8 microbatches: max err {err:.2e}, "
      f"bubble fraction {bubble:.2%}")
