"""Batched serving: prefill + lockstep decode against a static KV cache
(the inference-side end-to-end driver).

    PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "minicpm-2b", "--smoke", "--requests", "8",
          "--batch", "4", "--prompt-len", "32", "--gen-len", "16"])
