"""The paper's technique beyond the paper: MoE token routing as a
Gunrock frontier traversal (DESIGN.md §4).

Trains a reduced Kimi-K2-family MoE for 30 steps and reports the
frontier-dispatch metrics each step: expert load-balance (aux loss) and
capacity-drop fraction (the inexact-filter cull rate).

    PYTHONPATH=src python examples/moe_frontier_train.py
"""
import jax

from repro.configs import get_smoke_config
from repro.data import SyntheticLMDataset
from repro.models import build_model
from repro.train import adamw, make_schedule

cfg = get_smoke_config("kimi-k2-1t-a32b").replace(capacity_factor=1.25)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt_init, opt_update = adamw(make_schedule("cosine", 3e-3, 30,
                                           warmup_steps=3))
opt = opt_init(params)
ds = SyntheticLMDataset(cfg.vocab, 64, 8, seed=0)


@jax.jit
def step(p, o, batch):
    (l, metrics), g = jax.value_and_grad(model.loss, has_aux=True)(p,
                                                                   batch)
    p, o, om = opt_update(g, o, p)
    return p, o, {**metrics, "loss": l, **om}


for i in range(30):
    params, opt, m = step(params, opt, ds.next_batch())
    if i % 5 == 0 or i == 29:
        print(f"step {i:3d}  loss {float(m['loss']):6.3f}  "
              f"moe_aux {float(m['moe_aux_loss']):5.3f}  "
              f"drop_frac {float(m['moe_drop_frac']):5.3f}  "
              f"(frontier culling rate)")
print("\nMoE dispatch = advance (route) + inexact filter (capacity) + "
      "neighborhood reduction (combine)")
