"""Fault-tolerant LM training: trains a reduced MiniCPM with its WSD
schedule, kills itself at step 30 (injected failure), auto-restores from
the latest checkpoint, and finishes — the full elastic-restart path
(deliverable: fault tolerance).

    PYTHONPATH=src python examples/train_checkpoint_restart.py
"""
import tempfile

from repro.launch.train import main

with tempfile.TemporaryDirectory() as ckpt:
    report = main([
        "--arch", "minicpm-2b", "--smoke",
        "--steps", "60", "--batch", "8", "--seq", "128",
        "--ckpt-dir", ckpt, "--ckpt-every", "20",
        "--simulate-failure", "30",
    ])
    assert report["completed"] and report["restarts"] == 1
    losses = [h["loss"] for h in report["history"]]
    print(f"\nsurvived 1 injected failure; loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f} over {len(losses)} executed steps")
