"""Multi-device graph traversal (paper §8.2.1 scale-out): 1-D partitioned
BFS + PageRank over 8 (simulated) devices with shard_map frontier
exchange.

    python examples/distributed_bfs.py        (sets its own XLA_FLAGS)
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                    # noqa: E402
import numpy as np                            # noqa: E402

from repro.core import graph as G             # noqa: E402
from repro.core import ref as R               # noqa: E402
from repro.core.distributed import (distributed_bfs,      # noqa: E402
                                    distributed_pagerank)
from repro.core.partition import partition_1d  # noqa: E402
from repro.jax_compat import make_mesh        # noqa: E402

g = G.rmat(12, 8, seed=4)
pg = partition_1d(g, 8)
mesh = make_mesh((8,), ("graph",))
deg = np.diff(np.asarray(g.row_offsets))
src = int(np.argmax(deg))

r = distributed_bfs(pg, src, mesh)
ok = np.array_equal(np.asarray(r.labels), R.bfs_ref(g, src))
print(f"distributed BFS over {pg.num_parts} devices: n={g.num_vertices} "
      f"m={g.num_edges} iters={int(r.iterations)} valid={ok}")

pr = distributed_pagerank(pg, mesh, iters=15)
ok = np.allclose(np.asarray(pr), R.pagerank_ref(g, iters=15), atol=1e-6)
print(f"distributed PageRank: valid={ok}")
