"""End-to-end graph-analytics driver — the paper's application kind
(deliverable b): generate a graph, run all primitives, validate each
against its oracle, report runtime + MTEPS like the paper's §7 tables.

    PYTHONPATH=src python examples/graph_analytics.py
"""
from repro.launch.graph_run import main

if __name__ == "__main__":
    main(["--graph", "rmat", "--scale", "12", "--edge-factor", "8",
          "--primitives", "bfs,sssp,pagerank,cc,bc,tc,wtf",
          "--validate"])
