"""Quickstart: the data-centric abstraction in 40 lines.

Builds the paper's Fig-5 sample graph, manipulates frontiers with the
four operators (advance / filter / segmented intersect / compute), then
runs direction-optimized BFS on a scale-free graph.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import frontier as F
from repro.core import graph as G
from repro.core import operators as ops
from repro.core.primitives import bfs

# --- the paper's sample graph (Fig. 5/6) -----------------------------------
g = G.demo_graph()
print(f"sample graph: n={g.num_vertices} m={g.num_edges}")

# advance: expand the neighbor lists of frontier {0}
fr = F.from_ids([0], capacity=8)
res, _ = ops.advance(g, fr, cap_out=16)
print("advance({0}) ->", sorted(np.asarray(res.dst)[np.asarray(res.valid)]
                                .tolist()))

# filter: keep even vertices, exact-uniquified
new_fr = ops.advance_to_vertex_frontier(res, 16)
new_fr, _ = ops.filter_frontier(
    new_fr, functor=lambda ids, valid, d: (ids % 2 == 0, d),
    n=g.num_vertices, uniquify="exact")
print("filter(even) ->",
      np.asarray(new_fr.ids)[:int(new_fr.length)].tolist())

# segmented intersection: common neighbors of (0, 2) — triangle counting's
# core (paper §4.3)
res = ops.segmented_intersect(g, F.from_ids([0], 2), F.from_ids([2], 2),
                              cap_out=16)
print("N(0) ∩ N(2) =", np.asarray(res.items)[:int(res.length)].tolist())

# --- direction-optimized BFS on a scale-free graph --------------------------
big = G.rmat(12, 16, seed=0)
deg = np.diff(np.asarray(big.row_offsets))
src = int(np.argmax(deg))
r = bfs(big, src, direction=True, idempotence=True)
reached = int(np.sum(np.asarray(r.labels) >= 0))
print(f"\nBFS on rmat_s12_e16 from {src}: reached {reached}/"
      f"{big.num_vertices} vertices in {int(r.iterations)} iterations "
      f"({int(r.pull_iters)} pull), {int(r.edges_visited)} edges")
