"""repro.linalg — semiring sparse linear algebra over graph CSR/CSC.

The algebraic twin of the frontier engine (GraphBLAST's view of
Gunrock): whole-frontier primitives are one masked semiring product per
iteration instead of advance+filter chains.

  semirings  — plus_times, min_plus, or_and, max_min, plus_and
               (named, hashable, jit-closable; ``semiring.get`` by name)
  spmv       — masked/complemented semiring SpMV (dense x, CSR or CSC)
  spmsv      — sparse-input-vector product (push direction, via the
               "advance" registry hot path)
  spmm       — dense-accumulator SpMM (the batched / label-block form)
  mxm        — row-tiled masked SpGEMM (dot formulation over a mask
               pattern — triangle counting, sparse overlap queries)

All four dispatch through the ``repro.core.backend`` registry
("spmv" | "spmm" | "mxm" ops; spmsv rides "advance"), so
``backend="pallas"`` routes them through the fused masked-semiring
kernels in ``repro.kernels``. See DESIGN.md §4.
"""
from . import semiring
from .semiring import (SEMIRINGS, Semiring, max_min, min_plus, or_and,
                       plus_and, plus_times)
from .ops import mxm, spmm, spmsv, spmv

__all__ = ["semiring", "Semiring", "SEMIRINGS", "plus_times", "min_plus",
           "or_and", "max_min", "plus_and", "spmv", "spmsv", "spmm", "mxm"]
