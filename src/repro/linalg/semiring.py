"""Semirings for the sparse-linear-algebra layer (GraphBLAST's view of
Gunrock's operators: traversal is a masked matrix product over a semiring).

A ``Semiring`` bundles an additive monoid (the reduction that merges
incoming edge contributions — Gunrock's scatter/segment step) and a
multiplicative combinator (the per-edge functor). The named instances
cover the classic graph-algorithm algebra:

  plus_times — PageRank / SpMV proper (rank mass flows along edges)
  min_plus   — shortest paths (relaxation as matrix product)
  or_and     — reachability / BFS levels (boolean closure)
  max_min    — bottleneck paths / label spread (widest-path algebra)
  plus_and   — intersection counting (triangle counting: the or_and
               product with the plus accumulator exposed, so each
               and-match contributes 1 to the count)

Instances are frozen (hashable) dataclasses of str/float fields only, so
they are *jit-closable*: primitives pass them through
``jax.jit(static_argnames=...)`` and kernels select their combine ops at
trace time with zero runtime branching.

All values are float32 on device; boolean semirings operate on {0.0, 1.0}
(``and`` is ``minimum``, ``or`` is ``maximum`` on that domain).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

_ADD = ("plus", "min", "max", "or")
_MUL = ("times", "plus", "min", "max", "and")


@dataclass(frozen=True)
class Semiring:
    """A (⊕, ⊗) pair with identities. ``zero`` is the ⊕-identity (the
    value of an empty reduction / a masked-out output); ``one`` is the
    ⊗-identity (the value structural — valueless — matrices multiply
    by)."""

    name: str
    add: str     # ⊕: "plus" | "min" | "max" | "or"
    mul: str     # ⊗: "times" | "plus" | "min" | "max" | "and"
    zero: float  # ⊕ identity
    one: float   # ⊗ identity

    def __post_init__(self):
        if self.add not in _ADD:
            raise ValueError(f"unknown add monoid {self.add!r}")
        if self.mul not in _MUL:
            raise ValueError(f"unknown mul op {self.mul!r}")

    # --- combinators (all shapes, broadcasting) ---------------------------
    def mul_op(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """⊗ of two arrays (commutative for every supported op)."""
        if self.mul == "times":
            return a * b
        if self.mul == "plus":
            return a + b
        if self.mul in ("min", "and"):
            return jnp.minimum(a, b)
        return jnp.maximum(a, b)

    def add_op(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """⊕ of two partial reductions (merging ELL and overflow parts)."""
        if self.add == "plus":
            return a + b
        if self.add == "min":
            return jnp.minimum(a, b)
        return jnp.maximum(a, b)          # max | or

    def add_reduce(self, x: jax.Array, axis: int) -> jax.Array:
        """⊕-reduction along ``axis`` (invalid lanes must hold zero)."""
        if self.add == "plus":
            return jnp.sum(x, axis=axis)
        if self.add == "min":
            return jnp.min(x, axis=axis)
        return jnp.max(x, axis=axis)

    def segment_reduce(self, vals: jax.Array, seg: jax.Array,
                       num_segments: int,
                       indices_are_sorted: bool = False) -> jax.Array:
        """⊕-reduction of ``vals`` by segment id. Empty segments come back
        as the segment op's neutral element, NOT necessarily ``zero`` —
        callers clamp empty rows (see ops._finish_rows)."""
        fn = {"plus": jax.ops.segment_sum, "min": jax.ops.segment_min,
              "max": jax.ops.segment_max, "or": jax.ops.segment_max}[self.add]
        return fn(vals, seg, num_segments=num_segments,
                  indices_are_sorted=indices_are_sorted)

    def scatter_accum(self, target: jax.Array, index: jax.Array,
                      vals: jax.Array) -> jax.Array:
        """⊕-accumulate ``vals`` into ``target`` at ``index`` (the
        atomic-free scatter of operators.py, semiring-generalized)."""
        at = target.at[index]
        if self.add == "plus":
            return at.add(vals, mode="drop")
        if self.add == "min":
            return at.min(vals, mode="drop")
        return at.max(vals, mode="drop")


plus_times = Semiring("plus_times", "plus", "times", 0.0, 1.0)
min_plus = Semiring("min_plus", "min", "plus", float("inf"), 0.0)
or_and = Semiring("or_and", "or", "and", 0.0, 1.0)
max_min = Semiring("max_min", "max", "min", float("-inf"), float("inf"))
plus_and = Semiring("plus_and", "plus", "and", 0.0, 1.0)

SEMIRINGS = {s.name: s for s in
             (plus_times, min_plus, or_and, max_min, plus_and)}


def get(semiring) -> Semiring:
    """Coerce a name or Semiring instance to a Semiring."""
    if isinstance(semiring, Semiring):
        return semiring
    try:
        return SEMIRINGS[semiring]
    except KeyError:
        raise ValueError(
            f"unknown semiring {semiring!r}; named semirings: "
            f"{sorted(SEMIRINGS)}") from None
