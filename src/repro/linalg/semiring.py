"""Semirings for the sparse-linear-algebra layer (GraphBLAST's view of
Gunrock's operators: traversal is a masked matrix product over a semiring).

A ``Semiring`` bundles an additive monoid (the reduction that merges
incoming edge contributions — Gunrock's scatter/segment step) and a
multiplicative combinator (the per-edge functor). The named instances
cover the classic graph-algorithm algebra:

  plus_times — PageRank / SpMV proper (rank mass flows along edges)
  min_plus   — shortest paths (relaxation as matrix product)
  or_and     — reachability / BFS levels (boolean closure)
  max_min    — bottleneck paths / label spread (widest-path algebra)
  plus_and   — intersection counting (triangle counting: the or_and
               product with the plus accumulator exposed, so each
               and-match contributes 1 to the count)

Instances are frozen (hashable) dataclasses of str/float fields only, so
they are *jit-closable*: primitives pass them through
``jax.jit(static_argnames=...)`` and kernels select their combine ops at
trace time with zero runtime branching.

All values are float32 on device; boolean semirings operate on {0.0, 1.0}
(``and`` is ``minimum``, ``or`` is ``maximum`` on that domain).

Mixed precision (PR 6, the storage plan's ``value_dtype`` knob):
``with_precision(sr, "bf16")`` derives a variant whose ⊗ rounds both
operands to bfloat16 before combining and accumulates in float32 —
halving the multiply-side mantissa while keeping the ⊕ fold exact in
its own arithmetic. Only the plus-accumulating semirings (plus_times,
plus_and — PageRank mass flow and intersection counting) admit it; the
selection semirings (min/max/or — BFS, SSSP, bottleneck) are *exact*
algorithms whose results are id-like or distance-like, so they reject
bf16 rather than silently perturbing parity.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

_ADD = ("plus", "min", "max", "or")
_MUL = ("times", "plus", "min", "max", "and")
_PRECISIONS = ("fp32", "bf16")


@dataclass(frozen=True)
class Semiring:
    """A (⊕, ⊗) pair with identities. ``zero`` is the ⊕-identity (the
    value of an empty reduction / a masked-out output); ``one`` is the
    ⊗-identity (the value structural — valueless — matrices multiply
    by)."""

    name: str
    add: str     # ⊕: "plus" | "min" | "max" | "or"
    mul: str     # ⊗: "times" | "plus" | "min" | "max" | "and"
    zero: float  # ⊕ identity
    one: float   # ⊗ identity
    precision: str = "fp32"  # ⊗ operand rounding: "fp32" | "bf16"

    def __post_init__(self):
        if self.add not in _ADD:
            raise ValueError(f"unknown add monoid {self.add!r}")
        if self.mul not in _MUL:
            raise ValueError(f"unknown mul op {self.mul!r}")
        if self.precision not in _PRECISIONS:
            raise ValueError(f"unknown precision {self.precision!r}; "
                             f"expected one of {_PRECISIONS}")
        if self.precision == "bf16" and self.add != "plus":
            raise ValueError(
                f"bf16 precision is only defined for plus-accumulating "
                f"semirings (plus_times / plus_and); {self.name!r} is an "
                f"exact selection semiring")

    # --- combinators (all shapes, broadcasting) ---------------------------
    def mul_op(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """⊗ of two arrays (commutative for every supported op). Under
        ``precision="bf16"`` both operands round to bfloat16 and the
        product widens back to float32 for the ⊕ fold."""
        if self.precision == "bf16":
            a = jnp.asarray(a, jnp.bfloat16)
            b = jnp.asarray(b, jnp.bfloat16)
        if self.mul == "times":
            out = a * b
        elif self.mul == "plus":
            out = a + b
        elif self.mul in ("min", "and"):
            out = jnp.minimum(a, b)
        else:
            out = jnp.maximum(a, b)
        if self.precision == "bf16":
            out = out.astype(jnp.float32)
        return out

    def round_prod(self, x: jax.Array) -> jax.Array:
        """⊗-product rounding for the *structural* case (values=None ⇒
        the product IS the gathered operand, so mul_op never runs):
        under ``precision="bf16"`` the product stream still carries a
        bfloat16 mantissa before the fp32 ⊕ fold — the same contract as
        a stored-value multiply. Identity under fp32."""
        if self.precision == "bf16":
            return x.astype(jnp.bfloat16).astype(jnp.float32)
        return x

    def add_op(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """⊕ of two partial reductions (merging ELL and overflow parts)."""
        if self.add == "plus":
            return a + b
        if self.add == "min":
            return jnp.minimum(a, b)
        return jnp.maximum(a, b)          # max | or

    def add_reduce(self, x: jax.Array, axis: int) -> jax.Array:
        """⊕-reduction along ``axis`` (invalid lanes must hold zero)."""
        if self.add == "plus":
            return jnp.sum(x, axis=axis)
        if self.add == "min":
            return jnp.min(x, axis=axis)
        return jnp.max(x, axis=axis)

    def segment_reduce(self, vals: jax.Array, seg: jax.Array,
                       num_segments: int,
                       indices_are_sorted: bool = False) -> jax.Array:
        """⊕-reduction of ``vals`` by segment id. Empty segments come back
        as the segment op's neutral element, NOT necessarily ``zero`` —
        callers clamp empty rows (see ops._finish_rows)."""
        fn = {"plus": jax.ops.segment_sum, "min": jax.ops.segment_min,
              "max": jax.ops.segment_max, "or": jax.ops.segment_max}[self.add]
        return fn(vals, seg, num_segments=num_segments,
                  indices_are_sorted=indices_are_sorted)

    def scatter_accum(self, target: jax.Array, index: jax.Array,
                      vals: jax.Array) -> jax.Array:
        """⊕-accumulate ``vals`` into ``target`` at ``index`` (the
        atomic-free scatter of operators.py, semiring-generalized)."""
        at = target.at[index]
        if self.add == "plus":
            return at.add(vals, mode="drop")
        if self.add == "min":
            return at.min(vals, mode="drop")
        return at.max(vals, mode="drop")


plus_times = Semiring("plus_times", "plus", "times", 0.0, 1.0)
min_plus = Semiring("min_plus", "min", "plus", float("inf"), 0.0)
or_and = Semiring("or_and", "or", "and", 0.0, 1.0)
max_min = Semiring("max_min", "max", "min", float("-inf"), float("inf"))
plus_and = Semiring("plus_and", "plus", "and", 0.0, 1.0)

SEMIRINGS = {s.name: s for s in
             (plus_times, min_plus, or_and, max_min, plus_and)}


def get(semiring) -> Semiring:
    """Coerce a name or Semiring instance to a Semiring."""
    if isinstance(semiring, Semiring):
        return semiring
    try:
        return SEMIRINGS[semiring]
    except KeyError:
        raise ValueError(
            f"unknown semiring {semiring!r}; named semirings: "
            f"{sorted(SEMIRINGS)}") from None


def with_precision(semiring, precision: str = "fp32") -> Semiring:
    """The ``precision`` variant of a semiring (still frozen/hashable,
    so it passes through jit static args and registry dispatch exactly
    like the named instances). ``"fp32"`` returns the semiring as-is;
    ``"bf16"`` is rejected for the exact selection semirings — see the
    module docstring for the parity contract."""
    sr = get(semiring)
    if precision == sr.precision:
        return sr
    return dataclasses.replace(sr, precision=precision)
