"""Semiring sparse-linear-algebra operators (the GraphBLAST view).

Every frontier-engine hot path has an algebraic twin:

  advance + segment reduce      ↔  SpMV  y⟨m⟩ = A ⊗ x      (dense x)
  advance from a sparse frontier ↔ SpMSpV y⟨m⟩ = A ⊗ x     (sparse x)
  B batched advances             ↔  SpMM  Y⟨m⟩ = A ⊗ X     (dense n×k X)
  segmented intersection         ↔  masked SpGEMM  C⟨M⟩ = A ⊗ B

The three dense-output products are first-class backend-registry ops
(``"spmv"``, ``"spmm"``, ``"mxm"`` in ``repro.core.backend``): this
module registers the XLA implementations (gather + semiring segment
reduce — XLA fuses the ⊗ functor into the sweep) and
``repro.kernels.ops`` registers the Pallas ones (the fused
masked-semiring ELL row kernel + LB-expansion probe). The public
wrappers below resolve Graph vs raw-CSR inputs, masks/complement, and
static ELL metadata, then dispatch.

Registry contracts (shared by both backends):

  "spmv" (offsets, indices, values|None, x (nx,), sr, ell_width, mask|None,
          row_seg|None, over_pos|None, over_row|None)
         → y (n,)  f32
  "spmm" (offsets, indices, values|None, x (nx,k), sr, ell_width, mask|None,
          row_seg|None)
         → y (n,k) f32

  "mxm"  (a_off, a_idx, a_vals|None, bt_off, bt_idx, bt_vals|None,
          base (E,), probe_rows (E,), sr, cap_out)
         → c (E,) f32   — the dot formulation over a mask pattern;
           ``base`` rows of the expansion structure are LB-expanded
           (row-tiled by the advance kernels), each emitted column id is
           probed in ``probe_rows`` of the B-transpose structure, and
           matches are ⊗-combined and ⊕-reduced per mask edge.

``row_seg`` is the optional loop-invariant edge→row map ((m,) int32,
``Graph.row_seg`` / ``Graph.csc_row_seg`` build-time metadata). The XLA
sweep's segment reduce needs it every call; deriving it in-loop by
binary search was the single largest per-iteration cost of the PageRank
sweep. When absent (raw-CSR callers, sharded stacked slices) providers
derive it with the O(m) cumsum formulation — bit-identical, still ~3×
cheaper than searchsorted.

Masked-out rows carry the semiring's ⊕-identity. ``values=None`` means a
structural (pattern-only) matrix: every stored entry is the ⊗-identity.

The same three ops carry ``placement="sharded"`` providers
(``repro.core.distributed``) that accept the (p, …) stacked per-device
slices of a ``ShardedGraph`` and run under shard_map; the public
wrappers route a ShardedGraph operand there automatically, and results
bit-match the single-device sweeps.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as B
from repro.core import operators as _ops
from repro.core import storage as St
from repro.core.graph import Graph

from . import semiring as S
from .semiring import Semiring, plus_times

# ---------------------------------------------------------------------------
# XLA implementations
# ---------------------------------------------------------------------------


def _row_segments(offsets: jax.Array, m: int) -> jax.Array:
    from repro.core.graph import row_segments_of
    return row_segments_of(offsets, m)


def _apply_mask(y: jax.Array, mask: Optional[jax.Array], zero: float):
    if mask is None:
        return y
    m = mask if y.ndim == 1 else mask[:, None]
    return jnp.where(m, y, zero)


def hybrid_ell_reduce(offsets, indices, values, x, sr: Semiring,
                      width: int, *, over_pos=None, over_row=None,
                      row_seg=None, edge_valid=None):
    """Shared hybrid row reduction: y[i] = ⊕ over row i's edges of
    (values ⊗ x[dst]) — the XLA twin of the Pallas ELL kernel, designed
    for *placement-stable bits* (the PR-4 discipline: explicit
    elementwise dataflow only, no compiler-grouped reduces, no
    division):

      * the first ``width`` edges of each row land in a rank-aligned
        (rows, pow2(width)) block (pure gathers) and are ⊕-folded by an
        EXPLICIT pairwise halving tree — the grouping is the dataflow,
        so the single-device sweep and every shard_map row slice compute
        identical bits for identical rows;
      * edges past ``width`` (the heavy-tail remainder) continue the
        fold through the serial ⊕-scatter, in ascending edge order —
        either compacted build-time lists (``over_pos``/``over_row``,
        the fast single-device path: only ~the 95th-percentile overflow
        pays the serial scatter) or a masked drop-scatter over all edges
        (the per-shard path, where no compacted metadata exists; same
        per-row sequence, same bits).

    ``edge_valid`` masks padding lanes of stacked per-shard edge arrays.
    Returns the raw (rows,) folded vector — callers clamp empty rows and
    apply masks.
    """
    nrows = int(offsets.shape[0]) - 1
    m = St.store_num_edges(indices)
    width = max(int(width), 1)
    wp = 1
    while wp < width:
        wp *= 2
    starts = offsets[:-1]
    deg = offsets[1:] - offsets[:-1]
    lanes = jnp.arange(wp, dtype=jnp.int32)
    e = jnp.minimum(starts[:, None] + lanes[None, :], max(m - 1, 0))
    lane_ok = lanes[None, :] < jnp.minimum(deg, width)[:, None]
    # gather_cols decodes the touched (row, lane) slots in place when the
    # store is delta-encoded — the ELL block never materializes dense ids
    xi = x[jnp.clip(St.gather_cols(indices, e), 0,
                    x.shape[0] - 1)]                  # pad ids may be -1
    prod = sr.round_prod(xi) if values is None else sr.mul_op(values[e], xi)
    prod = jnp.where(lane_ok, prod, sr.zero)
    k = wp
    while k > 1:                      # explicit halving: grouping fixed
        k //= 2
        prod = sr.add_op(prod[:, :k], prod[:, k:2 * k])
    y = prod[:, 0]
    if over_pos is not None:
        if int(over_pos.shape[0]):
            ov = x[St.gather_cols(indices, over_pos)]
            ov = (sr.round_prod(ov) if values is None
                  else sr.mul_op(values[over_pos], ov))
            y = sr.scatter_accum(y, over_row, ov)
        return y
    # masked drop-scatter fallback (per-shard): rank ≥ width continues
    # the fold, everything else targets the drop slot
    seg = _row_segments(offsets, m) if row_seg is None else row_seg
    rank = jnp.arange(m, dtype=jnp.int32) - starts[seg]
    over = rank >= width
    if edge_valid is not None:
        over = over & edge_valid
    ov = x[jnp.clip(St.decode_cols(indices), 0, x.shape[0] - 1)]
    ov = sr.round_prod(ov) if values is None else sr.mul_op(values, ov)
    return sr.scatter_accum(y, jnp.where(over, seg, nrows), ov)


def fold_products(offsets, prods, sr: Semiring, width: int, *,
                  row_seg=None, edge_valid=None):
    """``hybrid_ell_reduce``'s product-level twin for pre-multiplied
    edge buffers: fold an (m,) per-slot product vector into per-row
    values with the IDENTICAL dataflow — same rank-aligned ELL gather,
    same explicit pairwise halving tree, same ascending-order overflow
    drop-scatter. A caller that ⊕-merged per-edge products across
    devices first (the 2-D vertex cut's pre-fold product exchange,
    where disjoint slot ownership makes the merge identity-only) then
    lands on the same bits as the single-device sweep for EVERY
    semiring. ``prods`` is indexed by CSR slot; slots past
    ``offsets[-1]`` are padding that ``edge_valid`` masks off the
    overflow scatter (the ELL lanes never touch them)."""
    nrows = int(offsets.shape[0]) - 1
    m = int(prods.shape[0])
    width = max(int(width), 1)
    wp = 1
    while wp < width:
        wp *= 2
    starts = offsets[:-1]
    deg = offsets[1:] - offsets[:-1]
    lanes = jnp.arange(wp, dtype=jnp.int32)
    e = jnp.minimum(starts[:, None] + lanes[None, :], max(m - 1, 0))
    lane_ok = lanes[None, :] < jnp.minimum(deg, width)[:, None]
    p = jnp.where(lane_ok, prods[e], sr.zero)
    k = wp
    while k > 1:                      # explicit halving: grouping fixed
        k //= 2
        p = sr.add_op(p[:, :k], p[:, k:2 * k])
    y = p[:, 0]
    seg = _row_segments(offsets, m) if row_seg is None else row_seg
    rank = jnp.arange(m, dtype=jnp.int32) - starts[seg]
    over = rank >= width
    if edge_valid is not None:
        over = over & edge_valid
    return sr.scatter_accum(y, jnp.where(over, seg, nrows), prods)


@B.register("spmv", B.XLA, encodings=("dense", "delta"))
def _spmv_xla(offsets, indices, values, x, sr: Semiring, ell_width, mask,
              row_seg=None, over_pos=None, over_row=None):
    """Hybrid ELL-tree + overflow-scatter sweep when the Graph's static
    width metadata is available (the hot path — PageRank's loop lives
    here); gather + semiring segment reduce otherwise (raw-CSR callers,
    bit-identical to the pre-refactor pagerank sweep). ``indices`` may
    be a delta-encoded store: the ELL block decodes per touched slot
    (gather_cols); the whole-edge fallback decodes vectorized."""
    n = int(offsets.shape[0]) - 1
    m = St.store_num_edges(indices)
    if ell_width is not None and m > 0 and over_pos is not None:
        y = hybrid_ell_reduce(offsets, indices, values, x, sr,
                              int(ell_width), over_pos=over_pos,
                              over_row=over_row)
    else:
        seg = _row_segments(offsets, m) if row_seg is None else row_seg
        xv = x[St.decode_cols(indices)]
        prod = sr.round_prod(xv) if values is None else sr.mul_op(values, xv)
        y = sr.segment_reduce(prod, seg, n, indices_are_sorted=True)
    deg = offsets[1:] - offsets[:-1]
    y = jnp.where(deg > 0, y, sr.zero)  # empty rows ⇒ ⊕-identity
    return _apply_mask(y, mask, sr.zero).astype(jnp.float32)


@B.register("spmm", B.XLA, encodings=("dense", "delta"))
def _spmm_xla(offsets, indices, values, x, sr: Semiring, ell_width, mask,
              row_seg=None):
    del ell_width
    n = int(offsets.shape[0]) - 1
    m = St.store_num_edges(indices)
    seg = _row_segments(offsets, m) if row_seg is None else row_seg
    xv = x[St.decode_cols(indices)]                   # (m, k)
    prod = (sr.round_prod(xv) if values is None
            else sr.mul_op(values[:, None], xv))
    y = sr.segment_reduce(prod, seg, n, indices_are_sorted=True)
    deg = offsets[1:] - offsets[:-1]
    y = jnp.where((deg > 0)[:, None], y, sr.zero)
    return _apply_mask(y, mask, sr.zero).astype(jnp.float32)


def _locate_xla(haystack: jax.Array, lo: jax.Array, hi: jax.Array,
                needles: jax.Array) -> jax.Array:
    """Position-returning probe (−1 when absent): the ``locate`` flavour
    of the shared SmallLarge binary search in core.operators."""
    return _ops._searchsorted_segment(haystack, lo, hi, needles,
                                      locate=True)


def make_mxm_impl(expand, locate):
    """Build a masked-SpGEMM registry impl from an LB-expansion hot path
    (the "advance" contract) and a position-returning probe. The same
    machinery serves both backends: xla passes the jnp expansion and
    search, kernels.ops passes the fused Pallas kernels."""

    def impl(a_off, a_idx, a_vals, bt_off, bt_idx, bt_vals,
             base, probe_rows, sr: Semiring, cap_out: int):
        e = int(base.shape[0])
        sizes = (a_off[base + 1] - a_off[base]).astype(jnp.int32)
        # row-tiled expansion of the mask edges' expansion-side rows: the
        # emitted column id IS the probe needle, in_pos the mask edge.
        _, needles, eid, pair, _, valid, _ = expand(
            a_off, a_idx, base, sizes, cap_out)
        rows = probe_rows[pair]
        pos = locate(bt_idx, bt_off[rows], bt_off[rows + 1], needles)
        found = (pos >= 0) & valid
        sv = (jnp.float32(sr.one) if a_vals is None
              else a_vals[jnp.clip(eid, 0, int(a_idx.shape[0]) - 1)])
        lv = (jnp.float32(sr.one) if bt_vals is None
              else bt_vals[jnp.clip(pos, 0, int(bt_idx.shape[0]) - 1)])
        prod = jnp.where(found, sr.mul_op(sv, lv), sr.zero)
        c = sr.segment_reduce(prod.astype(jnp.float32), pair, e,
                              indices_are_sorted=True)
        return jnp.where(sizes > 0, c, sr.zero).astype(jnp.float32)

    return impl


_mxm_xla = B.register("mxm", B.XLA)(
    make_mxm_impl(_ops._advance_xla, _locate_xla))


# ---------------------------------------------------------------------------
# public wrappers
# ---------------------------------------------------------------------------


def _csr_side(a, transpose: bool):
    """Resolve (offsets, store, values, ell_width, row_seg) from a
    Graph / ShardedGraph (CSR or its CSC mirror) or a raw (offsets,
    indices, values) triple. The column slot is the graph's *native*
    store (dense at the plan dtype, or the EncodedCols delta pytree) —
    wrappers run it through ``B.coerce_store`` for the provider that
    will execute. A ShardedGraph yields the (p, …) stacked per-device
    slices the sharded registry providers understand (its per-shard
    edge→row maps are derived locally, so row_seg is None). A
    Sharded2DGraph yields (R, C, …) blocked arrays with Blocks2D column
    stores for the 2d providers."""
    from repro.core.partition import Sharded2DGraph, ShardedGraph
    if isinstance(a, (Graph, ShardedGraph, Sharded2DGraph)):
        if transpose:
            if not a.has_csc:
                raise ValueError("transpose=True needs the CSC mirror "
                                 "(build_csc=True)")
            return (a.csc_offsets, a.csc_store, a.csc_edge_values,
                    a.csc_ell_width, a.csc_row_seg, a.csc_over_pos,
                    a.csc_over_row)
        return (a.row_offsets, a.col_store, a.edge_values, a.ell_width,
                a.row_seg, a.over_pos, a.over_row)
    if transpose:
        raise ValueError(
            "a raw (offsets, indices, values) triple carries no CSC "
            "mirror to transpose through; pass a Graph, or pass the "
            "transposed structure explicitly (for mxm: b_transpose=True "
            "with bᵀ's CSR)")
    offsets, indices, values = a
    return offsets, indices, values, None, None, None, None


def _resolve_mask(mask, complement: bool):
    if mask is None:
        if complement:
            raise ValueError("complement=True requires a mask")
        return None
    mask = jnp.asarray(mask)
    if mask.dtype != jnp.bool_:
        mask = mask.astype(bool)
    return ~mask if complement else mask


def _ell_or_raise(ell_width, meta, bk: str):
    if ell_width is None:
        ell_width = meta
    if ell_width is None and bk == B.PALLAS:
        raise ValueError(
            "the pallas backend needs a static ELL width; build the Graph "
            "via Graph.from_csr / from_edge_list (width is computed once "
            "at build time) or pass ell_width= explicitly")
    return None if ell_width is None else int(ell_width)


def spmv(a, x, *, semiring=plus_times, mask=None, complement: bool = False,
         transpose: bool = False, structural: bool = False,
         ell_width: Optional[int] = None, backend: Optional[str] = None,
         use_kernel: Optional[bool] = None,
         placement: Optional[str] = None,
         precision: str = "fp32") -> jax.Array:
    """Masked semiring SpMV: ``y⟨mask⟩ = A ⊗ x`` (y (n,), x dense).

    ``transpose=True`` multiplies by Aᵀ via the CSC mirror (the pull /
    PageRank direction). ``structural=True`` ignores stored edge values
    (every entry is the ⊗-identity). ``mask`` is a (n,) output row mask;
    ``complement=True`` flips it. Masked-out rows hold the ⊕-identity.
    ``a`` may be a ``ShardedGraph`` (``partition_1d(...).shard(mesh)``):
    the sweep then runs row-partitioned under shard_map and bit-matches
    the single-device result. ``precision="bf16"`` rounds the ⊗ operands
    to bfloat16 (fp32 accumulate); only the plus-accumulating semirings
    admit it (see semiring.with_precision).
    """
    sr = S.with_precision(semiring, precision)
    bk = B.resolve(backend, use_kernel)
    pl, ctx = B.resolve_graph_placement(a, placement)
    off, idx, vals, meta_w, seg, opos, orow = _csr_side(a, transpose)
    idx = B.coerce_store("spmv", bk, pl, store=idx)
    if structural:
        vals = None
    w = _ell_or_raise(ell_width, meta_w, bk if pl == B.SINGLE else B.XLA)
    m = _resolve_mask(mask, complement)
    x = jnp.asarray(x, jnp.float32)
    with ctx:
        return B.dispatch("spmv", bk, pl)(off, idx, vals, x, sr, w, m,
                                          seg, opos, orow)


def spmm(a, x, *, semiring=plus_times, mask=None, complement: bool = False,
         transpose: bool = False, structural: bool = False,
         ell_width: Optional[int] = None, backend: Optional[str] = None,
         use_kernel: Optional[bool] = None,
         placement: Optional[str] = None,
         precision: str = "fp32") -> jax.Array:
    """Dense-accumulator semiring SpMM: ``Y⟨mask⟩ = A ⊗ X`` (X (nx, k)).

    The whole-frontier batched product: each column of X is one lane
    (a reachability source, a label block). Same mask/transpose/
    structural/placement/precision semantics as ``spmv``.
    """
    sr = S.with_precision(semiring, precision)
    bk = B.resolve(backend, use_kernel)
    pl, ctx = B.resolve_graph_placement(a, placement)
    off, idx, vals, meta_w, seg, _, _ = _csr_side(a, transpose)
    idx = B.coerce_store("spmm", bk, pl, store=idx)
    if structural:
        vals = None
    w = _ell_or_raise(ell_width, meta_w, bk if pl == B.SINGLE else B.XLA)
    m = _resolve_mask(mask, complement)
    x = jnp.asarray(x, jnp.float32)
    if x.ndim != 2:
        raise ValueError(f"spmm needs a dense (n, k) operand, got {x.shape}")
    with ctx:
        return B.dispatch("spmm", bk, pl)(off, idx, vals, x, sr, w, m,
                                          seg)


def spmsv(a, ids, xvals=None, *, semiring=plus_times, mask=None,
          complement: bool = False, structural: bool = False,
          cap_out: Optional[int] = None, backend: Optional[str] = None,
          use_kernel: Optional[bool] = None) -> jax.Array:
    """Sparse-vector semiring product (SpMSpV, push direction):
    ``y⟨mask⟩[v] = ⊕_{u active} x[u] ⊗ A[u, v]`` with x given sparsely as
    frontier ``ids`` (−1 ⇒ dead lane) and per-lane ``xvals`` (None ⇒
    ⊗-identity). This is exactly an advance whose functor is ⊗ and whose
    scatter is ⊕ — it dispatches the expansion through the "advance"
    registry entry, so the fused Pallas kernel serves the algebra too.
    Output is dense (n,) — the direction-optimization contract: callers
    pick spmsv (push) for small frontiers and spmv (pull) for large ones.
    """
    from repro.core.partition import Sharded2DGraph, ShardedGraph
    if isinstance(a, (ShardedGraph, Sharded2DGraph)):
        raise ValueError(
            "spmsv has no sharded/2d provider (the push expansion is "
            "frontier-shaped); use spmv/spmm on the partitioned graph, "
            "or run spmsv on the unpartitioned source graph")
    sr = S.get(semiring)
    bk = B.resolve(backend, use_kernel)
    off, idx, vals, _, _, _, _ = _csr_side(a, transpose=False)
    # spmsv's expansion runs the "advance" hot path, whose providers
    # decode the delta stream natively — coerce against that op
    idx = B.coerce_store("advance", bk, B.SINGLE, store=idx)
    if structural:
        vals = None
    n = int(off.shape[0]) - 1
    m = St.store_num_edges(idx)
    ids = jnp.asarray(ids, jnp.int32)
    valid_in = ids >= 0
    base = jnp.where(valid_in, ids, 0)
    deg = off[base + 1] - off[base]
    sizes = jnp.where(valid_in, deg, 0).astype(jnp.int32)
    if cap_out is None:
        # duplicate frontier ids expand their row once PER lane, so a
        # plain m default under-counts; outside jit (the wrapper's
        # normal life) size the expansion exactly — host-side capacity
        # planning, like every frontier cap. Under jit nothing concrete
        # is available and a guessed cap would truncate silently, so
        # demand an explicit static one.
        if isinstance(ids, jax.core.Tracer) or \
                isinstance(off, jax.core.Tracer):
            raise ValueError(
                "spmsv under jit needs an explicit static cap_out "
                "(the exact default sizing is host-side; a guessed "
                "capacity would silently truncate duplicate-id "
                "expansions)")
        ro = np.asarray(off)
        live = np.asarray(ids)
        live = live[live >= 0]
        cap = int((ro[live + 1] - ro[live]).sum()) if len(live) else 1
    else:
        cap = int(cap_out)
    expand = B.dispatch("advance", bk, B.SINGLE)
    _, dst, eid, in_pos, _, exp_valid, _ = expand(off, idx, base, sizes,
                                                  max(cap, 1))
    sv = (jnp.float32(sr.one) if xvals is None
          else jnp.asarray(xvals, jnp.float32)[in_pos])
    av = (jnp.float32(sr.one) if vals is None
          else vals[jnp.clip(eid, 0, max(m - 1, 0))])
    prod = jnp.where(exp_valid, sr.mul_op(sv, av), sr.zero)
    tgt = jnp.where(exp_valid, dst, n)            # n ⇒ dropped
    y = jnp.full((n,), sr.zero, jnp.float32)
    y = sr.scatter_accum(y, tgt, prod.astype(jnp.float32))
    return _apply_mask(y, _resolve_mask(mask, complement), sr.zero)


def mxm(a, b, mask, *, semiring=plus_times, b_transpose: bool = False,
        structural: bool = False, cap_out: Optional[int] = None,
        backend: Optional[str] = None,
        use_kernel: Optional[bool] = None,
        placement: Optional[str] = None) -> jax.Array:
    """Row-tiled masked semiring SpGEMM (dot formulation):
    ``C⟨M⟩ = A ⊗ B`` computed only at the mask pattern.

    ``mask`` is the nnz pattern of M as ``(src_ids, dst_ids)`` int
    arrays; the result is ``c (E,)`` with
    ``c[e] = ⊕_w A[src_e, w] ⊗ B[w, dst_e]``.

    ``b_transpose=True`` computes ``A ⊗ bᵀ`` — column ``dst_e`` of B is
    then row ``dst_e`` of b's CSR (the triangle-counting case
    ``C = A ⊗ Aᵀ``); otherwise b's CSC mirror provides column access.

    When both operands share one structure (``C = A ⊗ Aᵀ``), each mask
    edge expands its *smaller* endpoint row and probes the larger — the
    SmallLarge workload reduction of paper §4.3, sound here because the
    dot is symmetric in the two rows and every supported ⊗ commutes.
    Capacity planning (``cap_out``) is host-side, like every frontier
    capacity in this engine; call the wrapper outside jit.

    Sharded: pass a ``ShardedGraph`` as ``a`` (the expansion side is
    row-partitioned over the mesh) with a plain Graph as ``b`` (the
    probe side stays replicated — the 1-D SpGEMM split). The SmallLarge
    swap is disabled there (the sides live in different layouts).
    """
    from repro.core.partition import Sharded2DGraph, ShardedGraph
    sr = S.get(semiring)
    bk = B.resolve(backend, use_kernel)
    pl, ctx = B.resolve_graph_placement(a, placement)
    if isinstance(b, (ShardedGraph, Sharded2DGraph)):
        # the probe side is ALWAYS replicated (the 1-D SpGEMM split):
        # stacked per-device slices can neither be probed globally nor
        # feed the single-device path's degree planning
        raise ValueError(
            "mxm keeps the probe side (b) replicated; pass the "
            "expansion side (a) as a ShardedGraph and b as a plain "
            "Graph (e.g. pg.source)")
    a_off, a_idx, a_vals = _csr_side(a, transpose=False)[:3]
    bt_off, bt_idx, bt_vals = _csr_side(b, transpose=not b_transpose)[:3]
    # decide shared-structure on the native stores (identity), THEN
    # coerce — decoding twice would break the `is` check and the
    # SmallLarge swap with it
    shared_store = (a_off is bt_off) and (a_idx is bt_idx)
    a_idx = B.coerce_store("mxm", bk, pl, store=a_idx)
    bt_idx = a_idx if shared_store else B.coerce_store("mxm", bk, pl,
                                                       store=bt_idx)
    if structural:
        a_vals = bt_vals = None
    msrc = np.asarray(mask[0], np.int32)
    mdst = np.asarray(mask[1], np.int32)
    if pl == B.SHARDED:
        # stacked (p, vpp+1) offsets → global out-degrees, pads → 0
        deg_all = np.diff(np.asarray(a_off), axis=1).reshape(-1)
        deg_a = deg_all[:a.num_vertices][msrc]
    elif pl == B.TWOD:
        # (R, C, vpr+1) block offsets: a row's global out-degree is the
        # SUM of its per-column-block degrees
        deg_all = np.diff(np.asarray(a_off), axis=2).sum(axis=1) \
                    .reshape(-1)
        deg_a = deg_all[:a.num_vertices][msrc]
    else:
        deg_a = np.diff(np.asarray(a_off))[msrc]
    deg_b = np.diff(np.asarray(bt_off))[mdst]
    shared = shared_store
    if shared:
        a_small = deg_a <= deg_b
        base = np.where(a_small, msrc, mdst)
        probe_rows = np.where(a_small, mdst, msrc)
        cap = int(np.minimum(deg_a, deg_b).sum())
    else:
        base, probe_rows = msrc, mdst
        cap = int(deg_a.sum())
    cap = max(cap, 1) if cap_out is None else int(cap_out)
    impl = B.dispatch("mxm", bk, pl)
    mesh_key = ((a.mesh, a.axis) if pl == B.SHARDED
                else (a.mesh, a.axes) if pl == B.TWOD else None)
    with ctx:
        run = _jit_mxm(impl, sr, cap, mesh_key)
        return run(a_off, a_idx, a_vals, bt_off, bt_idx, bt_vals,
                   jnp.asarray(base, jnp.int32),
                   jnp.asarray(probe_rows, jnp.int32))


@functools.lru_cache(maxsize=64)
def _jit_mxm(impl, sr: Semiring, cap: int, mesh_key=None):
    """One cached jit wrapper per (impl, semiring, capacity, mesh) —
    repeated mxm calls of the same shape reuse one trace. ``mesh_key``
    keys sharded traces by their (mesh, axis) so a cached program can
    never run against the wrong mesh."""
    return jax.jit(lambda *args: impl(*args, sr, cap))
