"""Host-side span tracing: phase timing as Chrome trace events.

The device-side telemetry (``obs.telemetry``) answers "what did the BSP
loop do per iteration"; this module answers "where did the wall clock
go" — graph build, partition, shard, compile, dispatch, validate — as
nested spans exportable to the Chrome trace-event JSON format (load the
file at ``ui.perfetto.dev`` or ``chrome://tracing``).

  * ``span("compile", args={"primitive": "bfs"})`` — a context manager
    timing its block with ``time.perf_counter_ns``. Spans nest; each
    records (name, category, start, duration, thread) into the ambient
    ``SpanRegistry``.
  * Async-dispatch fencing: JAX returns before the device finishes, so
    a span that should measure execution must fence. Pass the result
    pytree via ``sync=``: ``jax.block_until_ready`` runs INSIDE the
    span, immediately before the end stamp.
  * ``export_chrome_trace(path)`` writes ``{"traceEvents": [...]}``
    with complete ("ph": "X") events, microsecond timestamps.
  * ``REPRO_TRACE_JAX=1`` additionally wraps every span in
    ``jax.profiler.TraceAnnotation`` so span names land inside a
    ``jax.profiler.trace`` capture (the opt-in bridge; a missing or
    drifted profiler API degrades to host-only spans, never an error).

Span taxonomy (DESIGN.md §10): category "setup" for build/partition/
shard, "compile" for first-trace runs, "dispatch" for steady-state
execution, "validate" for oracle checks, "serve" for serving-loop
phases. The registry is per-process and explicitly clearable
(``reset()``) so drivers emit one file per run.
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


@dataclass
class SpanEvent:
    name: str
    category: str
    start_ns: int
    duration_ns: int
    thread_id: int
    args: Dict[str, Any] = field(default_factory=dict)


@dataclass
class SpanRegistry:
    """Accumulates finished spans; thread-safe appends."""

    events: List[SpanEvent] = field(default_factory=list)
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False)

    def add(self, ev: SpanEvent) -> None:
        with self._lock:
            self.events.append(ev)

    def reset(self) -> None:
        with self._lock:
            self.events.clear()

    def total_ns(self, name: str) -> int:
        return sum(e.duration_ns for e in self.events if e.name == name)

    def to_chrome(self) -> dict:
        """The Chrome trace-event JSON object (Perfetto-loadable)."""
        pid = os.getpid()
        return {
            "displayTimeUnit": "ms",
            "traceEvents": [
                {"name": e.name, "cat": e.category, "ph": "X",
                 "pid": pid, "tid": e.thread_id,
                 "ts": e.start_ns / 1e3, "dur": e.duration_ns / 1e3,
                 "args": e.args}
                for e in self.events
            ],
        }


_registry = SpanRegistry()


def registry() -> SpanRegistry:
    """The ambient per-process registry ``span()`` records into."""
    return _registry


def reset() -> None:
    _registry.reset()


def _jax_annotation(name: str):
    """The opt-in ``jax.profiler`` bridge: a TraceAnnotation context for
    ``name`` when REPRO_TRACE_JAX is set and the API exists, else None.
    Never raises — profiler API drift degrades to host-only spans."""
    if os.environ.get("REPRO_TRACE_JAX", "") not in ("1", "true"):
        return None
    try:
        from jax.profiler import TraceAnnotation
        return TraceAnnotation(name)
    except Exception:
        return None


@contextmanager
def span(name: str, category: str = "phase",
         args: Optional[Dict[str, Any]] = None, sync=None,
         into: Optional[SpanRegistry] = None):
    """Time a block as one span. ``sync`` is a pytree fenced with
    ``jax.block_until_ready`` before the end stamp (async dispatch
    would otherwise end the span at enqueue time, not completion)."""
    reg = into if into is not None else _registry
    bridge = _jax_annotation(name)
    if bridge is not None:
        bridge.__enter__()
    t0 = time.perf_counter_ns()
    try:
        yield reg
    finally:
        if sync is not None:
            import jax
            jax.block_until_ready(sync)
        dur = time.perf_counter_ns() - t0
        if bridge is not None:
            bridge.__exit__(None, None, None)
        reg.add(SpanEvent(name=name, category=category, start_ns=t0,
                          duration_ns=dur,
                          thread_id=threading.get_ident(),
                          args=dict(args or {})))


@contextmanager
def timed_span(name: str, **kw):
    """``span`` that also hands back the duration: yields a dict whose
    ``"ms"`` key is filled at exit (for drivers that print the phase
    time as well as tracing it)."""
    out: Dict[str, float] = {}
    t0 = time.perf_counter_ns()
    with span(name, **kw):
        yield out
    out["ms"] = (time.perf_counter_ns() - t0) / 1e6


def export_chrome_trace(path: str,
                        reg: Optional[SpanRegistry] = None) -> int:
    """Write the registry as Chrome trace-event JSON; returns the event
    count (drivers log it so an empty trace is visible, not silent)."""
    reg = reg if reg is not None else _registry
    obj = reg.to_chrome()
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return len(obj["traceEvents"])
