"""One logger for the scattered diagnostics (`REPRO_LOG` level knob).

Before this module existed, runtime diagnostics were split between bare
``print`` calls (graph_serve's per-run banner, the tuner CLI) and
``warnings.warn`` (the backend registry's deprecation shim) — impossible
to silence in a serving loop and impossible to make chattier when
debugging a kernel. Everything now routes through one ``logging``
hierarchy rooted at ``"repro"``:

  * ``get_logger("graph_serve")`` → the ``repro.graph_serve`` logger,
    emitting to stdout as ``[graph_serve] message`` (the historical
    prefix format, so smoke-test greps keep working).
  * ``REPRO_LOG=debug|info|warning|error`` sets the root level (default
    ``info`` — the pre-existing diagnostics stay visible by default).
  * ``deprecated(msg, stacklevel=…)`` is the deprecation funnel: it
    still raises a real ``DeprecationWarning`` through ``warnings``
    (the API contract tests pin) and additionally logs at debug so a
    ``REPRO_LOG=debug`` run shows where the deprecated path fired.

Handlers are installed exactly once, on the ``repro`` root logger only,
and ``propagate`` stays on below it — applications embedding the
library can detach the default handler and attach their own.
"""
from __future__ import annotations

import logging
import os
import sys
import warnings

ENV_VAR = "REPRO_LOG"

_LEVELS = {
    "debug": logging.DEBUG,
    "info": logging.INFO,
    "warning": logging.WARNING,
    "warn": logging.WARNING,
    "error": logging.ERROR,
}

_configured = False


class _ShortNameFormatter(logging.Formatter):
    """``[graph_serve] message`` — the short (leaf) logger name in the
    historical bracket-prefix style; warnings and errors keep their
    severity visible."""

    def format(self, record: logging.LogRecord) -> str:
        leaf = record.name.rsplit(".", 1)[-1]
        msg = record.getMessage()
        if record.levelno >= logging.WARNING:
            return f"[{leaf}] {record.levelname}: {msg}"
        return f"[{leaf}] {msg}"


class _StdoutHandler(logging.StreamHandler):
    """Resolves ``sys.stdout`` at emit time, so streams swapped *after*
    configure (pytest capture, ``contextlib.redirect_stdout``) still
    receive the log output."""

    def __init__(self):
        super().__init__(sys.stdout)

    @property
    def stream(self):
        return sys.stdout

    @stream.setter
    def stream(self, value):            # base __init__ assigns; ignore
        pass


def _level_from_env() -> int:
    raw = os.environ.get(ENV_VAR, "").strip().lower()
    return _LEVELS.get(raw, logging.INFO)


def configure(level: int | None = None, stream=None) -> logging.Logger:
    """Install the stdout handler on the ``repro`` root logger (idempotent
    unless called with explicit arguments, which reconfigure)."""
    global _configured
    root = logging.getLogger("repro")
    if _configured and level is None and stream is None:
        return root
    for h in list(root.handlers):
        root.removeHandler(h)
    handler = (_StdoutHandler() if stream is None
               else logging.StreamHandler(stream))
    handler.setFormatter(_ShortNameFormatter())
    root.addHandler(handler)
    root.setLevel(_level_from_env() if level is None else level)
    root.propagate = False
    _configured = True
    return root


def get_logger(name: str = "") -> logging.Logger:
    """The ``repro.<name>`` logger (the bare root for ``name=""``),
    with the default stdout handler installed on first use."""
    configure()
    return logging.getLogger(f"repro.{name}" if name else "repro")


def deprecated(message: str, *, stacklevel: int = 2) -> None:
    """Deprecation funnel: a real ``DeprecationWarning`` (the testable
    API contract) plus a debug-level log line for ``REPRO_LOG=debug``
    sessions chasing where a legacy path still fires."""
    # reprolint: disable=RL005 -- this IS the funnel: the one warnings.warn the rule routes to
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel + 1)
    get_logger("deprecation").debug(message)
