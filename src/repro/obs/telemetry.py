"""Jit-safe BSP telemetry: fixed-size on-device per-iteration buffers.

Gunrock's contribution is *characterizing* traversal — per-iteration
frontier size is what justifies direction switching (paper §5.1.4) and
tiered dispatch; the Multi-GPU follow-up does the same with per-step
communication volume. This module makes that trajectory observable
without breaking the one-trace discipline every primitive is built on:

  * ``TelemetryBuffer`` is a pytree of fixed-capacity columns plus a
    cursor. It rides the ``while_loop`` carry of the enactor loops
    (``run_until`` / ``run_until_any`` grow an optional ``probe=``
    hook), each BSP step writes one row at the cursor, and writes past
    capacity drop silently (``mode="drop"``) while the cursor keeps the
    true step count — the buffer is max-iteration sized by the caller,
    so the drop path is a guard, not a policy.
  * Probes are *read-only*: a probe maps (state before, state after)
    to scalar/per-lane values and never feeds anything back into the
    step, which is what makes the telemetry=on/off bit-parity contract
    (tests/test_obs.py) hold by construction.
  * ``trim`` converts a device buffer to a host ``TelemetryTrace`` —
    numpy columns truncated to the recorded step count, with per-lane
    valid lengths when the loop was batched.
  * For the distributed placements, ``distributed_trace`` builds the
    same trace shape from the PR 7 analytic comm model
    (``exchange_bytes_per_step``) plus — for BFS — level sizes
    recovered exactly from the result labels (level t's frontier is
    ``|{v : labels[v] == t}|``), so sharded/2d runs report per-step
    exchange bytes without instrumenting the shard_map interior.

Buffer layout (documented for DESIGN.md §10): every column is a
``(capacity, *tail)`` array, ``capacity`` = the loop's max_iter bound;
scalar-per-step columns have an empty tail, per-lane columns a ``(B,)``
tail. The cursor is a single int32 — one extra carry slot per loop.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class TelemetryBuffer:
    """Fixed-capacity per-iteration telemetry columns + a step cursor.

    A pytree (column names live in the static treedef aux, so two
    buffers with the same spec share one trace), safe to carry through
    ``jax.lax.while_loop``.
    """

    cursor: jax.Array                 # () int32 — true steps recorded
    data: Dict[str, jax.Array]        # name -> (capacity, *tail) column

    def tree_flatten(self):
        names = tuple(self.data)
        return (self.cursor, tuple(self.data[k] for k in names)), names

    @classmethod
    def tree_unflatten(cls, names, children):
        cursor, cols = children
        return cls(cursor=cursor, data=dict(zip(names, cols)))

    @classmethod
    def make(cls, capacity: int,
             spec: Mapping[str, Tuple[Tuple[int, ...], object]]
             ) -> "TelemetryBuffer":
        """Zero-filled buffer for ``capacity`` steps. ``spec`` maps a
        column name to ``(tail_shape, dtype)`` — ``()`` tail for one
        scalar per step, ``(B,)`` for a per-lane value."""
        capacity = max(int(capacity), 1)
        data = {name: jnp.zeros((capacity,) + tuple(tail), dtype)
                for name, (tail, dtype) in spec.items()}
        return cls(cursor=jnp.int32(0), data=data)

    @property
    def capacity(self) -> int:
        for col in self.data.values():
            return int(col.shape[0])
        return 0

    def record(self, **values) -> "TelemetryBuffer":
        """Write one row at the cursor (traced). Unknown names raise at
        trace time; missing columns keep their zeros. Writes past
        capacity drop; the cursor still counts them."""
        unknown = set(values) - set(self.data)
        if unknown:
            raise KeyError(f"telemetry columns not in spec: "
                           f"{sorted(unknown)}")
        i = self.cursor
        data = dict(self.data)
        for name, val in values.items():
            col = data[name]
            val = jnp.asarray(val, col.dtype)
            data[name] = col.at[i].set(val, mode="drop")
        return TelemetryBuffer(cursor=i + 1, data=data)


class TelemetryTrace:
    """Host-side trimmed trajectory: numpy columns over ``steps`` BSP
    iterations, optionally with per-lane valid lengths.

    ``columns[name]`` is ``(steps,)`` or ``(steps, B)``; entries of a
    per-lane column past ``lane_steps[b]`` are frozen-lane repeats (the
    batched loop computes every lane every wall-clock step)."""

    def __init__(self, columns: Dict[str, np.ndarray], steps: int,
                 lane_steps: Optional[np.ndarray] = None):
        self.steps = int(steps)
        self.columns = {k: np.asarray(v) for k, v in columns.items()}
        self.lane_steps = (None if lane_steps is None
                           else np.asarray(lane_steps))

    def __getitem__(self, name: str) -> np.ndarray:
        return self.columns[name]

    def __contains__(self, name: str) -> bool:
        return name in self.columns

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(sorted(self.columns))

    def lane(self, b: int) -> "TelemetryTrace":
        """One lane's trajectory: per-lane columns sliced at lane ``b``
        and trimmed to that lane's own iteration count."""
        steps = (self.steps if self.lane_steps is None
                 else int(self.lane_steps[b]))
        cols = {k: (v[:steps, b] if v.ndim > 1 else v[:steps])
                for k, v in self.columns.items()}
        return TelemetryTrace(cols, steps)

    def format_table(self, columns: Optional[Tuple[str, ...]] = None,
                     prefix: str = "") -> str:
        """Fixed-width per-iteration table. A column named
        ``direction`` renders push/pull; multi-lane columns render
        lane 0 (use ``.lane(b)`` first for another lane)."""
        names = list(columns) if columns else list(self.names)
        names = [n for n in names if n in self.columns]
        widths = {n: max(len(n), 9) for n in names}
        head = prefix + "iter  " + "  ".join(
            f"{n:>{widths[n]}s}" for n in names)
        lines = [head]
        for it in range(self.steps):
            cells = []
            for n in names:
                col = self.columns[n]
                v = col[it, 0] if col.ndim > 1 else col[it]
                if n == "direction":
                    v = "pull" if int(v) else "push"
                cells.append(f"{v:>{widths[n]}}")
            lines.append(prefix + f"{it + 1:4d}  " + "  ".join(cells))
        return "\n".join(lines)


def trim(buf: TelemetryBuffer,
         lane_steps=None) -> TelemetryTrace:
    """Device buffer → host trace, truncated to the recorded step count
    (writes past capacity were dropped, so the usable region is
    ``min(cursor, capacity)``). ``lane_steps`` is the per-lane iteration
    count from ``run_until_any`` when the loop was batched."""
    steps = min(int(buf.cursor), buf.capacity)
    cols = {k: np.asarray(v)[:steps] for k, v in buf.data.items()}
    return TelemetryTrace(cols, steps,
                          None if lane_steps is None
                          else np.asarray(lane_steps))


def distributed_trace(pg, primitive: str, iterations,
                      labels=None, tiles: Optional[int] = None
                      ) -> TelemetryTrace:
    """Telemetry trace for a distributed (sharded/2d) run, built from
    the PR 7 analytic comm model rather than in-loop instrumentation:
    ``exchange_bytes`` is the per-device bytes each BSP step moved
    (``core.distributed.exchange_bytes_per_step`` — constant per step
    by construction of the dense bitmask/vector exchanges), and for BFS
    the per-step ``frontier`` column is recovered exactly from the
    result labels (iteration t discovers the depth-t level)."""
    from repro.core import distributed as D
    steps = max(int(iterations), 0)
    kwargs = {} if tiles is None else {"tiles": tiles}
    per_step = D.exchange_bytes_per_step(pg, primitive, **kwargs)
    cols: Dict[str, np.ndarray] = {
        "exchange_bytes": np.full((steps,), per_step, np.int64)}
    if labels is not None and primitive == "bfs":
        lab = np.asarray(labels).reshape(-1)
        depth_counts = np.bincount(lab[lab >= 0],
                                   minlength=steps + 1)
        # iteration t (1-based) discovers the depth-t level; the final
        # iteration discovers nothing (that is how the loop terminates)
        frontier = np.zeros((steps,), np.int64)
        upto = min(steps, len(depth_counts) - 1)
        frontier[:upto] = depth_counts[1:upto + 1]
        cols["frontier"] = frontier
    return TelemetryTrace(cols, steps)
