"""Observability layer: BSP telemetry, span tracing, serving metrics.

Three planes, one package (DESIGN.md §10):

  * ``obs.telemetry`` — on-device per-iteration buffers riding the
    enactor while_loops (frontier size, tier, direction, overflow,
    exchange bytes), read-only by construction.
  * ``obs.tracing`` — host-side phase spans exportable as Chrome
    trace-event JSON (Perfetto), with ``block_until_ready`` fencing.
  * ``obs.metrics`` — streaming log-bucket histograms + counters/gauges
    with Prometheus text exposition for the serving driver.
  * ``obs.log`` — the one logger (``REPRO_LOG`` level knob) the
    scattered print/warnings diagnostics now route through.
"""
from repro.obs import log, metrics, telemetry, tracing
from repro.obs.log import get_logger
from repro.obs.metrics import (Histogram, Metrics, latency_summary,
                               quantile)
from repro.obs.telemetry import (TelemetryBuffer, TelemetryTrace,
                                 distributed_trace, trim)
from repro.obs.tracing import (SpanRegistry, export_chrome_trace,
                               registry, reset, span, timed_span)

__all__ = [
    "log", "metrics", "telemetry", "tracing",
    "get_logger",
    "Histogram", "Metrics", "latency_summary", "quantile",
    "TelemetryBuffer", "TelemetryTrace", "distributed_trace", "trim",
    "SpanRegistry", "export_chrome_trace", "registry", "reset", "span",
    "timed_span",
]
