"""Serving metrics: streaming log-bucket histograms, gauges, counters,
and a Prometheus text exposition.

The serving driver used to keep raw per-query latency lists and call
``np.percentile`` on them — fine for a 64-query smoke, wrong for the
millions-of-users scenario the ROADMAP targets (unbounded memory) and
subtly wrong at the other extreme (p95/p99 of <20 samples is just the
max order statistic unless quantiles interpolate AND report their
sample count). This module fixes both ends:

  * ``Histogram`` — fixed geometric (log-spaced) buckets, O(1) memory,
    exactly mergeable across streams/shards that share a layout (same
    ``lo``/``growth``/``buckets``). Quantiles linearly interpolate
    inside the winning bucket; true min/max are tracked so q=0/q=1 are
    exact and single-bucket interpolation is tight.
  * ``quantile`` / ``latency_summary`` — linear-interpolated quantiles
    over RAW samples for the small-sample reporting path, always
    alongside the sample count (`samples`), so a p99 computed from 8
    queries is visibly an 8-sample p99.
  * ``Metrics`` — a tiny label-aware registry (counter/gauge/histogram)
    with ``render()`` emitting Prometheus text format, including
    cumulative ``_bucket{le=…}`` series, ``_sum``/``_count``, and p50/
    p95/p99 gauges per label set. Counters the serving scheduler will
    need later (cache hits/misses, admission rejects) are plain
    ``counter()`` calls — the plumbing exists now so the ROADMAP's
    continuous-batching PR only has to increment.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

# default layout: 0.01 ms .. ~164 s in quarter-decade-ish steps
DEFAULT_LO = 0.01
DEFAULT_GROWTH = 2.0 ** 0.5
DEFAULT_BUCKETS = 48


def quantile(samples, q: float) -> float:
    """Linear-interpolated quantile of raw samples (the small-sample
    fix: never a bare extreme order statistic)."""
    arr = np.asarray(list(samples) if not isinstance(samples, np.ndarray)
                     else samples, dtype=np.float64)
    if arr.size == 0:
        return float("nan")
    try:
        return float(np.quantile(arr, q, method="linear"))
    except TypeError:          # numpy < 1.22 spelling
        return float(np.quantile(arr, q, interpolation="linear"))


def latency_summary(samples, prefix: str = "lat_ms") -> Dict[str, float]:
    """The serving driver's per-stream summary row: mean + interpolated
    p50/p95/p99 + the sample count they were computed from."""
    arr = np.asarray(list(samples) if not isinstance(samples, np.ndarray)
                     else samples, dtype=np.float64)
    n = int(arr.size)
    if n == 0:
        return {"samples": 0}
    return {
        "samples": n,
        f"{prefix}_mean": round(float(arr.mean()), 2),
        f"{prefix}_p50": round(quantile(arr, 0.50), 2),
        f"{prefix}_p95": round(quantile(arr, 0.95), 2),
        f"{prefix}_p99": round(quantile(arr, 0.99), 2),
    }


class Histogram:
    """Streaming histogram over fixed geometric buckets.

    Bucket i covers ``(lo·growth^(i-1), lo·growth^i]``; bucket 0 covers
    ``[0, lo]``; one overflow bucket catches everything past the top
    bound. Two histograms with the same layout merge by adding counts —
    the property that lets per-kind, per-shard, or per-process streams
    aggregate without raw samples.
    """

    def __init__(self, lo: float = DEFAULT_LO,
                 growth: float = DEFAULT_GROWTH,
                 buckets: int = DEFAULT_BUCKETS):
        assert lo > 0 and growth > 1 and buckets >= 1
        self.lo = float(lo)
        self.growth = float(growth)
        self.counts = np.zeros(buckets + 1, np.int64)  # [+overflow]
        self.total = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    @property
    def layout(self) -> Tuple[float, float, int]:
        return (self.lo, self.growth, len(self.counts) - 1)

    def bounds(self) -> np.ndarray:
        """Upper bound of each finite bucket."""
        k = len(self.counts) - 1
        return self.lo * self.growth ** np.arange(k)

    def _index(self, v: float) -> int:
        if v <= self.lo:
            return 0
        i = int(math.ceil(math.log(v / self.lo) / math.log(self.growth)))
        return min(i, len(self.counts) - 1)

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[self._index(v)] += 1
        self.total += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def observe_many(self, vs: Iterable[float]) -> None:
        for v in vs:
            self.observe(v)

    def merge(self, other: "Histogram") -> "Histogram":
        if self.layout != other.layout:
            raise ValueError(f"histogram layouts differ: {self.layout} "
                             f"vs {other.layout}")
        self.counts += other.counts
        self.total += other.total
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self

    def quantile(self, q: float) -> float:
        """Linear interpolation inside the winning bucket, clamped to
        the observed [min, max] so small-sample quantiles stay inside
        the data range instead of reporting a bucket bound."""
        if self.total == 0:
            return float("nan")
        q = min(max(q, 0.0), 1.0)
        target = q * self.total
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, target, side="left"))
        i = min(i, len(self.counts) - 1)
        bounds = self.bounds()
        hi = bounds[i] if i < len(bounds) else self.max
        lo = 0.0 if i == 0 else bounds[i - 1]
        prev = 0 if i == 0 else int(cum[i - 1])
        in_bucket = int(self.counts[i])
        frac = ((target - prev) / in_bucket) if in_bucket else 1.0
        est = lo + (hi - lo) * min(max(frac, 0.0), 1.0)
        return float(min(max(est, self.min), self.max))

    def summary(self, prefix: str = "lat_ms") -> Dict[str, float]:
        if self.total == 0:
            return {"samples": 0}
        return {
            "samples": self.total,
            f"{prefix}_mean": round(self.sum / self.total, 2),
            f"{prefix}_p50": round(self.quantile(0.50), 2),
            f"{prefix}_p95": round(self.quantile(0.95), 2),
            f"{prefix}_p99": round(self.quantile(0.99), 2),
        }


def _labelkey(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _labelstr(key: Tuple[Tuple[str, str], ...], extra: str = "") -> str:
    parts = [f'{k}="{v}"' for k, v in key]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt(v: float) -> str:
    if isinstance(v, float):
        if math.isinf(v):
            return "+Inf" if v > 0 else "-Inf"
        if v == int(v) and abs(v) < 1e15:
            return str(int(v))
        return repr(round(v, 6))
    return str(v)


@dataclass
class _Family:
    name: str
    kind: str                      # counter | gauge | histogram
    help: str
    series: Dict = field(default_factory=dict)


class Metrics:
    """Label-aware metric registry with Prometheus text rendering."""

    def __init__(self, namespace: str = "graph_serve"):
        self.namespace = namespace
        self._families: Dict[str, _Family] = {}

    def _family(self, name: str, kind: str, help: str) -> _Family:
        full = f"{self.namespace}_{name}" if self.namespace else name
        fam = self._families.get(full)
        if fam is None:
            fam = _Family(name=full, kind=kind, help=help)
            self._families[full] = fam
        elif fam.kind != kind:
            raise ValueError(f"{full} already registered as {fam.kind}")
        return fam

    def counter(self, name: str, value: float = 0.0, help: str = "",
                **labels) -> float:
        """Add ``value`` (default 0 — declares the series so the
        exposition shows it even before the first event) and return the
        running total."""
        fam = self._family(name, "counter", help)
        key = _labelkey(labels)
        fam.series[key] = fam.series.get(key, 0.0) + float(value)
        return fam.series[key]

    def gauge(self, name: str, value: float, help: str = "",
              **labels) -> None:
        fam = self._family(name, "gauge", help)
        fam.series[_labelkey(labels)] = float(value)

    def gauge_max(self, name: str, value: float, help: str = "",
                  **labels) -> None:
        """Keep the running maximum (queue-depth high-water marks)."""
        fam = self._family(name, "gauge", help)
        key = _labelkey(labels)
        fam.series[key] = max(fam.series.get(key, -math.inf),
                              float(value))

    def histogram(self, name: str, help: str = "",
                  lo: float = DEFAULT_LO, growth: float = DEFAULT_GROWTH,
                  buckets: int = DEFAULT_BUCKETS, **labels) -> Histogram:
        """The histogram for one label set (created on first touch)."""
        fam = self._family(name, "histogram", help)
        key = _labelkey(labels)
        h = fam.series.get(key)
        if h is None:
            h = Histogram(lo=lo, growth=growth, buckets=buckets)
            fam.series[key] = h
        return h

    def observe(self, name: str, value: float, help: str = "",
                **labels) -> None:
        self.histogram(name, help=help, **labels).observe(value)

    def render(self) -> str:
        """Prometheus text exposition (one block per family; histogram
        families additionally emit p50/p95/p99 quantile gauges so a
        scrape shows tail latency without server-side bucket math)."""
        lines: List[str] = []
        for fam in self._families.values():
            quant_blocks: List[str] = []
            if fam.help:
                lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for key in sorted(fam.series):
                val = fam.series[key]
                if fam.kind != "histogram":
                    lines.append(f"{fam.name}{_labelstr(key)} "
                                 f"{_fmt(float(val))}")
                    continue
                h: Histogram = val
                cum = np.cumsum(h.counts)
                for b, ub in zip(cum[:-1], h.bounds()):
                    le = 'le="%s"' % _fmt(float(ub))
                    lines.append(f"{fam.name}_bucket"
                                 f"{_labelstr(key, le)} {int(b)}")
                inf_le = 'le="+Inf"'
                lines.append(f"{fam.name}_bucket"
                             f"{_labelstr(key, inf_le)} {h.total}")
                lines.append(f"{fam.name}_sum{_labelstr(key)} "
                             f"{_fmt(h.sum)}")
                lines.append(f"{fam.name}_count{_labelstr(key)} "
                             f"{h.total}")
                for q in (0.5, 0.95, 0.99):
                    qv = h.quantile(q)
                    if math.isnan(qv):
                        continue
                    ql = 'quantile="%s"' % q
                    quant_blocks.append(
                        f"{fam.name}_quantile"
                        f"{_labelstr(key, ql)} {_fmt(qv)}")
            if quant_blocks:
                lines.append(f"# TYPE {fam.name}_quantile gauge")
                lines.extend(quant_blocks)
        return "\n".join(lines) + "\n"
