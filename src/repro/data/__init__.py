from .pipeline import SyntheticLMDataset, make_batch_for

__all__ = ["SyntheticLMDataset", "make_batch_for"]
