"""Deterministic, shardable, resumable data pipeline.

SyntheticLMDataset generates language-model token streams from a counter-
based PRNG (threefry on (seed, step, shard)) so that:
  * every (step, shard) batch is reproducible without replaying history —
    restart-from-checkpoint resumes the stream exactly (the `state()` /
    `restore()` pair is just the step counter);
  * different data shards (DP ranks / pods) draw disjoint streams;
  * no filesystem dependency (the container has no corpus). A real corpus
    would slot in behind the same interface (state = file offsets).

The synthetic stream is Zipf-distributed token ids with a deterministic
"repeat previous token block" structure so the LM loss actually decreases
(there is learnable signal), which the end-to-end example exploits.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class SyntheticLMDataset:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    step: int = 0
    zipf_a: float = 1.2

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict):
        self.step = int(state["step"])
        self.seed = int(state["seed"])

    def _tokens(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        # Zipf-ish marginal over the vocab
        z = rng.zipf(self.zipf_a, size=(self.global_batch,
                                        self.seq_len)).astype(np.int64)
        toks = (z - 1) % self.vocab
        # learnable structure: second half of every 64-token block repeats
        # the first half shifted by one
        s = self.seq_len
        blk = 64
        if s >= blk:
            t = toks.reshape(self.global_batch, -1)[:, :s - s % blk]
            t = t.reshape(self.global_batch, -1, blk)
            t[:, :, blk // 2:] = np.roll(t[:, :, :blk // 2], -1, axis=2)
            toks[:, :s - s % blk] = t.reshape(self.global_batch, -1)
        return toks.astype(np.int32)

    def next_batch(self) -> dict:
        toks = self._tokens(self.step)
        self.step += 1
        tokens = toks[:, :-1] if self.seq_len > 1 else toks
        labels = toks[:, 1:] if self.seq_len > 1 else toks
        # pad back to seq_len so shapes stay static
        pad = self.seq_len - tokens.shape[1]
        if pad:
            tokens = np.pad(tokens, ((0, 0), (0, pad)))
            labels = np.pad(labels, ((0, 0), (0, pad)),
                            constant_values=-100)
        return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()


def make_batch_for(cfg, shape: dict, kind: str, seed: int = 0) -> dict:
    """Materialize one concrete batch matching a model's input_specs —
    covers the stub-frontend archs (frames / patch embeddings /
    M-RoPE position ids)."""
    rng = np.random.default_rng(seed)
    b, s = shape["global_batch"], shape["seq_len"]
    batch = {}
    if kind in ("train", "prefill"):
        ds = SyntheticLMDataset(cfg.vocab, s, b, seed=seed)
        lm = ds.next_batch()
        batch["tokens"] = lm["tokens"]
        if kind == "train":
            batch["labels"] = lm["labels"]
    else:  # decode
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (b, 1)), jnp.int32)
    if cfg.family == "encdec" and kind in ("train", "prefill"):
        se = min(cfg.max_source_len, s)
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, se, cfg.d_model)) * 0.02,
            cfg.compute_dtype)
    if cfg.family == "vlm":
        st = 1 if kind == "decode" else s
        pos = np.broadcast_to(np.arange(st, dtype=np.int32)[None, None],
                              (3, b, st)).copy()
        batch["positions"] = jnp.asarray(pos)
        if kind != "decode":
            batch.pop("tokens", None)
            batch["input_embeds"] = jnp.asarray(
                rng.standard_normal((b, s, cfg.d_model)) * 0.02,
                cfg.compute_dtype)
    return batch
