"""Mamba2 (state-space duality / SSD) — arXiv:2405.21060.

Chunked SSD algorithm: the sequence is split into chunks of Q tokens;
within a chunk the recurrence is computed in its 'attention dual' form
(lower-triangular decay matrix — dense MXU work), and chunk boundary
states are propagated with a short `lax.scan` (S/Q steps). Decode is the
O(1)-state recurrence — which is why the SSM family owns the `long_500k`
cell (DESIGN.md §Arch-applicability).

Per-layer structure follows the reference implementation: fused in_proj →
(z, x, B, C, dt), causal depthwise conv over (x,B,C), SSD core, gated
RMSNorm, out_proj. n_groups = 1 (B/C shared across heads).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import constrain

from . import layers as L
from .api import ArchConfig, Model, count_params, maybe_scan
from .transformer import _norm, _norm_init, _remat, _vocab_padded, \
    logits_fn, xent_loss

BATCH = ("pod", "data")


def _dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    nh = d_inner // cfg.ssm_head_dim
    ds = cfg.ssm_state
    conv_dim = d_inner + 2 * ds          # x, B, C streams get the conv
    return d_inner, nh, ds, conv_dim


def mamba2_layer_init(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    d_inner, nh, ds, conv_dim = _dims(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    in_dim = 2 * d_inner + 2 * ds + nh
    return {
        "norm": _norm_init(cfg),
        "in_proj": L.truncated_normal_init(k1, (d, in_dim),
                                           1.0 / math.sqrt(d), dtype),
        "conv_w": L.truncated_normal_init(k2, (cfg.ssm_conv, conv_dim),
                                          0.5, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "gate_norm": {"scale": jnp.ones((d_inner,), jnp.float32)},
        "out_proj": L.truncated_normal_init(k3, (d_inner, d),
                                            1.0 / math.sqrt(d_inner),
                                            dtype),
    }


def _causal_conv(xbc, w, b, state=None):
    """Depthwise causal conv. xbc: (B,S,C); w: (K,C). state: (B,K-1,C)
    prefix for decode. Returns (out, new_state)."""
    k = w.shape[0]
    bsz, s, c = xbc.shape
    if state is None:
        pad = jnp.zeros((bsz, k - 1, c), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)          # (B, S+K-1, C)
    out = jnp.zeros((bsz, s, c), jnp.float32)
    for i in range(k):
        out = out + full[:, i:i + s, :].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    out = jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)
    new_state = full[:, s:s + k - 1, :] if s >= k - 1 else \
        jnp.concatenate([pad, xbc], axis=1)[:, -(k - 1):, :]
    return out, new_state


def _segsum(x):
    """exp-friendly segment sums: out[..., i, j] = Σ_{j<k<=i} x[..., k]."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, h0=None):
    """Chunked SSD scan.

    x: (B,S,H,P) inputs; dt: (B,S,H) softplus'd steps; A: (H,) negative;
    Bm/Cm: (B,S,N) shared across heads (n_groups=1).
    Returns (y: (B,S,H,P), final_state: (B,H,N,P)).
    """
    bsz, s, h, p = x.shape
    n = Bm.shape[-1]
    q = min(chunk, s)
    s_pad = -(-s // q) * q
    if s_pad != s:
        # ragged tail: pad with dt=0 steps (decay 1, zero input — identity
        # on the state); padded outputs are sliced off below.
        pad = s_pad - s
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = s_pad // q

    xc = x.reshape(bsz, nc, q, h, p)
    dtc = dt.reshape(bsz, nc, q, h)
    bc = Bm.reshape(bsz, nc, q, n)
    cc = Cm.reshape(bsz, nc, q, n)

    dA = dtc * A[None, None, None, :]                  # (B,nc,Q,H) ≤ 0
    cum = jnp.cumsum(dA, axis=2)                       # (B,nc,Q,H)

    # intra-chunk (attention dual): scores shared across heads, decay per
    # head. Lmat[b,c,h,i,j] = exp(cum_i - cum_j + dA_j ... ) via segsum.
    seg = _segsum(dA.transpose(0, 1, 3, 2))            # (B,nc,H,Q,Q)
    lmat = jnp.exp(seg)
    scores = jnp.einsum("bcqn,bckn->bcqk", cc, bc)     # (B,nc,Q,Q)
    m = scores[:, :, None] * lmat                      # (B,nc,H,Q,Q)
    dx = dtc[..., None] * xc                           # dt ⊙ x
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", m, dx)

    # chunk states: S_c = Σ_j exp(cum_end - cum_j) dt_j B_j x_j^T
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)       # (B,nc,Q,H)
    sc = jnp.einsum("bckn,bckh,bckhp->bchnp", bc, decay_end * dtc, xc)

    # inter-chunk recurrence over nc steps
    chunk_decay = jnp.exp(cum[:, :, -1, :])            # (B,nc,H)

    def scan_body(hprev, inputs):
        sc_c, dec_c = inputs                           # (B,H,N,P), (B,H)
        hnew = hprev * dec_c[..., None, None] + sc_c
        return hnew, hprev

    hinit = (jnp.zeros((bsz, h, n, p), jnp.float32) if h0 is None
             else h0.astype(jnp.float32))
    hlast, hprevs = jax.lax.scan(
        scan_body, hinit,
        (sc.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2).astype(jnp.float32)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)           # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcqn,bchnp,bcqh->bcqhp", cc, hprevs,
                         jnp.exp(cum))
    y = (y_intra + y_inter).reshape(bsz, s_pad, h, p)[:, :s]
    return y.astype(x.dtype), hlast


def ssd_decode(x, dt, A, Bm, Cm, hprev):
    """Single-token recurrence. x: (B,1,H,P); hprev: (B,H,N,P)."""
    dA = jnp.exp(dt[:, 0, :, None, None] * A[None, :, None, None])
    dBx = jnp.einsum("bn,bh,bhp->bhnp", Bm[:, 0], dt[:, 0], x[:, 0])
    hnew = hprev * dA + dBx
    y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0], hnew)
    return y[:, None].astype(x.dtype), hnew


def mamba2_block(cfg, lp, x, ssm_state=None, conv_state=None,
                 decode: bool = False):
    """x: (B,S,d). Returns (out, new_ssm_state, new_conv_state)."""
    d_inner, nh, ds, conv_dim = _dims(cfg)
    bsz, s, d = x.shape
    h = _norm(cfg, lp["norm"], x)
    zxbcdt = h @ lp["in_proj"].astype(h.dtype)
    z, xs, bm, cm, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + ds,
                 2 * d_inner + 2 * ds], axis=-1)
    xbc = jnp.concatenate([xs, bm, cm], axis=-1)
    xbc, new_conv = _causal_conv(xbc, lp["conv_w"], lp["conv_b"],
                                 conv_state)
    xs, bm, cm = jnp.split(xbc, [d_inner, d_inner + ds], axis=-1)
    xs = xs.reshape(bsz, s, nh, cfg.ssm_head_dim)
    xs = constrain(xs, BATCH, None, "model", None)
    a = -jnp.exp(lp["A_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + lp["dt_bias"][None, None, :])
    if decode:
        y, new_ssm = ssd_decode(xs, dt, a, bm, cm, ssm_state)
    else:
        y, new_ssm = ssd_chunked(xs, dt, a, bm, cm, cfg.ssm_chunk,
                                 h0=ssm_state)
    y = y + lp["D"][None, None, :, None].astype(y.dtype) * xs
    y = y.reshape(bsz, s, d_inner)
    # gated RMSNorm (mamba2's norm-before-out)
    y = L.rmsnorm(lp["gate_norm"], y * jax.nn.silu(z.astype(jnp.float32)
                                                   ).astype(y.dtype),
                  cfg.norm_eps)
    out = y @ lp["out_proj"].astype(y.dtype)
    return x + out, new_ssm, new_conv


def init_mamba2(cfg: ArchConfig, key):
    vp = _vocab_padded(cfg)
    keys = jax.random.split(key, 4)
    dt = cfg.param_dtype

    def layer_init(k):
        return mamba2_layer_init(k, cfg, dt)

    ks = jax.random.split(keys[1], cfg.n_layers)
    params = {
        "embed": L.embedding_init(keys[0], vp, cfg.d_model, dt),
        "layers": jax.vmap(layer_init)(ks),
        "final_norm": _norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.truncated_normal_init(
            keys[2], (cfg.d_model, vp), 1.0 / math.sqrt(cfg.d_model), dt)
    return params


def make_mamba2_model(cfg: ArchConfig) -> Model:
    d_inner, nh, ds, conv_dim = _dims(cfg)

    def init(key):
        return init_mamba2(cfg, key)

    def forward(params, tokens):
        x = L.embed(params["embed"], tokens, cfg.compute_dtype)
        x = constrain(x, BATCH, None, None)

        def body(carry, lp):
            x = carry
            x, _, _ = mamba2_block(cfg, lp, x)
            return x, None

        x, _ = maybe_scan(_remat(cfg, body), x, params["layers"],
                          cfg.scan_layers)
        return _norm(cfg, params["final_norm"], x)

    def loss(params, batch):
        hidden = forward(params, batch["tokens"])
        lg = logits_fn(cfg, params, hidden)
        l = xent_loss(cfg, lg, batch["labels"])
        return l, {"xent": l}

    def prefill(params, batch, cache_len=None):
        # cache_len accepted for API uniformity; SSM state is O(1) in
        # sequence length so there is nothing to size.
        tokens = batch["tokens"]
        bsz, s = tokens.shape
        x = L.embed(params["embed"], tokens, cfg.compute_dtype)

        def body(carry, lp):
            x = carry
            x, hs, cs = mamba2_block(cfg, lp, x)
            return x, (hs, cs)

        x, (hs, cs) = maybe_scan(body, x, params["layers"],
                                 cfg.scan_layers)
        x = _norm(cfg, params["final_norm"], x)
        lg = logits_fn(cfg, params, x[:, -1:, :])
        return lg, {"ssm": hs, "conv": cs,
                    "len": jnp.full((), s, jnp.int32)}

    def decode_step(params, cache, batch):
        tokens = batch["tokens"]
        x = L.embed(params["embed"], tokens, cfg.compute_dtype)

        def body(carry, xs):
            x = carry
            lp, hs, cs = xs
            x, nh_, nc_ = mamba2_block(cfg, lp, x, ssm_state=hs,
                                       conv_state=cs, decode=True)
            return x, (nh_, nc_)

        x, (hs, cs) = maybe_scan(body, x,
                                 (params["layers"], cache["ssm"],
                                  cache["conv"]), cfg.scan_layers)
        x = _norm(cfg, params["final_norm"], x)
        lg = logits_fn(cfg, params, x)
        return lg, {"ssm": hs, "conv": cs, "len": cache["len"] + 1}

    def param_specs(axes: dict):
        model = axes.get("model", 1)
        vp = _vocab_padded(cfg)
        h_ok = nh % model == 0
        v_ok = vp % model == 0
        layer = {
            "norm": {"scale": P(None, None)},
            "in_proj": P(None, "data", "model" if h_ok else None),
            "conv_w": P(None, None, None),
            "conv_b": P(None, None),
            "A_log": P(None, "model" if h_ok else None),
            "D": P(None, "model" if h_ok else None),
            "dt_bias": P(None, "model" if h_ok else None),
            "gate_norm": {"scale": P(None, "model" if h_ok else None)},
            "out_proj": P(None, "model" if h_ok else None, "data"),
        }
        specs = {
            "embed": {"table": P("model" if v_ok else None, "data")},
            "layers": layer,
            "final_norm": {"scale": P(None)},
        }
        if not cfg.tie_embeddings:
            specs["lm_head"] = P("data", "model" if v_ok else None)
        return specs

    def cache_specs(axes: dict):
        model = axes.get("model", 1)
        h_ok = nh % model == 0
        return {"ssm": P(None, BATCH, "model" if h_ok else None, None,
                         None),
                "conv": P(None, BATCH, None, None),
                "len": P()}

    def input_specs(shape, kind: str):
        b, s = shape["global_batch"], shape["seq_len"]
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if kind == "train":
            return {"tokens": tok, "labels": tok}
        if kind == "prefill":
            return {"tokens": tok}
        if kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        raise ValueError(kind)

    def active_param_count() -> int:
        vp = _vocab_padded(cfg)
        per_layer = (cfg.d_model * (2 * d_inner + 2 * ds + nh)
                     + cfg.ssm_conv * conv_dim + d_inner * cfg.d_model)
        emb = vp * cfg.d_model * (1 if cfg.tie_embeddings else 2)
        return cfg.n_layers * per_layer + emb

    return Model(cfg=cfg, init=init, loss=loss, prefill=prefill,
                 decode_step=decode_step, param_specs=param_specs,
                 cache_specs=cache_specs, input_specs=input_specs,
                 param_count=count_params,
                 active_param_count=active_param_count)
