"""LM model zoo: dense / MoE / SSM / hybrid / enc-dec / VLM backbones.

Built bottom-up from layers.py; every architecture family exposes the same
Model protocol (api.py): init / loss / prefill / decode_step / param_specs
/ input_specs, so the launcher, dry-run, and trainer are family-agnostic.
"""
from .api import Model, build_model

__all__ = ["Model", "build_model"]
