"""Mixture-of-Experts FFN with Gunrock frontier-style dispatch.

Token→expert routing is a bipartite V→E *advance*: each token expands to
its top-k expert edges; capacity enforcement is Gunrock's *inexact filter*
(over-capacity items culled); the gather into per-expert buffers is the
LB-balanced data movement (kernels/moe_dispatch.py); the weighted combine
is a *neighborhood reduction* (segment-sum back onto tokens). See
DESIGN.md §4 — this is the paper's machinery applied beyond the paper.

Distribution (mirrors Gunrock's multi-GPU frontier exchange [56]): the
token stream is viewed as (D, t_local) where D = pod×data shards; ALL
routing/sort/compaction math is shard-local (vmapped over the sharded
leading axis — zero cross-shard traffic), and the only communication is
the expert-parallel reshard of the (D, E, C_local, d) buffers onto the
"model" axis around the expert einsums — the EP all-to-all. A global
dispatch (flat argsort over all tokens) forces GSPMD to all-gather the
whole token matrix per layer; measured in EXPERIMENTS.md §Dry-run.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

from . import layers as L

BATCH = ("pod", "data")


def moe_init(key, cfg, dtype):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_expert
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s1 = 1.0 / math.sqrt(d)
    s2 = 1.0 / math.sqrt(f)
    p = {
        "router": L.truncated_normal_init(k1, (d, e), s1, jnp.float32),
        "w1": L.truncated_normal_init(k2, (e, d, f), s1, dtype),
        "w3": L.truncated_normal_init(k3, (e, d, f), s1, dtype),
        "w2": L.truncated_normal_init(k4, (e, f, d), s2, dtype),
    }
    if cfg.weight_quant:
        # int8 weight-only serving (beyond-paper §Perf): per-(expert, out-
        # column) absmax scales; FSDP gathers then move int8, not bf16
        for w in ("w1", "w3", "w2"):
            full = p[w].astype(jnp.float32)
            scale = jnp.max(jnp.abs(full), axis=1) / 127.0       # (e, out)
            p[w] = jnp.round(full / jnp.maximum(scale[:, None, :],
                                                1e-12)).astype(jnp.int8)
            p[f"{w}_scale"] = scale
    if cfg.n_shared_experts:
        p["shared"] = L.swiglu_init(k5, d,
                                    cfg.d_expert * cfg.n_shared_experts,
                                    dtype)
    return p


def _wq(params, name, dtype):
    """Fetch an expert weight, dequantizing int8 storage if present.

    The int8 codes are explicitly re-constrained to an expert-sharded /
    data-replicated layout BEFORE dequantization so the FSDP all-gather
    moves int8 bytes — without the constraint GSPMD hoists the f32
    dequant above the gather and the collective moves 4× the bytes
    (measured in EXPERIMENTS.md §Perf Q1)."""
    w = params[name]
    if w.dtype == jnp.int8:
        w = constrain(w, "model", None, None)        # gather int8 here
        scale = constrain(params[f"{name}_scale"], "model", None)
        return (w.astype(jnp.float32)
                * scale[:, None, :]).astype(dtype)
    return w.astype(dtype)


def _num_data_shards() -> int:
    from repro.jax_compat import get_abstract_mesh
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    d = 1
    for a in BATCH:
        d *= sizes.get(a, 1)
    return d


def _capacity(t_local: int, cfg) -> int:
    c = math.ceil(t_local * cfg.top_k / cfg.n_experts
                  * cfg.capacity_factor)
    return max(8 * math.ceil(c / 8), 8)


def moe_ffn(params, x, cfg, use_kernel: bool = False):
    """x: (B, S, d) → (B, S, d) plus aux metrics dict."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    dsh = _num_data_shards()
    if t % dsh != 0:
        dsh = 1
    tl = t // dsh                                   # tokens per shard
    cap = _capacity(tl, cfg)
    # (D, t_local, d): dim0 carries the batch sharding; everything until
    # the expert einsum is shard-local (vmapped over dim0)
    x3 = constrain(x.reshape(dsh, tl, d), BATCH, None, None)

    # --- route (the frontier: each token expands to k expert edges) ------
    logits = x3.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, k)          # (D, tl, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = expert.reshape(dsh, tl * k).astype(jnp.int32)
    flat_g = gate.reshape(dsh, tl * k)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tl, dtype=jnp.int32), k)[None],
        (dsh, tl * k))

    # --- LB dispatch: per-shard sort by expert (frontier compaction) -----
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    sorted_tok = jnp.take_along_axis(flat_tok, order, axis=-1)
    sorted_g = jnp.take_along_axis(flat_g, order, axis=-1)
    seg_start = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(e, dtype=jnp.int32)))(
        sorted_e)                                    # (D, E)
    rank = jnp.arange(tl * k, dtype=jnp.int32)[None] \
        - jnp.take_along_axis(seg_start, sorted_e, axis=-1)
    keep = rank < cap                                # inexact filter
    slot = jnp.where(keep, sorted_e * cap + rank, e * cap)

    def scatter_slots(slot_row, tok_row, gate_row, keep_row):
        st = jnp.full((e * cap,), -1, jnp.int32)
        st = st.at[slot_row].set(jnp.where(keep_row, tok_row, -1),
                                 mode="drop")
        sg = jnp.zeros((e * cap,), jnp.float32)
        sg = sg.at[slot_row].set(jnp.where(keep_row, gate_row, 0.0),
                                 mode="drop")
        return st, sg

    slot_tok, slot_gate = jax.vmap(scatter_slots)(slot, sorted_tok,
                                                  sorted_g, keep)
    # E over "model" from birth: the token gather below then produces only
    # each device's expert slice (x3 is model-replicated, so the gather is
    # local) — without this, a (D, E_full, C, d) buffer materializes
    # per-device and the EP reshard becomes a 10 GiB/layer all-gather
    # (EXPERIMENTS.md §Perf Q1)
    slot_tok = constrain(slot_tok.reshape(dsh, e, cap),
                         BATCH, "model", None)
    slot_gate = constrain(
        slot_gate.reshape(dsh, e, cap).astype(x.dtype),
        BATCH, "model", None)
    mask2 = slot_tok >= 0

    # --- gather tokens into expert buffers (shard-local) ------------------
    zero = jnp.zeros((), x3.dtype)

    def gather_tokens(xl, stl, ml):
        return jnp.where(ml[..., None], xl[jnp.where(ml, stl, 0)], zero)

    xin = jax.vmap(gather_tokens)(x3, slot_tok, mask2)   # (D, E, C, d)
    xin = constrain(xin, BATCH, "model", None, None)

    # --- expert SwiGLU (dense per-expert einsums; MXU work) ---------------
    w1 = _wq(params, "w1", x.dtype)
    w3 = _wq(params, "w3", x.dtype)
    w2 = _wq(params, "w2", x.dtype)
    g = jax.nn.silu(jnp.einsum("xecd,edf->xecf", xin, w1))
    u = jnp.einsum("xecd,edf->xecf", xin, w3)
    eo = jnp.einsum("xecf,efd->xecd", g * u, w2)
    eo = constrain(eo, BATCH, "model", None, None)
    eo = eo * slot_gate[..., None]
    # NOTE: eo stays E-sharded; the combine scatter produces per-model-rank
    # partial sums and XLA inserts the (B, tl, d) all-reduce — cheaper than
    # gathering the (E, C, d) buffer back (§Perf Q1)

    # --- combine (neighborhood reduction back onto tokens) ----------------
    def combine(eol, stl, ml):
        y = jnp.zeros((tl, d), x.dtype)
        idx = jnp.where(ml, stl, tl).reshape(-1)
        return y.at[idx].add(eol.reshape(e * cap, d), mode="drop")

    y3 = jax.vmap(combine)(eo, slot_tok, mask2)
    y2 = y3.reshape(t, d)

    if cfg.n_shared_experts:
        y2 = y2 + L.swiglu(params["shared"], x.reshape(t, d))

    # load-balance aux loss (Switch-style) + drop-rate metric
    me = jnp.mean(probs, axis=(0, 1))                # (e,)
    ce = jnp.zeros((e,), jnp.float32).at[flat_e.reshape(-1)].add(
        1.0 / (t * k))
    aux = {"moe_aux_loss": e * jnp.sum(me * ce),
           "moe_drop_frac": 1.0 - jnp.sum(keep, dtype=jnp.float32) / (t * k)}
    return y2.reshape(b, s, d), aux
