"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv/mel frontend is a STUB per the assignment: `input_specs()`
provides precomputed frame embeddings (B, S_enc, d) — the two conv1d
layers + GELU that would produce them are out of scope. Everything after
(sinusoidal positions, 32-layer bidirectional encoder, 32-layer decoder
with cross-attention, layernorm/GELU) is implemented.

Serving: prefill encodes the source and precomputes per-layer cross KV
(they are decode-invariant), then decode steps run self-attn against the
growing cache + fixed cross KV.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import constrain

from . import layers as L
from .api import ArchConfig, Model, count_params, maybe_scan
from .transformer import _norm, _norm_init, _remat, _vocab_padded, \
    xent_loss

BATCH = ("pod", "data")


def _enc_layers(cfg):
    return cfg.n_enc_layers or cfg.n_layers


def _dec_layers(cfg):
    return cfg.n_dec_layers or cfg.n_layers


def init_encdec(cfg: ArchConfig, key):
    vp = _vocab_padded(cfg)
    keys = jax.random.split(key, 8)
    dt = cfg.param_dtype

    def enc_layer(k):
        ka, kf = jax.random.split(k)
        return {
            "attn_norm": _norm_init(cfg),
            "attn": L.attention_init(ka, cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.hd, dt,
                                     with_bias=True),
            "mlp_norm": _norm_init(cfg),
            "mlp": L.gelu_mlp_init(kf, cfg.d_model, cfg.d_ff, dt),
        }

    def dec_layer(k):
        ka, kx, kf = jax.random.split(k, 3)
        return {
            "self_norm": _norm_init(cfg),
            "self_attn": L.attention_init(ka, cfg.d_model, cfg.n_heads,
                                          cfg.n_kv_heads, cfg.hd, dt,
                                          with_bias=True),
            "cross_norm": _norm_init(cfg),
            "cross_attn": L.attention_init(kx, cfg.d_model, cfg.n_heads,
                                           cfg.n_kv_heads, cfg.hd, dt,
                                           with_bias=True),
            "mlp_norm": _norm_init(cfg),
            "mlp": L.gelu_mlp_init(kf, cfg.d_model, cfg.d_ff, dt),
        }

    return {
        "enc_layers": jax.vmap(enc_layer)(
            jax.random.split(keys[0], _enc_layers(cfg))),
        "enc_final_norm": _norm_init(cfg),
        "dec_embed": L.embedding_init(keys[1], vp, cfg.d_model, dt),
        "dec_layers": jax.vmap(dec_layer)(
            jax.random.split(keys[2], _dec_layers(cfg))),
        "dec_final_norm": _norm_init(cfg),
    }


def encode(cfg, params, frames):
    """frames: (B, S_enc, d) stub embeddings → encoder states."""
    b, s, d = frames.shape
    x = frames.astype(cfg.compute_dtype)
    x = x + L.sinusoidal_positions(s, d).astype(x.dtype)[None]
    x = constrain(x, BATCH, None, None)

    def body(carry, lp):
        x = carry
        h = _norm(cfg, lp["attn_norm"], x)
        a, _ = L.attention(lp["attn"], h, n_heads=cfg.n_heads,
                           n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                           causal=False, use_rope=False)
        x = x + a
        h = _norm(cfg, lp["mlp_norm"], x)
        x = x + L.gelu_mlp(lp["mlp"], h)
        return constrain(x, BATCH, None, None), None

    x, _ = maybe_scan(_remat(cfg, body), x, params["enc_layers"],
                      cfg.scan_layers)
    return _norm(cfg, params["enc_final_norm"], x)


def _dec_block(cfg, lp, x, enc_out, kv_cache, cache_index, cross_kv=None):
    h = _norm(cfg, lp["self_norm"], x)
    a, new_cache = L.attention(lp["self_attn"], h, n_heads=cfg.n_heads,
                               n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                               causal=True, use_rope=False,
                               kv_cache=kv_cache, cache_index=cache_index)
    x = x + a
    h = _norm(cfg, lp["cross_norm"], x)
    if cross_kv is None:
        b, se, d = enc_out.shape
        k = (enc_out @ lp["cross_attn"]["wk"].astype(enc_out.dtype)
             + lp["cross_attn"]["bk"].astype(enc_out.dtype)
             ).reshape(b, se, cfg.n_kv_heads, cfg.hd)
        v = (enc_out @ lp["cross_attn"]["wv"].astype(enc_out.dtype)
             + lp["cross_attn"]["bv"].astype(enc_out.dtype)
             ).reshape(b, se, cfg.n_kv_heads, cfg.hd)
        cross_kv = (k, v)
    a, _ = L.attention(lp["cross_attn"], h, n_heads=cfg.n_heads,
                       n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                       causal=False, use_rope=False, kv_override=cross_kv)
    x = x + a
    h = _norm(cfg, lp["mlp_norm"], x)
    x = x + L.gelu_mlp(lp["mlp"], h)
    return constrain(x, BATCH, None, None), new_cache, cross_kv


def decode_train(cfg, params, enc_out, tokens):
    b, s = tokens.shape
    x = L.embed(params["dec_embed"], tokens, cfg.compute_dtype)
    x = x + L.sinusoidal_positions(s, cfg.d_model).astype(x.dtype)[None]
    x = constrain(x, BATCH, None, None)

    def body(carry, lp):
        x = carry
        x, _, _ = _dec_block(cfg, lp, x, enc_out, None, None)
        return x, None

    x, _ = maybe_scan(_remat(cfg, body), x, params["dec_layers"],
                      cfg.scan_layers)
    return _norm(cfg, params["dec_final_norm"], x)


def make_encdec_model(cfg: ArchConfig) -> Model:
    vp = _vocab_padded(cfg)

    def init(key):
        return init_encdec(cfg, key)

    def _logits(params, hidden):
        # whisper ties the decoder unembedding to the token embedding
        table = params["dec_embed"]["table"]
        lg = hidden @ table.astype(hidden.dtype).T
        return constrain(lg, BATCH, None, "model")

    def loss(params, batch):
        enc_out = encode(cfg, params, batch["frames"])
        hidden = decode_train(cfg, params, enc_out, batch["tokens"])
        lg = _logits(params, hidden)
        l = xent_loss(cfg, lg, batch["labels"])
        return l, {"xent": l}

    def prefill(params, batch, cache_len=None):
        """Encode + decoder prefill over the prompt tokens."""
        enc_out = encode(cfg, params, batch["frames"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = L.embed(params["dec_embed"], tokens, cfg.compute_dtype)
        x = x + L.sinusoidal_positions(s, cfg.d_model).astype(x.dtype)[None]
        cache0 = jnp.zeros((_dec_layers(cfg), b, cache_len or s,
                            cfg.n_kv_heads, cfg.hd), cfg.compute_dtype)

        def body(carry, xs):
            x = carry
            lp, ck, cv = xs
            x, nc, ckv = _dec_block(cfg, lp, x, enc_out,
                                    {"k": ck, "v": cv}, 0)
            return x, (nc["k"], nc["v"], ckv[0], ckv[1])

        x, (ks, vs, cks, cvs) = maybe_scan(
            body, x, (params["dec_layers"], cache0, cache0),
            cfg.scan_layers)
        x = _norm(cfg, params["dec_final_norm"], x)
        lg = _logits(params, x[:, -1:, :])
        return lg, {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs,
                    "len": jnp.full((), s, jnp.int32)}

    def decode_step(params, cache, batch):
        tokens = batch["tokens"]
        b = tokens.shape[0]
        pos = cache["len"]
        x = L.embed(params["dec_embed"], tokens, cfg.compute_dtype)
        # sinusoidal position at the current index
        pe = L.sinusoidal_positions(cfg.max_cache_len, cfg.d_model)
        x = x + jax.lax.dynamic_slice(
            pe, (pos, 0), (1, cfg.d_model)).astype(x.dtype)[None]

        def body(carry, xs):
            x = carry
            lp, ck, cv, xk, xv = xs
            x, nc, _ = _dec_block(cfg, lp, x, None, {"k": ck, "v": cv},
                                  pos, cross_kv=(xk, xv))
            return x, (nc["k"], nc["v"])

        x, (ks, vs) = maybe_scan(
            body, x, (params["dec_layers"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]),
            cfg.scan_layers)
        x = _norm(cfg, params["dec_final_norm"], x)
        lg = _logits(params, x)
        return lg, {"k": ks, "v": vs, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"], "len": pos + 1}

    def param_specs(axes: dict):
        model = axes.get("model", 1)
        a_ok = cfg.n_heads % model == 0
        kv_ok = cfg.n_kv_heads % model == 0
        ff_ok = cfg.d_ff % model == 0
        v_ok = vp % model == 0

        def attn_spec():
            return {
                "wq": P(None, "data", "model" if a_ok else None),
                "wk": P(None, "data", "model" if kv_ok else None),
                "wv": P(None, "data", "model" if kv_ok else None),
                "wo": P(None, "model" if a_ok else None, "data"),
                "bq": P(None, "model" if a_ok else None),
                "bk": P(None, "model" if kv_ok else None),
                "bv": P(None, "model" if kv_ok else None),
            }

        def mlp_spec():
            return {
                "w1": P(None, "data", "model" if ff_ok else None),
                "b1": P(None, "model" if ff_ok else None),
                "w2": P(None, "model" if ff_ok else None, "data"),
                "b2": P(None, None),
            }

        def norm_spec():
            return {"scale": P(None, None), "bias": P(None, None)} \
                if cfg.norm == "layernorm" else {"scale": P(None, None)}

        def fnorm_spec():
            return {"scale": P(None), "bias": P(None)} \
                if cfg.norm == "layernorm" else {"scale": P(None)}

        enc = {"attn_norm": norm_spec(), "attn": attn_spec(),
               "mlp_norm": norm_spec(), "mlp": mlp_spec()}
        dec = {"self_norm": norm_spec(), "self_attn": attn_spec(),
               "cross_norm": norm_spec(), "cross_attn": attn_spec(),
               "mlp_norm": norm_spec(), "mlp": mlp_spec()}
        return {
            "enc_layers": enc,
            "enc_final_norm": fnorm_spec(),
            "dec_embed": {"table": P("model" if v_ok else None, "data")},
            "dec_layers": dec,
            "dec_final_norm": fnorm_spec(),
        }

    def cache_specs(axes: dict):
        model = axes.get("model", 1)
        kv_ok = cfg.n_kv_heads % model == 0
        if kv_ok:
            kv = P(None, BATCH, None, "model", None)
        else:   # flash-decode layout: shard the sequence dim
            kv = P(None, BATCH, "model", None, None)
        return {"k": kv, "v": kv, "cross_k": kv, "cross_v": kv,
                "len": P()}

    def input_specs(shape, kind: str):
        b, s = shape["global_batch"], shape["seq_len"]
        se = min(cfg.max_source_len, s)
        frames = jax.ShapeDtypeStruct((b, se, cfg.d_model),
                                      cfg.compute_dtype)
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if kind == "train":
            return {"frames": frames, "tokens": tok, "labels": tok}
        if kind == "prefill":
            return {"frames": frames, "tokens": tok}
        if kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        raise ValueError(kind)

    def active_param_count() -> int:
        d = cfg.d_model
        attn = 2 * d * cfg.n_heads * cfg.hd + 2 * d * cfg.n_kv_heads * cfg.hd
        mlp = 2 * d * cfg.d_ff
        enc = _enc_layers(cfg) * (attn + mlp)
        dec = _dec_layers(cfg) * (2 * attn + mlp)
        return enc + dec + vp * d

    return Model(cfg=cfg, init=init, loss=loss, prefill=prefill,
                 decode_step=decode_step, param_specs=param_specs,
                 cache_specs=cache_specs, input_specs=input_specs,
                 param_count=count_params,
                 active_param_count=active_param_count)
