"""Architecture config + the family-agnostic Model protocol.

Every architecture (dense / MoE / SSM / hybrid / enc-dec / VLM) builds to a
`Model` with the same six entry points, so launch/dryrun/train/serve are
family-blind:

    init(key) -> params
    loss(params, batch) -> (scalar, metrics)        # train step core
    prefill(params, batch) -> (logits, cache)       # inference prefill
    decode_step(params, cache, batch) -> (logits, cache)
    param_specs(mesh_axes) -> pytree of PartitionSpec
    input_specs(shape, mesh_axes, kind) -> dict of ShapeDtypeStruct
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    mlp: str = "swiglu"              # swiglu | gelu
    tie_embeddings: bool = False
    attn_bias: bool = False
    norm_eps: float = 1e-5
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0                # per-expert FFN hidden
    capacity_factor: float = 1.25
    n_shared_experts: int = 0
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    # hybrid (zamba2): shared attention block applied every k ssm layers
    attn_every: int = 0
    # enc-dec (whisper)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    max_source_len: int = 1500       # whisper: 30 s → 1500 frames
    # VLM (qwen2-vl)
    mrope_sections: Optional[tuple] = None
    # dtypes / optimization
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    remat: str = "none"              # none | dots | full
    seq_shard_acts: bool = False     # shard saved carries' S over "model"
    scan_layers: bool = True         # False: unroll (dry-run cost probes)
    use_flash: bool = False
    # serving
    max_cache_len: int = 32768
    kv_quant: bool = False           # int8 KV cache (beyond-paper, §Perf)
    weight_quant: bool = False       # int8 MoE expert weights (serving)
    # notes for DESIGN/EXPERIMENTS
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclass
class Model:
    cfg: ArchConfig
    init: Callable
    loss: Callable                  # (params, batch) -> (loss, metrics)
    prefill: Callable               # (params, batch) -> (logits, cache)
    decode_step: Callable           # (params, cache, batch) -> (logits, cache)
    param_specs: Callable           # (mesh_axes: dict) -> spec pytree
    cache_specs: Callable           # (mesh_axes, batch, seq) -> spec pytree
    input_specs: Callable           # (shape, kind) -> dict[str, SDS]
    param_count: Callable           # (params) -> int
    active_param_count: Callable    # () -> analytic active params


def count_params(params) -> int:
    return int(sum(x.size for x in jax.tree.leaves(params)))


def maybe_scan(body, carry, xs, use_scan: bool):
    """jax.lax.scan or an unrolled python loop (identical semantics).

    Unrolling exists for the dry-run's cost probes: XLA's cost_analysis
    counts a while-loop body ONCE regardless of trip count, so per-layer
    costs are measured on small unrolled programs and extrapolated
    (launch/dryrun.py). Production programs always scan.
    """
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family in ("dense", "vlm"):
        from .transformer import make_dense_model
        return make_dense_model(cfg)
    if cfg.family == "moe":
        from .transformer import make_dense_model
        return make_dense_model(cfg)     # MoE FFN plugs into the same skeleton
    if cfg.family == "ssm":
        from .mamba2 import make_mamba2_model
        return make_mamba2_model(cfg)
    if cfg.family == "hybrid":
        from .hybrid import make_hybrid_model
        return make_hybrid_model(cfg)
    if cfg.family == "encdec":
        from .encdec import make_encdec_model
        return make_encdec_model(cfg)
    raise ValueError(f"unknown family {cfg.family}")
