"""Decoder-only transformer skeleton covering the dense, MoE, and VLM
families (GQA + RoPE / M-RoPE; SwiGLU or MoE FFN; scanned layers).

Layers are stacked (leading L axis) and executed with `jax.lax.scan` so
the HLO (and compile time) is depth-independent; remat policy is applied
to the scanned block. KV caches are stacked (L, B, Smax, KV, hd).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import constrain

from . import layers as L
from .api import ArchConfig, Model, count_params, maybe_scan
from .moe import moe_ffn, moe_init

BATCH = ("pod", "data")


def _vocab_padded(cfg: ArchConfig) -> int:
    return -(-cfg.vocab // 256) * 256


def _norm_init(cfg):
    return (L.rmsnorm_init(cfg.d_model, jnp.float32) if cfg.norm == "rmsnorm"
            else L.layernorm_init(cfg.d_model, jnp.float32))


def _norm(cfg, p, x):
    return (L.rmsnorm(p, x, cfg.norm_eps) if cfg.norm == "rmsnorm"
            else L.layernorm(p, x, cfg.norm_eps))


def init_dense(cfg: ArchConfig, key) -> dict:
    vp = _vocab_padded(cfg)
    keys = jax.random.split(key, 8)
    dt = cfg.param_dtype

    def stack(fn, k):
        ks = jax.random.split(k, cfg.n_layers)
        return jax.vmap(fn)(ks)

    def layer_init(k):
        ka, kf = jax.random.split(k)
        p = {
            "attn_norm": _norm_init(cfg),
            "attn": L.attention_init(ka, cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.hd, dt,
                                     with_bias=cfg.attn_bias),
            "mlp_norm": _norm_init(cfg),
        }
        if cfg.is_moe:
            p["moe"] = moe_init(kf, cfg, dt)
        elif cfg.mlp == "swiglu":
            p["mlp"] = L.swiglu_init(kf, cfg.d_model, cfg.d_ff, dt)
        else:
            p["mlp"] = L.gelu_mlp_init(kf, cfg.d_model, cfg.d_ff, dt)
        return p

    params = {
        "embed": L.embedding_init(keys[0], vp, cfg.d_model, dt),
        "layers": stack(layer_init, keys[1]),
        "final_norm": _norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.truncated_normal_init(
            keys[2], (cfg.d_model, vp), 1.0 / math.sqrt(cfg.d_model), dt)
    return params


def _block(cfg: ArchConfig, lp, x, positions, mrope_pos, kv_cache,
           cache_index):
    """One transformer block. Returns (x, aux, new_cache)."""
    if cfg.seq_shard_acts:
        # activation-ZeRO (beyond-paper, §Perf): the layer carry arrives
        # sequence-sharded over "model" (16x smaller checkpoint); gather
        # it here for compute
        x = constrain(x, BATCH, None, None)
    h = _norm(cfg, lp["attn_norm"], x)
    h = constrain(h, BATCH, None, None)
    attn_out, new_cache = L.attention(
        lp["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd, positions=positions, rope_theta=cfg.rope_theta,
        mrope_sections=(tuple(cfg.mrope_sections)
                        if cfg.mrope_sections else None),
        causal=True, kv_cache=kv_cache, cache_index=cache_index)
    x = x + attn_out
    h = _norm(cfg, lp["mlp_norm"], x)
    if cfg.is_moe:
        f, aux = moe_ffn(lp["moe"], h, cfg)
    else:
        f = (L.swiglu(lp["mlp"], h) if cfg.mlp == "swiglu"
             else L.gelu_mlp(lp["mlp"], h))
        aux = {"moe_aux_loss": jnp.float32(0.0),
               "moe_drop_frac": jnp.float32(0.0)}
    x = x + f
    if cfg.seq_shard_acts:
        x = constrain(x, BATCH, "model", None)
    else:
        x = constrain(x, BATCH, None, None)
    return x, aux, new_cache


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def forward(cfg: ArchConfig, params, tokens, positions=None,
            input_embeds=None):
    """tokens: (B,S) int32 (or input_embeds (B,S,d)); positions: (B,S) or
    (3,B,S) for M-RoPE. Returns final hidden states (B,S,d)."""
    dt = cfg.compute_dtype
    if input_embeds is not None:
        x = input_embeds.astype(dt)
        b, s = x.shape[:2]
    else:
        b, s = tokens.shape
        x = L.embed(params["embed"], tokens, dt)
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(positions[None], (3, b, s))
        else:
            positions = jnp.broadcast_to(positions, (b, s))
    x = constrain(x, BATCH, None, None)

    def body(carry, lp):
        x = carry
        x, aux, _ = _block(cfg, lp, x, positions, None, None, None)
        return x, aux

    x, auxs = maybe_scan(_remat(cfg, body), x, params["layers"],
                         cfg.scan_layers)
    x = _norm(cfg, params["final_norm"], x)
    aux = jax.tree.map(jnp.mean, auxs)
    return x, aux


def logits_fn(cfg, params, hidden):
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"])
    if cfg.tie_embeddings:
        lg = hidden @ table.astype(hidden.dtype).T
    else:
        lg = hidden @ table.astype(hidden.dtype)
    return constrain(lg, BATCH, None, "model")


def xent_loss(cfg, logits, labels, mask=None):
    """Cross-entropy in fp32 with optional z-loss; labels -100 ignored."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ignore = labels < 0
    safe = jnp.where(ignore, 0, labels)
    gold = jnp.take_along_axis(lf, safe[..., None], axis=-1)[..., 0]
    nll = lse - gold
    zloss = 1e-4 * lse ** 2
    w = jnp.where(ignore, 0.0, 1.0)
    if mask is not None:
        w = w * mask
    denom = jnp.maximum(jnp.sum(w), 1.0)
    return jnp.sum((nll + zloss) * w) / denom


def make_dense_model(cfg: ArchConfig) -> Model:
    vp = _vocab_padded(cfg)

    def init(key):
        return init_dense(cfg, key)

    def loss(params, batch):
        positions = batch.get("positions")
        embeds = batch.get("input_embeds")
        hidden, aux = forward(cfg, params, batch.get("tokens"), positions,
                              input_embeds=embeds)
        lg = logits_fn(cfg, params, hidden)
        l = xent_loss(cfg, lg, batch["labels"])
        total = l + 0.01 * aux["moe_aux_loss"]
        return total, {"xent": l, **aux}

    # ---- serving ---------------------------------------------------------
    def _empty_cache(b, smax):
        shp = (cfg.n_layers, b, smax, cfg.n_kv_heads, cfg.hd)
        if cfg.kv_quant:
            sshp = (cfg.n_layers, b, smax, cfg.n_kv_heads)
            return {"k": jnp.zeros(shp, jnp.int8),
                    "v": jnp.zeros(shp, jnp.int8),
                    "k_scale": jnp.zeros(sshp, jnp.float32),
                    "v_scale": jnp.zeros(sshp, jnp.float32)}
        return {"k": jnp.zeros(shp, cfg.compute_dtype),
                "v": jnp.zeros(shp, cfg.compute_dtype)}

    def prefill(params, batch, cache_len: Optional[int] = None):
        """Full-sequence forward that also emits the KV cache.

        cache_len (static): cache capacity; defaults to the prompt length
        (dry-run cells). Pass prompt+headroom for prefill→decode flows.
        """
        tokens = batch.get("tokens")
        embeds = batch.get("input_embeds")
        positions = batch.get("positions")
        dt = cfg.compute_dtype
        if embeds is not None:
            x = embeds.astype(dt)
            b, s = x.shape[:2]
        else:
            b, s = tokens.shape
            x = L.embed(params["embed"], tokens, dt)
        if positions is None:
            positions = jnp.arange(s, dtype=jnp.int32)[None, :]
            positions = (jnp.broadcast_to(positions[None], (3, b, s))
                         if cfg.mrope_sections
                         else jnp.broadcast_to(positions, (b, s)))
        x = constrain(x, BATCH, None, None)
        cache0 = _empty_cache(b, cache_len or s)

        def body(carry, xs):
            x = carry
            lp, cache_l = xs
            x, aux, nc = _block(cfg, lp, x, positions, None, cache_l, 0)
            return x, nc

        cache_xs = {k_: v_ for k_, v_ in cache0.items()}
        x, caches = maybe_scan(_remat(cfg, body), x,
                               (params["layers"], cache_xs),
                               cfg.scan_layers)
        x = _norm(cfg, params["final_norm"], x)
        lg = logits_fn(cfg, params, x[:, -1:, :])
        return lg, {**caches,
                    "len": jnp.full((), x.shape[1], jnp.int32)}

    def decode_step(params, cache, batch):
        """One-token decode against a static-size cache."""
        tokens = batch["tokens"]                     # (B, 1)
        b = tokens.shape[0]
        pos = cache["len"]                           # () int32
        dt = cfg.compute_dtype
        x = L.embed(params["embed"], tokens, dt)
        positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(
            jnp.int32)
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(positions[None], (3, b, 1))
        x = constrain(x, BATCH, None, None)

        def body(carry, xs):
            x = carry
            lp, cache_l = xs
            x, aux, nc = _block(cfg, lp, x, positions, None, cache_l,
                                pos)
            return x, nc

        cache_xs = {k_: v_ for k_, v_ in cache.items() if k_ != "len"}
        x, caches = maybe_scan(body, x, (params["layers"], cache_xs),
                               cfg.scan_layers)
        x = _norm(cfg, params["final_norm"], x)
        lg = logits_fn(cfg, params, x)
        return lg, {**caches, "len": pos + 1}

    # ---- sharding --------------------------------------------------------
    def param_specs(axes: dict):
        model = axes.get("model", 1)
        h_ok = cfg.n_heads % model == 0
        kv_ok = cfg.n_kv_heads % model == 0
        ff_ok = (cfg.d_expert if cfg.is_moe else cfg.d_ff) % model == 0
        e_ok = cfg.is_moe and cfg.n_experts % model == 0
        v_ok = vp % model == 0

        attn = {
            "wq": P(None, "data", "model" if h_ok else None),
            "wk": P(None, "data", "model" if kv_ok else None),
            "wv": P(None, "data", "model" if kv_ok else None),
            "wo": P(None, "model" if h_ok else None, "data"),
        }
        if cfg.attn_bias:
            attn["bq"] = P(None, "model" if h_ok else None)
            attn["bk"] = P(None, "model" if kv_ok else None)
            attn["bv"] = P(None, "model" if kv_ok else None)
        layer = {
            "attn_norm": {"scale": P(None, None)},
            "attn": attn,
            "mlp_norm": {"scale": P(None, None)},
        }
        if cfg.norm == "layernorm":
            layer["attn_norm"]["bias"] = P(None, None)
            layer["mlp_norm"]["bias"] = P(None, None)
        if cfg.is_moe:
            layer["moe"] = {
                "router": P(None, None, None),
                "w1": P(None, "model" if e_ok else None, "data", None),
                "w3": P(None, "model" if e_ok else None, "data", None),
                "w2": P(None, "model" if e_ok else None, None, "data"),
            }
            if cfg.weight_quant:
                sc = P(None, "model" if e_ok else None, None)
                layer["moe"].update({"w1_scale": sc, "w3_scale": sc,
                                     "w2_scale": sc})
            if cfg.n_shared_experts:
                layer["moe"]["shared"] = {
                    "w1": P(None, "data", "model" if ff_ok else None),
                    "w3": P(None, "data", "model" if ff_ok else None),
                    "w2": P(None, "model" if ff_ok else None, "data"),
                }
        elif cfg.mlp == "swiglu":
            layer["mlp"] = {
                "w1": P(None, "data", "model" if ff_ok else None),
                "w3": P(None, "data", "model" if ff_ok else None),
                "w2": P(None, "model" if ff_ok else None, "data"),
            }
        else:
            layer["mlp"] = {
                "w1": P(None, "data", "model" if ff_ok else None),
                "b1": P(None, "model" if ff_ok else None),
                "w2": P(None, "model" if ff_ok else None, "data"),
                "b2": P(None, None),
            }
        specs = {
            "embed": {"table": P("model" if v_ok else None, "data")},
            "layers": layer,
            "final_norm": {"scale": P(None)},
        }
        if cfg.norm == "layernorm":
            specs["final_norm"]["bias"] = P(None)
        if not cfg.tie_embeddings:
            specs["lm_head"] = P("data", "model" if v_ok else None)
        return specs

    def cache_specs(axes: dict):
        model = axes.get("model", 1)
        kv_ok = cfg.n_kv_heads % model == 0
        # prefer sharding KV heads over "model"; when head count doesn't
        # divide, shard the SEQUENCE dim instead (flash-decode layout:
        # big cache split 16x, tiny softmax-stat collectives)
        if kv_ok:
            kv = P(None, BATCH, None, "model", None)
            sc = P(None, BATCH, None, "model")
        else:
            kv = P(None, BATCH, "model", None, None)
            sc = P(None, BATCH, "model", None)
        out = {"k": kv, "v": kv, "len": P()}
        if cfg.kv_quant:
            out.update({"k_scale": sc, "v_scale": sc})
        return out

    def input_specs(shape, kind: str):
        b, s = shape["global_batch"], shape["seq_len"]
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if kind == "train":
            d = {"tokens": tok, "labels": tok}
        elif kind == "prefill":
            d = {"tokens": tok}
        elif kind == "decode":
            d = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        else:
            raise ValueError(kind)
        if cfg.family == "vlm":
            # stub frontend: precomputed patch/frame embeddings + M-RoPE ids
            st = 1 if kind == "decode" else s
            d["positions"] = jax.ShapeDtypeStruct(
                (3, b, st), jnp.int32)
            if kind != "decode":
                d.pop("tokens")
                d["input_embeds"] = jax.ShapeDtypeStruct(
                    (b, s, cfg.d_model), cfg.compute_dtype)
                if kind == "train":
                    d["labels"] = tok
        return d

    def active_param_count() -> int:
        """Analytic active params (per-token) for MODEL_FLOPS = 6·N·D."""
        d, l = cfg.d_model, cfg.n_layers
        attn = d * cfg.n_heads * cfg.hd + 2 * d * cfg.n_kv_heads * cfg.hd \
            + cfg.n_heads * cfg.hd * d
        if cfg.is_moe:
            ffn = 3 * d * cfg.d_expert * (cfg.top_k + cfg.n_shared_experts)
            ffn += d * cfg.n_experts  # router
        elif cfg.mlp == "swiglu":
            ffn = 3 * d * cfg.d_ff
        else:
            ffn = 2 * d * cfg.d_ff
        emb = vp * d * (1 if cfg.tie_embeddings else 2)
        return l * (attn + ffn) + emb

    return Model(cfg=cfg, init=init, loss=loss, prefill=prefill,
                 decode_step=decode_step, param_specs=param_specs,
                 cache_specs=cache_specs, input_specs=input_specs,
                 param_count=count_params,
                 active_param_count=active_param_count)
