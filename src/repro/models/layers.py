"""Shared neural layers: norms, rotary embeddings (RoPE / M-RoPE), GQA
attention (with KV cache), SwiGLU/GeLU MLPs, embeddings.

Pure-function style: each layer is `f(params, x, ...)` with params a dict;
`*_init` builds params. All layers take a `dtype` for compute precision and
keep params in their stored dtype (mixed-precision policy handled by the
caller). Sharding is applied by the caller through param-spec trees
(parallel/sharding.py) — layers are sharding-agnostic GSPMD code.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain, mesh_axis_size

BATCH = ("pod", "data")


def truncated_normal_init(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0):
    """Inverse frequencies for the even/odd rotary pairs: (head_dim//2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * inv  # (...,S,1,hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, sections,
                theta: float = 10000.0):
    """Multimodal RoPE (Qwen2-VL): 3 position streams (temporal, height,
    width) drive disjoint frequency bands.

    x: (B, S, H, hd); positions: (3, B, S); sections: 3 ints summing to
    hd//2 — how many frequency pairs each stream owns.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, "mrope sections must cover hd/2"
    inv = rope_freqs(hd, theta)                        # (hd/2,)
    # per-frequency stream selector
    stream = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                        total_repeat_length=hd // 2)   # (hd/2,)
    # pos_per_freq: (B, S, hd/2)
    pos = jnp.take_along_axis(
        positions.transpose(1, 2, 0),                  # (B, S, 3)
        stream[None, None, :], axis=2)
    ang = pos[..., None, :].astype(jnp.float32) * inv  # (B,S,1,hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def sinusoidal_positions(s: int, d: int):
    """Whisper-style fixed sinusoidal embeddings: (s, d)."""
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    div = jnp.exp(-math.log(10000.0)
                  * jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    pe = jnp.zeros((s, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# attention (GQA, optional KV cache, optional M-RoPE / no-RoPE)
# ---------------------------------------------------------------------------

def attention_init(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype=jnp.float32, with_bias=False):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d_model)
    p = {
        "wq": truncated_normal_init(k1, (d_model, n_heads * head_dim),
                                    scale, dtype),
        "wk": truncated_normal_init(k2, (d_model, n_kv_heads * head_dim),
                                    scale, dtype),
        "wv": truncated_normal_init(k3, (d_model, n_kv_heads * head_dim),
                                    scale, dtype),
        "wo": truncated_normal_init(k4, (n_heads * head_dim, d_model),
                                    scale, dtype),
    }
    if with_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


ATTN_CHUNK = 1024  # query-block size for the memory-bounded attention path


def _kv_quantize(x):
    """Per-(token, head) int8 quantization of K/V rows over head_dim.

    Halves decode's dominant HBM term (cache reads) — the beyond-paper
    optimization P7 in EXPERIMENTS.md §Perf. Returns (int8 codes,
    f32 scales (..., KV))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    codes = jnp.round(x.astype(jnp.float32)
                      / jnp.maximum(scale[..., None], 1e-12))
    return codes.astype(jnp.int8), scale


def _kv_dequantize(codes, scale, dtype):
    return (codes.astype(jnp.float32)
            * scale[..., None]).astype(dtype)


def _sdpa_block(q, k, v, scale, qpos, kpos, kmask=None,
                logits_spec=None):
    """One query block vs all keys. q: (B,cq,H,hd); k/v: (B,Sk,H,hd).

    logits_spec: optional PartitionSpec entries for (B,H,q,Sk) logits —
    used by the cached-decode path to force the flash-decode schedule
    (keep the key/sequence dim sharded through softmax instead of letting
    the partitioner all-gather the KV cache)."""
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if logits_spec is not None:
        logits = constrain(logits, *logits_spec)
    mask = kpos[None, :] <= qpos[:, None]
    if kmask is not None:
        mask = mask & kmask[None, :]
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    if logits_spec is not None:
        p = constrain(p, *logits_spec)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def _sdpa(q, k, v, causal: bool, q_offset=None, kmask_len=None,
          logits_spec=None):
    """q: (B,Sq,H,hd), k/v: (B,Sk,H,hd) — softmax attention.

    Long sequences are processed in query blocks of ATTN_CHUNK via
    lax.map, so the live score tensor is (B,H,chunk,Sk) instead of
    (B,H,Sq,Sk) — the jnp shape of what the Pallas flash kernel does
    natively on TPU (kernels/flash_attention.py).

    q_offset: scalar position of q[0] within the key sequence (cached
    decode: q_offset = cache_len; default aligns the ends).
    kmask_len: keys at positions >= kmask_len are masked (partially
    filled caches).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    off = (sk - sq) if q_offset is None else q_offset
    kpos = jnp.arange(sk, dtype=jnp.int32)
    kmask = (kpos < kmask_len) if kmask_len is not None else None
    if not causal:
        qpos = jnp.full((sq,), sk, jnp.int32)  # attend everything
    else:
        qpos = jnp.arange(sq, dtype=jnp.int32) + off

    if sq <= ATTN_CHUNK:
        return _sdpa_block(q, k, v, scale, qpos, kpos, kmask,
                           logits_spec)

    nq = -(-sq // ATTN_CHUNK)
    pad = nq * ATTN_CHUNK - sq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qpp = jnp.pad(qpos, (0, pad))
    qc = qp.reshape(b, nq, ATTN_CHUNK, h, hd).transpose(1, 0, 2, 3, 4)
    qposc = qpp.reshape(nq, ATTN_CHUNK)

    def one(args):
        qi, qpi = args
        return _sdpa_block(qi, k, v, scale, qpi, kpos, kmask,
                           logits_spec)

    out = jax.lax.map(one, (qc, qposc))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nq * ATTN_CHUNK, h, hd)
    return out[:, :sq]


def attention(params, x, *, n_heads: int, n_kv_heads: int, head_dim: int,
              positions=None, rope_theta: float = 10000.0,
              mrope_sections=None, causal: bool = True,
              kv_cache=None, cache_index=None, use_rope: bool = True,
              kv_override=None):
    """GQA attention.

    x: (B, S, d). kv_cache: optional dict {k, v}: (B, Smax, KV, hd) +
    cache_index () — decode appends at cache_index and attends to the
    prefix. kv_override: (k, v) tuple for cross-attention (ignores x for
    keys/values). Returns (out, new_kv_cache).
    """
    b, s, d = x.shape
    q = x @ params["wq"].astype(x.dtype)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
    q = q.reshape(b, s, n_heads, head_dim)
    if kv_override is None:
        k = x @ params["wk"].astype(x.dtype)
        v = x @ params["wv"].astype(x.dtype)
        if "bk" in params:
            k = k + params["bk"].astype(x.dtype)
            v = v + params["bv"].astype(x.dtype)
        k = k.reshape(b, s, n_kv_heads, head_dim)
        v = v.reshape(b, s, n_kv_heads, head_dim)
        if use_rope and positions is not None:
            if mrope_sections is not None:
                q = apply_mrope(q, positions, mrope_sections, rope_theta)
                k = apply_mrope(k, positions, mrope_sections, rope_theta)
            else:
                q = apply_rope(q, positions, rope_theta)
                k = apply_rope(k, positions, rope_theta)
    else:
        k, v = kv_override
        if use_rope and positions is not None and mrope_sections is None:
            q = apply_rope(q, positions, rope_theta)

    new_cache = None
    if kv_cache is not None:
        quant = "k_scale" in kv_cache
        if quant:
            k_store, k_scale = _kv_quantize(k)
            v_store, v_scale = _kv_quantize(v)
        else:
            k_store, v_store = k, v
        if k.shape[1] == 1:
            # single-token decode: masked select instead of a dynamic-
            # index update — a DUS at a traced index into the S-sharded
            # cache makes GSPMD all-gather the whole cache (measured in
            # EXPERIMENTS.md §Perf); the select is sharding-preserving.
            spos = jnp.arange(kv_cache["k"].shape[1],
                              dtype=jnp.int32)[None, :, None, None]
            hit = spos == cache_index
            ck = jnp.where(hit, k_store.astype(kv_cache["k"].dtype),
                           kv_cache["k"])
            cv = jnp.where(hit, v_store.astype(kv_cache["v"].dtype),
                           kv_cache["v"])
            if quant:
                cks = jnp.where(hit[..., 0], k_scale,
                                kv_cache["k_scale"])
                cvs = jnp.where(hit[..., 0], v_scale,
                                kv_cache["v_scale"])
        else:
            ck = jax.lax.dynamic_update_slice(kv_cache["k"], k_store.astype(
                kv_cache["k"].dtype), (0, cache_index, 0, 0))
            cv = jax.lax.dynamic_update_slice(kv_cache["v"], v_store.astype(
                kv_cache["v"].dtype), (0, cache_index, 0, 0))
            if quant:
                cks = jax.lax.dynamic_update_slice(
                    kv_cache["k_scale"], k_scale, (0, cache_index, 0))
                cvs = jax.lax.dynamic_update_slice(
                    kv_cache["v_scale"], v_scale, (0, cache_index, 0))
        if quant:
            new_cache = {"k": ck, "v": cv, "k_scale": cks,
                         "v_scale": cvs}
            k = _kv_dequantize(ck, cks, x.dtype)
            v = _kv_dequantize(cv, cvs, x.dtype)
        else:
            new_cache = {"k": ck, "v": cv}
            k, v = ck, cv
        # mask out cache slots beyond cache_index + s
        valid_len = cache_index + s
    else:
        valid_len = None

    groups = n_heads // n_kv_heads
    if groups > 1:
        k = jnp.repeat(k, groups, axis=2)
        v = jnp.repeat(v, groups, axis=2)

    if kv_cache is not None:
        # decode/cached path: causal against absolute positions, with the
        # unwritten cache tail masked. Logits sharding follows the cache
        # layout: KV heads over "model" when divisible, else the sequence
        # dim (flash-decode; see cache_specs).
        tp = mesh_axis_size("model")
        if n_kv_heads % tp == 0:
            lspec = (BATCH, "model", None, None)
        else:
            lspec = (BATCH, None, None, "model")
        out = _sdpa(q, k, v, causal=True, q_offset=cache_index,
                    kmask_len=valid_len, logits_spec=lspec)
    else:
        out = _sdpa(q, k, v, causal)

    out = out.reshape(b, s, n_heads * head_dim)
    return out @ params["wo"].astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s1 = 1.0 / math.sqrt(d_model)
    s2 = 1.0 / math.sqrt(d_ff)
    return {
        "w1": truncated_normal_init(k1, (d_model, d_ff), s1, dtype),  # gate
        "w3": truncated_normal_init(k2, (d_model, d_ff), s1, dtype),  # up
        "w2": truncated_normal_init(k3, (d_ff, d_model), s2, dtype),  # down
    }


def swiglu(params, x):
    g = jax.nn.silu(x @ params["w1"].astype(x.dtype))
    u = x @ params["w3"].astype(x.dtype)
    return (g * u) @ params["w2"].astype(x.dtype)


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "w1": truncated_normal_init(k1, (d_model, d_ff),
                                    1.0 / math.sqrt(d_model), dtype),
        "b1": jnp.zeros((d_ff,), dtype),
        "w2": truncated_normal_init(k2, (d_ff, d_model),
                                    1.0 / math.sqrt(d_ff), dtype),
        "b2": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(params, x):
    h = jax.nn.gelu(x @ params["w1"].astype(x.dtype)
                    + params["b1"].astype(x.dtype))
    return h @ params["w2"].astype(x.dtype) + params["b2"].astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d_model: int, dtype=jnp.float32):
    return {"table": truncated_normal_init(key, (vocab, d_model), 0.02,
                                           dtype)}


def embed(params, ids, dtype):
    return params["table"].astype(dtype)[ids]


def unembed(params, x, table=None):
    """Project to vocab logits; `table` for tied embeddings."""
    w = table if table is not None else params["out"]
    return x @ w.astype(x.dtype)
