"""Zamba2-style hybrid: Mamba2 backbone + a *shared* attention block
(single parameter set) applied after every `attn_every` SSM layers
(arXiv:2411.15242).

Structure: G = n_layers / attn_every groups; outer scan over groups
(carrying hidden state + that group's KV cache), inner scan over the
group's Mamba2 layers. The shared block's params are closed over — the
same weights execute at every application, exactly the paper's weight
sharing. Simplification vs. the released model: the shared block consumes
the hidden state only (no concat with the original embedding); noted in
DESIGN.md.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import constrain

from . import layers as L
from .api import ArchConfig, Model, count_params, maybe_scan
from .mamba2 import _dims, mamba2_block, mamba2_layer_init
from .transformer import _norm, _norm_init, _remat, _vocab_padded, \
    logits_fn, xent_loss

BATCH = ("pod", "data")


def _groups(cfg: ArchConfig) -> int:
    assert cfg.attn_every > 0 and cfg.n_layers % cfg.attn_every == 0
    return cfg.n_layers // cfg.attn_every


def init_hybrid(cfg: ArchConfig, key):
    vp = _vocab_padded(cfg)
    keys = jax.random.split(key, 6)
    dt = cfg.param_dtype
    g = _groups(cfg)
    k = cfg.attn_every

    ks = jax.random.split(keys[1], cfg.n_layers)
    stacked = jax.vmap(lambda kk: mamba2_layer_init(kk, cfg, dt))(ks)
    # regroup leading axis L -> (G, k)
    grouped = jax.tree.map(
        lambda a: a.reshape((g, k) + a.shape[1:]), stacked)

    ka, kf = jax.random.split(keys[2])
    shared = {
        "attn_norm": _norm_init(cfg),
        "attn": L.attention_init(ka, cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.hd, dt),
        "mlp_norm": _norm_init(cfg),
        "mlp": L.swiglu_init(kf, cfg.d_model, cfg.d_ff, dt),
    }
    params = {
        "embed": L.embedding_init(keys[0], vp, cfg.d_model, dt),
        "mamba": grouped,
        "shared": shared,
        "final_norm": _norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.truncated_normal_init(
            keys[3], (cfg.d_model, vp), 1.0 / math.sqrt(cfg.d_model), dt)
    return params


def _shared_block(cfg, sp, x, positions, kv_cache, cache_index):
    h = _norm(cfg, sp["attn_norm"], x)
    attn_out, new_cache = L.attention(
        sp["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd, positions=positions, rope_theta=cfg.rope_theta,
        causal=True, kv_cache=kv_cache, cache_index=cache_index)
    x = x + attn_out
    h = _norm(cfg, sp["mlp_norm"], x)
    x = x + L.swiglu(sp["mlp"], h)
    return constrain(x, BATCH, None, None), new_cache


def make_hybrid_model(cfg: ArchConfig) -> Model:
    d_inner, nh, ds, conv_dim = _dims(cfg)
    g = _groups(cfg)

    def init(key):
        return init_hybrid(cfg, key)

    def _run(params, tokens, ssm0=None, conv0=None, kv0=None, pos0=None,
             decode=False, collect=False, cache_len=None):
        """Shared trunk for forward/prefill/decode.

        ssm0/conv0: (G,k,...) states; kv0: {k,v} (G,B,Smax,KV,hd);
        pos0: () cache write index. Returns (hidden, states)."""
        bsz, s = tokens.shape
        x = L.embed(params["embed"], tokens, cfg.compute_dtype)
        x = constrain(x, BATCH, None, None)
        if pos0 is None:
            positions = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None, :], (bsz, s))
            cache_index = 0
        else:
            positions = jnp.broadcast_to(pos0[None, None],
                                         (bsz, s)).astype(jnp.int32)
            cache_index = pos0

        def inner(carry, xs):
            x = carry
            if decode or collect:
                lp, hs, cs = xs
                x, nh_, nc_ = mamba2_block(cfg, lp, x, ssm_state=hs,
                                           conv_state=cs, decode=decode)
                return x, (nh_, nc_)
            lp = xs
            x, _, _ = mamba2_block(cfg, lp, x)
            return x, None

        def outer(carry, xs):
            x = carry
            if decode or collect:
                mp, hs, cs, ck, cv = xs
                x, states = maybe_scan(inner, x, (mp, hs, cs),
                                       cfg.scan_layers)
                x, ncache = _shared_block(cfg, params["shared"], x,
                                          positions, {"k": ck, "v": cv},
                                          cache_index)
                return x, (states[0], states[1], ncache["k"], ncache["v"])
            mp = xs
            x, _ = maybe_scan(inner, x, mp, cfg.scan_layers)
            x, _ = _shared_block(cfg, params["shared"], x, positions,
                                 None, None)
            return x, None

        if decode or collect:
            if kv0 is None:  # prefill: fresh caches (s or cache_len)
                kvshape = (g, bsz, cache_len or s, cfg.n_kv_heads, cfg.hd)
                kv0 = {"k": jnp.zeros(kvshape, cfg.compute_dtype),
                       "v": jnp.zeros(kvshape, cfg.compute_dtype)}
                ssm0 = jnp.zeros((g, cfg.attn_every, bsz, nh, ds,
                                  cfg.ssm_head_dim), jnp.float32)
                conv0 = jnp.zeros((g, cfg.attn_every, bsz, cfg.ssm_conv - 1,
                                   conv_dim), cfg.compute_dtype)
                # prefill must not pass ssm0 as h0 in chunked mode... zeros ok
            x, states = maybe_scan(outer, x, (params["mamba"], ssm0,
                                              conv0, kv0["k"], kv0["v"]),
                                   cfg.scan_layers)
            x = _norm(cfg, params["final_norm"], x)
            return x, states
        x, _ = maybe_scan(_remat(cfg, outer), x, params["mamba"],
                          cfg.scan_layers)
        x = _norm(cfg, params["final_norm"], x)
        return x, None

    def loss(params, batch):
        hidden, _ = _run(params, batch["tokens"])
        lg = logits_fn(cfg, params, hidden)
        l = xent_loss(cfg, lg, batch["labels"])
        return l, {"xent": l}

    def prefill(params, batch, cache_len=None):
        tokens = batch["tokens"]
        s = tokens.shape[1]
        hidden, states = _run(params, tokens, collect=True,
                              cache_len=cache_len)
        hs, cs, ck, cv = states
        lg = logits_fn(cfg, params, hidden[:, -1:, :])
        return lg, {"ssm": hs, "conv": cs, "kv_k": ck, "kv_v": cv,
                    "len": jnp.full((), s, jnp.int32)}

    def decode_step(params, cache, batch):
        hidden, states = _run(params, batch["tokens"], ssm0=cache["ssm"],
                              conv0=cache["conv"],
                              kv0={"k": cache["kv_k"], "v": cache["kv_v"]},
                              pos0=cache["len"], decode=True)
        hs, cs, ck, cv = states
        lg = logits_fn(cfg, params, hidden)
        return lg, {"ssm": hs, "conv": cs, "kv_k": ck, "kv_v": cv,
                    "len": cache["len"] + 1}

    def param_specs(axes: dict):
        model = axes.get("model", 1)
        vp = _vocab_padded(cfg)
        h_ok = nh % model == 0
        a_ok = cfg.n_heads % model == 0
        kv_ok = cfg.n_kv_heads % model == 0
        ff_ok = cfg.d_ff % model == 0
        v_ok = vp % model == 0
        mamba = {
            "norm": {"scale": P(None, None, None)},
            "in_proj": P(None, None, "data", "model" if h_ok else None),
            "conv_w": P(None, None, None, None),
            "conv_b": P(None, None, None),
            "A_log": P(None, None, "model" if h_ok else None),
            "D": P(None, None, "model" if h_ok else None),
            "dt_bias": P(None, None, "model" if h_ok else None),
            "gate_norm": {"scale": P(None, None,
                                     "model" if h_ok else None)},
            "out_proj": P(None, None, "model" if h_ok else None, "data"),
        }
        shared = {
            "attn_norm": {"scale": P(None)},
            "attn": {
                "wq": P("data", "model" if a_ok else None),
                "wk": P("data", "model" if kv_ok else None),
                "wv": P("data", "model" if kv_ok else None),
                "wo": P("model" if a_ok else None, "data"),
            },
            "mlp_norm": {"scale": P(None)},
            "mlp": {
                "w1": P("data", "model" if ff_ok else None),
                "w3": P("data", "model" if ff_ok else None),
                "w2": P("model" if ff_ok else None, "data"),
            },
        }
        specs = {
            "embed": {"table": P("model" if v_ok else None, "data")},
            "mamba": mamba,
            "shared": shared,
            "final_norm": {"scale": P(None)},
        }
        if not cfg.tie_embeddings:
            specs["lm_head"] = P("data", "model" if v_ok else None)
        return specs

    def cache_specs(axes: dict):
        model = axes.get("model", 1)
        h_ok = nh % model == 0
        kv_ok = cfg.n_kv_heads % model == 0
        return {"ssm": P(None, None, BATCH, "model" if h_ok else None,
                         None, None),
                "conv": P(None, None, BATCH, None, None),
                "kv_k": (P(None, BATCH, None, "model", None) if kv_ok
                         else P(None, BATCH, "model", None, None)),
                "kv_v": (P(None, BATCH, None, "model", None) if kv_ok
                         else P(None, BATCH, "model", None, None)),
                "len": P()}

    def input_specs(shape, kind: str):
        b, s = shape["global_batch"], shape["seq_len"]
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if kind == "train":
            return {"tokens": tok, "labels": tok}
        if kind == "prefill":
            return {"tokens": tok}
        if kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        raise ValueError(kind)

    def active_param_count() -> int:
        vp = _vocab_padded(cfg)
        per_mamba = (cfg.d_model * (2 * d_inner + 2 * ds + nh)
                     + cfg.ssm_conv * conv_dim + d_inner * cfg.d_model)
        shared = (2 * cfg.d_model * cfg.n_heads * cfg.hd
                  + 2 * cfg.d_model * cfg.n_kv_heads * cfg.hd
                  + 3 * cfg.d_model * cfg.d_ff)
        emb = vp * cfg.d_model * (1 if cfg.tie_embeddings else 2)
        # shared block executes G times but its params count once;
        # *active* compute counts every application
        return cfg.n_layers * per_mamba + g * shared + emb

    return Model(cfg=cfg, init=init, loss=loss, prefill=prefill,
                 decode_step=decode_step, param_specs=param_specs,
                 cache_specs=cache_specs, input_specs=input_specs,
                 param_count=count_params,
                 active_param_count=active_param_count)
