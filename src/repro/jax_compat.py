"""Compatibility shims over the drifting jax mesh/sharding surface.

The mesh API has been renamed/moved repeatedly across jax releases:
``jax.sharding.get_abstract_mesh``, ``jax.set_mesh``,
``jax.sharding.AxisType`` and the ``axis_types=`` kwarg of
``jax.make_mesh`` all exist only on newer releases, while older ones
spell the same concepts through the classic ``with mesh:`` resource
environment. Model/launch code calling the new spellings directly
fails with ``AttributeError`` the moment the installed jax moves —
that failure took out 55 seed tests.

Every shim here resolves the new API with ``getattr`` first and falls
back to an equivalent older-jax formulation, so the same call sites run
on both sides of the rename. Only this module is allowed to touch
``jax._src`` — keep the fallback surface in one place.
"""
from __future__ import annotations

import jax


def axis_type_auto():
    """``jax.sharding.AxisType.Auto`` where it exists, else None."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    return getattr(axis_type, "Auto", None) if axis_type is not None else None


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with Auto axis types when the kwarg exists.

    Older jax has no ``axis_types=`` (every axis is implicitly "auto");
    newer jax wants it spelled out for the explicit-sharding rollout.
    """
    kwargs = {} if devices is None else {"devices": devices}
    auto = axis_type_auto()
    if auto is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=(auto,) * len(axis_names),
                                 **kwargs)
        except TypeError:
            pass                      # AxisType exists but the kwarg doesn't
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def set_mesh(mesh):
    """Context manager activating ``mesh`` for the enclosed block.

    Newer jax: ``jax.set_mesh``. Older jax: the ``Mesh`` object is
    itself the context manager (the classic resource environment), and
    ``get_abstract_mesh`` below reads through it.
    """
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every jax release
    (older jax returns a one-element list of dicts, newer the dict)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def get_abstract_mesh():
    """The active abstract mesh, or None when no mesh is active.

    Callers must handle both None and a mesh whose ``.empty`` is True
    (the two "no mesh" spellings across releases).
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from jax._src import mesh as _mesh_lib
    getter = getattr(_mesh_lib, "get_abstract_mesh", None)
    if getter is not None:
        got = getter()
        if isinstance(got, _mesh_lib.AbstractMesh):
            return got
    # classic resource env: `with mesh:` / the set_mesh fallback above
    env = getattr(_mesh_lib.thread_resources, "env", None)
    physical = getattr(env, "physical_mesh", None)
    if physical is not None and not physical.empty:
        return physical.abstract_mesh
    return None
