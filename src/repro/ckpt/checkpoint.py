"""Fault-tolerant, mesh-elastic checkpointing.

Layout:  <dir>/step_<N>/
            manifest.json        — tree structure, dtypes, shapes, step,
                                   data-pipeline state, config digest
            arr_<i>.npy          — one file per leaf (host-gathered)

Guarantees:
  * atomic: written to step_<N>.tmp, fsynced, then os.rename'd — a crash
    mid-save never corrupts the latest checkpoint (restart-safe).
  * elastic: leaves are stored as *global* arrays with no mesh metadata;
    `restore_checkpoint(..., mesh, spec_tree)` device_puts them under ANY
    mesh/sharding — scale-up/scale-down restarts re-shard for free.
  * retention: keep the newest `keep` checkpoints, best-effort cleanup.

On a real multi-host pod each host would write only its shard slice
(tensorstore-style); this single-process container holds the whole array,
so host-gather is exact and the elastic semantics are identical.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Optional

import jax
import numpy as np

from repro.parallel.sharding import tree_shardings


def _leaves_with_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save_checkpoint(directory: str, step: int, tree, extra: Optional[dict]
                    = None, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, treedef = _leaves_with_paths(tree)
    try:
        treedef_hex = jax.tree_util.tree_structure(
            tree).serialize_using_proto().hex()
    except Exception:
        treedef_hex = None    # custom nodes aren't proto-serializable
    manifest = {
        "step": step,
        "treedef": treedef_hex,
        "n_leaves": len(flat),
        "leaves": [],
        "extra": extra or {},
    }
    for i, leaf in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if arr.dtype.kind not in "biufc":   # ml_dtypes (bf16, fp8, ...)
            arr = arr.view(f"u{arr.dtype.itemsize}")
        np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
        manifest["leaves"].append({"dtype": dtype_name,
                                   "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _cleanup(directory, keep)
    return final


def _cleanup(directory: str, keep: int):
    steps = sorted(_all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        try:
            shutil.rmtree(os.path.join(directory, f"step_{s:08d}"))
        except OSError:
            pass


def _all_steps(directory: str):
    out = []
    if not os.path.isdir(directory):
        return out
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            path = os.path.join(directory, name, "manifest.json")
            if os.path.exists(path):
                out.append(int(name[5:]))
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = _all_steps(directory)
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like_tree, mesh=None,
                       spec_tree=None):
    """Restore into the structure of ``like_tree``. If mesh+spec_tree are
    given, leaves are device_put with those shardings (elastic re-shard).
    Returns (tree, extra)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = jax.tree.flatten(like_tree)
    assert len(flat_like) == manifest["n_leaves"], \
        f"checkpoint has {manifest['n_leaves']} leaves, model expects " \
        f"{len(flat_like)} — architecture/optimizer mismatch"
    leaves = []
    for i, like in enumerate(flat_like):
        arr = np.load(os.path.join(path, f"arr_{i}.npy"))
        meta = manifest["leaves"][i]
        if str(arr.dtype) != meta["dtype"]:   # raw-viewed exotic dtype
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, meta["dtype"])))
        assert list(arr.shape) == list(like.shape), \
            f"leaf {i}: checkpoint shape {arr.shape} != model {like.shape}"
        leaves.append(arr)
    if mesh is not None and spec_tree is not None:
        shardings = jax.tree.flatten(tree_shardings(mesh, spec_tree))[0]
        leaves = [jax.device_put(a, s) for a, s in zip(leaves, shardings)]
    else:
        leaves = [jax.device_put(a) for a in leaves]
    tree = jax.tree.unflatten(treedef, leaves)
    return tree, manifest.get("extra", {})
