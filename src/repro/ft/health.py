"""Health monitoring: device liveness probe + straggler watchdog.

On a real multi-host deployment these hooks sit on every host: the device
probe runs a tiny collective each heartbeat (a dead/hung chip fails it →
the job controller evicts the host and the elastic restart path kicks in),
and the watchdog flags steps whose wall time exceeds a robust multiple of
the running median — the standard straggler-mitigation signal (redispatch
slow hosts / exclude from the next allocation). In this single-process
container the same code paths run and are unit-tested; the eviction action
is a callback.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp


def check_devices(timeout_s: float = 30.0) -> dict:
    """Run a tiny reduction on every device; returns health report."""
    report = {}
    for dev in jax.devices():
        # reprolint: disable=RL004 -- float() materializes the result, which is the fence
        t0 = time.monotonic()
        try:
            x = jax.device_put(jnp.ones((8,)), dev)
            val = float(jnp.sum(x))
            ok = val == 8.0 and (time.monotonic() - t0) < timeout_s
        except Exception:
            ok = False
        report[str(dev)] = ok
    return report


class StepWatchdog:
    """Flags straggler steps: wall time > threshold × running median."""

    def __init__(self, window: int = 32, threshold: float = 2.0,
                 on_straggler: Optional[Callable[[int, float, float],
                                                 None]] = None):
        self.times = deque(maxlen=window)
        self.threshold = threshold
        self.on_straggler = on_straggler
        self.stragglers = []
        self._t0 = None
        self._step = 0

    def start(self, step: int):
        self._step = step
        self._t0 = time.monotonic()

    def stop(self) -> float:
        # reprolint: disable=RL004 -- fencing is the caller's contract: stop() after block_until_ready
        dt = time.monotonic() - self._t0
        med = self.median()
        if med is not None and dt > self.threshold * med:
            self.stragglers.append((self._step, dt, med))
            if self.on_straggler:
                self.on_straggler(self._step, dt, med)
        self.times.append(dt)
        return dt

    def median(self):
        if len(self.times) < 4:
            return None
        s = sorted(self.times)
        return s[len(s) // 2]
