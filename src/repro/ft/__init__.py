from .admission import AdmissionPolicy, UNBOUNDED
from .budget import Budget, UNLIMITED
from .degrade import Rung, engage, ladder, rung_for_attempt
from .elastic import RestartableTrainer
from .health import StepWatchdog, check_devices
from .inject import (FaultPlan, FaultSpecError, ShardLossError, active,
                     faults, install_from_env)
from .retry import RetryPolicy, backoff_ms, with_retry

__all__ = [
    "AdmissionPolicy", "UNBOUNDED",
    "Budget", "UNLIMITED",
    "Rung", "engage", "ladder", "rung_for_attempt",
    "RestartableTrainer",
    "StepWatchdog", "check_devices",
    "FaultPlan", "FaultSpecError", "ShardLossError",
    "active", "faults", "install_from_env",
    "RetryPolicy", "backoff_ms", "with_retry",
]
