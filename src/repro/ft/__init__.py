from .elastic import RestartableTrainer
from .health import StepWatchdog, check_devices

__all__ = ["RestartableTrainer", "StepWatchdog", "check_devices"]
