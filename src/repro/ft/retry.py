"""Retry with exponential backoff and deterministic jitter.

Wraps batch dispatch in the serving loop.  The backoff schedule is fully
deterministic given ``(policy, seed)`` so the fake-clock tests can assert
exact sleep sequences; jitter decorrelates real deployments where many
lanes retry at once.
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass
from typing import Callable, Optional, Tuple


@dataclass(frozen=True)
class RetryPolicy:
    """``retries`` attempts after the first, exponential base/factor, jitter.

    ``jitter`` is the fraction of the nominal delay drawn uniformly and
    added on top (0.0 = none, 0.5 = up to +50%).
    """

    retries: int = 2
    base_ms: float = 10.0
    factor: float = 2.0
    jitter: float = 0.25

    def __post_init__(self):
        if self.retries < 0:
            raise ValueError(f"retries must be >= 0, got {self.retries}")
        if self.base_ms < 0 or self.factor < 1.0:
            raise ValueError("base_ms must be >= 0 and factor >= 1.0")


def _unit(seed: int, attempt: int) -> float:
    h = hashlib.sha256(f"retry:{seed}:{attempt}".encode()).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


def backoff_ms(policy: RetryPolicy, attempt: int, seed: int = 0) -> float:
    """Delay before retry ``attempt`` (0-indexed), jitter included."""
    nominal = policy.base_ms * (policy.factor ** attempt)
    return nominal * (1.0 + policy.jitter * _unit(seed, attempt))


def with_retry(fn: Callable[[int], object],
               policy: RetryPolicy,
               *,
               seed: int = 0,
               retryable: Tuple[type, ...] = (Exception,),
               sleep: Optional[Callable[[float], None]] = None,
               on_retry: Optional[Callable[[int, BaseException], None]] = None):
    """Call ``fn(attempt)`` until it succeeds or the policy is exhausted.

    ``fn`` receives the attempt index so callers can escalate (e.g. walk a
    degradation ladder) rather than blindly repeat.  Non-``retryable``
    exceptions propagate immediately; the final attempt's exception
    propagates once retries are exhausted.  Returns ``(result, attempts)``
    where ``attempts`` counts calls made (1 = first try succeeded).
    """
    sleep = sleep if sleep is not None else time.sleep
    attempt = 0
    while True:
        try:
            return fn(attempt), attempt + 1
        except retryable as exc:
            if attempt >= policy.retries:
                raise
            if on_retry is not None:
                on_retry(attempt, exc)
            delay = backoff_ms(policy, attempt, seed)
            if delay > 0:
                sleep(delay / 1000.0)
            attempt += 1
