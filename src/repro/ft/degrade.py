"""Graceful-degradation ladder for graph queries.

When a batch keeps failing after retries, the serving loop walks down a
ladder of cheaper/safer configurations instead of failing the queries
outright:

  backend    pallas → xla              (same placement, same results)
  placement  2d → sharded → single     (same results, less parallelism)
  algorithm  bc exact → sampled        (approximate, ``samples=k``)
             reach k hops → k//2 hops  (approximate, smaller neighborhood)

Every step down is *declared* through the PR 9 registry machinery
(:func:`repro.core.backend.declare_fallback`) and logged through
``repro.obs``, and the serving layer stamps ``degraded=true`` on the
affected queries — a downgrade is never silent.

:func:`ladder` builds the rung sequence for a query; the serve loop indexes
into it with the retry attempt number, so attempt 0 runs the requested
configuration and each subsequent attempt runs one rung lower.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import List, Optional

from ..core import backend as B
from ..obs import get_logger

_log = get_logger("repro.ft.degrade")

# placement ladder, strongest first; degradation walks left→right
_PLACEMENT_ORDER = (B.TWOD, B.SHARDED, B.SINGLE)


@dataclass(frozen=True)
class Rung:
    """One configuration on the degradation ladder."""

    backend: str
    placement: str
    hops: Optional[int] = None    # reach: reduced neighborhood radius
    sampled: bool = False         # bc: Brandes-Pich estimator
    reason: str = ""              # how this rung differs from the one above

    @property
    def approximate(self) -> bool:
        return self.sampled or self.reason.startswith("reach")


def ladder(kind: str, backend: str, placement: str = B.SINGLE,
           *, hops: Optional[int] = None) -> List[Rung]:
    """Rung sequence for ``kind`` starting at the requested configuration.

    Rung 0 is always the request itself (``reason=""``); later rungs each
    change exactly one thing, ordered exact-preserving first (backend, then
    placement) and approximation last.
    """
    rungs = [Rung(backend=backend, placement=placement, hops=hops)]

    def _push(reason, **kw):
        rungs.append(replace(rungs[-1], reason=reason, **kw))

    if backend == B.PALLAS:
        _push("backend pallas→xla", backend=B.XLA)
    if placement in _PLACEMENT_ORDER:
        for lower in _PLACEMENT_ORDER[_PLACEMENT_ORDER.index(placement) + 1:]:
            _push(f"placement {rungs[-1].placement}→{lower}",
                  placement=lower)
    if kind == "bc":
        _push("bc exact→sampled", sampled=True)
    if kind == "reach" and hops is not None and hops > 1:
        _push(f"reach hops {hops}→{max(1, hops // 2)}",
              hops=max(1, hops // 2))
    return rungs


def rung_for_attempt(rungs: List[Rung], attempt: int) -> Rung:
    """The rung to run on retry ``attempt`` (clamped to the bottom)."""
    return rungs[min(attempt, len(rungs) - 1)]


def engage(kind: str, rung: Rung, exc: Optional[BaseException] = None) -> None:
    """Record a downgrade: declare it in the registry and log it.

    Idempotent per (kind, placement) — ``declare_fallback`` just overwrites
    the reason — so a hot serve loop can call it on every degraded flush.
    """
    if not rung.reason:
        return
    B.declare_fallback(kind, rung.placement,
                       reason=f"serve-time degradation: {rung.reason}")
    cause = f" after {type(exc).__name__}: {exc}" if exc is not None else ""
    _log.warning("degrade kind=%s %s%s", kind, rung.reason, cause)
