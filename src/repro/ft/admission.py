"""Admission control and load shedding for the serving loop.

Each query kind owns a bounded queue; when a queue is full (or the total
number of pending queries crosses the global cap) new arrivals are *shed* —
turned into structured per-query rejections the caller can see and retry —
rather than growing the queue without bound or raising out of the stream.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional


@dataclass(frozen=True)
class AdmissionPolicy:
    """Queue bounds: per-kind cap and a global pending cap.

    ``None`` means unbounded (the pre-admission behaviour).  ``max_per_kind``
    is the number of queries a single kind may have waiting for a flush;
    ``max_pending`` bounds the sum across kinds.
    """

    max_per_kind: Optional[int] = None
    max_pending: Optional[int] = None

    def __post_init__(self):
        for name in ("max_per_kind", "max_pending"):
            v = getattr(self, name)
            if v is not None and v < 1:
                raise ValueError(f"AdmissionPolicy.{name} must be >= 1, "
                                 f"got {v}")

    def admit(self, kind: str, pending: Dict[str, list]) -> Optional[str]:
        """None to admit, else a short shed-reason string."""
        if (self.max_per_kind is not None
                and len(pending.get(kind, ())) >= self.max_per_kind):
            return f"queue for kind={kind} full ({self.max_per_kind})"
        if self.max_pending is not None:
            total = sum(len(v) for v in pending.values())
            if total >= self.max_pending:
                return f"global pending queue full ({self.max_pending})"
        return None


UNBOUNDED = AdmissionPolicy()
