"""Elastic, restartable training driver.

`RestartableTrainer.run` executes a step function in a crash-tolerant
loop: checkpoints every `ckpt_every` steps, and on any exception (a real
device loss, or the injected `FailAt` used by tests/examples) it restores
the latest checkpoint — possibly onto a *different mesh* (elastic
scale-up/down), since checkpoints are mesh-agnostic (ckpt/checkpoint.py).

This is the single-process skeleton of the multi-host control loop: on a
cluster, the same restore path runs on every host after the scheduler
replaces a failed node.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass
from typing import Callable, Optional

import jax

from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
from .health import StepWatchdog

log = logging.getLogger("repro.ft")


class FailAt(Exception):
    """Injected failure for fault-tolerance tests/examples."""


@dataclass
class RestartableTrainer:
    ckpt_dir: str
    ckpt_every: int = 50
    max_restarts: int = 3

    def run(self, *, init_state: Callable[[], tuple],
            step_fn: Callable, data_state: Callable[[], dict],
            restore_data: Callable[[dict], None], total_steps: int,
            fail_at: Optional[int] = None,
            mesh=None, spec_tree=None) -> dict:
        """init_state() -> (params, opt_state); step_fn(state, step) ->
        (state, metrics). Returns run report."""
        restarts = 0
        watchdog = StepWatchdog()
        history = []

        while True:
            try:
                state = init_state()
                start = 0
                last = latest_step(self.ckpt_dir)
                if last is not None:
                    state, extra = restore_checkpoint(
                        self.ckpt_dir, last, state, mesh=mesh,
                        spec_tree=spec_tree)
                    restore_data(extra.get("data", {"step": last,
                                                    "seed": 0}))
                    start = last
                    log.info("resumed from step %d", last)
                for step in range(start, total_steps):
                    if fail_at is not None and step == fail_at \
                            and restarts == 0:
                        raise FailAt(f"injected failure at step {step}")
                    watchdog.start(step)
                    state, metrics = step_fn(state, step)
                    dt = watchdog.stop()
                    history.append({"step": step, "dt": dt,
                                    **{k: float(v) for k, v
                                       in metrics.items()}})
                    if (step + 1) % self.ckpt_every == 0 \
                            or step + 1 == total_steps:
                        save_checkpoint(self.ckpt_dir, step + 1, state,
                                        extra={"data": data_state()})
                return {"completed": True, "restarts": restarts,
                        "history": history,
                        "stragglers": watchdog.stragglers}
            except FailAt as e:
                restarts += 1
                log.warning("failure: %s — restart %d", e, restarts)
                if restarts > self.max_restarts:
                    return {"completed": False, "restarts": restarts,
                            "history": history,
                            "stragglers": watchdog.stragglers}
                continue
