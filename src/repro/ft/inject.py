"""Deterministic, seeded fault injection for the graph engine.

This is the chaos rig the robustness layer is tested against.  A
:class:`FaultPlan` is parsed from a compact spec string and installed either
programmatically (the :func:`faults` context manager) or from the
``REPRO_FAULTS`` environment variable (picked up once per process by
:func:`install_from_env`, which ``graph_serve`` calls at startup).

Spec syntax — semicolon-separated clauses, each ``kind[:site]@prob``::

    provider_miss@0.5;nan@0.25;straggler:flush@0.1;shard_loss@0.2

Fault kinds:

``provider_miss``
    :func:`repro.core.backend._lookup` raises ``ProviderMissError`` as if the
    provider table had no entry — exercises the retry + degradation ladder.
``nan``
    Poisons kernel output fields with NaN after a batch completes —
    exercises the serve-side NaN/Inf guardrail.
``straggler``
    Adds an artificial host-side delay to a batch flush — exercises the
    :class:`repro.ft.health.StepWatchdog` straggler gauge.
``shard_loss``
    Raises :class:`ShardLossError` from the sharded runner as if a shard's
    device dropped out — exercises the 2d→sharded→single placement ladder.

Determinism: each (kind, site) pair draws from its own counter-indexed
stream seeded by ``(seed, kind, site)``, so a given call site sees the same
fault schedule regardless of what other sites do, and two runs with the
same seed inject identical faults.  When no plan is installed every hook is
a single ``None`` check — bit-parity of the healthy path is preserved by
construction.
"""
from __future__ import annotations

import contextlib
import hashlib
import os
from typing import Dict, Optional, Tuple

KINDS = ("provider_miss", "nan", "straggler", "shard_loss")

_PLAN: Optional["FaultPlan"] = None
_ENV_DONE = False


class ShardLossError(RuntimeError):
    """A graph shard's device dropped out mid-batch (injected or real)."""


class FaultSpecError(ValueError):
    """A ``REPRO_FAULTS`` spec string could not be parsed."""


def _parse(spec: str) -> Dict[str, Tuple[str, float]]:
    plan: Dict[str, Tuple[str, float]] = {}
    for clause in spec.split(";"):
        clause = clause.strip()
        if not clause:
            continue
        head, sep, prob_s = clause.partition("@")
        if not sep:
            raise FaultSpecError(
                f"fault clause {clause!r} has no '@prob' part "
                f"(expected 'kind[:site]@prob')")
        kind, _, site = head.partition(":")
        kind = kind.strip()
        if kind not in KINDS:
            raise FaultSpecError(
                f"unknown fault kind {kind!r}; known kinds: {', '.join(KINDS)}")
        try:
            prob = float(prob_s)
        except ValueError:
            raise FaultSpecError(f"fault clause {clause!r}: bad probability "
                                 f"{prob_s!r}") from None
        if not 0.0 <= prob <= 1.0:
            raise FaultSpecError(
                f"fault clause {clause!r}: probability must be in [0, 1]")
        plan[kind] = (site.strip(), prob)
    return plan


def _draw(seed: int, kind: str, site: str, n: int) -> float:
    """n-th uniform in [0, 1) of the (seed, kind, site) stream."""
    h = hashlib.sha256(f"{seed}:{kind}:{site}:{n}".encode()).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


class FaultPlan:
    """Parsed fault schedule with per-site deterministic draw counters."""

    def __init__(self, spec: str, seed: int = 0):
        self.spec = spec
        self.seed = int(seed)
        self.clauses = _parse(spec)
        self._counters: Dict[Tuple[str, str], int] = {}
        self.fired: Dict[str, int] = {k: 0 for k in self.clauses}

    def should(self, kind: str, site: str = "") -> bool:
        """Deterministically decide whether this call site faults now."""
        clause = self.clauses.get(kind)
        if clause is None:
            return False
        want_site, prob = clause
        if want_site and want_site != site:
            return False
        key = (kind, site)
        n = self._counters.get(key, 0)
        self._counters[key] = n + 1
        hit = _draw(self.seed, kind, site, n) < prob
        if hit:
            self.fired[kind] += 1
        return hit

    def __repr__(self):
        return f"FaultPlan({self.spec!r}, seed={self.seed})"


def active() -> Optional[FaultPlan]:
    """The installed plan, or None (the fast path) when chaos is off."""
    return _PLAN


@contextlib.contextmanager
def faults(spec: str, seed: int = 0):
    """Install a seeded fault plan for the duration of the block."""
    global _PLAN
    prev = _PLAN
    _PLAN = FaultPlan(spec, seed)
    try:
        yield _PLAN
    finally:
        _PLAN = prev


def install_from_env() -> Optional[FaultPlan]:
    """Install a process-wide plan from ``REPRO_FAULTS`` (idempotent).

    ``REPRO_FAULTS_SEED`` selects the stream seed (default 0).  Returns the
    installed plan, the already-installed one, or None when the variable is
    unset.
    """
    global _PLAN, _ENV_DONE
    if _ENV_DONE:
        return _PLAN
    _ENV_DONE = True
    spec = os.environ.get("REPRO_FAULTS", "").strip()
    if not spec:
        return _PLAN
    seed = int(os.environ.get("REPRO_FAULTS_SEED", "0"))
    _PLAN = FaultPlan(spec, seed)
    return _PLAN


def _reset_for_tests():
    """Clear installed plan and env latch (test helper)."""
    global _PLAN, _ENV_DONE
    _PLAN = None
    _ENV_DONE = False
