"""Per-query execution budgets.

A :class:`Budget` bounds how much work a single query may consume: a cap on
BSP iterations (enforced inside the jitted loop — it just lowers the loop's
``max_iter`` guard, so the loop stays jit-clean) and a wall-clock deadline in
milliseconds (enforced host-side between flushes by the serving loop, where
a host sync already happens).  Both are optional; the default budget is
unbounded and identical to the pre-budget behaviour.

The dataclass is frozen (hashable) so it can ride in jit static arguments.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class Budget:
    """Bounds for one query: ``max_iters`` BSP steps, ``wall_ms`` wall clock.

    ``max_iters=None`` leaves the primitive's own iteration guard in place;
    ``wall_ms=None`` disables the deadline.
    """

    max_iters: Optional[int] = None
    wall_ms: Optional[float] = None

    def __post_init__(self):
        if self.max_iters is not None and self.max_iters < 1:
            raise ValueError(f"Budget.max_iters must be >= 1, "
                             f"got {self.max_iters}")
        if self.wall_ms is not None and self.wall_ms <= 0:
            raise ValueError(f"Budget.wall_ms must be > 0, got {self.wall_ms}")

    def cap_iters(self, max_iter: int) -> int:
        """Clamp a primitive's natural iteration guard to this budget."""
        if self.max_iters is None:
            return max_iter
        return min(max_iter, self.max_iters)

    def deadline_from(self, t0_s: float) -> Optional[float]:
        """Absolute monotonic deadline (seconds) for a query enqueued at t0."""
        if self.wall_ms is None:
            return None
        return t0_s + self.wall_ms / 1000.0


UNLIMITED = Budget()
