import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes and extract the roofline terms.

Per cell:
  1. FULL program (real n_layers, scanned): jit → lower → compile. This is
     the deliverable: the compile must succeed on the production mesh, and
     compiled.memory_analysis() proves the per-device footprint.
  2. COST PROBES: XLA's cost_analysis counts a while-loop body once
     regardless of trip count, so per-layer cost comes from two small
     UNROLLED programs (k1 and k2 layers): marginal = c(k2)−c(k1) per
     layer-unit, fixed = c(k1) − k1·marginal, total ≈ fixed + units·marginal.
     The same differencing extrapolates the collective bytes parsed from
     the probes' post-SPMD HLO.

Backend caveat (recorded in EXPERIMENTS.md): the CPU float-normalization
pass upcasts some bf16 ops to f32, so absolute byte terms are upper
bounds; §Perf compares deltas under the identical backend.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out results/dryrun.json
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, shapes_for
from repro.obs.log import get_logger
from repro.jax_compat import set_mesh
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes
from repro.models import build_model
from repro.parallel.sharding import (fit_sharding, spec_for_mesh,
                                     tree_shardings)
from repro.train.optimizer import (AdamWState, adamw, make_schedule,
                                   moment_specs)

# archs that need int8 optimizer moments to fit v5e HBM (DESIGN.md §6)
QUANT_OPT_ARCHS = {"llama3-405b", "kimi-k2-1t-a32b", "qwen3-moe-235b-a22b"}

# microbatch (gradient-accumulation) factor per arch for train_4k — the
# production memory plan: activation temps ÷ accum (DESIGN.md §6)
GRAD_ACCUM = {
    "llama3-405b": 16, "kimi-k2-1t-a32b": 16, "qwen3-moe-235b-a22b": 16,
    "yi-6b": 8, "starcoder2-15b": 8, "whisper-large-v3": 4,
    "minicpm-2b": 4, "qwen2-vl-2b": 4, "mamba2-780m": 4, "zamba2-2.7b": 4,
}

COLLECTIVE_RE = re.compile(
    r"=\s+(\(?)([a-z0-9\[\],{}\s]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
GROUPS_RE = re.compile(r"replica_groups=(?:\{\{([0-9,]+)\}|\[(\d+),(\d+)\])")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Sum collective output bytes (per-device shapes, post-SPMD) and a
    bytes-over-links estimate: all-reduce → 2×out (RS+AG phases);
    reduce-scatter → out×group (input is what moves); others → out."""
    per_op = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        op = m.group(3)
        shapes = SHAPE_RE.findall(m.group(2))
        out_bytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        g = 1
        gm = GROUPS_RE.search(line)
        if gm:
            g = (len(gm.group(1).split(",")) if gm.group(1) is not None
                 else int(gm.group(3)))
        if op == "all-reduce":
            link_bytes = 2.0 * out_bytes
        elif op == "reduce-scatter":
            link_bytes = float(out_bytes) * g
        else:
            link_bytes = float(out_bytes)
        rec = per_op.setdefault(op, {"count": 0, "bytes": 0.0,
                                     "link_bytes": 0.0})
        rec["count"] += 1
        rec["bytes"] += out_bytes
        rec["link_bytes"] += link_bytes
        total += link_bytes
    return {"per_op": per_op, "link_bytes": total}


def _sds_with_sharding(tree_sds, shardings):
    """Attach shardings to ShapeDtypeStructs, refitting each spec to the
    leaf's shape (drops non-divisible axes)."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=fit_sharding(sh.mesh, s.shape, sh.spec)),
        tree_sds, shardings)


def _probe_layers(cfg):
    """(k1, k2, units): probe layer counts and the full unit count."""
    if cfg.family == "hybrid":
        return cfg.attn_every, 2 * cfg.attn_every, \
            cfg.n_layers // cfg.attn_every
    return 1, 2, cfg.n_layers


def _with_layers(cfg, k):
    kw = dict(n_layers=k, scan_layers=False)
    if cfg.family == "encdec":
        kw.update(n_enc_layers=k, n_dec_layers=k)
    return cfg.replace(**kw)


def lower_program(cfg, shape: dict, kind: str, mesh, quant: bool,
                  grad_accum: int = 1):
    """Build + lower + compile one program. Returns compiled executable.

    grad_accum > 1 microbatches the train step (batch leaves become
    (accum, mb, ...) with mb sharded over pod×data): activation memory is
    divided by accum — the production memory plan for the big archs.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    model = build_model(cfg)
    axes = mesh_axis_sizes(mesh)

    with set_mesh(mesh):
        pspecs = model.param_specs(axes)
        pshard = tree_shardings(mesh, pspecs)
        params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        params_sds = _sds_with_sharding(params_sds, pshard)

        batch_sds = model.input_specs(shape, kind)
        bspec = spec_for_mesh(P(("pod", "data")), mesh)

        def batch_shard(s):
            if len(s.shape) == 0:
                sp = P()
            elif s.shape[0] == shape["global_batch"]:
                if grad_accum > 1 and kind == "train":
                    mb = s.shape[0] // grad_accum
                    nshape = (grad_accum, mb) + s.shape[1:]
                    return jax.ShapeDtypeStruct(
                        nshape, s.dtype,
                        sharding=fit_sharding(mesh, nshape,
                                              P(None, ("pod", "data"))))
                sp = P(("pod", "data"))
            else:   # (3, B, S) position ids
                if grad_accum > 1 and kind == "train":
                    mb = s.shape[1] // grad_accum
                    nshape = (grad_accum, s.shape[0], mb) + s.shape[2:]
                    return jax.ShapeDtypeStruct(
                        nshape, s.dtype,
                        sharding=fit_sharding(
                            mesh, nshape, P(None, None, ("pod", "data"))))
                sp = P(None, ("pod", "data"))
            return jax.ShapeDtypeStruct(
                s.shape, s.dtype,
                sharding=fit_sharding(mesh, s.shape, sp))

        batch_sds = jax.tree.map(batch_shard, batch_sds)

        if kind == "train":
            sched = make_schedule("cosine", 3e-4, 10000)
            opt_init, opt_update = adamw(sched, quantize_moments=quant)
            opt_sds = jax.eval_shape(opt_init, params_sds)
            ospecs = moment_specs(pspecs, params_sds,
                                  quantize_moments=quant)
            ospec_tree = AdamWState(step=P(), m=ospecs, v=ospecs)
            oshard = tree_shardings(mesh, ospec_tree)
            opt_sds = _sds_with_sharding(opt_sds, oshard)

            def loss_fn(p, mb):
                l, _ = model.loss(p, mb)
                return l

            if grad_accum > 1:
                def train_step(params, opt_state, batch):
                    def micro(acc, mb):
                        l, g = jax.value_and_grad(loss_fn)(params, mb)
                        return jax.tree.map(
                            lambda a, b: a + b.astype(jnp.float32),
                            acc, g), l
                    zeros = jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params)
                    grads, losses = jax.lax.scan(micro, zeros, batch)
                    grads = jax.tree.map(lambda g: g / grad_accum, grads)
                    new_p, new_o, _ = opt_update(grads, opt_state, params)
                    return new_p, new_o, jnp.mean(losses)
            else:
                def train_step(params, opt_state, batch):
                    loss, grads = jax.value_and_grad(loss_fn)(params,
                                                              batch)
                    new_p, new_o, _ = opt_update(grads, opt_state, params)
                    return new_p, new_o, loss

            jf = jax.jit(train_step, donate_argnums=(0, 1))
            lowered = jf.lower(params_sds, opt_sds, batch_sds)
        elif kind == "prefill":
            # constrain the emitted cache (it dominates prefill output
            # bytes — flash-decode layout per cache_specs)
            cspecs = model.cache_specs(axes)
            b, s = shape["global_batch"], shape["seq_len"]
            cache_sds_probe = jax.eval_shape(
                lambda p, bb: model.prefill(p, bb)[1], params_sds,
                batch_sds)
            cache_out_sh = jax.tree.map(
                lambda sd, sp: fit_sharding(mesh, sd.shape, sp),
                cache_sds_probe, cspecs)
            jf = jax.jit(model.prefill,
                         out_shardings=(None, cache_out_sh))
            lowered = jf.lower(params_sds, batch_sds)
        else:  # decode
            cspecs = model.cache_specs(axes)
            b, s = shape["global_batch"], shape["seq_len"]
            pf_sds = model.input_specs(
                {"global_batch": b, "seq_len": s}, "prefill")
            cache_sds = jax.eval_shape(
                lambda p, bb: model.prefill(p, bb)[1], params_sds, pf_sds)
            cache_sds = jax.tree.map(
                lambda sd, sp: jax.ShapeDtypeStruct(
                    sd.shape, sd.dtype,
                    sharding=fit_sharding(mesh, sd.shape, sp)),
                cache_sds, cspecs)
            jf = jax.jit(model.decode_step, donate_argnums=(1,))
            lowered = jf.lower(params_sds, cache_sds, batch_sds)

        compiled = lowered.compile()
    return compiled


def _cost_triplet(compiled) -> dict:
    from repro.jax_compat import cost_analysis
    cost = cost_analysis(compiled)
    coll = parse_collectives(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "transcendentals": float(cost.get("transcendentals", 0.0)),
            "coll_link_bytes": float(coll["link_bytes"]),
            "coll_per_op": coll["per_op"]}


def dryrun_cell(arch: str, shape_name: str, shape: dict, multi_pod: bool,
                verbose: bool = True, probes: bool = True) -> dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    kind = shape["kind"]
    quant = arch in QUANT_OPT_ARCHS
    accum = GRAD_ACCUM.get(arch, 1) if kind == "train" else 1

    # ---- 1. the real program: compile proof + memory analysis -----------
    # reprolint: disable=RL004 -- lower/compile is synchronous host work; nothing to fence
    t0 = time.monotonic()
    compiled = lower_program(cfg, shape, kind, mesh, quant,
                             grad_accum=accum)
    compile_s = time.monotonic() - t0
    mem = compiled.memory_analysis()

    # ---- 2. cost probes (unrolled k1/k2 layers; accum=1 — the per-step
    # flops/bytes/collectives are microbatching-invariant) -----------------
    est = None
    if probes:
        k1, k2, units = _probe_layers(cfg)
        c1 = _cost_triplet(lower_program(_with_layers(cfg, k1), shape,
                                         kind, mesh, quant))
        c2 = _cost_triplet(lower_program(_with_layers(cfg, k2), shape,
                                         kind, mesh, quant))
        per_unit_k = (k2 - k1) / (1 if cfg.family != "hybrid"
                                  else cfg.attn_every)
        n_units_probe1 = k1 if cfg.family != "hybrid" else 1
        est = {}
        for key in ("flops", "bytes", "transcendentals",
                    "coll_link_bytes"):
            marginal = max(c2[key] - c1[key], 0.0) / per_unit_k
            fixed = max(c1[key] - n_units_probe1 * marginal, 0.0)
            est[key] = fixed + units * marginal
            est[f"{key}_marginal"] = marginal
            est[f"{key}_fixed"] = fixed
        est["probe_k"] = (k1, k2, units)
        est["coll_per_op_probe2"] = c2["coll_per_op"]

    model = build_model(cfg)
    n_active = model.active_param_count()
    tokens = shape["global_batch"] * (shape["seq_len"]
                                      if kind != "decode" else 1)
    flops_factor = 6 if kind == "train" else 2
    model_flops = flops_factor * n_active * tokens

    row = {
        "arch": arch, "shape": shape_name, "kind": kind,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "compile_s": round(compile_s, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes + mem.output_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "est": est,
        "model_flops_global": model_flops,
        "n_active_params": n_active,
    }
    if verbose:
        msg = (f"{arch} × {shape_name} × {row['mesh']}: "
               f"compile {compile_s:.1f}s, peak mem/dev "
               f"{row['memory']['peak_per_device']/2**30:.2f} GiB")
        if est:
            msg += (f", est flops/dev {est['flops']:.3e}, bytes/dev "
                    f"{est['bytes']:.3e}, coll link-bytes/dev "
                    f"{est['coll_link_bytes']:.3e}")
        get_logger("dryrun").info(msg)
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=("off", "on", "both"),
                    default="off")
    ap.add_argument("--no-probes", action="store_true",
                    help="compile proof only (skip cost probes)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    archs = list(ARCH_IDS) if (args.all or args.arch is None) \
        else [args.arch]
    pods = {"off": [False], "on": [True], "both": [False, True]}[
        args.multi_pod]

    rows, failures = [], []
    for arch in archs:
        cfg = get_config(arch)
        shp = shapes_for(cfg)
        names = list(shp) if (args.all or args.shape is None) \
            else [args.shape]
        for name in names:
            if name not in shp:
                get_logger("dryrun").info(
                    f"skip {arch} × {name} "
                    f"(inapplicable for family {cfg.family})")
                continue
            for mp in pods:
                try:
                    rows.append(dryrun_cell(arch, name, shp[name], mp,
                                            probes=not args.no_probes))
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, name, mp, repr(e)))
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"rows": rows, "failures": failures}, f, indent=1)
        get_logger("dryrun").info(f"wrote {len(rows)} rows to {args.out}")
    if failures:
        get_logger("dryrun").error(f"{len(failures)} FAILURES:")
        for f_ in failures:
            get_logger("dryrun").error(f"    {f_}")
        sys.exit(1)
    get_logger("dryrun").info(f"all {len(rows)} cells compiled OK")


if __name__ == "__main__":
    main()
