"""Graph-analytics driver — the paper's own application kind.

Generates (or loads) a graph, runs the requested primitives, validates
against the numpy oracles, and reports runtime + MTEPS exactly as the
paper's evaluation does (§7: runtime is GPU-kernel time; MTEPS = edges
visited / runtime).

  PYTHONPATH=src python -m repro.launch.graph_run --graph rmat --scale 14 \
      --primitives bfs,sssp,pagerank,cc,bc,tc --validate --backend pallas

Multi-source: ``--sources 3,99,512`` runs bfs/sssp as ONE batched
multi-source program over the listed roots (per-lane validation) instead
of a single-source run; ``bc`` accumulates exactly those roots. For the
continuous-serving version of the same idea see launch/graph_serve.py.

Observability: ``--stats`` reruns each primitive with ``telemetry=``
and prints the per-iteration trajectory (frontier size, tier,
direction — the characterization tables of paper §5); ``--trace
out.json`` writes the phase spans (build/dispatch/validate) as Chrome
trace-event JSON, loadable at ui.perfetto.dev.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import obs
from repro.core import backend as B
from repro.core import graph as G
from repro.core import ref as R
from repro.core.primitives import (bc, bc_batch, bfs, bfs_batch,
                                   connected_components, label_propagation,
                                   pagerank, reach, reach_batch, sssp,
                                   sssp_batch, triangle_count,
                                   who_to_follow)
from repro.obs import telemetry as T

log = obs.get_logger("graph")


def make_graph(kind: str, scale: int, edge_factor: int, seed: int,
               index_dtype: str | None = None, encoding: str = "dense"):
    plan = dict(index_dtype=index_dtype, encoding=encoding)
    if kind == "rmat":
        return G.rmat(scale, edge_factor, seed=seed, weighted=True, **plan)
    if kind == "rgg":
        n = 1 << scale
        import math
        radius = math.sqrt(8.0 / n)   # ~avg degree 8·π/4
        return G.random_geometric(n, radius, seed=seed, weighted=True,
                                  **plan)
    if kind == "grid":
        side = int((1 << scale) ** 0.5)
        return G.grid2d(side, weighted=True, seed=seed, **plan)
    raise ValueError(kind)


def _warn_overflow(overflow: np.ndarray) -> None:
    """A nonzero BFSResult.overflow means a capped frontier dropped
    discoveries (possible only under idempotent hash culling) — the
    labels are untrustworthy and must not pass silently."""
    total = int(np.sum(overflow))
    if total:
        log.warning(f"bfs dropped {total} frontier entries "
                    f"(overflow); rerun with idempotence=False")


def run_primitive(name: str, g, src: int, validate: bool,
                  backend: str | None = None,
                  sources: list[int] | None = None,
                  hops: int = 3):
    bk = B.resolve(backend)
    t0 = time.monotonic()
    edges = g.num_edges
    ok = None
    if name == "bfs" and sources:
        r = bfs_batch(g, sources, backend=bk)
        jax.block_until_ready(r.labels)
        dt = time.monotonic() - t0
        edges = int(np.sum(np.asarray(r.edges_visited)))
        _warn_overflow(np.asarray(r.overflow))
        if validate:
            ok = all(np.array_equal(np.asarray(r.labels[i]),
                                    R.bfs_ref(g, s))
                     for i, s in enumerate(sources))
    elif name == "sssp" and sources:
        r = sssp_batch(g, sources, backend=bk)
        jax.block_until_ready(r.dist)
        dt = time.monotonic() - t0
        if validate:
            ok = all(np.allclose(np.asarray(r.dist[i]), R.sssp_ref(g, s),
                                 rtol=1e-5)
                     for i, s in enumerate(sources))
    elif name == "bc" and sources:
        r = bc_batch(g, sources, backend=bk)
        total = np.asarray(r.bc).sum(axis=0)
        dt = time.monotonic() - t0
        edges = 2 * g.num_edges * len(sources)
        if validate:
            ref = sum(R.bc_ref(g, s).astype(np.float64) for s in sources)
            ok = np.allclose(total, ref, rtol=1e-3, atol=1e-3)
    elif name == "bfs":
        r = bfs(g, src, backend=bk)
        jax.block_until_ready(r.labels)
        dt = time.monotonic() - t0
        edges = int(r.edges_visited)
        _warn_overflow(np.asarray(r.overflow))
        if validate:
            ok = np.array_equal(np.asarray(r.labels), R.bfs_ref(g, src))
    elif name == "sssp":
        r = sssp(g, src, backend=bk)
        jax.block_until_ready(r.dist)
        dt = time.monotonic() - t0
        if validate:
            ok = np.allclose(np.asarray(r.dist), R.sssp_ref(g, src),
                             rtol=1e-5)
    elif name == "pagerank":
        r = pagerank(g, max_iter=20, backend=bk)
        jax.block_until_ready(r.rank)
        dt = time.monotonic() - t0
        if validate:
            ok = np.allclose(np.asarray(r.rank), R.pagerank_ref(g,
                                                                iters=20),
                             atol=1e-6)
    elif name == "cc":
        r = connected_components(g, backend=bk)
        jax.block_until_ready(r.labels)
        dt = time.monotonic() - t0
        if validate:
            ref = R.cc_ref(g)
            a, b = np.asarray(r.labels), ref
            ok = len(np.unique(a)) == len(np.unique(b)) and np.array_equal(
                a[a == np.arange(len(a))], b[b == np.arange(len(b))])
    elif name == "bc":
        r = bc(g, src, backend=bk)
        jax.block_until_ready(r.bc)
        dt = time.monotonic() - t0
        edges = 2 * g.num_edges
        if validate:
            ok = np.allclose(np.asarray(r.bc), R.bc_ref(g, src),
                             rtol=1e-3, atol=1e-3)
    elif name == "tc":
        r = triangle_count(g, backend=bk)
        jax.block_until_ready(r.total)
        dt = time.monotonic() - t0
        if validate:
            ok = int(r.total) == R.tc_ref(g)
    elif name == "label_propagation":
        r = label_propagation(g, backend=bk)
        jax.block_until_ready(r.labels)
        dt = time.monotonic() - t0
        edges = g.num_edges * int(r.iterations)
        if validate:
            ok = np.array_equal(np.asarray(r.labels),
                                R.label_propagation_ref(g))
    elif name == "reach" and sources:
        r = reach_batch(g, sources, hops, backend=bk)
        jax.block_until_ready(r.reached)
        dt = time.monotonic() - t0
        edges = g.num_edges * hops * len(sources)
        if validate:
            ok = all(np.array_equal(np.asarray(r.reached[i]),
                                    R.reach_ref(g, s, hops))
                     for i, s in enumerate(sources))
    elif name == "reach":
        r = reach(g, src, hops, backend=bk)
        jax.block_until_ready(r.reached)
        dt = time.monotonic() - t0
        edges = g.num_edges * hops
        if validate:
            ok = np.array_equal(np.asarray(r.reached),
                                R.reach_ref(g, src, hops))
    elif name == "wtf":
        r = who_to_follow(g, src, k=min(1000, g.num_vertices - 1),
                          backend=bk)
        jax.block_until_ready(r.auth_scores)
        dt = time.monotonic() - t0
        ok = None
    else:
        raise ValueError(name)
    mteps = edges / dt / 1e6
    return dt, mteps, ok, bk


def collect_stats(name: str, g, src: int,
                  sources: list[int] | None = None,
                  backend: str | None = None, hops: int = 3):
    """Rerun ``name`` with ``telemetry=`` and return the trimmed host
    trace (lane 0 of a batched run), or None for primitives without a
    telemetry hook. A separate run on purpose: the timed run stays the
    exact program the perf numbers describe."""
    bk = B.resolve(backend)
    if name == "bfs":
        r, buf = bfs_batch(g, sources if sources else [src],
                           backend=bk, telemetry=True)
        return T.trim(buf, np.asarray(r.iterations)).lane(0)
    if name == "sssp":
        r, buf = sssp_batch(g, sources if sources else [src],
                            backend=bk, telemetry=True)
        return T.trim(buf, np.asarray(r.iterations)).lane(0)
    if name == "pagerank":
        _, buf = pagerank(g, max_iter=20, backend=bk, telemetry=True)
        return T.trim(buf)
    if name == "cc":
        _, buf = connected_components(g, backend=bk, telemetry=True)
        return T.trim(buf)
    if name == "bc":
        _, buf = bc_batch(g, sources if sources else [src],
                          backend=bk, telemetry=True)
        return T.trim(buf).lane(0)
    if name == "tc":
        _, buf = triangle_count(g, backend=bk, telemetry=True)
        return T.trim(buf)
    return None


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat",
                    choices=("rmat", "rgg", "grid"))
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--edge-factor", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--primitives",
                    default="bfs,sssp,pagerank,cc,bc,tc")
    ap.add_argument("--validate", action="store_true")
    ap.add_argument("--hops", type=int, default=3,
                    help="k for the reach primitive (k-hop reachability)")
    ap.add_argument("--src", type=int, default=None)
    ap.add_argument("--sources", default=None, metavar="S0,S1,...",
                    help="comma-separated source vertices: bfs/sssp run "
                         "as one batched multi-source program over these "
                         "roots (validated per lane), bc accumulates "
                         "exactly these roots")
    ap.add_argument("--backend", default=None,
                    choices=(B.XLA, B.PALLAS, B.AUTO),
                    help="operator backend (default: ambient context / "
                         "REPRO_BACKEND env / xla)")
    ap.add_argument("--stats", action="store_true",
                    help="print each primitive's per-iteration telemetry "
                         "trajectory (frontier / tier / direction)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write phase spans as Chrome trace-event JSON "
                         "(open at ui.perfetto.dev)")
    args = ap.parse_args(argv)

    if args.trace:
        obs.reset()
    with obs.span("build_graph", category="setup",
                  args={"kind": args.graph, "scale": args.scale}):
        g = make_graph(args.graph, args.scale, args.edge_factor,
                       args.seed)
        jax.block_until_ready(g.row_offsets)
    if args.validate:
        # structural validation first: a malformed CSR fails loudly with
        # the offending row/edge named, instead of as a wrong oracle
        from repro.core.graph import validate_graph
        validate_graph(g)
        log.info("structural validation: CSR/CSC clean")
    deg = np.diff(np.asarray(g.row_offsets))
    src = args.src if args.src is not None else int(np.argmax(deg))
    sources = ([int(s) for s in args.sources.split(",")]
               if args.sources else None)
    log.info(f"{args.graph} scale={args.scale}: n={g.num_vertices} "
             f"m={g.num_edges} max_deg={deg.max()} "
             f"src={sources if sources else src} "
             f"backend={B.resolve(args.backend)}")

    failures = 0
    for name in args.primitives.split(","):
        name = name.strip()
        with obs.span(f"run:{name}", category="dispatch",
                      args={"backend": B.resolve(args.backend)}):
            dt, mteps, ok, bk = run_primitive(
                name, g, src, args.validate, args.backend,
                sources=sources, hops=args.hops)
        status = "" if ok is None else ("  PASS" if ok else "  FAIL")
        log.info(f"{name:9s} {dt*1000:9.2f} ms  {mteps:9.2f} MTEPS"
                 f"  backend={bk}{status}")
        if ok is False:
            failures += 1
        if args.stats:
            with obs.span(f"stats:{name}", category="dispatch"):
                trace = collect_stats(name, g, src, sources=sources,
                                      backend=args.backend,
                                      hops=args.hops)
            if trace is not None and trace.steps:
                log.info(f"{name} per-iteration trajectory"
                         + (" (lane 0)" if sources else "") + ":")
                # reprolint: disable=RL005 -- multi-line table artifact; stdout is the CLI contract
                print(trace.format_table(prefix="  "))
    if args.trace:
        n_ev = obs.export_chrome_trace(args.trace)
        log.info(f"wrote {n_ev} trace events to {args.trace}")
    if failures:
        raise SystemExit(f"{failures} primitives failed validation")


if __name__ == "__main__":
    main()
