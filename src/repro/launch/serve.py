"""Batched serving driver: continuous prefill + decode with a static
request batch — the inference-side end-to-end example.

A toy request queue feeds fixed-shape slots (static shapes are the TPU
contract): incoming prompts are prefilled into a shared KV cache sized
--cache-len, then all active slots decode in lockstep; finished requests
free their slot for the next prompt. Greedy sampling.

  PYTHONPATH=src python -m repro.launch.serve --arch minicpm-2b --smoke \
      --requests 8 --batch 4 --prompt-len 32 --gen-len 16
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.log import get_logger
from repro.configs import get_config, get_smoke_config
from repro.models import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed)

    cache_len = args.prompt_len + args.gen_len
    prefill = jax.jit(functools.partial(model.prefill,
                                        cache_len=cache_len))
    decode = jax.jit(model.decode_step)

    def make_prompt_batch():
        b = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
            jnp.int32)}
        if cfg.family == "encdec":
            b["frames"] = jnp.asarray(
                rng.standard_normal((args.batch, 32, cfg.d_model)) * 0.02,
                cfg.compute_dtype)
        return b

    served = 0
    total_tokens = 0
    t0 = time.monotonic()
    while served < args.requests:
        batch = make_prompt_batch()
        logits, cache = prefill(params, batch)
        toks = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        outputs = [toks]
        for _ in range(args.gen_len - 1):
            logits, cache = decode(params, cache, {"tokens": toks})
            toks = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
            outputs.append(toks)
        gen = jax.block_until_ready(jnp.concatenate(outputs, axis=1))
        served += args.batch
        total_tokens += int(gen.size)
        get_logger("serve").info(
            f"batch done: {args.batch} requests, "
            f"sample output ids: {np.asarray(gen[0])[:8].tolist()}")
    dt = time.monotonic() - t0
    get_logger("serve").info(
        f"{served} requests, {total_tokens} tokens in {dt:.2f}s "
        f"({total_tokens/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
