"""Graph query-serving driver — batched mixed-kind query serving.

The inference-side drivers (launch/serve.py) pack token requests into
fixed-shape batch slots; this driver applies the same slot discipline to
*graph queries*, the ROADMAP's heavy-traffic scenario. A stream of
queries is packed into batches of ``--batch`` fixed slots and each batch
runs as ONE jitted multi-source program: the first batch of a kind pays
the trace, every later batch of the same (kind, shape) reuses it, and a
ragged final batch is padded with repeated sources on dead-weight slots
rather than retracing at a new shape.

The stream is no longer traversal-only: ``--kinds bfs,sssp,pagerank,reach``
serves MIXED query kinds from one stream — each kind keeps its own slot
queue (one compiled program per kind) and flushes when full, so
traversal queries (``bfs_batch`` / ``sssp_batch``), algebraic queries
(``reach_batch`` — or-and k-hop reachability) and global analytics
queries (``pagerank`` — one run answers its whole batch) interleave on
one engine. Per-kind latency is reported alongside the aggregate, and
lands in ``--json``.

Reports per-query latency (enqueue → batch completion, so queuing delay
from batch formation is included) and aggregate queries/sec.

  PYTHONPATH=src python -m repro.launch.graph_serve --graph rmat \
      --scale 10 --kinds bfs,pagerank,reach --requests 64 --batch 8

  PYTHONPATH=src python -m repro.launch.graph_serve --graph rmat \
      --scale 10 --primitive bfs --requests 64 --batch 8 --backend xla
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import backend as B
from repro.core import ref as R
from repro.core.primitives import bfs_batch, pagerank, reach_batch, \
    sssp_batch

from .graph_run import make_graph

KINDS = ("bfs", "sssp", "pagerank", "reach")


def serve(g, primitive: str, sources: np.ndarray, batch: int,
          backend: str, validate: bool = False) -> dict:
    """Serve ``sources`` in fixed batches; returns latency/qps stats."""
    run = {"bfs": bfs_batch, "sssp": sssp_batch}[primitive]
    n_q = len(sources)
    if n_q == 0:
        raise ValueError("empty query stream (requests must be > 0)")
    lat_ms = np.zeros(n_q)
    failures = 0
    overflow = 0                 # BFS discoveries dropped by the cap clamp
    answers = []                 # validated after the clock stops
    t_start = time.monotonic()
    enqueue = np.full(n_q, t_start)        # closed loop: all queries queued
    done = 0
    batches = 0
    while done < n_q:
        sl = sources[done:done + batch]
        # static-shape slots: pad the ragged tail by repeating the last
        # query (padding lanes are computed but not reported)
        srcs = np.concatenate(
            [sl, np.full(batch - len(sl), sl[-1], sl.dtype)])
        r = run(g, srcs, backend=backend)
        field = r.dist if primitive == "sssp" else r.labels
        jax.block_until_ready(field)
        t_done = time.monotonic()
        if primitive == "bfs":
            # nonzero means a capped frontier dropped discoveries — the
            # lane's answer is untrustworthy and must not ship silently
            overflow += int(np.asarray(r.overflow)[:len(sl)].sum())
        if validate:
            answers.append((sl, np.asarray(field)))
        lat_ms[done:done + len(sl)] = \
            (t_done - enqueue[done:done + len(sl)]) * 1e3
        done += len(sl)
        batches += 1
    total_s = time.monotonic() - t_start
    if validate:
        # oracle traversals are slow; keep them off the serving clock
        oracle = R.sssp_ref if primitive == "sssp" else R.bfs_ref
        for sl, field in answers:
            for i, s in enumerate(sl):
                ok = (np.allclose(field[i], oracle(g, int(s)), rtol=1e-5)
                      if primitive == "sssp"
                      else np.array_equal(field[i], oracle(g, int(s))))
                failures += not ok
    return {
        "primitive": primitive, "backend": backend, "batch": batch,
        "requests": n_q, "batches": batches, "total_s": round(total_s, 4),
        "qps": round(n_q / total_s, 2),
        "lat_ms_mean": round(float(lat_ms.mean()), 2),
        "lat_ms_p50": round(float(np.percentile(lat_ms, 50)), 2),
        "lat_ms_p95": round(float(np.percentile(lat_ms, 95)), 2),
        "overflow": overflow,
        "validation_failures": failures if validate else None,
    }


def _run_kind(g, kind: str, srcs: np.ndarray, backend: str, hops: int):
    """Execute one flushed batch of ``kind``; returns the ready field
    plus per-lane BFS overflow counts (zeros for other kinds — callers
    trim the ragged-tail padding lanes before summing)."""
    zeros = np.zeros(len(srcs), np.int64)
    if kind == "bfs":
        r = bfs_batch(g, srcs, backend=backend)
        jax.block_until_ready(r.labels)
        return r.labels, np.asarray(r.overflow)
    if kind == "sssp":
        r = sssp_batch(g, srcs, backend=backend)
        jax.block_until_ready(r.dist)
        return r.dist, zeros
    if kind == "reach":
        r = reach_batch(g, srcs, hops, backend=backend)
        jax.block_until_ready(r.reached)
        return r.reached, zeros
    if kind == "pagerank":
        # a global analytics query: one run answers every slot of the
        # batch (sources are ignored; the slot discipline still bounds
        # how many queries ride one execution)
        r = pagerank(g, backend=backend)
        jax.block_until_ready(r.rank)
        return r.rank, zeros
    raise ValueError(kind)


def _validate_kind(g, kind: str, srcs, field, hops: int) -> int:
    fails = 0
    if kind == "pagerank":
        return int(not np.allclose(np.asarray(field),
                                   R.pagerank_ref(g, iters=20), atol=1e-6))
    for i, s in enumerate(srcs):
        a = np.asarray(field[i])
        if kind == "bfs":
            ok = np.array_equal(a, R.bfs_ref(g, int(s)))
        elif kind == "sssp":
            ok = np.allclose(a, R.sssp_ref(g, int(s)), rtol=1e-5)
        else:
            ok = np.array_equal(a, R.reach_ref(g, int(s), hops))
        fails += not ok
    return fails


def serve_mixed(g, queries, batch: int, backend: str, hops: int = 3,
                validate: bool = False) -> dict:
    """Serve a mixed-kind query stream through per-kind fixed batch slots.

    ``queries`` is a sequence of ``(kind, source)`` pairs, kinds drawn
    from ``KINDS``. Each kind owns a slot queue: queries accumulate in
    arrival order and a queue flushes as ONE jitted batched program the
    moment it fills (ragged tails flush padded at end-of-stream). Returns
    aggregate stats plus a ``per_kind`` latency/qps breakdown.
    """
    n_q = len(queries)
    if n_q == 0:
        raise ValueError("empty query stream (requests must be > 0)")
    lat_ms = {k: [] for k in KINDS}
    pending: dict = {k: [] for k in KINDS}
    failures = 0
    overflow = 0
    answers = []
    batches = 0
    t_start = time.monotonic()

    def flush(kind):
        nonlocal batches, overflow
        q = pending[kind]
        if not q:
            return
        sl = np.asarray(q, np.int64)
        srcs = np.concatenate([sl, np.full(batch - len(sl), sl[-1],
                                           sl.dtype)])
        field, ovf = _run_kind(g, kind, srcs, backend, hops)
        t_done = time.monotonic()
        # padding lanes repeat the last real query; don't double-count
        # their overflow (same trim as serve())
        overflow += int(ovf[:len(sl)].sum())
        if validate:
            answers.append((kind, sl, np.asarray(field)))
        lat_ms[kind].extend([(t_done - t_start) * 1e3] * len(sl))
        pending[kind] = []
        batches += 1

    for kind, src in queries:            # closed loop: all queued at t0
        pending[kind].append(src)
        if len(pending[kind]) == batch:
            flush(kind)
    for kind in KINDS:                   # ragged tails, padded
        flush(kind)
    total_s = time.monotonic() - t_start

    if validate:                         # oracles off the serving clock
        for kind, sl, field in answers:
            failures += _validate_kind(g, kind, sl, field, hops)

    all_lat = np.asarray(sum(lat_ms.values(), []))
    per_kind = {}
    for kind in KINDS:
        lk = np.asarray(lat_ms[kind])
        if not len(lk):
            continue
        per_kind[kind] = {
            "requests": int(len(lk)),
            "lat_ms_mean": round(float(lk.mean()), 2),
            "lat_ms_p50": round(float(np.percentile(lk, 50)), 2),
            "lat_ms_p95": round(float(np.percentile(lk, 95)), 2),
        }
    return {
        "kinds": sorted(per_kind), "backend": backend, "batch": batch,
        "hops": hops, "requests": n_q, "batches": batches,
        "total_s": round(total_s, 4), "qps": round(n_q / total_s, 2),
        "lat_ms_mean": round(float(all_lat.mean()), 2),
        "lat_ms_p50": round(float(np.percentile(all_lat, 50)), 2),
        "lat_ms_p95": round(float(np.percentile(all_lat, 95)), 2),
        "per_kind": per_kind,
        "overflow": overflow,
        "validation_failures": failures if validate else None,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Serve a stream of graph queries in fixed-shape "
                    "batch slots (one jitted multi-source program per "
                    "(kind, batch shape); --kinds mixes query kinds in "
                    "one stream).")
    ap.add_argument("--graph", default="rmat",
                    choices=("rmat", "rgg", "grid"))
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edge-factor", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--primitive", default="bfs", choices=("bfs", "sssp"))
    ap.add_argument("--kinds", default=None, metavar="K0,K1,...",
                    help=f"serve a MIXED stream over these query kinds "
                         f"(subset of {','.join(KINDS)}); overrides "
                         f"--primitive")
    ap.add_argument("--hops", type=int, default=3,
                    help="k for reach queries (k-hop reachability)")
    ap.add_argument("--requests", type=int, default=64,
                    help="number of queries to serve")
    ap.add_argument("--batch", type=int, default=8,
                    help="fixed batch-slot count (B traversal lanes)")
    ap.add_argument("--warmup", type=int, default=1,
                    help="untimed warmup batches (pays the jit trace)")
    ap.add_argument("--validate", action="store_true",
                    help="check every lane against the numpy oracle")
    ap.add_argument("--backend", default=None,
                    choices=(B.XLA, B.PALLAS, B.AUTO))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="append the stats row to a JSON file")
    args = ap.parse_args(argv)

    bk = B.resolve(args.backend)
    g = make_graph(args.graph, args.scale, args.edge_factor, args.seed)
    rng = np.random.default_rng(args.seed)
    kinds = None
    if args.kinds:
        kinds = [k.strip() for k in args.kinds.split(",")]
        for k in kinds:
            if k not in KINDS:
                raise SystemExit(f"unknown query kind {k!r}; pick from "
                                 f"{KINDS}")
    what = ",".join(kinds) if kinds else args.primitive
    print(f"[graph_serve] {args.graph} scale={args.scale}: "
          f"n={g.num_vertices} m={g.num_edges} kinds={what} "
          f"batch={args.batch} backend={bk}")

    if kinds:
        for _ in range(args.warmup):        # one trace per kind
            for k in kinds:
                _run_kind(g, k,
                          rng.integers(0, g.num_vertices, args.batch),
                          bk, args.hops)
        queries = [(kinds[i % len(kinds)],
                    int(rng.integers(0, g.num_vertices)))
                   for i in range(args.requests)]
        stats = serve_mixed(g, queries, args.batch, bk, hops=args.hops,
                            validate=args.validate)
    else:
        run = {"bfs": bfs_batch, "sssp": sssp_batch}[args.primitive]
        for _ in range(args.warmup):
            w = run(g, rng.integers(0, g.num_vertices, args.batch),
                    backend=bk)
            jax.block_until_ready(
                w.dist if args.primitive == "sssp" else w.labels)
        sources = rng.integers(0, g.num_vertices, args.requests)
        stats = serve(g, args.primitive, sources, args.batch, bk,
                      validate=args.validate)
    print(f"[graph_serve] {stats['requests']} queries in "
          f"{stats['total_s']:.2f}s = {stats['qps']:.1f} q/s  "
          f"(lat ms mean {stats['lat_ms_mean']} p50 {stats['lat_ms_p50']} "
          f"p95 {stats['lat_ms_p95']})")
    for k, row in stats.get("per_kind", {}).items():
        print(f"[graph_serve]   {k:9s} {row['requests']:4d} queries  "
              f"lat ms mean {row['lat_ms_mean']} p50 {row['lat_ms_p50']} "
              f"p95 {row['lat_ms_p95']}")
    if stats["overflow"]:
        print(f"[graph_serve] WARNING: {stats['overflow']} BFS "
              f"discoveries dropped by capped frontiers — rerun the "
              f"affected queries with idempotence=False")
    if args.validate:
        print(f"[graph_serve] validation failures: "
              f"{stats['validation_failures']}")
        if stats["validation_failures"]:
            raise SystemExit("validation failed")
    if args.json:
        try:
            with open(args.json) as f:
                rows = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            rows = []
        rows.append(stats)
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
