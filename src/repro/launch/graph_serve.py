"""Graph query-serving driver — batched multi-source traversal serving.

The inference-side drivers (launch/serve.py) pack token requests into
fixed-shape batch slots; this driver applies the same slot discipline to
*traversal queries*, the ROADMAP's heavy-traffic scenario. A stream of
queries (source vertices, e.g. one personalization root per user) is
packed into batches of ``--batch`` fixed slots and each batch runs as ONE
jitted multi-source program (``bfs_batch`` / ``sssp_batch``): the first
batch pays the trace, every later batch of the same shape reuses it, and
a ragged final batch is padded with repeated sources on dead-weight slots
rather than retracing at a new shape.

Reports per-query latency (enqueue → batch completion, so queuing delay
from batch formation is included) and aggregate queries/sec.

  PYTHONPATH=src python -m repro.launch.graph_serve --graph rmat \
      --scale 10 --primitive bfs --requests 64 --batch 8 --backend xla
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import backend as B
from repro.core import ref as R
from repro.core.primitives import bfs_batch, sssp_batch

from .graph_run import make_graph


def serve(g, primitive: str, sources: np.ndarray, batch: int,
          backend: str, validate: bool = False) -> dict:
    """Serve ``sources`` in fixed batches; returns latency/qps stats."""
    run = {"bfs": bfs_batch, "sssp": sssp_batch}[primitive]
    n_q = len(sources)
    if n_q == 0:
        raise ValueError("empty query stream (requests must be > 0)")
    lat_ms = np.zeros(n_q)
    failures = 0
    overflow = 0                 # BFS discoveries dropped by the cap clamp
    answers = []                 # validated after the clock stops
    t_start = time.monotonic()
    enqueue = np.full(n_q, t_start)        # closed loop: all queries queued
    done = 0
    batches = 0
    while done < n_q:
        sl = sources[done:done + batch]
        # static-shape slots: pad the ragged tail by repeating the last
        # query (padding lanes are computed but not reported)
        srcs = np.concatenate(
            [sl, np.full(batch - len(sl), sl[-1], sl.dtype)])
        r = run(g, srcs, backend=backend)
        field = r.dist if primitive == "sssp" else r.labels
        jax.block_until_ready(field)
        t_done = time.monotonic()
        if primitive == "bfs":
            # nonzero means a capped frontier dropped discoveries — the
            # lane's answer is untrustworthy and must not ship silently
            overflow += int(np.asarray(r.overflow)[:len(sl)].sum())
        if validate:
            answers.append((sl, np.asarray(field)))
        lat_ms[done:done + len(sl)] = \
            (t_done - enqueue[done:done + len(sl)]) * 1e3
        done += len(sl)
        batches += 1
    total_s = time.monotonic() - t_start
    if validate:
        # oracle traversals are slow; keep them off the serving clock
        oracle = R.sssp_ref if primitive == "sssp" else R.bfs_ref
        for sl, field in answers:
            for i, s in enumerate(sl):
                ok = (np.allclose(field[i], oracle(g, int(s)), rtol=1e-5)
                      if primitive == "sssp"
                      else np.array_equal(field[i], oracle(g, int(s))))
                failures += not ok
    return {
        "primitive": primitive, "backend": backend, "batch": batch,
        "requests": n_q, "batches": batches, "total_s": round(total_s, 4),
        "qps": round(n_q / total_s, 2),
        "lat_ms_mean": round(float(lat_ms.mean()), 2),
        "lat_ms_p50": round(float(np.percentile(lat_ms, 50)), 2),
        "lat_ms_p95": round(float(np.percentile(lat_ms, 95)), 2),
        "overflow": overflow,
        "validation_failures": failures if validate else None,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Serve a stream of traversal queries in fixed-shape "
                    "batch slots (one jitted multi-source program per "
                    "batch shape).")
    ap.add_argument("--graph", default="rmat",
                    choices=("rmat", "rgg", "grid"))
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edge-factor", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--primitive", default="bfs", choices=("bfs", "sssp"))
    ap.add_argument("--requests", type=int, default=64,
                    help="number of traversal queries to serve")
    ap.add_argument("--batch", type=int, default=8,
                    help="fixed batch-slot count (B traversal lanes)")
    ap.add_argument("--warmup", type=int, default=1,
                    help="untimed warmup batches (pays the jit trace)")
    ap.add_argument("--validate", action="store_true",
                    help="check every lane against the numpy oracle")
    ap.add_argument("--backend", default=None,
                    choices=(B.XLA, B.PALLAS, B.AUTO))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="append the stats row to a JSON file")
    args = ap.parse_args(argv)

    bk = B.resolve(args.backend)
    g = make_graph(args.graph, args.scale, args.edge_factor, args.seed)
    rng = np.random.default_rng(args.seed)
    print(f"[graph_serve] {args.graph} scale={args.scale}: "
          f"n={g.num_vertices} m={g.num_edges} primitive={args.primitive} "
          f"batch={args.batch} backend={bk}")

    run = {"bfs": bfs_batch, "sssp": sssp_batch}[args.primitive]
    for _ in range(args.warmup):
        w = run(g, rng.integers(0, g.num_vertices, args.batch), backend=bk)
        jax.block_until_ready(
            w.dist if args.primitive == "sssp" else w.labels)

    sources = rng.integers(0, g.num_vertices, args.requests)
    stats = serve(g, args.primitive, sources, args.batch, bk,
                  validate=args.validate)
    print(f"[graph_serve] {stats['requests']} queries in "
          f"{stats['total_s']:.2f}s = {stats['qps']:.1f} q/s  "
          f"(lat ms mean {stats['lat_ms_mean']} p50 {stats['lat_ms_p50']} "
          f"p95 {stats['lat_ms_p95']})")
    if stats["overflow"]:
        print(f"[graph_serve] WARNING: {stats['overflow']} BFS "
              f"discoveries dropped by capped frontiers — rerun the "
              f"affected queries with idempotence=False")
    if args.validate:
        print(f"[graph_serve] validation failures: "
              f"{stats['validation_failures']}")
        if stats["validation_failures"]:
            raise SystemExit("validation failed")
    if args.json:
        try:
            with open(args.json) as f:
                rows = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            rows = []
        rows.append(stats)
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
