"""Graph query-serving driver — batched mixed-kind query serving.

The inference-side drivers (launch/serve.py) pack token requests into
fixed-shape batch slots; this driver applies the same slot discipline to
*graph queries*, the ROADMAP's heavy-traffic scenario. A stream of
queries is packed into batches of ``--batch`` fixed slots and each batch
runs as ONE jitted multi-source program: the first batch of a kind pays
the trace, every later batch of the same (kind, shape) reuses it, and a
ragged final batch is padded with repeated sources on dead-weight slots
rather than retracing at a new shape.

The stream is no longer traversal-only: ``--kinds bfs,sssp,pagerank,reach``
serves MIXED query kinds from one stream — each kind keeps its own slot
queue (one compiled program per kind) and flushes when full, so
traversal queries (``bfs_batch`` / ``sssp_batch``), algebraic queries
(``reach_batch`` — or-and k-hop reachability) and global analytics
queries (``pagerank`` — one run answers its whole batch) interleave on
one engine. Per-kind latency is reported alongside the aggregate, and
lands in ``--json``.

Reports per-query latency (enqueue → batch completion, so queuing delay
from batch formation is included; each query's enqueue time is stamped
when it joins its slot queue) and aggregate queries/sec.

``--parts P`` serves the same stream from a mesh: the graph is 1-D
partitioned once at startup, traversal kinds run the distributed
engine (bitmask-exchange advance), algebraic kinds the sharded
spmv/spmm providers — results bit-match single-device serving, and
``--json`` rows gain per-device balance accounting (edge AND vertex
imbalance — on rmat graphs the former is what hub skew shows up in).
``--mesh RxC`` serves from the 2-D vertex-cut placement instead
(``--parts P`` is the 1-D alias): edges are blocked on an R×C device
mesh and the frontier exchange is chunk-proportional, not
n-proportional. Results bit-match either way.

  PYTHONPATH=src python -m repro.launch.graph_serve --graph rmat \
      --scale 10 --kinds bfs,pagerank,reach --requests 64 --batch 8

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python -m repro.launch.graph_serve --graph rmat \
      --scale 10 --parts 4 --kinds bfs,sssp,pagerank,reach \
      --requests 64 --batch 8

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.graph_serve --graph rmat \
      --scale 10 --mesh 2x4 --kinds bfs,sssp,pagerank,reach \
      --requests 64 --batch 8

  PYTHONPATH=src python -m repro.launch.graph_serve --graph rmat \
      --scale 10 --primitive bfs --requests 64 --batch 8 --backend xla
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro import obs
from repro import ft
from repro.core import backend as B
from repro.core import ref as R
from repro.core.storage import resident_bytes
from repro.core.primitives import bfs_batch, pagerank, reach_batch, \
    sssp_batch
from repro.ft import inject
from repro.obs.metrics import Metrics, latency_summary

from .graph_run import make_graph

KINDS = ("bfs", "sssp", "pagerank", "reach")

# query terminal statuses (the per-query contract of serve_mixed) and
# the metrics counter each one lands in — the reconciliation invariant
# the chaos suite asserts: counter sums == status counts in the results
STATUSES = ("ok", "degraded", "deadline_exceeded", "shed", "error")
_STATUS_COUNTER = {
    "ok": "queries_ok_total",
    "degraded": "queries_degraded_total",
    "deadline_exceeded": "queries_deadline_total",
    "shed": "queries_shed_total",
    "error": "queries_error_total",
}

# injected-straggler stall: long enough that the watchdog's robust-median
# multiple flags it on any realistic batch cadence
_STRAGGLER_SLEEP_S = 0.2

log = obs.get_logger("graph_serve")


class PoisonedResultError(RuntimeError):
    """A kernel output failed the NaN/Inf guardrail probe."""


def serve(g, primitive: str, sources: np.ndarray, batch: int,
          backend: str, validate: bool = False,
          metrics: Metrics | None = None) -> dict:
    """Serve ``sources`` in fixed batches; returns latency/qps stats.
    Quantiles are linearly interpolated (``obs.metrics.latency_summary``)
    and reported alongside their sample count. An optional ``metrics``
    registry collects per-kind latency histograms / occupancy gauges /
    counters for the ``--metrics`` Prometheus dump."""
    run = {"bfs": bfs_batch, "sssp": sssp_batch}[primitive]
    n_q = len(sources)
    if n_q == 0:
        raise ValueError("empty query stream (requests must be > 0)")
    lat_ms = np.zeros(n_q)
    failures = 0
    overflow = 0                 # BFS discoveries dropped by the cap clamp
    answers = []                 # validated after the clock stops
    t_start = time.monotonic()
    enqueue = np.full(n_q, t_start)        # closed loop: all queries queued
    done = 0
    batches = 0
    while done < n_q:
        sl = sources[done:done + batch]
        # static-shape slots: pad the ragged tail by repeating the last
        # query (padding lanes are computed but not reported)
        srcs = np.concatenate(
            [sl, np.full(batch - len(sl), sl[-1], sl.dtype)])
        r = run(g, srcs, backend=backend)
        field = r.dist if primitive == "sssp" else r.labels
        jax.block_until_ready(field)
        t_done = time.monotonic()
        if primitive == "bfs":
            # nonzero means a capped frontier dropped discoveries — the
            # lane's answer is untrustworthy and must not ship silently
            overflow += int(np.asarray(r.overflow)[:len(sl)].sum())
        if validate:
            answers.append((sl, np.asarray(field)))
        batch_lat = (t_done - enqueue[done:done + len(sl)]) * 1e3
        lat_ms[done:done + len(sl)] = batch_lat
        if metrics is not None:
            _observe_batch(metrics, primitive, batch_lat,
                           len(sl), batch, queue_depth=n_q - done)
        done += len(sl)
        batches += 1
    total_s = time.monotonic() - t_start
    if validate:
        # oracle traversals are slow; keep them off the serving clock
        oracle = R.sssp_ref if primitive == "sssp" else R.bfs_ref
        for sl, field in answers:
            for i, s in enumerate(sl):
                ok = (np.allclose(field[i], oracle(g, int(s)), rtol=1e-5)
                      if primitive == "sssp"
                      else np.array_equal(field[i], oracle(g, int(s))))
                failures += not ok
    if metrics is not None:
        _count_totals(metrics, batches, overflow)
    return {
        "primitive": primitive, "backend": backend, "batch": batch,
        "requests": n_q, "batches": batches, "total_s": round(total_s, 4),
        "qps": round(n_q / total_s, 2),
        **latency_summary(lat_ms),
        "overflow": overflow,
        "validation_failures": failures if validate else None,
    }


def _observe_batch(m: Metrics, kind: str, batch_lat, real: int,
                   batch: int, queue_depth: int) -> None:
    """One flushed batch's worth of serving metrics: per-kind latency
    observations, batch-slot occupancy, and the queue-depth high-water
    mark at flush time."""
    for v in np.asarray(batch_lat, np.float64).reshape(-1):
        m.observe("latency_ms", float(v),
                  help="per-query latency, enqueue to batch completion",
                  kind=kind)
    m.counter("queries_total", real,
              help="queries answered", kind=kind)
    m.observe("batch_occupancy", real / max(batch, 1),
              help="fraction of batch slots holding real queries",
              kind=kind)
    m.gauge_max("queue_depth_peak", queue_depth,
                help="high-water mark of queued-but-unflushed queries")


def _count_totals(m: Metrics, batches: int, overflow: int) -> None:
    """Stream-level counters. Cache hits/misses are declared at zero —
    the serving scheduler the ROADMAP plans (answer caching, continuous
    batching) increments them; the exposition shows the series now so
    dashboards don't break when it lands."""
    m.counter("batches_total", batches, help="batches flushed")
    m.counter("overflow_total", overflow,
              help="BFS discoveries dropped by capped frontiers")
    m.counter("cache_hits_total", 0, help="answer-cache hits")
    m.counter("cache_misses_total", 0, help="answer-cache misses")


def _run_kind(g, kind: str, srcs: np.ndarray, backend: str, hops: int,
              budget=None):
    """Execute one flushed batch of ``kind``; returns the ready field,
    per-lane BFS overflow counts (zeros for other kinds — callers trim
    the ragged-tail padding lanes before summing), and the primitive's
    ``converged`` flags (per-lane or scalar; lanes cut short by an
    iteration budget report False and carry partial answers)."""
    zeros = np.zeros(len(srcs), np.int64)
    if kind == "bfs":
        r = bfs_batch(g, srcs, backend=backend, budget=budget)
        jax.block_until_ready(r.labels)
        return r.labels, np.asarray(r.overflow), np.asarray(r.converged)
    if kind == "sssp":
        r = sssp_batch(g, srcs, backend=backend, budget=budget)
        jax.block_until_ready(r.dist)
        return r.dist, zeros, np.asarray(r.converged)
    if kind == "reach":
        r = reach_batch(g, srcs, hops, backend=backend, budget=budget)
        jax.block_until_ready(r.reached)
        return r.reached, zeros, np.asarray(r.converged)
    if kind == "pagerank":
        # a global analytics query: one run answers every slot of the
        # batch (sources are ignored; the slot discipline still bounds
        # how many queries ride one execution)
        r = pagerank(g, backend=backend, budget=budget)
        jax.block_until_ready(r.rank)
        return r.rank, zeros, np.asarray(r.converged)
    raise ValueError(kind)


def make_sharded_runner(pg, mesh, axis="graph"):
    """Mesh-backed query runner: every kind is served from the 1-D (or
    2-D vertex-cut) partition built once at startup. Traversal kinds
    (bfs/sssp) run one cached distributed trace per query lane (the
    trace is keyed on the partition shapes + mesh, so lanes reuse it);
    algebraic kinds run the placement's "spmm"/"spmv" providers through
    the unchanged primitives. Results bit-match the single-device
    runner, so the oracle validation path needs no sharded variant."""
    import jax.numpy as jnp

    from repro.core.distributed import (_shard_any, distributed_bfs,
                                        distributed_sssp)
    from repro.core.primitives import pagerank, reach_batch

    sg = _shard_any(pg, mesh, axis)

    def _per_source(srcs, one):
        # padding lanes repeat the final real query — run each distinct
        # source once and fan the result back out to its lanes
        memo = {}
        rows = []
        for s in srcs:
            s = int(s)
            if s not in memo:
                memo[s] = one(s)
            rows.append(memo[s])
        return jnp.stack(rows)

    def run(kind: str, srcs: np.ndarray, backend: str, hops: int):
        zeros = np.zeros(len(srcs), np.int64)
        if kind == "bfs":
            out = _per_source(srcs, lambda s: distributed_bfs(
                pg, s, mesh, axis, backend=backend).labels)
            jax.block_until_ready(out)
            return out, zeros           # dense bitmask advance: no caps,
        if kind == "sssp":              # so no overflow to report
            out = _per_source(srcs, lambda s: distributed_sssp(
                pg, s, mesh, axis).dist)
            jax.block_until_ready(out)
            return out, zeros
        if kind == "reach":
            r = reach_batch(sg, srcs, hops, backend=backend)
            jax.block_until_ready(r.reached)
            return r.reached, zeros
        if kind == "pagerank":
            r = pagerank(sg, backend=backend)
            jax.block_until_ready(r.rank)
            return r.rank, zeros
        raise ValueError(kind)

    return run


def _validate_kind(g, kind: str, srcs, field, hops: int) -> int:
    fails = 0
    if kind == "pagerank":
        return int(not np.allclose(np.asarray(field),
                                   R.pagerank_ref(g, iters=20), atol=1e-6))
    for i, s in enumerate(srcs):
        a = np.asarray(field[i])
        if kind == "bfs":
            ok = np.array_equal(a, R.bfs_ref(g, int(s)))
        elif kind == "sssp":
            ok = np.allclose(a, R.sssp_ref(g, int(s)), rtol=1e-5)
        else:
            ok = np.array_equal(a, R.reach_ref(g, int(s), hops))
        fails += not ok
    return fails


def _norm_run(out):
    """Normalize a runner return to (field, overflow, converged). The
    runner contract is 2-tuple (field, overflow); the default in-process
    runner adds the primitives' ``converged`` flags as a third element,
    and runners that don't surface convergence report None (= assume
    converged — they ran to completion by construction)."""
    if len(out) == 3:
        return out
    field, ovf = out
    return field, ovf, None


def _guardrail(kind: str, field: np.ndarray) -> None:
    """NaN/Inf guardrail: reject poisoned float outputs before they ship.

    Reads the already-host-side result array — a pure probe, so healthy
    results stay bit-identical. Per-kind semantics: sssp distances are
    legitimately +inf on unreachable vertices (NaN is the poison there);
    pagerank ranks must be finite; bfs/reach fields are integral and
    can't carry float poison."""
    if field.dtype.kind != "f":
        return
    if kind == "sssp":
        bad = np.isnan(field)
    else:
        bad = ~np.isfinite(field)
    if bad.any():
        frac = float(bad.mean())
        raise PoisonedResultError(
            f"{kind} output failed the NaN/Inf guardrail "
            f"({frac:.1%} of entries non-finite)")


def serve_mixed(g, queries, batch: int, backend: str, hops: int = 3,
                validate: bool = False, runner=None,
                metrics: Metrics | None = None,
                budget: ft.Budget | None = None,
                admission: ft.AdmissionPolicy | None = None,
                retry: ft.RetryPolicy | None = None,
                placement: str = "single",
                watchdog=None) -> dict:
    """Serve a mixed-kind query stream through per-kind fixed batch slots.

    ``queries`` is a sequence of ``(kind, source)`` pairs, kinds drawn
    from ``KINDS``. Each kind owns a slot queue: queries accumulate in
    arrival order and a queue flushes as ONE jitted batched program the
    moment it fills (ragged tails flush padded at end-of-stream). Returns
    aggregate stats plus a ``per_kind`` latency/qps breakdown.

    Per-query latency is enqueue → batch completion: each query's
    enqueue time is recorded when it joins its slot queue and subtracted
    at flush. (Measuring from stream start instead — the old behavior —
    charged every query all the batches that ran before it joined the
    queue, so mixed-stream p50/p95 grew with stream position.)

    ``runner(kind, srcs, backend, hops)`` overrides query execution (the
    sharded driver passes a mesh-backed runner); defaults to the
    single-device ``_run_kind``. ``metrics`` (an ``obs.metrics.Metrics``)
    collects per-kind latency histograms, queue-depth / batch-occupancy
    gauges, and counters for the ``--metrics`` Prometheus dump.

    Request-lifecycle hardening (the robustness layer):

      * every query ends in exactly one terminal status — ``ok``,
        ``degraded``, ``deadline_exceeded``, ``shed`` or ``error`` —
        returned per-query under ``stats["queries"]`` and counted in the
        matching metrics counter; malformed input (unknown kind,
        out-of-range source) becomes a per-query ``error``, never an
        exception out of the stream;
      * ``budget`` bounds each query: ``max_iters`` rides into the
        primitives (lanes cut short → ``deadline_exceeded`` with partial
        answers), ``wall_ms`` is checked host-side at flush boundaries
        (already-expired queries are not dispatched; late completions
        are stamped ``deadline_exceeded``);
      * ``admission`` bounds the slot queues — arrivals over the cap are
        shed with a structured rejection;
      * batch dispatch runs under ``retry`` (exponential backoff,
        deterministic jitter) escalating through the ``repro.ft.degrade``
        ladder (pallas→xla, placement→single, reach reduced-hop); a
        downgraded batch's queries are stamped ``degraded`` and every
        rung change is declared + logged;
      * a NaN/Inf guardrail probes each batch's host-side output and
        aborts a poisoned batch cleanly (retryable; terminal ``error``
        if the ladder runs dry);
      * a :class:`repro.ft.StepWatchdog` times every flush — the
        robust-median straggler multiple lands in ``--metrics``.
    """
    n_q = len(queries)
    if n_q == 0:
        raise ValueError("empty query stream (requests must be > 0)")
    retry = retry if retry is not None else ft.RetryPolicy()
    wd = watchdog if watchdog is not None else ft.StepWatchdog()
    plan = inject.active()
    # a custom runner may not need the graph at all (stub/mesh drivers
    # pass g=None); range hardening then has no bound to check against
    num_v = None if g is None else g.num_vertices
    results: list = [None] * n_q
    lat_ms = {k: [] for k in KINDS}
    pending: dict = {k: [] for k in KINDS}   # (qid, src, t_enq, deadline)
    status_counts = {s: 0 for s in STATUSES}
    failures = 0
    overflow = 0
    retried = 0
    answers = []
    batches = 0
    if metrics is not None:
        # declare every lifecycle counter up front so the reconciliation
        # invariant (counters == per-query statuses) holds even for
        # fault classes that never fire in this run
        for s in STATUSES:
            metrics.counter(_STATUS_COUNTER[s], 0,
                            help=f"queries finished with status={s}")
        metrics.counter("queries_retried_total", 0,
                        help="queries whose batch needed >=1 retry")
    # reprolint: disable=RL004 -- run_kind fences internally (block_until_ready before return)
    t_start = time.monotonic()

    def finish(qid, kind, src, status, t_enq, t_done=None, reason=None,
               attempts=1, degraded_to=None):
        t_done = time.monotonic() if t_done is None else t_done
        rec = {"id": qid, "kind": kind, "source": src, "status": status,
               "lat_ms": round((t_done - t_enq) * 1e3, 3),
               "attempts": attempts}
        if reason:
            rec["reason"] = reason
        if degraded_to:
            rec["degraded_to"] = degraded_to
        results[qid] = rec
        status_counts[status] += 1
        if metrics is not None:
            metrics.counter(_STATUS_COUNTER[status], 1,
                            help=f"queries finished with status={status}",
                            kind=str(kind))
        return rec

    def dispatch(kind, srcs):
        """One batch through retry + the degradation ladder. Returns
        (field, ovf, conv, attempts, rung, error): on success ``error``
        is None; when the ladder runs dry ``field`` is None and
        ``error`` carries the terminal exception."""
        rungs = [r for r in ft.ladder(kind, backend, placement,
                                      hops=hops if kind == "reach"
                                      else None)
                 # rungs we can realize here: the runner's own placement,
                 # or the in-process single-device fallback
                 if r.placement in (placement, "single")]
        run_default = lambda k, s, bk2, h: _run_kind(g, k, s, bk2, h,
                                                     budget)
        run_kind = runner if runner is not None else run_default
        state = {"attempts": 1}

        def attempt(a):
            state["attempts"] = a + 1
            rung = ft.rung_for_attempt(rungs, a)
            state["rung"] = rung
            if rung.reason:
                ft.engage(kind, rung)
            if plan is not None and plan.should("provider_miss", kind):
                raise B.ProviderMissError(
                    kind, rung.backend, rung.placement,
                    detail="injected by repro.ft.inject")
            if (placement != "single" and rung.placement == placement
                    and plan is not None
                    and plan.should("shard_loss", kind)):
                raise inject.ShardLossError(
                    f"injected shard loss during {kind} flush")
            h = rung.hops if rung.hops is not None else hops
            if rung.placement != placement:
                out = run_default(kind, srcs, rung.backend, h)
            else:
                out = run_kind(kind, srcs, rung.backend, h)
            field, ovf, conv = _norm_run(out)
            field = np.asarray(field)
            if (plan is not None and field.dtype.kind == "f"
                    and plan.should("nan", kind)):
                field = field.copy()
                field.reshape(-1)[0] = np.nan
            if plan is not None and plan.should("straggler", kind):
                time.sleep(_STRAGGLER_SLEEP_S)
            _guardrail(kind, field)
            return field, ovf, conv

        def on_retry(a, exc):
            log.warning(f"{kind} batch attempt {a + 1} failed "
                        f"({type(exc).__name__}: {exc}); backing off")

        try:
            (field, ovf, conv), attempts = ft.with_retry(
                attempt, retry, seed=batches, sleep=time.sleep,
                on_retry=on_retry)
            return field, ovf, conv, attempts, state["rung"], None
        except Exception as exc:   # declared retry boundary: ladder dry
            log.error(f"{kind} batch failed after {state['attempts']} "
                      f"attempts: {type(exc).__name__}: {exc}")
            return (None, None, None, state["attempts"],
                    state.get("rung"), exc)

    def flush(kind):
        nonlocal batches, overflow, retried, failures
        q = pending[kind]
        if not q:
            return
        pending[kind] = []
        # serving latency deliberately includes queue wait; the device is
        # fenced inside dispatch (np.asarray pulls the result to host)
        now = time.monotonic()  # reprolint: disable=RL004 -- queue latency is the metric; dispatch fences
        live = []
        for qid, src, t_enq, dl in q:
            if dl is not None and now >= dl:
                # expired while queued: don't spend a batch slot on it
                finish(qid, kind, src, "deadline_exceeded", t_enq,
                       t_done=now, reason="deadline expired in queue")
            else:
                live.append((qid, src, t_enq, dl))
        if not live:
            return
        sl = np.asarray([src for _, src, _, _ in live], np.int64)
        srcs = np.concatenate([sl, np.full(batch - len(sl), sl[-1],
                                           sl.dtype)])
        wd.start(batches)
        field, ovf, conv, attempts, rung, err = dispatch(kind, srcs)
        dt = wd.stop()
        t_done = time.monotonic()
        batches += 1
        if metrics is not None and wd.median():
            metrics.gauge_max(
                "straggler_multiple_max", dt / wd.median(),
                help="worst batch wall time as a multiple of the "
                     "robust-median batch time")
        if field is None:
            # retries + the whole ladder failed: the queries get a
            # structured error, the stream lives on
            for qid, src, t_enq, _ in live:
                finish(qid, kind, src, "error", t_enq, t_done=t_done,
                       reason=f"{type(err).__name__}: {err}",
                       attempts=attempts)
            if metrics is not None:
                metrics.counter("queries_retried_total", len(live),
                                kind=kind)
            retried += len(live)
            return
        # padding lanes repeat the last real query; don't double-count
        # their overflow (same trim as serve())
        overflow += int(ovf[:len(sl)].sum())
        # degraded = the answer came from a lower rung; a retry that
        # recovered at the requested rung is full-fidelity "ok" (the
        # attempts field and retried counter still record it)
        degraded = bool(rung.reason)
        conv_arr = (None if conv is None
                    else np.asarray(conv).reshape(-1))
        if validate and not degraded and (conv_arr is None
                                          or conv_arr.all()):
            # oracle-comparable only when nothing was cut short or
            # approximated (a reduced-hop reach answers a different
            # question than the oracle's)
            answers.append((kind, sl, field))
        batch_lat = []
        for i, (qid, src, t_enq, dl) in enumerate(live):
            conv_i = (True if conv_arr is None else
                      bool(conv_arr[min(i, len(conv_arr) - 1)]))
            late = dl is not None and t_done > dl
            if not conv_i:
                st = "deadline_exceeded"
                reason = "iteration budget exhausted (partial result)"
            elif late:
                st = "deadline_exceeded"
                reason = "completed after deadline"
            elif degraded:
                st = "degraded"
                reason = None
            else:
                st = "ok"
                reason = None
            finish(qid, kind, src, st, t_enq, t_done=t_done,
                   reason=reason, attempts=attempts,
                   degraded_to=rung.reason if degraded else None)
            batch_lat.append((t_done - t_enq) * 1e3)
        if attempts > 1:
            retried += len(live)
            if metrics is not None:
                metrics.counter("queries_retried_total", len(live),
                                kind=kind)
        lat_ms[kind].extend(batch_lat)
        if metrics is not None:
            depth = sum(len(p) for p in pending.values())
            _observe_batch(metrics, kind, batch_lat, len(sl), batch,
                           queue_depth=depth)

    for qid, (kind, src) in enumerate(queries):
        t_enq = time.monotonic()
        # input hardening: malformed queries become structured per-query
        # errors — never an exception that kills the stream
        if kind not in KINDS:
            finish(qid, str(kind), src, "error", t_enq,
                   reason=f"unknown kind {kind!r}; expected one of "
                          f"{','.join(KINDS)}")
            continue
        try:
            src = int(src)
        except (TypeError, ValueError):
            finish(qid, kind, src, "error", t_enq,
                   reason=f"source {src!r} is not an integer")
            continue
        if num_v is not None and not 0 <= src < num_v:
            finish(qid, kind, src, "error", t_enq,
                   reason=f"source {src} out of range [0, {num_v})")
            continue
        if admission is not None:
            shed_reason = admission.admit(kind, pending)
            if shed_reason is not None:
                finish(qid, kind, src, "shed", t_enq, reason=shed_reason)
                continue
        dl = None if budget is None else budget.deadline_from(t_enq)
        pending[kind].append((qid, src, t_enq, dl))
        if metrics is not None:
            metrics.gauge_max(
                "queue_depth_peak",
                sum(len(p) for p in pending.values()),
                help="high-water mark of queued-but-unflushed queries")
        if len(pending[kind]) == batch:
            flush(kind)
    for kind in KINDS:                   # ragged tails, padded
        flush(kind)
    total_s = time.monotonic() - t_start

    if validate:                         # oracles off the serving clock
        for kind, sl, field in answers:
            failures += _validate_kind(g, kind, sl, field, hops)
    if metrics is not None:
        _count_totals(metrics, batches, overflow)
        metrics.counter("straggler_batches_total", len(wd.stragglers),
                        help="flushes the watchdog flagged as stragglers")

    all_lat = np.asarray(sum(lat_ms.values(), []))
    per_kind = {}
    for kind in KINDS:
        lk = np.asarray(lat_ms[kind])
        if not len(lk):
            continue
        per_kind[kind] = {"requests": int(len(lk)),
                          **latency_summary(lk)}
    return {
        "kinds": sorted(per_kind), "backend": backend, "batch": batch,
        "hops": hops, "requests": n_q, "batches": batches,
        "total_s": round(total_s, 4), "qps": round(n_q / total_s, 2),
        **latency_summary(all_lat),
        "per_kind": per_kind,
        "overflow": overflow,
        "queries": results,
        "status_counts": status_counts,
        "retried": retried,
        "stragglers": len(wd.stragglers),
        "validation_failures": failures if validate else None,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Serve a stream of graph queries in fixed-shape "
                    "batch slots (one jitted multi-source program per "
                    "(kind, batch shape); --kinds mixes query kinds in "
                    "one stream).")
    ap.add_argument("--graph", default="rmat",
                    choices=("rmat", "rgg", "grid"))
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edge-factor", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--index-dtype", default=None,
                    choices=("int16", "int32", "int64"),
                    help="vertex-id width for the served graph (default: "
                         "narrowest safe width)")
    ap.add_argument("--encoding", default="dense",
                    choices=("dense", "delta"),
                    help="CSR/CSC column storage encoding")
    ap.add_argument("--primitive", default="bfs", choices=("bfs", "sssp"))
    ap.add_argument("--kinds", default=None, metavar="K0,K1,...",
                    help=f"serve a MIXED stream over these query kinds "
                         f"(subset of {','.join(KINDS)}); overrides "
                         f"--primitive")
    ap.add_argument("--hops", type=int, default=3,
                    help="k for reach queries (k-hop reachability)")
    ap.add_argument("--requests", type=int, default=64,
                    help="number of queries to serve")
    ap.add_argument("--batch", type=int, default=8,
                    help="fixed batch-slot count (B traversal lanes)")
    ap.add_argument("--warmup", type=int, default=1,
                    help="untimed warmup batches (pays the jit trace)")
    ap.add_argument("--parts", type=int, default=None, metavar="P",
                    help="serve from a P-way 1-D partition over the "
                         "first P local devices (sharded placement; "
                         "builds the partition once, reports per-device "
                         "balance in --json)")
    ap.add_argument("--mesh", default=None, metavar="RxC",
                    help="serve from an R×C 2-D vertex-cut partition "
                         "(2d placement) over the first R*C local "
                         "devices; --parts P is the 1-D alias")
    ap.add_argument("--validate", action="store_true",
                    help="structurally validate the built graph "
                         "(Graph.validate_graph) and check every lane "
                         "against the numpy oracle")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-query wall-clock budget: queries that "
                         "expire in queue or complete late are stamped "
                         "deadline_exceeded")
    ap.add_argument("--max-iters", type=int, default=None,
                    help="per-query BSP iteration budget: lanes cut "
                         "short return partial results stamped "
                         "deadline_exceeded")
    ap.add_argument("--retries", type=int, default=2,
                    help="batch dispatch retries before the query is "
                         "declared failed (escalates through the "
                         "degradation ladder)")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="admission control: shed arrivals once this "
                         "many queries are queued (structured per-query "
                         "rejection, never an exception)")
    ap.add_argument("--backend", default=None,
                    choices=(B.XLA, B.PALLAS, B.AUTO))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="append the stats row to a JSON file")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write serving metrics (per-kind latency "
                         "histograms with p50/p95/p99, gauges, counters) "
                         "as Prometheus text; '-' prints to stdout")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write phase spans as Chrome trace-event JSON "
                         "(open at ui.perfetto.dev)")
    args = ap.parse_args(argv)

    if args.trace:
        obs.reset()
    # chaos rig: a seeded REPRO_FAULTS spec installs the fault plan for
    # the whole serving process (no-op when unset)
    plan = inject.install_from_env()
    if plan is not None:
        log.warning(f"fault injection ACTIVE: {plan.spec!r} "
                    f"seed={plan.seed}")
    # device health probe, once at startup: a failed device is named in
    # the log (the eviction signal a multi-host controller would act on)
    health = ft.check_devices()
    for dev, ok in health.items():
        if not ok:
            log.warning(f"device {dev} failed the health probe — "
                        f"evicting from the serving pool")
    bk = B.resolve(args.backend)
    metrics = Metrics() if args.metrics else None
    with obs.span("build_graph", category="setup",
                  args={"kind": args.graph, "scale": args.scale}):
        g = make_graph(args.graph, args.scale, args.edge_factor,
                       args.seed, index_dtype=args.index_dtype,
                       encoding=args.encoding)
        jax.block_until_ready(g.row_offsets)
    if args.validate:
        from repro.core.graph import validate_graph
        validate_graph(g)    # raises GraphValidationError with the
        log.info("structural validation: CSR/CSC clean")   # bad row/edge
    storage = resident_bytes(g)
    rng = np.random.default_rng(args.seed)
    kinds = None
    if args.kinds:
        kinds = [k.strip() for k in args.kinds.split(",")]
        for k in kinds:
            if k not in KINDS:
                raise SystemExit(f"unknown query kind {k!r}; pick from "
                                 f"{KINDS}")
    mesh_shape = None
    if args.mesh:
        if args.parts:
            raise SystemExit(
                "--mesh and --parts are mutually exclusive (--parts P "
                "is the 1-D alias of --mesh 1xP; pick one)")
        try:
            r, c = (int(t) for t in args.mesh.lower().split("x"))
            if r < 1 or c < 1:
                raise ValueError(args.mesh)
        except ValueError:
            raise SystemExit(
                f"--mesh wants RxC with positive integers (e.g. 2x4), "
                f"got {args.mesh!r}")
        mesh_shape = (r, c)
    if (args.parts or mesh_shape) and not kinds:
        kinds = [args.primitive]     # sharded serving goes through the
    runner = None                    # mixed-kind (runner-based) path
    pg = None
    if args.parts or mesh_shape:
        need = args.parts if args.parts else mesh_shape[0] * mesh_shape[1]
        flag = (f"--parts {args.parts}" if args.parts
                else f"--mesh {mesh_shape[0]}x{mesh_shape[1]} "
                     f"(= {need} devices)")
        if len(jax.devices()) < need:
            raise SystemExit(
                f"{flag} needs {need} devices but "
                f"only {len(jax.devices())} are visible (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={need} "
                f"for host-platform serving)")
        from jax.sharding import Mesh
        with obs.span("partition", category="setup",
                      args={"parts": need}):
            if mesh_shape:
                from repro.core.partition import partition_2d
                pg = partition_2d(g, *mesh_shape)
                mesh = Mesh(
                    np.array(jax.devices()[:need]).reshape(mesh_shape),
                    ("row", "col"))
                axis = ("row", "col")
            else:
                from repro.core.partition import partition_1d
                pg = partition_1d(g, args.parts)
                mesh = Mesh(np.array(jax.devices()[:need]), ("graph",))
                axis = "graph"
            runner = make_sharded_runner(pg, mesh, axis)
        bal = pg.balance()
        shape = (f"{mesh_shape[0]}x{mesh_shape[1]} mesh" if mesh_shape
                 else f"{need} parts")
        log.info(f"partition: {shape}, "
                 f"edge imbalance {bal['edge_imbalance']}x, "
                 f"vertex imbalance {bal['vertex_imbalance']}x")
        if metrics is not None:
            # analytic per-BSP-step exchange volume (the PR 7 comm
            # model) per served traversal kind — the distributed
            # counterpart of the single-device telemetry columns
            from repro.core.distributed import exchange_bytes_per_step
            for kind in (kinds or [args.primitive]):
                try:
                    metrics.gauge(
                        "exchange_bytes_per_step",
                        exchange_bytes_per_step(pg, kind),
                        help="analytic per-device exchange bytes per "
                             "BSP step (comm model)", kind=kind)
                except (KeyError, ValueError):
                    pass            # kind without a comm-model entry
    what = ",".join(kinds) if kinds else args.primitive
    placement = ("2d" if mesh_shape
                 else "sharded" if args.parts else "single")
    log.info(f"{args.graph} scale={args.scale}: "
             f"n={g.num_vertices} m={g.num_edges} kinds={what} "
             f"batch={args.batch} backend={bk} placement={placement}")
    pl = storage["plan"]
    log.info(f"storage: {pl['index_dtype']}/{pl['encoding']} "
             f"{storage['total_bytes'] / 2**20:.1f} MiB resident, "
             f"{storage['bytes_per_edge']} column bytes/edge "
             f"({storage['total_bytes_per_edge']} total)")

    if kinds:
        run_warm = runner if runner is not None else \
            (lambda k, srcs, b, h: _run_kind(g, k, srcs, b, h))
        with obs.span("warmup", category="compile",
                      args={"kinds": ",".join(kinds)}):
            for _ in range(args.warmup):        # one trace per kind
                for k in kinds:
                    try:
                        run_warm(k, rng.integers(0, g.num_vertices,
                                                 args.batch),
                                 bk, args.hops)
                    except Exception as exc:
                        # warmup is best-effort: under an installed
                        # fault plan a cold trace can hit an injected
                        # provider miss here; serving traces the kind on
                        # first flush, inside the retry boundary
                        log.warning(f"warmup {k} failed "
                                    f"({type(exc).__name__}: {exc}); "
                                    f"first flush will pay the trace")
        queries = [(kinds[i % len(kinds)],
                    int(rng.integers(0, g.num_vertices)))
                   for i in range(args.requests)]
        budget = (ft.Budget(max_iters=args.max_iters,
                            wall_ms=args.deadline_ms)
                  if (args.max_iters or args.deadline_ms) else None)
        admission = (ft.AdmissionPolicy(max_pending=args.max_pending)
                     if args.max_pending else None)
        with obs.span("serve", category="serve",
                      args={"requests": args.requests}):
            stats = serve_mixed(g, queries, args.batch, bk,
                                hops=args.hops, validate=args.validate,
                                runner=runner, metrics=metrics,
                                budget=budget, admission=admission,
                                retry=ft.RetryPolicy(retries=args.retries),
                                placement=placement)
        if pg is not None:
            stats["parts"] = pg.num_parts
            if mesh_shape:
                stats["mesh"] = list(mesh_shape)
            stats["balance"] = pg.balance()
    else:
        run = {"bfs": bfs_batch, "sssp": sssp_batch}[args.primitive]
        with obs.span("warmup", category="compile",
                      args={"kinds": args.primitive}):
            for _ in range(args.warmup):
                w = run(g, rng.integers(0, g.num_vertices, args.batch),
                        backend=bk)
                jax.block_until_ready(
                    w.dist if args.primitive == "sssp" else w.labels)
        sources = rng.integers(0, g.num_vertices, args.requests)
        with obs.span("serve", category="serve",
                      args={"requests": args.requests}):
            stats = serve(g, args.primitive, sources, args.batch, bk,
                          validate=args.validate, metrics=metrics)
    stats["storage"] = storage
    log.info(f"{stats['requests']} queries in "
             f"{stats['total_s']:.2f}s = {stats['qps']:.1f} q/s  "
             f"(lat ms mean {stats.get('lat_ms_mean', 0)} "
             f"p50 {stats.get('lat_ms_p50', 0)} "
             f"p95 {stats.get('lat_ms_p95', 0)} "
             f"p99 {stats.get('lat_ms_p99', 0)}, n={stats['samples']})")
    counts = stats.get("status_counts")
    if counts and any(counts[s] for s in STATUSES if s != "ok"):
        log.info("statuses: " + " ".join(
            f"{s}={counts[s]}" for s in STATUSES if counts[s]))
    for k, row in stats.get("per_kind", {}).items():
        log.info(f"  {k:9s} {row['requests']:4d} queries  "
                 f"lat ms mean {row['lat_ms_mean']} "
                 f"p50 {row['lat_ms_p50']} p95 {row['lat_ms_p95']} "
                 f"p99 {row['lat_ms_p99']}")
    if stats["overflow"]:
        log.warning(f"{stats['overflow']} BFS discoveries dropped by "
                    f"capped frontiers — rerun the affected queries "
                    f"with idempotence=False")
    if args.validate:
        log.info(f"validation failures: {stats['validation_failures']}")
        if stats["validation_failures"]:
            raise SystemExit("validation failed")
    if args.metrics:
        text = metrics.render()
        if args.metrics == "-":
            print(text, end="")  # reprolint: disable=RL005 -- --metrics "-" selects stdout
        else:
            with open(args.metrics, "w") as f:
                f.write(text)
            log.info(f"wrote Prometheus metrics to {args.metrics}")
    if args.trace:
        n_ev = obs.export_chrome_trace(args.trace)
        log.info(f"wrote {n_ev} trace events to {args.trace}")
    if args.json:
        try:
            with open(args.json) as f:
                rows = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            rows = []
        rows.append(stats)
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
