"""End-to-end training driver (CPU-runnable smoke scale → pod scale).

Wires every substrate together: config registry → model → sharded params
→ AdamW(+schedule) → synthetic data pipeline → jitted train step →
checkpoint/restore → fault-tolerant restart loop → straggler watchdog.

Examples
  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
      --steps 20 --simulate-failure 10      # injected fault + auto-resume
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.obs.log import get_logger
from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticLMDataset, make_batch_for
from repro.ft import RestartableTrainer
from repro.jax_compat import set_mesh
from repro.launch.mesh import make_test_mesh, mesh_axis_sizes
from repro.models import build_model
from repro.parallel.sharding import tree_shardings
from repro.train import adamw, make_schedule
from repro.train.optimizer import AdamWState, moment_specs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default=None,
                    help="constant|cosine|wsd (default: wsd for minicpm, "
                         "cosine otherwise — matching the papers)")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--data-parallel", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--simulate-failure", type=int, default=None)
    ap.add_argument("--quantized-optimizer", action="store_true")
    ap.add_argument("--log", default=None, help="write metrics jsonl")
    args = ap.parse_args(argv)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    model = build_model(cfg)
    schedule_kind = args.schedule or (
        "wsd" if args.arch == "minicpm-2b" else "cosine")
    sched = make_schedule(schedule_kind, args.lr, args.steps)
    opt_init, opt_update = adamw(
        sched, quantize_moments=args.quantized_optimizer)

    mesh = make_test_mesh(args.data_parallel, args.model_parallel)
    axes = mesh_axis_sizes(mesh)
    pspecs = model.param_specs(axes)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    ospec = AdamWState(
        step=jax.sharding.PartitionSpec(),
        m=moment_specs(pspecs, params_sds, args.quantized_optimizer),
        v=moment_specs(pspecs, params_sds, args.quantized_optimizer))
    shape = {"global_batch": args.batch, "seq_len": args.seq}

    ds = SyntheticLMDataset(cfg.vocab, args.seq, args.batch, seed=0)

    def make_batch():
        b = make_batch_for(cfg, shape, "train",
                           seed=ds.step + 1000 * ds.seed)
        lm = ds.next_batch()
        if "tokens" in b:
            b["tokens"] = lm["tokens"]
        b["labels"] = lm["labels"]
        return b

    with set_mesh(mesh):
        def init_state():
            params = model.init(jax.random.PRNGKey(0))
            return (params, opt_init(params))

        @jax.jit
        def train_step(params, opt_state, batch):
            def loss_fn(p):
                l, m = model.loss(p, batch)
                return l, m
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            new_p, new_o, om = opt_update(grads, opt_state, params)
            return new_p, new_o, {"loss": loss, **metrics, **om}

        def step_fn(state, step):
            params, opt_state = state
            batch = make_batch()
            params, opt_state, metrics = train_step(params, opt_state,
                                                    batch)
            return (params, opt_state), metrics

        if args.ckpt_dir:
            trainer = RestartableTrainer(args.ckpt_dir,
                                         ckpt_every=args.ckpt_every)
            report = trainer.run(
                init_state=init_state, step_fn=step_fn,
                data_state=ds.state, restore_data=ds.restore,
                total_steps=args.steps, fail_at=args.simulate_failure,
                mesh=mesh,
                spec_tree=(pspecs, ospec))
        else:
            state = init_state()
            history = []
            for step in range(args.steps):
                t0 = time.monotonic()
                state, metrics = step_fn(state, step)
                jax.block_until_ready(metrics)
                history.append({"step": step,
                                "dt": time.monotonic() - t0,
                                **{k: float(v) for k, v
                                   in metrics.items()}})
            report = {"completed": True, "restarts": 0,
                      "history": history, "stragglers": []}

    first = report["history"][0]["loss"] if report["history"] else None
    last = report["history"][-1]["loss"] if report["history"] else None
    get_logger("train").info(
        f"arch={args.arch} completed={report['completed']} "
        f"restarts={report['restarts']} steps={len(report['history'])} "
        f"loss {first:.4f} -> {last:.4f}")
    if args.log:
        with open(args.log, "w") as f:
            for row in report["history"]:
                f.write(json.dumps(row) + "\n")
    return report


if __name__ == "__main__":
    main()
