"""Production mesh definitions.

`make_production_mesh` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before calling it, and tests import freely under 1 device.

Single pod:  (16, 16)    axes ("data", "model")   — 256 chips (v5e pod)
Multi-pod:   (2, 16, 16) axes ("pod", "data", "model") — 512 chips.
The "pod" axis is pure data parallelism: the only collective that crosses
it is the per-step gradient all-reduce (DCN-friendly).

Meshes are built through ``repro.jax_compat.make_mesh`` so the
``axis_types=`` kwarg drift across jax releases never reaches callers.
"""
from __future__ import annotations

from repro.jax_compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def make_test_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests (requires enough local devices)."""
    return make_mesh((data, model), ("data", "model"))
