"""Train / serve step factories — the jitted programs the launcher,
dry-run, and roofline all consume.

make_train_step: loss → grad → (optional microbatch accumulation) →
AdamW update, with donated params/optimizer buffers and sharded in/out.
Gradient reduction across data/pod axes is implicit in GSPMD (batch is
sharded; XLA emits the reduce-scatter/all-reduce schedule — the
compute/comm overlap is XLA's latency-hiding scheduler's job, and the
§Perf pass verifies the collectives it emits).

make_serve_step: prefill or single-token decode against a static cache.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.api import Model


def make_train_step(model: Model, opt_update, *, grad_accum: int = 1,
                    donate: bool = True):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). ``grad_accum`` splits the batch on axis 0 into microbatches
    accumulated with a lax.scan (activation memory ÷ grad_accum)."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if grad_accum <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                acc = carry
                (l, met), g = grad_fn(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, (l, met)

            micro_batch = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, metricss) = jax.lax.scan(micro, zeros,
                                                     micro_batch)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metricss)
        new_params, new_opt, opt_metrics = opt_update(grads, opt_state,
                                                      params)
        return new_params, new_opt, {"loss": loss, **metrics,
                                     **opt_metrics}

    if donate:
        return jax.jit(train_step, donate_argnums=(0, 1))
    return jax.jit(train_step)


def make_serve_step(model: Model, kind: str):
    """kind='prefill' → serve_step(params, batch) -> (logits, cache);
    kind='decode'  → serve_step(params, cache, batch) -> (logits, cache)."""
    if kind == "prefill":
        return jax.jit(model.prefill)
    if kind == "decode":
        return jax.jit(model.decode_step, donate_argnums=(1,))
    raise ValueError(kind)
