from .optimizer import adamw, make_schedule
from .trainstep import make_train_step, make_serve_step

__all__ = ["adamw", "make_schedule", "make_train_step", "make_serve_step"]
