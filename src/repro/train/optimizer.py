"""Optimizers + LR schedules, built from scratch (no optax in the image).

- `adamw`: AdamW with decoupled weight decay and global-norm clipping.
  Moment states can be stored in **blockwise-quantized int8** (the
  gradient/optimizer-compression trick from DESIGN.md §5/§6 — 8-bit Adam à
  la Dettmers): each 256-value block keeps an fp32 absmax scale; this cuts
  optimizer state from 8 B/param to ~2 B/param and is what lets the 405B/1T
  archs fit their meshes.
- schedules: constant / cosine / WSD (warmup-stable-decay — the MiniCPM
  training schedule, so that arch's config trains as published).

State layout mirrors the param tree (same shardings apply), making the
optimizer fully ZeRO-compatible: moments inherit each param's
PartitionSpec.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

QBLOCK = 256


# ---------------------------------------------------------------------------
# blockwise int8 quantization for moment tensors
#
# Codes keep the PARAM'S SHAPE (blocks run along the last axis), so the
# moments inherit the param's PartitionSpec verbatim — dequantization is
# purely elementwise and GSPMD never reshards (a flat-block layout forces
# catastrophic replication copies; measured in EXPERIMENTS.md §Dry-run).
# ---------------------------------------------------------------------------

def quantizable(shape) -> bool:
    return len(shape) >= 2 and shape[-1] % QBLOCK == 0


def quantize_blockwise(x: jax.Array):
    """x: (..., D) with D % QBLOCK == 0 → codes int8 same shape,
    scale f32 (..., D // QBLOCK)."""
    shape = x.shape
    xb = x.astype(jnp.float32).reshape(shape[:-1]
                                       + (shape[-1] // QBLOCK, QBLOCK))
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    codes = jnp.round(xb / jnp.maximum(scale[..., None], 1e-12))
    return codes.reshape(shape).astype(jnp.int8), scale


def dequantize_blockwise(codes: jax.Array, scale: jax.Array, shape, dtype):
    shape = tuple(shape)
    xb = codes.astype(jnp.float32).reshape(
        shape[:-1] + (shape[-1] // QBLOCK, QBLOCK))
    return (xb * scale[..., None]).reshape(shape).astype(dtype)


class QTensor(NamedTuple):
    codes: jax.Array     # int8, same shape as the param
    scale: jax.Array     # f32, param.shape[:-1] + (D // QBLOCK,)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def make_schedule(kind: str, base_lr: float, total_steps: int,
                  warmup_steps: int = 100, stable_frac: float = 0.9,
                  min_ratio: float = 0.1):
    """Returns lr(step). kinds: constant | cosine | wsd."""
    warmup = max(warmup_steps, 1)

    def constant(step):
        w = jnp.minimum(step / warmup, 1.0)
        return base_lr * w

    def cosine(step):
        w = jnp.minimum(step / warmup, 1.0)
        t = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0., 1.)
        c = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return base_lr * w * c

    def wsd(step):
        """Warmup-Stable-Decay (MiniCPM): flat LR for stable_frac of the
        run, then a fast exponential-ish decay tail."""
        w = jnp.minimum(step / warmup, 1.0)
        stable_end = warmup + stable_frac * max(total_steps - warmup, 1)
        t = jnp.clip((step - stable_end)
                     / jnp.maximum(total_steps - stable_end, 1.0), 0., 1.)
        decay = min_ratio ** t          # exp decay to min_ratio
        return base_lr * w * decay

    return {"constant": constant, "cosine": cosine, "wsd": wsd}[kind]


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class AdamWState(NamedTuple):
    step: jax.Array
    m: object         # tree of f32 arrays or QTensor
    v: object


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw(schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: Optional[float] = 1.0,
          quantize_moments: bool = False):
    """Returns (init_fn, update_fn).

    update_fn(grads, state, params) -> (new_params, new_state, metrics)
    """

    def _q(x):
        if quantize_moments and quantizable(x.shape):
            return QTensor(*quantize_blockwise(x))
        return x.astype(jnp.float32)

    def _dq(q, like):
        if isinstance(q, QTensor):
            return dequantize_blockwise(q.codes, q.scale, like.shape,
                                        jnp.float32)
        return q

    def init_fn(params):
        zeros = jax.tree.map(lambda p: _q(jnp.zeros(p.shape, jnp.float32)),
                             params)
        zeros2 = jax.tree.map(lambda p: _q(jnp.zeros(p.shape, jnp.float32)),
                              params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=zeros2)

    def update_fn(grads, state, params):
        step = state.step + 1
        lr = schedule(step)
        gnorm = global_norm(grads)
        if clip_norm is not None:
            scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)

        def is_q(x):
            return isinstance(x, QTensor)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            mf = _dq(m, p)
            vf = _dq(v, p)
            mf = b1 * mf + (1 - b1) * g
            vf = b2 * vf + (1 - b2) * g * g
            mhat = mf / (1 - b1 ** step.astype(jnp.float32))
            vhat = vf / (1 - b2 ** step.astype(jnp.float32))
            upd = mhat / (jnp.sqrt(vhat) + eps)
            # decoupled weight decay on matrices only (ndim >= 2)
            if p.ndim >= 2:
                upd = upd + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
            return new_p, _q(mf), _q(vf)

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = jax.tree.flatten(state.m, is_leaf=is_q)[0]
        flat_v = jax.tree.flatten(state.v, is_leaf=is_q)[0]
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        metrics = {"lr": lr, "grad_norm": gnorm}
        return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics

    return init_fn, update_fn


def moment_specs(param_specs, params_sds=None, quantize_moments: bool
                 = False):
    """Optimizer-state PartitionSpecs matching the param tree.

    Quantized moments keep the param's shape (codes) / the param's shape
    minus the blocked last axis (scale), so BOTH reuse the param's spec —
    fit_sharding trims any non-divisible trailing entry on the scale.
    """
    from jax.sharding import PartitionSpec as P
    if not quantize_moments:
        return param_specs
    assert params_sds is not None, \
        "quantized moment_specs needs param shapes"
    return jax.tree.map(
        lambda s, sd: (QTensor(codes=s, scale=s)
                       if quantizable(sd.shape) else s),
        param_specs, params_sds,
        is_leaf=lambda s: isinstance(s, P))
