"""Gunrock-JAX core: the paper's data-centric frontier abstraction.

Public surface:
  graph      — CSR/CSC containers + generators (R-MAT, RGG, grid, bipartite)
  frontier   — Sparse/Dense frontier reps + compaction
  operators  — advance / filter / segmented_intersect / neighborhood_reduce
               / compute + LB/TWC/THREAD workload-mapping strategies
  backend    — operator backend registry + selection ("xla" | "pallas" |
               "auto"; context manager / REPRO_BACKEND env / per-call)
  direction  — push/pull direction-optimization heuristics
  enactor    — BSP convergence-loop driver
  primitives — bfs, sssp, pagerank, connected_components, bc,
               triangle_count, label_propagation, reach, who_to_follow
               (the algebraic ones route through repro.linalg)
"""
from . import backend, direction, enactor, frontier, graph, operators
from .backend import use_backend
from .primitives import (bc, bfs, connected_components, label_propagation,
                         pagerank, reach, sssp, triangle_count,
                         who_to_follow)

__all__ = ["graph", "frontier", "operators", "backend", "use_backend",
           "direction", "enactor", "bfs", "sssp", "pagerank",
           "connected_components", "bc", "triangle_count",
           "label_propagation", "reach", "who_to_follow"]
