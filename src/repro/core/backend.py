"""Backend registry + selection context for the operator layer.

Gunrock reaches its performance by fusing functors into a small set of
optimized operator kernels at compile time (paper §5.3); GraphBLAST gets
the same effect by routing every primitive through one backend layer.
This module is that layer for the JAX reproduction: every operator hot
path (advance expansion+gather, filter compaction, intersection probe,
SpMV sweep) is registered here once per backend, and primitives select a
backend instead of hand-threading ``use_kernel`` booleans.

Backends:
  "xla"    — pure jnp formulations (gather/scatter/segment ops). The
             portable default; XLA fuses the functor into the sweep.
  "pallas" — hand-written Pallas TPU kernels from ``repro.kernels``
             (interpret mode off-TPU, which is the correctness contract).
  "auto"   — resolves to "pallas" on a TPU backend, "xla" elsewhere.

Placements (the second registry dimension, paper §8.2.1 scale-out):
  "single"  — one device holds the whole graph (the default).
  "sharded" — the graph is 1-D partitioned over a mesh axis
              (``core.partition``); registered sharded providers run the
              hot path under ``shard_map`` with mesh collectives for the
              frontier/vector exchange (``core.distributed``). A sharded
              provider's array contract differs from its single twin:
              CSR/CSC operands arrive as (num_parts, …) stacked
              per-device slices (``ShardedGraph``), dense vectors stay
              replicated.
  "2d"      — the graph is vertex-cut 2-D partitioned over an R×C mesh
              (``partition_2d``): edge blocks are sharded over BOTH mesh
              axes, frontier discovery psum-ORs along the row axis and
              outputs mirror-merge along the column axis. CSR/CSC
              operands arrive as (R, C, …) stacked blocks
              (``Sharded2DGraph``), dense vectors stay replicated.

There is NO silent fallback from a distributed placement ("sharded" or
"2d") to "single" — dropping to one device would silently change what
the caller asked for — but a pallas-backend distributed dispatch falls
back to the xla provider of the SAME placement (kernels inside
shard_map are future work).

Selection precedence (first hit wins), identical for both dimensions:
  1. per-call override          advance(..., backend="pallas")
                                spmv(..., placement="sharded")
  2. deprecated use_kernel=     True -> "pallas", False -> "xla"
                                (backend only)
  3. context manager            with backend.use_backend("pallas"): ...
                                with backend.use_placement("sharded",
                                    mesh=mesh, axis="graph"): ...
  4. environment variable       REPRO_BACKEND=pallas / REPRO_PLACEMENT=…
  5. the default                "xla" / "single"

Resolution happens at *trace* time: jitted primitives resolve in their
Python wrapper and pass the concrete name down as a static argument, so
a cached trace can never observe a stale context/env value. The
placement context additionally carries the (mesh, axis) pair sharded
providers build their ``shard_map`` against; ``placement_mesh()`` reads
it at trace time.
"""
from __future__ import annotations

import importlib
import os
import threading
from contextlib import contextmanager
from typing import Callable, Optional

XLA = "xla"
PALLAS = "pallas"
AUTO = "auto"
BACKENDS = (XLA, PALLAS, AUTO)

SINGLE = "single"
SHARDED = "sharded"
TWOD = "2d"
PLACEMENTS = (SINGLE, SHARDED, TWOD)

ENV_VAR = "REPRO_BACKEND"
PLACEMENT_ENV_VAR = "REPRO_PLACEMENT"

_tls = threading.local()


class ProviderMissError(KeyError):
    """No provider for a (op, backend, placement) dispatch.

    Subclasses ``KeyError`` (the pinned public contract) but carries the
    structured miss — which op, which resolved backend/placement, the
    requested encoding when one was in play, and the nearest registered
    key — so a miss reads as "you asked for X, the registry has Y"
    instead of a bare repr.
    """

    def __init__(self, op: str, backend: str, placement: str,
                 encoding: Optional[str] = None,
                 nearest: Optional[tuple] = None,
                 detail: str = ""):
        self.op = op
        self.backend = backend
        self.placement = placement
        self.encoding = encoding
        self.nearest = nearest
        self.detail = detail
        super().__init__(str(self))

    def __str__(self) -> str:
        want = f"op={self.op!r} backend={self.backend!r} " \
               f"placement={self.placement!r}"
        if self.encoding is not None:
            want += f" encoding={self.encoding!r}"
        msg = f"no provider registered for {want}"
        if self.detail:
            msg += f" ({self.detail})"
        if self.nearest is not None:
            n_op, n_bk, n_pl = self.nearest
            msg += (f"; nearest registered key: op={n_op!r} "
                    f"backend={n_bk!r} placement={n_pl!r}")
        return msg


# (op, placement) -> reason. A distributed placement hole an op has
# consciously opted out of: dispatch still raises (the no-silent-drop
# rule stands), but the contract checker (repro.analysis.contracts)
# treats the hole as documented instead of flagging missing coverage.
_DECLARED_FALLBACKS: dict[tuple[str, str], str] = {}


def declare_fallback(op: str, placement: str, *, reason: str) -> None:
    """Declare that ``op`` intentionally has no ``placement`` provider.

    This does NOT change dispatch — a distributed miss still raises
    ``ProviderMissError`` — it makes the gap explicit so the registry
    contract checker can tell a declared design decision from an
    accidentally missing provider."""
    _check_placement(placement)
    if not reason:
        raise ValueError("declare_fallback requires a non-empty reason")
    _DECLARED_FALLBACKS[(op, placement)] = reason


def declared_fallback(op: str, placement: str) -> Optional[str]:
    """The declared-fallback reason for (op, placement), or None."""
    return _DECLARED_FALLBACKS.get((op, placement))


# (op_name, backend, placement) -> implementation. Populated by @register
# decorators in core.operators / core.frontier (xla), kernels.ops
# (pallas) and core.distributed (sharded).
_REGISTRY: dict[tuple[str, str, str], Callable] = {}

# (op_name, backend, placement) -> column encodings the provider decodes
# natively (third registry dimension, PR 6). Every provider accepts
# "dense" (any index width — gathers cast at the access point); a
# provider that also understands the delta stream declares
# encodings=("dense", "delta") and receives the EncodedCols pytree in
# the positional slot the dense array normally occupies. storage_arg()
# inserts the decode-to-dense fallback for everyone else, so every
# (op, backend, placement) combination works under every storage plan.
_ENCODINGS: dict[tuple[str, str, str], tuple] = {}

# Backends whose implementations live in a module that registers itself on
# import — imported lazily so `import repro.core` never pulls in Pallas.
_LAZY_PROVIDERS = {PALLAS: "repro.kernels.ops"}
# Same discipline for the distributed placements: their providers live
# with the mesh/shard_map machinery and register on import.
_LAZY_PLACEMENT_PROVIDERS = {SHARDED: "repro.core.distributed",
                             TWOD: "repro.core.distributed"}
_loaded: set[str] = set()

# Ops whose xla implementations live outside repro.core (the algebra
# layer): imported on first dispatch so `import repro.core` stays cheap
# and repro.linalg never has to be imported explicitly before use.
_LAZY_OPS = {
    "spmv": "repro.linalg.ops",
    "spmm": "repro.linalg.ops",
    "mxm": "repro.linalg.ops",
}


def _stack() -> list:
    if not hasattr(_tls, "stack"):
        _tls.stack = []
    return _tls.stack


def _pstack() -> list:
    if not hasattr(_tls, "pstack"):
        _tls.pstack = []
    return _tls.pstack


def _check(name: str) -> str:
    if name not in BACKENDS:
        raise ValueError(
            f"unknown backend {name!r}; expected one of {BACKENDS}")
    return name


def _check_placement(name: str) -> str:
    if name not in PLACEMENTS:
        raise ValueError(
            f"unknown placement {name!r}; expected one of {PLACEMENTS}")
    return name


def _auto() -> str:
    import jax
    return PALLAS if jax.default_backend() == "tpu" else XLA


def resolve(backend: Optional[str] = None,
            use_kernel: Optional[bool] = None) -> str:
    """Resolve a concrete backend name ("xla" | "pallas").

    ``backend`` is the per-call override; ``use_kernel`` is the deprecated
    boolean alias kept for one release (True -> pallas, False -> xla).
    Passing ``use_kernel`` always warns, even alongside an explicit
    ``backend`` (which wins).
    """
    if use_kernel is not None:
        # the obs.log funnel: a real DeprecationWarning (the pinned API
        # contract) plus a debug log under REPRO_LOG=debug
        from repro.obs.log import deprecated
        deprecated(
            "use_kernel= is deprecated; pass backend='pallas'/'xla' or use "
            "repro.core.backend.use_backend(...)", stacklevel=3)
        if backend is None:
            backend = PALLAS if use_kernel else XLA
    if backend is None:
        stack = _stack()
        backend = stack[-1] if stack else None
    if backend is None:
        backend = os.environ.get(ENV_VAR) or XLA
    _check(backend)
    return _auto() if backend == AUTO else backend


def resolve_placement(placement: Optional[str] = None) -> str:
    """Resolve a concrete placement name ("single" | "sharded"),
    mirroring backend resolution: per-call → context → env → default."""
    if placement is None:
        stack = _pstack()
        placement = stack[-1][0] if stack else None
    if placement is None:
        placement = os.environ.get(PLACEMENT_ENV_VAR) or SINGLE
    return _check_placement(placement)


@contextmanager
def use_backend(name: str):
    """Context manager: route operator dispatch through ``name``."""
    _check(name)
    _stack().append(name)
    try:
        yield
    finally:
        _stack().pop()


@contextmanager
def use_placement(name: str, mesh=None, axis="graph"):
    """Context manager: route operator dispatch through placement
    ``name``. For "sharded", ``mesh``/``axis`` name the 1-D mesh axis
    the providers shard over; for "2d", ``axis`` is the ("row", "col")
    axis-name pair of the R×C mesh. Providers read them at trace time
    via ``placement_mesh()``."""
    _check_placement(name)
    _pstack().append((name, mesh, axis))
    try:
        yield
    finally:
        _pstack().pop()


def placement_mesh():
    """The (mesh, axis) of the innermost placement context that carries
    one, or None. Distributed providers call this at trace time to build
    their shard_map (``axis`` is a name for 1-D placements, a name pair
    for 2-D)."""
    for name, mesh, axis in reversed(_pstack()):
        if mesh is not None:
            return mesh, axis
    return None


def resolve_graph_placement(graph, placement: Optional[str] = None):
    """Resolve placement for a Graph / ShardedGraph / Sharded2DGraph
    operand.

    Returns ``(placement, context)``: a ``ShardedGraph`` operand implies
    "sharded", a ``Sharded2DGraph`` implies "2d", and the context
    activates the container's mesh for the providers; a plain Graph
    resolves normally. Mismatches are errors, never silent overrides: a
    plain Graph under a distributed selection has nothing to shard over,
    and an explicit per-call placement that contradicts the operand's
    own layout cannot be honoured (re-assemble via ``pg.source`` to run
    single-device).
    Use as ``pl, ctx = resolve_graph_placement(g); with ctx: ...``.
    """
    import contextlib

    from .partition import Sharded2DGraph, ShardedGraph
    implied = (SHARDED if isinstance(graph, ShardedGraph)
               else TWOD if isinstance(graph, Sharded2DGraph) else None)
    if implied is not None:
        if placement is not None and placement != implied:
            raise ValueError(
                f"placement={placement!r} with a "
                f"{type(graph).__name__} operand: the per-device "
                f"slices only run the {implied!r} path; pass the "
                f"unpartitioned graph (the partition's .source) to run "
                f"elsewhere")
        axis = graph.axis if implied == SHARDED else graph.axes
        return implied, use_placement(implied, mesh=graph.mesh, axis=axis)
    pl = resolve_placement(placement)
    if pl == SHARDED:
        raise ValueError(
            "sharded placement needs a ShardedGraph operand "
            "(partition_1d(graph, p).shard(mesh)); got a single-device "
            "graph")
    if pl == TWOD:
        raise ValueError(
            "2d placement needs a Sharded2DGraph operand "
            "(partition_2d(graph, r, c).shard(mesh)); got a "
            "single-device graph")
    return pl, contextlib.nullcontext()


def register(op: str, backend: str, placement: str = SINGLE,
             encodings: tuple = ("dense",)):
    """Decorator: register ``fn`` as the ``backend`` implementation of
    operator hot path ``op`` under ``placement``. ``encodings`` declares
    which column storage encodings the provider decodes natively (see
    ``_ENCODINGS`` / ``storage_arg``)."""
    _check(backend)
    _check_placement(placement)
    for enc in encodings:
        if enc not in ("dense", "delta"):
            raise ValueError(f"unknown storage encoding {enc!r}")

    def deco(fn: Callable) -> Callable:
        _REGISTRY[(op, backend, placement)] = fn
        _ENCODINGS[(op, backend, placement)] = tuple(encodings)
        return fn

    return deco


def _load_lazy(op: str, bk: str, pl: str) -> None:
    if bk in _LAZY_PROVIDERS and bk not in _loaded:
        importlib.import_module(_LAZY_PROVIDERS[bk])
        _loaded.add(bk)
    if pl in _LAZY_PLACEMENT_PROVIDERS and pl not in _loaded:
        importlib.import_module(_LAZY_PLACEMENT_PROVIDERS[pl])
        _loaded.add(pl)
    if (op, bk, SINGLE) not in _REGISTRY and op in _LAZY_OPS:
        importlib.import_module(_LAZY_OPS.pop(op))


def dispatch(op: str, backend: Optional[str] = None,
             placement: Optional[str] = None) -> Callable:
    """Look up the implementation of ``op`` for the resolved backend and
    placement.

    Single placement falls back to the "xla" implementation when the
    backend has none registered (e.g. ops with no Pallas kernel yet).
    Distributed placements ("sharded", "2d") fall back only across
    *backends* (pallas → the xla provider of the same placement) and
    raise if the op has no provider for that placement at all — a
    silent drop to single-device execution would not be the program the
    caller selected. Internal call sites pass ``backend`` /
    ``placement`` only — the deprecated ``use_kernel`` alias lives
    solely in the public wrappers, which resolve it (with a warning)
    before anything reaches the registry.
    """
    bk = resolve(backend)
    pl = resolve_placement(placement)
    return _lookup(op, bk, pl)[1]


def _lookup(op: str, bk: str, pl: str) -> tuple[tuple, Callable]:
    """Resolved (registry key, impl) — the key identifies the provider
    that will actually run (fallbacks included), which is what encoding
    acceptance must be read from.

    Chaos hook: an installed ``repro.ft.inject`` plan with a
    ``provider_miss`` clause makes this lookup fail deterministically as
    if the table had no entry — the injection point the retry/degradation
    ladder is tested against. With no plan installed the hook is a single
    ``None`` check."""
    plan = _fault_plan()
    if plan is not None and plan.should("provider_miss", op):
        raise ProviderMissError(op, bk, pl, nearest=_nearest_key(op, bk, pl),
                                detail="injected by repro.ft.inject")
    _load_lazy(op, bk, pl)
    key = (op, bk, pl)
    impl = _REGISTRY.get(key)
    if impl is None:
        key = (op, XLA, pl)
        impl = _REGISTRY.get(key)
    if impl is None:
        if pl != SINGLE:
            raise ProviderMissError(
                op, bk, pl, nearest=_nearest_key(op, bk, pl),
                detail=f"{pl} dispatch never falls back to the "
                       f"single-device path")
        raise ProviderMissError(op, bk, pl,
                                nearest=_nearest_key(op, bk, pl))
    return key, impl


def _fault_plan():
    """The active ``repro.ft.inject`` plan, or None. Imported lazily so
    the registry module never pulls ``repro.ft`` (and its jax-importing
    health probes) at import time."""
    import sys
    mod = sys.modules.get("repro.ft.inject")
    if mod is None:
        return None
    return mod.active()


def _nearest_key(op: str, bk: str, pl: str) -> Optional[tuple]:
    """The registered key closest to the missed (op, bk, pl): prefer the
    same op under another backend/placement, else the closest op name."""
    same_op = [k for k in _REGISTRY if k[0] == op]
    if same_op:
        # same backend beats same placement beats anything
        return min(same_op, key=lambda k: (k[1] != bk, k[2] != pl, k))
    import difflib
    names = sorted({k[0] for k in _REGISTRY})
    close = difflib.get_close_matches(op, names, n=1)
    if close:
        return min(k for k in _REGISTRY if k[0] == close[0])
    return None


def registered(op: str, backend: str, placement: str = SINGLE) -> bool:
    """True if ``op`` has a native (non-fallback) impl for ``backend``
    under ``placement``."""
    _load_lazy(op, backend, placement)
    return (op, backend, placement) in _REGISTRY


def declared_encodings(op: str, backend: Optional[str] = None,
                       placement: Optional[str] = None) -> tuple:
    """Column encodings natively decoded by the provider that dispatch
    would select for (op, backend, placement), fallbacks included."""
    bk = resolve(backend)
    pl = resolve_placement(placement)
    key, _ = _lookup(op, bk, pl)
    return _ENCODINGS.get(key, ("dense",))


def coerce_store(op: str, backend: Optional[str] = None,
                 placement: Optional[str] = None, *, store):
    """The registry-level decode-to-dense fallback on a raw column
    store: returns ``store`` unchanged when it is already dense or when
    the provider dispatch would select declared its encoding, else the
    decoded dense int32 view."""
    from . import storage as S
    if not isinstance(store, S.EncodedCols):
        return store
    if "delta" in declared_encodings(op, backend, placement):
        return store
    return S.decode_cols(store)


def storage_arg(op: str, backend: Optional[str] = None,
                placement: Optional[str] = None, *, graph,
                side: str = "csr"):
    """The column-storage operand to pass in the registry contract's
    ``col_indices`` slot: the graph's native store when the selected
    provider declared its encoding, else the decoded dense int32 view
    (the registry-level decode-to-dense fallback). ``side`` picks the
    CSR or CSC mirror."""
    store = graph.col_store if side == "csr" else graph.csc_store
    return coerce_store(op, backend, placement, store=store)


# ---------------------------------------------------------------------------
# Capacity tiers (the frontier-proportional dispatch axis)
# ---------------------------------------------------------------------------


def tier_plan(op: str, cap: int, *, min_tier: Optional[int] = None
              ) -> tuple[int, ...]:
    """Static capacity ladder for ``op`` up to ``cap``.

    Primitives ``lax.switch`` their per-iteration step over this ladder
    so an iteration with a 40-vertex frontier does ~one-tile work
    instead of worst-case ``cap``. The ladder is keyed by op because its
    *floor* is the tuner's tile choice for that op on this platform
    (kernels/tuner.py): a tier smaller than one kernel tile would pad
    right back up to the tile, buying switch overhead for nothing.
    Tier choice never affects results — every rung computes the same
    masked expansion, larger rungs just carry more dead lanes — which is
    the tier/untier bit-parity contract tests/test_tiered.py pins.
    """
    from repro.core.frontier import MIN_TIER, tier_caps
    if min_tier is None:
        try:
            from repro.kernels import tuner
            min_tier = tuner.tier_floor(op, MIN_TIER)
        except ImportError:          # tuner unavailable: heuristic floor
            min_tier = MIN_TIER
    return tier_caps(cap, min_tier=min_tier)


def dispatch_tiered(op: str, backend: Optional[str] = None,
                    placement: Optional[str] = None, *, cap: int,
                    pin: bool = False) -> tuple[Callable, tuple[int, ...]]:
    """Resolve ``op`` plus the capacity ladder its call site may switch
    over: ``(impl, caps)``.

    ``pin=True`` and the distributed placements both pin to the top
    tier (single-rung ladder): a dense sweep touches every row
    regardless of the frontier, and sharded/2d providers run
    collectives whose shapes must agree across devices no matter what
    any one device's frontier holds — per-device tier choices would
    deadlock the exchange.
    """
    bk = resolve(backend)
    pl = resolve_placement(placement)
    impl = dispatch(op, bk, pl)
    if pin or pl != SINGLE:
        return impl, (max(int(cap), 1),)
    return impl, tier_plan(op, cap)
