"""Backend registry + selection context for the operator layer.

Gunrock reaches its performance by fusing functors into a small set of
optimized operator kernels at compile time (paper §5.3); GraphBLAST gets
the same effect by routing every primitive through one backend layer.
This module is that layer for the JAX reproduction: every operator hot
path (advance expansion+gather, filter compaction, intersection probe,
SpMV sweep) is registered here once per backend, and primitives select a
backend instead of hand-threading ``use_kernel`` booleans.

Backends:
  "xla"    — pure jnp formulations (gather/scatter/segment ops). The
             portable default; XLA fuses the functor into the sweep.
  "pallas" — hand-written Pallas TPU kernels from ``repro.kernels``
             (interpret mode off-TPU, which is the correctness contract).
  "auto"   — resolves to "pallas" on a TPU backend, "xla" elsewhere.

Selection precedence (first hit wins):
  1. per-call override          advance(..., backend="pallas")
  2. deprecated use_kernel=     True -> "pallas", False -> "xla"
  3. context manager            with backend.use_backend("pallas"): ...
  4. environment variable       REPRO_BACKEND=pallas
  5. the default                "xla"

Resolution happens at *trace* time: jitted primitives resolve in their
Python wrapper and pass the concrete name down as a static argument, so
a cached trace can never observe a stale context/env value.
"""
from __future__ import annotations

import importlib
import os
import threading
import warnings
from contextlib import contextmanager
from typing import Callable, Optional

XLA = "xla"
PALLAS = "pallas"
AUTO = "auto"
BACKENDS = (XLA, PALLAS, AUTO)

ENV_VAR = "REPRO_BACKEND"

_tls = threading.local()

# (op_name, backend) -> implementation. Populated by @register decorators
# in core.operators / core.frontier (xla) and kernels.ops (pallas).
_REGISTRY: dict[tuple[str, str], Callable] = {}

# Backends whose implementations live in a module that registers itself on
# import — imported lazily so `import repro.core` never pulls in Pallas.
_LAZY_PROVIDERS = {PALLAS: "repro.kernels.ops"}
_loaded: set[str] = set()

# Ops whose xla implementations live outside repro.core (the algebra
# layer): imported on first dispatch so `import repro.core` stays cheap
# and repro.linalg never has to be imported explicitly before use.
_LAZY_OPS = {
    "spmv": "repro.linalg.ops",
    "spmm": "repro.linalg.ops",
    "mxm": "repro.linalg.ops",
}


def _stack() -> list:
    if not hasattr(_tls, "stack"):
        _tls.stack = []
    return _tls.stack


def _check(name: str) -> str:
    if name not in BACKENDS:
        raise ValueError(
            f"unknown backend {name!r}; expected one of {BACKENDS}")
    return name


def _auto() -> str:
    import jax
    return PALLAS if jax.default_backend() == "tpu" else XLA


def resolve(backend: Optional[str] = None,
            use_kernel: Optional[bool] = None) -> str:
    """Resolve a concrete backend name ("xla" | "pallas").

    ``backend`` is the per-call override; ``use_kernel`` is the deprecated
    boolean alias kept for one release (True -> pallas, False -> xla).
    Passing ``use_kernel`` always warns, even alongside an explicit
    ``backend`` (which wins).
    """
    if use_kernel is not None:
        warnings.warn(
            "use_kernel= is deprecated; pass backend='pallas'/'xla' or use "
            "repro.core.backend.use_backend(...)", DeprecationWarning,
            stacklevel=3)
        if backend is None:
            backend = PALLAS if use_kernel else XLA
    if backend is None:
        stack = _stack()
        backend = stack[-1] if stack else None
    if backend is None:
        backend = os.environ.get(ENV_VAR) or XLA
    _check(backend)
    return _auto() if backend == AUTO else backend


@contextmanager
def use_backend(name: str):
    """Context manager: route operator dispatch through ``name``."""
    _check(name)
    _stack().append(name)
    try:
        yield
    finally:
        _stack().pop()


def register(op: str, backend: str):
    """Decorator: register ``fn`` as the ``backend`` implementation of
    operator hot path ``op``."""
    _check(backend)

    def deco(fn: Callable) -> Callable:
        _REGISTRY[(op, backend)] = fn
        return fn

    return deco


def dispatch(op: str, backend: Optional[str] = None) -> Callable:
    """Look up the implementation of ``op`` for the resolved backend.

    Falls back to the "xla" implementation when the backend has none
    registered (e.g. ops with no Pallas kernel yet). Internal call sites
    pass ``backend`` only — the deprecated ``use_kernel`` alias lives
    solely in the public wrappers, which resolve it (with a warning)
    before anything reaches the registry.
    """
    bk = resolve(backend)
    if bk in _LAZY_PROVIDERS and bk not in _loaded:
        importlib.import_module(_LAZY_PROVIDERS[bk])
        _loaded.add(bk)
    if (op, bk) not in _REGISTRY and op in _LAZY_OPS:
        importlib.import_module(_LAZY_OPS.pop(op))
    impl = _REGISTRY.get((op, bk))
    if impl is None:
        impl = _REGISTRY.get((op, XLA))
    if impl is None:
        raise KeyError(f"no implementation registered for operator {op!r}")
    return impl


def registered(op: str, backend: str) -> bool:
    """True if ``op`` has a native (non-fallback) impl for ``backend``."""
    if backend in _LAZY_PROVIDERS and backend not in _loaded:
        importlib.import_module(_LAZY_PROVIDERS[backend])
        _loaded.add(backend)
    if (op, backend) not in _REGISTRY and op in _LAZY_OPS:
        importlib.import_module(_LAZY_OPS.pop(op))
    return (op, backend) in _REGISTRY
