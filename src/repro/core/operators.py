"""Gunrock's graph operators in JAX (paper §3–§5).

Operators:
  advance               — neighbor expansion (V→V, V→E, E→V, E→E), the
                          irregular workhorse. Implemented with the paper's
                          merge-based Load-Balanced partitioning (LB):
                          prefix-sum over degrees + per-output-slot binary
                          search (sorted search), which is the TPU-native
                          translation of Davidson/Merrill load balancing.
  advance_pull          — pull/reverse advance over CSC from an unvisited
                          frontier (direction-optimized traversal, §5.1.4).
  filter                — stream compaction with exact or heuristic
                          uniquification (§4.2, §5.2.1).
  neighborhood_reduce   — advance + per-source segmented reduction (§8.2.3).
  segmented_intersect   — pairwise sorted neighbor-list intersection (§4.3),
                          SmallLarge binary-probe scheme.
  compute               — per-element map over a frontier (fused by XLA into
                          adjacent traversal ops — the paper's kernel fusion).

Conventions:
  * All shapes static. Invalid lanes carry id == -1 and mask == False.
  * "Functors" are *vectorized*: they receive whole vectors
    (src, dst, edge_id, rank) + problem-data pytree and return
    (keep_mask, new_data). This is the JAX translation of Gunrock's
    per-edge cond/apply functors; XLA fuses them into the traversal,
    exactly as Gunrock fuses functors into operator kernels at
    compile time (§5.3).
  * Load-balancing strategy is selectable (LB | TWC | THREAD) to support the
    paper's Fig.-20 ablation; LB is the default (the paper's LB_CULL).

Backends:
  Every operator takes ``backend=`` ("xla" | "pallas" | "auto" | None) and
  dispatches its hot path through the registry in ``core.backend``:

    advance               — "advance": XLA sorted-search + gathers below, or
                            the fused Pallas kernel (kernels/advance_fused.py)
                            that does search + CSR gathers in one pass.
    filter / compaction   — "compact": XLA scatter compaction or the Pallas
                            filter_compact kernel (tile-local scan).
    segmented_intersect   — "segment_search" for the binary probe, plus
                            "advance" for its expansion and "compact" for
                            its output.

  ``backend=None`` defers to the ambient selection (context manager /
  REPRO_BACKEND env var; see core/backend.py). THREAD has no Pallas
  implementation — it is the deliberately-unbalanced ablation baseline —
  and silently runs the XLA path on every backend. Design notes:
  DESIGN.md.

Batched operators:
  ``advance_batch`` / ``filter_frontier_batch`` / ``advance_pull_batch``
  run B traversal lanes over one shared topology in a single program —
  the frontier-matrix view (GraphBLAST's multi-source BFS). Hot paths
  dispatch through "advance_batch" (vmapped XLA expansion, or the fused
  Pallas kernel with an explicit (B, tiles) grid) and vmapped "compact".
  Functors keep their single-lane signature and are vmapped over the
  batch axis, so BFS/SSSP share one functor between the single- and
  multi-source paths; problem-data pytrees carry a leading batch axis.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import backend as B
from . import storage as S
from .frontier import (INVALID, BatchedDenseFrontier, BatchedSparseFrontier,
                       DenseFrontier, SparseFrontier, compact_values,
                       compact_values_batch)
from .graph import Graph, row_segments_of

# ---------------------------------------------------------------------------
# Expansion geometry: given per-input segment sizes, map output slots back to
# (input position, rank within segment). This is the LB sorted-search.
# ---------------------------------------------------------------------------


class Expansion(NamedTuple):
    in_pos: jax.Array    # (cap_out,) int32: which input item produced the slot
    rank: jax.Array      # (cap_out,) int32: index within the input's segment
    valid: jax.Array     # (cap_out,) bool
    total: jax.Array     # () int32: true number of output items


def lb_expand(sizes: jax.Array, valid_in: jax.Array, cap_out: int) -> Expansion:
    """Merge-based load-balanced expansion (paper §5.1.3, Fig. 11).

    sizes: (cap_in,) int32 per-input segment length (0 for invalid lanes).
    Every output slot costs O(log cap_in) — perfectly balanced by output.
    """
    sizes = jnp.where(valid_in, sizes, 0).astype(jnp.int32)
    offsets = jnp.cumsum(sizes, dtype=jnp.int32) - sizes    # exclusive scan
    total = (offsets[-1] + sizes[-1]) if sizes.shape[0] else jnp.int32(0)
    slots = jnp.arange(cap_out, dtype=jnp.int32)
    # sorted search: which segment does each output slot land in?
    in_pos = jnp.searchsorted(offsets, slots, side="right").astype(jnp.int32) - 1
    in_pos = jnp.clip(in_pos, 0, max(sizes.shape[0] - 1, 0))
    rank = slots - offsets[in_pos]
    valid = slots < total
    return Expansion(in_pos=in_pos, rank=rank, valid=valid,
                     total=total.astype(jnp.int32))


def twc_order(sizes: jax.Array) -> jax.Array:
    """TWC size-class grouping permutation — the dynamic-grouping (TWC)
    emulation of paper §5.1.2. GPU TWC arbitrates threads/warps/CTAs;
    that mechanism has no TPU analogue (documented in DESIGN.md). We keep
    its *grouping* idea: a stable sort of segments into small ≤ 32
    "thread", ≤ 256 "warp", else "block" classes, so each class is
    processed together by the LB machinery — identical output multiset,
    distinct scheduling order (the Fig.-20 ablation contrast). Consumed
    by the TWC path of ``advance``."""
    cls = jnp.where(sizes <= 32, 0, jnp.where(sizes <= 256, 1, 2))
    return jnp.argsort(cls, stable=True)


@B.register("advance", B.XLA, encodings=("dense", "delta"))
def _advance_xla(row_offsets: jax.Array, col_indices: S.ColStore,
                 base: jax.Array, sizes: jax.Array, cap_out: int):
    """XLA advance hot path: LB sorted search + CSR gathers as separate
    (XLA-fused) passes. Shares the registry contract with the fused Pallas
    kernel: (src, dst, edge_id, in_pos, rank, valid, total), with
    src/dst/edge_id masked to INVALID and rank to 0 on dead lanes.

    ``col_indices`` is the column *store* — a dense array at any index
    width, or the delta EncodedCols pytree. gather_cols decodes per
    touched edge (the src gather already in hand supplies the owning
    row, so delta decode adds exactly one uint16 gather + one add) and
    always yields int32 — storage width never leaks into frontier ids.
    """
    exp = lb_expand(sizes, jnp.ones(sizes.shape, bool), cap_out)
    src = base[exp.in_pos]
    edge_id = row_offsets[src] + exp.rank
    edge_id = jnp.where(exp.valid, edge_id, 0)
    dst = S.gather_cols(col_indices, edge_id, src)
    return (jnp.where(exp.valid, src, INVALID),
            jnp.where(exp.valid, dst, INVALID),
            jnp.where(exp.valid, edge_id, INVALID), exp.in_pos,
            jnp.where(exp.valid, exp.rank, 0), exp.valid, exp.total)


# ---------------------------------------------------------------------------
# advance
# ---------------------------------------------------------------------------


class AdvanceResult(NamedTuple):
    src: jax.Array        # (cap_out,) int32 source vertex of each output slot
    dst: jax.Array        # (cap_out,) int32 destination vertex
    edge_id: jax.Array    # (cap_out,) int32 CSR edge index
    in_pos: jax.Array     # (cap_out,) int32 input-frontier lane of each slot
    valid: jax.Array      # (cap_out,) bool
    total: jax.Array      # () int32 number of valid outputs (pre-functor)


def _frontier_base_vertices(graph: Graph, frontier: SparseFrontier,
                            input_kind: str):
    """Resolve the vertex whose neighbor list each input item expands."""
    ids = jnp.where(frontier.valid_mask, frontier.ids, 0)
    if input_kind == "vertex":
        return ids, frontier.valid_mask
    if input_kind == "edge":
        # an edge item expands the neighbor list of its destination
        # vertex (ids are edge positions; decode-on-gather handles every
        # storage plan and returns int32 vertex ids)
        return S.gather_cols(graph.col_store, ids), frontier.valid_mask
    raise ValueError(f"unknown input_kind {input_kind}")


def advance(graph: Graph, frontier: SparseFrontier, cap_out: int,
            functor: Optional[Callable] = None, data=None,
            input_kind: str = "vertex", strategy: str = "LB", *,
            backend: Optional[str] = None,
            use_kernel: Optional[bool] = None
            ) -> tuple[AdvanceResult, object]:
    """Gunrock advance (push): expand neighbor lists of the input frontier.

    functor(src, dst, edge_id, rank, valid, data) -> (keep_mask, data')
    applied in the same pass (kernel fusion). Returns the raw expansion (so
    callers can build V or E output frontiers) plus updated problem data.
    The expansion+gather hot path dispatches through the "advance" backend
    registry entry (see module docstring).
    """
    bk = B.resolve(backend, use_kernel)
    if strategy == "THREAD":
        # Static per-vertex mapping (ThreadExpand, §5.1.1) — the
        # Harish-Narayanan quadratic mapping the paper cites [32]: sweep
        # EVERY CSR slot and keep those whose source is in the frontier.
        # No load balancing, no compaction of the work list; cost is
        # O(m) per advance regardless of frontier size (the ablation
        # contrast to LB/TWC). Vertex frontiers only.
        assert input_kind == "vertex", "THREAD supports vertex frontiers"
        n, m = graph.num_vertices, graph.num_edges
        flags = frontier.to_dense(n).flags
        slot = jnp.arange(m, dtype=jnp.int32)
        src_of = (graph.row_seg if graph.row_seg is not None
                  else row_segments_of(graph.row_offsets, m))
        valid = flags[src_of]
        res = AdvanceResult(
            src=jnp.where(valid, src_of, INVALID)[:cap_out],
            dst=jnp.where(valid, graph.cols(), INVALID)[:cap_out],
            edge_id=jnp.where(valid, slot, INVALID)[:cap_out],
            in_pos=src_of[:cap_out],
            valid=valid[:cap_out],
            total=jnp.sum(valid, dtype=jnp.int32))
        if functor is None:
            return res, data
        keep, data = functor(res.src, res.dst, res.edge_id,
                             jnp.zeros_like(res.src), res.valid, data)
        keep = keep & res.valid
        return AdvanceResult(src=jnp.where(keep, res.src, INVALID),
                             dst=jnp.where(keep, res.dst, INVALID),
                             edge_id=jnp.where(keep, res.edge_id, INVALID),
                             in_pos=res.in_pos, valid=keep,
                             total=res.total), data

    if strategy not in ("LB", "TWC"):
        raise ValueError(f"unknown strategy {strategy}")
    if graph.num_edges == 0:
        bk = B.XLA          # nothing to gather; skip the kernel path
    base, valid_in = _frontier_base_vertices(graph, frontier, input_kind)
    deg = graph.row_offsets[base + 1] - graph.row_offsets[base]
    sizes = jnp.where(valid_in, deg, 0).astype(jnp.int32)
    order = None
    if strategy == "TWC":
        # dynamic-grouping emulation (§5.1.2): stably reorder segments by
        # size class, expand with the LB machinery, map lanes back
        order = twc_order(sizes)
        base, sizes = base[order], sizes[order]
    expand = B.dispatch("advance", bk, B.SINGLE)
    cols = B.storage_arg("advance", bk, B.SINGLE, graph=graph)
    src, dst, edge_id, in_pos, rank, valid, total = expand(
        graph.row_offsets, cols, base, sizes, cap_out)
    if order is not None:
        in_pos = order[in_pos]
    res = AdvanceResult(src=src, dst=dst, edge_id=edge_id, in_pos=in_pos,
                        valid=valid, total=total)
    if functor is None:
        return res, data
    keep, data = functor(res.src, res.dst, res.edge_id, rank, res.valid,
                         data)
    keep = keep & res.valid
    res = AdvanceResult(src=jnp.where(keep, res.src, INVALID),
                        dst=jnp.where(keep, res.dst, INVALID),
                        edge_id=jnp.where(keep, res.edge_id, INVALID),
                        in_pos=res.in_pos,
                        valid=keep, total=res.total)
    return res, data


@B.register("advance_batch", B.XLA, encodings=("dense", "delta"))
def _advance_batch_xla(row_offsets: jax.Array, col_indices: S.ColStore,
                       base: jax.Array, sizes: jax.Array, cap_out: int):
    """XLA batched advance: vmap the single-lane expansion over the batch
    axis (base/sizes (B, cap_in)); the CSR is closed over and shared.
    Contract mirrors "advance" with batched outputs and totals (B,)."""
    return jax.vmap(
        lambda b, s: _advance_xla(row_offsets, col_indices, b, s, cap_out)
    )(base, sizes)


def advance_batch(graph: Graph, frontier: BatchedSparseFrontier,
                  cap_out: int, functor: Optional[Callable] = None,
                  data=None, input_kind: str = "vertex",
                  strategy: str = "LB", *,
                  backend: Optional[str] = None
                  ) -> tuple[AdvanceResult, object]:
    """Multi-source push advance: expand B frontier lanes in one program.

    Same semantics as ``advance`` per lane. ``functor`` keeps its
    single-lane signature and is vmapped over the batch axis, so problem
    data must carry a leading batch axis on every leaf. Returns an
    ``AdvanceResult`` whose fields are (B, cap_out) with ``total`` (B,).
    """
    bk = B.resolve(backend)
    if strategy == "THREAD":
        # batched ThreadExpand: one shared O(m) sweep, per-lane masks
        assert input_kind == "vertex", "THREAD supports vertex frontiers"
        n, m = graph.num_vertices, graph.num_edges
        flags = frontier.to_dense(n).flags               # (B, n)
        slot = jnp.arange(m, dtype=jnp.int32)
        src_of = (graph.row_seg if graph.row_seg is not None
                  else row_segments_of(graph.row_offsets, m))
        valid = flags[:, src_of] if m else jnp.zeros((frontier.batch, 0),
                                                     bool)
        res = AdvanceResult(
            src=jnp.where(valid, src_of[None, :], INVALID)[:, :cap_out],
            dst=jnp.where(valid, graph.cols()[None, :],
                          INVALID)[:, :cap_out],
            edge_id=jnp.where(valid, slot[None, :], INVALID)[:, :cap_out],
            in_pos=jnp.broadcast_to(src_of[None, :],
                                    valid.shape)[:, :cap_out],
            valid=valid[:, :cap_out],
            total=jnp.sum(valid, dtype=jnp.int32, axis=1))
    else:
        if strategy not in ("LB", "TWC"):
            raise ValueError(f"unknown strategy {strategy}")
        if graph.num_edges == 0:
            bk = B.XLA
        # the helper is pure indexing on ids/valid_mask, so it serves the
        # batched frontier unchanged
        base, valid_in = _frontier_base_vertices(graph, frontier,
                                                 input_kind)
        deg = graph.row_offsets[base + 1] - graph.row_offsets[base]
        sizes = jnp.where(valid_in, deg, 0).astype(jnp.int32)
        order = None
        if strategy == "TWC":
            order = jax.vmap(twc_order)(sizes)
            base = jnp.take_along_axis(base, order, axis=1)
            sizes = jnp.take_along_axis(sizes, order, axis=1)
        expand = B.dispatch("advance_batch", bk, B.SINGLE)
        cols = B.storage_arg("advance_batch", bk, B.SINGLE, graph=graph)
        src, dst, edge_id, in_pos, rank, valid, total = expand(
            graph.row_offsets, cols, base, sizes, cap_out)
        if order is not None:
            in_pos = jnp.take_along_axis(order, in_pos, axis=1)
        res = AdvanceResult(src=src, dst=dst, edge_id=edge_id,
                            in_pos=in_pos, valid=valid, total=total)
    if functor is None:
        return res, data
    rank_arg = (jnp.zeros_like(res.src) if strategy == "THREAD" else rank)
    keep, data = jax.vmap(functor)(res.src, res.dst, res.edge_id, rank_arg,
                                   res.valid, data)
    keep = keep & res.valid
    return AdvanceResult(src=jnp.where(keep, res.src, INVALID),
                         dst=jnp.where(keep, res.dst, INVALID),
                         edge_id=jnp.where(keep, res.edge_id, INVALID),
                         in_pos=res.in_pos, valid=keep,
                         total=res.total), data


def frontier_workload(graph: Graph, frontier) -> jax.Array:
    """Upper bound on the advance output size of ``frontier``: the sum of
    out-degrees of its live vertices. (B,) for a batched frontier, ()
    for a single one. This is the traced quantity the tiered dispatch
    switches on (backend.tier_plan / enactor.tiered_step): computing it
    costs one degree gather — frontier-shaped, never edge-shaped."""
    ids = jnp.where(frontier.valid_mask, frontier.ids, 0)
    deg = graph.row_offsets[ids + 1] - graph.row_offsets[ids]
    deg = jnp.where(frontier.valid_mask, deg, 0)
    return jnp.sum(deg, axis=-1).astype(jnp.int32)


@B.register("advance_filter", B.XLA, encodings=("dense", "delta"))
def _advance_filter_xla(row_offsets: jax.Array, col_indices: S.ColStore,
                        base: jax.Array, sizes: jax.Array,
                        visited: jax.Array, cap_out: int, cap_front: int):
    """XLA advance_filter: the unfused composition the fused Pallas
    megakernel must match bit for bit — LB expansion, visited-bitmap
    predicate, exact FIRST-occurrence culling (min-lane winner, so the
    surviving order is ascending slot order — exactly the order the
    sequential kernel emits), compaction of (dst, src) into cap_front
    slots. Returns (ids, srcs, length, total)."""
    src, dst, _, _, _, valid, _ = _advance_xla(row_offsets, col_indices,
                                               base, sizes, cap_out)
    n = visited.shape[0]
    safe = jnp.where(valid, dst, 0)
    keep = valid & (visited.astype(jnp.int32)[safe] == 0)
    lane = jnp.arange(cap_out, dtype=jnp.int32)
    first = jnp.full((n,), cap_out, jnp.int32)
    first = first.at[safe].min(jnp.where(keep, lane, cap_out), mode="drop")
    keep = keep & (first[safe] == lane)
    ids, length = compact_values(dst, keep, cap_front, backend=B.XLA)
    srcs, _ = compact_values(src, keep, cap_front, backend=B.XLA)
    # int32-pinned: under jax_enable_x64 jnp.sum would widen the total
    # and split the while_loop carry dtypes between push and pull
    return ids, srcs, length, jnp.sum(
        keep.astype(jnp.int32)).astype(jnp.int32)


@B.register("advance_filter_batch", B.XLA, encodings=("dense", "delta"))
def _advance_filter_batch_xla(row_offsets: jax.Array,
                              col_indices: S.ColStore, base: jax.Array,
                              sizes: jax.Array, visited: jax.Array,
                              cap_out: int, cap_front: int):
    """Batched XLA advance_filter: vmap the single-lane composition
    (base/sizes/visited carry a leading batch axis, CSR shared)."""
    return jax.vmap(
        lambda b, s, v: _advance_filter_xla(row_offsets, col_indices,
                                            b, s, v, cap_out, cap_front)
    )(base, sizes, visited)


def advance_filter(graph: Graph, frontier: SparseFrontier,
                   visited: jax.Array, cap_out: int,
                   cap_front: Optional[int] = None, *,
                   backend: Optional[str] = None
                   ) -> tuple[SparseFrontier, jax.Array, jax.Array]:
    """Fused advance→filter (paper §5.3 taken whole): expand the
    frontier, keep destinations whose ``visited`` bit is clear, cull
    duplicates exactly (first discovering slot wins), and compact the
    survivors — without materializing the intermediate edge tuple.

    Returns ``(new_frontier, srcs, total)``: the compacted discovered
    frontier (capacity ``cap_front``, default the input's capacity), the
    discovering source of each surviving slot (aligned with
    ``new_frontier.ids``; the predecessor scatter BFS needs), and the
    true pre-clamp survivor count. Dispatches "advance_filter": the XLA
    composition above, or one fused Pallas megakernel
    (kernels/advance_filter_fused.py).
    """
    bk = B.resolve(backend)
    if graph.num_edges == 0:
        bk = B.XLA
    cap_front = frontier.capacity if cap_front is None else cap_front
    base, valid_in = _frontier_base_vertices(graph, frontier, "vertex")
    deg = graph.row_offsets[base + 1] - graph.row_offsets[base]
    sizes = jnp.where(valid_in, deg, 0).astype(jnp.int32)
    impl = B.dispatch("advance_filter", bk, B.SINGLE)
    cols = B.storage_arg("advance_filter", bk, B.SINGLE, graph=graph)
    ids, srcs, length, total = impl(graph.row_offsets, cols,
                                    base, sizes,
                                    visited.astype(jnp.int32),
                                    cap_out, cap_front)
    return SparseFrontier(ids=ids, length=length), srcs, total


def advance_filter_batch(graph: Graph, frontier: BatchedSparseFrontier,
                         visited: jax.Array, cap_out: int,
                         cap_front: Optional[int] = None, *,
                         backend: Optional[str] = None
                         ) -> tuple[BatchedSparseFrontier, jax.Array,
                                    jax.Array]:
    """Multi-source fused advance→filter; per-lane semantics identical
    to ``advance_filter`` (``visited`` is (B, n), outputs batched)."""
    bk = B.resolve(backend)
    if graph.num_edges == 0:
        bk = B.XLA
    cap_front = frontier.capacity if cap_front is None else cap_front
    base, valid_in = _frontier_base_vertices(graph, frontier, "vertex")
    deg = graph.row_offsets[base + 1] - graph.row_offsets[base]
    sizes = jnp.where(valid_in, deg, 0).astype(jnp.int32)
    impl = B.dispatch("advance_filter_batch", bk, B.SINGLE)
    cols = B.storage_arg("advance_filter_batch", bk, B.SINGLE, graph=graph)
    ids, srcs, lengths, totals = impl(graph.row_offsets,
                                      cols, base, sizes,
                                      visited.astype(jnp.int32),
                                      cap_out, cap_front)
    return BatchedSparseFrontier(ids=ids, lengths=lengths), srcs, totals


def advance_to_vertex_frontier(res: AdvanceResult,
                               cap: Optional[int] = None,
                               backend: Optional[str] = None
                               ) -> SparseFrontier:
    """Compact an advance result's destinations into a vertex frontier."""
    cap = int(res.dst.shape[0]) if cap is None else cap
    buf, length = compact_values(res.dst, res.valid, cap, backend=backend)
    return SparseFrontier(ids=buf, length=length)


def advance_to_edge_frontier(res: AdvanceResult,
                             cap: Optional[int] = None,
                             backend: Optional[str] = None) -> SparseFrontier:
    cap = int(res.edge_id.shape[0]) if cap is None else cap
    buf, length = compact_values(res.edge_id, res.valid, cap,
                                 backend=backend)
    return SparseFrontier(ids=buf, length=length)


def advance_to_vertex_frontier_batch(res: AdvanceResult,
                                     cap: Optional[int] = None,
                                     backend: Optional[str] = None
                                     ) -> BatchedSparseFrontier:
    """Per-lane compaction of a batched advance's destinations."""
    cap = int(res.dst.shape[1]) if cap is None else cap
    buf, lengths, _ = compact_values_batch(res.dst, res.valid, cap,
                                           backend=backend)
    return BatchedSparseFrontier(ids=buf, lengths=lengths)


def advance_pull(graph: Graph, unvisited: DenseFrontier,
                 current: DenseFrontier, return_preds: bool = False):
    """Pull-based advance (paper §5.1.4, Fig. 13).

    For every unvisited vertex, test whether any in-neighbor (CSC) is in the
    current frontier; those become the new frontier. Dense formulation: a
    masked segment-max over CSC — one sweep of the edge list, which is the
    pull phase's defining cost (and why it wins only when the active
    frontier is large).
    """
    assert graph.has_csc, "pull advance requires a CSC mirror"
    n = graph.num_vertices
    m = graph.num_edges
    # For each CSC slot e: dst vertex = segment owner, src = csc_indices[e].
    # The edge→row map is loop-invariant graph structure: build-time
    # metadata when available (Graph.from_csr), else derived here.
    seg = graph.csc_row_seg
    if seg is None:
        seg = row_segments_of(graph.csc_offsets, m)
    # the pull sweep touches every CSC slot, so the dense decoded view
    # costs nothing extra under delta storage (same O(m) stream); going
    # through the store keeps this generic over Graph / ShardedGraph
    csc = S.decode_cols(graph.csc_store)
    pred_active = current.flags[csc]
    # ONE segment-max serves both outputs: the max surviving in-neighbor
    # id is ≥ 0 exactly where any in-neighbor is active (ids are
    # non-negative), so the hit test rides the predecessor sweep free.
    pred_id = jnp.where(pred_active, csc, -1)
    preds = jax.ops.segment_max(pred_id, seg, num_segments=n,
                                indices_are_sorted=True)
    new_flags = (preds >= 0) & unvisited.flags
    if not return_preds:
        return DenseFrontier(new_flags)
    return DenseFrontier(new_flags), preds


def advance_pull_batch(graph: Graph, unvisited: BatchedDenseFrontier,
                       current: BatchedDenseFrontier,
                       return_preds: bool = False):
    """Per-lane pull advance: vmap the dense CSC sweep over the batch
    axis (one shared edge-list sweep per lane, lockstep)."""
    def fn(u, c):
        return advance_pull(graph, DenseFrontier(u), DenseFrontier(c),
                            return_preds=return_preds)

    if return_preds:
        out, preds = jax.vmap(fn)(unvisited.flags, current.flags)
        return BatchedDenseFrontier(out.flags), preds
    out = jax.vmap(fn)(unvisited.flags, current.flags)
    return BatchedDenseFrontier(out.flags)


# ---------------------------------------------------------------------------
# filter
# ---------------------------------------------------------------------------


def _uniquify_exact(ids: jax.Array, keep: jax.Array, n: int) -> jax.Array:
    """Global scatter winner test: exactly one surviving lane per id.
    Single-lane; the batched filter vmaps it."""
    capacity = ids.shape[0]
    slot_of = jnp.full((n,), INVALID, jnp.int32)
    lane = jnp.arange(capacity, dtype=jnp.int32)
    safe = jnp.where(keep, ids, 0)
    slot_of = slot_of.at[safe].max(jnp.where(keep, lane, INVALID),
                                   mode="drop")
    return keep & (slot_of[safe] == lane)


def _uniquify_hash(ids: jax.Array, keep: jax.Array,
                   hash_size: int) -> jax.Array:
    """Heuristic history-hashtable culling (§5.2.1): removes only some
    duplicates, never valid items. Single-lane; vmapped by the batched
    filter."""
    capacity = ids.shape[0]
    lane = jnp.arange(capacity, dtype=jnp.int32)
    slot = jnp.where(keep, ids % hash_size, hash_size)
    h_id = jnp.full((hash_size + 1,), INVALID, jnp.int32)
    h_ln = jnp.full((hash_size + 1,), INVALID, jnp.int32)
    h_id = h_id.at[slot].set(ids, mode="drop")
    h_ln = h_ln.at[slot].set(lane, mode="drop")
    dup = (h_id[slot] == ids) & (h_ln[slot] != lane)
    return keep & ~dup


def filter_frontier(frontier: SparseFrontier,
                    functor: Optional[Callable] = None, data=None,
                    n: Optional[int] = None, uniquify: str = "none",
                    cap: Optional[int] = None,
                    hash_size: int = 1024,
                    backend: Optional[str] = None,
                    use_kernel: Optional[bool] = None
                    ) -> tuple[SparseFrontier, object]:
    """Gunrock filter: predicate + compaction (+ optional uniquification).

    functor(ids, valid, data) -> (keep_mask, data')
    uniquify: 'none' | 'exact' (global scatter winner test) |
              'hash' (heuristic history-hashtable culling, §5.2.1 — removes
              only some duplicates, never valid items).
    The compaction dispatches through the "compact" registry entry (the
    Pallas filter_compact kernel under backend="pallas").
    """
    bk = B.resolve(backend, use_kernel)
    ids, valid = frontier.ids, frontier.valid_mask
    keep = valid
    if functor is not None:
        fkeep, data = functor(ids, valid, data)
        keep = keep & fkeep
    if uniquify == "exact":
        assert n is not None, "exact uniquify needs vertex count n"
        keep = _uniquify_exact(ids, keep, n)
    elif uniquify == "hash":
        keep = _uniquify_hash(ids, keep, hash_size)
    cap = frontier.capacity if cap is None else cap
    buf, length = compact_values(ids, keep, cap, backend=bk)
    return SparseFrontier(ids=buf, length=length), data


def filter_frontier_batch(frontier: BatchedSparseFrontier,
                          functor: Optional[Callable] = None, data=None,
                          n: Optional[int] = None, uniquify: str = "none",
                          cap: Optional[int] = None,
                          hash_size: int = 1024,
                          backend: Optional[str] = None
                          ) -> tuple[BatchedSparseFrontier, object,
                                     jax.Array]:
    """Per-lane filter: predicate + compaction (+ uniquification).

    Same semantics as ``filter_frontier`` per lane; ``functor`` keeps its
    single-lane signature and is vmapped (batched problem data). Returns
    ``(frontier, data, overflow)`` where ``overflow`` (B,) counts the
    surviving items dropped by the output-capacity clamp — nonzero only
    when heuristic uniquification leaves more than ``cap`` duplicates, and
    the signal that a capped run must not be trusted silently.
    """
    bk = B.resolve(backend)
    ids, valid = frontier.ids, frontier.valid_mask
    keep = valid
    if functor is not None:
        fkeep, data = jax.vmap(functor)(ids, valid, data)
        keep = keep & fkeep
    if uniquify == "exact":
        assert n is not None, "exact uniquify needs vertex count n"
        keep = jax.vmap(lambda i, k: _uniquify_exact(i, k, n))(ids, keep)
    elif uniquify == "hash":
        keep = jax.vmap(lambda i, k: _uniquify_hash(i, k, hash_size))(
            ids, keep)
    cap = frontier.capacity if cap is None else cap
    buf, lengths, totals = compact_values_batch(ids, keep, cap, backend=bk)
    overflow = jnp.maximum(totals - cap, 0)
    return (BatchedSparseFrontier(ids=buf, lengths=lengths), data,
            overflow)


def partition_frontier(frontier: SparseFrontier, predicate: jax.Array,
                       cap_near: Optional[int] = None,
                       cap_far: Optional[int] = None,
                       backend: Optional[str] = None
                       ) -> tuple[SparseFrontier, SparseFrontier]:
    """Two-way split of a frontier (the 2-level priority queue, §5.1.5):
    items with predicate=True go to the near pile, others to the far pile."""
    valid = frontier.valid_mask
    near_mask = valid & predicate
    far_mask = valid & ~predicate
    cap_near = frontier.capacity if cap_near is None else cap_near
    cap_far = frontier.capacity if cap_far is None else cap_far
    nbuf, nlen = compact_values(frontier.ids, near_mask, cap_near,
                                backend=backend)
    fbuf, flen = compact_values(frontier.ids, far_mask, cap_far,
                                backend=backend)
    return (SparseFrontier(nbuf, nlen), SparseFrontier(fbuf, flen))


# ---------------------------------------------------------------------------
# neighborhood reduction
# ---------------------------------------------------------------------------


def neighborhood_reduce(graph: Graph, frontier: SparseFrontier, cap_out: int,
                        edge_map: Callable, reduce_op: str = "add",
                        init=None, data=None, strategy: str = "LB",
                        backend: Optional[str] = None) -> jax.Array:
    """Advance + per-source segmented reduction (paper §8.2.3).

    edge_map(src, dst, edge_id, valid, data) -> values (cap_out,)
    Returns (cap_in,) reduced values aligned with the input frontier lanes.
    """
    res, _ = advance(graph, frontier, cap_out, strategy=strategy,
                     backend=backend)
    vals = edge_map(res.src, res.dst, res.edge_id, res.valid, data)
    seg_fn = {"add": jax.ops.segment_sum, "max": jax.ops.segment_max,
              "min": jax.ops.segment_min}[reduce_op]
    neutral = {"add": 0.0, "max": -jnp.inf, "min": jnp.inf}[reduce_op]
    vals = jnp.where(res.valid, vals, jnp.asarray(neutral, vals.dtype))
    # in_pos is monotone for LB (slot order) and THREAD (CSR order) but
    # TWC returns order[in_pos] (grouped by size class), where the
    # sorted-indices fast path would be unsound
    out = seg_fn(vals, res.in_pos, num_segments=frontier.capacity,
                 indices_are_sorted=(strategy != "TWC"))
    if init is not None:
        out = jnp.where(frontier.valid_mask, out, init)
    return out


# ---------------------------------------------------------------------------
# segmented intersection (paper §4.3)
# ---------------------------------------------------------------------------


def _searchsorted_segment(haystack: jax.Array, lo: jax.Array, hi: jax.Array,
                          needles: jax.Array, iters: int = 32,
                          locate: bool = False) -> jax.Array:
    """Vectorized binary search of ``needles`` within haystack[lo:hi) per
    lane; returns True where found — or, with ``locate=True``, the
    matched position (−1 when absent; the value-gathering probe the
    semiring SpGEMM needs). The SmallLarge kernel's probe (§4.3)."""
    lo = lo.astype(jnp.int32)
    hi = hi.astype(jnp.int32)

    def body(_, carry):
        lo_, hi_ = carry
        mid = (lo_ + hi_) // 2
        mid_val = haystack[jnp.clip(mid, 0, haystack.shape[0] - 1)]
        go_right = mid_val < needles
        lo_ = jnp.where(go_right & (lo_ < hi_), mid + 1, lo_)
        hi_ = jnp.where(~go_right & (lo_ < hi_), mid, hi_)
        return lo_, hi_

    lo_f, _ = jax.lax.fori_loop(0, iters, body, (lo, hi))
    in_range = lo_f < hi
    found_val = haystack[jnp.clip(lo_f, 0, haystack.shape[0] - 1)]
    found = in_range & (found_val == needles)
    if locate:
        return jnp.where(found, lo_f, -1).astype(jnp.int32)
    return found


class IntersectResult(NamedTuple):
    items: jax.Array      # (cap_out,) intersected vertex IDs (compacted)
    pair_of: jax.Array    # (cap_out,) which input pair produced the item
    length: jax.Array     # () int32
    counts: jax.Array     # (cap_in,) per-pair intersection sizes
    total: jax.Array      # () int32 global intersection count


@B.register("segment_search", B.XLA)
def _segment_search_xla(haystack: jax.Array, lo: jax.Array, hi: jax.Array,
                        needles: jax.Array) -> jax.Array:
    return _searchsorted_segment(haystack, lo, hi, needles)


def segmented_intersect(graph: Graph, fa: SparseFrontier, fb: SparseFrontier,
                        cap_out: int, *, backend: Optional[str] = None,
                        use_kernel: Optional[bool] = None
                        ) -> IntersectResult:
    """Intersect neighbor lists of paired items from two frontiers.

    Adjacency lists must be sorted (graph.from_edge_list guarantees it).
    Strategy: expand the *smaller* list of each pair (LB), binary-search each
    element in the larger list (SmallLarge scheme; TwoSmall is subsumed since
    a binary probe of a tiny list is equally cheap on the VPU). The
    expansion runs through the "advance" registry entry (so the fused
    Pallas kernel also serves intersection), the probe through
    "segment_search", the output compaction through "compact".
    """
    bk = B.resolve(backend, use_kernel)
    if graph.num_edges == 0:
        bk = B.XLA
    valid_pair = fa.valid_mask & fb.valid_mask
    a = jnp.where(valid_pair, fa.ids, 0)
    b = jnp.where(valid_pair, fb.ids, 0)
    deg_a = graph.row_offsets[a + 1] - graph.row_offsets[a]
    deg_b = graph.row_offsets[b + 1] - graph.row_offsets[b]
    a_small = deg_a <= deg_b
    small = jnp.where(a_small, a, b)
    large = jnp.where(a_small, b, a)
    sizes = jnp.where(valid_pair,
                      jnp.where(a_small, deg_a, deg_b), 0).astype(jnp.int32)
    # fused expansion: dst of the small-side advance IS the probe needle
    expand = B.dispatch("advance", bk, B.SINGLE)
    cols = B.storage_arg("advance", bk, B.SINGLE, graph=graph)
    _, needles, _, pair, _, exp_valid, _ = expand(
        graph.row_offsets, cols, small, sizes, cap_out)
    l_vert = large[pair]
    search = B.dispatch("segment_search", bk, B.SINGLE)
    # the probe binary-searches column VALUES in place, so it gets the
    # dense view (narrow dense compares fine; delta decodes once here)
    found = search(B.storage_arg("segment_search", bk, B.SINGLE,
                                 graph=graph),
                   graph.row_offsets[l_vert],
                   graph.row_offsets[l_vert + 1], needles)
    found = found & exp_valid
    counts = jax.ops.segment_sum(found.astype(jnp.int32), pair,
                                 num_segments=fa.capacity,
                                 indices_are_sorted=True)
    items, length = compact_values(needles, found, cap_out, backend=bk)
    pair_c, _ = compact_values(pair, found, cap_out, backend=bk)
    return IntersectResult(items=items, pair_of=pair_c, length=length,
                           counts=counts, total=jnp.sum(counts))


# ---------------------------------------------------------------------------
# compute
# ---------------------------------------------------------------------------


def compute(frontier: SparseFrontier, functor: Callable, data):
    """Per-element operation on all frontier elements (paper §3 'compute').

    functor(ids, valid, data) -> data'. XLA fuses this with neighbors.
    """
    return functor(jnp.where(frontier.valid_mask, frontier.ids, 0),
                   frontier.valid_mask, data)


# ---------------------------------------------------------------------------
# scatter helpers (atomic-replacement semantics, §5.2)
# ---------------------------------------------------------------------------


def scatter_min(values: jax.Array, index: jax.Array, valid: jax.Array,
                target: jax.Array) -> jax.Array:
    """atomicMin replacement: segment-min merged into ``target``."""
    safe_idx = jnp.where(valid, index, 0)
    big = jnp.asarray(jnp.inf, target.dtype) if jnp.issubdtype(
        target.dtype, jnp.floating) else jnp.iinfo(target.dtype).max
    vals = jnp.where(valid, values, big)
    return target.at[safe_idx].min(vals, mode="drop")


def scatter_add(values: jax.Array, index: jax.Array, valid: jax.Array,
                target: jax.Array) -> jax.Array:
    """atomicAdd replacement."""
    safe_idx = jnp.where(valid, index, 0)
    vals = jnp.where(valid, values, jnp.zeros((), target.dtype))
    return target.at[safe_idx].add(vals, mode="drop")


def scatter_or(index: jax.Array, valid: jax.Array,
               target: jax.Array) -> jax.Array:
    """Idempotent visited-bit set — no atomics needed (paper §5.2.1)."""
    safe_idx = jnp.where(valid, index, 0)
    return target.at[safe_idx].max(valid.astype(target.dtype), mode="drop")
