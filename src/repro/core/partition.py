"""Multi-device graph partitioning (paper §8.2.1 Scale-Out; Pan et al. [56]).

1-D contiguous vertex partition: device d owns vertices
[d·ceil(n/p), (d+1)·ceil(n/p)) and the out-edges (CSR rows) of those
vertices. Per-device CSR slices are rebased and padded to the max local
edge count so the partition stacks into dense (p, …) arrays that
shard_map can split over the mesh. When the source graph carries a CSC
mirror, the mirror is partitioned the same way (device d owns the
*in*-edges of its vertices), which is what lets pull-direction algebra
(PageRank's contribution sweep, reach's CSC SpMM) run row-local and
bit-identical to the single-device sweep.

This is the same partitioning Gunrock's multi-GPU framework uses; the
frontier exchange strategies and the sharded registry providers live in
core/distributed.py.

Two containers:

  ``PartitionedGraph``  — host-side numpy slices + balance accounting.
  ``ShardedGraph``      — the device-side pytree ``PartitionedGraph.shard``
                          builds: stacked jnp arrays named like ``Graph``
                          attributes (``row_offsets``/``csc_offsets``/…)
                          so primitives written against Graph run on it
                          unchanged, with the mesh + axis carried as
                          static aux data (part of every jit cache key).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import storage as S
from .graph import Graph

# What every shard actually holds, whatever the source graph's storage
# plan chose: partitioning decodes to dense int32 columns and fp32
# values (the pad sentinel -1 and the shard_map collectives both assume
# the canonical layout; compressing per-shard slices is future work —
# the plan still rides the ShardedGraph aux for reporting/provenance).
SHARD_PLAN = S.StoragePlan(index_dtype="int32", encoding="dense",
                           value_dtype="fp32")


def check_mesh_axis(mesh, axis: str, num_parts: int) -> None:
    """Validate that ``mesh`` carries a 1-D axis ``axis`` of size
    ``num_parts`` (the one mesh precondition every sharded entry point
    shares)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if sizes.get(axis) != num_parts:
        raise ValueError(
            f"mesh axis {axis!r} (size {sizes.get(axis)}) must match "
            f"the partition's {num_parts} parts")


def _slice_rows(ro: np.ndarray, ci: np.ndarray, ev: Optional[np.ndarray],
                n: int, num_parts: int, vpp: int):
    """Rebase + pad per-part row slices of one CSR-like structure."""
    max_edges = 0
    slices = []
    for p in range(num_parts):
        lo_v = min(p * vpp, n)
        hi_v = min((p + 1) * vpp, n)
        lo_e, hi_e = int(ro[lo_v]), int(ro[hi_v])
        local_ro = ro[lo_v:hi_v + 1] - ro[lo_v]
        # pad vertex dim (parts at the tail may own fewer vertices)
        pad_v = vpp - (hi_v - lo_v)
        if pad_v:
            local_ro = np.concatenate(
                [local_ro, np.full(pad_v, local_ro[-1], local_ro.dtype)])
        slices.append((local_ro, ci[lo_e:hi_e],
                       ev[lo_e:hi_e] if ev is not None else None, lo_v))
        max_edges = max(max_edges, hi_e - lo_e)
    max_edges = max(max_edges, 1)
    p_ro = np.stack([s[0] for s in slices]).astype(np.int32)
    p_ci = np.full((num_parts, max_edges), -1, np.int32)
    p_ev = (np.zeros((num_parts, max_edges), np.float32)
            if ev is not None else None)
    base = np.zeros((num_parts,), np.int32)
    for p, (_, c, v, lo_v) in enumerate(slices):
        p_ci[p, :len(c)] = c
        if v is not None:
            p_ev[p, :len(v)] = v
        base[p] = lo_v
    return p_ro, p_ci, p_ev, base


@dataclass(frozen=True)
class PartitionedGraph:
    """Host-side stacked per-device CSR (+ CSC) slices (leading axis =
    device). ``source`` keeps the unpartitioned Graph around for
    replicated operands (the probe side of a sharded SpGEMM, oracle
    validation, degree vectors) — 1-D partitioning distributes the sweep,
    not the whole dataset."""

    n: int                     # global vertex count
    m: int                     # global edge count
    num_parts: int
    verts_per_part: int        # ceil(n / p)
    row_offsets: np.ndarray    # (p, verts_per_part+1) rebased local CSR
    col_indices: np.ndarray    # (p, max_local_edges) global dst ids, pad -1
    edge_values: Optional[np.ndarray]  # (p, max_local_edges)
    vertex_base: np.ndarray    # (p,) first global vertex id of each part
    # CSC mirror slices (in-edges of owned vertices), same layout
    csc_row_offsets: Optional[np.ndarray] = None
    csc_col_indices: Optional[np.ndarray] = None
    csc_edge_values: Optional[np.ndarray] = None
    source: Optional[Graph] = None

    @property
    def max_local_edges(self) -> int:
        return int(self.col_indices.shape[1])

    @property
    def has_csc(self) -> bool:
        return self.csc_row_offsets is not None

    def owner_of(self, v: np.ndarray) -> np.ndarray:
        return v // self.verts_per_part

    def balance(self) -> dict:
        """Per-device load accounting (for serving --json / benchmarks):
        owned vertex and edge counts per part plus the edge imbalance
        factor (max/mean — 1.0 is a perfectly balanced partition)."""
        verts = [int(min((p + 1) * self.verts_per_part, self.n)
                     - min(p * self.verts_per_part, self.n))
                 for p in range(self.num_parts)]
        edges = [int(self.row_offsets[p, -1]) for p in range(self.num_parts)]
        mean_e = max(sum(edges) / max(self.num_parts, 1), 1e-9)
        return {
            "parts": self.num_parts,
            "vertices_per_part": verts,
            "edges_per_part": edges,
            "edge_imbalance": round(max(edges) / mean_e, 3),
        }

    def shard(self, mesh, axis: str = "graph") -> "ShardedGraph":
        """Device-side view for the sharded registry providers. ``mesh``
        must carry a 1-D axis ``axis`` of size ``num_parts``. Views are
        cached per (mesh, axis): repeated calls (every query of a
        serving loop goes through here) reuse one set of device arrays
        instead of re-uploading the partition."""
        check_mesh_axis(mesh, axis, self.num_parts)
        cache = self.__dict__.get("_shard_cache")
        if cache is None:
            object.__setattr__(self, "_shard_cache", {})  # frozen dc
            cache = self.__dict__["_shard_cache"]
        key = (mesh, axis)
        if key in cache:
            return cache[key]
        cache[key] = ShardedGraph(
            row_offsets=jnp.asarray(self.row_offsets),
            col_indices=jnp.asarray(self.col_indices),
            edge_values=(jnp.asarray(self.edge_values)
                         if self.edge_values is not None else None),
            csc_offsets=(jnp.asarray(self.csc_row_offsets)
                         if self.csc_row_offsets is not None else None),
            csc_indices=(jnp.asarray(self.csc_col_indices)
                         if self.csc_col_indices is not None else None),
            csc_edge_values=(jnp.asarray(self.csc_edge_values)
                             if self.csc_edge_values is not None else None),
            vertex_base=jnp.asarray(self.vertex_base),
            n=self.n, m=self.m, verts_per_part=self.verts_per_part,
            mesh=mesh, axis=axis,
            ell_width=(self.source.ell_width
                       if self.source is not None else None),
            csc_ell_width=(self.source.csc_ell_width
                           if self.source is not None else None),
            source_plan=(self.source.plan
                         if self.source is not None else None))
        return cache[key]


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class ShardedGraph:
    """Stacked per-device graph slices as a jit-friendly pytree.

    Attribute names mirror ``Graph`` (``row_offsets``, ``csc_offsets``,
    ``num_vertices``, …) so algebra primitives written against Graph
    dispatch on it unchanged — the sharded registry providers understand
    the stacked (p, …) array layout. ``mesh``/``axis`` are static aux
    data: they ride the pytree treedef, so every jit cache key that
    closes over a ShardedGraph includes the mesh identity and a cached
    trace can never run against the wrong mesh. ELL *widths* are carried
    as aux from the source graph — the sharded hybrid SpMV needs the
    same fold shape as the single-device sweep — but the providers stay
    xla-backed (a pallas-under-shard_map provider would re-pack per
    device).
    """

    row_offsets: jax.Array            # (p, vpp+1)
    col_indices: jax.Array            # (p, max_local_edges)
    edge_values: Optional[jax.Array]
    csc_offsets: Optional[jax.Array]  # (p, vpp+1)
    csc_indices: Optional[jax.Array]
    csc_edge_values: Optional[jax.Array]
    vertex_base: jax.Array            # (p,)
    n: int
    m: int
    verts_per_part: int
    mesh: object
    axis: str
    # ELL pack widths copied from the SOURCE graph: the sharded hybrid
    # SpMV must fold each row with exactly the same tree shape as the
    # single-device sweep (placement bit-parity), so the width is shared
    # static metadata, not a per-shard choice.
    ell_width: Optional[int] = None
    csc_ell_width: Optional[int] = None
    # the source graph's storage plan (provenance/reporting); the shards
    # themselves always hold SHARD_PLAN storage — see module constant
    source_plan: Optional[S.StoragePlan] = None

    # per-shard edge→row maps and overflow lists are derived locally by
    # the sharded providers (local offsets differ per device); the
    # Graph-level metadata has no stacked counterpart by design
    row_seg = None
    csc_row_seg = None
    over_pos = None
    over_row = None
    csc_over_pos = None
    csc_over_row = None

    def tree_flatten(self):
        children = (self.row_offsets, self.col_indices, self.edge_values,
                    self.csc_offsets, self.csc_indices,
                    self.csc_edge_values, self.vertex_base)
        aux = (self.n, self.m, self.verts_per_part, self.mesh, self.axis,
               self.ell_width, self.csc_ell_width, self.source_plan)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def num_vertices(self) -> int:
        return self.n

    @property
    def num_edges(self) -> int:
        return self.m

    @property
    def num_parts(self) -> int:
        return int(self.row_offsets.shape[0])

    @property
    def has_csc(self) -> bool:
        return self.csc_offsets is not None

    @property
    def weighted(self) -> bool:
        return self.edge_values is not None

    @property
    def plan(self) -> S.StoragePlan:
        """The storage plan of the shard arrays themselves (always
        SHARD_PLAN — dense int32/fp32); the source graph's plan is
        ``source_plan``."""
        return SHARD_PLAN

    @property
    def col_store(self):
        """Stacked dense column slices — ShardedGraph storage is always
        dense, so the store IS the array (keeps ``B.storage_arg``
        placement-generic in primitives that accept either container)."""
        return self.col_indices

    @property
    def csc_store(self):
        return self.csc_indices

    @property
    def degrees(self) -> jax.Array:
        """Global out-degree vector (n,), assembled from the local row
        slices (pad rows repeat the final offset ⇒ degree 0)."""
        local = self.row_offsets[:, 1:] - self.row_offsets[:, :-1]
        return local.reshape(-1)[:self.n]


def partition_1d(graph: Graph, num_parts: int) -> PartitionedGraph:
    ro = np.asarray(graph.row_offsets)
    # decode-to-dense before slicing: shards hold SHARD_PLAN storage
    # regardless of the source plan (narrow/delta/bf16 sources partition
    # fine; exact-semiring results stay bit-identical because decode is
    # exact and fp32 round-trips bf16 values losslessly)
    ci = graph.cols_np()
    ev = (np.asarray(graph.edge_values, np.float32)
          if graph.edge_values is not None else None)
    n = graph.num_vertices
    vpp = -(-n // num_parts)  # ceil
    p_ro, p_ci, p_ev, base = _slice_rows(ro, ci, ev, n, num_parts, vpp)
    c_ro = c_ci = c_ev = None
    if graph.has_csc:
        c_ro, c_ci, c_ev, _ = _slice_rows(
            np.asarray(graph.csc_offsets),
            np.asarray(graph.csc_cols()),
            (np.asarray(graph.csc_edge_values, np.float32)
             if graph.csc_edge_values is not None else None),
            n, num_parts, vpp)
    return PartitionedGraph(n=n, m=graph.num_edges, num_parts=num_parts,
                            verts_per_part=vpp, row_offsets=p_ro,
                            col_indices=p_ci, edge_values=p_ev,
                            vertex_base=base,
                            csc_row_offsets=c_ro, csc_col_indices=c_ci,
                            csc_edge_values=c_ev, source=graph)
