"""Multi-device graph partitioning (paper §8.2.1 Scale-Out; Pan et al. [56]).

1-D contiguous vertex partition: device d owns vertices
[d·ceil(n/p), (d+1)·ceil(n/p)) and the out-edges (CSR rows) of those
vertices. Per-device CSR slices are rebased and padded to the max local
edge count so the partition stacks into dense (p, …) arrays that
shard_map can split over the mesh. When the source graph carries a CSC
mirror, the mirror is partitioned the same way (device d owns the
*in*-edges of its vertices), which is what lets pull-direction algebra
(PageRank's contribution sweep, reach's CSC SpMM) run row-local and
bit-identical to the single-device sweep.

This is the same partitioning Gunrock's multi-GPU framework uses; the
frontier exchange strategies and the sharded registry providers live in
core/distributed.py.

2-D vertex-cut partition (placement="2d"): edges are blocked on an R×C
device mesh — device (i, j) holds the edges whose source lies in row
chunk i (ceil(n/R) vertices) and whose destination lies in column chunk
j (ceil(n/C) vertices). Every vertex has one designated owner device
(``owner_of``); the other devices touching it hold *mirrors* (the
vertex-cut replication the balance stats account). Frontier exchange
then shrinks from the 1-D all-reduce over (n,) to a psum along the R
row devices of one ceil(n/C) column chunk plus an all-gather of the C
chunks — the comm-volume win measured by benchmarks/distributed_scale.

Containers:

  ``PartitionedGraph``    — host-side 1-D numpy slices + balance stats.
  ``ShardedGraph``        — device-side 1-D pytree (``.shard(mesh)``):
                            stacked (p, …) jnp arrays named like
                            ``Graph`` attributes so primitives written
                            against Graph run on it unchanged, with the
                            mesh + axis carried as static aux data
                            (part of every jit cache key).
  ``Partitioned2DGraph``  — host-side R×C edge blocks + mirror tables.
  ``Sharded2DGraph``      — device-side 2-D pytree: (R, C, …) stacked
                            blocks, same Graph-mirroring attribute
                            names; its column stores are ``Blocks2D``
                            pytrees carrying the block↔row-chunk edge
                            alignment the exact 2-D semiring providers
                            need (see core/distributed.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import storage as S
from .graph import Graph

# What every shard actually holds, whatever the source graph's storage
# plan chose: partitioning decodes to dense int32 columns and fp32
# values (the pad sentinel -1 and the shard_map collectives both assume
# the canonical layout; compressing per-shard slices is future work —
# the plan still rides the ShardedGraph aux for reporting/provenance).
SHARD_PLAN = S.StoragePlan(index_dtype="int32", encoding="dense",
                           value_dtype="fp32")


def check_mesh_axis(mesh, axis: str, num_parts: int) -> None:
    """Validate that ``mesh`` carries a 1-D axis ``axis`` of size
    ``num_parts`` (the one mesh precondition every sharded entry point
    shares)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if sizes.get(axis) != num_parts:
        raise ValueError(
            f"mesh axis {axis!r} (size {sizes.get(axis)}) must match "
            f"the partition's {num_parts} parts")


def check_mesh_axes(mesh, axes, shape) -> None:
    """2-D twin of ``check_mesh_axis``: ``axes`` = (row_name, col_name)
    must exist on ``mesh`` with sizes ``shape`` = (R, C)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for ax, want in zip(axes, shape):
        if sizes.get(ax) != want:
            raise ValueError(
                f"mesh axis {ax!r} (size {sizes.get(ax)}) must match "
                f"the 2-D partition's {tuple(shape)} blocks")


def _slice_rows(ro: np.ndarray, ci: np.ndarray, ev: Optional[np.ndarray],
                n: int, num_parts: int, vpp: int):
    """Rebase + pad per-part row slices of one CSR-like structure."""
    max_edges = 0
    slices = []
    for p in range(num_parts):
        lo_v = min(p * vpp, n)
        hi_v = min((p + 1) * vpp, n)
        lo_e, hi_e = int(ro[lo_v]), int(ro[hi_v])
        local_ro = ro[lo_v:hi_v + 1] - ro[lo_v]
        # pad vertex dim (parts at the tail may own fewer vertices)
        pad_v = vpp - (hi_v - lo_v)
        if pad_v:
            local_ro = np.concatenate(
                [local_ro, np.full(pad_v, local_ro[-1], local_ro.dtype)])
        slices.append((local_ro, ci[lo_e:hi_e],
                       ev[lo_e:hi_e] if ev is not None else None, lo_v))
        max_edges = max(max_edges, hi_e - lo_e)
    max_edges = max(max_edges, 1)
    p_ro = np.stack([s[0] for s in slices]).astype(np.int32)
    p_ci = np.full((num_parts, max_edges), -1, np.int32)
    p_ev = (np.zeros((num_parts, max_edges), np.float32)
            if ev is not None else None)
    base = np.zeros((num_parts,), np.int32)
    for p, (_, c, v, lo_v) in enumerate(slices):
        p_ci[p, :len(c)] = c
        if v is not None:
            p_ev[p, :len(v)] = v
        base[p] = lo_v
    return p_ro, p_ci, p_ev, base


@dataclass(frozen=True)
class PartitionedGraph:
    """Host-side stacked per-device CSR (+ CSC) slices (leading axis =
    device). ``source`` keeps the unpartitioned Graph around for
    replicated operands (the probe side of a sharded SpGEMM, oracle
    validation, degree vectors) — 1-D partitioning distributes the sweep,
    not the whole dataset."""

    n: int                     # global vertex count
    m: int                     # global edge count
    num_parts: int
    verts_per_part: int        # ceil(n / p)
    row_offsets: np.ndarray    # (p, verts_per_part+1) rebased local CSR
    col_indices: np.ndarray    # (p, max_local_edges) global dst ids, pad -1
    edge_values: Optional[np.ndarray]  # (p, max_local_edges)
    vertex_base: np.ndarray    # (p,) first global vertex id of each part
    # CSC mirror slices (in-edges of owned vertices), same layout
    csc_row_offsets: Optional[np.ndarray] = None
    csc_col_indices: Optional[np.ndarray] = None
    csc_edge_values: Optional[np.ndarray] = None
    source: Optional[Graph] = None

    @property
    def max_local_edges(self) -> int:
        return int(self.col_indices.shape[1])

    @property
    def has_csc(self) -> bool:
        return self.csc_row_offsets is not None

    def owner_of(self, v: np.ndarray) -> np.ndarray:
        return v // self.verts_per_part

    def balance(self) -> dict:
        """Per-device load accounting (for serving --json / benchmarks):
        owned vertex and edge counts per part plus BOTH imbalance
        factors (max/mean — 1.0 is perfectly balanced). On rmat graphs
        the vertex factor is ~1.0 while the edge factor is not: the
        contiguous 1-D cut balances ownership, not work — the hub skew
        that motivates the 2-D vertex-cut placement."""
        verts = [int(min((p + 1) * self.verts_per_part, self.n)
                     - min(p * self.verts_per_part, self.n))
                 for p in range(self.num_parts)]
        edges = [int(self.row_offsets[p, -1]) for p in range(self.num_parts)]
        mean_e = max(sum(edges) / max(self.num_parts, 1), 1e-9)
        mean_v = max(sum(verts) / max(self.num_parts, 1), 1e-9)
        return {
            "parts": self.num_parts,
            "vertices_per_part": verts,
            "edges_per_part": edges,
            "edge_imbalance": round(max(edges) / mean_e, 3),
            "vertex_imbalance": round(max(verts) / mean_v, 3),
        }

    def shard(self, mesh, axis: str = "graph") -> "ShardedGraph":
        """Device-side view for the sharded registry providers. ``mesh``
        must carry a 1-D axis ``axis`` of size ``num_parts``. Views are
        cached per (mesh, axis): repeated calls (every query of a
        serving loop goes through here) reuse one set of device arrays
        instead of re-uploading the partition."""
        check_mesh_axis(mesh, axis, self.num_parts)
        cache = self.__dict__.get("_shard_cache")
        if cache is None:
            object.__setattr__(self, "_shard_cache", {})  # frozen dc
            cache = self.__dict__["_shard_cache"]
        key = (mesh, axis)
        if key in cache:
            return cache[key]
        cache[key] = ShardedGraph(
            row_offsets=jnp.asarray(self.row_offsets),
            col_indices=jnp.asarray(self.col_indices),
            edge_values=(jnp.asarray(self.edge_values)
                         if self.edge_values is not None else None),
            csc_offsets=(jnp.asarray(self.csc_row_offsets)
                         if self.csc_row_offsets is not None else None),
            csc_indices=(jnp.asarray(self.csc_col_indices)
                         if self.csc_col_indices is not None else None),
            csc_edge_values=(jnp.asarray(self.csc_edge_values)
                             if self.csc_edge_values is not None else None),
            vertex_base=jnp.asarray(self.vertex_base),
            n=self.n, m=self.m, verts_per_part=self.verts_per_part,
            mesh=mesh, axis=axis,
            ell_width=(self.source.ell_width
                       if self.source is not None else None),
            csc_ell_width=(self.source.csc_ell_width
                           if self.source is not None else None),
            source_plan=(self.source.plan
                         if self.source is not None else None))
        return cache[key]


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class ShardedGraph:
    """Stacked per-device graph slices as a jit-friendly pytree.

    Attribute names mirror ``Graph`` (``row_offsets``, ``csc_offsets``,
    ``num_vertices``, …) so algebra primitives written against Graph
    dispatch on it unchanged — the sharded registry providers understand
    the stacked (p, …) array layout. ``mesh``/``axis`` are static aux
    data: they ride the pytree treedef, so every jit cache key that
    closes over a ShardedGraph includes the mesh identity and a cached
    trace can never run against the wrong mesh. ELL *widths* are carried
    as aux from the source graph — the sharded hybrid SpMV needs the
    same fold shape as the single-device sweep — but the providers stay
    xla-backed (a pallas-under-shard_map provider would re-pack per
    device).
    """

    row_offsets: jax.Array            # (p, vpp+1)
    col_indices: jax.Array            # (p, max_local_edges)
    edge_values: Optional[jax.Array]
    csc_offsets: Optional[jax.Array]  # (p, vpp+1)
    csc_indices: Optional[jax.Array]
    csc_edge_values: Optional[jax.Array]
    vertex_base: jax.Array            # (p,)
    n: int
    m: int
    verts_per_part: int
    mesh: object
    axis: str
    # ELL pack widths copied from the SOURCE graph: the sharded hybrid
    # SpMV must fold each row with exactly the same tree shape as the
    # single-device sweep (placement bit-parity), so the width is shared
    # static metadata, not a per-shard choice.
    ell_width: Optional[int] = None
    csc_ell_width: Optional[int] = None
    # the source graph's storage plan (provenance/reporting); the shards
    # themselves always hold SHARD_PLAN storage — see module constant
    source_plan: Optional[S.StoragePlan] = None

    # per-shard edge→row maps and overflow lists are derived locally by
    # the sharded providers (local offsets differ per device); the
    # Graph-level metadata has no stacked counterpart by design
    row_seg = None
    csc_row_seg = None
    over_pos = None
    over_row = None
    csc_over_pos = None
    csc_over_row = None

    def tree_flatten(self):
        children = (self.row_offsets, self.col_indices, self.edge_values,
                    self.csc_offsets, self.csc_indices,
                    self.csc_edge_values, self.vertex_base)
        aux = (self.n, self.m, self.verts_per_part, self.mesh, self.axis,
               self.ell_width, self.csc_ell_width, self.source_plan)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def num_vertices(self) -> int:
        return self.n

    @property
    def num_edges(self) -> int:
        return self.m

    @property
    def num_parts(self) -> int:
        return int(self.row_offsets.shape[0])

    @property
    def has_csc(self) -> bool:
        return self.csc_offsets is not None

    @property
    def weighted(self) -> bool:
        return self.edge_values is not None

    @property
    def plan(self) -> S.StoragePlan:
        """The storage plan of the shard arrays themselves (always
        SHARD_PLAN — dense int32/fp32); the source graph's plan is
        ``source_plan``."""
        return SHARD_PLAN

    @property
    def col_store(self):
        """Stacked dense column slices — ShardedGraph storage is always
        dense, so the store IS the array (keeps ``B.storage_arg``
        placement-generic in primitives that accept either container)."""
        return self.col_indices

    @property
    def csc_store(self):
        return self.csc_indices

    @property
    def degrees(self) -> jax.Array:
        """Global out-degree vector (n,), assembled from the local row
        slices (pad rows repeat the final offset ⇒ degree 0)."""
        local = self.row_offsets[:, 1:] - self.row_offsets[:, :-1]
        return local.reshape(-1)[:self.n]


# ---------------------------------------------------------------------------
# 2-D vertex-cut partition (placement="2d")
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class Blocks2D:
    """The column-store operand of a ``Sharded2DGraph``: per-block
    column ids plus the block↔row-chunk alignment metadata the exact 2-D
    semiring providers need. Riding inside one pytree keeps the registry
    contracts positional and placement-generic — ``B.storage_arg`` hands
    this to the 2-D spmv/spmm providers in the slot a dense column array
    occupies elsewhere.

    ``epos`` maps every block edge to its position inside the owning row
    chunk's 1-D CSR slice (``chunk_ro``): devices along one mesh row
    scatter their per-edge products into disjoint slots of one
    (chunk_emax,) buffer and ⊕-combine — merging identities only, so the
    subsequent per-row fold replays the single-device sequence exactly
    (the PR-4 bit-parity discipline survives the vertex cut)."""

    cols: jax.Array       # (R, C, be) global dst ids, pad -1
    epos: jax.Array       # (R, C, be) edge position in the row chunk
    chunk_ro: jax.Array   # (R, C, vpr+1) row-chunk CSR offsets (col-repl.)
    chunk_emax: int       # static: max edges of any row chunk

    def tree_flatten(self):
        return (self.cols, self.epos, self.chunk_ro), (self.chunk_emax,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


def _slice_blocks(ro: np.ndarray, ci: np.ndarray, ev: Optional[np.ndarray],
                  n: int, rows: int, cols: int, vpr: int, vpc: int):
    """Block one CSR-like structure on the R×C vertex cut.

    Returns stacked (R, C, …) block arrays (rebased offsets, global
    column ids padded with -1, values, row-chunk edge positions), the
    (R, vpr+1) row-chunk offsets, the max chunk edge count, and the host
    accounting tables (per-block edge counts / ELL widths / distinct
    vertices materialized per block — the mirror table)."""
    from .graph import ell_width_for
    blocks: list = []
    chunk_ros = []
    be_max, chunk_emax = 1, 1
    block_edges = np.zeros((rows, cols), np.int64)
    block_ell = np.ones((rows, cols), np.int64)
    mirrors = np.zeros((rows, cols), np.int64)
    for i in range(rows):
        lo_v = min(i * vpr, n)
        hi_v = min((i + 1) * vpr, n)
        lo_e, hi_e = int(ro[lo_v]), int(ro[hi_v])
        cro = (ro[lo_v:hi_v + 1] - ro[lo_v]).astype(np.int64)
        pad_v = vpr - (hi_v - lo_v)
        if pad_v:
            cro = np.concatenate(
                [cro, np.full(pad_v, cro[-1], cro.dtype)])
        chunk_ros.append(cro)
        chunk_emax = max(chunk_emax, hi_e - lo_e)
        c_ci = ci[lo_e:hi_e]
        c_ev = ev[lo_e:hi_e] if ev is not None else None
        epos = np.arange(hi_e - lo_e, dtype=np.int64)
        row_of = np.repeat(np.arange(hi_v - lo_v),
                           np.diff(ro[lo_v:hi_v + 1]))
        row_blocks = []
        for j in range(cols):
            sel = (c_ci >= j * vpc) & (c_ci < (j + 1) * vpc)
            cnt = np.bincount(row_of[sel], minlength=vpr)[:vpr]
            b_ro = np.concatenate(
                [[0], np.cumsum(cnt)]).astype(np.int32)
            row_blocks.append((b_ro, c_ci[sel],
                               c_ev[sel] if c_ev is not None else None,
                               epos[sel]))
            ne = int(sel.sum())
            be_max = max(be_max, ne)
            block_edges[i, j] = ne
            block_ell[i, j] = ell_width_for(cnt[cnt > 0])
            # vertex copies materialized on device (i, j): distinct
            # source rows with a block edge + distinct destinations
            mirrors[i, j] = int((cnt > 0).sum()) + \
                len(np.unique(c_ci[sel]))
        blocks.append(row_blocks)
    b_ro = np.stack([np.stack([b[0] for b in r]) for r in blocks])
    b_ci = np.full((rows, cols, be_max), -1, np.int32)
    b_ep = np.zeros((rows, cols, be_max), np.int32)
    b_ev = (np.zeros((rows, cols, be_max), np.float32)
            if ev is not None else None)
    for i in range(rows):
        for j in range(cols):
            _, c, v, e = blocks[i][j]
            b_ci[i, j, :len(c)] = c
            b_ep[i, j, :len(e)] = e
            if v is not None:
                b_ev[i, j, :len(v)] = v
    chunk_ro = np.stack(chunk_ros).astype(np.int32)
    return (b_ro, b_ci, b_ev, b_ep, chunk_ro, int(chunk_emax),
            block_edges, block_ell, mirrors)


def partition_1d(graph: Graph, num_parts: int) -> PartitionedGraph:
    ro = np.asarray(graph.row_offsets)
    # decode-to-dense before slicing: shards hold SHARD_PLAN storage
    # regardless of the source plan (narrow/delta/bf16 sources partition
    # fine; exact-semiring results stay bit-identical because decode is
    # exact and fp32 round-trips bf16 values losslessly)
    ci = graph.cols_np()
    ev = (np.asarray(graph.edge_values, np.float32)
          if graph.edge_values is not None else None)
    n = graph.num_vertices
    vpp = -(-n // num_parts)  # ceil
    p_ro, p_ci, p_ev, base = _slice_rows(ro, ci, ev, n, num_parts, vpp)
    c_ro = c_ci = c_ev = None
    if graph.has_csc:
        c_ro, c_ci, c_ev, _ = _slice_rows(
            np.asarray(graph.csc_offsets),
            np.asarray(graph.csc_cols()),
            (np.asarray(graph.csc_edge_values, np.float32)
             if graph.csc_edge_values is not None else None),
            n, num_parts, vpp)
    return PartitionedGraph(n=n, m=graph.num_edges, num_parts=num_parts,
                            verts_per_part=vpp, row_offsets=p_ro,
                            col_indices=p_ci, edge_values=p_ev,
                            vertex_base=base,
                            csc_row_offsets=c_ro, csc_col_indices=c_ci,
                            csc_edge_values=c_ev, source=graph)


@dataclass(frozen=True)
class Partitioned2DGraph:
    """Host-side R×C vertex-cut edge blocks + mirror/balance accounting.

    Device (i, j) holds the block of edges with source in row chunk i
    and destination in column chunk j. ``chunk_offsets`` keeps each row
    chunk's un-blocked 1-D CSR offsets — the fold shape the exact 2-D
    semiring providers replay after merging block products — and
    ``edge_pos`` aligns every block edge back into that slice.
    ``source`` keeps the unpartitioned Graph for replicated operands and
    oracle validation, exactly like the 1-D container."""

    n: int
    m: int
    rows: int                    # R (mesh rows)
    cols: int                    # C (mesh columns)
    vpr: int                     # ceil(n / R): row-chunk vertices
    vpc: int                     # ceil(n / C): column-chunk vertices
    row_offsets: np.ndarray      # (R, C, vpr+1) rebased block CSR
    col_indices: np.ndarray      # (R, C, be) global dst ids, pad -1
    edge_values: Optional[np.ndarray]
    edge_pos: np.ndarray         # (R, C, be) position in the row chunk
    chunk_offsets: np.ndarray    # (R, vpr+1) row-chunk CSR offsets
    chunk_emax: int
    row_base: np.ndarray         # (R,) first vertex id of each row chunk
    col_base: np.ndarray         # (C,) first vertex id of each col chunk
    block_edges: np.ndarray      # (R, C) host accounting
    block_ell_width: np.ndarray  # (R, C) per-block ELL widths
    mirrors: np.ndarray          # (R, C) vertex copies per device
    # CSC mirror blocks (in-edges), same layout
    csc_row_offsets: Optional[np.ndarray] = None
    csc_col_indices: Optional[np.ndarray] = None
    csc_edge_values: Optional[np.ndarray] = None
    csc_edge_pos: Optional[np.ndarray] = None
    csc_chunk_offsets: Optional[np.ndarray] = None
    csc_chunk_emax: int = 1
    source: Optional[Graph] = None

    @property
    def num_parts(self) -> int:
        return self.rows * self.cols

    @property
    def has_csc(self) -> bool:
        return self.csc_row_offsets is not None

    def owner_of(self, v):
        """Designated owner device (mesh row, mesh col) of vertex v —
        the device whose row chunk AND column chunk both contain v;
        every other device touching v holds a mirror."""
        v = np.asarray(v)
        return (np.minimum(v // self.vpr, self.rows - 1),
                np.minimum(v // self.vpc, self.cols - 1))

    def balance(self) -> dict:
        """2-D load accounting: per-block edge counts, both imbalance
        factors, and the vertex-cut replication stats (mean/max copies
        of a vertex across the mesh — 2-D placements trade mirrors for
        smaller exchanges)."""
        edges = self.block_edges
        mean_e = max(edges.sum() / max(self.num_parts, 1), 1e-9)
        verts = [int(min((i + 1) * self.vpr, self.n)
                     - min(i * self.vpr, self.n))
                 for i in range(self.rows)]
        mean_v = max(sum(verts) / max(self.rows, 1), 1e-9)
        return {
            "parts": self.num_parts,
            "mesh": [self.rows, self.cols],
            "vertices_per_chunk": verts,
            "edges_per_block": edges.astype(int).tolist(),
            "edge_imbalance": round(float(edges.max()) / mean_e, 3),
            "vertex_imbalance": round(max(verts) / mean_v, 3),
            "block_ell_width": self.block_ell_width.astype(int).tolist(),
            "mirror_factor": round(float(self.mirrors.sum())
                                   / max(self.n, 1), 3),
            "max_block_mirrors": int(self.mirrors.max()),
        }

    def shard(self, mesh, axes=("row", "col")) -> "Sharded2DGraph":
        """Device-side view for the 2-D registry providers. ``mesh``
        must carry axes ``axes`` of sizes (R, C). Cached per
        (mesh, axes) like the 1-D container."""
        axes = tuple(axes)
        check_mesh_axes(mesh, axes, (self.rows, self.cols))
        cache = self.__dict__.get("_shard_cache")
        if cache is None:
            object.__setattr__(self, "_shard_cache", {})  # frozen dc
            cache = self.__dict__["_shard_cache"]
        key = (mesh, axes)
        if key in cache:
            return cache[key]

        def repl(chunk_ro):
            # replicate the (R, vpr+1) chunk offsets along the column
            # axis so they shard like every other (R, C, …) block leaf
            return np.broadcast_to(chunk_ro[:, None, :],
                                   (self.rows, self.cols,
                                    chunk_ro.shape[1])).copy()

        cache[key] = Sharded2DGraph(
            row_offsets=jnp.asarray(self.row_offsets),
            col_indices=jnp.asarray(self.col_indices),
            edge_values=(jnp.asarray(self.edge_values)
                         if self.edge_values is not None else None),
            edge_pos=jnp.asarray(self.edge_pos),
            chunk_offsets=jnp.asarray(repl(self.chunk_offsets)),
            csc_offsets=(jnp.asarray(self.csc_row_offsets)
                         if self.csc_row_offsets is not None else None),
            csc_indices=(jnp.asarray(self.csc_col_indices)
                         if self.csc_col_indices is not None else None),
            csc_edge_values=(jnp.asarray(self.csc_edge_values)
                             if self.csc_edge_values is not None else None),
            csc_edge_pos=(jnp.asarray(self.csc_edge_pos)
                          if self.csc_edge_pos is not None else None),
            csc_chunk_offsets=(jnp.asarray(repl(self.csc_chunk_offsets))
                               if self.csc_chunk_offsets is not None
                               else None),
            row_base=jnp.asarray(self.row_base),
            col_base=jnp.asarray(self.col_base),
            n=self.n, m=self.m, rows=self.rows, cols=self.cols,
            vpr=self.vpr, vpc=self.vpc,
            chunk_emax=self.chunk_emax,
            csc_chunk_emax=self.csc_chunk_emax,
            mesh=mesh, axes=axes,
            ell_width=(self.source.ell_width
                       if self.source is not None else None),
            csc_ell_width=(self.source.csc_ell_width
                           if self.source is not None else None),
            source_plan=(self.source.plan
                         if self.source is not None else None))
        return cache[key]


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class Sharded2DGraph:
    """(R, C, …) stacked edge blocks as a jit-friendly pytree.

    Attribute names mirror ``Graph``/``ShardedGraph`` so primitives
    written against Graph dispatch on it unchanged; the 2-D registry
    providers understand the blocked layout. ``mesh``/``axes`` are
    static aux data (part of every jit cache key), like the 1-D
    container. ``col_store``/``csc_store`` return ``Blocks2D`` pytrees —
    the column ids plus the chunk-alignment metadata the exact semiring
    providers consume in the contract's column slot."""

    row_offsets: jax.Array            # (R, C, vpr+1)
    col_indices: jax.Array            # (R, C, be)
    edge_values: Optional[jax.Array]
    edge_pos: jax.Array               # (R, C, be)
    chunk_offsets: jax.Array          # (R, C, vpr+1) column-replicated
    csc_offsets: Optional[jax.Array]
    csc_indices: Optional[jax.Array]
    csc_edge_values: Optional[jax.Array]
    csc_edge_pos: Optional[jax.Array]
    csc_chunk_offsets: Optional[jax.Array]
    row_base: jax.Array               # (R,)
    col_base: jax.Array               # (C,)
    n: int
    m: int
    rows: int
    cols: int
    vpr: int
    vpc: int
    chunk_emax: int
    csc_chunk_emax: int
    mesh: object
    axes: tuple
    # ELL widths copied from the SOURCE graph: the 2-D fold must use the
    # same tree shape as the single-device sweep (placement bit-parity)
    ell_width: Optional[int] = None
    csc_ell_width: Optional[int] = None
    source_plan: Optional[S.StoragePlan] = None

    # like ShardedGraph: no stacked counterparts by design
    row_seg = None
    csc_row_seg = None
    over_pos = None
    over_row = None
    csc_over_pos = None
    csc_over_row = None

    def tree_flatten(self):
        children = (self.row_offsets, self.col_indices, self.edge_values,
                    self.edge_pos, self.chunk_offsets, self.csc_offsets,
                    self.csc_indices, self.csc_edge_values,
                    self.csc_edge_pos, self.csc_chunk_offsets,
                    self.row_base, self.col_base)
        aux = (self.n, self.m, self.rows, self.cols, self.vpr, self.vpc,
               self.chunk_emax, self.csc_chunk_emax, self.mesh,
               self.axes, self.ell_width, self.csc_ell_width,
               self.source_plan)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def num_vertices(self) -> int:
        return self.n

    @property
    def num_edges(self) -> int:
        return self.m

    @property
    def num_parts(self) -> int:
        return self.rows * self.cols

    @property
    def has_csc(self) -> bool:
        return self.csc_offsets is not None

    @property
    def weighted(self) -> bool:
        return self.edge_values is not None

    @property
    def plan(self) -> S.StoragePlan:
        return SHARD_PLAN

    @property
    def col_store(self) -> Blocks2D:
        return Blocks2D(cols=self.col_indices, epos=self.edge_pos,
                        chunk_ro=self.chunk_offsets,
                        chunk_emax=self.chunk_emax)

    @property
    def csc_store(self) -> Blocks2D:
        return Blocks2D(cols=self.csc_indices, epos=self.csc_edge_pos,
                        chunk_ro=self.csc_chunk_offsets,
                        chunk_emax=self.csc_chunk_emax)

    @property
    def degrees(self) -> jax.Array:
        """Global out-degree vector (n,) from the row-chunk offsets
        (pad rows repeat the final offset ⇒ degree 0)."""
        local = self.chunk_offsets[:, 0, 1:] - self.chunk_offsets[:, 0, :-1]
        return local.reshape(-1)[:self.n]


def partition_2d(graph: Graph, rows: int, cols: int) -> Partitioned2DGraph:
    """Vertex-cut 2-D partition of ``graph`` on an R×C mesh. Like
    ``partition_1d``, blocks hold SHARD_PLAN storage whatever the source
    plan chose (decode is exact)."""
    ro = np.asarray(graph.row_offsets)
    ci = graph.cols_np()
    ev = (np.asarray(graph.edge_values, np.float32)
          if graph.edge_values is not None else None)
    n = graph.num_vertices
    vpr = -(-n // rows)
    vpc = -(-n // cols)
    (b_ro, b_ci, b_ev, b_ep, chunk_ro, chunk_emax,
     block_edges, block_ell, mirrors) = _slice_blocks(
        ro, ci, ev, n, rows, cols, vpr, vpc)
    kw: dict = {}
    if graph.has_csc:
        (c_ro, c_ci, c_ev, c_ep, c_cro, c_emax, _, _, _) = _slice_blocks(
            np.asarray(graph.csc_offsets),
            np.asarray(graph.csc_cols()),
            (np.asarray(graph.csc_edge_values, np.float32)
             if graph.csc_edge_values is not None else None),
            n, rows, cols, vpr, vpc)
        kw = dict(csc_row_offsets=c_ro, csc_col_indices=c_ci,
                  csc_edge_values=c_ev, csc_edge_pos=c_ep,
                  csc_chunk_offsets=c_cro, csc_chunk_emax=c_emax)
    return Partitioned2DGraph(
        n=n, m=graph.num_edges, rows=rows, cols=cols, vpr=vpr, vpc=vpc,
        row_offsets=b_ro, col_indices=b_ci, edge_values=b_ev,
        edge_pos=b_ep, chunk_offsets=chunk_ro, chunk_emax=chunk_emax,
        row_base=(np.arange(rows) * vpr).astype(np.int32),
        col_base=(np.arange(cols) * vpc).astype(np.int32),
        block_edges=block_edges, block_ell_width=block_ell,
        mirrors=mirrors, source=graph, **kw)
