"""Multi-device graph partitioning (paper §8.2.1 Scale-Out; Pan et al. [56]).

1-D contiguous vertex partition: device d owns vertices
[d·ceil(n/p), (d+1)·ceil(n/p)) and the out-edges (CSR rows) of those
vertices. Per-device CSR slices are rebased and padded to the max local
edge count so the partition stacks into dense (p, …) arrays that
shard_map can split over the mesh.

This is the same partitioning Gunrock's multi-GPU framework uses; the
frontier exchange strategies live in core/distributed.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from .graph import Graph


@dataclass(frozen=True)
class PartitionedGraph:
    """Host-side stacked per-device CSR slices (leading axis = device)."""

    n: int                     # global vertex count
    m: int                     # global edge count
    num_parts: int
    verts_per_part: int        # ceil(n / p)
    row_offsets: np.ndarray    # (p, verts_per_part+1) rebased local CSR
    col_indices: np.ndarray    # (p, max_local_edges) global dst ids, pad -1
    edge_values: Optional[np.ndarray]  # (p, max_local_edges)
    vertex_base: np.ndarray    # (p,) first global vertex id of each part

    @property
    def max_local_edges(self) -> int:
        return int(self.col_indices.shape[1])

    def owner_of(self, v: np.ndarray) -> np.ndarray:
        return v // self.verts_per_part


def partition_1d(graph: Graph, num_parts: int) -> PartitionedGraph:
    ro = np.asarray(graph.row_offsets)
    ci = np.asarray(graph.col_indices)
    ev = (np.asarray(graph.edge_values)
          if graph.edge_values is not None else None)
    n = graph.num_vertices
    vpp = -(-n // num_parts)  # ceil
    max_edges = 0
    slices = []
    for p in range(num_parts):
        lo_v = min(p * vpp, n)
        hi_v = min((p + 1) * vpp, n)
        lo_e, hi_e = int(ro[lo_v]), int(ro[hi_v])
        local_ro = ro[lo_v:hi_v + 1] - ro[lo_v]
        # pad vertex dim (parts at the tail may own fewer vertices)
        pad_v = vpp - (hi_v - lo_v)
        if pad_v:
            local_ro = np.concatenate(
                [local_ro, np.full(pad_v, local_ro[-1], local_ro.dtype)])
        slices.append((local_ro, ci[lo_e:hi_e],
                       ev[lo_e:hi_e] if ev is not None else None, lo_v))
        max_edges = max(max_edges, hi_e - lo_e)
    max_edges = max(max_edges, 1)
    p_ro = np.stack([s[0] for s in slices]).astype(np.int32)
    p_ci = np.full((num_parts, max_edges), -1, np.int32)
    p_ev = (np.zeros((num_parts, max_edges), np.float32)
            if ev is not None else None)
    base = np.zeros((num_parts,), np.int32)
    for p, (_, c, v, lo_v) in enumerate(slices):
        p_ci[p, :len(c)] = c
        if v is not None:
            p_ev[p, :len(v)] = v
        base[p] = lo_v
    return PartitionedGraph(n=n, m=graph.num_edges, num_parts=num_parts,
                            verts_per_part=vpp, row_offsets=p_ro,
                            col_indices=p_ci, edge_values=p_ev,
                            vertex_base=base)
