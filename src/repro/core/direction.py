"""Direction-optimized traversal heuristics (paper §5.1.4, eqs. 1–6).

Beamer-style push/pull switching adapted as in the paper: because computing
m_f and m_u exactly would need two extra prefix-sum passes, Gunrock
*estimates* them from frontier cardinalities (eqs. 3/4) and switches with
tunable do_a / do_b (eqs. 5/6). We implement the paper's printed estimates
verbatim so the Fig.-21 parameter sweep reproduces.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

PUSH = jnp.int32(0)
PULL = jnp.int32(1)


class DirectionParams(NamedTuple):
    do_a: float = 0.001
    do_b: float = 0.200
    enabled: bool = True


def estimate_workloads(n_f, n_u, n: int, m: int):
    """Paper eqs. (3) and (4): m_f = n_f·m/n ; m_u = n_u·n/(n−n_u)."""
    n_f = n_f.astype(jnp.float32)
    n_u = n_u.astype(jnp.float32)
    m_f = n_f * (m / n)
    m_u = n_u * n / jnp.maximum(jnp.float32(n) - n_u, 1.0)
    return m_f, m_u


def decide_direction(mode, n_f, n_u, n: int, m: int,
                     params: DirectionParams):
    """Return the next traversal mode (paper eqs. 5/6).

    push→pull when m_f > m_u·do_a ; pull→push when m_f < m_u·do_b.
    """
    if not params.enabled:
        return PUSH
    m_f, m_u = estimate_workloads(n_f, n_u, n, m)
    to_pull = m_f > m_u * params.do_a
    to_push = m_f < m_u * params.do_b
    return jnp.where(mode == PUSH,
                     jnp.where(to_pull, PULL, PUSH),
                     jnp.where(to_push, PUSH, PULL)).astype(jnp.int32)
