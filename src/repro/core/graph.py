"""Graph containers and generators for the Gunrock-JAX engine.

Gunrock stores graphs in CSR (compressed sparse row) for vertex-centric
operations and optionally COO for edge-centric operations (paper §5.4).
We mirror that: ``Graph`` is a frozen pytree of int32 arrays

    row_offsets : (n+1,)  CSR offsets
    col_indices : (m,)    neighbor vertex IDs
    edge_values : (m,)    optional per-edge weights (float32)

plus an optional CSC mirror (``csc_*``) used by pull-direction traversal
(paper §5.1.4) and reverse advance (BC backward pass).

All shapes are static; n and m are Python ints so a Graph can be closed
over by jitted functions without retracing on content changes.

Storage is planned at build time (core/storage.py): ``from_csr`` /
``from_edge_list`` pick the narrowest safe vertex-id dtype (or honor an
explicit ``index_dtype=``), optionally delta-encode the CSR/CSC columns
(``encoding="delta"``), and pin EVERY structural array to the plan's
dtype — under ``jax_enable_x64`` JAX would otherwise silently widen
index arrays to int64 and double the traversal bandwidth. The chosen
:class:`~repro.core.storage.StoragePlan` rides the pytree aux data, so
storage format is part of every jit cache key, like the mesh of a
ShardedGraph.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import storage as S


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class Graph:
    """Static-topology graph in CSR (+ optional CSC) form."""

    row_offsets: jax.Array          # (n+1,) int32
    col_indices: Optional[jax.Array]  # (m,) plan index dtype; None when
    #                                   the columns are delta-encoded
    edge_values: Optional[jax.Array] = None   # (m,) float32
    # CSC mirror (for pull traversal / reverse advance)
    csc_offsets: Optional[jax.Array] = None   # (n+1,) int32
    csc_indices: Optional[jax.Array] = None   # (m,)  int32
    csc_edge_values: Optional[jax.Array] = None
    # mapping from CSC slot -> original edge id (for edge-centric pulls)
    csc_edge_ids: Optional[jax.Array] = None
    # edge→row maps (slot e ⇒ owning row): loop-invariant structure that
    # the edge-sweep hot paths (SpMV segment reduce, pull advance, THREAD
    # expansion) would otherwise re-derive by binary search EVERY
    # iteration inside their jitted while loops — XLA does not reliably
    # hoist it. Built once with the CSR.
    row_seg: Optional[jax.Array] = None       # (m,) int32
    csc_row_seg: Optional[jax.Array] = None   # (m,) int32
    # compacted ELL-overflow edge lists (positions + owning rows of edges
    # whose within-row rank ≥ ell width): the hybrid XLA SpMV reduces
    # the first `ell_width` edges of every row with a dense rank-aligned
    # tree and lets ONLY these edges take the serial-scatter path.
    # Ascending edge order (the fold-continuation contract).
    over_pos: Optional[jax.Array] = None       # (K,) int32
    over_row: Optional[jax.Array] = None       # (K,) int32
    csc_over_pos: Optional[jax.Array] = None   # (Kc,) int32
    csc_over_row: Optional[jax.Array] = None   # (Kc,) int32
    # Delta-encoded column stores (storage plan encoding="delta"): when
    # set, the matching dense ``*_indices`` child is None and consumers
    # go through ``col_store``/``cols()`` (storage.gather_cols decodes
    # per touched edge; storage.decode_cols is the dense fallback).
    col_enc: Optional[S.EncodedCols] = None
    csc_enc: Optional[S.EncodedCols] = None
    # Host-side (static) kernel metadata, computed at build time so jitted
    # code never synchronizes to pick kernel shapes: ELL pack width for the
    # hybrid SpMV kernel, out-degree (CSR) and in-degree (CSC) flavours.
    ell_width: Optional[int] = None
    csc_ell_width: Optional[int] = None
    # The build-time storage decision (static aux: part of every jit
    # cache key). None only for hand-constructed Graphs.
    plan: Optional[S.StoragePlan] = None

    # --- pytree plumbing -------------------------------------------------
    def tree_flatten(self):
        children = (self.row_offsets, self.col_indices, self.edge_values,
                    self.csc_offsets, self.csc_indices, self.csc_edge_values,
                    self.csc_edge_ids, self.row_seg, self.csc_row_seg,
                    self.over_pos, self.over_row,
                    self.csc_over_pos, self.csc_over_row,
                    self.col_enc, self.csc_enc)
        return children, (self.ell_width, self.csc_ell_width, self.plan)

    @classmethod
    def tree_unflatten(cls, aux, children):
        ell, csc_ell, plan = aux if aux is not None else (None, None, None)
        return cls(*children, ell_width=ell, csc_ell_width=csc_ell,
                   plan=plan)

    # --- basic properties -------------------------------------------------
    @property
    def num_vertices(self) -> int:
        return int(self.row_offsets.shape[0]) - 1

    @property
    def num_edges(self) -> int:
        if self.col_indices is not None:
            return int(self.col_indices.shape[0])
        return self.col_enc.num_edges

    # --- storage access ---------------------------------------------------
    @property
    def col_store(self) -> S.ColStore:
        """CSR column storage as the registry passes it: the dense array
        (plan index dtype) or the EncodedCols pytree."""
        return self.col_indices if self.col_enc is None else self.col_enc

    @property
    def csc_store(self) -> Optional[S.ColStore]:
        if self.csc_enc is not None:
            return self.csc_enc
        return self.csc_indices

    def cols(self) -> jax.Array:
        """Dense int32 CSR column view (decode-to-dense when delta)."""
        return S.decode_cols(self.col_store)

    def csc_cols(self) -> jax.Array:
        assert self.has_csc, "graph has no CSC mirror"
        return S.decode_cols(self.csc_store)

    def cols_np(self) -> np.ndarray:
        """Host-side dense int32 columns (partitioning, edge recovery)."""
        return np.asarray(self.cols())

    @property
    def degrees(self) -> jax.Array:
        return self.row_offsets[1:] - self.row_offsets[:-1]

    @property
    def has_csc(self) -> bool:
        return self.csc_offsets is not None

    @property
    def weighted(self) -> bool:
        return self.edge_values is not None

    def neighbors_padded(self, max_degree: int) -> tuple[jax.Array, jax.Array]:
        """Dense (n, max_degree) neighbor table + validity mask (ELL format)."""
        n = self.num_vertices
        lanes = jnp.arange(max_degree, dtype=jnp.int32)[None, :]
        starts = self.row_offsets[:-1, None]
        deg = self.degrees[:, None]
        idx = jnp.minimum(starts + lanes, self.num_edges - 1)
        nbrs = self.cols()[idx]
        mask = lanes < deg
        return jnp.where(mask, nbrs, -1), mask

    @classmethod
    def from_csr(cls, row_offsets, col_indices, edge_values=None, *,
                 build_csc: bool = True,
                 sort_neighbors: bool = True,
                 index_dtype: Optional[str] = None,
                 encoding: str = "dense",
                 value_dtype: str = "fp32",
                 validate: bool = False) -> "Graph":
        """Build a Graph from host-side CSR arrays.

        ALL static kernel metadata — the CSC mirror and both ELL pack
        widths — is computed here, exactly once, at build time. Jitted
        code (the pallas SpMV/SpMM hot paths in particular) reads the
        widths as static attributes and never synchronizes to the host;
        hand-constructing ``Graph(...)`` directly skips this and leaves
        the metadata ``None``, which the pallas backend rejects.

        Neighbor lists are sorted within each row (values permuted
        along) unless ``sort_neighbors=False`` — segmented intersection
        and the SpGEMM probe binary-search rows and silently miscount on
        unsorted input (paper §4.3 assumes sorted adjacency lists).

        The storage plan (``index_dtype`` / ``encoding`` /
        ``value_dtype``, see core/storage.py) is resolved here and every
        structural array is pinned to it — notably under
        ``jax_enable_x64``, where index arrays would otherwise drift to
        int64. ``encoding="delta"`` requires sorted neighbor lists.

        ``validate=True`` runs :func:`validate_csr` on the RAW input
        arrays — before any dtype cast can silently truncate a bad id —
        and raises :class:`GraphValidationError` with the offending
        row/edge named. Off by default: trusted in-process builders
        (rmat, from_edge_list) construct valid CSR by construction.
        """
        ro = np.asarray(row_offsets, np.int64)
        n = len(ro) - 1
        plan = S.plan_for(n, index_dtype=index_dtype, encoding=encoding,
                          value_dtype=value_dtype)
        if validate:
            validate_csr(row_offsets, col_indices, edge_values, plan=plan)
        # delta encoding needs sorted rows; callers that pre-sort (e.g.
        # from_edge_list) pass sort_neighbors=False and encode_delta
        # itself rejects genuinely unsorted input.
        ci = np.asarray(col_indices, plan.np_index_dtype)
        vals = (None if edge_values is None
                else np.asarray(edge_values, np.float32))
        counts = np.diff(ro)
        if sort_neighbors and len(ci):
            order = np.lexsort((ci, np.repeat(np.arange(n), counts)))
            ci = ci[order]
            if vals is not None:
                vals = vals[order]
        csc = (None, None, None, None)
        csc_ell = None
        csc_seg = None
        csc_over = (None, None)
        src = np.repeat(np.arange(n, dtype=np.int32), counts)
        ell_w = ell_width_for(counts)
        over = _overflow_edges(ro, src, ell_w)
        if build_csc:
            csc = _build_csc(n, src, ci.astype(np.int64), vals)
            csc_ell = ell_width_for(np.diff(csc[0]))
            csc_seg = np.repeat(np.arange(n, dtype=np.int32),
                                np.diff(csc[0]))
            csc_over = _overflow_edges(csc[0], csc_seg, csc_ell)

        def _idx(a):
            """Pin a structural index array to the plan's dtype on
            device, and verify the dtype survived the transfer (without
            jax_enable_x64 JAX silently truncates int64 to int32 —
            corrupting ids on a >2^31-vertex graph, so refuse)."""
            out = jnp.asarray(np.asarray(a, plan.np_index_dtype))
            if out.dtype != plan.jnp_index_dtype:
                raise RuntimeError(
                    f"index_dtype={plan.index_dtype!r} needs "
                    "jax_enable_x64 (JAX truncated the array to "
                    f"{out.dtype})")
            return out

        col_enc = csc_enc = None
        col_dense = _idx(ci)
        csc_dense = _idx(csc[1]) if csc[1] is not None else None
        if plan.encoding == "delta":
            col_enc = S.encode_delta(ro, ci, src)
            col_dense = None
            if csc[1] is not None:
                csc_enc = S.encode_delta(csc[0], csc[1], csc_seg)
                csc_dense = None
        # value_dtype="bf16" halves resident value bytes; compute
        # promotes back through float32 (semiring.with_precision is the
        # compute-side knob — the two compose but are independent)
        vdt = jnp.bfloat16 if plan.value_dtype == "bf16" else jnp.float32
        return cls(
            row_offsets=jnp.asarray(ro.astype(np.int32)),
            col_indices=col_dense,
            edge_values=jnp.asarray(vals, vdt) if vals is not None else None,
            csc_offsets=(jnp.asarray(csc[0].astype(np.int32))
                         if csc[0] is not None else None),
            csc_indices=csc_dense,
            csc_edge_values=(jnp.asarray(csc[2], vdt)
                             if csc[2] is not None else None),
            csc_edge_ids=jnp.asarray(csc[3]) if csc[3] is not None else None,
            row_seg=jnp.asarray(src),
            csc_row_seg=(jnp.asarray(csc_seg)
                         if csc_seg is not None else None),
            over_pos=jnp.asarray(over[0]),
            over_row=jnp.asarray(over[1]),
            csc_over_pos=(jnp.asarray(csc_over[0])
                          if csc_over[0] is not None else None),
            csc_over_row=(jnp.asarray(csc_over[1])
                          if csc_over[1] is not None else None),
            col_enc=col_enc,
            csc_enc=csc_enc,
            ell_width=ell_w,
            csc_ell_width=csc_ell,
            plan=plan,
        )


class GraphValidationError(ValueError):
    """Structurally invalid CSR input (see :func:`validate_csr`)."""


def validate_csr(row_offsets, col_indices, edge_values=None, *,
                 plan: Optional[S.StoragePlan] = None) -> tuple[int, int]:
    """Strict structural validation of host-side CSR arrays.

    Runs on the raw (pre-cast) arrays so a column id that would overflow
    the storage plan's index dtype is caught instead of silently
    truncated. Checks, each with the offending row/edge in the message:

      * indptr is 1-D, non-empty, starts at 0, and is non-decreasing;
      * ``indptr[-1]`` equals ``len(col_indices)`` (edge-count match);
      * every column id is in ``[0, n)``;
      * ids and ``n`` fit the storage plan's index dtype (when given);
      * ``edge_values`` (when given) has one finite value per edge.

    Returns ``(num_vertices, num_edges)``; raises
    :class:`GraphValidationError` on the first violation.
    """
    ro = np.asarray(row_offsets, np.int64)
    ci = np.asarray(col_indices, np.int64)
    if ro.ndim != 1 or len(ro) < 1:
        raise GraphValidationError(
            f"row_offsets must be a 1-D array of n+1 offsets; got "
            f"shape {ro.shape}")
    n = len(ro) - 1
    if len(ro) and ro[0] != 0:
        raise GraphValidationError(
            f"row_offsets[0] must be 0 (CSR rows start at the origin), "
            f"got {int(ro[0])}")
    diffs = np.diff(ro)
    bad = np.nonzero(diffs < 0)[0]
    if len(bad):
        i = int(bad[0])
        raise GraphValidationError(
            f"non-monotone row_offsets at row {i}: offsets[{i}]="
            f"{int(ro[i])} > offsets[{i + 1}]={int(ro[i + 1])}; each "
            f"row's edge range must be non-decreasing")
    if int(ro[-1]) != len(ci):
        raise GraphValidationError(
            f"indptr/edge-count mismatch: row_offsets[-1]={int(ro[-1])} "
            f"but col_indices has {len(ci)} entries — the offsets claim "
            f"a different edge count than the column array holds")
    if len(ci):
        oob = np.nonzero((ci < 0) | (ci >= n))[0]
        if len(oob):
            e = int(oob[0])
            raise GraphValidationError(
                f"column id out of range at edge {e}: {int(ci[e])} not "
                f"in [0, {n}) — every destination must name an existing "
                f"vertex")
    if plan is not None:
        info = np.iinfo(plan.np_index_dtype)
        top = max(n - 1, int(ci.max()) if len(ci) else 0)
        if top > info.max:
            raise GraphValidationError(
                f"index dtype overflow: storage plan "
                f"index_dtype={plan.index_dtype!r} holds ids up to "
                f"{info.max} but the graph needs {top}; pass a wider "
                f"index_dtype (or index_dtype=None to auto-size)")
    if edge_values is not None:
        ev = np.asarray(edge_values, np.float64)
        if len(ev) != len(ci):
            raise GraphValidationError(
                f"edge_values length {len(ev)} != edge count {len(ci)}")
        nf = np.nonzero(~np.isfinite(ev))[0]
        if len(nf):
            e = int(nf[0])
            raise GraphValidationError(
                f"non-finite edge value at edge {e}: {ev[e]!r}; weights "
                f"must be finite")
    return n, len(ci)


def validate_graph(g: "Graph") -> tuple[int, int]:
    """Re-run structural validation on a built ``Graph`` (the CLI
    ``--validate`` hook): pulls the device CSR back to host and applies
    :func:`validate_csr` against the graph's own storage plan, plus the
    CSC mirror's offsets/edge-count when one exists."""
    ro = np.asarray(g.row_offsets)
    cols = g.cols_np()
    vals = (None if g.edge_values is None
            else np.asarray(g.edge_values, np.float32))
    shape = validate_csr(ro, cols, vals, plan=g.plan)
    if g.has_csc:
        validate_csr(np.asarray(g.csc_offsets), np.asarray(g.csc_cols()),
                     plan=g.plan)
    return shape


def _overflow_edges(offsets: np.ndarray, seg: np.ndarray,
                    width: int) -> tuple[np.ndarray, np.ndarray]:
    """Host-side (build-time): positions + owning rows of the edges whose
    within-row rank ≥ ``width`` — the serial-scatter remainder of the
    hybrid ELL SpMV. Ascending edge order by construction."""
    m = len(seg)
    rank = np.arange(m, dtype=np.int64) - offsets[:-1][seg]
    pos = np.nonzero(rank >= width)[0].astype(np.int32)
    return pos, seg[pos].astype(np.int32)


def row_segments_of(offsets: jax.Array, m: int) -> jax.Array:
    """Edge→row map derived from CSR offsets under jit, O(m): cumsum of
    row-start marks. Bit-identical to the searchsorted formulation
    (``searchsorted(offsets, e, 'right') - 1``) at ~3× less cost — the
    fallback for hand-built Graphs whose ``row_seg`` metadata is None."""
    marks = jnp.zeros((m,), jnp.int32).at[offsets[1:-1]].add(
        1, mode="drop")
    return jnp.cumsum(marks)


def ell_width_for(degrees: np.ndarray) -> int:
    """Default ELL pack width for the hybrid SpMV kernel: covers ≥95% of
    edges, clamped to [1, 1024]. Host-side, run once at Graph build time —
    the old on-demand jax.device_get default broke under jit."""
    if len(degrees) == 0:
        return 1
    w = int(np.percentile(np.asarray(degrees), 95))
    return max(min(w, 1024), 1)


def _build_csc(n: int, src: np.ndarray, dst: np.ndarray,
               vals: Optional[np.ndarray]):
    """Transpose an edge list into CSC arrays (numpy, host-side)."""
    order = np.argsort(dst, kind="stable")
    csc_indices = src[order].astype(np.int32)
    csc_edge_ids = order.astype(np.int32)
    counts = np.bincount(dst, minlength=n)
    csc_offsets = np.zeros(n + 1, dtype=np.int32)
    np.cumsum(counts, out=csc_offsets[1:])
    csc_vals = vals[order].astype(np.float32) if vals is not None else None
    return csc_offsets, csc_indices, csc_vals, csc_edge_ids


def from_edge_list(src, dst, n: Optional[int] = None, values=None,
                   undirected: bool = False, build_csc: bool = True,
                   sort_neighbors: bool = True,
                   remove_self_loops: bool = True,
                   deduplicate: bool = True,
                   index_dtype: Optional[str] = None,
                   encoding: str = "dense",
                   value_dtype: str = "fp32") -> Graph:
    """Build a Graph from host-side edge arrays.

    Mirrors the paper's dataset preparation: optionally symmetrize,
    remove self loops and duplicate edges (paper Table 4 note).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if values is not None:
        values = np.asarray(values, dtype=np.float32)
    if n is None:
        n = int(max(src.max(initial=-1), dst.max(initial=-1))) + 1 if len(src) else 0
    if undirected:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        if values is not None:
            values = np.concatenate([values, values])
    if remove_self_loops and len(src):
        keep = src != dst
        src, dst = src[keep], dst[keep]
        if values is not None:
            values = values[keep]
    if deduplicate and len(src):
        key = src * n + dst
        _, first = np.unique(key, return_index=True)
        first.sort()
        src, dst = src[first], dst[first]
        if values is not None:
            values = values[first]
    # CSR: sort by (src, dst) so neighbor lists are sorted (needed by
    # segmented intersection; paper §4.3 assumes sorted adjacency lists).
    if sort_neighbors and len(src):
        order = np.lexsort((dst, src))
    else:
        order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    if values is not None:
        values = values[order]
    counts = np.bincount(src, minlength=n)
    row_offsets = np.zeros(n + 1, dtype=np.int32)
    np.cumsum(counts, out=row_offsets[1:])
    # Graph.from_csr is the single build-time home of kernel metadata
    # (CSC mirror + ELL pack widths) — computed once, never under jit.
    # Rows are already in the order this function's flags chose, so the
    # constructor must not re-sort them. ``encoding="delta"`` needs
    # sorted rows (storage.encode_delta validates).
    if encoding == "delta" and not sort_neighbors:
        raise ValueError("encoding='delta' requires sort_neighbors=True")
    return Graph.from_csr(row_offsets, dst, values,
                          build_csc=build_csc, sort_neighbors=False,
                          index_dtype=index_dtype, encoding=encoding,
                          value_dtype=value_dtype)


def edge_list(graph: Graph) -> tuple[np.ndarray, np.ndarray]:
    """Recover (src, dst) host arrays from CSR."""
    ro = np.asarray(graph.row_offsets)
    ci = graph.cols_np()
    src = np.repeat(np.arange(len(ro) - 1, dtype=np.int32), np.diff(ro))
    return src, ci


# ---------------------------------------------------------------------------
# Generators (paper Table 4 families: scale-free R-MAT, random geometric,
# mesh-like road networks).
# ---------------------------------------------------------------------------

def rmat(scale: int, edge_factor: int = 16, a: float = 0.57, b: float = 0.19,
         c: float = 0.19, seed: int = 0, weighted: bool = False,
         undirected: bool = True, index_dtype: Optional[str] = None,
         encoding: str = "dense", value_dtype: str = "fp32") -> Graph:
    """R-MAT / Kronecker generator with Graph500 parameters (paper §7).

    a=0.57, b=0.19, c=0.19, d=0.05 is the Graph500 initiator used in the
    paper's rmat_s22_e64 etc. datasets.
    """
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for level in range(scale):
        r = rng.random(m)
        # quadrant probabilities: a (0,0), b (0,1), c (1,0), d (1,1)
        go_right = ((r >= a) & (r < a + b)) | (r >= a + b + c)
        go_down = r >= a + b
        src |= go_down.astype(np.int64) << level
        dst |= go_right.astype(np.int64) << level
    # permute vertex IDs to remove locality bias
    perm = rng.permutation(n)
    src, dst = perm[src], perm[dst]
    values = rng.integers(1, 64, size=m).astype(np.float32) if weighted else None
    return from_edge_list(src, dst, n=n, values=values,
                          undirected=undirected, index_dtype=index_dtype,
                          encoding=encoding, value_dtype=value_dtype)


def random_geometric(n: int, radius: float, seed: int = 0,
                     weighted: bool = False,
                     index_dtype: Optional[str] = None,
                     encoding: str = "dense",
                     value_dtype: str = "fp32") -> Graph:
    """Random geometric graph on the unit square (paper's rgg datasets)."""
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2))
    # grid-bucket neighbor search to stay O(n) at small radius
    cell = max(radius, 1e-6)
    gx = (pts[:, 0] / cell).astype(np.int64)
    gy = (pts[:, 1] / cell).astype(np.int64)
    ncell = int(1.0 / cell) + 1
    bucket = gx * ncell + gy
    order = np.argsort(bucket)
    src_l, dst_l = [], []
    sorted_bucket = bucket[order]
    starts = np.searchsorted(sorted_bucket, np.arange(ncell * ncell))
    r2 = radius * radius
    for dxy in ((0, 0), (0, 1), (1, -1), (1, 0), (1, 1)):
        nb = (gx + dxy[0]) * ncell + (gy + dxy[1])
        valid = (gx + dxy[0] < ncell) & (gy + dxy[1] >= 0) & (gy + dxy[1] < ncell)
        for i in np.nonzero(valid)[0]:
            b = nb[i]
            if b < 0 or b >= ncell * ncell:
                continue
            lo = starts[b]
            hi = starts[b + 1] if b + 1 < len(starts) else n
            cand = order[lo:hi]
            if dxy == (0, 0):
                cand = cand[cand > i]
            d2 = ((pts[cand] - pts[i]) ** 2).sum(axis=1)
            close = cand[d2 <= r2]
            src_l.append(np.full(len(close), i, dtype=np.int64))
            dst_l.append(close.astype(np.int64))
    src = np.concatenate(src_l) if src_l else np.zeros(0, np.int64)
    dst = np.concatenate(dst_l) if dst_l else np.zeros(0, np.int64)
    values = (rng.integers(1, 64, size=len(src)).astype(np.float32)
              if weighted else None)
    return from_edge_list(src, dst, n=n, values=values, undirected=True,
                          index_dtype=index_dtype, encoding=encoding,
                          value_dtype=value_dtype)


def grid2d(side: int, weighted: bool = False, seed: int = 0,
           index_dtype: Optional[str] = None, encoding: str = "dense",
           value_dtype: str = "fp32") -> Graph:
    """2-D grid — the mesh-like / road-network stand-in (large diameter,
    uniform small degree, like the paper's roadnet_USA)."""
    rng = np.random.default_rng(seed)
    idx = np.arange(side * side, dtype=np.int64).reshape(side, side)
    right = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], axis=0)
    down = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], axis=0)
    src = np.concatenate([right[0], down[0]])
    dst = np.concatenate([right[1], down[1]])
    values = (rng.integers(1, 64, size=len(src)).astype(np.float32)
              if weighted else None)
    return from_edge_list(src, dst, n=side * side, values=values,
                          undirected=True, index_dtype=index_dtype,
                          encoding=encoding, value_dtype=value_dtype)


def bipartite_random(n_users: int, n_items: int, avg_degree: int,
                     seed: int = 0) -> Graph:
    """Random bipartite follow-graph for the WTF primitive (paper §7.5).

    Users [0, n_users) point at items [n_users, n_users+n_items).
    Directed; CSC gives the reverse (who-follows-me) direction.
    """
    rng = np.random.default_rng(seed)
    m = n_users * avg_degree
    src = rng.integers(0, n_users, size=m).astype(np.int64)
    dst = (n_users + rng.integers(0, n_items, size=m)).astype(np.int64)
    return from_edge_list(src, dst, n=n_users + n_items, undirected=False)


@functools.lru_cache(maxsize=32)
def demo_graph() -> Graph:
    """The 7-node / 15-edge sample graph from paper Fig. 5/6."""
    src = [0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6]
    dst = [1, 2, 3, 2, 4, 3, 5, 4, 5, 5, 6, 6, 0, 0, 2]
    return from_edge_list(src, dst, n=7, undirected=False,
                          deduplicate=False, remove_self_loops=False)
