from .ref_graph import (bfs_ref, sssp_ref, pagerank_ref, cc_ref, bc_ref,
                        tc_ref, reach_ref, label_propagation_ref, ppr_ref,
                        salsa_ref)

__all__ = ["bfs_ref", "sssp_ref", "pagerank_ref", "cc_ref", "bc_ref",
           "tc_ref", "reach_ref", "label_propagation_ref", "ppr_ref",
           "salsa_ref"]
