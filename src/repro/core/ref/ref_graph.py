"""Pure-numpy oracle implementations of every graph primitive.

These are the correctness references for the JAX/Pallas engine — serial,
textbook versions (the same algorithms the paper's hardwired baselines
implement). Used by unit/property tests and the benchmark harness's
validation pass.
"""
from __future__ import annotations

import heapq

import numpy as np


def _csr(graph):
    return (np.asarray(graph.row_offsets), graph.cols_np(),
            None if graph.edge_values is None
            else np.asarray(graph.edge_values))


def bfs_ref(graph, src: int) -> np.ndarray:
    """Breadth-first search depths (-1 = unreachable)."""
    ro, ci, _ = _csr(graph)
    n = len(ro) - 1
    depth = np.full(n, -1, dtype=np.int32)
    depth[src] = 0
    frontier = [src]
    d = 0
    while frontier:
        d += 1
        nxt = []
        for u in frontier:
            for e in range(ro[u], ro[u + 1]):
                v = ci[e]
                if depth[v] < 0:
                    depth[v] = d
                    nxt.append(v)
        frontier = nxt
    return depth


def sssp_ref(graph, src: int) -> np.ndarray:
    """Dijkstra distances (inf = unreachable)."""
    ro, ci, w = _csr(graph)
    assert w is not None, "sssp needs edge weights"
    n = len(ro) - 1
    dist = np.full(n, np.inf, dtype=np.float64)
    dist[src] = 0.0
    heap = [(0.0, src)]
    while heap:
        d, u = heapq.heappop(heap)
        if d > dist[u]:
            continue
        for e in range(ro[u], ro[u + 1]):
            v = ci[e]
            nd = d + w[e]
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(heap, (nd, v))
    return dist.astype(np.float32)


def pagerank_ref(graph, damping: float = 0.85, iters: int = 20,
                 tol: float = 0.0) -> np.ndarray:
    """Power-iteration PageRank with uniform teleport.

    Dangling mass is redistributed uniformly (standard formulation).
    """
    ro, ci, _ = _csr(graph)
    n = len(ro) - 1
    deg = np.diff(ro)
    pr = np.full(n, 1.0 / n)
    src = np.repeat(np.arange(n), deg)
    for _ in range(iters):
        contrib = np.where(deg > 0, pr / np.maximum(deg, 1), 0.0)
        nxt = np.zeros(n)
        np.add.at(nxt, ci, contrib[src])
        dangling = pr[deg == 0].sum() / n
        new = (1 - damping) / n + damping * (nxt + dangling)
        if tol > 0 and np.abs(new - pr).max() < tol:
            pr = new
            break
        pr = new
    return pr.astype(np.float32)


def cc_ref(graph) -> np.ndarray:
    """Connected-component labels (union-find; labels = min vertex id of
    component, then relabeled to root representative)."""
    ro, ci, _ = _csr(graph)
    n = len(ro) - 1
    parent = np.arange(n)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    src = np.repeat(np.arange(n), np.diff(ro))
    for u, v in zip(src, ci):
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    return np.array([find(x) for x in range(n)], dtype=np.int32)


def bc_ref(graph, src: int) -> np.ndarray:
    """Brandes betweenness centrality contribution from one source."""
    ro, ci, _ = _csr(graph)
    n = len(ro) - 1
    sigma = np.zeros(n)
    sigma[src] = 1.0
    depth = np.full(n, -1, dtype=np.int64)
    depth[src] = 0
    order = [src]
    frontier = [src]
    d = 0
    while frontier:
        d += 1
        nxt = []
        for u in frontier:
            for e in range(ro[u], ro[u + 1]):
                v = ci[e]
                if depth[v] < 0:
                    depth[v] = d
                    nxt.append(v)
                    order.append(v)
                if depth[v] == d:
                    sigma[v] += sigma[u]
        frontier = nxt
    delta = np.zeros(n)
    for u in reversed(order):
        for e in range(ro[u], ro[u + 1]):
            v = ci[e]
            if depth[v] == depth[u] + 1 and sigma[v] > 0:
                delta[u] += sigma[u] / sigma[v] * (1.0 + delta[v])
    bc = delta.copy()
    bc[src] = 0.0
    return bc.astype(np.float32)


def tc_ref(graph) -> int:
    """Exact triangle count of an undirected graph (forward algorithm)."""
    ro, ci, _ = _csr(graph)
    n = len(ro) - 1
    deg = np.diff(ro)
    count = 0
    adj = [set(ci[ro[u]:ro[u + 1]]) for u in range(n)]
    for u in range(n):
        for e in range(ro[u], ro[u + 1]):
            v = ci[e]
            # orient edges from higher-degree to lower-degree (paper §6.6):
            # each triangle is then charged to exactly 3 oriented edges,
            # once per edge, with full-adjacency intersections.
            if (deg[u], u) > (deg[v], v):
                count += len(adj[u] & adj[v])
    return count // 3


def reach_ref(graph, src: int, k: int) -> np.ndarray:
    """k-hop reachability oracle: bfs depth within [0, k]."""
    depth = bfs_ref(graph, src)
    return (depth >= 0) & (depth <= k)


def label_propagation_ref(graph, max_iter: int = 30,
                          labels: np.ndarray | None = None) -> np.ndarray:
    """Synchronous label propagation — the exact mirror of the device
    rule: every vertex adopts the most frequent neighbor label (ties →
    smallest label; no neighbors / no votes → keep), all vertices
    updating simultaneously, until stable or max_iter."""
    ro, ci, _ = _csr(graph)
    n = len(ro) - 1
    lab = (np.arange(n, dtype=np.int64) if labels is None
           else np.asarray(labels, np.int64).copy())
    for _ in range(max_iter):
        new = lab.copy()
        for u in range(n):
            nbr = ci[ro[u]:ro[u + 1]]
            if len(nbr) == 0:
                continue
            cnt = np.bincount(lab[nbr], minlength=n)
            if cnt.max() > 0:
                new[u] = int(np.argmax(cnt))    # first max = smallest label
        if np.array_equal(new, lab):
            break
        lab = new
    return lab.astype(np.int32)


def ppr_ref(graph, src: int, damping: float = 0.85,
            iters: int = 30) -> np.ndarray:
    """Personalized PageRank with teleport to ``src``."""
    ro, ci, _ = _csr(graph)
    n = len(ro) - 1
    deg = np.diff(ro)
    pr = np.zeros(n)
    pr[src] = 1.0
    e_src = np.repeat(np.arange(n), deg)
    for _ in range(iters):
        contrib = np.where(deg > 0, pr / np.maximum(deg, 1), 0.0)
        nxt = np.zeros(n)
        np.add.at(nxt, ci, contrib[e_src])
        dangling = pr[deg == 0].sum()
        new = damping * nxt
        new[src] += (1 - damping) + damping * dangling
        pr = new
    return pr.astype(np.float32)


def salsa_ref(graph, hubs: np.ndarray, iters: int = 10):
    """Bipartite SALSA on the subgraph induced by ``hubs`` (bool mask over
    vertices) and their out-neighbors. Returns (hub_scores, auth_scores)."""
    ro, ci, _ = _csr(graph)
    n = len(ro) - 1
    hubs = np.asarray(hubs, dtype=bool)
    auth_set = np.zeros(n, dtype=bool)
    edges = []
    for u in np.nonzero(hubs)[0]:
        for e in range(ro[u], ro[u + 1]):
            edges.append((u, ci[e]))
            auth_set[ci[e]] = True
    if not edges:
        return np.zeros(n, np.float32), np.zeros(n, np.float32)
    es = np.array(edges)
    hub_deg = np.zeros(n)
    np.add.at(hub_deg, es[:, 0], 1.0)
    auth_deg = np.zeros(n)
    np.add.at(auth_deg, es[:, 1], 1.0)
    h = hubs / max(hubs.sum(), 1)
    a = np.zeros(n)
    for _ in range(iters):
        # hub -> auth
        a = np.zeros(n)
        contrib = np.where(hub_deg > 0, h / np.maximum(hub_deg, 1), 0.0)
        np.add.at(a, es[:, 1], contrib[es[:, 0]])
        # auth -> hub
        h = np.zeros(n)
        contrib = np.where(auth_deg > 0, a / np.maximum(auth_deg, 1), 0.0)
        np.add.at(h, es[:, 0], contrib[es[:, 1]])
    return h.astype(np.float32), a.astype(np.float32)
