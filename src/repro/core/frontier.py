"""Frontier data structures — the core abstraction of the paper (§3).

A frontier is "a subset of the edges or vertices within the graph that is
currently of interest". On TPU, XLA requires static shapes, so a frontier
is a fixed-capacity buffer:

  SparseFrontier: ids (capacity,) int32, padded with -1 past ``length``.
                  This is Gunrock's compacted work queue.
  DenseFrontier:  flags (n,) bool — one bit per vertex. This is exactly the
                  bitmap Gunrock uses for the pull phase (§5.1.4) and the
                  visited-status arrays of idempotent traversal (§5.2.1).

Conversions between the two are first-class, because the paper's
direction-optimized traversal is precisely a representation switch.

Batched variants carry a leading batch axis — B concurrent traversals
sharing one topology (the frontier-*matrix* view of GraphBLAST's
multi-source BFS):

  BatchedSparseFrontier: ids (B, cap) int32, lengths (B,) — one compacted
                         work queue per lane.
  BatchedDenseFrontier:  flags (B, n) bool — one bitmap per lane.

They obey the same conversion/compaction contract as the single-lane
classes; compaction vmaps the registered "compact" backend implementation
(xla scatter or the Pallas filter_compact kernel) over the batch axis.

Capacity tiers: Gunrock's core performance property is work proportional
to the *frontier*, not the graph. Static shapes would seem to forbid
that — every buffer is worst-case sized — but a ``lax.switch`` over a
power-of-two capacity ladder restores it: each BSP step runs in the
smallest tier that holds the live workload, and only state (which is
frontier- or vertex-shaped, never edge-shaped) crosses the switch
boundary. ``tier_caps`` builds the static ladder, ``tier_index`` picks
the rung from a traced workload bound. Compaction is already
tier-aware: ``compact_values(_batch)`` accepts an output capacity larger
than its input length and pads, so a tier-sized expansion compacts
straight into the full-capacity frontier buffer the loop carries.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from . import backend as B

INVALID = jnp.int32(-1)

# The smallest capacity tier. Below this, switch overhead beats the work
# saved; it also matches the kernels' default tile floor so a tier is
# never smaller than one kernel tile (kernels/tuner.py).
MIN_TIER = 512


def tier_caps(cap: int, min_tier: int = MIN_TIER) -> tuple[int, ...]:
    """Static power-of-two capacity ladder ending exactly at ``cap``:
    (min_tier, 2·min_tier, …, cap). A cap at or below the floor is a
    single-rung ladder (untiered)."""
    cap = max(int(cap), 1)
    if cap <= min_tier:
        return (cap,)
    caps, t = [], min_tier
    while t < cap:
        caps.append(t)
        t *= 2
    caps.append(cap)
    return tuple(caps)


def tier_index(need, caps: tuple[int, ...]) -> jax.Array:
    """Index of the smallest tier with cap ≥ ``need`` (traced). A need
    beyond every rung selects the top tier — the untiered worst case,
    which is exactly what an unbounded workload must get."""
    need = jnp.asarray(need, jnp.int32)
    idx = jnp.int32(0)
    for c in caps[:-1]:
        idx = idx + (need > c).astype(jnp.int32)
    return idx


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class SparseFrontier:
    """Compacted queue of vertex or edge IDs with static capacity."""

    ids: jax.Array      # (capacity,) int32; entries >= length are INVALID
    length: jax.Array   # () int32

    def tree_flatten(self):
        return (self.ids, self.length), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def capacity(self) -> int:
        return int(self.ids.shape[0])

    @property
    def valid_mask(self) -> jax.Array:
        return jnp.arange(self.capacity, dtype=jnp.int32) < self.length

    def to_dense(self, n: int) -> "DenseFrontier":
        flags = jnp.zeros((n,), dtype=bool)
        # max-scatter: invalid lanes (mapped to slot 0) must never clear a
        # real member's flag
        safe = jnp.where(self.valid_mask, self.ids, 0)
        flags = flags.at[safe].max(self.valid_mask, mode="drop")
        return DenseFrontier(flags)


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class DenseFrontier:
    """Bitmap frontier over all n vertices."""

    flags: jax.Array    # (n,) bool

    def tree_flatten(self):
        return (self.flags,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n(self) -> int:
        return int(self.flags.shape[0])

    @property
    def length(self) -> jax.Array:
        # int32-pinned: under jax_enable_x64 jnp.sum accumulates int32
        # into int64, which would leak into while_loop carries
        return jnp.sum(self.flags.astype(jnp.int32)).astype(jnp.int32)

    def to_sparse(self, capacity: int | None = None,
                  backend: Optional[str] = None) -> SparseFrontier:
        capacity = self.n if capacity is None else capacity
        return compact_indices(self.flags, capacity, backend=backend)


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class BatchedSparseFrontier:
    """B compacted queues over one shared topology."""

    ids: jax.Array       # (B, capacity) int32; entries >= lengths[b] INVALID
    lengths: jax.Array   # (B,) int32

    def tree_flatten(self):
        return (self.ids, self.lengths), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def batch(self) -> int:
        return int(self.ids.shape[0])

    @property
    def capacity(self) -> int:
        return int(self.ids.shape[1])

    @property
    def valid_mask(self) -> jax.Array:
        lane = jnp.arange(self.capacity, dtype=jnp.int32)[None, :]
        return lane < self.lengths[:, None]

    def to_dense(self, n: int) -> "BatchedDenseFrontier":
        safe = jnp.where(self.valid_mask, self.ids, 0)
        flags = jnp.zeros((self.batch, n), bool)
        flags = jax.vmap(lambda f, s, v: f.at[s].max(v, mode="drop"))(
            flags, safe, self.valid_mask)
        return BatchedDenseFrontier(flags)

    def lane(self, b) -> SparseFrontier:
        """View one lane as a single-source frontier (squeeze)."""
        return SparseFrontier(ids=self.ids[b], length=self.lengths[b])


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class BatchedDenseFrontier:
    """B bitmap frontiers over all n vertices."""

    flags: jax.Array    # (B, n) bool

    def tree_flatten(self):
        return (self.flags,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def batch(self) -> int:
        return int(self.flags.shape[0])

    @property
    def n(self) -> int:
        return int(self.flags.shape[1])

    @property
    def lengths(self) -> jax.Array:
        # int32-pinned — see DenseFrontier.length
        return jnp.sum(self.flags.astype(jnp.int32),
                       axis=1).astype(jnp.int32)

    def to_sparse(self, capacity: int | None = None,
                  backend: Optional[str] = None) -> BatchedSparseFrontier:
        capacity = self.n if capacity is None else capacity
        return compact_indices_batch(self.flags, capacity, backend=backend)

    def lane(self, b) -> DenseFrontier:
        return DenseFrontier(self.flags[b])


def from_ids(ids, capacity: int) -> SparseFrontier:
    """Build a SparseFrontier from a (short) list/array of IDs."""
    ids = jnp.asarray(ids, dtype=jnp.int32).reshape(-1)
    k = ids.shape[0]
    buf = jnp.full((capacity,), INVALID, dtype=jnp.int32)
    buf = buf.at[:k].set(ids)
    return SparseFrontier(ids=buf, length=jnp.int32(k))


def empty(capacity: int) -> SparseFrontier:
    return SparseFrontier(ids=jnp.full((capacity,), INVALID, jnp.int32),
                          length=jnp.int32(0))


@B.register("compact", B.XLA)
def _compact_xla(values: jax.Array, mask: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """Full-length stable compaction of ``values[mask]`` → (packed, count).

    Prefix-sum + scatter — the standard GPU compaction the paper builds
    filter on (§4.2), expressed as XLA ops. The ``"pallas"`` counterpart
    is ``repro.kernels.ops.filter_compact`` (Merrill's local-scan
    filtering strategy, §5.2.1); both share this (values, mask) contract
    in the backend registry.
    """
    n = mask.shape[0]
    mask_i = mask.astype(jnp.int32)
    pos = jnp.cumsum(mask_i, dtype=jnp.int32) - mask_i   # exclusive scan
    buf = jnp.full((n,), INVALID, values.dtype)
    tgt = jnp.where(mask, pos, n)                # invalid lanes fall off
    buf = buf.at[tgt].set(values, mode="drop")
    return buf, jnp.sum(mask_i).astype(jnp.int32)


def compact_indices(mask: jax.Array, capacity: int,
                    backend: Optional[str] = None) -> SparseFrontier:
    """Stream-compact ``nonzero(mask)`` into a fixed-size buffer."""
    n = mask.shape[0]
    buf, length = compact_values(jnp.arange(n, dtype=jnp.int32), mask,
                                 capacity, backend=backend)
    return SparseFrontier(ids=buf, length=length)


def compact_values(values: jax.Array, mask: jax.Array,
                   capacity: int, fill=INVALID,
                   backend: Optional[str] = None
                   ) -> tuple[jax.Array, jax.Array]:
    """Compact ``values[mask]`` into a fixed-size buffer. Returns (buf, len).

    Dispatches through the backend registry ("xla" scatter compaction or
    the Pallas ``filter_compact`` kernel); overflow past ``capacity`` is
    dropped, the tail is ``fill``. Backend resolution happens at trace
    time — inside jitted code pass ``backend`` explicitly. A squeezed
    batch-of-1 call — one clamp/pad code path with the batched variant.
    """
    buf, lengths, _ = compact_values_batch(values[None, :], mask[None, :],
                                           capacity, fill=fill,
                                           backend=backend)
    return buf[0], lengths[0]


def from_ids_batch(srcs, capacity: int) -> BatchedSparseFrontier:
    """One single-vertex lane per entry of ``srcs`` — the typical seed
    frontier of a multi-source traversal (duplicates allowed: lanes are
    independent)."""
    srcs = jnp.asarray(srcs, dtype=jnp.int32).reshape(-1)
    b = srcs.shape[0]
    buf = jnp.full((b, capacity), INVALID, dtype=jnp.int32)
    buf = buf.at[:, 0].set(srcs)
    return BatchedSparseFrontier(ids=buf, lengths=jnp.ones((b,), jnp.int32))


def compact_values_batch(values: jax.Array, mask: jax.Array,
                         capacity: int, fill=INVALID,
                         backend: Optional[str] = None
                         ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-lane compaction of ``values[b][mask[b]]`` → fixed (B, capacity).

    Returns (buf, lengths, totals): ``lengths`` is clamped to ``capacity``
    while ``totals`` is the true pre-clamp count, so callers can detect
    capacity overflow per lane instead of silently dropping work. Same
    backend registry entry ("compact") as the single-lane path, vmapped
    over the batch axis (for "pallas" the batching rule turns the
    filter_compact kernel's grid into a (B, tiles) grid).
    """
    impl = B.dispatch("compact", backend, B.SINGLE)
    packed, totals = jax.vmap(impl)(values, mask)
    n = packed.shape[1]
    lengths = jnp.minimum(totals, capacity).astype(jnp.int32)
    if capacity <= n:
        out = packed[:, :capacity]
    else:
        pad = jnp.full((packed.shape[0], capacity - n), INVALID,
                       packed.dtype)
        out = jnp.concatenate([packed, pad], axis=1)
    lane = jnp.arange(capacity, dtype=jnp.int32)[None, :]
    out = jnp.where(lane < lengths[:, None], out,
                    jnp.asarray(fill, values.dtype))
    return out, lengths, totals.astype(jnp.int32)


def compact_indices_batch(mask: jax.Array, capacity: int,
                          backend: Optional[str] = None
                          ) -> BatchedSparseFrontier:
    """Per-lane stream-compaction of ``nonzero(mask[b])``."""
    b, n = mask.shape
    vals = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (b, n))
    buf, lengths, _ = compact_values_batch(vals, mask, capacity,
                                           backend=backend)
    return BatchedSparseFrontier(ids=buf, lengths=lengths)
