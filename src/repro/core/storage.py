"""Bandwidth-proportional graph storage (paper §5.4 + ROADMAP
"compression is speed").

Gunrock's traversal operators are memory-bound: every advance/filter
step streams the CSR column array, so *bytes per edge* — not FLOPs — is
the ceiling on traversal throughput. PR 5's tier ladder cut how many
edges a step touches; this layer cuts how many bytes each touched edge
costs. Three independent knobs, chosen once at ``Graph.from_csr`` build
time and carried as a :class:`StoragePlan` in the Graph's static aux
data (so every jit cache key includes the storage format):

  index dtype   int16 | int32 | int64 — the narrowest dtype that holds
                every vertex id (and the -1 invalid sentinel). Picked
                automatically from ``n`` by :func:`plan_for`; an
                explicit ``index_dtype=`` override must still be wide
                enough (validated, never silently narrowed).
  encoding      "dense" — the classic column array, stored at the index
                dtype. "delta" — per-row anchored deltas: neighbor
                lists are sorted (a from_csr invariant), so row r is
                stored as ``anchor[r]`` (its first neighbor id, int32)
                plus uint16 ``delta[e] = col[e] - anchor[r]``. Escape
                path: a delta that would exceed 0xFFFE stores the
                sentinel 0xFFFF and the true value rides in a sorted
                (position, value) side list — O(log K) fixup on gather,
                zero cost when K == 0 (the common case: escapes need
                id ranges wider than 65534 *within one row*).
  value dtype   "fp32" | "bf16" — requested compute precision for the
                inexact semirings (plus_times / plus_and): bf16
                multiply, fp32 accumulate. Exact semirings (min/max/or)
                ignore it; see linalg.ops for the parity contract.

Anchored deltas (not prefix deltas) keep O(1) random slot access:
``col[e] = anchor[row(e)] + delta[e]`` needs no scan, so the LB advance
kernels decode in place with one extra VMEM gather while streaming half
the bytes. :func:`gather_cols` is the one decode primitive every XLA
consumer routes through — gathers decode per *touched* edge, never by
materializing the dense array (that fallback exists too, for providers
that declare ``encodings=("dense",)``; backend.storage_arg inserts it).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

INDEX_DTYPES = ("int16", "int32", "int64")
ENCODINGS = ("dense", "delta")
VALUE_DTYPES = ("fp32", "bf16")

# uint16 delta stream: 0xFFFF marks an escaped slot (true value in the
# side list); 0xFFFE is therefore the largest inline delta.
DELTA_ESCAPE = 0xFFFF
DELTA_MAX = 0xFFFE

_NP_INDEX = {"int16": np.int16, "int32": np.int32, "int64": np.int64}
_JNP_INDEX = {"int16": jnp.int16, "int32": jnp.int32, "int64": jnp.int64}
# largest representable vertex id per dtype, keeping -1 free as the
# invalid-lane sentinel (any id ≤ max is distinguishable from -1)
_MAX_ID = {"int16": 2**15 - 1, "int32": 2**31 - 1, "int64": 2**63 - 1}


@dataclass(frozen=True)
class StoragePlan:
    """The build-time storage decision. Frozen + hashable (str fields
    only) so it rides pytree aux data and jit static args unchanged."""

    index_dtype: str = "int32"
    encoding: str = "dense"
    value_dtype: str = "fp32"

    def __post_init__(self):
        if self.index_dtype not in INDEX_DTYPES:
            raise ValueError(f"index_dtype must be one of {INDEX_DTYPES}, "
                             f"got {self.index_dtype!r}")
        if self.encoding not in ENCODINGS:
            raise ValueError(f"encoding must be one of {ENCODINGS}, "
                             f"got {self.encoding!r}")
        if self.value_dtype not in VALUE_DTYPES:
            raise ValueError(f"value_dtype must be one of {VALUE_DTYPES}, "
                             f"got {self.value_dtype!r}")

    @property
    def np_index_dtype(self):
        return _NP_INDEX[self.index_dtype]

    @property
    def jnp_index_dtype(self):
        return _JNP_INDEX[self.index_dtype]

    @property
    def index_bytes(self) -> int:
        return np.dtype(self.np_index_dtype).itemsize


def plan_for(n: int, *, index_dtype: Optional[str] = None,
             encoding: str = "dense",
             value_dtype: str = "fp32") -> StoragePlan:
    """Pick the storage plan for an ``n``-vertex graph.

    With no override the dtype ladder selects the narrowest type whose
    id range covers ``n-1`` (int16 up to 32767 vertices, int32 up to
    2^31-1, int64 beyond). An explicit ``index_dtype`` must still be
    wide enough — requesting int16 for a 10^6-vertex graph raises
    instead of corrupting ids. int64 requires ``jax_enable_x64`` (JAX
    silently truncates 64-bit arrays otherwise); that check lives in
    Graph.from_csr where the arrays are created.
    """
    max_id = max(n - 1, 0)
    if index_dtype is None:
        for cand in INDEX_DTYPES:
            if max_id <= _MAX_ID[cand]:
                index_dtype = cand
                break
    elif index_dtype not in INDEX_DTYPES:
        raise ValueError(f"index_dtype must be one of {INDEX_DTYPES}, "
                         f"got {index_dtype!r}")
    elif max_id > _MAX_ID[index_dtype]:
        raise ValueError(
            f"index_dtype={index_dtype!r} cannot hold vertex ids up to "
            f"{max_id} (max {_MAX_ID[index_dtype]})")
    return StoragePlan(index_dtype=index_dtype, encoding=encoding,
                       value_dtype=value_dtype)


class EncodedCols(NamedTuple):
    """Delta-encoded CSR/CSC column storage — a pytree, so it flows
    through jit / registry dispatch in the positional slot the dense
    column array normally occupies (providers that declared the
    ``"delta"`` encoding branch on ``isinstance(..., EncodedCols)`` at
    trace time).

    anchor   (n,) int32   first neighbor id of each row (0 if empty)
    delta    (m,) uint16  col - anchor[row]; 0xFFFF = escaped slot
    esc_pos  (K,) int32   edge positions of escaped slots, ascending
    esc_val  (K,) int32   true column values at those positions
    row_seg  (m,) int32   edge→row map (anchors the vectorized decode)
    """

    anchor: jax.Array
    delta: jax.Array
    esc_pos: jax.Array
    esc_val: jax.Array
    row_seg: jax.Array

    @property
    def num_edges(self) -> int:
        return int(self.delta.shape[0])

    @property
    def num_escapes(self) -> int:
        return int(self.esc_pos.shape[0])


ColStore = Union[jax.Array, EncodedCols]


def encode_delta(offsets: np.ndarray, cols: np.ndarray,
                 row_seg: np.ndarray) -> EncodedCols:
    """Host-side (build-time) delta encoder. ``cols`` must be sorted
    within each row — a ``Graph.from_csr`` invariant — so deltas are
    non-negative and decoded rows stay sorted (segmented intersection
    binary-searches them)."""
    offsets = np.asarray(offsets, np.int64)
    cols64 = np.asarray(cols, np.int64)
    seg = np.asarray(row_seg, np.int64)
    n = len(offsets) - 1
    anchor = np.zeros(n, np.int32)
    nonempty = offsets[:-1] < offsets[1:]
    anchor[nonempty] = cols64[offsets[:-1][nonempty]]
    d = cols64 - anchor.astype(np.int64)[seg]
    if len(d) and d.min() < 0:
        raise ValueError("delta encoding requires sorted neighbor lists "
                         "(build the Graph with sort_neighbors=True)")
    esc = np.nonzero(d > DELTA_MAX)[0].astype(np.int32)
    delta = np.where(d > DELTA_MAX, DELTA_ESCAPE, d).astype(np.uint16)
    return EncodedCols(
        anchor=jnp.asarray(anchor),
        delta=jnp.asarray(delta),
        esc_pos=jnp.asarray(esc),
        esc_val=jnp.asarray(cols64[esc].astype(np.int32)
                            if len(esc) else np.zeros(0, np.int32)),
        row_seg=jnp.asarray(np.asarray(row_seg, np.int32)))


def decode_cols(store: ColStore) -> jax.Array:
    """Canonical dense int32 column view — the decode-to-dense fallback
    (vectorized, one gather + one add + an escape scatter, O(m))."""
    if not isinstance(store, EncodedCols):
        return store if store.dtype == jnp.int32 else store.astype(jnp.int32)
    dense = store.anchor[store.row_seg] + store.delta.astype(jnp.int32)
    if store.num_escapes:
        dense = dense.at[store.esc_pos].set(store.esc_val)
    return dense


def gather_cols(store: ColStore, eid: jax.Array,
                src: Optional[jax.Array] = None) -> jax.Array:
    """Decode-on-gather: column values at edge positions ``eid``, as
    int32 whatever the storage. THE access primitive for XLA providers —
    bytes move per touched edge, the dense array is never materialized.

    ``src`` (owning row of each ``eid``, when the caller already has it,
    e.g. the advance expansion) saves the row_seg lookup; without it the
    encoded row_seg map supplies the row. Escaped slots are patched via
    binary search of the sorted escape list (K is 0 for every graph
    whose per-row id spans fit 16 bits, so the searchsorted branch is
    compiled out in the common case)."""
    if store_num_edges(store) == 0:
        # XLA rejects gathers from a zero-length axis; an edgeless store
        # has no real slots, so every (masked-out) lane reads 0
        return jnp.zeros(jnp.shape(eid), jnp.int32)
    if not isinstance(store, EncodedCols):
        out = store[eid]
        return out if out.dtype == jnp.int32 else out.astype(jnp.int32)
    row = store.row_seg[eid] if src is None else src
    out = store.anchor[row] + store.delta[eid].astype(jnp.int32)
    if store.num_escapes:
        j = jnp.searchsorted(store.esc_pos, eid.astype(jnp.int32))
        j = jnp.clip(j, 0, store.num_escapes - 1)
        hit = store.esc_pos[j] == eid
        out = jnp.where(hit, store.esc_val[j], out)
    return out


def store_num_edges(store: ColStore) -> int:
    """Edge count of a column store (dense array or delta stream)."""
    if isinstance(store, EncodedCols):
        return store.num_edges
    return int(store.shape[0])


def store_bytes(store: Optional[ColStore]) -> int:
    """Resident bytes of one column store (dense array or delta parts)."""
    if store is None:
        return 0
    if isinstance(store, EncodedCols):
        return sum(int(np.dtype(a.dtype).itemsize) * int(a.shape[0])
                   for a in (store.anchor, store.delta,
                             store.esc_pos, store.esc_val))
    return int(np.dtype(store.dtype).itemsize) * int(store.shape[0])


def resident_bytes(graph) -> dict:
    """Per-array resident-byte breakdown for a Graph (serving --json and
    every bench artifact report this next to latency).

    ``bytes_per_edge`` is the headline bandwidth metric: bytes of
    *column storage* (CSR + CSC neighbor ids, the arrays every
    advance/SpMV step streams per edge) divided by m. The edge→row maps
    and offsets are deliberately excluded from the headline — they are
    loop metadata, not per-edge streamed payload — but appear in the
    breakdown and in ``total_bytes`` / ``total_bytes_per_edge``.
    """
    def _nbytes(a):
        if a is None:
            return 0
        return int(np.dtype(a.dtype).itemsize) * int(np.prod(a.shape))

    arrays = {
        "row_offsets": _nbytes(graph.row_offsets),
        "col_storage": store_bytes(graph.col_store),
        "edge_values": _nbytes(graph.edge_values),
        "csc_offsets": _nbytes(graph.csc_offsets),
        "csc_col_storage": store_bytes(graph.csc_store),
        "csc_edge_values": _nbytes(graph.csc_edge_values),
        "csc_edge_ids": _nbytes(graph.csc_edge_ids),
        "row_seg": _nbytes(graph.row_seg),
        "csc_row_seg": _nbytes(graph.csc_row_seg),
        "overflow_lists": (_nbytes(graph.over_pos) + _nbytes(graph.over_row)
                           + _nbytes(graph.csc_over_pos)
                           + _nbytes(graph.csc_over_row)),
    }
    m = max(graph.num_edges, 1)
    col_bytes = arrays["col_storage"] + arrays["csc_col_storage"]
    total = sum(arrays.values())
    plan = getattr(graph, "plan", None)
    return {
        "plan": None if plan is None else {
            "index_dtype": plan.index_dtype, "encoding": plan.encoding,
            "value_dtype": plan.value_dtype},
        "arrays": arrays,
        "column_bytes": col_bytes,
        "bytes_per_edge": round(col_bytes / m, 3),
        "total_bytes": total,
        "total_bytes_per_edge": round(total / m, 3),
    }
