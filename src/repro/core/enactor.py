"""The enactor: Gunrock's iterative-convergent BSP loop driver (paper §3).

A Gunrock program is a `Problem` (algorithm state pytree), a set of functors,
and an `Enactor` that runs bulk-synchronous operator steps until convergence
(typically: empty frontier, or max-iteration / volatile-flag criteria).

`run_until` wraps `jax.lax.while_loop` with an iteration guard so every
primitive shares the same convergence contract and can be jitted end-to-end
(one XLA program per primitive — the whole-primitive analogue of the paper's
kernel-fusion philosophy).

`run_until_any` is the batched variant: state carries a leading batch axis
(one lane per concurrent traversal — the frontier-matrix view of
GraphBLAST's multi-source BFS), `cond` returns a per-lane flag, and the
loop runs while *any* lane is active. Converged lanes are frozen: the body
still computes them (BSP lockstep — static shapes rule out early exit) but
the driver discards their updates, so stragglers finish while finished
lanes are bit-stable no-ops. Per-lane iteration counts come back alongside
the wall-clock iteration count.

`tiered_step` is the frontier-proportional escape hatch from worst-case
static shapes: one BSP step dispatched over a static capacity ladder
(`lax.switch`), so the edge-shaped intermediates inside the step are
sized to the live workload's tier instead of the graph. Only state —
frontier/vertex-shaped, tier-independent — crosses the switch boundary,
which is what makes every rung bit-identical given enough capacity.

Telemetry (`obs.telemetry`): both loops accept an optional read-only
``probe`` — ``probe(prev_state, new_state) -> {column: value}`` —
recorded into a caller-provided ``TelemetryBuffer`` carried alongside
the loop state. ``probe=None`` is byte-for-byte the historical path;
with a probe the loop returns the filled buffer as one extra element.
Probes observe, never steer: nothing they compute feeds back into the
step, which is what makes the telemetry on/off bit-parity contract
(tests/test_obs.py) hold by construction.
"""
from __future__ import annotations

from typing import Callable, Sequence, TypeVar

import jax
import jax.numpy as jnp

S = TypeVar("S")


def run_until(cond: Callable[[S], jax.Array],
              body: Callable[[S], S],
              state: S,
              max_iter: int,
              probe: Callable[[S, S], dict] | None = None,
              telemetry=None,
              budget=None):
    """while (cond(state) && it < max_iter): state = body(state).

    Returns (final_state, iterations_run). ``max_iter`` bounds the loop so
    XLA sees a well-founded while; primitives pass n (or a diameter bound).

    ``budget`` (a ``repro.ft.Budget``, duck-typed via ``cap_iters`` to keep
    the core free of an ft import) clamps ``max_iter`` to the query's
    iteration budget: the loop then returns the *partial* state at the cap
    — callers compare ``iters`` against their convergence predicate to
    stamp ``converged`` / ``deadline_exceeded`` flags. ``budget=None`` is
    byte-for-byte the historical path. Wall-clock budgets are enforced
    host-side by the serving loop, not here — a jitted while cannot
    consult the host clock.

    With ``probe``/``telemetry`` set, each step additionally records
    ``probe(prev, new)`` into the ``TelemetryBuffer`` and the loop
    returns (final_state, iterations_run, filled_buffer).
    """
    if budget is not None:
        max_iter = budget.cap_iters(max_iter)

    if probe is None:

        def _cond(carry):
            state, it = carry
            return jnp.logical_and(cond(state), it < max_iter)

        def _body(carry):
            state, it = carry
            return body(state), it + 1

        (final, iters) = jax.lax.while_loop(_cond, _body,
                                            (state, jnp.int32(0)))
        return final, iters

    if telemetry is None:
        raise ValueError("probe= requires a telemetry buffer")

    def _cond_t(carry):
        state, it, _ = carry
        return jnp.logical_and(cond(state), it < max_iter)

    def _body_t(carry):
        state, it, buf = carry
        new = body(state)
        return new, it + 1, buf.record(**probe(state, new))

    final, iters, buf = jax.lax.while_loop(
        _cond_t, _body_t, (state, jnp.int32(0), telemetry))
    return final, iters, buf


def select_lanes(mask: jax.Array, on_true: S, on_false: S) -> S:
    """Per-lane pytree select: ``mask`` (B,) broadcast against every
    leaf's leading batch axis. The one place the batched engine's
    lane-choice contract lives (freezing, mixed-direction picks, relax
    vs bucket-pop)."""

    def pick(a, c):
        m = mask.reshape(mask.shape + (1,) * (a.ndim - 1))
        return jnp.where(m, a, c)

    return jax.tree_util.tree_map(pick, on_true, on_false)


def run_until_any(cond: Callable[[S], jax.Array],
                  body: Callable[[S], S],
                  state: S,
                  max_iter: int,
                  probe: Callable[[S, S], dict] | None = None,
                  telemetry=None,
                  budget=None):
    """Batched BSP loop: iterate while any lane of ``cond(state)`` holds.

    Contract:
      * every leaf of ``state`` has a leading batch axis of size B;
      * ``cond(state)`` returns a (B,) bool of still-active lanes;
      * ``body(state)`` computes one step for ALL lanes (lockstep).

    The driver masks the update per lane: a lane whose ``cond`` was False
    entering the step keeps its old state bit-for-bit (frozen), so a
    converged traversal is a no-op while ragged stragglers continue.
    Returns (final_state, per_lane_iters (B,) int32, iterations_run ()).

    With ``probe``/``telemetry`` set, each wall-clock step records
    ``probe(prev, new)`` (``new`` is the already lane-masked state, so
    frozen lanes report their frozen values) and the filled buffer comes
    back as a fourth element; per-lane valid lengths are exactly the
    returned ``lane_iters``.

    ``budget`` clamps ``max_iter`` exactly as in :func:`run_until`; lanes
    still active at the cap come back partial, and ``cond(final)`` tells
    the caller which lanes those are.
    """
    if budget is not None:
        max_iter = budget.cap_iters(max_iter)

    # the (B,) active mask rides in the carry so cond runs once per step
    if probe is None:

        def _cond(carry):
            _, _, it, active = carry
            return jnp.logical_and(jnp.any(active), it < max_iter)

        def _body(carry):
            st, lane_iters, it, active = carry
            st = select_lanes(active, body(st), st)  # freeze finished lanes
            return (st, lane_iters + active.astype(jnp.int32), it + 1,
                    cond(st))

        active0 = cond(state)
        lanes0 = jnp.zeros(active0.shape, jnp.int32)
        final, lane_iters, iters, _ = jax.lax.while_loop(
            _cond, _body, (state, lanes0, jnp.int32(0), active0))
        return final, lane_iters, iters

    if telemetry is None:
        raise ValueError("probe= requires a telemetry buffer")

    def _cond_t(carry):
        _, _, it, active, _ = carry
        return jnp.logical_and(jnp.any(active), it < max_iter)

    def _body_t(carry):
        st, lane_iters, it, active, buf = carry
        new = select_lanes(active, body(st), st)
        buf = buf.record(**probe(st, new))
        return (new, lane_iters + active.astype(jnp.int32), it + 1,
                cond(new), buf)

    active0 = cond(state)
    lanes0 = jnp.zeros(active0.shape, jnp.int32)
    final, lane_iters, iters, _, buf = jax.lax.while_loop(
        _cond_t, _body_t,
        (state, lanes0, jnp.int32(0), active0, telemetry))
    return final, lane_iters, iters, buf


def tiered_step(need, caps: Sequence[int],
                step_of: Callable[[int], Callable[[S], S]],
                state: S, with_index: bool = False):
    """Run one BSP step at the smallest capacity tier holding ``need``.

    ``caps`` is the static power-of-two ladder (``backend.tier_plan``),
    ``need`` the traced workload upper bound (e.g. the frontier's degree
    sum), ``step_of(cap)`` builds the step function for one static tier
    capacity. Every branch must return state of identical structure —
    which holds by construction when only frontier/vertex-shaped state
    crosses the boundary and the tier sizes just the edge-shaped
    intermediates. A single-rung ladder skips the switch entirely (the
    untiered / pinned case — also the contract of every distributed
    placement, sharded and 2d alike, where per-device tier choices
    would desynchronize collective shapes).

    ``with_index=True`` additionally returns the chosen tier index as a
    traced int32 — the telemetry hook for "which rung fired this step"
    without the caller recomputing the ladder search.
    """
    if len(caps) == 1:
        if with_index:
            return step_of(caps[0])(state), jnp.int32(0)
        return step_of(caps[0])(state)
    from .frontier import tier_index
    idx = tier_index(need, tuple(caps))
    out = jax.lax.switch(idx, [step_of(c) for c in caps], state)
    if with_index:
        return out, idx
    return out
