"""The enactor: Gunrock's iterative-convergent BSP loop driver (paper §3).

A Gunrock program is a `Problem` (algorithm state pytree), a set of functors,
and an `Enactor` that runs bulk-synchronous operator steps until convergence
(typically: empty frontier, or max-iteration / volatile-flag criteria).

`run_until` wraps `jax.lax.while_loop` with an iteration guard so every
primitive shares the same convergence contract and can be jitted end-to-end
(one XLA program per primitive — the whole-primitive analogue of the paper's
kernel-fusion philosophy).
"""
from __future__ import annotations

from typing import Callable, TypeVar

import jax
import jax.numpy as jnp

S = TypeVar("S")


def run_until(cond: Callable[[S], jax.Array],
              body: Callable[[S], S],
              state: S,
              max_iter: int) -> tuple[S, jax.Array]:
    """while (cond(state) && it < max_iter): state = body(state).

    Returns (final_state, iterations_run). ``max_iter`` bounds the loop so
    XLA sees a well-founded while; primitives pass n (or a diameter bound).
    """

    def _cond(carry):
        state, it = carry
        return jnp.logical_and(cond(state), it < max_iter)

    def _body(carry):
        state, it = carry
        return body(state), it + 1

    (final, iters) = jax.lax.while_loop(_cond, _body, (state, jnp.int32(0)))
    return final, iters
