"""Subgraph matching (paper §6.7) — the filtering-and-joining procedure.

Finds all embeddings of a small connected query pattern in the data
graph:

  filter phase — candidates for each query vertex are pruned by degree
      (and optional label) — a Gunrock filter over the vertex frontier.
  join phase   — query vertices are bound one at a time in BFS order;
      each extension expands the candidate neighbor list of one bound
      anchor (LB advance) and probes membership in every other bound
      anchor's adjacency with the segmented-intersection binary search
      (kernels/segment_search) + distinctness filter.

Static shapes: the partial-embedding table is a fixed-capacity buffer
(cap × n_q); overflow is reported (matches beyond `cap` are dropped and
`truncated` is set). Embeddings are *ordered* maps query→data vertex, so
each undirected match is found once per query automorphism (e.g. a
triangle query yields 6 embeddings per triangle) — same convention as
the paper's join-based enumeration.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .. import operators as ops
from ..frontier import compact_values
from ..graph import Graph


class MatchResult(NamedTuple):
    embeddings: jax.Array    # (cap, n_q) int32, -1 padded
    count: jax.Array         # () int32
    truncated: bool


def _bfs_order_ok(n_q: int, q_edges) -> bool:
    seen = {0}
    for k in range(1, n_q):
        if not any((a in seen) for a, b in q_edges if b == k) and \
           not any((b in seen) for a, b in q_edges if a == k):
            return False
        seen.add(k)
    return True


def subgraph_match(graph: Graph, n_q: int,
                   q_edges: Sequence[tuple], cap: int = 4096,
                   labels: Optional[jax.Array] = None,
                   q_labels: Optional[Sequence[int]] = None) -> MatchResult:
    """Enumerate embeddings of the query graph (undirected pattern).

    q_edges: list of (a, b) query edges with vertices 0..n_q-1, ordered so
    every vertex k>0 has an edge to some earlier vertex (BFS order).
    labels/q_labels: optional vertex labels for the filtering phase.
    """
    assert _bfs_order_ok(n_q, q_edges), "query must be BFS-ordered"
    q_edges = [(int(a), int(b)) for a, b in q_edges]
    qdeg = np.zeros(n_q, np.int32)
    for a, b in q_edges:
        qdeg[a] += 1
        qdeg[b] += 1

    n = graph.num_vertices
    deg = graph.degrees
    # dense decoded view, hoisted once: the join loop re-reads the full
    # adjacency both for expansion and for the binary-search probes
    ci = graph.cols()

    # ---- filtering phase: candidates of query vertex 0 -------------------
    keep = deg >= int(qdeg[0])
    if labels is not None and q_labels is not None:
        keep = keep & (labels == int(q_labels[0]))
    cand0, count = compact_values(jnp.arange(n, dtype=jnp.int32), keep,
                                  cap)
    truncated = bool(int(jnp.sum(keep, dtype=jnp.int32)) > cap)
    emb = jnp.full((cap, n_q), -1, jnp.int32)
    emb = emb.at[:, 0].set(cand0)
    count = jnp.minimum(count, cap)
    # ---- joining phase: bind query vertices 1..n_q-1 ---------------------
    for k in range(1, n_q):
        anchors = sorted({a for a, b in q_edges if b == k} |
                         {b for a, b in q_edges if a == k})
        anchors = [a for a in anchors if a < k]
        a0 = anchors[0]
        valid_emb = jnp.arange(cap) < count
        base = jnp.where(valid_emb, emb[:, a0], 0)
        sizes = jnp.where(valid_emb,
                          graph.row_offsets[base + 1]
                          - graph.row_offsets[base], 0)
        # the join loop runs eagerly (tiny query graphs), so the expansion
        # buffer can be sized exactly to the round's work
        cap_out = max(int(jnp.sum(sizes)), 1)
        exp = ops.lb_expand(sizes, valid_emb, cap_out)
        src_row = exp.in_pos                       # embedding index
        eidx = graph.row_offsets[base[src_row]] + exp.rank
        cand = ci[jnp.where(exp.valid, eidx, 0)]
        ok = exp.valid
        # degree / label filter
        ok = ok & (deg[cand] >= int(qdeg[k]))
        if labels is not None and q_labels is not None:
            ok = ok & (labels[cand] == int(q_labels[k]))
        # adjacency probes against the other bound anchors
        for a in anchors[1:]:
            av = emb[src_row, a]
            lo = graph.row_offsets[jnp.where(ok, av, 0)]
            hi = graph.row_offsets[jnp.where(ok, av, 0) + 1]
            found = ops._searchsorted_segment(ci, lo, hi, cand)
            ok = ok & found
        # distinctness: candidate must differ from all bound vertices
        for j in range(k):
            ok = ok & (cand != emb[src_row, j])
        # compact surviving (embedding, candidate) pairs
        oki = ok.astype(jnp.int32)
        pos = jnp.cumsum(oki, dtype=jnp.int32) - oki
        raw = jnp.sum(ok, dtype=jnp.int32)
        truncated = truncated or int(raw) > cap
        new_count = jnp.minimum(raw, cap)
        tgt = jnp.where(ok & (pos < cap), pos, cap)
        new_emb = jnp.full((cap, n_q), -1, jnp.int32)
        new_emb = new_emb.at[tgt, :].set(emb[src_row], mode="drop")
        new_emb = new_emb.at[tgt, k].set(cand, mode="drop")
        emb, count = new_emb, new_count

    return MatchResult(embeddings=emb, count=count, truncated=truncated)


def subgraph_match_ref(graph: Graph, n_q: int, q_edges) -> int:
    """Brute-force oracle: count ordered embeddings (numpy)."""
    ro = np.asarray(graph.row_offsets)
    ci = graph.cols_np()
    n = len(ro) - 1
    adj = [set(ci[ro[u]:ro[u + 1]]) for u in range(n)]
    q_adj = [[] for _ in range(n_q)]
    for a, b in q_edges:
        q_adj[b].append(a)
        q_adj[a].append(b)

    count = 0
    stack = [(v,) for v in range(n)]
    while stack:
        partial = stack.pop()
        k = len(partial)
        if k == n_q:
            count += 1
            continue
        anchors = [a for a in q_adj[k] if a < k]
        cands = set(adj[partial[anchors[0]]]) if anchors else set(range(n))
        for a in anchors[1:]:
            cands &= adj[partial[a]]
        for c in cands:
            if c not in partial:
                stack.append(partial + (c,))
    return count
