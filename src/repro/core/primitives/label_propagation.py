"""Label-propagation community detection — an algebraic primitive.

The update rule is the classic synchronous LP: every vertex adopts the
most frequent label among its neighbors (ties → smallest label, no
votes → keep). Algebraically one iteration is a plus-times SpMM against
the one-hot label matrix followed by a max-argmax row reduction over
the ⟨max,min⟩ (max score, min label) merge — the "argmax semiring"
formulation of CombBLAS/GraphBLAST, which is exactly the kind of
whole-frontier primitive that is awkward to express vertex-centrically
(the per-vertex mode needs a histogram, not a scatter).

Label space is swept in blocks of ``block`` columns (row-tiled over the
label domain): each block is one dense-accumulator SpMM through the
``"spmm"`` registry op — the fused masked-semiring row kernel under
``backend="pallas"`` — and blocks merge into a running
(best_count, best_label) pair under the max-min tie-break, so memory
stays O(n·block) while the full n-label domain is covered. Cost is
O(m·L/block) gathers per iteration over a label domain of size L —
the price of exact mode computation; communities collapse the active
label set quickly in practice.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.linalg import semiring as SR

from .. import backend as B
from ..enactor import run_until
from ..graph import Graph


class LPResult(NamedTuple):
    labels: jax.Array       # (n,) int32 community labels
    iterations: jax.Array   # () int32


@functools.partial(jax.jit, static_argnames=("max_iter", "backend",
                                             "ell_width", "num_labels",
                                             "block", "placement"))
def _lp_impl(graph: Graph, labels0: jax.Array, max_iter: int, backend: str,
             ell_width: Optional[int], num_labels: int,
             block: int, placement: str = B.SINGLE) -> LPResult:
    n = graph.num_vertices
    spmm_op = B.dispatch("spmm", backend, placement)
    col_store = B.storage_arg("spmm", backend, placement, graph=graph,
                              side="csr")
    nblk = -(-num_labels // block)

    def body(st):
        labels, _ = st

        def blk(i, carry):
            best, bestl = carry
            cols = i * block + jnp.arange(block, dtype=jnp.int32)
            onehot = (labels[:, None] == cols[None, :]).astype(jnp.float32)
            # votes[v, j] = #neighbors of v carrying label cols[j]
            votes = spmm_op(graph.row_offsets, col_store, None,
                            onehot, SR.plus_times, ell_width, None,
                            graph.row_seg)
            bs = jnp.max(votes, axis=1)
            bl = cols[jnp.argmax(votes, axis=1)]   # first max = min label
            # ⟨max,min⟩ merge: higher count wins, equal count → smaller
            # label; zero-vote candidates never displace the carry
            take = (bs > best) | ((bs == best) & (bs > 0) & (bl < bestl))
            return jnp.where(take, bs, best), jnp.where(take, bl, bestl)

        best0 = jnp.zeros((n,), jnp.float32)
        _, new_labels = jax.lax.fori_loop(0, nblk, blk, (best0, labels))
        changed = jnp.sum(new_labels != labels, dtype=jnp.int32)
        return new_labels, changed

    state = (labels0, jnp.int32(1))
    (labels, _), iters = run_until(lambda st: st[1] > 0, body, state,
                                   max_iter=max_iter)
    return LPResult(labels=labels, iterations=iters)


def label_propagation(graph, *, labels0=None,
                      num_labels: Optional[int] = None,
                      max_iter: int = 30, block: Optional[int] = None,
                      backend: Optional[str] = None,
                      use_kernel: Optional[bool] = None,
                      placement: Optional[str] = None) -> LPResult:
    """Synchronous LP until the labeling is stable (or max_iter).

    ``labels0`` defaults to each vertex being its own community
    (``arange(n)``); ``num_labels`` bounds the label domain (defaults to
    n) and ``block`` the SpMM column-block width. Labels spread along
    out-neighbors; pass an undirected graph for community detection.
    ``graph`` may be a ``ShardedGraph`` — the one-hot SpMM blocks then
    run through the sharded registry provider and labels bit-match the
    single-device run.
    """
    bk = B.resolve(backend, use_kernel)
    pl, ctx = B.resolve_graph_placement(graph, placement)
    n = graph.num_vertices
    if labels0 is None:
        labels0 = jnp.arange(n, dtype=jnp.int32)
    else:
        labels0 = jnp.asarray(labels0, jnp.int32)
    if num_labels is None:
        num_labels = n
    if block is None:
        block = max(1, min(32, num_labels))
    ell_width = graph.ell_width
    if ell_width is None and bk == B.PALLAS and pl == B.SINGLE:
        raise ValueError(
            "label_propagation on the pallas backend needs "
            "Graph.ell_width; build the Graph via Graph.from_csr / "
            "from_edge_list")
    with ctx:
        return _lp_impl(graph, labels0, max_iter, bk,
                        None if ell_width is None else int(ell_width),
                        int(num_labels), int(block), pl)
