"""Triangle counting (paper §6.6) — forward algorithm via segmented
intersection.

Stage 1 (host, 'forming edge lists'): advance over all vertices to the full
edge frontier, then *filter* to keep each undirected edge once, oriented
from the higher-(degree, id) endpoint to the lower — the paper's workload
reduction that removes ~5/6 of the intersection work. The filtered edges
induce a DAG subgraph G'.

Stage 2 (device): segmented intersection of N'(u) ∩ N'(v) for every
remaining edge (u,v) — each triangle is counted exactly once.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import backend as B
from .. import operators as ops
from ..frontier import SparseFrontier
from ..graph import Graph, edge_list, from_edge_list


class TCResult(NamedTuple):
    total: jax.Array          # () int32 global triangle count
    per_edge: jax.Array       # (m',) per-oriented-edge counts
    edge_src: np.ndarray      # (m',) oriented edge sources (host)
    edge_dst: np.ndarray      # (m',) oriented edge dsts (host)


def _orient(graph: Graph) -> tuple[Graph, np.ndarray, np.ndarray]:
    """Filter stage: orient each undirected edge high→low (deg, id)."""
    src, dst = edge_list(graph)
    ro = np.asarray(graph.row_offsets)
    deg = np.diff(ro)
    keep = (deg[src] > deg[dst]) | ((deg[src] == deg[dst]) & (src > dst))
    fsrc, fdst = src[keep], dst[keep]
    sub = from_edge_list(fsrc, fdst, n=graph.num_vertices, undirected=False,
                         build_csc=False, deduplicate=False,
                         remove_self_loops=False)
    ssrc, sdst = edge_list(sub)
    return sub, ssrc, sdst


def triangle_count(graph: Graph, *, backend: Optional[str] = None,
                   use_kernel: Optional[bool] = None) -> TCResult:
    """Exact TC. The graph must be undirected (both edge directions
    present), with sorted neighbor lists (from_edge_list guarantees)."""
    bk = B.resolve(backend, use_kernel)
    sub, ssrc, sdst = _orient(graph)
    mp = sub.num_edges
    if mp == 0:
        z = jnp.int32(0)
        return TCResult(z, jnp.zeros((0,), jnp.int32), ssrc, sdst)
    fa = SparseFrontier(ids=jnp.asarray(ssrc, jnp.int32),
                        length=jnp.int32(mp))
    fb = SparseFrontier(ids=jnp.asarray(sdst, jnp.int32),
                        length=jnp.int32(mp))
    # output capacity: sum of min-degree per pair, bounded by edges of G'
    deg = np.diff(np.asarray(sub.row_offsets))
    cap_out = int(np.minimum(deg[ssrc], deg[sdst]).sum())
    cap_out = max(cap_out, 1)

    @jax.jit
    def run(sub, fa, fb):
        res = ops.segmented_intersect(sub, fa, fb, cap_out, backend=bk)
        return res.total, res.counts

    total, counts = run(sub, fa, fb)
    return TCResult(total=total.astype(jnp.int32),
                    per_edge=counts[:mp], edge_src=ssrc, edge_dst=sdst)


def triangle_count_full(graph: Graph, *, backend: Optional[str] = None,
                        use_kernel: Optional[bool] = None) -> jax.Array:
    """Unfiltered variant ('tc-intersection-full' in Fig. 25): intersect
    both directions of every edge and divide by 6 — the baseline that
    shows the filter's ~6x workload reduction."""
    bk = B.resolve(backend, use_kernel)
    src, dst = edge_list(graph)
    m = graph.num_edges
    fa = SparseFrontier(ids=jnp.asarray(src, jnp.int32), length=jnp.int32(m))
    fb = SparseFrontier(ids=jnp.asarray(dst, jnp.int32), length=jnp.int32(m))
    deg = np.diff(np.asarray(graph.row_offsets))
    cap_out = int(np.minimum(deg[src], deg[dst]).sum())
    cap_out = max(cap_out, 1)

    @jax.jit
    def run(graph, fa, fb):
        res = ops.segmented_intersect(graph, fa, fb, cap_out, backend=bk)
        return res.total

    return (run(graph, fa, fb) // 6).astype(jnp.int32)
