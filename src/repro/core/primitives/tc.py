"""Triangle counting (paper §6.6) — masked semiring SpGEMM.

Stage 1 (host, 'forming edge lists'): orient each undirected edge from
the higher-(degree, id) endpoint to the lower — the paper's workload
reduction that removes ~5/6 of the intersection work. The oriented
edges are the nnz pattern of the output mask M and induce a DAG G'.

Stage 2 (device): the GraphBLAST formulation ``C⟨M⟩ = A' ⊗ A'ᵀ`` over
the boolean adjacency with the plus accumulator exposed (the ⟨plus,and⟩
semiring): ``C[u,v] = Σ_w A'[u,w] ∧ A'[v,w] = |N'(u) ∩ N'(v)|``, so
every triangle is counted exactly once at its mask edge. The product
dispatches through the ``"mxm"`` registry op of ``repro.linalg`` on
both backends — the row-tiled dot-formulation SpGEMM whose expansion
runs on the "advance" hot path (LB row tiling) and whose probe is the
segment-search kernel, i.e. the algebraic reading of the old segmented
intersection.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import linalg
from repro.analysis import sanitize

from .. import backend as B
from ..graph import Graph, edge_list, from_edge_list


class TCResult(NamedTuple):
    total: jax.Array          # () int32 global triangle count
    per_edge: jax.Array       # (m',) per-oriented-edge counts
    edge_src: np.ndarray      # (m',) oriented edge sources (host)
    edge_dst: np.ndarray      # (m',) oriented edge dsts (host)


@jax.jit
def _tc_total(counts: jax.Array) -> jax.Array:
    """Jitted reduction tail — TC's mxm plans capacity host-side (it
    cannot be jitted whole), so the retrace probe lives here: one fixed
    oriented-edge count → one trace."""
    sanitize.trace_probe("tc")   # compile counter: runs on cache miss only
    return jnp.sum(counts, dtype=jnp.int32)


def _orient(graph: Graph) -> tuple[Graph, np.ndarray, np.ndarray]:
    """Filter stage: orient each undirected edge high→low (deg, id)."""
    src, dst = edge_list(graph)
    ro = np.asarray(graph.row_offsets)
    deg = np.diff(ro)
    keep = (deg[src] > deg[dst]) | ((deg[src] == deg[dst]) & (src > dst))
    fsrc, fdst = src[keep], dst[keep]
    sub = from_edge_list(fsrc, fdst, n=graph.num_vertices, undirected=False,
                         build_csc=False, deduplicate=False,
                         remove_self_loops=False)
    ssrc, sdst = edge_list(sub)
    return sub, ssrc, sdst


def triangle_count(graph: Graph, *, backend: Optional[str] = None,
                   use_kernel: Optional[bool] = None,
                   telemetry: bool = False):
    """Exact TC via ``C⟨G'⟩ = G' ⊗ G'ᵀ`` over ⟨plus,and⟩. The graph must
    be undirected (both edge directions present), with sorted neighbor
    lists (from_edge_list guarantees). ``telemetry=True`` returns
    ``(TCResult, TelemetryBuffer)`` — TC is single-shot (no BSP loop),
    so the trajectory is one row recording the oriented workload; the
    kwarg exists so all six primitives share the telemetry contract."""
    bk = B.resolve(backend, use_kernel)
    sub, ssrc, sdst = _orient(graph)
    mp = sub.num_edges
    if mp == 0:
        z = jnp.int32(0)
        result = TCResult(z, jnp.zeros((0,), jnp.int32), ssrc, sdst)
    else:
        counts = linalg.mxm(sub, sub, (ssrc, sdst),
                            semiring=linalg.plus_and,
                            b_transpose=True, structural=True,
                            backend=bk).astype(jnp.int32)
        result = TCResult(total=_tc_total(counts),
                          per_edge=counts, edge_src=ssrc, edge_dst=sdst)
    if telemetry:
        from ...obs.telemetry import TelemetryBuffer
        buf = TelemetryBuffer.make(1, {"oriented_edges": ((), jnp.int32)})
        buf = buf.record(oriented_edges=jnp.int32(mp))
        return result, buf
    return result


def triangle_count_full(graph: Graph, *, backend: Optional[str] = None,
                        use_kernel: Optional[bool] = None) -> jax.Array:
    """Unfiltered variant ('tc-intersection-full' in Fig. 25): the same
    masked SpGEMM over BOTH directions of every edge, divided by 6 — the
    baseline that shows the orientation mask's ~6x workload reduction."""
    bk = B.resolve(backend, use_kernel)
    src, dst = edge_list(graph)
    if graph.num_edges == 0:
        return jnp.int32(0)
    counts = linalg.mxm(graph, graph, (src, dst),
                        semiring=linalg.plus_and, b_transpose=True,
                        structural=True, backend=bk)
    return (jnp.sum(counts).astype(jnp.int32) // 6).astype(jnp.int32)
