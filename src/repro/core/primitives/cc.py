"""Connected components (paper §6.4) — hooking + pointer-jumping
(Soman et al. [72] style) expressed on an edge frontier.

Each outer iteration:
  hooking       — every live edge tries to hook the higher component ID of
                  its endpoints onto the lower one (segment-min scatter —
                  the race the paper notes is resolved by min-reduction).
  filter        — edges whose endpoints now share a component are culled
                  from the edge frontier (Gunrock filter on edges).
  pointer-jump  — component trees are flattened to stars (cid = cid[cid]
                  until fixpoint; log-depth inner while loop).

Converges when the edge frontier is empty.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.analysis import sanitize

from .. import backend as B
from ..enactor import run_until
from ..graph import Graph, edge_list


class CCState(NamedTuple):
    cid: jax.Array       # (n,) int32 component ids
    live: jax.Array      # (m,) bool  edge frontier membership
    n_live: jax.Array    # () int32


class CCResult(NamedTuple):
    labels: jax.Array
    num_components: jax.Array
    iterations: jax.Array


@functools.partial(jax.jit, static_argnames=("telemetry",))
def _cc_impl(graph: Graph, src: jax.Array, telemetry: bool = False):
    sanitize.trace_probe("cc")   # compile counter: body runs only on a jit cache miss
    n, m = graph.num_vertices, graph.num_edges
    # dense decoded view, hoisted once before the loop (the hooking sweep
    # reads every edge every iteration — an in-loop decode would re-run)
    dst = graph.cols()

    def pointer_jump(cid):
        def cond(c):
            return jnp.any(c[c] != c)

        def body(c):
            return c[c]

        return jax.lax.while_loop(cond, body, cid)

    def body(st: CCState):
        cu = st.cid[src]
        cv = st.cid[dst]
        lo = jnp.minimum(cu, cv)
        hi = jnp.maximum(cu, cv)
        live = st.live & (cu != cv)
        # hooking: cid[hi-root] = min(lo) — scatter-min replaces the racy
        # concurrent hook the paper describes
        tgt = jnp.where(live, hi, n)
        cid = st.cid.at[tgt].min(jnp.where(live, lo, jnp.int32(2**30)),
                                 mode="drop")
        cid = pointer_jump(cid)
        # filter: retire edges inside a single component
        still = live & (cid[src] != cid[dst])
        return CCState(cid=cid, live=still,
                       n_live=jnp.sum(still).astype(jnp.int32))

    state = CCState(cid=jnp.arange(n, dtype=jnp.int32),
                    live=jnp.ones((m,), bool), n_live=jnp.int32(m))
    if telemetry:
        # CC's frontier is the live-edge set — its per-iteration size is
        # the convergence trajectory (hooking halves component trees)
        from ...obs.telemetry import TelemetryBuffer
        buf0 = TelemetryBuffer.make(n + 1, {
            "live_edges": ((), jnp.int32)})
        final, iters, buf = run_until(
            lambda st: st.n_live > 0, body, state, max_iter=n + 1,
            probe=lambda prev, new: {"live_edges": new.n_live},
            telemetry=buf0)
    else:
        buf = None
        final, iters = run_until(lambda st: st.n_live > 0, body, state,
                                 max_iter=n + 1)
    ncomp = jnp.sum(final.cid == jnp.arange(n), dtype=jnp.int32)
    result = CCResult(labels=final.cid, num_components=ncomp,
                      iterations=iters)
    return (result, buf) if telemetry else result


def connected_components(graph: Graph, *, backend: Optional[str] = None,
                         telemetry: bool = False):
    """Hooking + pointer-jumping CC. ``backend`` is accepted for a uniform
    primitive interface; CC is pure scatter/segment algebra with no
    dedicated Pallas kernel yet, so the registry resolves both backends to
    the same XLA sweep. ``telemetry=True`` returns
    ``(CCResult, TelemetryBuffer)`` with the per-iteration live-edge
    count; the result is bit-identical to ``telemetry=False``."""
    B.resolve(backend)
    src, _ = edge_list(graph)
    return _cc_impl(graph, jnp.asarray(src, dtype=jnp.int32), telemetry)
