"""PageRank (paper §6.5) — the algebra layer's flagship consumer.

Each iteration is one plus-times SpMV over the CSC transpose
(rank mass flows along reversed edges: ``acc = Aᵀ ⊗ contrib``) plus a
convergence filter that retires settled vertices. The paper implements
the same sweep as an advance with atomicAdd; GraphBLAST's observation —
PR *is* SpMV over the plus-times semiring — is taken literally here:
the contribution sweep dispatches through the ``"spmv"`` registry op of
``repro.linalg`` on BOTH backends (xla: gather + segment-sum, fused by
XLA; pallas: the fused masked-semiring ELL row kernel).

The ELL pack width is static graph metadata computed exactly once at
build time (``Graph.from_csr`` → ``Graph.csc_ell_width``); the impl is
jit-clean end to end — no host synchronization inside the iteration
loop (asserted by a one-trace test in tests/test_linalg.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.linalg import semiring as SR

from .. import backend as B
from ..enactor import run_until
from ..graph import Graph


class PRState(NamedTuple):
    rank: jax.Array       # (n,) float32
    active: jax.Array     # (n,) bool — the frontier (unconverged vertices)
    n_active: jax.Array   # () int32
    iters: jax.Array      # () int32


class PRResult(NamedTuple):
    rank: jax.Array
    iterations: jax.Array


@functools.partial(jax.jit, static_argnames=("max_iter", "backend",
                                             "ell_width"))
def _pagerank_impl(graph: Graph, damping: jax.Array, tol: jax.Array,
                   max_iter: int, backend: str,
                   ell_width: Optional[int]) -> PRResult:
    n = graph.num_vertices
    deg = graph.degrees.astype(jnp.float32)
    spmv_op = B.dispatch("spmv", backend)

    def body(st: PRState):
        contrib = jnp.where(deg > 0, st.rank / jnp.maximum(deg, 1.0), 0.0)
        # acc = Aᵀ ⊗ contrib over plus-times (structural adjacency)
        acc = spmv_op(graph.csc_offsets, graph.csc_indices, None, contrib,
                      SR.plus_times, ell_width, None)
        dangling = jnp.sum(jnp.where(deg == 0, st.rank, 0.0)) / n
        new_rank = (1.0 - damping) / n + damping * (acc + dangling)
        # convergence filter: retire vertices whose rank has settled
        still = jnp.abs(new_rank - st.rank) > tol
        return PRState(rank=new_rank, active=still,
                       n_active=jnp.sum(still).astype(jnp.int32),
                       iters=st.iters + 1)

    state = PRState(rank=jnp.full((n,), 1.0 / n), active=jnp.ones((n,), bool),
                    n_active=jnp.int32(n), iters=jnp.int32(0))
    final, iters = run_until(lambda st: st.n_active > 0, body, state,
                             max_iter=max_iter)
    return PRResult(rank=final.rank, iterations=iters)


def pagerank(graph: Graph, *, damping: float = 0.85, tol: float = 0.0,
             max_iter: int = 20, backend: Optional[str] = None,
             use_kernel: Optional[bool] = None,
             ell_width: Optional[int] = None) -> PRResult:
    assert graph.has_csc, "pagerank uses the CSC transpose"
    bk = B.resolve(backend, use_kernel)
    if ell_width is None:
        # static kernel metadata, computed exactly once at Graph build
        # time (Graph.from_csr) — never recomputed here, so the impl
        # stays synchronization-free on every path
        ell_width = graph.csc_ell_width
    if ell_width is None and bk == B.PALLAS:
        raise ValueError(
            "pagerank on the pallas backend needs Graph.csc_ell_width; "
            "build the Graph via Graph.from_csr / from_edge_list (the "
            "width is computed once at build time) or pass ell_width=")
    return _pagerank_impl(graph, jnp.float32(damping), jnp.float32(tol),
                          max_iter, bk,
                          None if ell_width is None else int(ell_width))
