"""PageRank (paper §6.5) — the algebra layer's flagship consumer.

Each iteration is one plus-times SpMV over the CSC transpose
(rank mass flows along reversed edges: ``acc = Aᵀ ⊗ contrib``) plus a
convergence filter that retires settled vertices. The paper implements
the same sweep as an advance with atomicAdd; GraphBLAST's observation —
PR *is* SpMV over the plus-times semiring — is taken literally here:
the contribution sweep dispatches through the ``"spmv"`` registry op of
``repro.linalg`` on BOTH backends (xla: gather + segment-sum, fused by
XLA; pallas: the fused masked-semiring ELL row kernel).

The ELL pack width is static graph metadata computed exactly once at
build time (``Graph.from_csr`` → ``Graph.csc_ell_width``); the impl is
jit-clean end to end — no host synchronization inside the iteration
loop (asserted by a one-trace test in tests/test_linalg.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.linalg import semiring as SR

from repro.analysis import sanitize

from .. import backend as B
from ..enactor import run_until
from ..graph import Graph


class PRState(NamedTuple):
    rank: jax.Array       # (n,) float32
    active: jax.Array     # (n,) bool — the frontier (unconverged vertices)
    n_active: jax.Array   # () int32
    iters: jax.Array      # () int32


class PRResult(NamedTuple):
    rank: jax.Array
    iterations: jax.Array
    # () bool: ranks settled below tol OR the *requested* sweep count ran
    # to completion; False only when a query budget cut sweeps short
    converged: jax.Array = None


def _fixed_tree_sum(x: jax.Array) -> jax.Array:
    """Float sum with an accumulation grouping fixed by construction:
    explicit pairwise halving, each step one elementwise add. A plain
    ``jnp.sum`` leaves the grouping to per-program codegen, which drifts
    by an ulp between the single-device and shard_map programs; here the
    tree IS the dataflow, so both placements compute identical bits."""
    n = int(x.shape[0])
    k = 1
    while k < n:
        k *= 2
    x = jnp.pad(x, (0, k - n))
    while k > 1:
        k //= 2
        x = x[:k] + x[k:]
    return x[0]


@functools.partial(jax.jit, static_argnames=("max_iter", "backend",
                                             "ell_width", "placement",
                                             "precision", "telemetry",
                                             "full_iter"))
def _pagerank_impl(graph: Graph, inv_deg: jax.Array, damping: jax.Array,
                   tol: jax.Array, max_iter: int, backend: str,
                   ell_width: Optional[int],
                   placement: str = B.SINGLE,
                   precision: str = "fp32",
                   telemetry: bool = False,
                   full_iter: Optional[int] = None):
    sanitize.trace_probe("pagerank")   # compile counter: body runs only on a jit cache miss
    n = graph.num_vertices
    # PageRank's sweep is dense — every row contributes every iteration —
    # so it is explicitly PINNED to the top capacity tier (pin=True); the
    # frontier-proportional tier ladder applies to traversal, not to
    # dense algebra. Sharded placements pin for a second reason:
    # collective shapes must agree across devices.
    spmv_op, _tiers = B.dispatch_tiered("spmv", backend, placement,
                                        cap=n, pin=True)
    # the storage-plan column store when the provider decodes it
    # natively, else the dense fallback view (decoded once, hoisted out
    # of the iteration loop)
    csc = B.storage_arg("spmv", backend, placement, graph=graph,
                        side="csc")
    sr = SR.with_precision(SR.plus_times, precision)

    def body(st: PRState):
        # contribution split: rank × (host-precomputed) reciprocal
        # out-degree. The reciprocal is NOT computed in-loop on purpose:
        # XLA's per-kernel codegen emits an approximate (±1 ulp)
        # division depending on what the op is fused with, and the
        # fusion context differs between a single-device gather sweep
        # and a shard_map call — sharded ranks then drift from
        # single-device ranks. A single IEEE multiply has no such
        # freedom, so placement bit-parity (a tested contract) holds.
        # inv_deg is 0 on dangling vertices, folding the deg>0 guard in.
        contrib = st.rank * inv_deg
        # acc = Aᵀ ⊗ contrib over plus-times (structural adjacency). The
        # CSC edge→row map rides along as build-time metadata so the
        # sweep never re-derives it inside the loop (it was the largest
        # single per-iteration cost of this impl).
        acc = spmv_op(graph.csc_offsets, csc, None, contrib,
                      sr, ell_width, None, graph.csc_row_seg,
                      graph.csc_over_pos, graph.csc_over_row)
        # grouping-fixed sum — see _fixed_tree_sum for why jnp.sum would
        # break placement bit-parity here
        dangling = _fixed_tree_sum(
            jnp.where(inv_deg == 0, st.rank, 0.0)) / n
        new_rank = (1.0 - damping) / n + damping * (acc + dangling)
        # convergence filter: retire vertices whose rank has settled
        still = jnp.abs(new_rank - st.rank) > tol
        return PRState(rank=new_rank, active=still,
                       n_active=jnp.sum(still).astype(jnp.int32),
                       iters=st.iters + 1)

    # float32-pinned: under jax_enable_x64 the bare python literal would
    # seed a float64 rank vector and the whole loop would run (and
    # retrace) in double precision
    state = PRState(rank=jnp.full((n,), 1.0 / n, jnp.float32),
                    active=jnp.ones((n,), bool),
                    n_active=jnp.int32(n), iters=jnp.int32(0))
    # the caller's *requested* sweep count: "converged" means ranks
    # settled OR the requested sweeps all ran — only a budget cutting
    # max_iter below full_iter can make it False
    fi = max_iter if full_iter is None else full_iter

    def _conv(final, iters):
        return (final.n_active == 0) | (iters >= fi)

    if telemetry:
        # per-sweep active (not-yet-converged) vertex count: the dense
        # analogue of a frontier trajectory — with tol=0 it stays n
        # until the final sweep, with tol>0 it charts convergence
        from ...obs.telemetry import TelemetryBuffer
        buf0 = TelemetryBuffer.make(max_iter, {
            "active": ((), jnp.int32)})
        final, iters, buf = run_until(
            lambda st: st.n_active > 0, body, state, max_iter=max_iter,
            probe=lambda prev, new: {"active": new.n_active},
            telemetry=buf0)
        return PRResult(rank=final.rank, iterations=iters,
                        converged=_conv(final, iters)), buf
    final, iters = run_until(lambda st: st.n_active > 0, body, state,
                             max_iter=max_iter)
    return PRResult(rank=final.rank, iterations=iters,
                    converged=_conv(final, iters))


def pagerank(graph, *, damping: float = 0.85, tol: float = 0.0,
             max_iter: int = 20, backend: Optional[str] = None,
             use_kernel: Optional[bool] = None,
             ell_width: Optional[int] = None,
             placement: Optional[str] = None,
             precision: str = "fp32", telemetry: bool = False,
             budget=None):
    """``graph`` may be a ``Graph`` or a ``ShardedGraph``
    (``partition_1d(...).shard(mesh)``) — a sharded graph routes the
    SpMV sweep through the mesh providers and the SAME impl otherwise,
    so ranks bit-match across placements. ``precision="bf16"`` runs the
    sweep's ⊗ in bfloat16 (fp32 accumulate) — ranks then agree with the
    fp32 run to ~1e-2 absolute on a unit-mass vector (the documented
    parity tolerance; see DESIGN.md §8), not bit-exactly.

    ``budget`` (``repro.ft.Budget``) caps the sweep count below
    ``max_iter``: a cut-short run returns the partial ranks with
    ``converged=False``; without a budget the result is bit-identical to
    the historical path."""
    assert graph.has_csc, "pagerank uses the CSC transpose"
    bk = B.resolve(backend, use_kernel)
    pl, ctx = B.resolve_graph_placement(graph, placement)
    if ell_width is None:
        # static kernel metadata, computed exactly once at Graph build
        # time (Graph.from_csr) — never recomputed here, so the impl
        # stays synchronization-free on every path
        ell_width = graph.csc_ell_width
    if ell_width is None and bk == B.PALLAS and pl == B.SINGLE:
        raise ValueError(
            "pagerank on the pallas backend needs Graph.csc_ell_width; "
            "build the Graph via Graph.from_csr / from_edge_list (the "
            "width is computed once at build time) or pass ell_width=")
    effective = max_iter if budget is None else budget.cap_iters(max_iter)
    with ctx:
        return _pagerank_impl(
            graph, _inv_out_degrees(graph), jnp.float32(damping),
            jnp.float32(tol), effective, bk,
            None if ell_width is None else int(ell_width), pl,
            precision, telemetry, full_iter=max_iter)


def _inv_out_degrees(graph) -> jax.Array:
    """Exact host-side reciprocal out-degrees (0 on dangling vertices);
    see the in-loop comment for why the division never happens on
    device. Memoized on the graph instance — the host sync + transfer
    happens once per graph, not once per serving-loop call. (Both graph
    containers are frozen dataclasses; the cache rides ``__dict__``
    outside the pytree fields.)"""
    cached = graph.__dict__.get("_inv_deg")
    if cached is None:
        import numpy as np
        deg = np.asarray(graph.degrees).astype(np.float32)
        inv = np.where(deg > 0, np.float32(1.0) / np.maximum(deg, 1.0),
                       np.float32(0.0)).astype(np.float32)
        cached = jnp.asarray(inv)
        object.__setattr__(graph, "_inv_deg", cached)
    return cached
