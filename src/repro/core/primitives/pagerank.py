"""PageRank (paper §6.5).

The frontier starts with all vertices; each iteration is one advance
(accumulate rank contributions along edges — the paper uses atomicAdd, we
use a segment-sum over the CSC transpose, which XLA turns into the same
dense sweep) plus a filter that retires converged vertices from the
frontier. Iteration stops when every vertex has converged (empty frontier)
or at max_iter.

``use_kernel=True`` routes the contribution sweep through the Pallas CSR
SpMV kernel (the computation is congruent to SpMV, as the paper notes).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..enactor import run_until
from ..graph import Graph


class PRState(NamedTuple):
    rank: jax.Array       # (n,) float32
    active: jax.Array     # (n,) bool — the frontier (unconverged vertices)
    n_active: jax.Array   # () int32
    iters: jax.Array      # () int32


class PRResult(NamedTuple):
    rank: jax.Array
    iterations: jax.Array


@functools.partial(jax.jit, static_argnames=("max_iter", "use_kernel",
                                             "ell_width"))
def _pagerank_impl(graph: Graph, damping: jax.Array, tol: jax.Array,
                   max_iter: int, use_kernel: bool,
                   ell_width: int) -> PRResult:
    n, m = graph.num_vertices, graph.num_edges
    deg = graph.degrees.astype(jnp.float32)
    seg = jnp.searchsorted(graph.csc_offsets,
                           jnp.arange(m, dtype=jnp.int32), side="right") - 1

    def spmv(contrib):
        if use_kernel:
            from repro.kernels import ops as kops
            return kops.csr_spmv(graph.csc_offsets, graph.csc_indices,
                                 contrib, ell_width=ell_width)
        vals = contrib[graph.csc_indices]
        return jax.ops.segment_sum(vals, seg, num_segments=n,
                                   indices_are_sorted=True)

    def body(st: PRState):
        contrib = jnp.where(deg > 0, st.rank / jnp.maximum(deg, 1.0), 0.0)
        acc = spmv(contrib)
        dangling = jnp.sum(jnp.where(deg == 0, st.rank, 0.0)) / n
        new_rank = (1.0 - damping) / n + damping * (acc + dangling)
        # convergence filter: retire vertices whose rank has settled
        still = jnp.abs(new_rank - st.rank) > tol
        return PRState(rank=new_rank, active=still,
                       n_active=jnp.sum(still).astype(jnp.int32),
                       iters=st.iters + 1)

    state = PRState(rank=jnp.full((n,), 1.0 / n), active=jnp.ones((n,), bool),
                    n_active=jnp.int32(n), iters=jnp.int32(0))
    final, iters = run_until(lambda st: st.n_active > 0, body, state,
                             max_iter=max_iter)
    return PRResult(rank=final.rank, iterations=iters)


def pagerank(graph: Graph, *, damping: float = 0.85, tol: float = 0.0,
             max_iter: int = 20, use_kernel: bool = False) -> PRResult:
    assert graph.has_csc, "pagerank uses the CSC transpose"
    ell_width = 1
    if use_kernel:
        import numpy as np
        in_deg = np.diff(np.asarray(graph.csc_offsets))
        ell_width = int(np.percentile(in_deg, 95)) if len(in_deg) else 1
        ell_width = max(min(ell_width, 1024), 1)
    return _pagerank_impl(graph, jnp.float32(damping), jnp.float32(tol),
                          max_iter, use_kernel, ell_width)
