"""PageRank (paper §6.5).

The frontier starts with all vertices; each iteration is one advance
(accumulate rank contributions along edges — the paper uses atomicAdd, we
use a segment-sum over the CSC transpose, which XLA turns into the same
dense sweep) plus a filter that retires converged vertices from the
frontier. Iteration stops when every vertex has converged (empty frontier)
or at max_iter.

``backend="pallas"`` routes the contribution sweep through the Pallas CSR
SpMV kernel (the computation is congruent to SpMV, as the paper notes).
The ELL pack width is static graph metadata computed at build time
(``Graph.csc_ell_width``), so the pallas path is jit-clean end to end —
no host synchronization inside the iteration loop.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .. import backend as B
from ..enactor import run_until
from ..graph import Graph, ell_width_for


class PRState(NamedTuple):
    rank: jax.Array       # (n,) float32
    active: jax.Array     # (n,) bool — the frontier (unconverged vertices)
    n_active: jax.Array   # () int32
    iters: jax.Array      # () int32


class PRResult(NamedTuple):
    rank: jax.Array
    iterations: jax.Array


@functools.partial(jax.jit, static_argnames=("max_iter", "backend",
                                             "ell_width"))
def _pagerank_impl(graph: Graph, damping: jax.Array, tol: jax.Array,
                   max_iter: int, backend: str,
                   ell_width: int) -> PRResult:
    n, m = graph.num_vertices, graph.num_edges
    deg = graph.degrees.astype(jnp.float32)
    seg = jnp.searchsorted(graph.csc_offsets,
                           jnp.arange(m, dtype=jnp.int32), side="right") - 1

    def spmv(contrib):
        if backend == B.PALLAS:
            kernel_spmv = B.dispatch("spmv", backend)
            return kernel_spmv(graph.csc_offsets, graph.csc_indices,
                               contrib, ell_width)
        vals = contrib[graph.csc_indices]
        return jax.ops.segment_sum(vals, seg, num_segments=n,
                                   indices_are_sorted=True)

    def body(st: PRState):
        contrib = jnp.where(deg > 0, st.rank / jnp.maximum(deg, 1.0), 0.0)
        acc = spmv(contrib)
        dangling = jnp.sum(jnp.where(deg == 0, st.rank, 0.0)) / n
        new_rank = (1.0 - damping) / n + damping * (acc + dangling)
        # convergence filter: retire vertices whose rank has settled
        still = jnp.abs(new_rank - st.rank) > tol
        return PRState(rank=new_rank, active=still,
                       n_active=jnp.sum(still).astype(jnp.int32),
                       iters=st.iters + 1)

    state = PRState(rank=jnp.full((n,), 1.0 / n), active=jnp.ones((n,), bool),
                    n_active=jnp.int32(n), iters=jnp.int32(0))
    final, iters = run_until(lambda st: st.n_active > 0, body, state,
                             max_iter=max_iter)
    return PRResult(rank=final.rank, iterations=iters)


def pagerank(graph: Graph, *, damping: float = 0.85, tol: float = 0.0,
             max_iter: int = 20, backend: Optional[str] = None,
             use_kernel: Optional[bool] = None,
             ell_width: Optional[int] = None) -> PRResult:
    assert graph.has_csc, "pagerank uses the CSC transpose"
    bk = B.resolve(backend, use_kernel)
    if ell_width is None:
        # static graph metadata (computed at build time). Only the pallas
        # spmv consumes the width, so only that path pays the host-side
        # fallback for hand-constructed Graphs — still outside jit, so the
        # impl stays synchronization-free.
        ell_width = graph.csc_ell_width
        if ell_width is None:
            if bk == B.PALLAS:
                import numpy as np
                ell_width = ell_width_for(np.diff(np.asarray(
                    graph.csc_offsets)))
            else:
                ell_width = 1
    return _pagerank_impl(graph, jnp.float32(damping), jnp.float32(tol),
                          max_iter, bk, int(ell_width))
