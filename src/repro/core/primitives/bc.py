"""Betweenness centrality (paper §6.3) — Brandes's two-phase formulation,
batched over sources.

Phase 1 (forward): level-synchronous BFS that also accumulates sigma
(shortest-path counts) — an advance identical to BFS plus a compute step
(segment-sum of sigma from settled parents). Phase 2 (backward): iterate
the BFS levels in reverse with an edge-parallel advance accumulating the
dependency deltas (Jia et al. / Sariyüce et al. edge-parallel method, which
is what Gunrock's implementation maps to).

Both phases are whole-edge-list sweeps per level masked by depth — the
BSP/TPU translation of the edge-parallel hardwired kernels. The engine is
*batched*: ``_bc_impl`` runs B Brandes passes at once with a leading batch
axis on every array and per-lane level counters (``run_until_any`` freezes
shallow lanes while deep ones finish — sources have ragged BFS depths).

True BC is a sum over all sources (the paper's flagship multi-source
workload). ``bc(graph)`` with no ``src`` computes it *exactly* by
accumulating batched passes in chunks of ``chunk`` roots:
ceil(n/chunk) invocations of one cached trace, each a (chunk, n) pass,
padded lanes masked to weight 0. ``samples=k`` instead draws k distinct
roots uniformly and scales by n/k (the Brandes-Pich estimator); the same
chunking runs underneath.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitize

from .. import backend as B
from ..enactor import run_until_any
from ..graph import Graph, edge_list


class FwdState(NamedTuple):
    depth: jax.Array     # (B, n) int32
    sigma: jax.Array     # (B, n) float32
    level: jax.Array     # (B,) int32
    n_f: jax.Array       # (B,) int32


class BwdState(NamedTuple):
    delta: jax.Array     # (B, n) float32
    lvl: jax.Array       # (B,) int32


class BCResult(NamedTuple):
    bc: jax.Array        # per-source dependency (single) / accumulated sum
    sigma: jax.Array
    depth: jax.Array
    max_level: jax.Array


class MultiBCResult(NamedTuple):
    bc: jax.Array          # (n,) exact or estimated centrality
    num_sources: jax.Array  # () int32 roots accumulated
    chunks: int            # python int: number of batched passes run


@functools.partial(jax.jit, static_argnames=("telemetry",))
def _bc_impl(graph: Graph, esrc: jax.Array, srcs: jax.Array,
             weights: jax.Array, telemetry: bool = False):
    """B Brandes passes in one program. ``weights`` (B,) scales each
    lane's dependency contribution (0 masks a padding lane)."""
    sanitize.trace_probe("bc")   # compile counter: body runs only on a jit cache miss
    n, m = graph.num_vertices, graph.num_edges
    b = srcs.shape[0]
    edst = graph.cols()
    lane = jnp.arange(b)

    # ---- forward: BFS levels + sigma accumulation -----------------------
    def fwd_body(st: FwdState):
        lvl = st.level
        # edges from the current level into undiscovered territory
        u_on = st.depth[:, esrc] == lvl[:, None]
        v_new = st.depth[:, edst] < 0
        disc = u_on & v_new
        depth = jax.vmap(lambda dp, dc, l1: dp.at[
            jnp.where(dc, edst, n)].set(l1, mode="drop"))(
                st.depth, disc, lvl + 1)
        # sigma flows along all edges u(level) -> v(level+1)
        tree = u_on & (depth[:, edst] == (lvl + 1)[:, None])
        add = jnp.where(tree, st.sigma[:, esrc], 0.0)
        sigma = jax.vmap(lambda sg, tr, ad: sg.at[
            jnp.where(tr, edst, n)].add(ad, mode="drop"))(
                st.sigma, tree, add)
        n_f = jnp.sum(depth == (lvl + 1)[:, None], axis=1,
                      dtype=jnp.int32)
        return FwdState(depth=depth, sigma=sigma, level=lvl + 1, n_f=n_f)

    depth0 = jnp.full((b, n), -1, jnp.int32).at[lane, srcs].set(0)
    sigma0 = jnp.zeros((b, n)).at[lane, srcs].set(1.0)
    fwd0 = FwdState(depth=depth0, sigma=sigma0,
                    level=jnp.zeros((b,), jnp.int32),
                    n_f=jnp.ones((b,), jnp.int32))
    buf = None
    if telemetry:
        # instrument the forward (BFS) phase: its per-level frontier is
        # the trajectory that matters; the backward phase replays the
        # same levels in reverse by construction
        from ...obs.telemetry import TelemetryBuffer
        buf0 = TelemetryBuffer.make(n + 1, {"frontier": ((b,), jnp.int32)})
        fwd, _, _, buf = run_until_any(
            lambda st: st.n_f > 0, fwd_body, fwd0, max_iter=n + 1,
            probe=lambda prev, new: {"frontier": new.n_f},
            telemetry=buf0)
    else:
        fwd, _, _ = run_until_any(
            lambda st: st.n_f > 0, fwd_body, fwd0, max_iter=n + 1)
    max_level = fwd.level  # (B,) one past each lane's deepest level

    # ---- backward: dependency accumulation ------------------------------
    def bwd_body(st: BwdState):
        u_on = fwd.depth[:, esrc] == st.lvl[:, None]
        v_next = fwd.depth[:, edst] == (st.lvl + 1)[:, None]
        tree = u_on & v_next & (fwd.sigma[:, edst] > 0)
        contrib = jnp.where(
            tree,
            fwd.sigma[:, esrc]
            / jnp.maximum(fwd.sigma[:, edst], 1e-30)
            * (1.0 + st.delta[:, edst]), 0.0)
        delta = jax.vmap(lambda dl, tr, co: dl.at[
            jnp.where(tr, esrc, n)].add(co, mode="drop"))(
                st.delta, tree, contrib)
        return BwdState(delta=delta, lvl=st.lvl - 1)

    bwd, _, _ = run_until_any(
        lambda st: st.lvl >= 0, bwd_body,
        BwdState(delta=jnp.zeros((b, n)), lvl=max_level - 1),
        max_iter=n + 1)
    bc_lanes = bwd.delta.at[lane, srcs].set(0.0)
    result = BCResult(bc=(bc_lanes * weights[:, None]).astype(jnp.float32),
                      sigma=fwd.sigma, depth=fwd.depth,
                      max_level=max_level)
    return (result, buf) if telemetry else result


def bc_batch(graph: Graph, srcs, weights=None, *,
             backend: Optional[str] = None, telemetry: bool = False):
    """One batched Brandes pass: lane i holds the per-source dependency
    of ``srcs[i]`` (scaled by ``weights[i]`` if given). ``backend`` is
    accepted for a uniform primitive interface; both phases are
    whole-edge-list sweeps (scatter/segment algebra) with no dedicated
    Pallas kernel yet, so the registry resolves both backends to the
    same XLA sweep. ``telemetry=True`` returns
    ``(BCResult, TelemetryBuffer)`` with the forward phase's per-level
    frontier sizes; the result is bit-identical to
    ``telemetry=False``."""
    B.resolve(backend)
    srcs = jnp.asarray(srcs, dtype=jnp.int32).reshape(-1)
    if weights is None:
        weights = jnp.ones(srcs.shape, jnp.float32)
    esrc, _ = edge_list(graph)
    return _bc_impl(graph, jnp.asarray(esrc, dtype=jnp.int32), srcs,
                    jnp.asarray(weights, jnp.float32), telemetry)


def bc(graph: Graph, src: Optional[int] = None, *, chunk: int = 32,
       samples: Optional[int] = None, seed: int = 0,
       backend: Optional[str] = None, telemetry: bool = False):
    """Betweenness centrality.

    * ``src`` given — one Brandes pass; returns the per-source dependency
      ``BCResult`` (a squeezed batch-of-1 call, like bfs/sssp).
    * ``src=None`` — **exact BC**: accumulate every vertex as a root, in
      batched chunks of ``chunk`` sources (one cached trace, ceil(n/chunk)
      invocations). Returns ``MultiBCResult``.
    * ``samples=k`` — sampled BC: k distinct uniform roots, contributions
      scaled by n/k (unbiased estimator). Returns ``MultiBCResult``.
    """
    if src is not None:
        r = bc_batch(graph, [src], backend=backend, telemetry=telemetry)
        if telemetry:
            res, buf = r
            return jax.tree_util.tree_map(lambda x: x[0], res), buf
        return jax.tree_util.tree_map(lambda x: x[0], r)
    if telemetry:
        raise ValueError("telemetry= is per-pass; pass src= (or use "
                         "bc_batch) to collect a trajectory")
    n = graph.num_vertices
    if samples is None:
        roots = np.arange(n, dtype=np.int32)
        scale = 1.0
    else:
        samples = min(samples, n)
        roots = np.random.default_rng(seed).choice(
            n, size=samples, replace=False).astype(np.int32)
        scale = n / max(samples, 1)
    chunk = max(1, min(chunk, len(roots))) if len(roots) else 1
    B.resolve(backend)
    esrc = jnp.asarray(edge_list(graph)[0], dtype=jnp.int32)  # once
    total = jnp.zeros((n,), jnp.float32)
    chunks = 0
    for lo in range(0, len(roots), chunk):
        sl = roots[lo:lo + chunk]
        pad = chunk - len(sl)
        # fixed (chunk,) shape so every invocation reuses one trace;
        # padding lanes repeat root 0 with weight 0
        srcs = np.concatenate([sl, np.zeros(pad, np.int32)])
        w = np.concatenate([np.full(len(sl), scale, np.float32),
                            np.zeros(pad, np.float32)])
        r = _bc_impl(graph, esrc, jnp.asarray(srcs), jnp.asarray(w))
        total = total + jnp.sum(r.bc, axis=0)
        chunks += 1
    return MultiBCResult(bc=total, num_sources=jnp.int32(len(roots)),
                         chunks=chunks)
