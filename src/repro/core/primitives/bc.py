"""Betweenness centrality (paper §6.3) — Brandes's two-phase formulation.

Phase 1 (forward): level-synchronous BFS that also accumulates sigma
(shortest-path counts) — an advance identical to BFS plus a compute step
(segment-sum of sigma from settled parents). Phase 2 (backward): iterate
the BFS levels in reverse with an edge-parallel advance accumulating the
dependency deltas (Jia et al. / Sariyüce et al. edge-parallel method, which
is what Gunrock's implementation maps to).

Both phases are whole-edge-list sweeps per level masked by depth — the
BSP/TPU translation of the edge-parallel hardwired kernels.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .. import backend as B
from ..enactor import run_until
from ..graph import Graph, edge_list


class FwdState(NamedTuple):
    depth: jax.Array     # (n,) int32
    sigma: jax.Array     # (n,) float32
    level: jax.Array     # () int32
    n_f: jax.Array       # () int32


class BCResult(NamedTuple):
    bc: jax.Array
    sigma: jax.Array
    depth: jax.Array
    max_level: jax.Array


@jax.jit
def _bc_impl(graph: Graph, esrc: jax.Array, src: jax.Array) -> BCResult:
    n, m = graph.num_vertices, graph.num_edges
    edst = graph.col_indices

    # ---- forward: BFS levels + sigma accumulation -----------------------
    def fwd_body(st: FwdState):
        lvl = st.level
        # edges from the current level into undiscovered territory
        u_on = st.depth[esrc] == lvl
        v_new = st.depth[edst] < 0
        disc = u_on & v_new
        depth = st.depth.at[jnp.where(disc, edst, n)].set(lvl + 1,
                                                          mode="drop")
        # sigma flows along all edges u(level) -> v(level+1)
        tree = u_on & (depth[edst] == lvl + 1)
        add = jnp.where(tree, st.sigma[esrc], 0.0)
        sigma = st.sigma.at[jnp.where(tree, edst, n)].add(add, mode="drop")
        n_f = jnp.sum((depth == lvl + 1).astype(jnp.int32))
        return FwdState(depth=depth, sigma=sigma, level=lvl + 1, n_f=n_f)

    depth0 = jnp.full((n,), -1, jnp.int32).at[src].set(0)
    sigma0 = jnp.zeros((n,)).at[src].set(1.0)
    fwd, _ = run_until(lambda st: st.n_f > 0, fwd_body,
                       FwdState(depth=depth0, sigma=sigma0,
                                level=jnp.int32(0), n_f=jnp.int32(1)),
                       max_iter=n + 1)
    max_level = fwd.level  # one past the deepest level

    # ---- backward: dependency accumulation ------------------------------
    def bwd_body(carry):
        delta, lvl = carry
        u_on = fwd.depth[esrc] == lvl
        v_next = fwd.depth[edst] == lvl + 1
        tree = u_on & v_next & (fwd.sigma[edst] > 0)
        contrib = jnp.where(
            tree,
            fwd.sigma[esrc] / jnp.maximum(fwd.sigma[edst], 1e-30)
            * (1.0 + delta[edst]), 0.0)
        delta = delta.at[jnp.where(tree, esrc, n)].add(contrib, mode="drop")
        return delta, lvl - 1

    def bwd_cond(carry):
        _, lvl = carry
        return lvl >= 0

    delta = jnp.zeros((n,))
    (delta, _) = jax.lax.while_loop(bwd_cond, bwd_body,
                                    (delta, max_level - 1))
    bc = delta.at[src].set(0.0)
    return BCResult(bc=bc.astype(jnp.float32), sigma=fwd.sigma,
                    depth=fwd.depth, max_level=max_level)


def bc(graph: Graph, src: int, *, backend: Optional[str] = None) -> BCResult:
    """Brandes BC. ``backend`` is accepted for a uniform primitive
    interface; both phases are whole-edge-list sweeps (scatter/segment
    algebra) with no dedicated Pallas kernel yet, so the registry resolves
    both backends to the same XLA sweep."""
    B.resolve(backend)
    esrc, _ = edge_list(graph)
    return _bc_impl(graph, jnp.asarray(esrc, dtype=jnp.int32),
                    jnp.int32(src))
