from .bfs import bfs
from .sssp import sssp
from .pagerank import pagerank
from .cc import connected_components
from .bc import bc
from .tc import triangle_count
from .wtf import who_to_follow
from .subgraph import subgraph_match

__all__ = ["bfs", "sssp", "pagerank", "connected_components", "bc",
           "triangle_count", "who_to_follow", "subgraph_match"]
