from .bfs import bfs, bfs_batch
from .sssp import sssp, sssp_batch
from .pagerank import pagerank
from .cc import connected_components
from .bc import bc, bc_batch
from .tc import triangle_count
from .label_propagation import label_propagation
from .reach import reach, reach_batch
from .wtf import who_to_follow
from .subgraph import subgraph_match

__all__ = ["bfs", "bfs_batch", "sssp", "sssp_batch", "pagerank",
           "connected_components", "bc", "bc_batch", "triangle_count",
           "label_propagation", "reach", "reach_batch",
           "who_to_follow", "subgraph_match"]
