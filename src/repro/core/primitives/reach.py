"""k-hop reachability — or-and batched over sources (an algebraic BFS).

``reach_batch`` answers B reachability queries against one shared
topology as a single jitted program: the frontier matrix R (n, B) holds
one 0/1 column per source lane, and each hop is one dense-accumulator
SpMM over the or-and semiring through the CSC mirror
(``R'[v, b] = ⋁_u A[u, v] ∧ R[u, b]``), ⊕-merged into R. This is the
linear-algebra reading of ``bfs_batch`` with depths erased — exactly
GraphBLAST's boolean closure — and it exercises the masked product for
real: rows every lane has already reached are masked out of the sweep
(the complement of the all-reached set), which is the algebraic twin of
BFS's visited-set culling.

Batched over sources like ``bfs_batch``: every result field carries a
leading batch axis; the single-source ``reach`` is a squeezed
batch-of-1 call. Oracle: lane b of ``reached`` equals
``0 <= bfs depth <= k``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.linalg import semiring as SR

from .. import backend as B
from ..graph import Graph


class ReachResult(NamedTuple):
    reached: jax.Array    # (B, n) bool — within k hops of srcs[b]
    counts: jax.Array     # (B,) int32 reachable-set sizes
    hops: jax.Array       # () int32 the k that was run
    # () bool: all requested hops ran; False only when a budget clamps k
    converged: jax.Array = None


@functools.partial(jax.jit, static_argnames=("k", "backend", "ell_width",
                                             "placement"))
def _reach_impl(graph: Graph, srcs: jax.Array, k: int, backend: str,
                ell_width: Optional[int],
                placement: str = B.SINGLE) -> ReachResult:
    n = graph.num_vertices
    b = srcs.shape[0]
    spmm_op = B.dispatch("spmm", backend, placement)
    csc = B.storage_arg("spmm", backend, placement, graph=graph,
                        side="csc")
    r0 = jnp.zeros((n, b), jnp.float32).at[
        srcs, jnp.arange(b, dtype=jnp.int32)].set(1.0)

    def hop(_, r):
        # complemented mask: rows already reached by EVERY lane cannot
        # change (R is monotone under ⋁), so skip their sweep entirely
        need = jnp.min(r, axis=1) < 1.0
        new = spmm_op(graph.csc_offsets, csc, None, r,
                      SR.or_and, ell_width, need, graph.csc_row_seg)
        return jnp.maximum(r, new)

    r = jax.lax.fori_loop(0, k, hop, r0)
    reached = r.T > 0
    return ReachResult(reached=reached,
                       counts=jnp.sum(reached, axis=1).astype(jnp.int32),
                       hops=jnp.int32(k),
                       converged=jnp.bool_(True))


def reach_batch(graph, srcs, k: int = 3, *,
                backend: Optional[str] = None,
                use_kernel: Optional[bool] = None,
                placement: Optional[str] = None,
                budget=None) -> ReachResult:
    """B-source k-hop reachability as ONE jitted or-and program.
    ``graph`` may be a ``ShardedGraph`` — each hop's CSC SpMM then runs
    through the sharded registry provider (bit-matching results).
    ``budget`` clamps ``k`` to ``budget.max_iters``: a clamped run
    answers the smaller neighborhood (``hops`` records what actually ran,
    ``converged=False``)."""
    assert graph.has_csc, "reach uses the CSC transpose (pull sweeps)"
    bk = B.resolve(backend, use_kernel)
    pl, ctx = B.resolve_graph_placement(graph, placement)
    ell_width = graph.csc_ell_width
    if ell_width is None and bk == B.PALLAS and pl == B.SINGLE:
        raise ValueError(
            "reach on the pallas backend needs Graph.csc_ell_width; "
            "build the Graph via Graph.from_csr / from_edge_list")
    srcs = jnp.asarray(srcs, jnp.int32).reshape(-1)
    k_eff = int(k) if budget is None else budget.cap_iters(int(k))
    with ctx:
        res = _reach_impl(graph, srcs, k_eff, bk,
                          None if ell_width is None else int(ell_width),
                          pl)
    if k_eff < int(k):
        res = res._replace(converged=jnp.bool_(False))
    return res


def reach(graph: Graph, src: int, k: int = 3, *,
          backend: Optional[str] = None,
          use_kernel: Optional[bool] = None) -> ReachResult:
    """Single-source k-hop reachability — a squeezed batch-of-1 call."""
    r = reach_batch(graph, [src], k, backend=backend, use_kernel=use_kernel)
    return ReachResult(reached=r.reached[0], counts=r.counts[0],
                       hops=r.hops)
