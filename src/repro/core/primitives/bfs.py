"""Breadth-first search (paper §6.1) with the full optimization surface:

  * push advance with LB / TWC / THREAD workload mapping (Fig. 20 ablation)
  * direction-optimized push↔pull switching with do_a/do_b (Fig. 21)
  * idempotent mode: skip exact uniquification, rely on the heuristic
    hash/bitmask culling filter (Fig. 19 ablation)
  * predecessor recording

The whole search is one jitted XLA while-loop (kernel-fusion philosophy).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .. import backend as B
from .. import operators as ops
from ..direction import PULL, PUSH, DirectionParams, decide_direction
from ..enactor import run_until
from ..frontier import DenseFrontier, SparseFrontier, from_ids
from ..graph import Graph


class BFSState(NamedTuple):
    labels: jax.Array        # (n,) int32 depth, -1 unvisited
    preds: jax.Array         # (n,) int32 predecessor, -1 none
    frontier: SparseFrontier  # sparse rep (push)
    dense: jax.Array         # (n,) bool current frontier bitmap (pull)
    visited: jax.Array       # (n,) bool status-check array (§5.2.1)
    n_f: jax.Array           # () int32 current frontier size
    n_u: jax.Array           # () int32 unvisited count
    depth: jax.Array         # () int32
    mode: jax.Array          # () int32 PUSH/PULL
    pull_iters: jax.Array    # () int32 (for characterization)


class BFSResult(NamedTuple):
    labels: jax.Array
    preds: jax.Array
    iterations: jax.Array
    pull_iters: jax.Array
    edges_visited: jax.Array


@functools.partial(jax.jit, static_argnames=(
    "direction", "idempotence", "strategy", "record_preds", "backend"))
def _bfs_impl(graph: Graph, src: jax.Array, do_a: float, do_b: float,
              direction: bool, idempotence: bool, strategy: str,
              record_preds: bool, backend: str) -> BFSResult:
    n, m = graph.num_vertices, graph.num_edges
    # frontier buffers are edge-capacity: pre-uniquify frontiers hold
    # duplicates (idempotent mode keeps them on purpose), so a vertex-
    # capacity buffer could silently drop discoveries (paper: frontiers
    # are sized by worst-case expansion)
    cap_v = m
    cap_e = m
    params = DirectionParams(do_a=do_a, do_b=do_b, enabled=direction)

    labels = jnp.full((n,), -1, jnp.int32).at[src].set(0)
    preds = jnp.full((n,), -1, jnp.int32)
    visited = jnp.zeros((n,), bool).at[src].set(True)
    frontier = from_ids(src[None], cap_v)
    state = BFSState(labels=labels, preds=preds, frontier=frontier,
                     dense=visited, visited=visited,
                     n_f=jnp.int32(1), n_u=jnp.int32(n - 1),
                     depth=jnp.int32(0), mode=PUSH,
                     pull_iters=jnp.int32(0))

    def push_step(st: BFSState):
        depth1 = st.depth + 1

        def functor(s, d, e, rank, valid, data):
            # cond functor: discover unvisited destinations
            unseen = ~data["visited"][jnp.where(valid, d, 0)]
            return valid & unseen, data

        res, _ = ops.advance(graph, st.frontier, cap_e, functor=functor,
                             data={"visited": st.visited}, strategy=strategy,
                             backend=backend)
        # apply: set depth (idempotent write — same value for all dups,
        # so no atomics are needed; paper §5.2.1)
        tgt = jnp.where(res.valid, res.dst, n)   # n = out of bounds → drop
        labels = st.labels.at[tgt].set(depth1, mode="drop")
        if record_preds:
            preds = st.preds.at[tgt].set(res.src, mode="drop")
        else:
            preds = st.preds
        visited = ops.scatter_or(res.dst, res.valid, st.visited)
        new_frontier = ops.advance_to_vertex_frontier(res, cap_v,
                                                      backend=backend)
        # contract: uniquify (exact unless idempotent mode; idempotent mode
        # uses the cheap hash-culling heuristic and tolerates leftover dups)
        uniq = "hash" if idempotence else "exact"
        new_frontier, _ = ops.filter_frontier(new_frontier, n=n,
                                              uniquify=uniq, cap=cap_v,
                                              backend=backend)
        return st._replace(labels=labels, preds=preds, frontier=new_frontier,
                           dense=visited, visited=visited,
                           n_f=new_frontier.length,
                           n_u=st.n_u - new_frontier.length, depth=depth1)

    def pull_step(st: BFSState):
        depth1 = st.depth + 1
        current = DenseFrontier(st.dense)
        unvisited = DenseFrontier(~st.visited)
        new_dense, pull_preds = ops.advance_pull(graph, unvisited, current,
                                                 return_preds=True)
        labels = jnp.where(new_dense.flags, depth1, st.labels)
        preds = (jnp.where(new_dense.flags, pull_preds, st.preds)
                 if record_preds else st.preds)
        visited = st.visited | new_dense.flags
        n_new = new_dense.length.astype(jnp.int32)
        sparse = new_dense.to_sparse(cap_v, backend=backend)
        return st._replace(labels=labels, preds=preds, frontier=sparse,
                           dense=new_dense.flags, visited=visited,
                           n_f=n_new, n_u=st.n_u - n_new, depth=depth1,
                           pull_iters=st.pull_iters + 1)

    def body(st: BFSState):
        mode = decide_direction(st.mode, st.n_f, st.n_u, n, m, params)
        st = st._replace(mode=mode)
        if not direction:
            return push_step(st)
        # dense rep of the *current* frontier is required by pull; push_step
        # keeps `dense` = visited, so rebuild it from the sparse frontier.
        dense_cur = st.frontier.to_dense(n).flags
        st = st._replace(dense=dense_cur)
        return jax.lax.cond(mode == PULL, pull_step, push_step, st)

    final, iters = run_until(lambda st: st.n_f > 0, body, state,
                             max_iter=n + 1)
    edges = jnp.sum(jnp.where(final.labels >= 0,
                              graph.degrees, 0)).astype(jnp.int32)
    return BFSResult(labels=final.labels, preds=final.preds,
                     iterations=iters, pull_iters=final.pull_iters,
                     edges_visited=edges)


def bfs(graph: Graph, src: int, *, direction: bool = True,
        do_a: float = 0.001, do_b: float = 0.2, idempotence: bool = True,
        strategy: str = "LB", record_preds: bool = True,
        backend: Optional[str] = None,
        use_kernel: Optional[bool] = None) -> BFSResult:
    """Run BFS from ``src``. See module docstring for options.

    ``backend`` selects the operator backend ("xla" | "pallas" | "auto";
    None defers to the ambient context / REPRO_BACKEND). Resolved here,
    outside jit, and passed down as a static argument."""
    if direction and not graph.has_csc:
        direction = False
    return _bfs_impl(graph, jnp.int32(src), do_a, do_b, direction,
                     idempotence, strategy, record_preds,
                     B.resolve(backend, use_kernel))
