"""Breadth-first search (paper §6.1) with the full optimization surface:

  * push advance with LB / TWC / THREAD workload mapping (Fig. 20 ablation)
  * direction-optimized push↔pull switching with do_a/do_b (Fig. 21)
  * idempotent mode: skip exact uniquification, rely on the heuristic
    hash/bitmask culling filter (Fig. 19 ablation)
  * predecessor recording

The LB push is the fused tiered path: one "advance_filter" dispatch per
iteration (expansion + visited test + exact first-occurrence culling +
compaction in a single op — paper §5.3's fusion applied to the whole
step), run at the smallest power-of-two capacity tier that holds the
frontier's degree sum (``enactor.tiered_step``), so an iteration's cost
tracks the live frontier instead of worst-case m. In-op culling is
exact for free (the bitmap is already in hand), which makes
``idempotence`` moot there; the flag keeps selecting hash-vs-exact
uniquify on the unfused TWC/THREAD ablation path.

The engine is *multi-source*: ``bfs_batch`` runs B traversals over one
shared topology as a single jitted batched BSP loop (the frontier-matrix
view — GraphBLAST's multi-source BFS), with per-lane convergence masking
in ``run_until_any`` so ragged lanes freeze as they finish. The
single-source ``bfs`` is a squeezed batch-of-1 call — one code path.

Frontier capacities: edge frontiers (the raw advance output) are sized at
m, but vertex frontiers are *post-uniquify* and need only min(n, m) slots.
Heuristic uniquification (idempotent mode) can leave more duplicates than
that; the per-lane ``overflow`` counter in ``BFSResult`` records any
discoveries dropped by the clamp so capped runs are detectable instead of
silent (a nonzero count means rerun with ``idempotence=False``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.analysis import sanitize

from .. import backend as B
from .. import operators as ops
from ..direction import PULL, PUSH, DirectionParams, decide_direction
from ..enactor import run_until_any, select_lanes, tiered_step
from ..frontier import (BatchedDenseFrontier, BatchedSparseFrontier,
                        from_ids_batch)
from ..graph import Graph


class BFSState(NamedTuple):
    labels: jax.Array        # (B, n) int32 depth, -1 unvisited
    preds: jax.Array         # (B, n) int32 predecessor, -1 none
    frontier: BatchedSparseFrontier  # sparse rep (push), (B, cap_v)
    dense: jax.Array         # (B, n) bool current frontier bitmap (pull)
    visited: jax.Array       # (B, n) bool status-check array (§5.2.1)
    n_f: jax.Array           # (B,) int32 current frontier size
    n_u: jax.Array           # (B,) int32 unvisited count
    depth: jax.Array         # (B,) int32
    mode: jax.Array          # (B,) int32 PUSH/PULL
    pull_iters: jax.Array    # (B,) int32 (for characterization)
    overflow: jax.Array      # (B,) int32 discoveries dropped by cap_v clamp


class BFSResult(NamedTuple):
    labels: jax.Array
    preds: jax.Array
    iterations: jax.Array
    pull_iters: jax.Array
    edges_visited: jax.Array
    overflow: jax.Array
    # (B,) bool: lane's frontier drained (False = an iteration budget cut
    # the traversal short and labels are partial). Defaults keep older
    # construction sites valid.
    converged: jax.Array = None


@functools.partial(jax.jit, static_argnames=(
    "direction", "idempotence", "strategy", "record_preds", "backend",
    "tiered", "telemetry", "max_iters"))
def _bfs_impl(graph: Graph, srcs: jax.Array, do_a: float, do_b: float,
              direction: bool, idempotence: bool, strategy: str,
              record_preds: bool, backend: str,
              tiered: bool = True, telemetry: bool = False,
              max_iters: Optional[int] = None):
    sanitize.trace_probe("bfs")   # compile counter: body runs only on a jit cache miss
    n, m = graph.num_vertices, graph.num_edges
    b = srcs.shape[0]
    # edge frontiers are worst-case expansion (m); vertex frontiers are
    # post-uniquify and need only min(n, m) — overflow past that is
    # counted per lane instead of silently sized away. The floor of 1
    # keeps the seed frontier representable on an edgeless graph.
    cap_v = max(min(n, m), 1)
    cap_e = m
    # LB push runs the fused advance_filter over a capacity-tier ladder:
    # each iteration expands in the smallest tier holding its live
    # workload (the frontier's degree sum) instead of worst-case cap_e.
    # Tier choice never changes results — tested bit-exact against the
    # pinned top tier (tiered=False). TWC/THREAD keep the unfused
    # ablation path at full capacity.
    caps_e = (B.tier_plan("advance_filter", cap_e)
              if (tiered and strategy == "LB" and cap_e > 0) else
              (max(cap_e, 1),))
    params = DirectionParams(do_a=do_a, do_b=do_b, enabled=direction)

    lane = jnp.arange(b)
    labels = jnp.full((b, n), -1, jnp.int32).at[lane, srcs].set(0)
    preds = jnp.full((b, n), -1, jnp.int32)
    visited = jnp.zeros((b, n), bool).at[lane, srcs].set(True)
    frontier = from_ids_batch(srcs, cap_v)
    state = BFSState(labels=labels, preds=preds, frontier=frontier,
                     dense=visited, visited=visited,
                     n_f=jnp.ones((b,), jnp.int32),
                     n_u=jnp.full((b,), n - 1, jnp.int32),
                     depth=jnp.zeros((b,), jnp.int32),
                     mode=jnp.full((b,), PUSH),
                     pull_iters=jnp.zeros((b,), jnp.int32),
                     overflow=jnp.zeros((b,), jnp.int32))

    def fused_push_at(cap_t: int):
        """LB push at one capacity tier: the fused advance_filter does
        expansion, visited test, exact first-occurrence culling and
        compaction in one dispatch — the (cap_t,) edge tuple never
        escapes the op, and every scatter below is frontier-shaped
        (cap_v), not edge-shaped (cap_e)."""

        def push_step(st: BFSState):
            depth1 = st.depth + 1
            new_frontier, srcs, totals = ops.advance_filter_batch(
                graph, st.frontier, st.visited, cap_t, cap_front=cap_v,
                backend=backend)
            ids = new_frontier.ids
            tgt = jnp.where(ids >= 0, ids, n)    # n = out of bounds → drop
            # apply: set depth (one surviving slot per discovery, so the
            # scatters are conflict-free; paper §5.2.1)
            labels = jax.vmap(
                lambda l, t, d1: l.at[t].set(d1, mode="drop"))(
                    st.labels, tgt, depth1)
            if record_preds:
                preds = jax.vmap(
                    lambda p, t, s: p.at[t].set(s, mode="drop"))(
                        st.preds, tgt, srcs)
            else:
                preds = st.preds
            visited = jax.vmap(
                lambda v, t: v.at[t].set(True, mode="drop"))(
                    st.visited, tgt)
            # exact culling can never exceed the min(n, m) vertex
            # frontier; the counter stays for the state contract
            ovf = jnp.maximum(totals - new_frontier.lengths, 0)
            return st._replace(labels=labels, preds=preds,
                               frontier=new_frontier, dense=visited,
                               visited=visited,
                               n_f=new_frontier.lengths,
                               n_u=st.n_u - new_frontier.lengths,
                               depth=depth1, overflow=st.overflow + ovf)

        return push_step

    def legacy_push_step(st: BFSState):
        # TWC/THREAD ablation path: unfused advance → filter with the
        # idempotence-selected uniquify, at full capacity
        depth1 = st.depth + 1

        def functor(s, d, e, rank, valid, data):
            # cond functor: discover unvisited destinations (single-lane
            # signature — advance_batch vmaps it over the batch axis)
            unseen = ~data["visited"][jnp.where(valid, d, 0)]
            return valid & unseen, data

        res, _ = ops.advance_batch(graph, st.frontier, cap_e,
                                   functor=functor,
                                   data={"visited": st.visited},
                                   strategy=strategy, backend=backend)
        # apply: set depth (idempotent write — same value for all dups,
        # so no atomics are needed; paper §5.2.1)
        tgt = jnp.where(res.valid, res.dst, n)   # n = out of bounds → drop
        labels = jax.vmap(lambda l, t, d1: l.at[t].set(d1, mode="drop"))(
            st.labels, tgt, depth1)
        if record_preds:
            preds = jax.vmap(lambda p, t, s: p.at[t].set(s, mode="drop"))(
                st.preds, tgt, res.src)
        else:
            preds = st.preds
        visited = jax.vmap(ops.scatter_or)(res.dst, res.valid, st.visited)
        # contract: compact the full expansion, then uniquify down into
        # the cap_v vertex frontier (exact unless idempotent mode;
        # idempotent mode uses the cheap hash-culling heuristic, whose
        # leftover duplicates are the only way to overflow cap_v)
        wide = ops.advance_to_vertex_frontier_batch(res, cap_e,
                                                    backend=backend)
        uniq = "hash" if idempotence else "exact"
        new_frontier, _, ovf = ops.filter_frontier_batch(
            wide, n=n, uniquify=uniq, cap=cap_v, backend=backend)
        return st._replace(labels=labels, preds=preds,
                           frontier=new_frontier, dense=visited,
                           visited=visited, n_f=new_frontier.lengths,
                           n_u=st.n_u - new_frontier.lengths, depth=depth1,
                           overflow=st.overflow + ovf)

    def push_step(st: BFSState):
        if strategy != "LB":
            return legacy_push_step(st)
        need = jnp.max(ops.frontier_workload(graph, st.frontier))
        return tiered_step(need, caps_e, fused_push_at, st)

    def pull_step(st: BFSState):
        depth1 = st.depth + 1
        current = BatchedDenseFrontier(st.dense)
        unvisited = BatchedDenseFrontier(~st.visited)
        new_dense, pull_preds = ops.advance_pull_batch(
            graph, unvisited, current, return_preds=True)
        labels = jnp.where(new_dense.flags, depth1[:, None], st.labels)
        preds = (jnp.where(new_dense.flags, pull_preds, st.preds)
                 if record_preds else st.preds)
        visited = st.visited | new_dense.flags
        n_new = new_dense.lengths
        sparse = new_dense.to_sparse(cap_v, backend=backend)
        return st._replace(labels=labels, preds=preds, frontier=sparse,
                           dense=new_dense.flags, visited=visited,
                           n_f=n_new, n_u=st.n_u - n_new, depth=depth1,
                           pull_iters=st.pull_iters + 1)

    def body(st: BFSState):
        if not direction:
            return push_step(st)
        mode = jax.vmap(
            lambda md, nf, nu: decide_direction(md, nf, nu, n, m, params)
        )(st.mode, st.n_f, st.n_u)
        st = st._replace(mode=mode)
        # dense rep of the *current* frontier is required by pull;
        # push_step keeps `dense` = visited, so rebuild it.
        dense_cur = st.frontier.to_dense(n).flags
        st = st._replace(dense=dense_cur)
        if b == 1:
            # batch-of-1 (the single-source path): a real branch, so the
            # idle direction costs nothing
            return jax.lax.cond(mode[0] == PULL, pull_step, push_step, st)

        def mixed_step(st):
            # lanes disagree: compute both directions in lockstep and
            # select per lane
            return select_lanes(mode == PULL, pull_step(st), push_step(st))

        # direction decisions correlate strongly across lanes (shared
        # topology), so branch on the homogeneous cases and pay the
        # both-directions mixed step only when lanes actually disagree.
        # Converged lanes are frozen by run_until_any whatever we compute
        # for them, so only *active* lanes count toward homogeneity.
        active = st.n_f > 0
        return jax.lax.cond(
            jnp.all(~active | (mode == PUSH)), push_step,
            lambda s2: jax.lax.cond(jnp.all(~active | (mode == PULL)),
                                    pull_step, mixed_step, s2),
            st)

    # a query budget just lowers the loop guard — the loop stays
    # jit-clean and lanes still running at the cap come back partial
    mi = n + 1 if max_iters is None else min(n + 1, max_iters)
    buf = None
    if telemetry:
        # read-only probe: per-lane frontier size / direction / overflow
        # delta after each step, plus the tier rung the step's workload
        # selected (recomputed from the prev frontier — XLA CSEs it
        # against the dispatch in push_step, so it costs nothing).
        from ...obs.telemetry import TelemetryBuffer
        from ..frontier import tier_index
        caps_arr = jnp.asarray(caps_e, jnp.int32)

        def probe(prev: BFSState, new: BFSState) -> dict:
            need = jnp.max(ops.frontier_workload(graph, prev.frontier))
            tier = caps_arr[tier_index(need, caps_e)]
            return {"frontier": new.n_f, "tier": tier,
                    "direction": new.mode,
                    "overflow": new.overflow - prev.overflow}

        buf0 = TelemetryBuffer.make(n + 1, {
            "frontier": ((b,), jnp.int32),
            "tier": ((), jnp.int32),
            "direction": ((b,), jnp.int32),
            "overflow": ((b,), jnp.int32)})
        final, lane_iters, _, buf = run_until_any(
            lambda st: st.n_f > 0, body, state, max_iter=mi,
            probe=probe, telemetry=buf0)
    else:
        final, lane_iters, _ = run_until_any(lambda st: st.n_f > 0, body,
                                             state, max_iter=mi)
    edges = jnp.sum(jnp.where(final.labels >= 0,
                              graph.degrees[None, :], 0),
                    axis=1).astype(jnp.int32)
    result = BFSResult(labels=final.labels, preds=final.preds,
                       iterations=lane_iters, pull_iters=final.pull_iters,
                       edges_visited=edges, overflow=final.overflow,
                       converged=final.n_f == 0)
    return (result, buf) if telemetry else result


def bfs_batch(graph: Graph, srcs, *, direction: bool = True,
              do_a: float = 0.001, do_b: float = 0.2,
              idempotence: bool = True, strategy: str = "LB",
              record_preds: bool = True,
              backend: Optional[str] = None,
              tiered: bool = True, telemetry: bool = False,
              budget=None):
    """Multi-source BFS: one jitted batched BSP loop over ``srcs``.

    Every ``BFSResult`` field carries a leading batch axis; lane i is
    bit-identical to ``bfs(graph, srcs[i])``. All lanes share one trace —
    batches of the same size never retrace, which is the contract the
    query-serving driver (launch/graph_serve.py) relies on.

    ``tiered=False`` pins every push to the top capacity tier (the
    worst-case-sized program) — results are bit-identical to the tiered
    default; the flag exists for the tier-parity tests and A/B
    benchmarking.

    ``telemetry=True`` returns ``(BFSResult, TelemetryBuffer)`` — the
    buffer holds per-iteration frontier size / tier / direction /
    overflow columns (``obs.telemetry.trim`` converts to host arrays);
    the result itself is bit-identical to ``telemetry=False``.

    ``budget`` (``repro.ft.Budget``) caps BSP iterations per query: lanes
    cut short come back with partial labels and ``converged=False``; the
    wall-clock half of the budget is the serving loop's job. ``budget=None``
    (or an unlimited budget) is bit-identical to the historical path."""
    if direction and not graph.has_csc:
        direction = False
    srcs = jnp.asarray(srcs, dtype=jnp.int32).reshape(-1)
    max_iters = None if budget is None else budget.max_iters
    return _bfs_impl(graph, srcs, do_a, do_b, direction, idempotence,
                     strategy, record_preds, B.resolve(backend),
                     tiered, telemetry, max_iters)


def bfs(graph: Graph, src: int, *, direction: bool = True,
        do_a: float = 0.001, do_b: float = 0.2, idempotence: bool = True,
        strategy: str = "LB", record_preds: bool = True,
        backend: Optional[str] = None,
        use_kernel: Optional[bool] = None, telemetry: bool = False):
    """Run BFS from ``src`` — a squeezed batch-of-1 ``bfs_batch`` call.

    ``backend`` selects the operator backend ("xla" | "pallas" | "auto";
    None defers to the ambient context / REPRO_BACKEND). ``use_kernel``
    is the deprecated alias (public wrapper only) and always warns.
    ``telemetry=True`` returns ``(BFSResult, TelemetryBuffer)`` with the
    result squeezed but the buffer keeping its lane axis (lane 0)."""
    r = bfs_batch(graph, [src], direction=direction, do_a=do_a, do_b=do_b,
                  idempotence=idempotence, strategy=strategy,
                  record_preds=record_preds,
                  backend=B.resolve(backend, use_kernel),
                  telemetry=telemetry)
    if telemetry:
        res, buf = r
        return jax.tree_util.tree_map(lambda x: x[0], res), buf
    return jax.tree_util.tree_map(lambda x: x[0], r)
