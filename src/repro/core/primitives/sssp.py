"""Single-source shortest path (paper §6.2, Algorithm 1).

Delta-stepping [Davidson et al. / Meyer-Sanders] via Gunrock's two-level
priority queue (§5.1.5): each iteration advances the *near* frontier,
relaxes distances with a segment-min (the atomicMin replacement), filters
redundant discoveries, and splits the improved set into near/far piles by
the current bucket threshold. When the near pile drains, the bucket index
advances and the far pile is re-split.

``delta=None`` selects Bellman-Ford mode (everything is near — the
baseline the paper compares against via Ligra).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .. import backend as B
from .. import operators as ops
from ..enactor import run_until
from ..frontier import DenseFrontier, SparseFrontier, from_ids
from ..graph import Graph

INF = jnp.float32(jnp.inf)


class SSSPState(NamedTuple):
    dist: jax.Array       # (n,) float32
    preds: jax.Array      # (n,) int32
    near: jax.Array       # (n,) bool  near-pile membership mask
    far: jax.Array        # (n,) bool  far-pile membership mask
    bucket: jax.Array     # () int32   current priority level
    n_near: jax.Array     # () int32
    relaxations: jax.Array  # () int32 total edge relaxations (work measure)


class SSSPResult(NamedTuple):
    dist: jax.Array
    preds: jax.Array
    iterations: jax.Array
    relaxations: jax.Array


@functools.partial(jax.jit, static_argnames=("use_delta", "strategy",
                                             "backend"))
def _sssp_impl(graph: Graph, src: jax.Array, delta: jax.Array,
               use_delta: bool, strategy: str,
               backend: str) -> SSSPResult:
    n, m = graph.num_vertices, graph.num_edges
    dist = jnp.full((n,), INF).at[src].set(0.0)
    preds = jnp.full((n,), -1, jnp.int32)
    near = jnp.zeros((n,), bool).at[src].set(True)
    state = SSSPState(dist=dist, preds=preds, near=near,
                      far=jnp.zeros((n,), bool), bucket=jnp.int32(0),
                      n_near=jnp.int32(1), relaxations=jnp.int32(0))

    def relax_step(st: SSSPState):
        frontier = DenseFrontier(st.near).to_sparse(n, backend=backend)

        def functor(s, d, e, rank, valid, data):
            return valid, data

        res, _ = ops.advance(graph, frontier, m, functor=functor,
                             strategy=strategy, backend=backend)
        w = graph.edge_values[jnp.where(res.valid, res.edge_id, 0)]
        cand = st.dist[jnp.where(res.valid, res.src, 0)] + w
        # atomicMin replacement: segment-min into dist (paper Update_Label)
        new_dist = ops.scatter_min(cand, res.dst, res.valid, st.dist)
        improved = new_dist < st.dist
        # Set_Pred: the winning edge writes the predecessor
        winner = res.valid & (cand <= new_dist[jnp.where(res.valid, res.dst, 0)])
        preds = st.preds.at[jnp.where(winner, res.dst, n)].set(
            res.src, mode="drop")
        # priority-queue split (near/far) on the improved vertices
        thresh = (st.bucket.astype(jnp.float32) + 1.0) * delta
        if use_delta:
            add_near = improved & (new_dist < thresh)
            add_far = improved & (new_dist >= thresh)
        else:
            add_near = improved
            add_far = jnp.zeros_like(improved)
        # vertices stay in far until their bucket comes up; improved ones
        # migrate piles according to their *new* distance
        far = (st.far | add_far) & ~add_near
        relax = st.relaxations + res.total
        return st._replace(dist=new_dist, preds=preds, near=add_near,
                           far=far, n_near=jnp.sum(add_near).astype(jnp.int32),
                           relaxations=relax)

    def pop_far(st: SSSPState):
        # near pile empty: advance the bucket to the smallest far distance
        far_min = jnp.min(jnp.where(st.far, st.dist, INF))
        new_bucket = jnp.where(jnp.isfinite(far_min),
                               (far_min / delta).astype(jnp.int32),
                               st.bucket + 1)
        thresh = (new_bucket.astype(jnp.float32) + 1.0) * delta
        near = st.far & (st.dist < thresh)
        far = st.far & ~near
        return st._replace(near=near, far=far, bucket=new_bucket,
                           n_near=jnp.sum(near).astype(jnp.int32))

    def body(st: SSSPState):
        return jax.lax.cond(st.n_near > 0, relax_step, pop_far, st)

    def cond(st: SSSPState):
        return (st.n_near > 0) | jnp.any(st.far)

    final, iters = run_until(cond, body, state, max_iter=4 * n + 8)
    return SSSPResult(dist=final.dist, preds=final.preds, iterations=iters,
                      relaxations=final.relaxations)


def sssp(graph: Graph, src: int, *, delta: Optional[float] = None,
         strategy: str = "LB", backend: Optional[str] = None,
         use_kernel: Optional[bool] = None) -> SSSPResult:
    """Delta-stepping SSSP; ``delta=None`` = auto (avg weight × avg degree
    heuristic from Davidson et al.), ``delta=inf``-like big → Bellman-Ford."""
    assert graph.weighted, "SSSP needs edge weights"
    if delta is None:
        mean_w = float(jnp.mean(graph.edge_values))
        avg_deg = max(graph.num_edges / max(graph.num_vertices, 1), 1.0)
        delta = mean_w * avg_deg / 2.0
    use_delta = bool(jnp.isfinite(delta)) and delta > 0
    return _sssp_impl(graph, jnp.int32(src), jnp.float32(delta), use_delta,
                      strategy, B.resolve(backend, use_kernel))


def sssp_bellman_ford(graph: Graph, src: int, **kw) -> SSSPResult:
    """Bellman-Ford-style full relaxation (the Ligra comparison baseline)."""
    big = 1e30
    return _sssp_impl(graph, jnp.int32(src), jnp.float32(big), False,
                      kw.get("strategy", "LB"),
                      B.resolve(kw.get("backend"), kw.get("use_kernel")))
