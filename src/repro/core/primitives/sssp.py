"""Single- and multi-source shortest path (paper §6.2, Algorithm 1).

Delta-stepping [Davidson et al. / Meyer-Sanders] via Gunrock's two-level
priority queue (§5.1.5): each iteration advances the *near* frontier,
relaxes distances with a segment-min (the atomicMin replacement), filters
redundant discoveries, and splits the improved set into near/far piles by
the current bucket threshold. When the near pile drains, the bucket index
advances and the far pile is re-split.

``sssp_batch`` runs B sources as one jitted batched BSP loop: every lane
keeps its own near/far piles and bucket counter, each step computes the
relax and the bucket-pop for all lanes in lockstep and selects per lane
(the pop is a cheap mask split, so idle-direction work is negligible),
and ``run_until_any`` freezes converged lanes until the stragglers drain.
``sssp`` is a squeezed batch-of-1 call — one code path.

``delta=None`` selects the auto heuristic; a huge delta degenerates to
Bellman-Ford mode (everything is near — the Ligra comparison baseline).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.analysis import sanitize

from .. import backend as B
from .. import operators as ops
from ..enactor import run_until_any, select_lanes, tiered_step
from ..frontier import BatchedDenseFrontier
from ..graph import Graph

INF = jnp.float32(jnp.inf)


class SSSPState(NamedTuple):
    dist: jax.Array       # (B, n) float32
    preds: jax.Array      # (B, n) int32
    near: jax.Array       # (B, n) bool  near-pile membership mask
    far: jax.Array        # (B, n) bool  far-pile membership mask
    bucket: jax.Array     # (B,) int32   current priority level
    n_near: jax.Array     # (B,) int32
    relaxations: jax.Array  # (B,) int32 total edge relaxations per lane


class SSSPResult(NamedTuple):
    dist: jax.Array
    preds: jax.Array
    iterations: jax.Array
    relaxations: jax.Array
    # (B,) bool: both piles drained (False = iteration budget cut the
    # relaxation short and dist is an upper bound, not the fixpoint)
    converged: jax.Array = None


@functools.partial(jax.jit, static_argnames=("use_delta", "strategy",
                                             "backend", "tiered",
                                             "telemetry", "max_iters"))
def _sssp_impl(graph: Graph, srcs: jax.Array, delta: jax.Array,
               use_delta: bool, strategy: str,
               backend: str, tiered: bool = True,
               telemetry: bool = False,
               max_iters: Optional[int] = None):
    sanitize.trace_probe("sssp")   # compile counter: body runs only on a jit cache miss
    n, m = graph.num_vertices, graph.num_edges
    b = srcs.shape[0]
    # relax sweeps run at the smallest capacity tier holding the near
    # pile's degree sum — delta-stepping's whole point is small near
    # piles, so most relaxes run orders of magnitude below worst-case m.
    # Results are bit-identical across tiers (tested vs tiered=False).
    # THREAD pins to the top tier: its O(m) static sweep is truncated at
    # cap_out, not workload-bounded, so a smaller tier would drop edges.
    # ladder keyed under "advance" — the op the expansion kernels tile
    # (advance_fused_batch_kernel's tuner key), so the floor coupling
    # reads the entries the probes actually write
    caps_e = (B.tier_plan("advance", m)
              if (tiered and m > 0 and strategy != "THREAD")
              else (max(m, 1),))
    lane = jnp.arange(b)
    dist = jnp.full((b, n), INF).at[lane, srcs].set(0.0)
    preds = jnp.full((b, n), -1, jnp.int32)
    near = jnp.zeros((b, n), bool).at[lane, srcs].set(True)
    state = SSSPState(dist=dist, preds=preds, near=near,
                      far=jnp.zeros((b, n), bool),
                      bucket=jnp.zeros((b,), jnp.int32),
                      n_near=jnp.ones((b,), jnp.int32),
                      relaxations=jnp.zeros((b,), jnp.int32))

    def relax_at(cap_t: int):
        def relax_step(st: SSSPState):
            return _relax_step(st, cap_t)
        return relax_step

    def relax_step(st: SSSPState):
        need = jnp.max(jnp.sum(
            jnp.where(st.near, graph.degrees[None, :], 0), axis=1))
        return tiered_step(need, caps_e, relax_at, st)

    def _relax_step(st: SSSPState, cap_t: int):
        frontier = BatchedDenseFrontier(st.near).to_sparse(
            n, backend=backend)

        def functor(s, d, e, rank, valid, data):
            return valid, data

        res, _ = ops.advance_batch(graph, frontier, cap_t,
                                   functor=functor,
                                   strategy=strategy, backend=backend)
        w = graph.edge_values[jnp.where(res.valid, res.edge_id, 0)]
        safe_src = jnp.where(res.valid, res.src, 0)
        cand = jnp.take_along_axis(st.dist, safe_src, axis=1) + w
        # atomicMin replacement: segment-min into dist (paper Update_Label)
        new_dist = jax.vmap(ops.scatter_min)(cand, res.dst, res.valid,
                                             st.dist)
        improved = new_dist < st.dist
        # Set_Pred: the winning edge writes the predecessor
        safe_dst = jnp.where(res.valid, res.dst, 0)
        winner = res.valid & (cand <= jnp.take_along_axis(new_dist,
                                                          safe_dst, axis=1))
        preds = jax.vmap(lambda p, wn, d, s: p.at[
            jnp.where(wn, d, n)].set(s, mode="drop"))(
                st.preds, winner, res.dst, res.src)
        # priority-queue split (near/far) on the improved vertices
        thresh = (st.bucket.astype(jnp.float32) + 1.0) * delta
        if use_delta:
            add_near = improved & (new_dist < thresh[:, None])
            add_far = improved & (new_dist >= thresh[:, None])
        else:
            add_near = improved
            add_far = jnp.zeros_like(improved)
        # vertices stay in far until their bucket comes up; improved ones
        # migrate piles according to their *new* distance
        far = (st.far | add_far) & ~add_near
        relax = st.relaxations + res.total
        return st._replace(dist=new_dist, preds=preds, near=add_near,
                           far=far,
                           n_near=jnp.sum(add_near, axis=1,
                                          dtype=jnp.int32),
                           relaxations=relax)

    def pop_far(st: SSSPState):
        # near pile empty: advance the bucket to the smallest far distance
        far_min = jnp.min(jnp.where(st.far, st.dist, INF), axis=1)
        new_bucket = jnp.where(jnp.isfinite(far_min),
                               (far_min / delta).astype(jnp.int32),
                               st.bucket + 1)
        thresh = (new_bucket.astype(jnp.float32) + 1.0) * delta
        near = st.far & (st.dist < thresh[:, None])
        far = st.far & ~near
        return st._replace(near=near, far=far, bucket=new_bucket,
                           n_near=jnp.sum(near, axis=1, dtype=jnp.int32))

    def body(st: SSSPState):
        if b == 1:
            # batch-of-1 (the single-source path): a real branch, so
            # bucket-pop iterations never pay an idle relax sweep
            return jax.lax.cond(st.n_near[0] > 0, relax_step, pop_far, st)

        def mixed_step(st):
            # lanes disagree (relax vs bucket pop); the pop is a cheap
            # mask split, so compute both and select per lane
            return select_lanes(st.n_near > 0, relax_step(st), pop_far(st))

        # bucket advances tend to synchronize on a shared topology: when
        # no lane has near work, skip the idle full-edge relax sweep
        return jax.lax.cond(jnp.any(st.n_near > 0), mixed_step, pop_far,
                            st)

    def cond(st: SSSPState):
        return (st.n_near > 0) | jnp.any(st.far, axis=1)

    # query budget: lower the guard, keep the loop jit-clean
    mi = 4 * n + 8 if max_iters is None else min(4 * n + 8, max_iters)
    buf = None
    if telemetry:
        # per-step near-pile size, bucket level, relaxation delta, and
        # the relax tier the step's workload selected (bucket-pop steps
        # record the hypothetical tier of their empty near pile — rung 0)
        from ...obs.telemetry import TelemetryBuffer
        from ..frontier import tier_index
        caps_arr = jnp.asarray(caps_e, jnp.int32)

        def probe(prev: SSSPState, new: SSSPState) -> dict:
            need = jnp.max(jnp.sum(
                jnp.where(prev.near, graph.degrees[None, :], 0), axis=1))
            tier = caps_arr[tier_index(need, caps_e)]
            return {"frontier": new.n_near, "tier": tier,
                    "bucket": new.bucket,
                    "relaxations": new.relaxations - prev.relaxations}

        buf0 = TelemetryBuffer.make(4 * n + 8, {
            "frontier": ((b,), jnp.int32),
            "tier": ((), jnp.int32),
            "bucket": ((b,), jnp.int32),
            "relaxations": ((b,), jnp.int32)})
        final, lane_iters, _, buf = run_until_any(
            cond, body, state, max_iter=mi,
            probe=probe, telemetry=buf0)
    else:
        final, lane_iters, _ = run_until_any(cond, body, state,
                                             max_iter=mi)
    result = SSSPResult(dist=final.dist, preds=final.preds,
                        iterations=lane_iters,
                        relaxations=final.relaxations,
                        converged=~cond(final))
    return (result, buf) if telemetry else result


def _auto_delta(graph: Graph) -> float:
    """Avg weight × avg degree heuristic from Davidson et al."""
    mean_w = float(jnp.mean(graph.edge_values))
    avg_deg = max(graph.num_edges / max(graph.num_vertices, 1), 1.0)
    return mean_w * avg_deg / 2.0


def sssp_batch(graph: Graph, srcs, *, delta: Optional[float] = None,
               strategy: str = "LB",
               backend: Optional[str] = None,
               tiered: bool = True, telemetry: bool = False,
               budget=None):
    """Multi-source delta-stepping: one jitted batched program over
    ``srcs``; lane i is bit-identical to ``sssp(graph, srcs[i])``.
    ``tiered=False`` pins relax sweeps to the worst-case capacity
    (bit-identical results; the tier-parity test hook).
    ``telemetry=True`` returns ``(SSSPResult, TelemetryBuffer)`` with
    per-iteration near-pile size / tier / bucket / relaxation columns;
    the result is bit-identical to ``telemetry=False``.
    ``budget`` caps BSP iterations per query (``converged=False`` on lanes
    cut short — their ``dist`` is an upper bound, not the fixpoint)."""
    assert graph.weighted, "SSSP needs edge weights"
    if delta is None:
        delta = _auto_delta(graph)
    use_delta = bool(jnp.isfinite(delta)) and delta > 0
    srcs = jnp.asarray(srcs, dtype=jnp.int32).reshape(-1)
    max_iters = None if budget is None else budget.max_iters
    return _sssp_impl(graph, srcs, jnp.float32(delta), use_delta,
                      strategy, B.resolve(backend), tiered, telemetry,
                      max_iters)


def sssp(graph: Graph, src: int, *, delta: Optional[float] = None,
         strategy: str = "LB", backend: Optional[str] = None,
         use_kernel: Optional[bool] = None, telemetry: bool = False):
    """Delta-stepping SSSP — a squeezed batch-of-1 ``sssp_batch`` call.
    ``delta=None`` = auto heuristic; ``use_kernel`` is the deprecated
    alias (public wrapper only) and always warns."""
    r = sssp_batch(graph, [src], delta=delta, strategy=strategy,
                   backend=B.resolve(backend, use_kernel),
                   telemetry=telemetry)
    if telemetry:
        res, buf = r
        return jax.tree_util.tree_map(lambda x: x[0], res), buf
    return jax.tree_util.tree_map(lambda x: x[0], r)


def sssp_bellman_ford(graph: Graph, src: int, *,
                      strategy: str = "LB",
                      backend: Optional[str] = None) -> SSSPResult:
    """Bellman-Ford-style full relaxation (the Ligra comparison baseline):
    a batch-of-1 run with the priority queue disabled."""
    srcs = jnp.asarray([src], dtype=jnp.int32)
    r = _sssp_impl(graph, srcs, jnp.float32(1e30), False, strategy,
                   B.resolve(backend))
    return jax.tree_util.tree_map(lambda x: x[0], r)
