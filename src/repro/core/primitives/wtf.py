"""Who-To-Follow (paper §7.5; Geil et al. [20]) — Twitter's recommendation
pipeline on a follow graph:

  1. PPR    — personalized PageRank from the query user.
  2. CoT    — 'circle of trust': top-k PPR vertices (k=1000 in the paper).
  3. Money  — SALSA on the bipartite graph {CoT as hubs} × {their
              out-neighbors as authorities}; authority scores are the
              follow recommendations, hub scores the 'similar users'.

All three stages run as dense frontier sweeps on the same CSR/CSC the rest
of the engine uses; the bipartite advance is a masked advance (live edges =
edges whose source is a hub).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .. import backend as B
from ..graph import Graph


class WTFResult(NamedTuple):
    ppr: jax.Array          # (n,) personalized pagerank
    cot: jax.Array          # (k,) circle-of-trust vertex ids
    hub_scores: jax.Array   # (n,) SALSA hub scores ('similar to you')
    auth_scores: jax.Array  # (n,) SALSA authority scores (recommendations)


@functools.partial(jax.jit, static_argnames=("k", "ppr_iters", "salsa_iters"))
def _wtf_impl(graph: Graph, src: jax.Array, damping: jax.Array, k: int,
              ppr_iters: int, salsa_iters: int) -> WTFResult:
    n, m = graph.num_vertices, graph.num_edges
    deg = graph.degrees.astype(jnp.float32)
    # segment owner of each CSC slot (= the edge's destination vertex)
    seg = jnp.searchsorted(graph.csc_offsets,
                           jnp.arange(m, dtype=jnp.int32), side="right") - 1
    # segment owner of each CSR slot (= the edge's source vertex)
    src_all = jnp.searchsorted(graph.row_offsets,
                               jnp.arange(m, dtype=jnp.int32),
                               side="right") - 1
    esrc_csc = graph.csc_cols()
    edst_csr = graph.cols()

    # ---- stage 1: PPR ----------------------------------------------------
    def ppr_body(pr):
        contrib = jnp.where(deg > 0, pr / jnp.maximum(deg, 1.0), 0.0)
        acc = jax.ops.segment_sum(contrib[esrc_csc], seg, num_segments=n,
                                  indices_are_sorted=True)
        dangling = jnp.sum(jnp.where(deg == 0, pr, 0.0))
        new = damping * acc
        return new.at[src].add((1.0 - damping) + damping * dangling)

    pr = jnp.zeros((n,)).at[src].set(1.0)
    pr = jax.lax.fori_loop(0, ppr_iters, lambda _, p: ppr_body(p), pr)

    # ---- stage 2: circle of trust (top-k PPR, excluding the source) ------
    masked = pr.at[src].set(-jnp.inf)
    top_vals, cot = jax.lax.top_k(masked, k)
    cot_ok = top_vals > 0.0
    hubs = jnp.zeros((n,), bool).at[jnp.where(cot_ok, cot, 0)].set(
        cot_ok, mode="drop")

    # ---- stage 3: SALSA on the CoT-induced bipartite graph ---------------
    live_csr = hubs[src_all]        # per-CSR-slot: source is a hub
    live_csc = hubs[esrc_csc]       # per-CSC-slot: source is a hub
    hub_deg = jax.ops.segment_sum(live_csr.astype(jnp.float32), src_all,
                                  num_segments=n, indices_are_sorted=True)
    auth_deg = jax.ops.segment_sum(live_csc.astype(jnp.float32), seg,
                                   num_segments=n, indices_are_sorted=True)
    h0 = hubs.astype(jnp.float32) / jnp.maximum(jnp.sum(hubs), 1)

    def salsa_body(_, carry):
        h, a = carry
        # hub -> authority (gather per CSC slot, reduce by destination)
        contrib_h = jnp.where(hub_deg > 0, h / jnp.maximum(hub_deg, 1.0),
                              0.0)
        a_new = jax.ops.segment_sum(
            jnp.where(live_csc, contrib_h[esrc_csc], 0.0), seg,
            num_segments=n, indices_are_sorted=True)
        # authority -> hub (gather per CSR slot, reduce by source)
        contrib_a = jnp.where(auth_deg > 0, a_new / jnp.maximum(auth_deg,
                                                                1.0), 0.0)
        h_new = jax.ops.segment_sum(
            jnp.where(live_csr, contrib_a[edst_csr], 0.0), src_all,
            num_segments=n, indices_are_sorted=True)
        h_new = jnp.where(hubs, h_new, 0.0)
        return h_new, a_new

    h, a = jax.lax.fori_loop(0, salsa_iters, salsa_body,
                             (h0, jnp.zeros((n,))))
    return WTFResult(ppr=pr.astype(jnp.float32), cot=cot,
                     hub_scores=h.astype(jnp.float32),
                     auth_scores=a.astype(jnp.float32))


def who_to_follow(graph: Graph, user: int, *, k: int = 1000,
                  damping: float = 0.85, ppr_iters: int = 30,
                  salsa_iters: int = 10,
                  backend: Optional[str] = None) -> WTFResult:
    """WTF pipeline. ``backend`` is accepted for a uniform primitive
    interface; all three stages are dense segment-sum sweeps with no
    dedicated Pallas kernel yet, so the registry resolves both backends to
    the same XLA sweep."""
    B.resolve(backend)
    assert graph.has_csc
    k = min(k, graph.num_vertices - 1)
    return _wtf_impl(graph, jnp.int32(user), jnp.float32(damping), k,
                     ppr_iters, salsa_iters)
