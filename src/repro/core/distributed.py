"""Distributed graph primitives + the sharded registry providers
(paper §8.2.1; Pan et al. [56]).

Gunrock's multi-GPU design keeps the single-GPU engine unchanged and
adds communication + partition modules; we do the same, but behind the
backend registry's *placement* dimension: this module registers the
``placement="sharded"`` providers for the operator hot paths, so the
same dispatch that picks xla-vs-pallas kernels also picks
single-vs-mesh execution.

The 1-D partition (partition.py) gives each device a CSR slice (and a
CSC slice when the source graph carries the mirror); the providers run
under ``shard_map`` with two exchange strategies:

  * "advance" (sharded) — bitmask exchange: each device expands its
    owned frontier slice into a *global* discovered bitmask and the
    masks are OR-combined with an all-reduce. O(n) bytes/device/step,
    independent of frontier raggedness — the BSP-safe translation of
    Gunrock's frontier segment exchange (which needed p2p queues).
    Contract (called INSIDE an active shard_map):
      (local_ro (vpp+1,), local_ci (me,), frontier (n,), base (),
       vpp, axis) → (n,) bool discovered mask, already all-reduced.
  * "spmv"/"spmm" (sharded) — classic 1-D row-partitioned products:
    the dense operand stays replicated (the all-gather side), each
    device reduces its owned rows locally with exactly the
    single-device gather+segment formulation, and the row blocks
    concatenate — no reduction crosses devices, so results are
    bit-identical to the single-device sweep. Same positional contract
    as the single providers, with (p, …) stacked CSR operands.
  * "mxm" (sharded) — 1-D SpGEMM: the expansion side is row-partitioned
    (each device expands the mask edges whose base row it owns), the
    probe side stays replicated, and per-edge partials ⊕-combine across
    the mesh (disjoint ownership ⇒ identity merge ⇒ bit parity).

Traversal loops (BFS / SSSP / CC) run whole-loop inside one shard_map
with replicated (n,)-sized state and local edge sweeps; every state
update is an exact min/OR combine, so labels and distances bit-match
the single-device primitives. All impls are module-level jits with the
mesh as a static argument — repeated calls (the serving driver) reuse
one trace per (shape, mesh).

placement="2d" (the vertex-cut R×C mesh, ``partition_2d``) registers a
second provider family with different exchange geometry:

  * "advance"/"advance_filter" (2d) — chunked bitmask exchange: device
    (i, j) expands its edge block into a ceil(n/C) *column-chunk* mask,
    the R devices of each mesh column psum-OR their chunks (row-axis
    collective), and the C chunks all-gather along the column axis into
    the global mask (the mirror-merge: every mirror's discoveries fold
    into the owner chunk's lane). The chunk exchange is DOUBLE-BUFFERED
    over static edge tiles — the psum for tile t is consumed one loop
    iteration after it is issued, so tile t+1's local gathers overlap
    the collective (XLA overlaps the in-flight psum with the next
    tile's scatter; OR is idempotent and order-free, so the overlap
    cannot change bits). Per-device bytes/step drop from the 1-D
    2·(p−1)/p·n·4 to tiles·2·(R−1)/R·vpc + (C−1)·vpc uint8 lanes.
  * "spmv"/"spmm" (2d) — pre-fold product exchange: each device
    computes its block's per-edge products (bit-identical IEEE ops),
    scatters them at their ``Blocks2D.epos`` slots into one
    ⊕-identity-background (chunk_emax,) buffer, and the mesh row
    ⊕-all-reduces — slots are DISJOINT across the row, so the combine
    merges identities only and is exact for every semiring. The merged
    chunk then replays the exact single-device per-row fold
    (``fold_products``, the product-level twin of hybrid_ell_reduce),
    keeping PR-4 bit parity through the vertex cut.
  * "mxm" (2d) — both axes expand their block slices of the owned mask
    rows; per-edge partials ⊕-combine over the whole mesh (exact for
    the exact-⊕ and integer-sum semirings, which covers the tc
    workload; arbitrary-float plus-times SpGEMM regroups, documented).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from . import backend as B
from .partition import (Partitioned2DGraph, PartitionedGraph,
                        check_mesh_axes, check_mesh_axis)

# a plain Python int on purpose: this module is imported LAZILY by the
# registry, possibly in the middle of someone else's jit trace, and a
# module-level jnp constant created there would be a leaked tracer
INT_BIG = 2 ** 30


class DistBFSResult(NamedTuple):
    labels: jax.Array      # (n,) global depths
    iterations: jax.Array


class DistSSSPResult(NamedTuple):
    dist: jax.Array        # (n,) float32 distances
    iterations: jax.Array


class DistCCResult(NamedTuple):
    labels: jax.Array
    num_components: jax.Array
    iterations: jax.Array


# how many static edge tiles the 2-D bitmask exchange double-buffers
# over (the comm–compute overlap depth); 1 disables the overlap
DEFAULT_EXCHANGE_TILES = 2


def _axes_arg(axis) -> tuple:
    """Normalize the ``axis`` argument of the distributed entry points
    for a 2-D partition: an explicit (row, col) pair passes through, the
    1-D default name maps to the canonical ("row", "col") axes."""
    if isinstance(axis, (tuple, list)):
        if len(axis) != 2:
            raise ValueError(f"2-D placement needs two mesh axes, got "
                             f"{tuple(axis)}")
        return tuple(axis)
    return ("row", "col")


def _check_mesh(pg, mesh: Mesh, axis) -> None:
    if isinstance(pg, Partitioned2DGraph):
        check_mesh_axes(mesh, _axes_arg(axis), (pg.rows, pg.cols))
    else:
        check_mesh_axis(mesh, axis, pg.num_parts)


def _shard_any(pg, mesh: Mesh, axis):
    """Shard either partition container on its mesh (the entry-point
    glue that keeps 1-D and 2-D one code path, not a fork)."""
    if isinstance(pg, Partitioned2DGraph):
        return pg.shard(mesh, _axes_arg(axis))
    return pg.shard(mesh, axis)


def _require_placement_mesh():
    ctx = B.placement_mesh()
    if ctx is None:
        raise RuntimeError(
            "distributed dispatch needs an active placement context "
            "that carries a mesh: with backend.use_placement('sharded', "
            "mesh=mesh, axis='graph'): ... (or '2d' with "
            "axis=('row', 'col'))")
    return ctx


def _require_2d_mesh():
    mesh, axes = _require_placement_mesh()
    if not (isinstance(axes, tuple) and len(axes) == 2):
        raise RuntimeError(
            "2d providers need a (row, col) mesh-axis pair: "
            "use_placement('2d', mesh=mesh, axis=('row', 'col')) — "
            f"got axis={axes!r}")
    return mesh, axes


def _all_reduce(sr, x: jax.Array, axis: str) -> jax.Array:
    """⊕-combine per-device partials across the mesh axis."""
    if sr.add == "plus":
        return jax.lax.psum(x, axis)
    if sr.add == "min":
        return jax.lax.pmin(x, axis)
    return jax.lax.pmax(x, axis)          # max | or


# ---------------------------------------------------------------------------
# local sweeps (the per-device half of each exchange strategy)
# ---------------------------------------------------------------------------


def _local_slots(local_ro: jax.Array, local_ci: jax.Array, vpp: int):
    """Map local CSR slots back to (local source row, validity)."""
    me = local_ci.shape[0]
    slot = jnp.arange(me, dtype=jnp.int32)
    src_local = jnp.searchsorted(local_ro, slot, side="right") - 1
    src_local = jnp.clip(src_local, 0, vpp - 1).astype(jnp.int32)
    valid = (slot < local_ro[-1]) & (local_ci >= 0)
    return src_local, valid


def _local_expand_mask(local_ro, local_ci, frontier_slice, n, vpp):
    """Expand the owned frontier slice; return a global discovered bitmask.

    frontier_slice: (vpp,) bool of owned active vertices.
    Dense formulation: every local CSR slot whose source vertex is active
    marks its destination. Source of local slot e = searchsorted(ro, e).
    """
    src_local, valid = _local_slots(local_ro, local_ci, vpp)
    active = frontier_slice[src_local] & valid
    mask = jnp.zeros((n,), bool)
    tgt = jnp.where(active, local_ci, n)
    mask = mask.at[tgt].set(True, mode="drop")
    return mask


# ---------------------------------------------------------------------------
# sharded registry providers
# ---------------------------------------------------------------------------


def _owned_slice(vec: jax.Array, base, vpp: int, fill=0):
    """The (vpp,) owned slice of a replicated vector, correct for the
    padded tail part: ``dynamic_slice`` CLAMPS an out-of-range start, so
    slicing (n,) state directly would hand the tail part a shifted
    window whenever p·vpp > n — pad by one part first so every start is
    in range (pad lanes belong to no real row and never survive the
    validity masks)."""
    padded = jnp.pad(vec, (0, vpp), constant_values=fill)
    return jax.lax.dynamic_slice(padded, (base,), (vpp,))


@B.register("advance", B.XLA, B.SHARDED)
def _advance_bitmask_exchange(local_ro, local_ci, frontier, base, vpp: int,
                              axis: str):
    """Bitmask-exchange advance step — see the module docstring contract.
    Must be called inside an active shard_map over ``axis``."""
    n = frontier.shape[0]
    my_slice = _owned_slice(frontier, base, vpp)
    disc = _local_expand_mask(local_ro, local_ci, my_slice, n, vpp)
    return jax.lax.psum(disc.astype(jnp.int32), axis) > 0


@B.register("spmm", B.XLA, B.SHARDED)
def _spmm_sharded(offsets, indices, values, x, sr, ell_width, mask,
                  row_seg=None):
    """1-D row-partitioned semiring SpMM: Y⟨mask⟩ = A ⊗ X.

    ``offsets``/``indices``/``values`` are (p, …) stacked per-device row
    slices; ``x`` (n, k) and ``mask`` (n,) stay replicated. Each device
    reduces its owned rows with the single-device gather+segment
    formulation (bit parity); row blocks concatenate over the mesh axis.
    Requires a square operand (the 1-D vertex partition), i.e.
    x.shape[0] == the global row count.
    """
    del ell_width                      # single-pallas-only metadata
    del row_seg     # per-shard edge->row maps are derived locally below
    mesh, axis = _require_placement_mesh()
    vpp = int(offsets.shape[1]) - 1
    n = int(x.shape[0])
    part, rep = P(axis), P()

    def local_rows(ro_s, ci_s, ev_s, xg):
        ro, ci = ro_s[0], ci_s[0]
        src_local, valid = _local_slots(ro, ci, vpp)
        xv = xg[jnp.where(valid, ci, 0)]                       # (me, k)
        ev = None if ev_s is None else ev_s[0]
        prod = xv if ev is None else sr.mul_op(ev[:, None], xv)
        prod = jnp.where(valid[:, None], prod, sr.zero)
        y = sr.segment_reduce(prod.astype(jnp.float32), src_local, vpp,
                              indices_are_sorted=True)
        deg = ro[1:] - ro[:-1]
        return jnp.where((deg > 0)[:, None], y, sr.zero)

    if values is None:
        run = shard_map(lambda ro, ci, xg: local_rows(ro, ci, None, xg),
                        mesh=mesh, in_specs=(part, part, rep),
                        out_specs=part, check_rep=False)
        y = run(offsets, indices, x)
    else:
        run = shard_map(local_rows, mesh=mesh,
                        in_specs=(part, part, part, rep),
                        out_specs=part, check_rep=False)
        y = run(offsets, indices, values, x)
    y = y[:n]                                   # drop tail-part padding rows
    if mask is not None:
        y = jnp.where(mask[:, None], y, sr.zero)
    return y.astype(jnp.float32)


@B.register("spmv", B.XLA, B.SHARDED)
def _spmv_sharded(offsets, indices, values, x, sr, ell_width, mask,
                  row_seg=None, over_pos=None, over_row=None):
    """1-D row-partitioned semiring SpMV.

    With ``ell_width`` metadata (a ShardedGraph built from a
    ``Graph.from_csr`` source) each device runs the SAME hybrid
    ELL-tree + overflow-fold as the single-device sweep on its local row
    slice — identical per-row fold dataflow, so bits match across
    placements (the PR-4 parity discipline). The compacted overflow
    lists have no stacked counterpart, so shards take the masked
    drop-scatter flavour (same per-row edge sequence, same bits; the
    sharded path is a parity/serving path, not the single-device hot
    loop). Without metadata, falls back to the k=1 SpMM column.
    """
    del row_seg, over_pos, over_row        # derived/absent per shard
    if ell_width is None:
        return _spmm_sharded(offsets, indices, values, x[:, None], sr,
                             None, mask)[:, 0]
    from repro.linalg.ops import hybrid_ell_reduce
    mesh, axis = _require_placement_mesh()
    vpp = int(offsets.shape[1]) - 1
    n = int(x.shape[0])
    part, rep = P(axis), P()

    def local_rows(ro_s, ci_s, ev_s, xg):
        ro, ci = ro_s[0], ci_s[0]
        ev = None if ev_s is None else ev_s[0]
        me = ci.shape[0]
        edge_valid = jnp.arange(me, dtype=jnp.int32) < ro[-1]
        y = hybrid_ell_reduce(ro, ci, ev, xg, sr, int(ell_width),
                              edge_valid=edge_valid)
        deg = ro[1:] - ro[:-1]
        return jnp.where(deg > 0, y, sr.zero)

    if values is None:
        run = shard_map(lambda ro, ci, xg: local_rows(ro, ci, None, xg),
                        mesh=mesh, in_specs=(part, part, rep),
                        out_specs=part, check_rep=False)
        y = run(offsets, indices, x)
    else:
        run = shard_map(local_rows, mesh=mesh,
                        in_specs=(part, part, part, rep),
                        out_specs=part, check_rep=False)
        y = run(offsets, indices, values, x)
    y = y[:n]
    if mask is not None:
        y = jnp.where(mask, y, sr.zero)
    return y.astype(jnp.float32)


# advance_filter has no sharded (1-D) provider BY DESIGN, not omission:
# the fused predicate needs the global visited bitmap coherent per tile,
# and the 1-D exchange only reconciles it per BSP step — the sharded BFS
# path composes advance + a post-exchange filter instead. The 2-D path
# registers one because its row-axis psum-OR makes the bitmap coherent
# inside the step. Declared so the registry contract checker (CT001)
# reads the hole as a decision, while dispatch still refuses to drop to
# single-device.
B.declare_fallback(
    "advance_filter", B.SHARDED,
    reason="1-D exchange cannot keep the visited bitmap coherent inside "
           "a fused tile sweep; sharded BFS composes advance + filter "
           "around the frontier exchange instead")


@B.register("mxm", B.XLA, B.SHARDED)
def _mxm_sharded(a_off, a_idx, a_vals, bt_off, bt_idx, bt_vals,
                 base, probe_rows, sr, cap_out: int):
    """1-D masked SpGEMM: the expansion side (A) is row-partitioned, the
    probe side (Bᵀ) replicated. Each device LB-expands the mask edges
    whose ``base`` row it owns and probes the replicated structure;
    per-edge partials ⊕-combine across the mesh (ownership is disjoint,
    so the combine only merges identities — bit parity with the
    single-device dot formulation)."""
    from . import operators as _ops
    mesh, axis = _require_placement_mesh()
    vpp = int(a_off.shape[1]) - 1
    e = int(base.shape[0])
    part, rep = P(axis), P()
    # one shard_map signature serves the structural/valued combinations:
    # absent value operands ride as zero-size placeholders, the closure
    # flags decide whether the slots index them
    has_av = a_vals is not None
    has_btv = bt_vals is not None
    av_in = (a_vals if has_av
             else jnp.zeros((int(a_off.shape[0]), 0), jnp.float32))
    btv_in = bt_vals if has_btv else jnp.zeros((0,), jnp.float32)

    def local(ao_s, ai_s, av_s, bto, bti, btv, base_g, rows_g):
        ao, ai = ao_s[0], ai_s[0]
        me = int(ai.shape[0])
        my_base = jax.lax.axis_index(axis).astype(jnp.int32) * vpp
        owned = (base_g >= my_base) & (base_g < my_base + vpp)
        base_l = jnp.where(owned, base_g - my_base, 0)
        deg = ao[base_l + 1] - ao[base_l]
        sizes = jnp.where(owned, deg, 0).astype(jnp.int32)
        _, needles, eid, pair, _, valid, _ = _ops._advance_xla(
            ao, ai, base_l, sizes, cap_out)
        rows = rows_g[pair]
        pos = _ops._searchsorted_segment(bti, bto[rows], bto[rows + 1],
                                         needles, locate=True)
        found = (pos >= 0) & valid
        sv = (av_s[0][jnp.clip(eid, 0, me - 1)] if has_av
              else jnp.float32(sr.one))
        lv = (btv[jnp.clip(pos, 0, int(bti.shape[0]) - 1)] if has_btv
              else jnp.float32(sr.one))
        prod = jnp.where(found, sr.mul_op(sv, lv), sr.zero)
        c = sr.segment_reduce(prod.astype(jnp.float32), pair, e,
                              indices_are_sorted=True)
        c = _all_reduce(sr, c, axis)
        gsizes = jax.lax.psum(sizes, axis)
        return jnp.where(gsizes > 0, c, sr.zero).astype(jnp.float32)

    run = shard_map(local, mesh=mesh,
                    in_specs=(part, part, part, rep, rep, rep, rep, rep),
                    out_specs=rep, check_rep=False)
    return run(a_off, a_idx, av_in, bt_off, bt_idx, btv_in, base,
               probe_rows)


# ---------------------------------------------------------------------------
# 2-D vertex-cut providers (placement="2d")
# ---------------------------------------------------------------------------


def _block_slots(block_ro, block_ci, vpr: int):
    """(local source row, validity) of every block CSR slot — the block
    twin of ``_local_slots``."""
    return _local_slots(block_ro, block_ci, vpr)


def _block_discover_chunk(block_ro, block_ci, frontier, row_base,
                          col_base, vpr: int, vpc: int, row_ax: str,
                          tiles: int):
    """The per-device half of the 2-D bitmask exchange: expand this
    block's edges from the owned frontier slice into a (vpc,) column
    chunk mask, psum-OR'd along the mesh row — double-buffered over
    ``tiles`` static edge tiles so the collective for tile t is in
    flight while tile t+1's local gathers run (OR is idempotent and
    order-free, so the overlap cannot change bits; a tile's clamped
    re-read at the ragged tail re-marks targets idempotently for the
    same reason). uint8 lanes keep the exchange byte-proportional to
    the chunk, not to n."""
    src_local, valid = _block_slots(block_ro, block_ci, vpr)
    my_src = _owned_slice(frontier, row_base, vpr)
    active = my_src[src_local] & valid
    # local column-chunk target of every block edge; inactive ⇒ vpc
    # (dropped by the scatter)
    tgt = jnp.where(active, block_ci - col_base, vpc).astype(jnp.int32)
    be = int(block_ci.shape[0])
    tiles = max(int(tiles), 1)
    ept = max(-(-be // tiles), 1)

    def tile_mask(t):
        sl = jax.lax.dynamic_slice(tgt, (t * ept,), (ept,))
        return jnp.zeros((vpc,), jnp.uint8).at[sl].set(1, mode="drop")

    def body(t, carry):
        acc, inflight = carry
        cur = tile_mask(t)                 # local gathers for tile t …
        acc = jnp.maximum(acc, inflight)   # … overlap tile t−1's psum
        return acc, jax.lax.psum(cur, row_ax)

    inflight0 = jax.lax.psum(tile_mask(0), row_ax)
    acc0 = jnp.zeros((vpc,), jnp.uint8)
    if tiles > 1:
        acc, inflight = jax.lax.fori_loop(1, tiles, body,
                                          (acc0, inflight0))
    else:
        acc, inflight = acc0, inflight0
    return jnp.maximum(acc, inflight) > 0


def _gather_chunks(chunk, col_ax: str, n: int):
    """Column-axis mirror-merge: assemble the global (n,) vector from
    the C per-chunk lanes (each chunk is already the exact row-combined
    value for its vertices — concatenate and trim the ceil padding)."""
    full = jax.lax.all_gather(chunk, col_ax, axis=0, tiled=False)
    return full.reshape(-1)[:n]


@B.register("advance", B.XLA, B.TWOD)
def _advance_2d(block_ro, block_ci, frontier, row_base, col_base,
                vpr: int, vpc: int, axes: tuple,
                tiles: int = DEFAULT_EXCHANGE_TILES):
    """2-D chunked bitmask-exchange advance. Must be called inside an
    active shard_map over both mesh axes. Contract:
      (block_ro (vpr+1,), block_ci (be,), frontier (n,), row_base (),
       col_base (), vpr, vpc, axes, tiles) → (n,) bool discovered mask,
    already row-psum'd and column-gathered (identical on every
    device)."""
    row_ax, col_ax = axes
    chunk = _block_discover_chunk(block_ro, block_ci, frontier, row_base,
                                  col_base, vpr, vpc, row_ax, tiles)
    return _gather_chunks(chunk, col_ax, int(frontier.shape[0]))


@B.register("advance_filter", B.XLA, B.TWOD)
def _advance_filter_2d(block_ro, block_ci, frontier, visited, row_base,
                       col_base, vpr: int, vpc: int, axes: tuple,
                       tiles: int = DEFAULT_EXCHANGE_TILES):
    """Fused 2-D advance+filter: the visited filter applies to the
    merged column chunk BEFORE the column-axis gather, so the filter
    costs no extra exchange (the 2-D analogue of the single-device
    fused megakernel). Same contract as the 2d "advance" plus the
    replicated (n,) visited mask; returns the new frontier."""
    row_ax, col_ax = axes
    chunk = _block_discover_chunk(block_ro, block_ci, frontier, row_base,
                                  col_base, vpr, vpc, row_ax, tiles)
    my_visited = _owned_slice(visited, col_base, vpc)
    return _gather_chunks(chunk & ~my_visited, col_ax,
                          int(frontier.shape[0]))


def _merge_block_products(store_leaf, valid, prod, sr, emax: int,
                          col_ax: str):
    """Scatter this block's per-edge products to their row-chunk slice
    positions and ⊕-merge the mesh row: slots are disjoint across the
    row's blocks, so the all-reduce only ever combines a product with
    ⊕-identities — exact for every semiring, including float plus
    (the pre-fold product exchange that keeps 2-D spmv/spmm
    bit-identical to the single-device sweep)."""
    merged = jnp.full(((emax,) + prod.shape[1:]), sr.zero, jnp.float32)
    tgt = jnp.where(valid, store_leaf, emax)
    merged = merged.at[tgt].set(prod.astype(jnp.float32), mode="drop")
    return _all_reduce(sr, merged, col_ax)


@B.register("spmv", B.XLA, B.TWOD)
def _spmv_2d(offsets, store, values, x, sr, ell_width, mask,
             row_seg=None, over_pos=None, over_row=None):
    """2-D vertex-cut semiring SpMV: pre-fold product exchange along
    the mesh row, then the EXACT single-device per-row fold on the
    merged chunk (``fold_products`` — the product-level twin of
    hybrid_ell_reduce, same ELL tree, same overflow scatter order), row
    chunks concatenating over the row axis. ``store`` is the
    ``Blocks2D`` pytree a Sharded2DGraph's col/csc store yields."""
    del row_seg, over_pos, over_row
    if ell_width is None:
        return _spmm_2d(offsets, store, values, x[:, None], sr, None,
                        mask)[:, 0]
    from repro.linalg.ops import fold_products
    mesh, axes = _require_2d_mesh()
    row_ax, col_ax = axes
    vpr = int(offsets.shape[2]) - 1
    n = int(x.shape[0])
    emax = int(store.chunk_emax)
    blk, rep = P(row_ax, col_ax), P()

    def local(ro_s, st, ev_s, xg):
        ro = ro_s[0, 0]
        ci, ep, cro = st.cols[0, 0], st.epos[0, 0], st.chunk_ro[0, 0]
        ev = None if ev_s is None else ev_s[0, 0]
        _, valid = _block_slots(ro, ci, vpr)
        xv = xg[jnp.where(valid, ci, 0)]
        prod = sr.round_prod(xv) if ev is None else sr.mul_op(ev, xv)
        merged = _merge_block_products(ep, valid, prod, sr, emax, col_ax)
        edge_valid = jnp.arange(emax, dtype=jnp.int32) < cro[-1]
        y = fold_products(cro, merged, sr, int(ell_width),
                          edge_valid=edge_valid)
        deg = cro[1:] - cro[:-1]
        return jnp.where(deg > 0, y, sr.zero)

    if values is None:
        run = shard_map(lambda ro, st, xg: local(ro, st, None, xg),
                        mesh=mesh, in_specs=(blk, blk, rep),
                        out_specs=P(row_ax), check_rep=False)
        y = run(offsets, store, x)
    else:
        run = shard_map(local, mesh=mesh,
                        in_specs=(blk, blk, blk, rep),
                        out_specs=P(row_ax), check_rep=False)
        y = run(offsets, store, values, x)
    y = y[:n]
    if mask is not None:
        y = jnp.where(mask, y, sr.zero)
    return y.astype(jnp.float32)


@B.register("spmm", B.XLA, B.TWOD)
def _spmm_2d(offsets, store, values, x, sr, ell_width, mask,
             row_seg=None):
    """2-D vertex-cut semiring SpMM: the same pre-fold product exchange
    as the 2d spmv, then the single-device gather+segment formulation
    on the merged (chunk_emax, k) products (per-row value sequence
    identical to the 1-D/single sweeps ⇒ bit parity)."""
    del ell_width, row_seg
    mesh, axes = _require_2d_mesh()
    row_ax, col_ax = axes
    vpr = int(offsets.shape[2]) - 1
    n = int(x.shape[0])
    emax = int(store.chunk_emax)
    blk, rep = P(row_ax, col_ax), P()

    def local(ro_s, st, ev_s, xg):
        ci, ep, cro = st.cols[0, 0], st.epos[0, 0], st.chunk_ro[0, 0]
        ev = None if ev_s is None else ev_s[0, 0]
        _, valid = _block_slots(ro_s[0, 0], ci, vpr)
        xv = xg[jnp.where(valid, ci, 0)]                       # (be, k)
        prod = xv if ev is None else sr.mul_op(ev[:, None], xv)
        prod = jnp.where(valid[:, None], prod, sr.zero)
        merged = _merge_block_products(ep, valid, prod, sr, emax, col_ax)
        slot = jnp.arange(emax, dtype=jnp.int32)
        seg = jnp.clip(jnp.searchsorted(cro, slot, side="right") - 1,
                       0, vpr - 1).astype(jnp.int32)
        y = sr.segment_reduce(merged, seg, vpr, indices_are_sorted=True)
        deg = cro[1:] - cro[:-1]
        return jnp.where((deg > 0)[:, None], y, sr.zero)

    if values is None:
        run = shard_map(lambda ro, st, xg: local(ro, st, None, xg),
                        mesh=mesh, in_specs=(blk, blk, rep),
                        out_specs=P(row_ax), check_rep=False)
        y = run(offsets, store, x)
    else:
        run = shard_map(local, mesh=mesh,
                        in_specs=(blk, blk, blk, rep),
                        out_specs=P(row_ax), check_rep=False)
        y = run(offsets, store, values, x)
    y = y[:n]
    if mask is not None:
        y = jnp.where(mask[:, None], y, sr.zero)
    return y.astype(jnp.float32)


@B.register("mxm", B.XLA, B.TWOD)
def _mxm_2d(a_off, a_store, a_vals, bt_off, bt_idx, bt_vals,
            base, probe_rows, sr, cap_out: int):
    """2-D masked SpGEMM: every device expands ITS block slice of the
    mask edges whose base row its mesh row owns (the row's edges are
    split across the C column blocks), probes the replicated Bᵀ
    structure, and per-edge partials ⊕-combine over the whole mesh.
    Block ownership of A-edges is disjoint, so the combine is exact for
    the exact-⊕ semirings and for integer-valued sums (plus_and
    triangle counts); arbitrary-float plus-times regroups the per-edge
    dot (documented 2-D caveat — use the 1-D placement for bit-exact
    float SpGEMM)."""
    from . import operators as _ops
    mesh, axes = _require_2d_mesh()
    row_ax, col_ax = axes
    vpr = int(a_off.shape[2]) - 1
    e = int(base.shape[0])
    a_idx = a_store.cols if hasattr(a_store, "cols") else a_store
    blk, rep = P(row_ax, col_ax), P()
    has_av = a_vals is not None
    has_btv = bt_vals is not None
    av_in = (a_vals if has_av
             else jnp.zeros(a_idx.shape[:2] + (0,), jnp.float32))
    btv_in = bt_vals if has_btv else jnp.zeros((0,), jnp.float32)

    def local(ao_s, ai_s, av_s, bto, bti, btv, base_g, rows_g):
        ao, ai = ao_s[0, 0], ai_s[0, 0]
        me = int(ai.shape[0])
        my_base = jax.lax.axis_index(row_ax).astype(jnp.int32) * vpr
        owned = (base_g >= my_base) & (base_g < my_base + vpr)
        base_l = jnp.where(owned, base_g - my_base, 0)
        deg = ao[base_l + 1] - ao[base_l]       # this block's slice only
        sizes = jnp.where(owned, deg, 0).astype(jnp.int32)
        _, needles, eid, pair, _, valid, _ = _ops._advance_xla(
            ao, ai, base_l, sizes, cap_out)
        rows = rows_g[pair]
        pos = _ops._searchsorted_segment(bti, bto[rows], bto[rows + 1],
                                         needles, locate=True)
        found = (pos >= 0) & valid
        sv = (av_s[0, 0][jnp.clip(eid, 0, me - 1)] if has_av
              else jnp.float32(sr.one))
        lv = (btv[jnp.clip(pos, 0, int(bti.shape[0]) - 1)] if has_btv
              else jnp.float32(sr.one))
        prod = jnp.where(found, sr.mul_op(sv, lv), sr.zero)
        c = sr.segment_reduce(prod.astype(jnp.float32), pair, e,
                              indices_are_sorted=True)
        c = _all_reduce(sr, c, (row_ax, col_ax))
        gsizes = jax.lax.psum(sizes, (row_ax, col_ax))
        return jnp.where(gsizes > 0, c, sr.zero).astype(jnp.float32)

    run = shard_map(local, mesh=mesh,
                    in_specs=(blk, blk, blk, rep, rep, rep, rep, rep),
                    out_specs=rep, check_rep=False)
    return run(a_off, a_idx, av_in, bt_off, bt_idx, btv_in, base,
               probe_rows)


# ---------------------------------------------------------------------------
# traversal primitives (whole loop inside one shard_map)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("n", "vpp", "mesh", "axis", "backend"))
def _bfs_dist_impl(ro, ci, base, src, *, n: int, vpp: int, mesh: Mesh,
                   axis: str, backend: str):
    expand = B.dispatch("advance", backend, B.SHARDED)
    part, rep = P(axis), P()

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(part, part, part, rep),
        out_specs=(rep, rep),
        check_rep=False)
    def run(ro_s, ci_s, base_s, src_v):
        local_ro = ro_s[0]
        local_ci = ci_s[0]
        my_base = base_s[0]

        def cond(carry):
            labels, frontier, it = carry
            return jnp.any(frontier) & (it <= n)

        def body(carry):
            labels, frontier, it = carry
            # bitmask-exchange advance (OR-combined across devices)
            disc = expand(local_ro, local_ci, frontier, my_base, vpp,
                          axis)
            new = disc & (labels < 0)
            labels = jnp.where(new, it + 1, labels)
            return labels, new, it + 1

        labels0 = jnp.full((n,), -1, jnp.int32).at[src_v].set(0)
        frontier0 = jnp.zeros((n,), bool).at[src_v].set(True)
        labels, _, it = jax.lax.while_loop(cond, body,
                                           (labels0, frontier0,
                                            jnp.int32(0)))
        return labels, it

    return run(ro, ci, base, src)


@functools.partial(jax.jit,
                   static_argnames=("n", "vpr", "vpc", "mesh", "axes",
                                    "tiles", "backend"))
def _bfs_2d_impl(ro, ci, row_base, col_base, src, *, n: int, vpr: int,
                 vpc: int, mesh: Mesh, axes: tuple, tiles: int,
                 backend: str):
    af = B.dispatch("advance_filter", backend, B.TWOD)
    row_ax, col_ax = axes
    blk, rep = P(row_ax, col_ax), P()

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(blk, blk, P(row_ax), P(col_ax), rep),
        out_specs=(rep, rep),
        check_rep=False)
    def run(ro_s, ci_s, rb_s, cb_s, src_v):
        block_ro, block_ci = ro_s[0, 0], ci_s[0, 0]
        my_rb, my_cb = rb_s[0], cb_s[0]

        def cond(carry):
            labels, frontier, it = carry
            return jnp.any(frontier) & (it <= n)

        def body(carry):
            labels, frontier, it = carry
            # fused 2-D advance+filter: row-psum'd chunk discovery with
            # the visited filter applied pre-gather
            new = af(block_ro, block_ci, frontier, labels >= 0, my_rb,
                     my_cb, vpr, vpc, axes, tiles)
            labels = jnp.where(new, it + 1, labels)
            return labels, new, it + 1

        labels0 = jnp.full((n,), -1, jnp.int32).at[src_v].set(0)
        frontier0 = jnp.zeros((n,), bool).at[src_v].set(True)
        labels, _, it = jax.lax.while_loop(cond, body,
                                           (labels0, frontier0,
                                            jnp.int32(0)))
        return labels, it

    return run(ro, ci, row_base, col_base, src)


def distributed_bfs(pg, src: int, mesh: Mesh, axis="graph",
                    backend: Optional[str] = None,
                    tiles: int = DEFAULT_EXCHANGE_TILES) -> DistBFSResult:
    """Multi-device BFS (bitmask-exchange advance). A PartitionedGraph
    runs the 1-D row placement (``mesh`` must have a 1-D axis named
    ``axis`` whose size equals pg.num_parts); a Partitioned2DGraph runs
    the vertex-cut 2-D placement (``axis`` may name the (row, col) axis
    pair; ``tiles`` sets the double-buffer depth of the chunked bitmask
    exchange). Labels are bit-identical to the single-device ``bfs``
    either way."""
    if isinstance(pg, Partitioned2DGraph):
        axes = _axes_arg(axis)
        _check_mesh(pg, mesh, axes)
        sg = pg.shard(mesh, axes)
        labels, it = _bfs_2d_impl(
            sg.row_offsets, sg.col_indices, sg.row_base, sg.col_base,
            jnp.int32(src), n=pg.n, vpr=pg.vpr, vpc=pg.vpc, mesh=mesh,
            axes=axes, tiles=max(int(tiles), 1),
            backend=B.resolve(backend))
        return DistBFSResult(labels=labels, iterations=it)
    sg = pg.shard(mesh, axis)            # cached device arrays per mesh
    labels, it = _bfs_dist_impl(
        sg.row_offsets, sg.col_indices, sg.vertex_base, jnp.int32(src),
        n=pg.n, vpp=pg.verts_per_part, mesh=mesh, axis=axis,
        backend=B.resolve(backend))
    return DistBFSResult(labels=labels, iterations=it)


@functools.partial(jax.jit,
                   static_argnames=("n", "vpp", "use_delta", "mesh", "axis"))
def _sssp_dist_impl(ro, ci, ev, base, src, delta, *, n: int, vpp: int,
                    use_delta: bool, mesh: Mesh, axis: str):
    part, rep = P(axis), P()
    inf = jnp.float32(jnp.inf)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(part, part, part, part, rep, rep),
        out_specs=(rep, rep),
        check_rep=False)
    def run(ro_s, ci_s, ev_s, base_s, src_v, delta_v):
        local_ro, local_ci, local_ev = ro_s[0], ci_s[0], ev_s[0]
        my_base = base_s[0]
        src_local, valid = _local_slots(local_ro, local_ci, vpp)

        def relax_step(st):
            # dense relax of the owned near-frontier rows: candidate
            # distances scatter-min locally, min-combine across devices
            # (min is exact — the atomicMin of paper §5.2 twice over)
            dist, near, far, bucket = st
            my_near = _owned_slice(near, my_base, vpp)
            my_dist = _owned_slice(dist, my_base, vpp)
            active = my_near[src_local] & valid
            cand_v = my_dist[src_local] + local_ev
            cand = jnp.full((n,), inf, jnp.float32)
            tgt = jnp.where(active, local_ci, n)
            cand = cand.at[tgt].min(jnp.where(active, cand_v, inf),
                                    mode="drop")
            cand = jax.lax.pmin(cand, axis)
            new_dist = jnp.minimum(dist, cand)
            improved = new_dist < dist
            thresh = (bucket.astype(jnp.float32) + 1.0) * delta_v
            if use_delta:
                add_near = improved & (new_dist < thresh)
                add_far = improved & (new_dist >= thresh)
            else:
                add_near = improved
                add_far = jnp.zeros_like(improved)
            far2 = (far | add_far) & ~add_near
            return new_dist, add_near, far2, bucket

        def pop_far(st):
            # near pile empty: advance the bucket to the smallest far
            # distance (replicated state ⇒ every device agrees)
            dist, near, far, bucket = st
            far_min = jnp.min(jnp.where(far, dist, inf))
            new_bucket = jnp.where(jnp.isfinite(far_min),
                                   (far_min / delta_v).astype(jnp.int32),
                                   bucket + 1)
            thresh = (new_bucket.astype(jnp.float32) + 1.0) * delta_v
            near2 = far & (dist < thresh)
            return dist, near2, far & ~near2, new_bucket

        def body(carry):
            st, it = carry
            st = jax.lax.cond(jnp.any(st[1]), relax_step, pop_far, st)
            return st, it + 1

        def cond(carry):
            (dist, near, far, bucket), it = carry
            return (jnp.any(near) | jnp.any(far)) & (it < 4 * n + 8)

        dist0 = jnp.full((n,), inf, jnp.float32).at[src_v].set(0.0)
        near0 = jnp.zeros((n,), bool).at[src_v].set(True)
        far0 = jnp.zeros((n,), bool)
        (dist, _, _, _), it = jax.lax.while_loop(
            cond, body, ((dist0, near0, far0, jnp.int32(0)), jnp.int32(0)))
        return dist, it

    return run(ro, ci, ev, base, src, delta)


@functools.partial(jax.jit,
                   static_argnames=("n", "vpr", "vpc", "use_delta",
                                    "mesh", "axes"))
def _sssp_2d_impl(ro, ci, ev, row_base, col_base, src, delta, *, n: int,
                  vpr: int, vpc: int, use_delta: bool, mesh: Mesh,
                  axes: tuple):
    row_ax, col_ax = axes
    blk, rep = P(row_ax, col_ax), P()
    inf = jnp.float32(jnp.inf)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(blk, blk, blk, P(row_ax), P(col_ax), rep, rep),
        out_specs=(rep, rep),
        check_rep=False)
    def run(ro_s, ci_s, ev_s, rb_s, cb_s, src_v, delta_v):
        block_ro, block_ci, block_ev = ro_s[0, 0], ci_s[0, 0], ev_s[0, 0]
        my_rb, my_cb = rb_s[0], cb_s[0]
        src_local, valid = _block_slots(block_ro, block_ci, vpr)

        def relax_step(st):
            # dense relax of this block's edges: candidates scatter-min
            # into the (vpc,) column chunk, min-combine the mesh row,
            # then chunks concatenate over the column axis (min is
            # exact, so the 2-D regrouping cannot move a bit)
            dist, near, far, bucket = st
            my_near = _owned_slice(near, my_rb, vpr)
            my_dist = _owned_slice(dist, my_rb, vpr)
            active = my_near[src_local] & valid
            cand_v = my_dist[src_local] + block_ev
            chunk = jnp.full((vpc,), inf, jnp.float32)
            tgt = jnp.where(active, block_ci - my_cb, vpc)
            chunk = chunk.at[tgt].min(jnp.where(active, cand_v, inf),
                                      mode="drop")
            chunk = jax.lax.pmin(chunk, row_ax)
            cand = _gather_chunks(chunk, col_ax, n)
            new_dist = jnp.minimum(dist, cand)
            improved = new_dist < dist
            thresh = (bucket.astype(jnp.float32) + 1.0) * delta_v
            if use_delta:
                add_near = improved & (new_dist < thresh)
                add_far = improved & (new_dist >= thresh)
            else:
                add_near = improved
                add_far = jnp.zeros_like(improved)
            far2 = (far | add_far) & ~add_near
            return new_dist, add_near, far2, bucket

        def pop_far(st):
            dist, near, far, bucket = st
            far_min = jnp.min(jnp.where(far, dist, inf))
            new_bucket = jnp.where(jnp.isfinite(far_min),
                                   (far_min / delta_v).astype(jnp.int32),
                                   bucket + 1)
            thresh = (new_bucket.astype(jnp.float32) + 1.0) * delta_v
            near2 = far & (dist < thresh)
            return dist, near2, far & ~near2, new_bucket

        def body(carry):
            st, it = carry
            st = jax.lax.cond(jnp.any(st[1]), relax_step, pop_far, st)
            return st, it + 1

        def cond(carry):
            (dist, near, far, bucket), it = carry
            return (jnp.any(near) | jnp.any(far)) & (it < 4 * n + 8)

        dist0 = jnp.full((n,), inf, jnp.float32).at[src_v].set(0.0)
        near0 = jnp.zeros((n,), bool).at[src_v].set(True)
        far0 = jnp.zeros((n,), bool)
        (dist, _, _, _), it = jax.lax.while_loop(
            cond, body, ((dist0, near0, far0, jnp.int32(0)), jnp.int32(0)))
        return dist, it

    return run(ro, ci, ev, row_base, col_base, src, delta)


def distributed_sssp(pg, src: int, mesh: Mesh, axis="graph",
                     delta: Optional[float] = None) -> DistSSSPResult:
    """Multi-device delta-stepping SSSP: per-bucket dense relaxation of
    owned rows (1-D) or owned blocks (2-D vertex cut) with
    min-all-reduced distance improvements. Distances are bit-identical
    to the single-device ``sssp`` (every relaxation value ``dist[u] + w``
    is computed the same way and min is exact)."""
    assert pg.edge_values is not None, "SSSP needs edge weights"
    if delta is None:
        if pg.source is not None:
            from .primitives.sssp import _auto_delta
            delta = _auto_delta(pg.source)
        else:
            import numpy as np
            real = np.asarray(pg.col_indices) >= 0
            mean_w = float(np.asarray(pg.edge_values)[real].mean())
            delta = mean_w * max(pg.m / max(pg.n, 1), 1.0) / 2.0
    use_delta = bool(jnp.isfinite(delta)) and delta > 0
    if isinstance(pg, Partitioned2DGraph):
        axes = _axes_arg(axis)
        _check_mesh(pg, mesh, axes)
        sg = pg.shard(mesh, axes)
        dist, it = _sssp_2d_impl(
            sg.row_offsets, sg.col_indices, sg.edge_values, sg.row_base,
            sg.col_base, jnp.int32(src), jnp.float32(delta),
            n=pg.n, vpr=pg.vpr, vpc=pg.vpc, use_delta=use_delta,
            mesh=mesh, axes=axes)
        return DistSSSPResult(dist=dist, iterations=it)
    sg = pg.shard(mesh, axis)
    dist, it = _sssp_dist_impl(
        sg.row_offsets, sg.col_indices, sg.edge_values, sg.vertex_base,
        jnp.int32(src), jnp.float32(delta),
        n=pg.n, vpp=pg.verts_per_part, use_delta=use_delta, mesh=mesh,
        axis=axis)
    return DistSSSPResult(dist=dist, iterations=it)


@functools.partial(jax.jit, static_argnames=("n", "vpp", "mesh", "axis"))
def _cc_dist_impl(ro, ci, base, *, n: int, vpp: int, mesh: Mesh, axis: str):
    part, rep = P(axis), P()

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(part, part, part),
        out_specs=(rep, rep),
        check_rep=False)
    def run(ro_s, ci_s, base_s):
        local_ro, local_ci = ro_s[0], ci_s[0]
        my_base = base_s[0]
        src_local, valid = _local_slots(local_ro, local_ci, vpp)
        src_g = my_base + src_local
        dst = jnp.where(valid, local_ci, 0)

        def pointer_jump(cid):
            return jax.lax.while_loop(lambda c: jnp.any(c[c] != c),
                                      lambda c: c[c], cid)

        def body(carry):
            cid, live, n_live, it = carry
            cu = cid[src_g]
            cv = cid[dst]
            live = live & (cu != cv)
            lo = jnp.minimum(cu, cv)
            hi = jnp.maximum(cu, cv)
            # hooking: scatter-min the local live edges, min-combine the
            # label candidates across devices (all-reduced label mins)
            tgt = jnp.where(live, hi, n)
            cand = jnp.full((n,), INT_BIG, jnp.int32)
            cand = cand.at[tgt].min(jnp.where(live, lo, INT_BIG),
                                    mode="drop")
            cand = jax.lax.pmin(cand, axis)
            cid = pointer_jump(jnp.minimum(cid, cand))
            still = live & (cid[src_g] != cid[dst])
            n_live = jax.lax.psum(
                jnp.sum(still, dtype=jnp.int32), axis)
            return cid, still, n_live, it + 1

        def cond(carry):
            _, _, n_live, it = carry
            return (n_live > 0) & (it < n + 1)

        cid0 = jnp.arange(n, dtype=jnp.int32)
        cid, _, _, it = jax.lax.while_loop(
            cond, body,
            (cid0, valid, jnp.int32(1), jnp.int32(0)))
        return cid, it

    labels, it = run(ro, ci, base)
    ncomp = jnp.sum(labels == jnp.arange(n), dtype=jnp.int32)
    return labels, ncomp, it


@functools.partial(jax.jit, static_argnames=("n", "vpr", "mesh", "axes"))
def _cc_2d_impl(ro, ci, row_base, *, n: int, vpr: int, mesh: Mesh,
                axes: tuple):
    row_ax, col_ax = axes
    blk, rep = P(row_ax, col_ax), P()

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(blk, blk, P(row_ax)),
        out_specs=(rep, rep),
        check_rep=False)
    def run(ro_s, ci_s, rb_s):
        block_ro, block_ci = ro_s[0, 0], ci_s[0, 0]
        my_rb = rb_s[0]
        src_local, valid = _block_slots(block_ro, block_ci, vpr)
        src_g = my_rb + src_local
        dst = jnp.where(valid, block_ci, 0)

        def pointer_jump(cid):
            return jax.lax.while_loop(lambda c: jnp.any(c[c] != c),
                                      lambda c: c[c], cid)

        def body(carry):
            cid, live, n_live, it = carry
            cu = cid[src_g]
            cv = cid[dst]
            live = live & (cu != cv)
            lo = jnp.minimum(cu, cv)
            hi = jnp.maximum(cu, cv)
            # hooking: labels target arbitrary component ids, so the
            # candidate vector stays (n,) and min-combines over the
            # WHOLE mesh (both axes) — a vertex cut cannot shrink this
            # exchange, which exchange_bytes_per_step reports honestly
            tgt = jnp.where(live, hi, n)
            cand = jnp.full((n,), INT_BIG, jnp.int32)
            cand = cand.at[tgt].min(jnp.where(live, lo, INT_BIG),
                                    mode="drop")
            cand = jax.lax.pmin(cand, (row_ax, col_ax))
            cid = pointer_jump(jnp.minimum(cid, cand))
            still = live & (cid[src_g] != cid[dst])
            n_live = jax.lax.psum(jnp.sum(still, dtype=jnp.int32),
                                  (row_ax, col_ax))
            return cid, still, n_live, it + 1

        def cond(carry):
            _, _, n_live, it = carry
            return (n_live > 0) & (it < n + 1)

        cid0 = jnp.arange(n, dtype=jnp.int32)
        cid, _, _, it = jax.lax.while_loop(
            cond, body,
            (cid0, valid, jnp.int32(1), jnp.int32(0)))
        return cid, it

    labels, it = run(ro, ci, row_base)
    ncomp = jnp.sum(labels == jnp.arange(n), dtype=jnp.int32)
    return labels, ncomp, it


def distributed_cc(pg, mesh: Mesh, axis="graph") -> DistCCResult:
    """Multi-device connected components: hooking over owned edges (1-D
    rows or 2-D blocks) with all-reduced label mins + replicated
    pointer-jumping. Labels are bit-identical to the single-device
    ``connected_components`` (every combine is an exact integer min)."""
    if isinstance(pg, Partitioned2DGraph):
        axes = _axes_arg(axis)
        _check_mesh(pg, mesh, axes)
        sg = pg.shard(mesh, axes)
        labels, ncomp, it = _cc_2d_impl(
            sg.row_offsets, sg.col_indices, sg.row_base,
            n=pg.n, vpr=pg.vpr, mesh=mesh, axes=axes)
        return DistCCResult(labels=labels, num_components=ncomp,
                            iterations=it)
    sg = pg.shard(mesh, axis)
    labels, ncomp, it = _cc_dist_impl(
        sg.row_offsets, sg.col_indices, sg.vertex_base,
        n=pg.n, vpp=pg.verts_per_part, mesh=mesh, axis=axis)
    return DistCCResult(labels=labels, num_components=ncomp, iterations=it)


def distributed_pagerank(pg, mesh: Mesh, axis="graph",
                         damping: float = 0.85,
                         iters: int = 20) -> jax.Array:
    """SpMV PageRank through the sharded/2d "spmv" provider: the rank
    vector stays replicated, each device reduces its owned CSC rows
    (1-D) or ⊕-merges its CSC block's pre-fold products (2-D). This
    runs the SAME ``_pagerank_impl`` as the single-device primitive —
    only the dispatched spmv differs — so ranks are bit-identical to
    ``pagerank``, not merely close."""
    from .primitives.pagerank import pagerank
    _check_mesh(pg, mesh, axis)
    if not pg.has_csc:
        raise ValueError(
            "distributed_pagerank needs the partitioned CSC mirror; "
            "partition a Graph built with build_csc=True")
    return pagerank(_shard_any(pg, mesh, axis), damping=damping,
                    max_iter=iters).rank


# ---------------------------------------------------------------------------
# algebraic primitives on a partition (delegate to the Graph primitives —
# they dispatch through the sharded providers via ShardedGraph)
# ---------------------------------------------------------------------------


def distributed_label_propagation(pg, mesh: Mesh, axis="graph",
                                  **kwargs):
    """Label propagation on the partition (1-D or 2-D): the one-hot
    SpMM blocks run through the placement's "spmm" provider; labels
    bit-match the single-device primitive (the vote sums are
    small-integer-valued floats, exact under any regrouping)."""
    from .primitives.label_propagation import label_propagation
    _check_mesh(pg, mesh, axis)
    return label_propagation(_shard_any(pg, mesh, axis), **kwargs)


def distributed_reach(pg, srcs, k: int = 3, *,
                      mesh: Mesh, axis="graph", **kwargs):
    """Batched k-hop reachability on the partition (or-and SpMM closure
    through the placement's provider)."""
    from .primitives.reach import reach_batch
    _check_mesh(pg, mesh, axis)
    return reach_batch(_shard_any(pg, mesh, axis), srcs, k, **kwargs)


# ---------------------------------------------------------------------------
# comm-volume model (the benchmark's bytes-per-step column)
# ---------------------------------------------------------------------------


def exchange_bytes_per_step(pg, primitive: str = "bfs",
                            tiles: int = DEFAULT_EXCHANGE_TILES) -> int:
    """Analytic bytes exchanged PER DEVICE in one BSP step of
    ``primitive`` under ``pg``'s placement, with the standard ring
    cost model (an all-reduce of b bytes moves 2·(p−1)/p·b per device;
    an all-gather of b-byte shards moves (p−1)·b).

    1-D exchanges are n-proportional (the replicated-vector tax the
    2-D cut removes): bfs/sssp/cc all-reduce an (n,) candidate vector,
    pagerank all-gathers its (n/p,) spmv output shard. 2-D traversal
    exchanges are chunk-proportional: bfs psums ``tiles`` uint8
    (vpc,)-chunk tiles along the R-row and gathers C chunks; sssp the
    float32 twin; pagerank trades them for a (chunk_emax,) product
    psum along the column axis plus the output-row gather. cc hooks
    into arbitrary component ids, so its exchange stays (n,) on any
    mesh — reported as-is, not hidden."""
    tiles = max(int(tiles), 1)
    n = pg.n
    if isinstance(pg, Partitioned2DGraph):
        r, c = pg.rows, pg.cols
        if primitive == "bfs":
            return int(tiles * 2 * (r - 1) / r * pg.vpc
                       + (c - 1) * pg.vpc)
        if primitive == "sssp":
            return int((2 * (r - 1) / r * pg.vpc
                        + (c - 1) * pg.vpc) * 4)
        if primitive == "cc":
            p = r * c
            return int(2 * (p - 1) / p * n * 4)
        if primitive == "pagerank":
            return int(2 * (c - 1) / c * pg.csc_chunk_emax * 4
                       + (r - 1) * pg.vpr * 4)
        raise ValueError(f"unknown primitive {primitive!r}")
    p = pg.num_parts
    if primitive in ("bfs", "sssp", "cc"):
        return int(2 * (p - 1) / p * n * 4)
    if primitive == "pagerank":
        return int((p - 1) / p * n * 4)
    raise ValueError(f"unknown primitive {primitive!r}")
