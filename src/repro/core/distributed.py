"""Distributed graph primitives over a device mesh (paper §8.2.1).

Gunrock's multi-GPU design [56] keeps the single-GPU engine unchanged and
adds communication + partition modules; we do the same. The 1-D partition
(partition.py) gives each device a CSR slice; traversal exchanges frontier
information with mesh collectives inside `shard_map`:

  * push advance  — each device expands its owned frontier slice, marks
    discovered destinations in a *global* bitmask, and the masks are
    OR-combined with an all-reduce (`jax.lax.psum` on bools). This is the
    bitmask-exchange strategy: O(n) bytes/device/iteration, independent of
    frontier raggedness — the BSP-safe translation of Gunrock's frontier
    segment exchange (which needed peer-to-peer queues).
  * PageRank — classic 1-D SpMV: all-gather the rank vector, reduce owned
    rows locally (the contribution sweep stays fully local).

These run on any 1-D mesh axis ("graph"), including the flattened
data×model axes of the production mesh.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .partition import PartitionedGraph


class DistBFSResult(NamedTuple):
    labels: jax.Array      # (n,) global depths
    iterations: jax.Array


def _local_expand_mask(local_ro, local_ci, frontier_slice, n, vpp, base):
    """Expand the owned frontier slice; return a global discovered bitmask.

    frontier_slice: (vpp,) bool of owned active vertices.
    Dense formulation: every local CSR slot whose source vertex is active
    marks its destination. Source of local slot e = searchsorted(ro, e).
    """
    me = local_ci.shape[0]
    slot = jnp.arange(me, dtype=jnp.int32)
    src_local = jnp.searchsorted(local_ro, slot, side="right") - 1
    src_local = jnp.clip(src_local, 0, vpp - 1)
    valid = (slot < local_ro[-1]) & (local_ci >= 0)
    active = frontier_slice[src_local] & valid
    mask = jnp.zeros((n,), bool)
    tgt = jnp.where(active, local_ci, n)
    mask = mask.at[tgt].set(True, mode="drop")
    return mask


def distributed_bfs(pg: PartitionedGraph, src: int, mesh: Mesh,
                    axis: str = "graph") -> DistBFSResult:
    """Multi-device BFS. `mesh` must have a 1-D axis named ``axis`` whose
    size equals pg.num_parts."""
    n, vpp, p = pg.n, pg.verts_per_part, pg.num_parts
    assert mesh.shape[axis] == p

    ro = jnp.asarray(pg.row_offsets)
    ci = jnp.asarray(pg.col_indices)
    base = jnp.asarray(pg.vertex_base)

    part = P(axis)
    rep = P()

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(part, part, part, rep),
        out_specs=(rep, rep),
        check_rep=False)
    def run(ro_s, ci_s, base_s, src_v):
        local_ro = ro_s[0]
        local_ci = ci_s[0]
        my_base = base_s[0]

        def cond(carry):
            labels, frontier, it = carry
            return jnp.any(frontier) & (it <= n)

        def body(carry):
            labels, frontier, it = carry
            my_slice = jax.lax.dynamic_slice(frontier, (my_base,), (vpp,))
            disc = _local_expand_mask(local_ro, local_ci, my_slice, n, vpp,
                                      my_base)
            # OR-combine discoveries across devices (frontier exchange)
            disc = jax.lax.psum(disc.astype(jnp.int32), axis) > 0
            new = disc & (labels < 0)
            labels = jnp.where(new, it + 1, labels)
            return labels, new, it + 1

        labels0 = jnp.full((n,), -1, jnp.int32).at[src_v].set(0)
        frontier0 = jnp.zeros((n,), bool).at[src_v].set(True)
        labels, _, it = jax.lax.while_loop(cond, body,
                                           (labels0, frontier0,
                                            jnp.int32(0)))
        return labels, it

    labels, it = jax.jit(run)(ro, ci, base, jnp.int32(src))
    return DistBFSResult(labels=labels, iterations=it)


def distributed_pagerank(pg: PartitionedGraph, mesh: Mesh,
                         axis: str = "graph", damping: float = 0.85,
                         iters: int = 20) -> jax.Array:
    """1-D SpMV PageRank: rank vector all-gathered, rows reduced locally.

    Pull formulation needs in-edges; with an out-edge partition we instead
    push locally then all-reduce partial accumulations — communication is
    one psum of (n,) floats per iteration.
    """
    n, vpp, p = pg.n, pg.verts_per_part, pg.num_parts
    ro = jnp.asarray(pg.row_offsets)
    ci = jnp.asarray(pg.col_indices)
    base = jnp.asarray(pg.vertex_base)
    # global out-degrees (host-side from partition)
    import numpy as np
    degs = np.zeros(n, np.int32)
    for q in range(p):
        local_deg = np.diff(np.asarray(pg.row_offsets[q]))
        lo = int(pg.vertex_base[q])
        hi = min(lo + vpp, n)
        degs[lo:hi] = local_deg[:hi - lo]
    deg = jnp.asarray(degs, jnp.float32)

    part = P(axis)
    rep = P()

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(part, part, part, rep),
        out_specs=rep,
        check_rep=False)
    def run(ro_s, ci_s, base_s, deg_g):
        local_ro = ro_s[0]
        local_ci = ci_s[0]
        my_base = base_s[0]
        me = local_ci.shape[0]
        slot = jnp.arange(me, dtype=jnp.int32)
        src_local = jnp.searchsorted(local_ro, slot, side="right") - 1
        src_local = jnp.clip(src_local, 0, vpp - 1)
        valid = (slot < local_ro[-1]) & (local_ci >= 0)

        def body(_, pr):
            contrib = jnp.where(deg_g > 0, pr / jnp.maximum(deg_g, 1.), 0.)
            my_contrib = jax.lax.dynamic_slice(contrib, (my_base,), (vpp,))
            vals = jnp.where(valid, my_contrib[src_local], 0.0)
            acc = jnp.zeros((n,), jnp.float32)
            acc = acc.at[jnp.where(valid, local_ci, n)].add(vals,
                                                            mode="drop")
            acc = jax.lax.psum(acc, axis)
            dangling = jnp.sum(jnp.where(deg_g == 0, pr, 0.0)) / n
            return (1.0 - damping) / n + damping * (acc + dangling)

        pr0 = jnp.full((n,), 1.0 / n, jnp.float32)
        return jax.lax.fori_loop(0, iters, body, pr0)

    return jax.jit(run)(ro, ci, base, deg)
