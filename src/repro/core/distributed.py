"""Distributed graph primitives + the sharded registry providers
(paper §8.2.1; Pan et al. [56]).

Gunrock's multi-GPU design keeps the single-GPU engine unchanged and
adds communication + partition modules; we do the same, but behind the
backend registry's *placement* dimension: this module registers the
``placement="sharded"`` providers for the operator hot paths, so the
same dispatch that picks xla-vs-pallas kernels also picks
single-vs-mesh execution.

The 1-D partition (partition.py) gives each device a CSR slice (and a
CSC slice when the source graph carries the mirror); the providers run
under ``shard_map`` with two exchange strategies:

  * "advance" (sharded) — bitmask exchange: each device expands its
    owned frontier slice into a *global* discovered bitmask and the
    masks are OR-combined with an all-reduce. O(n) bytes/device/step,
    independent of frontier raggedness — the BSP-safe translation of
    Gunrock's frontier segment exchange (which needed p2p queues).
    Contract (called INSIDE an active shard_map):
      (local_ro (vpp+1,), local_ci (me,), frontier (n,), base (),
       vpp, axis) → (n,) bool discovered mask, already all-reduced.
  * "spmv"/"spmm" (sharded) — classic 1-D row-partitioned products:
    the dense operand stays replicated (the all-gather side), each
    device reduces its owned rows locally with exactly the
    single-device gather+segment formulation, and the row blocks
    concatenate — no reduction crosses devices, so results are
    bit-identical to the single-device sweep. Same positional contract
    as the single providers, with (p, …) stacked CSR operands.
  * "mxm" (sharded) — 1-D SpGEMM: the expansion side is row-partitioned
    (each device expands the mask edges whose base row it owns), the
    probe side stays replicated, and per-edge partials ⊕-combine across
    the mesh (disjoint ownership ⇒ identity merge ⇒ bit parity).

Traversal loops (BFS / SSSP / CC) run whole-loop inside one shard_map
with replicated (n,)-sized state and local edge sweeps; every state
update is an exact min/OR combine, so labels and distances bit-match
the single-device primitives. All impls are module-level jits with the
mesh as a static argument — repeated calls (the serving driver) reuse
one trace per (shape, mesh).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from . import backend as B
from .partition import PartitionedGraph, check_mesh_axis

# a plain Python int on purpose: this module is imported LAZILY by the
# registry, possibly in the middle of someone else's jit trace, and a
# module-level jnp constant created there would be a leaked tracer
INT_BIG = 2 ** 30


class DistBFSResult(NamedTuple):
    labels: jax.Array      # (n,) global depths
    iterations: jax.Array


class DistSSSPResult(NamedTuple):
    dist: jax.Array        # (n,) float32 distances
    iterations: jax.Array


class DistCCResult(NamedTuple):
    labels: jax.Array
    num_components: jax.Array
    iterations: jax.Array


def _check_mesh(pg: PartitionedGraph, mesh: Mesh, axis: str) -> None:
    check_mesh_axis(mesh, axis, pg.num_parts)


def _require_placement_mesh():
    ctx = B.placement_mesh()
    if ctx is None:
        raise RuntimeError(
            "sharded dispatch needs an active placement context that "
            "carries a mesh: with backend.use_placement('sharded', "
            "mesh=mesh, axis='graph'): ...")
    return ctx


def _all_reduce(sr, x: jax.Array, axis: str) -> jax.Array:
    """⊕-combine per-device partials across the mesh axis."""
    if sr.add == "plus":
        return jax.lax.psum(x, axis)
    if sr.add == "min":
        return jax.lax.pmin(x, axis)
    return jax.lax.pmax(x, axis)          # max | or


# ---------------------------------------------------------------------------
# local sweeps (the per-device half of each exchange strategy)
# ---------------------------------------------------------------------------


def _local_slots(local_ro: jax.Array, local_ci: jax.Array, vpp: int):
    """Map local CSR slots back to (local source row, validity)."""
    me = local_ci.shape[0]
    slot = jnp.arange(me, dtype=jnp.int32)
    src_local = jnp.searchsorted(local_ro, slot, side="right") - 1
    src_local = jnp.clip(src_local, 0, vpp - 1).astype(jnp.int32)
    valid = (slot < local_ro[-1]) & (local_ci >= 0)
    return src_local, valid


def _local_expand_mask(local_ro, local_ci, frontier_slice, n, vpp):
    """Expand the owned frontier slice; return a global discovered bitmask.

    frontier_slice: (vpp,) bool of owned active vertices.
    Dense formulation: every local CSR slot whose source vertex is active
    marks its destination. Source of local slot e = searchsorted(ro, e).
    """
    src_local, valid = _local_slots(local_ro, local_ci, vpp)
    active = frontier_slice[src_local] & valid
    mask = jnp.zeros((n,), bool)
    tgt = jnp.where(active, local_ci, n)
    mask = mask.at[tgt].set(True, mode="drop")
    return mask


# ---------------------------------------------------------------------------
# sharded registry providers
# ---------------------------------------------------------------------------


@B.register("advance", B.XLA, B.SHARDED)
def _advance_bitmask_exchange(local_ro, local_ci, frontier, base, vpp: int,
                              axis: str):
    """Bitmask-exchange advance step — see the module docstring contract.
    Must be called inside an active shard_map over ``axis``."""
    n = frontier.shape[0]
    my_slice = jax.lax.dynamic_slice(frontier, (base,), (vpp,))
    disc = _local_expand_mask(local_ro, local_ci, my_slice, n, vpp)
    return jax.lax.psum(disc.astype(jnp.int32), axis) > 0


@B.register("spmm", B.XLA, B.SHARDED)
def _spmm_sharded(offsets, indices, values, x, sr, ell_width, mask,
                  row_seg=None):
    """1-D row-partitioned semiring SpMM: Y⟨mask⟩ = A ⊗ X.

    ``offsets``/``indices``/``values`` are (p, …) stacked per-device row
    slices; ``x`` (n, k) and ``mask`` (n,) stay replicated. Each device
    reduces its owned rows with the single-device gather+segment
    formulation (bit parity); row blocks concatenate over the mesh axis.
    Requires a square operand (the 1-D vertex partition), i.e.
    x.shape[0] == the global row count.
    """
    del ell_width                      # single-pallas-only metadata
    del row_seg     # per-shard edge->row maps are derived locally below
    mesh, axis = _require_placement_mesh()
    vpp = int(offsets.shape[1]) - 1
    n = int(x.shape[0])
    part, rep = P(axis), P()

    def local_rows(ro_s, ci_s, ev_s, xg):
        ro, ci = ro_s[0], ci_s[0]
        src_local, valid = _local_slots(ro, ci, vpp)
        xv = xg[jnp.where(valid, ci, 0)]                       # (me, k)
        ev = None if ev_s is None else ev_s[0]
        prod = xv if ev is None else sr.mul_op(ev[:, None], xv)
        prod = jnp.where(valid[:, None], prod, sr.zero)
        y = sr.segment_reduce(prod.astype(jnp.float32), src_local, vpp,
                              indices_are_sorted=True)
        deg = ro[1:] - ro[:-1]
        return jnp.where((deg > 0)[:, None], y, sr.zero)

    if values is None:
        run = shard_map(lambda ro, ci, xg: local_rows(ro, ci, None, xg),
                        mesh=mesh, in_specs=(part, part, rep),
                        out_specs=part, check_rep=False)
        y = run(offsets, indices, x)
    else:
        run = shard_map(local_rows, mesh=mesh,
                        in_specs=(part, part, part, rep),
                        out_specs=part, check_rep=False)
        y = run(offsets, indices, values, x)
    y = y[:n]                                   # drop tail-part padding rows
    if mask is not None:
        y = jnp.where(mask[:, None], y, sr.zero)
    return y.astype(jnp.float32)


@B.register("spmv", B.XLA, B.SHARDED)
def _spmv_sharded(offsets, indices, values, x, sr, ell_width, mask,
                  row_seg=None, over_pos=None, over_row=None):
    """1-D row-partitioned semiring SpMV.

    With ``ell_width`` metadata (a ShardedGraph built from a
    ``Graph.from_csr`` source) each device runs the SAME hybrid
    ELL-tree + overflow-fold as the single-device sweep on its local row
    slice — identical per-row fold dataflow, so bits match across
    placements (the PR-4 parity discipline). The compacted overflow
    lists have no stacked counterpart, so shards take the masked
    drop-scatter flavour (same per-row edge sequence, same bits; the
    sharded path is a parity/serving path, not the single-device hot
    loop). Without metadata, falls back to the k=1 SpMM column.
    """
    del row_seg, over_pos, over_row        # derived/absent per shard
    if ell_width is None:
        return _spmm_sharded(offsets, indices, values, x[:, None], sr,
                             None, mask)[:, 0]
    from repro.linalg.ops import hybrid_ell_reduce
    mesh, axis = _require_placement_mesh()
    vpp = int(offsets.shape[1]) - 1
    n = int(x.shape[0])
    part, rep = P(axis), P()

    def local_rows(ro_s, ci_s, ev_s, xg):
        ro, ci = ro_s[0], ci_s[0]
        ev = None if ev_s is None else ev_s[0]
        me = ci.shape[0]
        edge_valid = jnp.arange(me, dtype=jnp.int32) < ro[-1]
        y = hybrid_ell_reduce(ro, ci, ev, xg, sr, int(ell_width),
                              edge_valid=edge_valid)
        deg = ro[1:] - ro[:-1]
        return jnp.where(deg > 0, y, sr.zero)

    if values is None:
        run = shard_map(lambda ro, ci, xg: local_rows(ro, ci, None, xg),
                        mesh=mesh, in_specs=(part, part, rep),
                        out_specs=part, check_rep=False)
        y = run(offsets, indices, x)
    else:
        run = shard_map(local_rows, mesh=mesh,
                        in_specs=(part, part, part, rep),
                        out_specs=part, check_rep=False)
        y = run(offsets, indices, values, x)
    y = y[:n]
    if mask is not None:
        y = jnp.where(mask, y, sr.zero)
    return y.astype(jnp.float32)


@B.register("mxm", B.XLA, B.SHARDED)
def _mxm_sharded(a_off, a_idx, a_vals, bt_off, bt_idx, bt_vals,
                 base, probe_rows, sr, cap_out: int):
    """1-D masked SpGEMM: the expansion side (A) is row-partitioned, the
    probe side (Bᵀ) replicated. Each device LB-expands the mask edges
    whose ``base`` row it owns and probes the replicated structure;
    per-edge partials ⊕-combine across the mesh (ownership is disjoint,
    so the combine only merges identities — bit parity with the
    single-device dot formulation)."""
    from . import operators as _ops
    mesh, axis = _require_placement_mesh()
    vpp = int(a_off.shape[1]) - 1
    e = int(base.shape[0])
    part, rep = P(axis), P()
    # one shard_map signature serves the structural/valued combinations:
    # absent value operands ride as zero-size placeholders, the closure
    # flags decide whether the slots index them
    has_av = a_vals is not None
    has_btv = bt_vals is not None
    av_in = (a_vals if has_av
             else jnp.zeros((int(a_off.shape[0]), 0), jnp.float32))
    btv_in = bt_vals if has_btv else jnp.zeros((0,), jnp.float32)

    def local(ao_s, ai_s, av_s, bto, bti, btv, base_g, rows_g):
        ao, ai = ao_s[0], ai_s[0]
        me = int(ai.shape[0])
        my_base = jax.lax.axis_index(axis).astype(jnp.int32) * vpp
        owned = (base_g >= my_base) & (base_g < my_base + vpp)
        base_l = jnp.where(owned, base_g - my_base, 0)
        deg = ao[base_l + 1] - ao[base_l]
        sizes = jnp.where(owned, deg, 0).astype(jnp.int32)
        _, needles, eid, pair, _, valid, _ = _ops._advance_xla(
            ao, ai, base_l, sizes, cap_out)
        rows = rows_g[pair]
        pos = _ops._searchsorted_segment(bti, bto[rows], bto[rows + 1],
                                         needles, locate=True)
        found = (pos >= 0) & valid
        sv = (av_s[0][jnp.clip(eid, 0, me - 1)] if has_av
              else jnp.float32(sr.one))
        lv = (btv[jnp.clip(pos, 0, int(bti.shape[0]) - 1)] if has_btv
              else jnp.float32(sr.one))
        prod = jnp.where(found, sr.mul_op(sv, lv), sr.zero)
        c = sr.segment_reduce(prod.astype(jnp.float32), pair, e,
                              indices_are_sorted=True)
        c = _all_reduce(sr, c, axis)
        gsizes = jax.lax.psum(sizes, axis)
        return jnp.where(gsizes > 0, c, sr.zero).astype(jnp.float32)

    run = shard_map(local, mesh=mesh,
                    in_specs=(part, part, part, rep, rep, rep, rep, rep),
                    out_specs=rep, check_rep=False)
    return run(a_off, a_idx, av_in, bt_off, bt_idx, btv_in, base,
               probe_rows)


# ---------------------------------------------------------------------------
# traversal primitives (whole loop inside one shard_map)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("n", "vpp", "mesh", "axis", "backend"))
def _bfs_dist_impl(ro, ci, base, src, *, n: int, vpp: int, mesh: Mesh,
                   axis: str, backend: str):
    expand = B.dispatch("advance", backend, B.SHARDED)
    part, rep = P(axis), P()

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(part, part, part, rep),
        out_specs=(rep, rep),
        check_rep=False)
    def run(ro_s, ci_s, base_s, src_v):
        local_ro = ro_s[0]
        local_ci = ci_s[0]
        my_base = base_s[0]

        def cond(carry):
            labels, frontier, it = carry
            return jnp.any(frontier) & (it <= n)

        def body(carry):
            labels, frontier, it = carry
            # bitmask-exchange advance (OR-combined across devices)
            disc = expand(local_ro, local_ci, frontier, my_base, vpp,
                          axis)
            new = disc & (labels < 0)
            labels = jnp.where(new, it + 1, labels)
            return labels, new, it + 1

        labels0 = jnp.full((n,), -1, jnp.int32).at[src_v].set(0)
        frontier0 = jnp.zeros((n,), bool).at[src_v].set(True)
        labels, _, it = jax.lax.while_loop(cond, body,
                                           (labels0, frontier0,
                                            jnp.int32(0)))
        return labels, it

    return run(ro, ci, base, src)


def distributed_bfs(pg: PartitionedGraph, src: int, mesh: Mesh,
                    axis: str = "graph",
                    backend: Optional[str] = None) -> DistBFSResult:
    """Multi-device BFS (bitmask-exchange advance). `mesh` must have a
    1-D axis named ``axis`` whose size equals pg.num_parts. Labels are
    bit-identical to the single-device ``bfs``."""
    sg = pg.shard(mesh, axis)            # cached device arrays per mesh
    labels, it = _bfs_dist_impl(
        sg.row_offsets, sg.col_indices, sg.vertex_base, jnp.int32(src),
        n=pg.n, vpp=pg.verts_per_part, mesh=mesh, axis=axis,
        backend=B.resolve(backend))
    return DistBFSResult(labels=labels, iterations=it)


@functools.partial(jax.jit,
                   static_argnames=("n", "vpp", "use_delta", "mesh", "axis"))
def _sssp_dist_impl(ro, ci, ev, base, src, delta, *, n: int, vpp: int,
                    use_delta: bool, mesh: Mesh, axis: str):
    part, rep = P(axis), P()
    inf = jnp.float32(jnp.inf)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(part, part, part, part, rep, rep),
        out_specs=(rep, rep),
        check_rep=False)
    def run(ro_s, ci_s, ev_s, base_s, src_v, delta_v):
        local_ro, local_ci, local_ev = ro_s[0], ci_s[0], ev_s[0]
        my_base = base_s[0]
        src_local, valid = _local_slots(local_ro, local_ci, vpp)

        def relax_step(st):
            # dense relax of the owned near-frontier rows: candidate
            # distances scatter-min locally, min-combine across devices
            # (min is exact — the atomicMin of paper §5.2 twice over)
            dist, near, far, bucket = st
            my_near = jax.lax.dynamic_slice(near, (my_base,), (vpp,))
            my_dist = jax.lax.dynamic_slice(dist, (my_base,), (vpp,))
            active = my_near[src_local] & valid
            cand_v = my_dist[src_local] + local_ev
            cand = jnp.full((n,), inf, jnp.float32)
            tgt = jnp.where(active, local_ci, n)
            cand = cand.at[tgt].min(jnp.where(active, cand_v, inf),
                                    mode="drop")
            cand = jax.lax.pmin(cand, axis)
            new_dist = jnp.minimum(dist, cand)
            improved = new_dist < dist
            thresh = (bucket.astype(jnp.float32) + 1.0) * delta_v
            if use_delta:
                add_near = improved & (new_dist < thresh)
                add_far = improved & (new_dist >= thresh)
            else:
                add_near = improved
                add_far = jnp.zeros_like(improved)
            far2 = (far | add_far) & ~add_near
            return new_dist, add_near, far2, bucket

        def pop_far(st):
            # near pile empty: advance the bucket to the smallest far
            # distance (replicated state ⇒ every device agrees)
            dist, near, far, bucket = st
            far_min = jnp.min(jnp.where(far, dist, inf))
            new_bucket = jnp.where(jnp.isfinite(far_min),
                                   (far_min / delta_v).astype(jnp.int32),
                                   bucket + 1)
            thresh = (new_bucket.astype(jnp.float32) + 1.0) * delta_v
            near2 = far & (dist < thresh)
            return dist, near2, far & ~near2, new_bucket

        def body(carry):
            st, it = carry
            st = jax.lax.cond(jnp.any(st[1]), relax_step, pop_far, st)
            return st, it + 1

        def cond(carry):
            (dist, near, far, bucket), it = carry
            return (jnp.any(near) | jnp.any(far)) & (it < 4 * n + 8)

        dist0 = jnp.full((n,), inf, jnp.float32).at[src_v].set(0.0)
        near0 = jnp.zeros((n,), bool).at[src_v].set(True)
        far0 = jnp.zeros((n,), bool)
        (dist, _, _, _), it = jax.lax.while_loop(
            cond, body, ((dist0, near0, far0, jnp.int32(0)), jnp.int32(0)))
        return dist, it

    return run(ro, ci, ev, base, src, delta)


def distributed_sssp(pg: PartitionedGraph, src: int, mesh: Mesh,
                     axis: str = "graph",
                     delta: Optional[float] = None) -> DistSSSPResult:
    """Multi-device delta-stepping SSSP: per-bucket dense relaxation of
    owned rows with min-all-reduced distance improvements. Distances are
    bit-identical to the single-device ``sssp`` (every relaxation value
    ``dist[u] + w`` is computed the same way and min is exact)."""
    assert pg.edge_values is not None, "SSSP needs edge weights"
    sg = pg.shard(mesh, axis)
    if delta is None:
        if pg.source is not None:
            from .primitives.sssp import _auto_delta
            delta = _auto_delta(pg.source)
        else:
            import numpy as np
            real = np.asarray(pg.col_indices) >= 0
            mean_w = float(np.asarray(pg.edge_values)[real].mean())
            delta = mean_w * max(pg.m / max(pg.n, 1), 1.0) / 2.0
    use_delta = bool(jnp.isfinite(delta)) and delta > 0
    dist, it = _sssp_dist_impl(
        sg.row_offsets, sg.col_indices, sg.edge_values, sg.vertex_base,
        jnp.int32(src), jnp.float32(delta),
        n=pg.n, vpp=pg.verts_per_part, use_delta=use_delta, mesh=mesh,
        axis=axis)
    return DistSSSPResult(dist=dist, iterations=it)


@functools.partial(jax.jit, static_argnames=("n", "vpp", "mesh", "axis"))
def _cc_dist_impl(ro, ci, base, *, n: int, vpp: int, mesh: Mesh, axis: str):
    part, rep = P(axis), P()

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(part, part, part),
        out_specs=(rep, rep),
        check_rep=False)
    def run(ro_s, ci_s, base_s):
        local_ro, local_ci = ro_s[0], ci_s[0]
        my_base = base_s[0]
        src_local, valid = _local_slots(local_ro, local_ci, vpp)
        src_g = my_base + src_local
        dst = jnp.where(valid, local_ci, 0)

        def pointer_jump(cid):
            return jax.lax.while_loop(lambda c: jnp.any(c[c] != c),
                                      lambda c: c[c], cid)

        def body(carry):
            cid, live, n_live, it = carry
            cu = cid[src_g]
            cv = cid[dst]
            live = live & (cu != cv)
            lo = jnp.minimum(cu, cv)
            hi = jnp.maximum(cu, cv)
            # hooking: scatter-min the local live edges, min-combine the
            # label candidates across devices (all-reduced label mins)
            tgt = jnp.where(live, hi, n)
            cand = jnp.full((n,), INT_BIG, jnp.int32)
            cand = cand.at[tgt].min(jnp.where(live, lo, INT_BIG),
                                    mode="drop")
            cand = jax.lax.pmin(cand, axis)
            cid = pointer_jump(jnp.minimum(cid, cand))
            still = live & (cid[src_g] != cid[dst])
            n_live = jax.lax.psum(jnp.sum(still.astype(jnp.int32)), axis)
            return cid, still, n_live, it + 1

        def cond(carry):
            _, _, n_live, it = carry
            return (n_live > 0) & (it < n + 1)

        cid0 = jnp.arange(n, dtype=jnp.int32)
        cid, _, _, it = jax.lax.while_loop(
            cond, body,
            (cid0, valid, jnp.int32(1), jnp.int32(0)))
        return cid, it

    labels, it = run(ro, ci, base)
    ncomp = jnp.sum((labels == jnp.arange(n)).astype(jnp.int32))
    return labels, ncomp, it


def distributed_cc(pg: PartitionedGraph, mesh: Mesh,
                   axis: str = "graph") -> DistCCResult:
    """Multi-device connected components: hooking over owned edges with
    all-reduced label mins + replicated pointer-jumping. Labels are
    bit-identical to the single-device ``connected_components`` (every
    combine is an exact integer min)."""
    sg = pg.shard(mesh, axis)
    labels, ncomp, it = _cc_dist_impl(
        sg.row_offsets, sg.col_indices, sg.vertex_base,
        n=pg.n, vpp=pg.verts_per_part, mesh=mesh, axis=axis)
    return DistCCResult(labels=labels, num_components=ncomp, iterations=it)


def distributed_pagerank(pg: PartitionedGraph, mesh: Mesh,
                         axis: str = "graph", damping: float = 0.85,
                         iters: int = 20) -> jax.Array:
    """1-D SpMV PageRank through the sharded "spmv" provider: the rank
    vector stays replicated (the all-gather side of a 1-D SpMV), each
    device reduces its owned CSC rows locally. This runs the SAME
    ``_pagerank_impl`` as the single-device primitive — only the
    dispatched spmv differs — so ranks are bit-identical to
    ``pagerank``, not merely close."""
    from .primitives.pagerank import pagerank
    _check_mesh(pg, mesh, axis)
    if not pg.has_csc:
        raise ValueError(
            "distributed_pagerank needs the partitioned CSC mirror; "
            "partition a Graph built with build_csc=True")
    return pagerank(pg.shard(mesh, axis), damping=damping,
                    max_iter=iters).rank


# ---------------------------------------------------------------------------
# algebraic primitives on a partition (delegate to the Graph primitives —
# they dispatch through the sharded providers via ShardedGraph)
# ---------------------------------------------------------------------------


def distributed_label_propagation(pg: PartitionedGraph, mesh: Mesh,
                                  axis: str = "graph", **kwargs):
    """Label propagation on the partition: the one-hot SpMM blocks run
    through the sharded "spmm" provider; labels bit-match the
    single-device primitive."""
    from .primitives.label_propagation import label_propagation
    _check_mesh(pg, mesh, axis)
    return label_propagation(pg.shard(mesh, axis), **kwargs)


def distributed_reach(pg: PartitionedGraph, srcs, k: int = 3, *,
                      mesh: Mesh, axis: str = "graph", **kwargs):
    """Batched k-hop reachability on the partition (or-and SpMM closure
    through the sharded provider)."""
    from .primitives.reach import reach_batch
    _check_mesh(pg, mesh, axis)
    return reach_batch(pg.shard(mesh, axis), srcs, k, **kwargs)
