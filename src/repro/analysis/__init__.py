"""repro.analysis — correctness tooling for the provider matrix.

Three tools, one package (ISSUE 9):

  * ``repro.analysis.lint`` — *reprolint*, an AST linter (stdlib ``ast``,
    zero dependencies) enforcing the conventions the engine's
    correctness rests on: no host syncs inside jitted paths, no Python
    control flow over tracers, int32-pinned accumulators under x64,
    fenced wall-clock timing, diagnostics routed through
    ``repro.obs.log``. CLI: ``python -m repro.analysis.lint src/repro``.
  * ``repro.analysis.contracts`` — the registry contract checker: loads
    ``core.backend``'s (op × backend × placement × encoding) provider
    matrix and verifies its invariants (distributed coverage or declared
    fallbacks, encodings declared everywhere, telemetry= on every
    primitive, no silent fallback to single, compile budgets declared).
    CLI: ``python -m repro.analysis.contracts``.
  * ``repro.analysis.sanitize`` — runtime sanitizers: a trace-time
    retrace detector with per-primitive compile budgets
    (``budgets.COMPILE_BUDGETS``) and a Pallas grid/BlockSpec memory
    sanitizer (out-of-bounds tile maps, write-write races between grid
    cells) hooked into ``kernels.runtime.pallas_call`` under
    ``REPRO_SANITIZE=1``.

This module stays import-light on purpose: ``lint`` and ``sanitize``
are stdlib-only, so ``repro.core`` / ``repro.kernels`` may import them
without cycles; ``contracts`` imports the registry and is pulled in
lazily (tests and CLI only).
"""
from __future__ import annotations

__all__ = ["budgets", "contracts", "lint", "sanitize"]
