"""Per-primitive compile budgets — the retrace-detector contract.

A budget is the number of fresh traces ONE fixed workload configuration
(same graph shapes, same batch size, same static flags) is allowed to
cost inside a ``sanitize.retrace_guard`` window, warmup included. The
serving hot path compiles each kind once and then replays the cached
executable; a primitive that traces per call turns a sub-millisecond
query into a multi-second compile — the regression these budgets make
un-ignorable (``tests/test_analysis.py`` pins them on a live hot loop).

Budgets are 1 wherever the primitive is a single jitted impl (one
static config → one trace). ``bc`` gets 2: the full-graph estimator
sweeps sources in fixed-size chunks and a ragged tail chunk legally
costs a second trace.
"""
from __future__ import annotations

COMPILE_BUDGETS: dict[str, int] = {
    "bfs": 1,
    "sssp": 1,
    "pagerank": 1,
    "cc": 1,
    "bc": 2,
    "tc": 1,
}


def budget_for(name: str) -> int:
    """The declared budget for ``name``; unknown names raise — an
    undeclared primitive must not silently get an infinite budget."""
    try:
        return COMPILE_BUDGETS[name]
    except KeyError:
        raise KeyError(
            f"no compile budget declared for primitive {name!r}; add it "
            f"to repro.analysis.budgets.COMPILE_BUDGETS") from None
